#!/usr/bin/env sh
# Promote real CI bench artifacts into the repo, replacing the
# pending-toolchain placeholders (open ROADMAP item).
#
# Usage:
#   artifacts/promote.sh <BENCH_gemm.json> <BENCH_serve.json>
#
# Download both artifacts from a green CI run (`BENCH_gemm` and
# `BENCH_serve` of the `rust` job), then run this from `rust/`. The
# script validates that each file is a real measured run (not a
# placeholder, required keys present, pre-encode counters live) before
# copying it over the checked-in placeholder.
set -eu

if [ "$#" -ne 2 ]; then
    echo "usage: $0 <BENCH_gemm.json> <BENCH_serve.json>" >&2
    exit 2
fi

here="$(dirname "$0")"

python3 - "$1" "$2" <<'EOF'
import json
import sys

gemm = json.load(open(sys.argv[1]))
serve = json.load(open(sys.argv[2]))

def fail(msg):
    sys.exit(f"refusing to promote: {msg}")

for name, doc in (("BENCH_gemm", gemm), ("BENCH_serve", serve)):
    if doc.get("status") == "pending-toolchain-run":
        fail(f"{name} is still a placeholder, not a measured run")

if not isinstance(gemm.get("results"), list) or not gemm["results"]:
    fail("BENCH_gemm has no results series")
names = {r.get("name", "") for r in gemm["results"]}
for needle in ("nibble-direct", "kernel="):
    if not any(needle in n for n in names):
        fail(f"BENCH_gemm is missing the {needle!r} series (old bench binary?)")

for key in ("pre_encoded_ops", "encode_stage_ms", "cache_budget_mb", "p99_ms"):
    if key not in serve:
        fail(f"BENCH_serve is missing {key!r} (old serve-sim binary?)")
if serve.get("mode") != "async":
    fail("BENCH_serve must come from the --async smoke (mode != async)")
if not serve["pre_encoded_ops"]:
    fail("BENCH_serve reports zero pre-encoded ops — pipeline not live")

print("both artifacts are measured runs with live pipeline counters")
EOF

cp "$1" "$here/BENCH_gemm.json"
cp "$2" "$here/BENCH_serve.json"
echo "promoted: $here/BENCH_gemm.json and $here/BENCH_serve.json"
echo "commit them to close the ROADMAP artifact-promotion item"
