#!/usr/bin/env sh
# Promote real CI bench artifacts into the repo, replacing the
# pending-toolchain placeholders (open ROADMAP item).
#
# Usage:
#   artifacts/promote.sh <BENCH_gemm.json> <BENCH_serve.json> [autotune.json] [BENCH_fabric.json] [BENCH_registry.json]
#
# Download the artifacts from a green CI run (`BENCH_gemm`,
# `BENCH_serve`, and optionally `autotune` / `BENCH_fabric` /
# `BENCH_registry` of the `rust` job), then run this from `rust/`. The
# script validates that each file is a real measured run (not a
# placeholder, required keys present, pre-encode counters live,
# executed-kernel accounting consistent) before copying it over the
# checked-in placeholder. The optional files are classified by content,
# so their order does not matter. The autotune table additionally has
# its `boosters-autotune-v1` schema checked entry-by-entry so a
# malformed table can never be promoted into the registry's load path;
# the fabric artifact must be a bit-verified run with live dedup
# counters; the registry artifact must be a bit-verified warm start
# with zero weight encodes and live cross-epoch dedup.
set -eu

if [ "$#" -lt 2 ] || [ "$#" -gt 5 ]; then
    echo "usage: $0 <BENCH_gemm.json> <BENCH_serve.json> [autotune.json] [BENCH_fabric.json] [BENCH_registry.json]" >&2
    exit 2
fi

here="$(dirname "$0")"

python3 - "$1" "$2" <<'EOF'
import json
import sys

gemm = json.load(open(sys.argv[1]))
serve = json.load(open(sys.argv[2]))

def fail(msg):
    sys.exit(f"refusing to promote: {msg}")

for name, doc in (("BENCH_gemm", gemm), ("BENCH_serve", serve)):
    if doc.get("status") == "pending-toolchain-run":
        fail(f"{name} is still a placeholder, not a measured run")

if not isinstance(gemm.get("results"), list) or not gemm["results"]:
    fail("BENCH_gemm has no results series")
names = {r.get("name", "") for r in gemm["results"]}
for needle in ("nibble-direct", "kernel="):
    if not any(needle in n for n in names):
        fail(f"BENCH_gemm is missing the {needle!r} series (old bench binary?)")

for key in (
    "pre_encoded_ops",
    "encode_stage_ms",
    "cache_budget_mb",
    "p99_ms",
    # PR 10 schema bump: stale pre-grouping serve artifacts (no
    # weight-stationary counters) are rejected, not silently promoted.
    "grouped_ops",
    "ungrouped_ops",
    "weight_plane_loads_avoided_bytes",
):
    if key not in serve:
        fail(f"BENCH_serve is missing {key!r} (old serve-sim binary?)")
if serve.get("mode") != "async":
    fail("BENCH_serve must come from the --async smoke (mode != async)")
if not serve["pre_encoded_ops"]:
    fail("BENCH_serve reports zero pre-encoded ops — pipeline not live")
if (serve.get("grouped_ops") or 0) + (serve.get("ungrouped_ops") or 0) != serve.get(
    "completed"
):
    fail("BENCH_serve grouped_ops + ungrouped_ops must partition completed ops")
kops = serve.get("kernel_ops")
if not isinstance(kops, list) or not kops:
    fail("BENCH_serve has no kernel_ops series (old serve-sim binary?)")
if sum(e.get("ops", 0) for e in kops) != serve.get("completed"):
    fail("BENCH_serve kernel_ops do not sum to completed ops")

print("BENCH_gemm and BENCH_serve are measured runs with live pipeline counters")
EOF

cp "$1" "$here/BENCH_gemm.json"
cp "$2" "$here/BENCH_serve.json"
promoted="$here/BENCH_gemm.json and $here/BENCH_serve.json"
shift 2

for extra in "$@"; do
    # Classify by content (validation lives with the classification):
    # an autotune table vs a fabric serving artifact.
    kind=$(python3 - "$extra" <<'EOF'
import json
import sys

doc = json.load(open(sys.argv[1]))

def fail(msg):
    sys.exit(f"refusing to promote: {msg}")

if doc.get("status") == "pending-toolchain-run":
    fail(f"{sys.argv[1]} is still a placeholder, not a measured run")

if doc.get("schema") == "boosters-autotune-v1":
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        fail("autotune table has no entries — run bench --autotune first")
    layouts = {"i4x2", "i8", "i16"}
    blocks = {"b16", "b64", "bwide"}
    mnks = {"small", "medium", "large"}
    for i, e in enumerate(entries):
        for key in ("x", "w", "block_bucket", "mnk_bucket", "kernel"):
            if key not in e:
                fail(f"autotune entry {i} is missing {key!r}")
        if e["x"] not in layouts or e["w"] not in layouts:
            fail(f"autotune entry {i} has unknown layout {e['x']!r}/{e['w']!r}")
        if e["block_bucket"] not in blocks:
            fail(f"autotune entry {i} has unknown block bucket {e['block_bucket']!r}")
        if e["mnk_bucket"] not in mnks:
            fail(f"autotune entry {i} has unknown mnk bucket {e['mnk_bucket']!r}")
        if not isinstance(e["kernel"], str) or not e["kernel"]:
            fail(f"autotune entry {i} has an empty kernel name")
    print("autotune")
elif doc.get("suite") == "serve_fabric":
    if not doc.get("verified"):
        fail("BENCH_fabric run was not bit-verified vs the scalar reference")
    if doc.get("failed"):
        fail(f"BENCH_fabric run lost {doc['failed']} accepted op(s)")
    if not doc.get("dedup_hits"):
        fail("BENCH_fabric reports zero dedup hits — digest dedup not live")
    if doc.get("killed_runner") and not doc.get("failovers"):
        fail("BENCH_fabric killed a runner but recorded no failovers")
    print("fabric")
elif doc.get("suite") == "serve_registry":
    if not doc.get("verified"):
        fail("BENCH_registry run was not bit-verified vs a fresh encode")
    if doc.get("weight_encodes_warm", 1):
        fail(
            "BENCH_registry warm start performed "
            f"{doc.get('weight_encodes_warm')} weight encode(s) — "
            "the zero-encode contract is the point of promotion"
        )
    if not doc.get("blobs_deduped") or not doc.get("dedup_ratio"):
        fail("BENCH_registry reports no cross-epoch dedup — store not live")
    if doc.get("warm_load_ms", -1) < 0:
        fail("BENCH_registry has no warm_load_ms timing")
    print("registry")
else:
    fail(f"{sys.argv[1]} is not an autotune table, fabric, or registry artifact")
EOF
) || exit 1
    case "$kind" in
        autotune)
            cp "$extra" "$here/autotune.json"
            promoted="$promoted and $here/autotune.json"
            ;;
        fabric)
            cp "$extra" "$here/BENCH_fabric.json"
            promoted="$promoted and $here/BENCH_fabric.json"
            ;;
        registry)
            cp "$extra" "$here/BENCH_registry.json"
            promoted="$promoted and $here/BENCH_registry.json"
            ;;
    esac
done

echo "promoted: $promoted"
echo "commit them to close the ROADMAP artifact-promotion item"
