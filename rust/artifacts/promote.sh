#!/usr/bin/env sh
# Promote real CI bench artifacts into the repo, replacing the
# pending-toolchain placeholders (open ROADMAP item).
#
# Usage:
#   artifacts/promote.sh <BENCH_gemm.json> <BENCH_serve.json> [autotune.json]
#
# Download the artifacts from a green CI run (`BENCH_gemm`,
# `BENCH_serve`, and optionally `autotune` of the `rust` job), then run
# this from `rust/`. The script validates that each file is a real
# measured run (not a placeholder, required keys present, pre-encode
# counters live, executed-kernel accounting consistent) before copying
# it over the checked-in placeholder. The autotune table additionally
# has its `boosters-autotune-v1` schema checked entry-by-entry so a
# malformed table can never be promoted into the registry's load path.
set -eu

if [ "$#" -lt 2 ] || [ "$#" -gt 3 ]; then
    echo "usage: $0 <BENCH_gemm.json> <BENCH_serve.json> [autotune.json]" >&2
    exit 2
fi

here="$(dirname "$0")"

python3 - "$@" <<'EOF'
import json
import sys

gemm = json.load(open(sys.argv[1]))
serve = json.load(open(sys.argv[2]))
tune = json.load(open(sys.argv[3])) if len(sys.argv) > 3 else None

def fail(msg):
    sys.exit(f"refusing to promote: {msg}")

for name, doc in (("BENCH_gemm", gemm), ("BENCH_serve", serve)):
    if doc.get("status") == "pending-toolchain-run":
        fail(f"{name} is still a placeholder, not a measured run")

if not isinstance(gemm.get("results"), list) or not gemm["results"]:
    fail("BENCH_gemm has no results series")
names = {r.get("name", "") for r in gemm["results"]}
for needle in ("nibble-direct", "kernel="):
    if not any(needle in n for n in names):
        fail(f"BENCH_gemm is missing the {needle!r} series (old bench binary?)")

for key in ("pre_encoded_ops", "encode_stage_ms", "cache_budget_mb", "p99_ms"):
    if key not in serve:
        fail(f"BENCH_serve is missing {key!r} (old serve-sim binary?)")
if serve.get("mode") != "async":
    fail("BENCH_serve must come from the --async smoke (mode != async)")
if not serve["pre_encoded_ops"]:
    fail("BENCH_serve reports zero pre-encoded ops — pipeline not live")
kops = serve.get("kernel_ops")
if not isinstance(kops, list) or not kops:
    fail("BENCH_serve has no kernel_ops series (old serve-sim binary?)")
if sum(e.get("ops", 0) for e in kops) != serve.get("completed"):
    fail("BENCH_serve kernel_ops do not sum to completed ops")

if tune is not None:
    if tune.get("status") == "pending-toolchain-run":
        fail("autotune table is still a placeholder, not a measured run")
    if tune.get("schema") != "boosters-autotune-v1":
        fail(f"autotune schema {tune.get('schema')!r} != 'boosters-autotune-v1'")
    entries = tune.get("entries")
    if not isinstance(entries, list) or not entries:
        fail("autotune table has no entries — run bench --autotune first")
    layouts = {"i4x2", "i8", "i16"}
    blocks = {"b16", "b64", "bwide"}
    mnks = {"small", "medium", "large"}
    for i, e in enumerate(entries):
        for key in ("x", "w", "block_bucket", "mnk_bucket", "kernel"):
            if key not in e:
                fail(f"autotune entry {i} is missing {key!r}")
        if e["x"] not in layouts or e["w"] not in layouts:
            fail(f"autotune entry {i} has unknown layout {e['x']!r}/{e['w']!r}")
        if e["block_bucket"] not in blocks:
            fail(f"autotune entry {i} has unknown block bucket {e['block_bucket']!r}")
        if e["mnk_bucket"] not in mnks:
            fail(f"autotune entry {i} has unknown mnk bucket {e['mnk_bucket']!r}")
        if not isinstance(e["kernel"], str) or not e["kernel"]:
            fail(f"autotune entry {i} has an empty kernel name")

print("all artifacts are measured runs with live pipeline counters")
EOF

cp "$1" "$here/BENCH_gemm.json"
cp "$2" "$here/BENCH_serve.json"
promoted="$here/BENCH_gemm.json and $here/BENCH_serve.json"
if [ "$#" -eq 3 ]; then
    cp "$3" "$here/autotune.json"
    promoted="$promoted and $here/autotune.json"
fi
echo "promoted: $promoted"
echo "commit them to close the ROADMAP artifact-promotion item"
