#!/usr/bin/env python3
"""Perf gate: fail CI when a bench-of-record regresses vs the promoted
artifact.

Usage:
    python3 rust/artifacts/perf_gate.py <fresh BENCH_gemm.json> <promoted BENCH_gemm.json>

Compares ``mean_ns`` of every bench of record present in both files and
exits non-zero if any fresh mean is more than ``THRESHOLD`` times the
promoted mean. While the promoted artifact is still the
pending-toolchain placeholder the gate skips with a notice instead of
passing vacuously -- promoting a measured run (artifacts/promote.sh)
arms it.

The 15% threshold is deliberately loose: CI runners are heterogeneous
and the bench budget is trimmed (REPRO_BENCH_BUDGET_MS), so the gate is
a tripwire for order-of-magnitude mistakes (a dispatch change that
routes large GEMMs to the scalar kernel, an encode path that stopped
being nibble-direct), not a microbenchmark referee.
"""

import json
import sys

THRESHOLD = 1.15

# Fallback list for promoted artifacts that predate the
# ``benches_of_record`` key; kept in sync with the placeholder in
# artifacts/BENCH_gemm.json.
BENCHES_OF_RECORD = [
    "hbfp_gemm SCALAR 512^3 m=4 b=64 (MACs)",
    "hbfp_gemm PACKED 512^3 m=4 b=64 (MACs)",
    "BfpMatrix::gemm PACKED pre-encoded 512^3 (MACs)",
    "encode_into 1024x1024 m=4 b=64 nibble-direct (f32)",
    "encode_into 1024x1024 m=6 b=64 i8 writer (f32)",
    "encode_transposed 1024x256 m=4 b=64 nibble-direct (f32)",
    "encode_transposed 1024x256 m=6 b=64 i8 writer (f32)",
    "BatchGemm 64 heterogeneous ops (MACs)",
    "sequential BatchGemm 1-op batches, same 64 ops (MACs)",
    "sequential hbfp_gemm via service, same 64 ops (MACs)",
    "BfpService async pipeline 64 ops decode-overlap (MACs)",
]


def main(argv):
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    fresh = json.load(open(argv[1]))
    promoted = json.load(open(argv[2]))

    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::perf gate skipped: promoted BENCH_gemm.json is still the "
            "pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm the gate"
        )
        return 0

    record = promoted.get("benches_of_record") or BENCHES_OF_RECORD
    fresh_by = {r["name"]: r for r in fresh.get("results", [])}
    prom_by = {r["name"]: r for r in promoted.get("results", [])}

    checked = 0
    failures = []
    for name in record:
        f, p = fresh_by.get(name), prom_by.get(name)
        if f is None or p is None:
            where = "fresh" if f is None else "promoted"
            print(
                f"::warning::perf gate: bench of record {name!r} missing from "
                f"the {where} artifact; skipped"
            )
            continue
        ratio = f["mean_ns"] / p["mean_ns"]
        checked += 1
        verdict = "REGRESSION" if ratio > THRESHOLD else "ok"
        print(
            f"{verdict:10} {name}: {p['mean_ns']:.0f} -> {f['mean_ns']:.0f} ns "
            f"({ratio:.2f}x)"
        )
        if ratio > THRESHOLD:
            failures.append((name, ratio))

    if checked == 0:
        print(
            "perf gate: no benches of record overlapped between the fresh and "
            "promoted artifacts -- bench names drifted; update "
            "benches_of_record when renaming a series",
            file=sys.stderr,
        )
        return 1
    if failures:
        for name, ratio in failures:
            print(
                f"::error::perf regression: {name} is {ratio:.2f}x the promoted "
                f"mean (threshold {THRESHOLD:.2f}x)"
            )
        return 1
    print(f"perf gate passed: {checked} benches of record within {THRESHOLD:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
