#!/usr/bin/env python3
"""Perf gate: fail CI when a bench-of-record regresses vs the promoted
artifact.

Usage:
    python3 rust/artifacts/perf_gate.py <fresh BENCH_gemm.json> <promoted BENCH_gemm.json>
    python3 rust/artifacts/perf_gate.py --fabric <fresh BENCH_fabric.json> <promoted BENCH_fabric.json>

Compares ``mean_ns`` of every bench of record present in both files and
exits non-zero if any fresh mean is more than ``THRESHOLD`` times the
promoted mean. While the promoted artifact is still the
pending-toolchain placeholder the gate skips with a notice instead of
passing vacuously -- promoting a measured run (artifacts/promote.sh)
arms it.

The 15% threshold is deliberately loose: CI runners are heterogeneous
and the bench budget is trimmed (REPRO_BENCH_BUDGET_MS), so the gate is
a tripwire for order-of-magnitude mistakes (a dispatch change that
routes large GEMMs to the scalar kernel, an encode path that stopped
being nibble-direct), not a microbenchmark referee.
"""

import json
import sys

THRESHOLD = 1.15

# Fallback list for promoted artifacts that predate the
# ``benches_of_record`` key; kept in sync with the placeholder in
# artifacts/BENCH_gemm.json.
BENCHES_OF_RECORD = [
    "hbfp_gemm SCALAR 512^3 m=4 b=64 (MACs)",
    "hbfp_gemm PACKED 512^3 m=4 b=64 (MACs)",
    "BfpMatrix::gemm PACKED pre-encoded 512^3 (MACs)",
    "encode_into 1024x1024 m=4 b=64 nibble-direct (f32)",
    "encode_into 1024x1024 m=6 b=64 i8 writer (f32)",
    "encode_transposed 1024x256 m=4 b=64 nibble-direct (f32)",
    "encode_transposed 1024x256 m=6 b=64 i8 writer (f32)",
    "BatchGemm 64 heterogeneous ops (MACs)",
    "sequential BatchGemm 1-op batches, same 64 ops (MACs)",
    "sequential hbfp_gemm via service, same 64 ops (MACs)",
    "BfpService async pipeline 64 ops decode-overlap (MACs)",
]


# Fabric serving is wall-clock noisy (process spawn, loopback TCP, a
# deliberate runner kill mid-run), so its regression threshold is looser
# than the microbench one: a 2x p95 or halved throughput is a real
# routing/dedup mistake, not scheduler jitter.
FABRIC_THRESHOLD = 2.0


def fabric_gate(fresh_path, promoted_path):
    """``--fabric`` mode: BENCH_fabric.json of record.

    Always checks the fresh artifact's structural invariants (they are
    deterministic outcomes of the protocol, not timings); compares
    p95_ms / throughput_rps against the promoted artifact only once one
    has been promoted.
    """
    fresh = json.load(open(fresh_path))

    # Structural invariants: these hold on any healthy run, regardless
    # of machine speed, and are the acceptance criteria of the fabric.
    assert fresh.get("suite") == "serve_fabric", fresh.get("suite")
    assert fresh["completed"] + fresh["failed"] == fresh["accepted"], (
        fresh["completed"],
        fresh["failed"],
        fresh["accepted"],
    )
    assert fresh["failed"] == 0, f"fabric lost {fresh['failed']} accepted op(s)"
    assert fresh["verified"], "fabric run was not bit-verified vs scalar"
    # Digest dedup must be live: repeated weights resolve without
    # re-sending plane bytes, and transfers are bounded by
    # |weights| x |runners|, never by the op count.
    assert fresh["dedup_hits"] > 0, "operand dedup never hit"
    assert 0.0 <= fresh["dedup_hit_rate"] <= 1.0, fresh["dedup_hit_rate"]
    assert fresh["plane_bytes_sent"] > 0, "no operand planes ever moved"
    if fresh.get("killed_runner"):
        assert fresh["alive_runners_end"] < fresh["runners"], (
            fresh["alive_runners_end"],
            fresh["runners"],
        )
        assert fresh["failovers"] > 0, (
            "a runner was killed but no in-flight op failed over"
        )
    print(
        f"fabric invariants ok: {fresh['completed']}/{fresh['accepted']} completed, "
        f"{fresh['failovers']} failovers, dedup {fresh['dedup_hits']} hits "
        f"({100 * fresh['dedup_hit_rate']:.0f}%), "
        f"{fresh['plane_bytes_sent']} B sent / {fresh['plane_bytes_deduped']} B deduped"
    )

    promoted = json.load(open(promoted_path))
    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::fabric perf gate skipped: promoted BENCH_fabric.json is "
            "still the pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm it"
        )
        return 0

    failures = []
    p95_ratio = fresh["p95_ms"] / max(promoted["p95_ms"], 1e-9)
    rps_ratio = promoted["throughput_rps"] / max(fresh["throughput_rps"], 1e-9)
    for label, ratio in (("p95_ms", p95_ratio), ("throughput_rps", rps_ratio)):
        verdict = "REGRESSION" if ratio > FABRIC_THRESHOLD else "ok"
        print(f"{verdict:10} fabric {label}: {ratio:.2f}x vs promoted")
        if ratio > FABRIC_THRESHOLD:
            failures.append(label)
    if failures:
        for label in failures:
            print(
                f"::error::fabric perf regression on {label} "
                f"(threshold {FABRIC_THRESHOLD:.1f}x)"
            )
        return 1
    print("fabric perf gate passed")
    return 0


def main(argv):
    if len(argv) == 4 and argv[1] == "--fabric":
        return fabric_gate(argv[2], argv[3])
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    fresh = json.load(open(argv[1]))
    promoted = json.load(open(argv[2]))

    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::perf gate skipped: promoted BENCH_gemm.json is still the "
            "pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm the gate"
        )
        return 0

    record = promoted.get("benches_of_record") or BENCHES_OF_RECORD
    fresh_by = {r["name"]: r for r in fresh.get("results", [])}
    prom_by = {r["name"]: r for r in promoted.get("results", [])}

    checked = 0
    failures = []
    for name in record:
        f, p = fresh_by.get(name), prom_by.get(name)
        if f is None or p is None:
            where = "fresh" if f is None else "promoted"
            print(
                f"::warning::perf gate: bench of record {name!r} missing from "
                f"the {where} artifact; skipped"
            )
            continue
        ratio = f["mean_ns"] / p["mean_ns"]
        checked += 1
        verdict = "REGRESSION" if ratio > THRESHOLD else "ok"
        print(
            f"{verdict:10} {name}: {p['mean_ns']:.0f} -> {f['mean_ns']:.0f} ns "
            f"({ratio:.2f}x)"
        )
        if ratio > THRESHOLD:
            failures.append((name, ratio))

    if checked == 0:
        print(
            "perf gate: no benches of record overlapped between the fresh and "
            "promoted artifacts -- bench names drifted; update "
            "benches_of_record when renaming a series",
            file=sys.stderr,
        )
        return 1
    if failures:
        for name, ratio in failures:
            print(
                f"::error::perf regression: {name} is {ratio:.2f}x the promoted "
                f"mean (threshold {THRESHOLD:.2f}x)"
            )
        return 1
    print(f"perf gate passed: {checked} benches of record within {THRESHOLD:.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
