#!/usr/bin/env python3
"""Perf gate: fail CI when a bench-of-record regresses vs the promoted
artifact.

Usage:
    python3 rust/artifacts/perf_gate.py <fresh BENCH_gemm.json> <promoted BENCH_gemm.json>
    python3 rust/artifacts/perf_gate.py --fabric <fresh BENCH_fabric.json> <promoted BENCH_fabric.json>
    python3 rust/artifacts/perf_gate.py --registry <fresh BENCH_registry.json> <promoted BENCH_registry.json>

Compares ``mean_ns`` of every bench of record present in both files and
exits non-zero if any fresh mean is more than ``THRESHOLD`` times the
promoted mean. While the promoted artifact is still the
pending-toolchain placeholder the gate skips with a notice instead of
passing vacuously -- promoting a measured run (artifacts/promote.sh)
arms it.

The 15% threshold is deliberately loose: CI runners are heterogeneous
and the bench budget is trimmed (REPRO_BENCH_BUDGET_MS), so the gate is
a tripwire for order-of-magnitude mistakes (a dispatch change that
routes large GEMMs to the scalar kernel, an encode path that stopped
being nibble-direct), not a microbenchmark referee.
"""

import json
import sys

THRESHOLD = 1.15

# Fallback list for promoted artifacts that predate the
# ``benches_of_record`` key; kept in sync with the placeholder in
# artifacts/BENCH_gemm.json.
BENCHES_OF_RECORD = [
    "hbfp_gemm SCALAR 512^3 m=4 b=64 (MACs)",
    "hbfp_gemm PACKED 512^3 m=4 b=64 (MACs)",
    "BfpMatrix::gemm PACKED pre-encoded 512^3 (MACs)",
    "encode_into 1024x1024 m=4 b=64 nibble-direct (f32)",
    "encode_into 1024x1024 m=6 b=64 i8 writer (f32)",
    "encode_transposed 1024x256 m=4 b=64 nibble-direct (f32)",
    "encode_transposed 1024x256 m=6 b=64 i8 writer (f32)",
    "BatchGemm 64 heterogeneous ops (MACs)",
    "BatchGemm 64 shared-weight ops grouped (MACs)",
    "BatchGemm 64 shared-weight ops ungrouped (MACs)",
    "sequential BatchGemm 1-op batches, same 64 ops (MACs)",
    "sequential hbfp_gemm via service, same 64 ops (MACs)",
    "BfpService async pipeline 64 ops decode-overlap (MACs)",
]

# Weight-stationary grouping is a pure memory-traffic optimization over
# the identical MAC work, so grouped slower than ungrouped by more than
# measurement noise means the grouping path itself regressed. Checked
# structurally on the FRESH artifact (both series ride in the same run,
# so runner speed cancels) -- it is live even while the promoted
# artifact is still the placeholder.
GROUPED_SERIES = "BatchGemm 64 shared-weight ops grouped (MACs)"
UNGROUPED_SERIES = "BatchGemm 64 shared-weight ops ungrouped (MACs)"


def grouped_structural_check(fresh):
    by_name = {r["name"]: r for r in fresh.get("results", [])}
    g, u = by_name.get(GROUPED_SERIES), by_name.get(UNGROUPED_SERIES)
    if g is None or u is None:
        print(
            "::warning::perf gate: grouped/ungrouped shared-weight series "
            "missing from the fresh artifact; structural check skipped"
        )
        return 0
    ratio = g["mean_ns"] / max(u["mean_ns"], 1e-9)
    verdict = "REGRESSION" if ratio > THRESHOLD else "ok"
    print(
        f"{verdict:10} grouped vs ungrouped (same run): {u['mean_ns']:.0f} -> "
        f"{g['mean_ns']:.0f} ns ({ratio:.2f}x)"
    )
    if ratio > THRESHOLD:
        print(
            f"::error::weight-stationary grouping is {ratio:.2f}x the ungrouped "
            f"time on the same 64 shared-weight ops (threshold {THRESHOLD:.2f}x) "
            f"-- grouping must never lose to per-op execution"
        )
        return 1
    return 0


# Fabric serving is wall-clock noisy (process spawn, loopback TCP, a
# deliberate runner kill mid-run), so its regression threshold is looser
# than the microbench one: a 2x p95 or halved throughput is a real
# routing/dedup mistake, not scheduler jitter.
FABRIC_THRESHOLD = 2.0


def fabric_gate(fresh_path, promoted_path):
    """``--fabric`` mode: BENCH_fabric.json of record.

    Always checks the fresh artifact's structural invariants (they are
    deterministic outcomes of the protocol, not timings); compares
    p95_ms / throughput_rps against the promoted artifact only once one
    has been promoted.
    """
    fresh = json.load(open(fresh_path))

    # Structural invariants: these hold on any healthy run, regardless
    # of machine speed, and are the acceptance criteria of the fabric.
    assert fresh.get("suite") == "serve_fabric", fresh.get("suite")
    assert fresh["completed"] + fresh["failed"] == fresh["accepted"], (
        fresh["completed"],
        fresh["failed"],
        fresh["accepted"],
    )
    assert fresh["failed"] == 0, f"fabric lost {fresh['failed']} accepted op(s)"
    assert fresh["verified"], "fabric run was not bit-verified vs scalar"
    # Digest dedup must be live: repeated weights resolve without
    # re-sending plane bytes, and transfers are bounded by
    # |weights| x |runners|, never by the op count.
    assert fresh["dedup_hits"] > 0, "operand dedup never hit"
    assert 0.0 <= fresh["dedup_hit_rate"] <= 1.0, fresh["dedup_hit_rate"]
    assert fresh["plane_bytes_sent"] > 0, "no operand planes ever moved"
    if fresh.get("killed_runner"):
        assert fresh["alive_runners_end"] < fresh["runners"], (
            fresh["alive_runners_end"],
            fresh["runners"],
        )
        assert fresh["failovers"] > 0, (
            "a runner was killed but no in-flight op failed over"
        )
    print(
        f"fabric invariants ok: {fresh['completed']}/{fresh['accepted']} completed, "
        f"{fresh['failovers']} failovers, dedup {fresh['dedup_hits']} hits "
        f"({100 * fresh['dedup_hit_rate']:.0f}%), "
        f"{fresh['plane_bytes_sent']} B sent / {fresh['plane_bytes_deduped']} B deduped"
    )

    promoted = json.load(open(promoted_path))
    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::fabric perf gate skipped: promoted BENCH_fabric.json is "
            "still the pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm it"
        )
        return 0

    failures = []
    p95_ratio = fresh["p95_ms"] / max(promoted["p95_ms"], 1e-9)
    rps_ratio = promoted["throughput_rps"] / max(fresh["throughput_rps"], 1e-9)
    for label, ratio in (("p95_ms", p95_ratio), ("throughput_rps", rps_ratio)):
        verdict = "REGRESSION" if ratio > FABRIC_THRESHOLD else "ok"
        print(f"{verdict:10} fabric {label}: {ratio:.2f}x vs promoted")
        if ratio > FABRIC_THRESHOLD:
            failures.append(label)
    if failures:
        for label in failures:
            print(
                f"::error::fabric perf regression on {label} "
                f"(threshold {FABRIC_THRESHOLD:.1f}x)"
            )
        return 1
    print("fabric perf gate passed")
    return 0


# Warm-start load is mmap + cache publish, so it is less noisy than the
# fabric run, but CI filesystems vary (page-cache state, overlay fs);
# 2x on the load time is an algorithmic mistake (a decode or re-encode
# snuck back into the load path), not jitter.
REGISTRY_THRESHOLD = 2.0


def registry_gate(fresh_path, promoted_path):
    """``--registry`` mode: BENCH_registry.json of record.

    The structural invariants ARE the PR's acceptance bar and hold on
    any healthy run: cross-epoch dedup live, warm start bit-verified
    with zero weight encodes. Timings compare against the promoted
    artifact only once one has been promoted.
    """
    fresh = json.load(open(fresh_path))

    assert fresh.get("suite") == "serve_registry", fresh.get("suite")
    assert fresh["verified"], "registry warm start was not bit-verified"
    assert fresh["epochs"] >= 2, f"need >= 2 epochs to observe dedup, got {fresh['epochs']}"
    assert fresh["layers_pushed"] == fresh["epochs"] * fresh["layers_per_epoch"], (
        fresh["layers_pushed"],
        fresh["epochs"],
        fresh["layers_per_epoch"],
    )
    # Cross-epoch dedup must be live: unchanged layers reuse blobs, so
    # strictly fewer blobs exist than layers were pushed.
    assert fresh["blobs_written"] + fresh["blobs_deduped"] == fresh["layers_pushed"], (
        fresh["blobs_written"],
        fresh["blobs_deduped"],
        fresh["layers_pushed"],
    )
    assert fresh["blobs_deduped"] > 0 and fresh["dedup_ratio"] > 0.0, (
        "cross-epoch dedup never reused a blob"
    )
    assert fresh["blob_count"] == fresh["blobs_written"], (
        fresh["blob_count"],
        fresh["blobs_written"],
    )
    assert fresh["bytes_written"] > 0 and fresh["blob_bytes"] > 0
    # The tentpole's zero-encode contract: the warm path installed every
    # final-epoch layer and the hot path never fell back to the encoder.
    assert fresh["warm_installed"] == fresh["layers_per_epoch"], (
        fresh["warm_installed"],
        fresh["layers_per_epoch"],
    )
    assert fresh["weight_encodes_warm"] == 0, (
        f"warm start performed {fresh['weight_encodes_warm']} weight encode(s)"
    )
    assert fresh["warm_cache_hits"] >= fresh["warm_installed"], (
        fresh["warm_cache_hits"],
        fresh["warm_installed"],
    )
    assert fresh["encode_ops_avoided"] == fresh["warm_installed"]
    assert fresh["warm_plane_bytes"] > 0
    assert 0 <= fresh["mapped_loads"] <= fresh["warm_installed"]
    assert fresh["warm_load_ms"] >= 0.0 and fresh["cold_encode_ms"] >= 0.0
    assert fresh["completed"] == fresh["requests"], (
        fresh["completed"],
        fresh["requests"],
    )
    print(
        f"registry invariants ok: {fresh['layers_pushed']} layers pushed over "
        f"{fresh['epochs']} epochs -> {fresh['blob_count']} blobs "
        f"(dedup {100 * fresh['dedup_ratio']:.0f}%), warm start installed "
        f"{fresh['warm_installed']} planes in {fresh['warm_load_ms']:.2f} ms "
        f"({fresh['mapped_loads']} mmap-served) with 0 weight encodes vs "
        f"{fresh['cold_encode_ms']:.2f} ms cold encode"
    )

    promoted = json.load(open(promoted_path))
    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::registry perf gate skipped: promoted BENCH_registry.json "
            "is still the pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm it"
        )
        return 0

    ratio = fresh["warm_load_ms"] / max(promoted["warm_load_ms"], 1e-9)
    verdict = "REGRESSION" if ratio > REGISTRY_THRESHOLD else "ok"
    print(f"{verdict:10} registry warm_load_ms: {ratio:.2f}x vs promoted")
    if ratio > REGISTRY_THRESHOLD:
        print(
            f"::error::registry warm-start load regressed {ratio:.2f}x vs the "
            f"promoted artifact (threshold {REGISTRY_THRESHOLD:.1f}x) -- did a "
            f"decode or re-encode sneak into the zero-copy load path?"
        )
        return 1
    print("registry perf gate passed")
    return 0


def main(argv):
    if len(argv) == 4 and argv[1] == "--fabric":
        return fabric_gate(argv[2], argv[3])
    if len(argv) == 4 and argv[1] == "--registry":
        return registry_gate(argv[2], argv[3])
    if len(argv) != 3:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    fresh = json.load(open(argv[1]))
    promoted = json.load(open(argv[2]))

    # The grouped-vs-ungrouped comparison is within-run, so it runs on
    # every fresh artifact BEFORE the placeholder skip below.
    structural_rc = grouped_structural_check(fresh)

    if promoted.get("status") == "pending-toolchain-run":
        print(
            "::notice::perf gate skipped: promoted BENCH_gemm.json is still the "
            "pending-toolchain placeholder; promote a green run "
            "(artifacts/promote.sh) to arm the gate"
        )
        return structural_rc

    record = promoted.get("benches_of_record") or BENCHES_OF_RECORD
    fresh_by = {r["name"]: r for r in fresh.get("results", [])}
    prom_by = {r["name"]: r for r in promoted.get("results", [])}

    checked = 0
    failures = []
    for name in record:
        f, p = fresh_by.get(name), prom_by.get(name)
        if f is None or p is None:
            where = "fresh" if f is None else "promoted"
            print(
                f"::warning::perf gate: bench of record {name!r} missing from "
                f"the {where} artifact; skipped"
            )
            continue
        ratio = f["mean_ns"] / p["mean_ns"]
        checked += 1
        verdict = "REGRESSION" if ratio > THRESHOLD else "ok"
        print(
            f"{verdict:10} {name}: {p['mean_ns']:.0f} -> {f['mean_ns']:.0f} ns "
            f"({ratio:.2f}x)"
        )
        if ratio > THRESHOLD:
            failures.append((name, ratio))

    if checked == 0:
        print(
            "perf gate: no benches of record overlapped between the fresh and "
            "promoted artifacts -- bench names drifted; update "
            "benches_of_record when renaming a series",
            file=sys.stderr,
        )
        return 1
    if failures:
        for name, ratio in failures:
            print(
                f"::error::perf regression: {name} is {ratio:.2f}x the promoted "
                f"mean (threshold {THRESHOLD:.2f}x)"
            )
        return 1
    print(f"perf gate passed: {checked} benches of record within {THRESHOLD:.2f}x")
    return structural_rc


if __name__ == "__main__":
    sys.exit(main(sys.argv))
