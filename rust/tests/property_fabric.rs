//! Loopback integration tests for the multi-node execution fabric:
//! a [`FabricRouter`] over two in-process runners (each hosting its own
//! [`ExecRuntime`] behind a real TCP socket) must
//!
//! * return responses **bit-identical** to [`hbfp_gemm_scalar`] — the
//!   same invariant every local execution path pins, now across a wire;
//! * move each distinct weight operand's plane bytes **at most once per
//!   runner** (the digest-dedup negotiation), visible in the router's
//!   hit counters;
//! * survive a runner kill mid-flight: every accepted op still
//!   fulfills, re-placed on the survivor, with the failover counted.

use boosters::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use boosters::exec::{ExecRuntime, Priority, Ticket};
use boosters::fabric::{
    fetch_metrics, serve_on, serve_on_capped, warm_start_store, FabricRouter, RouterConfig,
    RunnerHandle,
};
use boosters::registry::{PushLayer, Registry};
use boosters::util::Rng;
use std::collections::BTreeMap;
use std::net::TcpListener;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// Spawn `n` loopback runners, each with its own two-thread runtime.
fn spawn_fleet(n: usize) -> (Vec<RunnerHandle>, Vec<String>) {
    let mut handles = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = serve_on(listener, Arc::new(ExecRuntime::with_threads(2))).unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    (handles, addrs)
}

/// A mixed-shape op stream over a small working set of repeated
/// weights — the dedup protocol's bread and butter.
fn build_stream(
    rng: &mut Rng,
    distinct_weights: usize,
    ops: usize,
    k: usize,
    c: usize,
) -> (Vec<(Arc<Mat>, BlockFormat)>, Vec<(usize, Arc<Mat>)>) {
    let fmts = [
        BlockFormat::new(4, 64).unwrap(),
        BlockFormat::new(6, 16).unwrap(),
    ];
    let weights: Vec<(Arc<Mat>, BlockFormat)> = (0..distinct_weights)
        .map(|i| {
            let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
            (w, fmts[i % fmts.len()])
        })
        .collect();
    let stream = (0..ops)
        .map(|_| {
            let wi = rng.below(distinct_weights);
            let m = 1 + rng.below(24);
            (wi, Arc::new(Mat::new(m, k, randn(rng, m * k)).unwrap()))
        })
        .collect();
    (weights, stream)
}

fn submit_all(
    router: &FabricRouter,
    weights: &[(Arc<Mat>, BlockFormat)],
    stream: &[(usize, Arc<Mat>)],
) -> Vec<Ticket> {
    stream
        .iter()
        .enumerate()
        .map(|(i, (wi, x))| {
            let (w, fmt) = &weights[*wi];
            // Alternate QoS classes so both sharding paths execute.
            let prio = if i % 2 == 0 {
                Priority::Interactive
            } else {
                Priority::Bulk
            };
            router
                .submit(Arc::clone(x), Arc::clone(w), *fmt, None, prio)
                .expect("loopback fleet under MAC budget must admit")
        })
        .collect()
}

fn assert_bit_identical(
    weights: &[(Arc<Mat>, BlockFormat)],
    stream: &[(usize, Arc<Mat>)],
    tickets: Vec<Ticket>,
) {
    for (i, ((wi, x), ticket)) in stream.iter().zip(tickets).enumerate() {
        let resp = ticket
            .wait()
            .unwrap_or_else(|e| panic!("op {i} lost by the fabric: {e:#}"));
        let (w, fmt) = &weights[*wi];
        let want = hbfp_gemm_scalar(x, w, *fmt).unwrap();
        assert_eq!(resp.out.rows, want.rows, "op {i} row drift");
        assert_eq!(resp.out.cols, want.cols, "op {i} col drift");
        for (j, (g, r)) in resp.out.data.iter().zip(&want.data).enumerate() {
            assert_eq!(
                g.to_bits(),
                r.to_bits(),
                "op {i} elem {j}: fabric result diverged from hbfp_gemm_scalar"
            );
        }
    }
}

#[test]
fn two_runner_fleet_is_bit_identical_and_dedups_weights() {
    let (handles, addrs) = spawn_fleet(2);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    let mut rng = Rng::new(7);
    let (weights, stream) = build_stream(&mut rng, 3, 36, 96, 40);
    let tickets = submit_all(&router, &weights, &stream);
    assert_bit_identical(&weights, &stream, tickets);

    let stats = router.stats();
    assert_eq!(stats.completed, 36, "{stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    // 36 ops over 3 distinct weights: the overwhelming majority of
    // weight references must resolve without moving plane bytes…
    assert!(stats.dedup_hits > 0, "{stats:?}");
    // …and each distinct weight's planes cross the wire at most once
    // per runner — the misses (= PutOperand transfers) are bounded by
    // |weights| × |runners|, never by the op count.
    assert!(
        stats.dedup_misses <= (weights.len() * addrs.len()) as u64,
        "{stats:?}"
    );
    assert_eq!(
        stats.dedup_hits + stats.dedup_misses,
        36,
        "every op references exactly one weight: {stats:?}"
    );
    assert!(stats.plane_bytes_sent > 0, "{stats:?}");
    assert!(
        stats.plane_bytes_deduped >= stats.plane_bytes_sent,
        "repeated references must out-dedup the initial transfers: {stats:?}"
    );
    // The probe protocol ran (first reference per runner), then the
    // known-key set short-circuited it (no probe per repeated op).
    assert!(stats.probes >= 1 && stats.probes <= stats.dedup_misses + 2, "{stats:?}");

    // Both runners saw work (bulk ops round-robin across the fleet).
    for r in &stats.runners {
        assert!(r.alive, "{stats:?}");
        assert!(r.completed > 0, "both runners must share the load: {stats:?}");
    }

    // The runner's Prometheus endpoint serves the pinned exposition
    // format with the fabric counters appended.
    let text = fetch_metrics(&addrs[0]).unwrap();
    assert!(text.contains("# TYPE boosters_exec_submitted_total counter"));
    assert!(text.contains("boosters_fabric_runner_ops_total"));
    assert!(text.contains("boosters_fabric_runner_operands_stored"));

    drop(router);
    for h in handles {
        h.kill();
    }
}

#[test]
fn router_fails_over_killed_runner_without_losing_ops() {
    let (mut handles, addrs) = spawn_fleet(2);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    // Big enough ops that a kill right after submission is guaranteed
    // to catch some of them in flight on the victim.
    let mut rng = Rng::new(11);
    let (weights, stream) = build_stream(&mut rng, 2, 32, 256, 96);
    let tickets = submit_all(&router, &weights, &stream);

    // SIGKILL-equivalent: drop the victim's sockets out from under the
    // router. Accepted ops must re-place on the survivor.
    handles.pop().unwrap().kill();

    assert_bit_identical(&weights, &stream, tickets);
    let stats = router.stats();
    assert_eq!(stats.completed, 32, "no accepted op may be lost: {stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    assert!(
        stats.failovers >= 1,
        "ops in flight on the victim must have re-placed: {stats:?}"
    );
    assert_eq!(router.alive_runners(), 1, "{stats:?}");
    let dead = stats.runners.iter().filter(|r| !r.alive).count();
    assert_eq!(dead, 1, "{stats:?}");

    // The fleet keeps serving after the failover.
    let x = Arc::new(Mat::new(3, 256, randn(&mut rng, 3 * 256)).unwrap());
    let (w, fmt) = &weights[0];
    let t = router
        .submit(Arc::clone(&x), Arc::clone(w), *fmt, None, Priority::Interactive)
        .unwrap();
    let resp = t.wait().unwrap();
    let want = hbfp_gemm_scalar(&x, w, *fmt).unwrap();
    assert!(resp
        .out
        .data
        .iter()
        .zip(&want.data)
        .all(|(g, r)| g.to_bits() == r.to_bits()));

    drop(router);
    for h in handles {
        h.kill();
    }
}

/// Pull one counter out of a runner's snapshot pairs.
fn counter(handle: &RunnerHandle, name: &str) -> u64 {
    handle
        .shared()
        .counters_snapshot()
        .iter()
        .find(|(n, _)| *n == name)
        .map(|(_, v)| *v)
        .unwrap_or_else(|| panic!("runner counter {name:?} missing"))
}

#[test]
fn store_cap_evicts_and_renegotiates_via_need_operand() {
    // A 1-byte store budget: any second install evicts the first (the
    // sole-resident rule keeps exactly one plane alive), so alternating
    // between two weights ping-pongs the store and every revisit of an
    // evicted digest must bounce through NEED_OPERAND re-negotiation.
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = serve_on_capped(listener, Arc::new(ExecRuntime::with_threads(2)), 1).unwrap();
    let addrs = vec![handle.addr().to_string()];
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    let mut rng = Rng::new(23);
    let (weights, _) = build_stream(&mut rng, 2, 0, 64, 32);
    // Serialize ops (wait each before the next) so the eviction
    // ping-pong is deterministic: w0, w1 (evicts w0), w0 again, …
    let ops = 6usize;
    for i in 0..ops {
        let (w, fmt) = &weights[i % 2];
        let m = 2 + i;
        let x = Arc::new(Mat::new(m, 64, randn(&mut rng, m * 64)).unwrap());
        let t = router
            .submit(Arc::clone(&x), Arc::clone(w), *fmt, None, Priority::Interactive)
            .unwrap();
        let resp = t.wait().unwrap_or_else(|e| panic!("op {i} lost: {e:#}"));
        let want = hbfp_gemm_scalar(&x, w, *fmt).unwrap();
        assert!(
            resp.out
                .data
                .iter()
                .zip(&want.data)
                .all(|(g, r)| g.to_bits() == r.to_bits()),
            "op {i} diverged after re-negotiation"
        );
    }

    let stats = router.stats();
    assert_eq!(stats.completed, ops as u64, "{stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    assert!(
        stats.retries >= 1,
        "an evicted digest must have re-negotiated: {stats:?}"
    );
    // The runner attributes every crossing: evictions forced
    // re-transfers, counted apart from first copies — so the dedup
    // story stays monotone instead of silently eroding.
    assert!(counter(&handle, "fabric_runner_operands_evicted") >= 2);
    assert!(counter(&handle, "fabric_runner_operand_bytes_evicted") > 0);
    assert!(counter(&handle, "fabric_runner_need_operand_total") >= 1);
    assert!(counter(&handle, "fabric_runner_operands_retransferred") >= 1);
    assert!(
        counter(&handle, "fabric_runner_operands_stored")
            >= 2 + counter(&handle, "fabric_runner_operands_retransferred")
    );

    drop(router);
    handle.kill();
}

#[test]
fn restarted_runner_rejoins_via_reconnect_and_keeps_serving() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let handle = serve_on(listener, Arc::new(ExecRuntime::with_threads(2))).unwrap();
    let addrs = vec![addr.to_string()];
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    let mut rng = Rng::new(31);
    let (weights, stream) = build_stream(&mut rng, 2, 8, 96, 40);
    let tickets = submit_all(&router, &weights, &stream);
    assert_bit_identical(&weights, &stream, tickets);
    let before = router.stats();
    assert_eq!(before.reconnects, 0, "{before:?}");

    // Kill the runner (socket-level, like a crashed node), then restart
    // a fresh one on the SAME address — the reconnect thread must redial
    // it, wipe the stale known-set, and re-probe the negotiated digests.
    handle.kill();
    let deadline = Instant::now() + Duration::from_secs(10);
    while router.alive_runners() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(router.alive_runners(), 0, "kill must be observed");
    let listener = TcpListener::bind(addr).expect("rebinding the runner address");
    let handle = serve_on(listener, Arc::new(ExecRuntime::with_threads(2))).unwrap();

    let deadline = Instant::now() + Duration::from_secs(15);
    while router.stats().reconnects == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(25));
    }
    let mid = router.stats();
    assert!(mid.reconnects >= 1, "router never rejoined: {mid:?}");
    assert_eq!(router.alive_runners(), 1, "{mid:?}");
    // The restarted store is empty, so the re-probe of previously
    // negotiated digests answered negative — no phantom dedup hits, and
    // the probe counter moved (counters stay monotone through death).
    assert!(mid.probes > before.probes, "{mid:?}");

    // Traffic flows again through the rejoined runner, re-shipping the
    // weight planes it lost with the restart.
    let (w, fmt) = &weights[0];
    let x = Arc::new(Mat::new(3, 96, randn(&mut rng, 3 * 96)).unwrap());
    let t = router
        .submit(Arc::clone(&x), Arc::clone(w), *fmt, None, Priority::Interactive)
        .unwrap();
    let resp = t.wait().unwrap();
    let want = hbfp_gemm_scalar(&x, w, *fmt).unwrap();
    assert!(resp
        .out
        .data
        .iter()
        .zip(&want.data)
        .all(|(g, r)| g.to_bits() == r.to_bits()));
    let after = router.stats();
    assert_eq!(after.failed, 0, "{after:?}");
    assert!(
        after.plane_bytes_sent > mid.plane_bytes_sent,
        "the rejoined runner needed the planes again: {after:?}"
    );

    drop(router);
    handle.kill();
}

#[test]
fn registry_warm_started_runner_needs_no_plane_transfer() {
    let mut rng = Rng::new(47);
    let (weights, stream) = build_stream(&mut rng, 2, 10, 64, 48);

    // Push the working set into a registry, then warm-start a fresh
    // runner's operand store from it before any router connects.
    let dir = std::env::temp_dir().join(format!("boosters-fabric-warm-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let reg = Registry::open(&dir).unwrap();
    let names: Vec<String> = (0..weights.len()).map(|i| format!("w{i}")).collect();
    let layers: Vec<PushLayer<'_>> = weights
        .iter()
        .zip(&names)
        .map(|((w, fmt), name)| PushLayer {
            name,
            weight: w,
            fmt: *fmt,
        })
        .collect();
    reg.push("boot", &layers, &BTreeMap::new()).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let handle = serve_on(listener, Arc::new(ExecRuntime::with_threads(2))).unwrap();
    let installed = warm_start_store(handle.shared(), &dir).unwrap();
    assert_eq!(installed, weights.len());
    assert_eq!(
        counter(&handle, "fabric_runner_operands_preloaded"),
        weights.len() as u64
    );

    let addrs = vec![handle.addr().to_string()];
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();
    let tickets = submit_all(&router, &weights, &stream);
    assert_bit_identical(&weights, &stream, tickets);

    // The whole point of the warm start: every probe answers "present",
    // so zero plane bytes ever cross the wire.
    let stats = router.stats();
    assert_eq!(stats.completed, 10, "{stats:?}");
    assert_eq!(stats.plane_bytes_sent, 0, "{stats:?}");
    assert_eq!(stats.dedup_misses, 0, "{stats:?}");
    assert_eq!(stats.dedup_hits, 10, "{stats:?}");

    drop(router);
    handle.kill();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn submit_rejects_non_contracting_shapes_locally() {
    let (handles, addrs) = spawn_fleet(1);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();
    let mut rng = Rng::new(3);
    let x = Arc::new(Mat::new(2, 17, randn(&mut rng, 34)).unwrap());
    let w = Arc::new(Mat::new(16, 4, randn(&mut rng, 64)).unwrap());
    let fmt = BlockFormat::new(4, 16).unwrap();
    let err = router
        .submit(x, w, fmt, None, Priority::Bulk)
        .expect_err("17 vs 16 cannot contract");
    assert!(matches!(
        err,
        boosters::exec::AdmissionError::InvalidShape { .. }
    ));
    drop(router);
    for h in handles {
        h.kill();
    }
}
