//! Loopback integration tests for the multi-node execution fabric:
//! a [`FabricRouter`] over two in-process runners (each hosting its own
//! [`ExecRuntime`] behind a real TCP socket) must
//!
//! * return responses **bit-identical** to [`hbfp_gemm_scalar`] — the
//!   same invariant every local execution path pins, now across a wire;
//! * move each distinct weight operand's plane bytes **at most once per
//!   runner** (the digest-dedup negotiation), visible in the router's
//!   hit counters;
//! * survive a runner kill mid-flight: every accepted op still
//!   fulfills, re-placed on the survivor, with the failover counted.

use boosters::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use boosters::exec::{ExecRuntime, Priority, Ticket};
use boosters::fabric::{fetch_metrics, serve_on, FabricRouter, RouterConfig, RunnerHandle};
use boosters::util::Rng;
use std::net::TcpListener;
use std::sync::Arc;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// Spawn `n` loopback runners, each with its own two-thread runtime.
fn spawn_fleet(n: usize) -> (Vec<RunnerHandle>, Vec<String>) {
    let mut handles = Vec::with_capacity(n);
    let mut addrs = Vec::with_capacity(n);
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let handle = serve_on(listener, Arc::new(ExecRuntime::with_threads(2))).unwrap();
        addrs.push(handle.addr().to_string());
        handles.push(handle);
    }
    (handles, addrs)
}

/// A mixed-shape op stream over a small working set of repeated
/// weights — the dedup protocol's bread and butter.
fn build_stream(
    rng: &mut Rng,
    distinct_weights: usize,
    ops: usize,
    k: usize,
    c: usize,
) -> (Vec<(Arc<Mat>, BlockFormat)>, Vec<(usize, Arc<Mat>)>) {
    let fmts = [
        BlockFormat::new(4, 64).unwrap(),
        BlockFormat::new(6, 16).unwrap(),
    ];
    let weights: Vec<(Arc<Mat>, BlockFormat)> = (0..distinct_weights)
        .map(|i| {
            let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
            (w, fmts[i % fmts.len()])
        })
        .collect();
    let stream = (0..ops)
        .map(|_| {
            let wi = rng.below(distinct_weights);
            let m = 1 + rng.below(24);
            (wi, Arc::new(Mat::new(m, k, randn(rng, m * k)).unwrap()))
        })
        .collect();
    (weights, stream)
}

fn submit_all(
    router: &FabricRouter,
    weights: &[(Arc<Mat>, BlockFormat)],
    stream: &[(usize, Arc<Mat>)],
) -> Vec<Ticket> {
    stream
        .iter()
        .enumerate()
        .map(|(i, (wi, x))| {
            let (w, fmt) = &weights[*wi];
            // Alternate QoS classes so both sharding paths execute.
            let prio = if i % 2 == 0 {
                Priority::Interactive
            } else {
                Priority::Bulk
            };
            router
                .submit(Arc::clone(x), Arc::clone(w), *fmt, None, prio)
                .expect("loopback fleet under MAC budget must admit")
        })
        .collect()
}

fn assert_bit_identical(
    weights: &[(Arc<Mat>, BlockFormat)],
    stream: &[(usize, Arc<Mat>)],
    tickets: Vec<Ticket>,
) {
    for (i, ((wi, x), ticket)) in stream.iter().zip(tickets).enumerate() {
        let resp = ticket
            .wait()
            .unwrap_or_else(|e| panic!("op {i} lost by the fabric: {e:#}"));
        let (w, fmt) = &weights[*wi];
        let want = hbfp_gemm_scalar(x, w, *fmt).unwrap();
        assert_eq!(resp.out.rows, want.rows, "op {i} row drift");
        assert_eq!(resp.out.cols, want.cols, "op {i} col drift");
        for (j, (g, r)) in resp.out.data.iter().zip(&want.data).enumerate() {
            assert_eq!(
                g.to_bits(),
                r.to_bits(),
                "op {i} elem {j}: fabric result diverged from hbfp_gemm_scalar"
            );
        }
    }
}

#[test]
fn two_runner_fleet_is_bit_identical_and_dedups_weights() {
    let (handles, addrs) = spawn_fleet(2);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    let mut rng = Rng::new(7);
    let (weights, stream) = build_stream(&mut rng, 3, 36, 96, 40);
    let tickets = submit_all(&router, &weights, &stream);
    assert_bit_identical(&weights, &stream, tickets);

    let stats = router.stats();
    assert_eq!(stats.completed, 36, "{stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    // 36 ops over 3 distinct weights: the overwhelming majority of
    // weight references must resolve without moving plane bytes…
    assert!(stats.dedup_hits > 0, "{stats:?}");
    // …and each distinct weight's planes cross the wire at most once
    // per runner — the misses (= PutOperand transfers) are bounded by
    // |weights| × |runners|, never by the op count.
    assert!(
        stats.dedup_misses <= (weights.len() * addrs.len()) as u64,
        "{stats:?}"
    );
    assert_eq!(
        stats.dedup_hits + stats.dedup_misses,
        36,
        "every op references exactly one weight: {stats:?}"
    );
    assert!(stats.plane_bytes_sent > 0, "{stats:?}");
    assert!(
        stats.plane_bytes_deduped >= stats.plane_bytes_sent,
        "repeated references must out-dedup the initial transfers: {stats:?}"
    );
    // The probe protocol ran (first reference per runner), then the
    // known-key set short-circuited it (no probe per repeated op).
    assert!(stats.probes >= 1 && stats.probes <= stats.dedup_misses + 2, "{stats:?}");

    // Both runners saw work (bulk ops round-robin across the fleet).
    for r in &stats.runners {
        assert!(r.alive, "{stats:?}");
        assert!(r.completed > 0, "both runners must share the load: {stats:?}");
    }

    // The runner's Prometheus endpoint serves the pinned exposition
    // format with the fabric counters appended.
    let text = fetch_metrics(&addrs[0]).unwrap();
    assert!(text.contains("# TYPE boosters_exec_submitted_total counter"));
    assert!(text.contains("boosters_fabric_runner_ops_total"));
    assert!(text.contains("boosters_fabric_runner_operands_stored"));

    drop(router);
    for h in handles {
        h.kill();
    }
}

#[test]
fn router_fails_over_killed_runner_without_losing_ops() {
    let (mut handles, addrs) = spawn_fleet(2);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();

    // Big enough ops that a kill right after submission is guaranteed
    // to catch some of them in flight on the victim.
    let mut rng = Rng::new(11);
    let (weights, stream) = build_stream(&mut rng, 2, 32, 256, 96);
    let tickets = submit_all(&router, &weights, &stream);

    // SIGKILL-equivalent: drop the victim's sockets out from under the
    // router. Accepted ops must re-place on the survivor.
    handles.pop().unwrap().kill();

    assert_bit_identical(&weights, &stream, tickets);
    let stats = router.stats();
    assert_eq!(stats.completed, 32, "no accepted op may be lost: {stats:?}");
    assert_eq!(stats.failed, 0, "{stats:?}");
    assert!(
        stats.failovers >= 1,
        "ops in flight on the victim must have re-placed: {stats:?}"
    );
    assert_eq!(router.alive_runners(), 1, "{stats:?}");
    let dead = stats.runners.iter().filter(|r| !r.alive).count();
    assert_eq!(dead, 1, "{stats:?}");

    // The fleet keeps serving after the failover.
    let x = Arc::new(Mat::new(3, 256, randn(&mut rng, 3 * 256)).unwrap());
    let (w, fmt) = &weights[0];
    let t = router
        .submit(Arc::clone(&x), Arc::clone(w), *fmt, None, Priority::Interactive)
        .unwrap();
    let resp = t.wait().unwrap();
    let want = hbfp_gemm_scalar(&x, w, *fmt).unwrap();
    assert!(resp
        .out
        .data
        .iter()
        .zip(&want.data)
        .all(|(g, r)| g.to_bits() == r.to_bits()));

    drop(router);
    for h in handles {
        h.kill();
    }
}

#[test]
fn submit_rejects_non_contracting_shapes_locally() {
    let (handles, addrs) = spawn_fleet(1);
    let router = FabricRouter::connect(
        &addrs,
        RouterConfig::default(),
        Arc::new(ExecRuntime::with_threads(1)),
    )
    .unwrap();
    let mut rng = Rng::new(3);
    let x = Arc::new(Mat::new(2, 17, randn(&mut rng, 34)).unwrap());
    let w = Arc::new(Mat::new(16, 4, randn(&mut rng, 64)).unwrap());
    let fmt = BlockFormat::new(4, 16).unwrap();
    let err = router
        .submit(x, w, fmt, None, Priority::Bulk)
        .expect_err("17 vs 16 cannot contract");
    assert!(matches!(
        err,
        boosters::exec::AdmissionError::InvalidShape { .. }
    ));
    drop(router);
    for h in handles {
        h.kill();
    }
}
