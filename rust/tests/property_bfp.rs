//! Randomized property tests over the BFP substrate and the coordinator
//! invariants (proptest is unavailable offline; the in-tree RNG drives
//! many-case randomized sweeps with explicit failure seeds instead).

use boosters::bfp::{
    bfp_dot_blocks, bfp_dot_fixed_point, dequant_dot, hbfp_gemm, hbfp_gemm_scalar, quantize_flat,
    quantize_packed, scale_shift, BfpMatrix, BfpTensor, BlockFormat, Mat, Quantizer, RoundMode,
};
use boosters::config::PrecisionPolicy;
use boosters::coordinator::PrecisionScheduler;
use boosters::metrics::wasserstein1;
use boosters::util::Rng;

fn randn(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(scale)).collect()
}

const CASES: usize = 120;

/// Quantization never *increases* any element's magnitude by more than
/// one interval, preserves signs of surviving values, and is idempotent.
#[test]
fn prop_quantizer_pointwise_invariants() {
    let mut rng = Rng::new(0xB00157);
    for case in 0..CASES {
        let n = 1 + rng.below(800);
        let block = [4usize, 16, 25, 49, 64, 576][rng.below(6)];
        let m = [2u32, 3, 4, 5, 6, 8, 12][rng.below(7)];
        let scale = [1e-5, 1.0, 1e4][rng.below(3)];
        let x = randn(&mut rng, n, scale);
        let q = Quantizer::nearest(m);
        let out = quantize_flat(&x, block, q, 0);
        for (i, (&a, &b)) in x.iter().zip(&out).enumerate() {
            // Sign preservation (or exact zero) under nearest rounding.
            assert!(
                b == 0.0 || a.signum() == b.signum(),
                "case {case}: sign flip at {i}: {a} -> {b} (m={m} b={block})"
            );
        }
        // Idempotence per block — EXCEPT blocks where the first pass
        // rounded a negative value onto the clamp boundary -2^(m-1)*s:
        // that grows max|v| to 2^(e+1), bumping the shared exponent, so a
        // re-quantization legitimately re-grids (true of the jnp oracle
        // too; the golden tests pin that behaviour bit-for-bit).
        let twice = quantize_flat(&out, block, q, 0);
        for (bi, (o, t)) in out.chunks(block).zip(twice.chunks(block)).enumerate() {
            if o == t {
                continue;
            }
            let maxabs = o.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let at_boundary = o
                .iter()
                .any(|&v| v < 0.0 && v.abs() == maxabs && maxabs.log2().fract() == 0.0);
            assert!(
                at_boundary,
                "case {case}: non-boundary re-grid in block {bi} (m={m} b={block})"
            );
        }
    }
}

/// The fixed-point integer dot equals the dequantized float dot for any
/// shape/format — the HBFP arithmetic-equivalence invariant.
#[test]
fn prop_fixed_point_dot_equivalence() {
    let mut rng = Rng::new(0xD07);
    for case in 0..CASES {
        let n = 1 + rng.below(500);
        let block = [8usize, 16, 64][rng.below(3)];
        let m = [3u32, 4, 6, 8][rng.below(4)];
        let fmt = BlockFormat::new(m, block).unwrap();
        let x = randn(&mut rng, n, 1.0);
        let y = randn(&mut rng, n, 1.0);
        let fixed = bfp_dot_fixed_point(&x, &y, fmt).unwrap();
        let float = dequant_dot(&x, &y, fmt).unwrap();
        assert!(
            (fixed - float).abs() <= 1e-9 * float.abs().max(1.0),
            "case {case}: {fixed} vs {float} (m={m} b={block} n={n})"
        );
    }
}

/// Pack -> unpack -> decode is identical to direct quantize for random
/// tensors (the storage format is a lossless carrier).
#[test]
fn prop_pack_roundtrip() {
    let mut rng = Rng::new(0xAC4);
    for case in 0..CASES {
        let n = 1 + rng.below(300);
        let block = [8usize, 25, 64][rng.below(3)];
        let m = [2u32, 4, 7, 11][rng.below(4)];
        let fmt = BlockFormat::new(m, block).unwrap();
        let x = randn(&mut rng, n, 1.0);
        let t = BfpTensor::encode(&x, fmt).unwrap();
        for blk in &t.blocks {
            let back =
                boosters::bfp::BfpBlock::unpack(&blk.pack(), fmt).expect("unpack");
            assert_eq!(&back, blk, "case {case} (m={m} b={block})");
        }
        assert_eq!(
            t.decode(),
            quantize_flat(&x, block, Quantizer::nearest(m), 0),
            "case {case}"
        );
    }
}

/// The packed tensor engine (`BfpMatrix::gemm`, threaded tiled kernel)
/// is **bit-identical** to the scalar per-block reference across the
/// paper's mantissa/block grid, including ragged K with padded tail
/// blocks — the refactor's central invariant.
#[test]
fn prop_packed_gemm_bit_identical_to_scalar() {
    let mut rng = Rng::new(0x9E77);
    for &m in &[3u32, 4, 6, 8] {
        for &b in &[16usize, 64, 576] {
            let fmt = BlockFormat::new(m, b).unwrap();
            for case in 0..4 {
                // Ragged K: rarely a block multiple, sometimes < b.
                let k = 1 + rng.below(2 * b + 37);
                let r = 1 + rng.below(6);
                let c = 1 + rng.below(7);
                let x = Mat::new(r, k, randn(&mut rng, r * k, 1.0)).unwrap();
                let w = Mat::new(k, c, randn(&mut rng, k * c, 1.0)).unwrap();
                let packed = hbfp_gemm(&x, &w, fmt).unwrap();
                let scalar = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
                for (i, (p, s)) in packed.data.iter().zip(&scalar.data).enumerate() {
                    assert_eq!(
                        p.to_bits(),
                        s.to_bits(),
                        "case {case} m={m} b={b} k={k} elem {i}: {p} vs {s}"
                    );
                }
            }
        }
    }
}

/// Mixed mantissa widths across the two operands (i8 x i16 planes, the
/// bit-sliced mixed-precision case) agree bit-for-bit with an
/// independently coded per-block reference.
#[test]
fn prop_packed_gemm_mixed_widths_match_block_reference() {
    let mut rng = Rng::new(0xA11);
    for case in 0..20 {
        let b = [16usize, 32, 64][rng.below(3)];
        let (mx, mw) = [(4u32, 12u32), (6, 10), (12, 4), (8, 16)][rng.below(4)];
        let k = 1 + rng.below(150);
        let (r, c) = (1 + rng.below(4), 1 + rng.below(4));
        let fx = BlockFormat::new(mx, b).unwrap();
        let fw = BlockFormat::new(mw, b).unwrap();
        let x = Mat::new(r, k, randn(&mut rng, r * k, 1.0)).unwrap();
        let w = Mat::new(k, c, randn(&mut rng, k * c, 1.0)).unwrap();
        let xp = BfpMatrix::encode(&x.data, r, k, fx, Quantizer::nearest(mx)).unwrap();
        let wp = BfpMatrix::encode_transposed(&w, fw, Quantizer::nearest(mw)).unwrap();
        let got = xp.gemm(&wp).unwrap();
        // Independent reference: scalar BfpTensor blocks per row/column,
        // f64 accumulation in ascending block order.
        let wt = w.transpose();
        for i in 0..r {
            let bx = BfpTensor::encode(&x.data[i * k..(i + 1) * k], fx).unwrap();
            for j in 0..c {
                let bw = BfpTensor::encode(&wt.data[j * k..(j + 1) * k], fw).unwrap();
                let mut acc = 0.0f64;
                for (xb, wb) in bx.blocks.iter().zip(&bw.blocks) {
                    acc += bfp_dot_blocks(xb, wb).unwrap();
                }
                let want = acc as f32;
                let gotv = got.at(i, j);
                assert_eq!(
                    gotv.to_bits(),
                    want.to_bits(),
                    "case {case} b={b} mx={mx} mw={mw} ({i},{j}): {gotv} vs {want}"
                );
            }
        }
    }
}

/// `quantize_packed` round-trips through the integer planes to exactly
/// the flat quantizer's output for both rounding modes and arbitrary
/// sites, identifying only the sign of zero (an integer mantissa cannot
/// carry -0.0).
#[test]
fn prop_quantize_packed_matches_flat() {
    let mut rng = Rng::new(0xFACADE);
    for case in 0..CASES {
        let n = 1 + rng.below(900);
        let block = [4usize, 16, 49, 64, 576][rng.below(5)];
        let m = [2u32, 3, 4, 6, 8, 12, 16][rng.below(7)];
        let site = rng.below(1 << 16) as u32;
        let scale = [1e-6, 1.0, 3e4][rng.below(3)];
        let x = randn(&mut rng, n, scale);
        let q = if rng.below(2) == 0 {
            Quantizer::nearest(m)
        } else {
            Quantizer::stochastic(m, rng.below(1 << 20) as u32)
        };
        let got = quantize_packed(&x, block, q, site);
        let want = quantize_flat(&x, block, q, site);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let same = (*g == 0.0 && *w == 0.0) || g.to_bits() == w.to_bits();
            assert!(
                same,
                "case {case} m={m} b={block} rmode={:?} site={site} elem {i}: {g} vs {w}",
                q.mode
            );
        }
        // Bit-level spot check that the sign-of-zero carve-out is the
        // ONLY divergence.
        if q.mode == RoundMode::NearestEven {
            for (g, w) in got.iter().zip(&want) {
                if *w != 0.0 {
                    assert_eq!(g.to_bits(), w.to_bits());
                }
            }
        }
    }
}

/// The packed dot path (`bfp_dot_fixed_point` over planes) equals the
/// scalar block loop bit-for-bit, and the decode scale everywhere is
/// `2^scale_shift(e, m)`.
#[test]
fn prop_packed_dot_and_scale_shift() {
    let mut rng = Rng::new(0xD0D0);
    for case in 0..CASES {
        let n = 1 + rng.below(600);
        let block = [8usize, 16, 64, 576][rng.below(4)];
        let m = [3u32, 4, 6, 8, 12][rng.below(5)];
        let fmt = BlockFormat::new(m, block).unwrap();
        let x = randn(&mut rng, n, 1.0);
        let y = randn(&mut rng, n, 1.0);
        let got = bfp_dot_fixed_point(&x, &y, fmt).unwrap();
        let tx = BfpTensor::encode(&x, fmt).unwrap();
        let ty = BfpTensor::encode(&y, fmt).unwrap();
        let mut want = 0.0f64;
        for (bx, by) in tx.blocks.iter().zip(&ty.blocks) {
            assert_eq!(bx.scale_shift(), scale_shift(bx.exponent, m), "case {case}");
            want += bfp_dot_blocks(bx, by).unwrap();
        }
        assert_eq!(
            got.to_bits(),
            want.to_bits(),
            "case {case} m={m} b={block} n={n}: {got} vs {want}"
        );
    }
}

/// Wasserstein distance is a metric on these samples: symmetric,
/// non-negative, zero on identity, and respects the triangle inequality.
#[test]
fn prop_wasserstein_metric_axioms() {
    let mut rng = Rng::new(0x3A55);
    for case in 0..40 {
        let n = 16 + rng.below(400);
        let a = randn(&mut rng, n, 1.0);
        let b = randn(&mut rng, n, 1.0);
        let c = randn(&mut rng, n, 2.0);
        let ab = wasserstein1(&a, &b);
        let ba = wasserstein1(&b, &a);
        let ac = wasserstein1(&a, &c);
        let cb = wasserstein1(&c, &b);
        assert!(ab >= 0.0);
        assert!((ab - ba).abs() < 1e-12, "case {case}: asymmetric");
        assert_eq!(wasserstein1(&a, &a), 0.0);
        assert!(ab <= ac + cb + 1e-9, "case {case}: triangle violated");
    }
}

/// Scheduler invariants across random policies and horizons: bits stay in
/// the policy's range, edge bits never drop below mid bits for Booster,
/// and the boosted suffix has exactly `boost_epochs` epochs.
#[test]
fn prop_scheduler_invariants() {
    let mut rng = Rng::new(0x5C4ED);
    for _ in 0..200 {
        let total = 2 + rng.below(300);
        let boost = 1 + rng.below(total.min(20));
        let sched = PrecisionScheduler::new(
            PrecisionPolicy::Booster {
                low: 4,
                high: 6,
                boost_epochs: boost,
            },
            total,
            true,
        );
        let mut boosted = 0;
        for e in 0..total {
            let (mid, edge) = sched.bits_at(e);
            assert!(edge >= mid);
            assert!(mid == 4.0 || mid == 6.0);
            if sched.is_boosted(e) {
                boosted += 1;
                assert_eq!(mid, 6.0);
            } else {
                assert_eq!(mid, 4.0);
            }
        }
        assert_eq!(boosted, boost.min(total));
    }
}
