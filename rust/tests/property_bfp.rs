//! Randomized property tests over the BFP substrate and the coordinator
//! invariants (proptest is unavailable offline; the in-tree RNG drives
//! many-case randomized sweeps with explicit failure seeds instead).

use boosters::bfp::{
    bfp_dot_fixed_point, dequant_dot, quantize_flat, BfpTensor, BlockFormat, Quantizer,
};
use boosters::config::PrecisionPolicy;
use boosters::coordinator::PrecisionScheduler;
use boosters::metrics::wasserstein1;
use boosters::util::Rng;

fn randn(rng: &mut Rng, n: usize, scale: f64) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(scale)).collect()
}

const CASES: usize = 120;

/// Quantization never *increases* any element's magnitude by more than
/// one interval, preserves signs of surviving values, and is idempotent.
#[test]
fn prop_quantizer_pointwise_invariants() {
    let mut rng = Rng::new(0xB00157);
    for case in 0..CASES {
        let n = 1 + rng.below(800);
        let block = [4usize, 16, 25, 49, 64, 576][rng.below(6)];
        let m = [2u32, 3, 4, 5, 6, 8, 12][rng.below(7)];
        let scale = [1e-5, 1.0, 1e4][rng.below(3)];
        let x = randn(&mut rng, n, scale);
        let q = Quantizer::nearest(m);
        let out = quantize_flat(&x, block, q, 0);
        for (i, (&a, &b)) in x.iter().zip(&out).enumerate() {
            // Sign preservation (or exact zero) under nearest rounding.
            assert!(
                b == 0.0 || a.signum() == b.signum(),
                "case {case}: sign flip at {i}: {a} -> {b} (m={m} b={block})"
            );
        }
        // Idempotence per block — EXCEPT blocks where the first pass
        // rounded a negative value onto the clamp boundary -2^(m-1)*s:
        // that grows max|v| to 2^(e+1), bumping the shared exponent, so a
        // re-quantization legitimately re-grids (true of the jnp oracle
        // too; the golden tests pin that behaviour bit-for-bit).
        let twice = quantize_flat(&out, block, q, 0);
        for (bi, (o, t)) in out.chunks(block).zip(twice.chunks(block)).enumerate() {
            if o == t {
                continue;
            }
            let maxabs = o.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let at_boundary = o
                .iter()
                .any(|&v| v < 0.0 && v.abs() == maxabs && maxabs.log2().fract() == 0.0);
            assert!(
                at_boundary,
                "case {case}: non-boundary re-grid in block {bi} (m={m} b={block})"
            );
        }
    }
}

/// The fixed-point integer dot equals the dequantized float dot for any
/// shape/format — the HBFP arithmetic-equivalence invariant.
#[test]
fn prop_fixed_point_dot_equivalence() {
    let mut rng = Rng::new(0xD07);
    for case in 0..CASES {
        let n = 1 + rng.below(500);
        let block = [8usize, 16, 64][rng.below(3)];
        let m = [3u32, 4, 6, 8][rng.below(4)];
        let fmt = BlockFormat::new(m, block).unwrap();
        let x = randn(&mut rng, n, 1.0);
        let y = randn(&mut rng, n, 1.0);
        let fixed = bfp_dot_fixed_point(&x, &y, fmt).unwrap();
        let float = dequant_dot(&x, &y, fmt).unwrap();
        assert!(
            (fixed - float).abs() <= 1e-9 * float.abs().max(1.0),
            "case {case}: {fixed} vs {float} (m={m} b={block} n={n})"
        );
    }
}

/// Pack -> unpack -> decode is identical to direct quantize for random
/// tensors (the storage format is a lossless carrier).
#[test]
fn prop_pack_roundtrip() {
    let mut rng = Rng::new(0xAC4);
    for case in 0..CASES {
        let n = 1 + rng.below(300);
        let block = [8usize, 25, 64][rng.below(3)];
        let m = [2u32, 4, 7, 11][rng.below(4)];
        let fmt = BlockFormat::new(m, block).unwrap();
        let x = randn(&mut rng, n, 1.0);
        let t = BfpTensor::encode(&x, fmt).unwrap();
        for blk in &t.blocks {
            let back =
                boosters::bfp::BfpBlock::unpack(&blk.pack(), fmt).expect("unpack");
            assert_eq!(&back, blk, "case {case} (m={m} b={block})");
        }
        assert_eq!(
            t.decode(),
            quantize_flat(&x, block, Quantizer::nearest(m), 0),
            "case {case}"
        );
    }
}

/// Wasserstein distance is a metric on these samples: symmetric,
/// non-negative, zero on identity, and respects the triangle inequality.
#[test]
fn prop_wasserstein_metric_axioms() {
    let mut rng = Rng::new(0x3A55);
    for case in 0..40 {
        let n = 16 + rng.below(400);
        let a = randn(&mut rng, n, 1.0);
        let b = randn(&mut rng, n, 1.0);
        let c = randn(&mut rng, n, 2.0);
        let ab = wasserstein1(&a, &b);
        let ba = wasserstein1(&b, &a);
        let ac = wasserstein1(&a, &c);
        let cb = wasserstein1(&c, &b);
        assert!(ab >= 0.0);
        assert!((ab - ba).abs() < 1e-12, "case {case}: asymmetric");
        assert_eq!(wasserstein1(&a, &a), 0.0);
        assert!(ab <= ac + cb + 1e-9, "case {case}: triangle violated");
    }
}

/// Scheduler invariants across random policies and horizons: bits stay in
/// the policy's range, edge bits never drop below mid bits for Booster,
/// and the boosted suffix has exactly `boost_epochs` epochs.
#[test]
fn prop_scheduler_invariants() {
    let mut rng = Rng::new(0x5C4ED);
    for _ in 0..200 {
        let total = 2 + rng.below(300);
        let boost = 1 + rng.below(total.min(20));
        let sched = PrecisionScheduler::new(
            PrecisionPolicy::Booster {
                low: 4,
                high: 6,
                boost_epochs: boost,
            },
            total,
            true,
        );
        let mut boosted = 0;
        for e in 0..total {
            let (mid, edge) = sched.bits_at(e);
            assert!(edge >= mid);
            assert!(mid == 4.0 || mid == 6.0);
            if sched.is_boosted(e) {
                boosted += 1;
                assert_eq!(mid, 6.0);
            } else {
                assert_eq!(mid, 4.0);
            }
        }
        assert_eq!(boosted, boost.min(total));
    }
}
