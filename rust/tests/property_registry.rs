//! Integration contracts for the content-addressed encoded-weight
//! registry, exercised through the public API the way real callers
//! (checkpoint import, fabric warm start, `repro registry`) use it:
//!
//! * push/pull round-trips are **bit-identical** to a fresh
//!   [`BfpMatrix::encode_transposed`] across the full plane-layout grid
//!   (I4Packed / I8 / I16) — the zero-copy loader never re-quantizes;
//! * cross-epoch pushes dedup exactly the unchanged layers — blob
//!   counts are a pure function of distinct (digest, format) pairs;
//! * [`Registry::warm_cache`] publishes planes under the *hot-path*
//!   cache key, so `encode_transposed_cached` afterwards is all hits —
//!   zero encode operations, the PR's warm-start acceptance bar;
//! * the blob header's layout byte stays in lockstep with the fabric
//!   wire mapping (1 = i4x2, 2 = i8, 3 = i16) — a registry blob and a
//!   wire frame must never disagree about what a plane byte means;
//! * corruption (payload flip, truncation, garbage manifest) is a
//!   typed rejection, and `gc` keeps every manifest-reachable blob.

use boosters::bfp::{BfpMatrix, BlockFormat, Mat, PlaneLayout, Quantizer};
use boosters::exec::ExecRuntime;
use boosters::registry::{PushLayer, Registry, RegistryError};
use boosters::util::digest::content_fingerprint;
use boosters::util::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};

fn temp_root(tag: &str) -> PathBuf {
    static SEQ: AtomicUsize = AtomicUsize::new(0);
    std::env::temp_dir().join(format!(
        "boosters-prop-registry-{}-{}-{tag}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

fn mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let data = (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect();
    Mat::new(rows, cols, data).unwrap()
}

fn fresh_encode(w: &Mat, fmt: BlockFormat) -> BfpMatrix {
    BfpMatrix::encode_transposed(w, fmt, Quantizer::nearest(fmt.mantissa_bits)).unwrap()
}

#[test]
fn roundtrip_is_bit_identical_across_the_layout_grid() {
    let root = temp_root("grid");
    let reg = Registry::open(&root).unwrap();
    // Shapes deliberately include block-ragged edges (33x17) so the
    // padded-tail bytes round-trip too; formats cover all three plane
    // layouts, 4-bit packed first — it is the paper's headline width.
    let shapes = [(64usize, 48usize), (33, 17), (16, 64), (128, 96)];
    let fmts = [
        BlockFormat::new(4, 16).unwrap(),
        BlockFormat::new(4, 64).unwrap(),
        BlockFormat::new(6, 16).unwrap(),
        BlockFormat::new(12, 16).unwrap(),
    ];
    let mut layouts_seen = Vec::new();
    let mut weights = Vec::new();
    for (i, &(r, c)) in shapes.iter().enumerate() {
        for (j, &f) in fmts.iter().enumerate() {
            weights.push((format!("w{i}f{j}"), mat(r, c, 100 + (i * 7 + j) as u64), f));
            if !layouts_seen.contains(&f.plane_layout()) {
                layouts_seen.push(f.plane_layout());
            }
        }
    }
    assert!(
        layouts_seen.contains(&PlaneLayout::I4Packed)
            && layouts_seen.contains(&PlaneLayout::I8)
            && layouts_seen.contains(&PlaneLayout::I16),
        "grid must cover every plane layout, saw {layouts_seen:?}"
    );
    let layers: Vec<PushLayer<'_>> = weights
        .iter()
        .map(|(name, w, f)| PushLayer {
            name,
            weight: w,
            fmt: *f,
        })
        .collect();
    let (_, stats) = reg.push("grid", &layers, &BTreeMap::new()).unwrap();
    assert_eq!(stats.blobs_written, weights.len());
    assert_eq!(stats.blobs_deduped, 0);

    for ((entry, loaded), (name, w, f)) in reg.pull("grid").unwrap().iter().zip(&weights) {
        let want = fresh_encode(w, *f);
        assert_eq!(**loaded, want, "{name}: loaded plane diverged");
        assert_eq!(entry.digest, content_fingerprint(&w.data, w.rows, w.cols));
        assert_eq!(entry.layout, f.plane_layout(), "{name}");
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn blob_header_layout_byte_matches_the_wire_mapping() {
    // Offset 6 of every blob is the plane-layout byte, and it must use
    // the SAME mapping as the fabric wire protocol (1 = i4x2, 2 = i8,
    // 3 = i16) — this test is the lockstep pin named in both modules'
    // docs. If either side renumbers, this fails before any fleet does.
    let root = temp_root("layout-byte");
    let reg = Registry::open(&root).unwrap();
    let cases: [(BlockFormat, PlaneLayout, u8); 3] = [
        (BlockFormat::new(4, 16).unwrap(), PlaneLayout::I4Packed, 1),
        (BlockFormat::new(6, 16).unwrap(), PlaneLayout::I8, 2),
        (BlockFormat::new(12, 16).unwrap(), PlaneLayout::I16, 3),
    ];
    let w = mat(32, 32, 9);
    for (i, &(f, layout, byte)) in cases.iter().enumerate() {
        assert_eq!(f.plane_layout(), layout);
        let (manifest, _) = reg
            .push(
                &format!("m{i}"),
                &[PushLayer {
                    name: "w",
                    weight: &w,
                    fmt: f,
                }],
                &BTreeMap::new(),
            )
            .unwrap();
        let entry = &manifest.layers[0];
        let bytes = std::fs::read(reg.blob_path(entry.digest, entry.fmt)).unwrap();
        assert_eq!(&bytes[0..4], b"BFPR");
        assert_eq!(
            bytes[6], byte,
            "layout byte for {layout:?} drifted from the wire mapping"
        );
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn cross_epoch_pushes_dedup_exactly_the_unchanged_layers() {
    let root = temp_root("epochs");
    let reg = Registry::open(&root).unwrap();
    let fmt = BlockFormat::new(4, 64).unwrap();
    let layer_count = 6usize;
    let epochs = 4usize;
    // Epoch e fresh-samples layer i when `i % 3 == e % 3` (the serve-sim
    // registry benchmark's schedule); everything else is byte-stable.
    let mut current: Vec<Mat> = (0..layer_count)
        .map(|i| mat(48, 32, 500 + i as u64))
        .collect();
    let mut distinct: std::collections::HashSet<_> = std::collections::HashSet::new();
    let mut written = 0usize;
    let mut deduped = 0usize;
    for e in 0..epochs {
        if e > 0 {
            for i in 0..layer_count {
                if i % 3 == e % 3 {
                    current[i] = mat(48, 32, 1000 + (e * layer_count + i) as u64);
                }
            }
        }
        let names: Vec<String> = (0..layer_count).map(|i| format!("layer{i:02}")).collect();
        let layers: Vec<PushLayer<'_>> = current
            .iter()
            .zip(&names)
            .map(|(w, name)| PushLayer {
                name,
                weight: w,
                fmt,
            })
            .collect();
        let (manifest, stats) = reg
            .push(&format!("epoch{e}"), &layers, &BTreeMap::new())
            .unwrap();
        // Exact dedup accounting: a layer writes a blob iff its
        // (digest, fmt) pair is globally new.
        let new_digests = manifest
            .layers
            .iter()
            .filter(|l| distinct.insert((l.digest, l.fmt)))
            .count();
        assert_eq!(stats.blobs_written, new_digests, "epoch {e}");
        assert_eq!(stats.blobs_deduped, layer_count - new_digests, "epoch {e}");
        if e > 0 {
            assert!(stats.dedup_ratio() > 0.0, "epoch {e} reused nothing");
            assert_eq!(stats.blobs_deduped, layer_count - 2, "epoch {e}");
        }
        written += stats.blobs_written;
        deduped += stats.blobs_deduped;
    }
    assert_eq!(written, distinct.len());
    assert_eq!(written + deduped, layer_count * epochs);
    assert_eq!(reg.blob_stats().unwrap().0, distinct.len());
    // Every epoch remains pullable and bit-identical after the churn.
    for (entry, loaded) in reg.pull(&format!("epoch{}", epochs - 1)).unwrap() {
        let i: usize = entry.name.trim_start_matches("layer").parse().unwrap();
        assert_eq!(*loaded, fresh_encode(&current[i], fmt), "{}", entry.name);
    }
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn warm_cache_makes_the_hot_path_pure_lookup_with_zero_encodes() {
    let root = temp_root("warm");
    let reg = Registry::open(&root).unwrap();
    let fmts = [
        BlockFormat::new(4, 64).unwrap(),
        BlockFormat::new(6, 16).unwrap(),
    ];
    let weights: Vec<(Mat, BlockFormat)> = (0..5)
        .map(|i| (mat(64, 48, 700 + i as u64), fmts[i % fmts.len()]))
        .collect();
    let names: Vec<String> = (0..weights.len()).map(|i| format!("w{i}")).collect();
    let layers: Vec<PushLayer<'_>> = weights
        .iter()
        .zip(&names)
        .map(|((w, f), name)| PushLayer {
            name,
            weight: w,
            fmt: *f,
        })
        .collect();
    reg.push("ck", &layers, &BTreeMap::new()).unwrap();

    // A cold runtime, warm-started purely from the registry: the
    // subsequent hot-path encode calls must all be cache hits — the
    // warm start's entire value proposition is zero encoder work.
    let rt = ExecRuntime::with_threads(1);
    let warm = reg.warm_cache("ck", rt.cache()).unwrap();
    assert_eq!(warm.installed, weights.len());
    assert!(warm.plane_bytes > 0);
    assert!(warm.mapped_loads <= warm.installed);
    assert_eq!(rt.cache().preloads(), weights.len() as u64);

    for (i, (w, f)) in weights.iter().enumerate() {
        let got = rt.encode_transposed_cached(w, *f).unwrap();
        assert_eq!(*got, fresh_encode(w, *f), "w{i} diverged through warm cache");
    }
    let stats = rt.cache_stats();
    assert_eq!(stats.misses, 0, "warm start must eliminate every encode");
    assert_eq!(stats.hits, weights.len() as u64);
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn corruption_and_truncation_are_typed_rejections() {
    let root = temp_root("reject");
    let reg = Registry::open(&root).unwrap();
    let w = mat(32, 16, 800);
    let f = BlockFormat::new(4, 16).unwrap();
    let (manifest, _) = reg
        .push(
            "ck",
            &[PushLayer {
                name: "w",
                weight: &w,
                fmt: f,
            }],
            &BTreeMap::new(),
        )
        .unwrap();
    let entry = &manifest.layers[0];
    let path = reg.blob_path(entry.digest, entry.fmt);
    let pristine = std::fs::read(&path).unwrap();

    // Payload byte flip → checksum rejection, never a wrong matrix.
    let mut flipped = pristine.clone();
    let last = flipped.len() - 1;
    flipped[last] ^= 0x80;
    std::fs::write(&path, &flipped).unwrap();
    match reg.load_blob(entry) {
        Err(RegistryError::CorruptBlob { detail, .. }) => {
            assert!(detail.contains("checksum"), "{detail}")
        }
        other => panic!("flipped payload: expected CorruptBlob, got {other:?}"),
    }

    // Truncation (mid-payload and mid-header) → structural rejection.
    for cut in [pristine.len() - 8, 40] {
        std::fs::write(&path, &pristine[..cut]).unwrap();
        assert!(
            matches!(reg.load_blob(entry), Err(RegistryError::CorruptBlob { .. })),
            "truncated at {cut} must be CorruptBlob"
        );
    }

    // Restore the blob, then break the manifest instead.
    std::fs::write(&path, &pristine).unwrap();
    assert_eq!(*reg.load_blob(entry).unwrap(), fresh_encode(&w, f));
    let mpath = root.join("manifests/ck.json");
    let mtext = std::fs::read_to_string(&mpath).unwrap();
    std::fs::write(&mpath, &mtext[..mtext.len() / 2]).unwrap();
    assert!(matches!(
        reg.pull("ck"),
        Err(RegistryError::BadManifest { .. })
    ));

    // Deleting the blob under an intact manifest is the third distinct
    // failure: MissingBlob, not corruption.
    std::fs::write(&mpath, &mtext).unwrap();
    std::fs::remove_file(&path).unwrap();
    assert!(matches!(
        reg.pull("ck"),
        Err(RegistryError::MissingBlob { .. })
    ));
    std::fs::remove_dir_all(&root).ok();
}

#[test]
fn gc_keeps_every_manifest_reachable_blob_through_churn() {
    let root = temp_root("gc");
    let reg = Registry::open(&root).unwrap();
    let f = BlockFormat::new(4, 16).unwrap();
    let shared = mat(24, 24, 900);
    let only_a = mat(24, 24, 901);
    let only_b = mat(24, 24, 902);
    let layer_names = ["l0", "l1"];
    let push = |name: &str, mats: &[&Mat]| {
        let layers: Vec<PushLayer<'_>> = mats
            .iter()
            .zip(layer_names)
            .map(|(w, lname)| PushLayer {
                name: lname,
                weight: w,
                fmt: f,
            })
            .collect();
        reg.push(name, &layers, &BTreeMap::new()).unwrap();
    };
    push("a", &[&shared, &only_a]);
    push("b", &[&shared, &only_b]);
    assert_eq!(reg.blob_stats().unwrap().0, 3);

    // Nothing unreachable yet: gc is a no-op and both manifests pull.
    let noop = reg.gc().unwrap();
    assert_eq!((noop.blobs_kept, noop.blobs_removed), (3, 0));

    // Drop manifest "a": its exclusive blob goes, the shared one stays
    // because "b" still reaches it.
    std::fs::remove_file(root.join("manifests/a.json")).unwrap();
    let swept = reg.gc().unwrap();
    assert_eq!((swept.blobs_kept, swept.blobs_removed), (2, 1));
    assert!(swept.bytes_removed > 0);
    assert!(reg.has_blob(content_fingerprint(&shared.data, 24, 24), f));
    assert!(!reg.has_blob(content_fingerprint(&only_a.data, 24, 24), f));
    let pulled = reg.pull("b").unwrap();
    assert_eq!(pulled.len(), 2);
    assert_eq!(*pulled[0].1, fresh_encode(&shared, f));
    assert_eq!(*pulled[1].1, fresh_encode(&only_b, f));
    std::fs::remove_dir_all(&root).ok();
}
