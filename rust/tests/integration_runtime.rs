//! Integration tests over the full L3 stack: artifact loading, train/eval
//! steps, Booster schedule end-to-end on a tiny run, decode plumbing, and
//! the fp32-bypass equivalence between the compiled graph and the rust
//! BFP substrate. Requires `make artifacts`.
//!
//! All tests share one PJRT client (the CPU plugin is happiest as a
//! process singleton), so everything lives in one #[test] body per
//! concern, serialized by an explicit driver.

use boosters::config::PrecisionPolicy;
use boosters::coordinator::{init_state, Trainer, TrainerData};
use boosters::experiments::common::config_for;
use boosters::experiments::Preset;
use boosters::runtime::{artifacts_dir, Engine, Index, StepScalars, Tensor};

/// None (with a loud skip note) when `make artifacts` has not run —
/// keeps the tier-1 suite green on fresh clones and stub-xla builds.
fn engine() -> Option<Engine> {
    if !artifacts_dir().join("index.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(Engine::new().expect("pjrt cpu client"))
}

#[test]
fn index_lists_all_model_families() {
    // Pure file I/O — no PJRT client needed, just the artifacts index.
    if !artifacts_dir().join("index.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return;
    }
    let index = Index::load(&artifacts_dir()).unwrap();
    assert!(index.variants.len() >= 4);
    for family in ["mlp", "cnn", "transformer"] {
        assert!(
            index.variants.iter().any(|v| v.model == family),
            "family {family} missing from artifacts"
        );
    }
    // The Pallas flagship build must be present.
    assert!(index.variants.iter().any(|v| v.pallas));
}

#[test]
fn runtime_end_to_end() {
    let Some(engine) = engine() else { return };
    let artifacts = artifacts_dir();

    // --- mlp: deterministic step + state round-trip --------------------
    let v = engine.load_variant_by_name(&artifacts, "mlp_bs64").unwrap();
    let cfg = config_for(&v, PrecisionPolicy::Hbfp { bits: 4 }, Preset::Quick);
    let data = TrainerData::for_variant(&v, &cfg).unwrap();
    let idx: Vec<usize> = (0..v.manifest.batch).collect();
    let (x, y) = data.batch(&idx, false);

    let sc = StepScalars::hbfp(4.0).with_seed(9);
    let mut s1 = init_state(&v.manifest, 7).unwrap();
    let mut s2 = init_state(&v.manifest, 7).unwrap();
    let r1 = engine.train_step(&v, &mut s1, &x, &y, sc, 0.05).unwrap();
    let r2 = engine.train_step(&v, &mut s2, &x, &y, sc, 0.05).unwrap();
    assert_eq!(r1.loss.to_bits(), r2.loss.to_bits(), "steps must be deterministic");
    let p1 = s1.params_to_tensors().unwrap();
    let p2 = s2.params_to_tensors().unwrap();
    assert_eq!(p1, p2);
    // Params actually moved.
    let init = boosters::coordinator::init::init_params(&v.manifest, 7).unwrap();
    assert_ne!(p1[0], init[0]);

    // --- eval is pure (does not mutate state) ---------------------------
    let before = s1.params_to_tensors().unwrap();
    let e1 = engine.eval_batch(&v, &s1, &x, &y, sc).unwrap();
    let e2 = engine.eval_batch(&v, &s1, &x, &y, sc).unwrap();
    assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
    assert_eq!(before, s1.params_to_tensors().unwrap());

    // --- fp32 bypass: mid/edge >= 23 behaves as one precision ----------
    let e32a = engine
        .eval_batch(&v, &s1, &x, &y, StepScalars::fp32())
        .unwrap();
    let e32b = engine
        .eval_batch(
            &v,
            &s1,
            &x,
            &y,
            StepScalars {
                bits_mid: 24.0,
                bits_edge: 31.0,
                rmode_grad: 0.0,
                seed: 3.0,
            },
        )
        .unwrap();
    assert_eq!(e32a.loss.to_bits(), e32b.loss.to_bits(), "bypass must ignore bits");

    // --- pallas variant computes the same function as the jnp variant --
    let vp = engine
        .load_variant_by_name(&artifacts, "mlp_bs64_pallas")
        .unwrap();
    let sp = init_state(&vp.manifest, 7).unwrap();
    let sj = init_state(&v.manifest, 7).unwrap();
    let ep = engine.eval_batch(&vp, &sp, &x, &y, sc).unwrap();
    let ej = engine.eval_batch(&v, &sj, &x, &y, sc).unwrap();
    assert_eq!(
        ep.loss.to_bits(),
        ej.loss.to_bits(),
        "pallas and jnp quantizers must be numerically identical"
    );

    // --- booster mini-run: precision switch happens and training works -
    let mut cfg = config_for(&v, PrecisionPolicy::booster(1), Preset::Quick);
    cfg.epochs = 3;
    cfg.steps_per_epoch = 6;
    let result = Trainer::new(&engine, &v, &data, cfg).run().unwrap();
    assert_eq!(result.history.epochs.len(), 3);
    assert_eq!(result.history.epochs[0].bits_mid, 4.0);
    assert_eq!(result.history.epochs[2].bits_mid, 6.0); // boosted tail
    let first = result.history.epochs[0].train_loss;
    let last = result.history.epochs[2].train_loss;
    assert!(last < first, "loss should drop: {first} -> {last}");

    // --- transformer decode shape ---------------------------------------
    let vt = engine
        .load_variant_by_name(&artifacts, "transformer_bs64")
        .unwrap();
    let cfg_t = config_for(&vt, PrecisionPolicy::Fp32, Preset::Quick);
    let data_t = TrainerData::for_variant(&vt, &cfg_t).unwrap();
    if let TrainerData::Text(text) = &data_t {
        let st = init_state(&vt.manifest, 3).unwrap();
        let idx: Vec<usize> = (0..vt.manifest.batch).collect();
        let (src, refs) = text.decode_batch(&idx, true);
        let out = engine.decode(&vt, &st, &src, StepScalars::fp32()).unwrap();
        let dec = vt.manifest.decode.as_ref().unwrap();
        assert_eq!(out.shape(), &[vt.manifest.batch, dec.out_len]);
        assert_eq!(refs.len(), vt.manifest.batch);
        let toks = out.as_i32().unwrap();
        assert!(toks.iter().all(|&t| (0..32).contains(&t)));
    } else {
        panic!("transformer data must be text");
    }
}

#[test]
fn quantized_graph_matches_rust_bfp_on_degenerate_input() {
    // A 1x48 MLP input quantized by the graph at m=4 must equal the rust
    // quantizer's output: feed x through eval with weights = identity-ish
    // is overkill; instead check the *data path* by quantizing the batch
    // host-side and verifying the graph's FP32-bypass on pre-quantized
    // data equals the quantized run on raw data for the first linear
    // layer... which reduces to: Q(x) computed in rust equals Q(x) the
    // graph applies. We can't read intermediates out of the graph, so
    // this asserts the *loss* equality instead:
    //   eval(raw x, bits=4)  ==  eval(Q4(x), bits=4)
    // because Q is idempotent and the first dot quantizes its input.
    // Holds only when EVERY quantizer in the graph sees identical values
    // in both runs — i.e. when weights already are 4-bit representable.
    let Some(engine) = engine() else { return };
    let artifacts = artifacts_dir();
    let v = engine.load_variant_by_name(&artifacts, "mlp_bs64").unwrap();
    let cfg = config_for(&v, PrecisionPolicy::Hbfp { bits: 4 }, Preset::Quick);
    let data = TrainerData::for_variant(&v, &cfg).unwrap();
    let idx: Vec<usize> = (0..v.manifest.batch).collect();
    let (x, y) = data.batch(&idx, false);

    // Make weights 4-bit representable: quantize the initial params.
    let raw = boosters::coordinator::init::init_params(&v.manifest, 11).unwrap();
    let qparams: Vec<Tensor> = raw
        .iter()
        .map(|t| {
            let d = t.as_f32().unwrap();
            // Weights are quantized along their K axis in the graph; for
            // 2-D [K, N] weights the graph's blocking transposes first.
            // Idempotence is all we need, so quantize in that layout.
            let shape = t.shape().to_vec();
            if shape.len() == 2 {
                // (transpose so K is innermost, quantize, transpose back)
                let (k, n) = (shape[0], shape[1]);
                let mut tr = vec![0.0f32; d.len()];
                for i in 0..k {
                    for j in 0..n {
                        tr[j * k + i] = d[i * n + j];
                    }
                }
                let q = boosters::bfp::quantize_tensor(&tr, v.manifest.block, 4);
                let mut back = vec![0.0f32; d.len()];
                for j in 0..n {
                    for i in 0..k {
                        back[i * n + j] = q[j * k + i];
                    }
                }
                Tensor::from_f32(&shape, back).unwrap()
            } else {
                t.clone()
            }
        })
        .collect();

    let opt: Vec<Tensor> = v
        .manifest
        .opt
        .slots
        .iter()
        .map(|s| Tensor::zeros(&s.shape))
        .collect();
    let state = boosters::runtime::TrainState::from_tensors(&qparams, &opt).unwrap();
    let sc = StepScalars {
        bits_mid: 4.0,
        bits_edge: 4.0,
        rmode_grad: 0.0,
        seed: 0.0,
    };
    let e1 = engine.eval_batch(&v, &state, &x, &y, sc).unwrap();
    let e2 = engine.eval_batch(&v, &state, &x, &y, sc).unwrap();
    // Determinism sanity (the real idempotence assertion is in the golden
    // tests; graph-internal activations can't be pre-quantized from here).
    assert_eq!(e1.loss.to_bits(), e2.loss.to_bits());
    assert!(e1.loss.is_finite());
}
