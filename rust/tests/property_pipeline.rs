//! Property tests for the three-stage pipeline (PR 7): the decode/
//! writeback split and the buffer-recycling arena may reorder *work*,
//! never *numerics*. Every response that went through the staged
//! MAC-accumulate + deferred decode path must be bit-identical to the
//! per-op scalar reference — across kernel backends, pool widths, and
//! plane layouts (including wide i16 planes that run fused inside the
//! split) — and a recycled arena buffer must never leak a prior
//! batch's contents, even when the residency cap degrades checkouts to
//! stall-then-evict.

use boosters::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use boosters::exec::{BfpService, ExecRuntime, GemmRequest, OwnedGemmOp, ServiceConfig, Ticket};
use boosters::util::{KernelChoice, Rng};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// Narrow formats that take the MAC-split decode path, plus wide
/// formats (i16 mantissa planes, and one over the i32-overflow block
/// gate) that must run fused *inside* the split pipeline: both halves
/// of `StagedOut` are exercised in every run.
fn build_ops(rng: &mut Rng) -> Vec<OwnedGemmOp> {
    let mut out = Vec::new();
    for &(m, b) in &[
        (3u32, 16usize),
        (4, 16),
        (4, 64),
        (6, 64),
        (8, 16),
        // Wide mantissas -> i16 planes -> fused-in-split.
        (12, 576),
        (16, 64),
    ] {
        let fmt = BlockFormat::new(m, b).unwrap();
        for _ in 0..3 {
            let k = 1 + rng.below(2 * b.min(128) + 37);
            let r = 1 + rng.below(6);
            let c = 1 + rng.below(7);
            let x = Arc::new(Mat::new(r, k, randn(rng, r * k)).unwrap());
            let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
            out.push(OwnedGemmOp::new(x, w, fmt).unwrap());
        }
    }
    out
}

fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: elem {i}: {x} vs {y}");
    }
}

/// Acceptance gate (PR 7): the staged decode path — MAC accumulation
/// on the pool, decode/writeback on the dedicated stage thread — is
/// bit-identical to the per-op scalar reference under every
/// kernel-backend choice and pool width, and the decode-stage counters
/// attribute every completed op.
#[test]
fn prop_decode_split_bit_identical_across_kernels_and_threads() {
    let mut rng = Rng::new(0x1DE0);
    let ops = build_ops(&mut rng);
    for choice in [
        KernelChoice::Scalar,
        KernelChoice::Autovec,
        KernelChoice::Avx2,
        KernelChoice::Avx512,
        KernelChoice::Neon,
    ] {
        for threads in [1usize, 4] {
            let svc = BfpService::new(
                Arc::new(ExecRuntime::with_threads(threads)),
                ServiceConfig {
                    kernel: choice,
                    ..ServiceConfig::default()
                },
            );
            let tickets: Vec<Ticket> = ops
                .iter()
                .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
                .collect();
            for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
                let resp = t.wait().unwrap();
                let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
                assert_bits_eq(
                    &resp.out,
                    &want,
                    &format!(
                        "kernel {choice:?} threads {threads} op {i} (m={} b={})",
                        op.fmt.mantissa_bits, op.fmt.block_size
                    ),
                );
                // Stage attribution rides on every response.
                assert!(resp.encode_ms >= 0.0 && resp.gemm_ms >= 0.0 && resp.decode_ms >= 0.0);
            }
            let stats = svc.stats();
            assert_eq!(stats.decode_ops, ops.len() as u64, "{stats:?}");
            assert!(stats.decoded_overlapped <= stats.decode_ops, "{stats:?}");
            assert!((0.0..=1.0).contains(&stats.decode_overlap_rate()), "{stats:?}");
            assert!((0.0..=1.0).contains(&stats.arena_hit_rate()), "{stats:?}");
        }
    }
}

/// Purity: free lists deliberately poisoned with NaN f32 and junk i32
/// buffers large enough to serve every checkout class the batch asks
/// for must not perturb a single output bit — a recycled buffer never
/// leaks prior contents.
#[test]
fn prop_arena_purity_poisoned_freelists_never_leak() {
    let mut rng = Rng::new(0x9015);
    let ops = build_ops(&mut rng);
    let rt = Arc::new(ExecRuntime::with_threads(2));
    // Poison the way a hostile prior batch would: every element of
    // every class that the outputs / MAC planes / shift scratch will
    // reuse.
    for _ in 0..8 {
        let mut f = rt.arena().take_f32(1 << 12);
        f.iter_mut().for_each(|v| *v = f32::NAN);
        rt.arena().put_f32(f);
        let mut i = rt.arena().take_i32(1 << 14);
        i.iter_mut().for_each(|v| *v = i32::MIN);
        rt.arena().put_i32(i);
    }
    let before = rt.arena().stats();
    let svc = BfpService::new(Arc::clone(&rt), ServiceConfig::default());
    let tickets: Vec<Ticket> = ops
        .iter()
        .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
        .collect();
    for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
        let resp = t.wait().unwrap();
        let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
        assert_bits_eq(&resp.out, &want, &format!("poisoned-arena op {i}"));
        assert!(resp.out.data.iter().all(|v| !v.is_nan()));
    }
    let after = rt.arena().stats();
    assert!(
        after.hits > before.hits,
        "poisoned free-list buffers were never recycled: {after:?}"
    );
}

/// A pathological 1-byte residency cap degrades checkouts to
/// stall-then-evict-then-allocate — throughput suffers, numerics and
/// liveness never do.
#[test]
fn prop_tiny_arena_cap_stalls_never_corrupts() {
    let mut rng = Rng::new(0x7149);
    let mut ops = build_ops(&mut rng);
    // Every checkout under a 1-byte cap pays bounded stall rounds while
    // other buffers are outstanding; a handful of ops keeps the test
    // fast while still cycling both StagedOut halves through the cap
    // (the grid's tail is the wide fused-in-split formats).
    let wide: Vec<OwnedGemmOp> = ops.split_off(ops.len() - 4);
    ops.truncate(4);
    ops.extend(wide);
    let rt = Arc::new(ExecRuntime::new_with_caps(2, 64, 16 << 20, 1));
    let svc = BfpService::new(Arc::clone(&rt), ServiceConfig::default());
    let tickets: Vec<Ticket> = ops
        .iter()
        .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
        .collect();
    for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
        let resp = t.wait().unwrap();
        let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
        assert_bits_eq(&resp.out, &want, &format!("capped-arena op {i}"));
    }
    let stats = svc.stats();
    assert_eq!(stats.completed, ops.len() as u64, "{stats:?}");
    assert_eq!(stats.decode_ops, ops.len() as u64, "{stats:?}");
    // Every ticket was taken and a 1-byte cap retains nothing, so the
    // arena must account zero residency once the pipeline drains.
    assert_eq!(stats.arena_resident_bytes, 0, "{stats:?}");
}

/// Tickets dropped without `wait` recycle their arena-backed outputs
/// (the drop half of the ticket/arena contract): a second identical
/// round must see free-list hits, and its results stay bit-exact.
#[test]
fn prop_dropped_tickets_recycle_outputs() {
    const SEED: u64 = 0xD20F;
    let ops = build_ops(&mut Rng::new(SEED));
    let svc = BfpService::with_threads(2);
    let tickets: Vec<Ticket> = ops
        .iter()
        .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
        .collect();
    // Let every op complete, then abandon all results unconsumed.
    let deadline = Instant::now() + Duration::from_secs(60);
    while !tickets.iter().all(Ticket::poll) {
        assert!(Instant::now() < deadline, "pipeline never drained");
        std::thread::sleep(Duration::from_millis(1));
    }
    drop(tickets);
    let mid = svc.stats();
    assert_eq!(mid.completed, ops.len() as u64, "{mid:?}");
    // Round two: identical shapes, so every output class the decode
    // stage checks out was just recycled by the dropped tickets.
    let ops2 = build_ops(&mut Rng::new(SEED));
    let tickets2: Vec<Ticket> = ops2
        .iter()
        .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
        .collect();
    for (i, (t, op)) in tickets2.iter().zip(&ops2).enumerate() {
        let resp = t.wait().unwrap();
        let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
        assert_bits_eq(&resp.out, &want, &format!("post-recycle op {i}"));
    }
    let after = svc.stats();
    assert!(after.arena_hits > mid.arena_hits, "{after:?}");
    assert!(after.arena_recycled_bytes > 0, "{after:?}");
}
