//! Encode-parity property grid for the unified block-writer encode
//! core: every mantissa-plane layout (nibble-packed `I4Packed`, `I8`,
//! `I16`) must encode bit-identically to the scalar reference
//! quantizer, under serial and threaded pools, on ragged-K shapes,
//! through both the row-wise and the transposed (weight-side) paths.
//! The pool splits (row-band, block-range, transposed column bands)
//! exist in exactly one generic copy since PR 5 — this suite is the
//! gate that the unification changed no bits.

use boosters::bfp::{quantize_flat, BfpMatrix, BlockFormat, Mat, PlaneLayout, Quantizer};
use boosters::exec::ExecRuntime;
use boosters::util::Rng;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// f32 equality that identifies +/-0 but is bitwise otherwise (packed
/// round-trips canonicalize -0.0 — the documented exception).
fn same(a: f32, b: f32) -> bool {
    (a == 0.0 && b == 0.0) || a.to_bits() == b.to_bits()
}

/// One format per layout, all with ragged-friendly block sizes:
/// `(mantissa_bits, block_size, expected layout)`.
const LAYOUT_GRID: &[(u32, usize, PlaneLayout)] = &[
    (3, 16, PlaneLayout::I4Packed),
    (4, 64, PlaneLayout::I4Packed),
    (6, 64, PlaneLayout::I8),
    (4, 49, PlaneLayout::I8), // odd block: m <= 4 stays on the byte plane
    (8, 16, PlaneLayout::I8),
    (12, 64, PlaneLayout::I16),
];

/// Every layout x {multi-row ragged, single-row} x {nearest,
/// stochastic}: the unified encode decodes exactly what the scalar
/// reference quantizer emits, row by row (rows restart the stream).
#[test]
fn prop_unified_encode_matches_scalar_quantizer_grid() {
    let mut rng = Rng::new(0xE4C0);
    for &(m, b, layout) in LAYOUT_GRID {
        let fmt = BlockFormat::new(m, b).unwrap();
        for &(rows, cols) in &[(5usize, 2 * b + 37), (1usize, 3 * b + 11), (3, b - 1)] {
            let data = randn(&mut rng, rows * cols);
            for q in [Quantizer::nearest(m), Quantizer::stochastic(m, 17)] {
                let enc = BfpMatrix::encode(&data, rows, cols, fmt, q).unwrap();
                assert_eq!(enc.mantissas.layout(), layout, "m={m} b={b}");
                let mut got = Vec::new();
                enc.decode_into(&mut got);
                for r in 0..rows {
                    let want = quantize_flat(&data[r * cols..(r + 1) * cols], b, q, 0);
                    for (i, (g, w)) in got[r * cols..(r + 1) * cols].iter().zip(&want).enumerate()
                    {
                        assert!(
                            same(*g, *w),
                            "m={m} b={b} rows={rows} row {r} elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }
}

/// Serial pool vs multi-thread pool produce byte-identical planes for
/// every layout, above the parallel-encode threshold (so the row-band
/// split actually engages on the threaded runtime). Compared at the
/// plane level, not just decoded values.
#[test]
fn prop_threaded_encode_planes_bit_identical_to_serial() {
    let mut rng = Rng::new(0xE4C1);
    // 128 x 640 = 80k elements: past PARALLEL_MIN_ENCODE (64k).
    let (rows, cols) = (128usize, 640usize);
    let data = randn(&mut rng, rows * cols);
    let serial = ExecRuntime::with_threads(1);
    let threaded = ExecRuntime::with_threads(4);
    for &(m, b, layout) in LAYOUT_GRID {
        let fmt = BlockFormat::new(m, b).unwrap();
        let a = serial.encode_cached(&data, rows, cols, fmt).unwrap();
        let c = threaded.encode_cached(&data, rows, cols, fmt).unwrap();
        assert_eq!(a.exponents, c.exponents, "m={m} b={b}");
        match layout {
            PlaneLayout::I4Packed => {
                assert_eq!(a.mantissas.try_i4().unwrap(), c.mantissas.try_i4().unwrap())
            }
            PlaneLayout::I8 => {
                assert_eq!(a.mantissas.try_i8().unwrap(), c.mantissas.try_i8().unwrap())
            }
            PlaneLayout::I16 => {
                assert_eq!(a.mantissas.try_i16().unwrap(), c.mantissas.try_i16().unwrap())
            }
        }
    }
}

/// The transposed (weight-side) encode equals the row encode of the
/// explicit transpose for every layout — on a small serial shape and
/// on a wide shape that engages the transposed column-band pool split.
#[test]
fn prop_transposed_encode_parity_across_layouts() {
    let mut rng = Rng::new(0xE4C2);
    for &(m, b, layout) in LAYOUT_GRID {
        let fmt = BlockFormat::new(m, b).unwrap();
        let q = Quantizer::nearest(m);
        // (k, n): small serial case, then wide-enough-to-split case.
        for &(k, n) in &[(2 * b + 5, 3usize), (257usize, 300usize)] {
            let w = Mat::new(k, n, randn(&mut rng, k * n)).unwrap();
            let a = BfpMatrix::encode_transposed(&w, fmt, q).unwrap();
            let wt = w.transpose();
            let bmat = BfpMatrix::encode(&wt.data, wt.rows, wt.cols, fmt, q).unwrap();
            assert_eq!(a.exponents, bmat.exponents, "m={m} b={b} k={k} n={n}");
            match layout {
                PlaneLayout::I4Packed => assert_eq!(
                    a.mantissas.try_i4().unwrap(),
                    bmat.mantissas.try_i4().unwrap(),
                    "m={m} b={b} k={k} n={n}"
                ),
                PlaneLayout::I8 => assert_eq!(
                    a.mantissas.try_i8().unwrap(),
                    bmat.mantissas.try_i8().unwrap(),
                    "m={m} b={b} k={k} n={n}"
                ),
                PlaneLayout::I16 => assert_eq!(
                    a.mantissas.try_i16().unwrap(),
                    bmat.mantissas.try_i16().unwrap(),
                    "m={m} b={b} k={k} n={n}"
                ),
            }
        }
    }
}

/// The nibble-direct writer packs exactly the mantissas the byte-plane
/// path would produce: an m=4 even-block encode and an m=4 odd-block
/// encode (forced onto the i8 plane) of the same values agree value
/// for value wherever their blockings coincide — and the packed plane
/// holds half the bytes.
#[test]
fn prop_nibble_direct_writer_matches_byte_writer_values() {
    let mut rng = Rng::new(0xE4C3);
    let cols = 4096usize;
    let data = randn(&mut rng, cols);
    let q = Quantizer::nearest(4);
    // The even block size selects the nibble-direct writer; the scalar
    // quantizer is the value-level reference for what each stored
    // nibble must decode to.
    let fmt = BlockFormat::new(4, 16).unwrap();
    let enc = BfpMatrix::encode(&data, 1, cols, fmt, q).unwrap();
    assert_eq!(enc.mantissas.layout(), PlaneLayout::I4Packed);
    assert_eq!(2 * enc.mantissas.resident_bytes(), enc.mantissas.len());
    let want = quantize_flat(&data, 16, q, 0);
    let mut got = Vec::new();
    enc.decode_into(&mut got);
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(same(*g, *w), "elem {i}: {g} vs {w}");
    }
    // Every stored mantissa sits in the 4-bit two's-complement range.
    for i in 0..cols {
        let v = enc.mantissas.value(i);
        assert!((-8..=7).contains(&v), "elem {i}: {v} out of 4-bit range");
    }
}
