//! Cross-language numerics contract: replay the golden vectors emitted by
//! `python/compile/golden.py` (from the jnp oracle) through the rust BFP
//! quantizer and require **bit-exact** agreement, plus the raw XORshift
//! stream. This is what licenses the rust-side analyses (Fig 1/2) to
//! claim they see the same numerics the AOT training graph applies.
//!
//! The packed tensor engine (`quantize_packed`) is replayed against the
//! same vectors, so the golden file pins *both* carriers. The only
//! licensed divergence: integer mantissa planes cannot store -0.0, so
//! the packed path canonicalizes it to +0.0.
//!
//! The golden file is **checked in** at `rust/artifacts/golden_bfp.json`
//! (regenerate with `python -m python.compile.golden`), so these tests
//! pin the contract on every `cargo test` run. Should the file be
//! absent (custom `REPRO_ARTIFACTS`), the tests return early — note
//! that libtest captures the skip message unless run with
//! `-- --nocapture`, so a green run with a missing file is easy to
//! mistake for a real replay; keep the file in the tree.

use boosters::bfp::{
    quantize_flat, quantize_packed, xorshift_hash, BfpMatrix, BlockFormat, PlaneLayout, Quantizer,
    RoundMode,
};
use boosters::runtime::artifacts_dir;
use boosters::util::Json;

fn load_golden() -> Option<Json> {
    let path = artifacts_dir().join("golden_bfp.json");
    let text = std::fs::read_to_string(&path).ok()?;
    Some(Json::parse(&text).expect("golden json parses"))
}

fn skip() {
    eprintln!(
        "SKIP: golden_bfp.json missing — it ships at rust/artifacts/golden_bfp.json; \
         restore it (or `python -m python.compile.golden`) to pin the numerics contract"
    );
}

#[test]
fn golden_quantize_bitexact() {
    let Some(doc) = load_golden() else {
        skip();
        return;
    };
    let cases = doc.req("cases").unwrap().as_arr().unwrap();
    assert!(cases.len() > 30, "expected a full golden sweep");
    let mut checked = 0usize;
    for c in cases {
        let input = c.req("input").unwrap().as_f32_vec().unwrap();
        let want = c.req("output").unwrap().as_f32_vec().unwrap();
        let block = c.req("block").unwrap().as_usize().unwrap();
        let m = c.req("m_bits").unwrap().as_usize().unwrap() as u32;
        let rmode = c.req("rmode").unwrap().as_usize().unwrap();
        let seed = c.req("seed").unwrap().as_i64().unwrap() as u32;
        let site = c.req("site").unwrap().as_usize().unwrap() as u32;
        let q = Quantizer {
            m_bits: m,
            mode: if rmode == 1 {
                RoundMode::Stochastic
            } else {
                RoundMode::NearestEven
            },
            seed,
        };
        let got = quantize_flat(&input, block, q, site);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "case m={m} b={block} rmode={rmode} seed={seed} site={site} elem {i}: {g} != {w}"
            );
            checked += 1;
        }
        // The packed carrier must reproduce the same oracle vectors
        // (modulo the sign of zero, which integer mantissas drop).
        let packed = quantize_packed(&input, block, q, site);
        for (i, (g, w)) in packed.iter().zip(&want).enumerate() {
            let same = (*g == 0.0 && *w == 0.0) || g.to_bits() == w.to_bits();
            assert!(
                same,
                "packed: case m={m} b={block} rmode={rmode} site={site} elem {i}: {g} != {w}"
            );
        }
    }
    assert!(checked > 10_000, "checked {checked} values");
}

#[test]
fn golden_i4packed_plane_bitexact() {
    // The nibble-packed mantissa plane is a *storage* change, never a
    // numeric one: replay every deterministic (nearest-even) golden
    // case that lands on the I4Packed layout (m <= 4, even block)
    // through a direct plane encode and require the decode to match
    // the jnp-oracle vectors bit-for-bit (modulo -0.0, which integer
    // mantissas canonicalize) — while asserting the plane really is
    // nibble-packed at half a byte per value.
    let Some(doc) = load_golden() else {
        skip();
        return;
    };
    let cases = doc.req("cases").unwrap().as_arr().unwrap();
    let mut checked = 0usize;
    for c in cases {
        let m = c.req("m_bits").unwrap().as_usize().unwrap() as u32;
        let block = c.req("block").unwrap().as_usize().unwrap();
        let rmode = c.req("rmode").unwrap().as_usize().unwrap();
        if m > 4 || block % 2 != 0 || rmode != 0 {
            continue;
        }
        let input = c.req("input").unwrap().as_f32_vec().unwrap();
        let want = c.req("output").unwrap().as_f32_vec().unwrap();
        let fmt = BlockFormat::new(m, block).unwrap();
        assert_eq!(fmt.plane_layout(), PlaneLayout::I4Packed);
        let enc =
            BfpMatrix::encode(&input, 1, input.len(), fmt, Quantizer::nearest(m)).unwrap();
        assert_eq!(enc.mantissas.layout(), PlaneLayout::I4Packed, "m={m} b={block}");
        assert_eq!(
            2 * enc.mantissas.resident_bytes(),
            enc.mantissas.len(),
            "two 4-bit mantissas per stored byte"
        );
        let mut got = Vec::new();
        enc.decode_into(&mut got);
        assert_eq!(got.len(), want.len());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let same = (*g == 0.0 && *w == 0.0) || g.to_bits() == w.to_bits();
            assert!(same, "i4packed: case m={m} b={block} elem {i}: {g} != {w}");
        }
        checked += 1;
    }
    assert!(checked >= 5, "expected the m<=4 even-block nearest cases, got {checked}");
}

#[test]
fn golden_xorshift_stream() {
    let Some(doc) = load_golden() else {
        skip();
        return;
    };
    let streams = doc.req("xorshift").unwrap();
    for (seed_str, arr) in match streams {
        Json::Obj(fields) => fields.iter(),
        _ => panic!("xorshift must be an object"),
    } {
        let seed: u32 = seed_str.parse().unwrap();
        let want = arr.as_arr().unwrap();
        for (idx, w) in want.iter().enumerate() {
            let got = xorshift_hash(idx as u32, seed);
            assert_eq!(got as i64, w.as_i64().unwrap(), "seed {seed} idx {idx}");
        }
    }
}
