//! Property tests for the asynchronous execution service: admission
//! order, deadlines, priorities, and backpressure may reorder
//! *execution*, never *numerics* — every response must be bit-identical
//! to the per-op scalar reference and to the synchronous facade, across
//! thread counts and arrival orders; the bounded queue must return the
//! typed `AdmissionError` instead of blocking; deadline misses must be
//! observed and counted, never enforced by cancellation.

use boosters::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use boosters::exec::{
    AdmissionError, BatchGemm, BfpService, ExecRuntime, GemmRequest, OwnedGemmOp, Priority,
    ServiceConfig, Ticket,
};
use boosters::util::{KernelChoice, Rng};
use std::sync::Arc;
use std::time::Duration;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// The m in {3,4,6,8} x {16,64} grid with ragged K, 3 cases each:
/// 24 heterogeneous ops sharing a few weight operands.
fn build_ops(rng: &mut Rng) -> Vec<OwnedGemmOp> {
    let mut out = Vec::new();
    for &m in &[3u32, 4, 6, 8] {
        for &b in &[16usize, 64] {
            let fmt = BlockFormat::new(m, b).unwrap();
            for _ in 0..3 {
                // Ragged K: rarely a block multiple, sometimes < b.
                let k = 1 + rng.below(2 * b + 37);
                let r = 1 + rng.below(6);
                let c = 1 + rng.below(7);
                let x = Arc::new(Mat::new(r, k, randn(rng, r * k)).unwrap());
                let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
                out.push(OwnedGemmOp::new(x, w, fmt).unwrap());
            }
        }
    }
    out
}

fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: elem {i}: {x} vs {y}");
    }
}

/// Acceptance gate: async responses are bit-identical to the per-op
/// scalar reference and to the synchronous facade, across thread
/// counts and with a mix of deadlines/priorities in flight.
#[test]
fn prop_async_bit_identical_to_sync_and_scalar() {
    let mut rng = Rng::new(0xA51C);
    let ops = build_ops(&mut rng);
    let sync_rt = ExecRuntime::with_threads(1);
    let sync = BatchGemm::new(&sync_rt).run(&ops).unwrap();
    for threads in [1usize, 4] {
        let svc = BfpService::with_threads(threads);
        let tickets: Vec<Ticket> = ops
            .iter()
            .enumerate()
            .map(|(i, op)| {
                // Interleave QoS envelopes; none of this may touch bits.
                let mut req = GemmRequest::new(op.clone());
                if i % 2 == 0 {
                    req = req.with_deadline(Duration::from_secs(60));
                }
                if i % 3 == 0 {
                    req = req.with_priority(Priority::Interactive);
                }
                svc.submit_blocking(req).unwrap()
            })
            .collect();
        for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
            let resp = t.wait().unwrap();
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            let ctx = format!(
                "threads={threads} op {i} (m={} b={})",
                op.fmt.mantissa_bits, op.fmt.block_size
            );
            assert_bits_eq(&resp.out, &want, &format!("{ctx} vs scalar"));
            assert_bits_eq(&resp.out, &sync[i], &format!("{ctx} vs sync facade"));
        }
    }
}

/// Acceptance gate (PR 4, extended PR 6): the service stays
/// bit-identical to the scalar reference under **every kernel-backend
/// choice** — forced scalar, forced autovec, and forced AVX2 /
/// AVX-512-VNNI / NEON (each of which degrades loudly to a runnable
/// backend on hosts without the feature) — across serial and
/// multi-thread pools, on the full grid (nibble-packed m <= 4 planes
/// included). The adaptive batch budget is active throughout; like
/// every scheduling knob it can never touch numerics.
#[test]
fn prop_service_bit_identical_under_every_kernel_choice() {
    let mut rng = Rng::new(0x6B31);
    let ops = build_ops(&mut rng);
    for choice in [
        KernelChoice::Scalar,
        KernelChoice::Autovec,
        KernelChoice::Avx2,
        KernelChoice::Avx512,
        KernelChoice::Neon,
    ] {
        for threads in [1usize, 4] {
            let svc = BfpService::new(
                Arc::new(ExecRuntime::with_threads(threads)),
                ServiceConfig {
                    kernel: choice,
                    ..ServiceConfig::default()
                },
            );
            assert!(!svc.stats().kernel.is_empty());
            let tickets: Vec<Ticket> = ops
                .iter()
                .map(|op| svc.submit_blocking(GemmRequest::new(op.clone())).unwrap())
                .collect();
            for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
                let resp = t.wait().unwrap();
                let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
                assert_bits_eq(
                    &resp.out,
                    &want,
                    &format!(
                        "kernel {choice:?} threads {threads} op {i} (m={} b={})",
                        op.fmt.mantissa_bits, op.fmt.block_size
                    ),
                );
            }
            // The effective adaptive budget stayed inside its
            // [base/4, 4*base] envelope and was surfaced.
            let stats = svc.stats();
            let base = ServiceConfig::default().max_batch_macs as u64;
            assert!(
                (base / 4..=4 * base).contains(&stats.effective_batch_macs),
                "{:?}",
                stats
            );
        }
    }
}

/// Acceptance gate (PR 5): the pipeline's admission-time pre-encode is
/// invisible to numerics. Ops forced through the pre-encode stage
/// (pause batch formation, wait until the encode thread fills every
/// op's shared slot, resume) return bits identical to the synchronous
/// facade (inline encode, fresh ops) and to the scalar reference —
/// across thread counts and under every kernel-backend choice — and
/// the service counters attribute every op to the pre-encode path.
#[test]
fn prop_pre_encoded_bit_identical_to_inline_and_scalar() {
    const SEED: u64 = 0x93E3;
    // Inline-encoded comparator: the sync facade on ops whose slots
    // nothing ever fills.
    let inline_ops = build_ops(&mut Rng::new(SEED));
    let inline_rt = ExecRuntime::with_threads(1);
    let inline = BatchGemm::new(&inline_rt).run(&inline_ops).unwrap();
    for choice in [
        KernelChoice::Scalar,
        KernelChoice::Autovec,
        KernelChoice::Avx2,
        KernelChoice::Avx512,
        KernelChoice::Neon,
    ] {
        for threads in [1usize, 4] {
            // Fresh ops (same deterministic values, EMPTY slots) per
            // grid cell, so every cell's pre-encode stage really runs
            // under its own pool width and kernel choice instead of
            // consuming slots a previous cell filled.
            let ops = build_ops(&mut Rng::new(SEED));
            let svc = BfpService::new(
                Arc::new(ExecRuntime::with_threads(threads)),
                ServiceConfig {
                    kernel: choice,
                    ..ServiceConfig::default()
                },
            );
            // Freeze batch formation; the pre-encode stage keeps
            // running, so every submitted op's slot fills while no
            // batch can execute — a deterministic all-pre-encoded run.
            svc.pause();
            let tickets: Vec<Ticket> = ops
                .iter()
                .map(|op| svc.submit(GemmRequest::new(op.clone())).unwrap())
                .collect();
            let deadline = std::time::Instant::now() + Duration::from_secs(60);
            while !ops.iter().all(OwnedGemmOp::is_pre_encoded) {
                assert!(
                    std::time::Instant::now() < deadline,
                    "pre-encode stage never filled all slots ({choice:?}, {threads} threads)"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            svc.resume();
            for (i, (t, op)) in tickets.iter().zip(&ops).enumerate() {
                let resp = t.wait().unwrap();
                let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
                let ctx = format!(
                    "kernel {choice:?} threads {threads} op {i} (m={} b={})",
                    op.fmt.mantissa_bits, op.fmt.block_size
                );
                assert_bits_eq(&resp.out, &want, &format!("{ctx} vs scalar"));
                assert_bits_eq(&resp.out, &inline[i], &format!("{ctx} vs inline encode"));
            }
            let stats = svc.stats();
            assert_eq!(stats.pre_encoded, ops.len() as u64, "{stats:?}");
            assert_eq!(stats.inline_encoded, 0, "{stats:?}");
            assert_eq!(stats.pre_encode_hit_rate(), 1.0);
        }
    }
    // The sync facade itself never publishes slots: the comparator ops
    // went through BatchGemm::run and must all still be slot-free.
    assert!(inline_ops.iter().all(|op| !op.is_pre_encoded()));
}

/// Submitting the same ops in a different order yields the same bits
/// per op — admission order is a scheduling detail, not a numeric one.
#[test]
fn prop_submission_order_independence() {
    let mut rng = Rng::new(0x0D3A);
    let ops = build_ops(&mut rng);
    let forward_svc = BfpService::with_threads(3);
    let forward: Vec<Mat> = ops
        .iter()
        .map(|op| {
            forward_svc
                .submit_blocking(GemmRequest::new(op.clone()))
                .unwrap()
        })
        .collect::<Vec<_>>()
        .iter()
        .map(|t| t.wait().unwrap().out)
        .collect();
    let mut perm: Vec<usize> = (0..ops.len()).collect();
    rng.shuffle(&mut perm);
    let perm_svc = BfpService::with_threads(3);
    // Submit everything in permuted order *before* waiting on anything,
    // so the admission loop actually sees the permuted stream.
    let tickets: Vec<(usize, Ticket)> = perm
        .iter()
        .map(|&orig| {
            (
                orig,
                perm_svc
                    .submit_blocking(GemmRequest::new(ops[orig].clone()))
                    .unwrap(),
            )
        })
        .collect();
    for (orig, t) in tickets {
        let resp = t.wait().unwrap();
        assert_bits_eq(
            &resp.out,
            &forward[orig],
            &format!("permuted submission of op {orig}"),
        );
    }
}

/// Deadline misses are observed (flag + counter) and never affect
/// results; generous deadlines never count as missed.
#[test]
fn prop_deadline_miss_accounting() {
    let mut rng = Rng::new(0xDEAD);
    let fmt = BlockFormat::new(4, 16).unwrap();
    let svc = BfpService::with_threads(2);
    let mk = |rng: &mut Rng| {
        OwnedGemmOp::new(
            Arc::new(Mat::new(3, 32, randn(rng, 96)).unwrap()),
            Arc::new(Mat::new(32, 4, randn(rng, 128)).unwrap()),
            fmt,
        )
        .unwrap()
    };
    // Zero-duration deadlines are in the past by the time the scheduler
    // fulfills them: guaranteed misses, deterministic accounting.
    let doomed: Vec<Ticket> = (0..5)
        .map(|_| {
            svc.submit(GemmRequest::new(mk(&mut rng)).with_deadline(Duration::ZERO))
                .unwrap()
        })
        .collect();
    let relaxed = svc
        .submit(GemmRequest::new(mk(&mut rng)).with_deadline(Duration::from_secs(3600)))
        .unwrap();
    let unconstrained = svc.submit(GemmRequest::new(mk(&mut rng))).unwrap();
    for t in &doomed {
        let resp = t.wait().unwrap();
        assert!(resp.deadline_missed, "zero deadline must be missed");
        assert!(resp.out.data.iter().all(|v| v.is_finite()));
    }
    assert!(!relaxed.wait().unwrap().deadline_missed);
    assert!(!unconstrained.wait().unwrap().deadline_missed);
    let stats = svc.stats();
    assert_eq!(stats.deadline_missed, 5, "{stats:?}");
    assert_eq!(stats.completed, 7, "{stats:?}");
    assert_eq!(stats.miss_rate(), 5.0 / 7.0);
}

/// A full bounded queue returns `AdmissionError::QueueFull` from
/// `submit` immediately instead of blocking forever; draining restores
/// admission, and everything admitted still completes correctly.
#[test]
fn prop_bounded_queue_backpressure() {
    let mut rng = Rng::new(0xB0B5);
    let fmt = BlockFormat::new(4, 16).unwrap();
    let capacity = 3usize;
    let svc = BfpService::new(
        Arc::new(ExecRuntime::with_threads(2)),
        ServiceConfig {
            queue_capacity: capacity,
            ..ServiceConfig::default()
        },
    );
    // Freeze the admission loop so the pipeline is deterministically
    // "full" rather than racing the scheduler thread.
    svc.pause();
    let mk = |rng: &mut Rng| {
        OwnedGemmOp::new(
            Arc::new(Mat::new(2, 16, randn(rng, 32)).unwrap()),
            Arc::new(Mat::new(16, 3, randn(rng, 48)).unwrap()),
            fmt,
        )
        .unwrap()
    };
    let admitted: Vec<(OwnedGemmOp, Ticket)> = (0..capacity)
        .map(|_| {
            let op = mk(&mut rng);
            let t = svc.submit(GemmRequest::new(op.clone())).unwrap();
            (op, t)
        })
        .collect();
    // The queue is now full: submit must fail fast with the typed
    // error, not block.
    let overflow_op = mk(&mut rng);
    match svc.submit(GemmRequest::new(overflow_op.clone())) {
        Err(AdmissionError::QueueFull { capacity: c }) => assert_eq!(c, capacity),
        other => panic!("expected QueueFull, got {other:?}"),
    }
    let stats = svc.stats();
    assert_eq!(stats.rejected, 1, "{stats:?}");
    assert_eq!(stats.queue_depth, capacity, "{stats:?}");
    assert_eq!(stats.peak_queue_depth, capacity, "{stats:?}");
    // Nothing was fulfilled while paused.
    assert!(admitted.iter().all(|(_, t)| !t.poll()));
    svc.resume();
    for (i, (op, t)) in admitted.iter().enumerate() {
        let resp = t.wait().unwrap();
        let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
        assert_bits_eq(&resp.out, &want, &format!("admitted op {i} after resume"));
    }
    // Space freed: the previously rejected op now goes through.
    let t = svc.submit(GemmRequest::new(overflow_op.clone())).unwrap();
    let resp = t.wait().unwrap();
    let want = hbfp_gemm_scalar(&overflow_op.x, &overflow_op.w, overflow_op.fmt).unwrap();
    assert_bits_eq(&resp.out, &want, "resubmitted overflow op");
}

/// `wait_deadline` times out on in-flight work without consuming the
/// ticket, and delivers the result on a later call.
#[test]
fn prop_wait_deadline_preserves_ticket() {
    let mut rng = Rng::new(0x71C7);
    let fmt = BlockFormat::new(6, 16).unwrap();
    let svc = BfpService::with_threads(2);
    svc.pause();
    let op = OwnedGemmOp::new(
        Arc::new(Mat::new(4, 48, randn(&mut rng, 192)).unwrap()),
        Arc::new(Mat::new(48, 5, randn(&mut rng, 240)).unwrap()),
        fmt,
    )
    .unwrap();
    let ticket = svc.submit(GemmRequest::new(op.clone())).unwrap();
    // Paused service: the bounded wait must expire, leaving the ticket
    // usable.
    assert!(ticket.wait_deadline(Duration::from_millis(20)).is_none());
    assert!(!ticket.poll());
    svc.resume();
    let resp = ticket
        .wait_deadline(Duration::from_secs(60))
        .expect("must complete after resume")
        .unwrap();
    let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
    assert_bits_eq(&resp.out, &want, "wait_deadline result");
}
