//! Property tests for weight-stationary grouped execution (PR 10):
//! digest-grouping same-weight ops into one tall-M GEMM may change how
//! *work* is traversed — weight planes stream once per band tile per
//! group instead of once per op — but never a single output bit.
//! Grouped and ungrouped runs of the same mixed batch must agree with
//! each other and with the per-op scalar reference, across every
//! kernel backend, pool width, and plane layout (nibble-packed i4,
//! i8, and wide i16 planes that run fused inside the split and are
//! never grouped), under ragged K and arbitrary submission order.

use boosters::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use boosters::exec::{BfpService, ExecRuntime, GemmRequest, OwnedGemmOp, ServiceConfig, Ticket};
use boosters::util::{KernelChoice, Rng};
use std::sync::Arc;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// Mixed batch with deliberate same-weight runs: for each format on
/// the grid, two shared weights carrying three ops each (distinct
/// activation heights) plus one solo-weight op. Formats cover every
/// plane layout — (4,16)/(4,64) nibble-packed i4, (6,64)/(8,16) i8,
/// and (12,576)/(16,64) wide i16 planes that run fused-in-split and
/// must ride through a grouped batch untouched. K is ragged: every
/// weight gets its own K, so groups with different K coexist.
fn build_grouped_ops(rng: &mut Rng) -> Vec<OwnedGemmOp> {
    let mut out = Vec::new();
    for &(m, b) in &[
        (4u32, 16usize),
        (4, 64),
        (6, 64),
        (8, 16),
        // Wide mantissas -> i16 planes -> fused-in-split, never grouped.
        (12, 576),
        (16, 64),
    ] {
        let fmt = BlockFormat::new(m, b).unwrap();
        for _ in 0..2 {
            let k = 1 + rng.below(2 * b.min(128) + 37);
            let c = 1 + rng.below(7);
            let shared = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
            for _ in 0..3 {
                let r = 1 + rng.below(9);
                let x = Arc::new(Mat::new(r, k, randn(rng, r * k)).unwrap());
                out.push(OwnedGemmOp::new(x, Arc::clone(&shared), fmt).unwrap());
            }
        }
        // One solo weight per format: stays ungrouped by construction.
        let k = 1 + rng.below(2 * b.min(128) + 37);
        let c = 1 + rng.below(6);
        let r = 1 + rng.below(5);
        let x = Arc::new(Mat::new(r, k, randn(rng, r * k)).unwrap());
        let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
        out.push(OwnedGemmOp::new(x, w, fmt).unwrap());
    }
    out
}

fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: elem {i}: {x} vs {y}");
    }
}

/// Drive one service over `ops` in the given submission order with
/// batch formation paused, so the whole stream lands in as few batches
/// as the budget allows — the shape that actually forms groups.
fn drive(svc: &BfpService, ops: &[OwnedGemmOp], order: &[usize]) -> Vec<Mat> {
    svc.pause();
    let tickets: Vec<(usize, Ticket)> = order
        .iter()
        .map(|&i| (i, svc.submit(GemmRequest::new(ops[i].clone())).unwrap()))
        .collect();
    svc.resume();
    let mut outs: Vec<Option<Mat>> = (0..ops.len()).map(|_| None).collect();
    for (i, t) in tickets {
        outs[i] = Some(t.wait().unwrap().out);
    }
    outs.into_iter().map(Option::unwrap).collect()
}

/// Acceptance gate (PR 10): grouped execution is bit-identical to both
/// the ungrouped service and the per-op scalar reference across every
/// kernel backend × pool width × plane layout, and the grouped
/// counters partition the completed stream exactly.
#[test]
fn prop_grouped_bit_identical_across_kernels_threads_layouts() {
    let mut rng = Rng::new(0x62B1);
    let ops = build_grouped_ops(&mut rng);
    let order: Vec<usize> = (0..ops.len()).collect();
    let want: Vec<Mat> = ops
        .iter()
        .map(|op| hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap())
        .collect();
    for choice in [
        KernelChoice::Scalar,
        KernelChoice::Autovec,
        KernelChoice::Avx2,
        KernelChoice::Avx512,
        KernelChoice::Neon,
    ] {
        for threads in [1usize, 4] {
            let grouped = BfpService::new(
                Arc::new(ExecRuntime::with_threads(threads)),
                ServiceConfig {
                    kernel: choice,
                    group_min_ops: 2,
                    ..ServiceConfig::default()
                },
            );
            let ungrouped = BfpService::new(
                Arc::new(ExecRuntime::with_threads(threads)),
                ServiceConfig {
                    kernel: choice,
                    group_min_ops: 0,
                    ..ServiceConfig::default()
                },
            );
            let got_g = drive(&grouped, &ops, &order);
            let got_u = drive(&ungrouped, &ops, &order);
            for (i, ((g, u), w)) in got_g.iter().zip(&got_u).zip(&want).enumerate() {
                let ctx = format!(
                    "kernel {choice:?} threads {threads} op {i} (m={} b={})",
                    ops[i].fmt.mantissa_bits, ops[i].fmt.block_size
                );
                assert_bits_eq(g, w, &format!("{ctx} grouped-vs-scalar"));
                assert_bits_eq(u, w, &format!("{ctx} ungrouped-vs-scalar"));
            }
            let gs = grouped.stats();
            assert_eq!(gs.completed, ops.len() as u64, "{gs:?}");
            assert_eq!(gs.grouped_ops + gs.ungrouped_ops, gs.completed, "{gs:?}");
            // Same-weight narrow runs exist by construction, and the
            // whole stream was admitted before batch formation resumed.
            assert!(gs.grouped_ops > 0, "{gs:?}");
            assert!(gs.groups_formed > 0, "{gs:?}");
            assert!(gs.weight_plane_loads_avoided > 0, "{gs:?}");
            let us = ungrouped.stats();
            assert_eq!(us.grouped_ops, 0, "{us:?}");
            assert_eq!(us.groups_formed, 0, "{us:?}");
            assert_eq!(us.ungrouped_ops, us.completed, "{us:?}");
        }
    }
}

/// Submission order never changes a result: the same op multiset
/// submitted forward, reversed, and weight-interleaved produces
/// bit-identical per-op responses — grouping keys on content digest,
/// not arrival position.
#[test]
fn prop_grouped_results_are_submission_order_invariant() {
    let mut rng = Rng::new(0x0D3A);
    let ops = build_grouped_ops(&mut rng);
    let n = ops.len();
    let forward: Vec<usize> = (0..n).collect();
    let reversed: Vec<usize> = (0..n).rev().collect();
    // Interleave front/back so same-weight triples scatter across the
    // submission stream instead of arriving adjacent.
    let mut interleaved = Vec::with_capacity(n);
    for i in 0..n / 2 {
        interleaved.push(i);
        interleaved.push(n - 1 - i);
    }
    if n % 2 == 1 {
        interleaved.push(n / 2);
    }
    let want: Vec<Mat> = ops
        .iter()
        .map(|op| hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap())
        .collect();
    for order in [&forward, &reversed, &interleaved] {
        let svc = BfpService::new(
            Arc::new(ExecRuntime::with_threads(2)),
            ServiceConfig {
                group_min_ops: 2,
                ..ServiceConfig::default()
            },
        );
        let got = drive(&svc, &ops, order);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_bits_eq(g, w, &format!("order {order:?} op {i}"));
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, n as u64, "{stats:?}");
        assert_eq!(
            stats.grouped_ops + stats.ungrouped_ops,
            stats.completed,
            "{stats:?}"
        );
        assert!(stats.grouped_ops > 0, "{stats:?}");
    }
}
