//! Property tests for the execution runtime: `BatchGemm` must be
//! bit-identical to the per-op scalar reference across thread counts,
//! shard sizes, and batch orderings, on the paper's mantissa grid with
//! ragged contraction dims — and the operand cache must behave as a
//! pure memoization (hits change nothing but speed).

use boosters::analysis::quantize_params_packed_cached;
use boosters::bfp::{hbfp_gemm_scalar, registry, BlockFormat, Mat, PlaneLayout, Quantizer};
use boosters::exec::{BatchGemm, ExecRuntime, OwnedGemmOp};
use boosters::runtime::Tensor;
use boosters::util::Rng;
use std::sync::Arc;

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

/// The m in {3,4,6,8} x {16,64,576} grid with ragged K, 6 cases each:
/// 72 heterogeneous ops (>= the 64 the acceptance gate requires).
fn build_ops(rng: &mut Rng) -> Vec<(Arc<Mat>, Arc<Mat>, BlockFormat)> {
    let mut out = Vec::new();
    for &m in &[3u32, 4, 6, 8] {
        for &b in &[16usize, 64, 576] {
            let fmt = BlockFormat::new(m, b).unwrap();
            for _ in 0..6 {
                // Ragged K: rarely a block multiple, sometimes < b.
                let k = 1 + rng.below(2 * b + 37);
                let r = 1 + rng.below(6);
                let c = 1 + rng.below(7);
                let x = Arc::new(Mat::new(r, k, randn(rng, r * k)).unwrap());
                let w = Arc::new(Mat::new(k, c, randn(rng, k * c)).unwrap());
                out.push((x, w, fmt));
            }
        }
    }
    out
}

fn as_ops(triples: &[(Arc<Mat>, Arc<Mat>, BlockFormat)]) -> Vec<OwnedGemmOp> {
    triples
        .iter()
        .map(|(x, w, fmt)| OwnedGemmOp::new(Arc::clone(x), Arc::clone(w), *fmt).unwrap())
        .collect()
}

fn assert_bits_eq(a: &Mat, b: &Mat, ctx: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{ctx}: shape");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: elem {i}: {x} vs {y}");
    }
}

/// Acceptance gate: >= 64 heterogeneous ops, every result bit-identical
/// to per-op `hbfp_gemm_scalar`.
#[test]
fn prop_batch_gemm_bit_identical_to_scalar_reference() {
    let mut rng = Rng::new(0xBA7C4);
    let triples = build_ops(&mut rng);
    assert!(triples.len() >= 64, "need >= 64 ops, got {}", triples.len());
    let rt = ExecRuntime::with_threads(4);
    let got = BatchGemm::new(&rt).run(&as_ops(&triples)).unwrap();
    assert_eq!(got.len(), triples.len());
    for (i, ((x, w, fmt), out)) in triples.iter().zip(&got).enumerate() {
        let want = hbfp_gemm_scalar(x, w, *fmt).unwrap();
        assert_bits_eq(out, &want, &format!("op {i} (m={} b={})", fmt.mantissa_bits, fmt.block_size));
    }
}

/// Acceptance gate (PR 4, extended PR 6): **every registered kernel
/// backend** — scalar, autovec, and AVX2 / AVX-512-VNNI / NEON where
/// the host supports them — reproduces the scalar reference
/// bit-for-bit on the full m x ragged-K grid (which mixes
/// nibble-packed m <= 4 operands with i8 planes), under a serial pool
/// and a multi-thread pool. SIMD backends the host cannot register are
/// skipped **loudly** (stderr marker greppable in CI logs) so an
/// unsupported runner never reads as silent coverage. (The CI kernel
/// matrix additionally runs the whole suite under each
/// `BOOSTERS_KERNEL` selection.)
#[test]
fn prop_every_registered_kernel_bit_identical_to_scalar() {
    let mut rng = Rng::new(0x4EE1);
    let triples = build_ops(&mut rng);
    // The grid must actually exercise the nibble-packed layout.
    assert!(
        triples
            .iter()
            .any(|(_, _, fmt)| fmt.plane_layout() == PlaneLayout::I4Packed),
        "grid lost its m <= 4 coverage"
    );
    for simd in ["avx2", "avx512-vnni", "neon-sdot"] {
        if registry().by_name(simd).is_none() {
            eprintln!(
                "KERNEL-SKIP: backend {simd:?} not registered on this host \
                 (missing CPU feature or wrong arch); grid runs without it"
            );
        }
    }
    let kernels = registry().all();
    assert!(kernels.len() >= 2, "expected scalar + autovec at minimum");
    for kernel in kernels {
        for threads in [1usize, boosters::util::gemm_thread_budget().clamp(2, 16)] {
            let rt = ExecRuntime::with_threads(threads);
            let got = BatchGemm::new(&rt)
                .with_kernel(*kernel)
                .run(&as_ops(&triples))
                .unwrap();
            for (i, ((x, w, fmt), out)) in triples.iter().zip(&got).enumerate() {
                let want = hbfp_gemm_scalar(x, w, *fmt).unwrap();
                assert_bits_eq(
                    out,
                    &want,
                    &format!(
                        "kernel {} threads {threads} op {i} (m={} b={})",
                        kernel.name(),
                        fmt.mantissa_bits,
                        fmt.block_size
                    ),
                );
            }
        }
    }
}

/// m = 4 operands store nibble-packed through the whole exec path:
/// the cached encode yields half-byte-per-mantissa planes and the
/// operand-cache key carries the layout.
#[test]
fn prop_m4_cached_encodes_are_nibble_packed() {
    let mut rng = Rng::new(0x4B17);
    let rt = ExecRuntime::with_threads(2);
    let data = randn(&mut rng, 4 * 320);
    let fmt4 = BlockFormat::new(4, 64).unwrap();
    let enc = rt.encode_cached(&data, 4, 320, fmt4).unwrap();
    assert_eq!(enc.mantissas.layout(), PlaneLayout::I4Packed);
    assert_eq!(2 * enc.mantissas.resident_bytes(), enc.mantissas.len());
    // Same content under an i8-layout format is a distinct entry.
    let fmt5 = BlockFormat::new(5, 64).unwrap();
    let enc5 = rt.encode_cached(&data, 4, 320, fmt5).unwrap();
    assert_eq!(enc5.mantissas.layout(), PlaneLayout::I8);
    assert_eq!(rt.cache_stats().entries, 2);
    assert_eq!(enc5.mantissas.resident_bytes(), 2 * enc.mantissas.resident_bytes());
}

/// The execution stage's encode report partitions a facade batch
/// exactly: no service pipeline ran, so every op is inline-encoded,
/// results match the scalar reference, and the sync facade never
/// publishes encodes into the ops' shared slots (cache purity).
#[test]
fn prop_facade_batches_report_inline_encode_only() {
    let mut rng = Rng::new(0x1A7E);
    let triples = build_ops(&mut rng);
    let rt = ExecRuntime::with_threads(2);
    let ops = as_ops(&triples);
    let (outs, report) = BatchGemm::new(&rt).run_with_stats(&ops).unwrap();
    assert_eq!(report.pre_encoded, 0, "{report:?}");
    assert_eq!(report.inline_encoded, ops.len(), "{report:?}");
    for (i, ((x, w, fmt), out)) in triples.iter().zip(&outs).enumerate() {
        let want = hbfp_gemm_scalar(x, w, *fmt).unwrap();
        assert_bits_eq(out, &want, &format!("op {i}"));
    }
    assert!(
        ops.iter().all(|op| !op.is_pre_encoded()),
        "the sync facade must not publish encoded slots"
    );
}

/// BOOSTERS_GEMM_THREADS=1 vs the default budget, and a spread of
/// forced shard heights, all produce the same bits. (The CI workflow
/// additionally runs the whole suite under both env settings.)
#[test]
fn prop_batch_gemm_invariant_to_threads_and_shard_size() {
    let mut rng = Rng::new(0x51AB5);
    let triples = build_ops(&mut rng);
    let ops = as_ops(&triples);
    let serial_rt = ExecRuntime::with_threads(1);
    let base = BatchGemm::new(&serial_rt).run(&ops).unwrap();
    let wide_rt = ExecRuntime::with_threads(boosters::util::gemm_thread_budget().clamp(2, 16));
    let default_bands = BatchGemm::new(&wide_rt).run(&ops).unwrap();
    for (i, (a, b)) in base.iter().zip(&default_bands).enumerate() {
        assert_bits_eq(a, b, &format!("threads=1 vs default, op {i}"));
    }
    for band in [1usize, 2, 5, 10_000] {
        let sharded = BatchGemm::new(&wide_rt).band_rows(band).run(&ops).unwrap();
        for (i, (a, b)) in base.iter().zip(&sharded).enumerate() {
            assert_bits_eq(a, b, &format!("band_rows={band}, op {i}"));
        }
    }
}

/// Reordering the batch permutes the outputs and changes nothing else.
#[test]
fn prop_batch_gemm_invariant_to_submission_order() {
    let mut rng = Rng::new(0x0D3);
    let triples = build_ops(&mut rng);
    let rt = ExecRuntime::with_threads(3);
    let forward = BatchGemm::new(&rt).run(&as_ops(&triples)).unwrap();
    // A deterministic shuffle with its inverse mapping.
    let mut perm: Vec<usize> = (0..triples.len()).collect();
    rng.shuffle(&mut perm);
    let shuffled: Vec<OwnedGemmOp> = perm
        .iter()
        .map(|&i| {
            let (x, w, fmt) = &triples[i];
            OwnedGemmOp::new(Arc::clone(x), Arc::clone(w), *fmt).unwrap()
        })
        .collect();
    let permuted = BatchGemm::new(&rt).run(&shuffled).unwrap();
    for (pos, &orig) in perm.iter().enumerate() {
        assert_bits_eq(
            &permuted[pos],
            &forward[orig],
            &format!("permuted pos {pos} = original op {orig}"),
        );
    }
}

/// Cache hits are pure: a batch that reuses weights returns the same
/// bits as a cold cache, and the counters show the reuse.
#[test]
fn prop_weight_cache_reuse_is_bit_pure() {
    let mut rng = Rng::new(0xCAFE);
    let fmt = BlockFormat::new(4, 64).unwrap();
    let w = Arc::new(Mat::new(150, 12, randn(&mut rng, 150 * 12)).unwrap());
    let xs: Vec<Arc<Mat>> = (0..10)
        .map(|_| {
            let m = 1 + rng.below(20);
            Arc::new(Mat::new(m, 150, randn(&mut rng, m * 150)).unwrap())
        })
        .collect();
    let warm_rt = ExecRuntime::with_threads(2);
    let ops: Vec<OwnedGemmOp> = xs
        .iter()
        .map(|x| OwnedGemmOp::new(Arc::clone(x), Arc::clone(&w), fmt).unwrap())
        .collect();
    let first = BatchGemm::new(&warm_rt).run(&ops).unwrap();
    let second = BatchGemm::new(&warm_rt).run(&ops).unwrap();
    let stats = warm_rt.cache_stats();
    assert_eq!(stats.misses, 1, "one weight, one miss: {stats:?}");
    assert_eq!(stats.hits, 19, "{stats:?}");
    let cold = BatchGemm::new(&warm_rt).cache_weights(false).run(&ops).unwrap();
    for i in 0..ops.len() {
        let want = hbfp_gemm_scalar(&xs[i], &w, fmt).unwrap();
        assert_bits_eq(&first[i], &want, &format!("first run op {i}"));
        assert_bits_eq(&second[i], &want, &format!("cached run op {i}"));
        assert_bits_eq(&cold[i], &want, &format!("uncached run op {i}"));
    }
}

/// The acceptance criterion's "Trainer emulation loop": epochs of
/// host-BFP weight-store round-trips where one tensor trains (changes)
/// and one is frozen. The frozen tensor must be served from the operand
/// cache after its first epoch, and every snapped value must equal the
/// scalar quantizer's output.
#[test]
fn trainer_emulation_loop_hits_operand_cache() {
    let mut rng = Rng::new(0x7EA1);
    let rt = ExecRuntime::with_threads(2);
    let frozen_vals = randn(&mut rng, 320);
    let mut live_vals = randn(&mut rng, 256);
    let mut qbuf = Vec::new();
    for epoch in 0..5 {
        // The live tensor drifts every epoch (a training step); the
        // frozen one never does.
        for v in live_vals.iter_mut() {
            *v += 0.01;
        }
        let mut params = vec![
            Tensor::from_f32(&[16, 16], live_vals.clone()).unwrap(),
            Tensor::from_f32(&[320], frozen_vals.clone()).unwrap(),
        ];
        quantize_params_packed_cached(&mut params, 4, 64, &rt, &mut qbuf).unwrap();
        // Trainer writes the snapped literals back.
        live_vals = params[0].as_f32().unwrap().to_vec();
        // Snapped values match the uncached scalar quantizer bit-for-bit.
        let want = boosters::bfp::quantize_packed(&frozen_vals, 64, Quantizer::nearest(4), 0);
        let got = params[1].as_f32().unwrap();
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (*g == 0.0 && *w == 0.0) || g.to_bits() == w.to_bits(),
                "epoch {epoch} elem {i}: {g} vs {w}"
            );
        }
    }
    let stats = rt.cache_stats();
    assert!(
        stats.hits >= 4,
        "frozen tensor must hit the cache after epoch 0: {stats:?}"
    );
    assert!(stats.misses >= 5, "live tensor re-encodes every epoch: {stats:?}");
}
