//! Checkpoints: named f32 tensors in a simple self-describing binary
//! container (JSON header + raw little-endian payload). Used for the
//! Fig 1 / Fig 2 analyses, which quantize *trained* weights offline.
//!
//! For serving, this f32 container is the **interchange** format only:
//! [`crate::registry`] subsumes it as the ingest path
//! (`Registry::import_checkpoint` / `repro registry push`), storing
//! each tensor as a digest-addressed blob of already-encoded planes so
//! warm starts never re-read or re-encode the f32 payload.

use crate::bfp::Mat;
use crate::runtime::Tensor;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"BOOSTCK1";

/// A named set of f32 tensors plus free-form metadata.
#[derive(Debug, Default, Clone)]
pub struct Checkpoint {
    pub names: Vec<String>,
    pub tensors: Vec<Tensor>,
    pub meta: std::collections::BTreeMap<String, String>,
}

impl Checkpoint {
    pub fn new(names: Vec<String>, tensors: Vec<Tensor>) -> Self {
        assert_eq!(names.len(), tensors.len());
        Self {
            names,
            tensors,
            meta: Default::default(),
        }
    }

    pub fn with_meta(mut self, key: &str, value: impl ToString) -> Self {
        self.meta.insert(key.to_string(), value.to_string());
        self
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.names
            .iter()
            .position(|n| n == name)
            .map(|i| &self.tensors[i])
    }

    /// View every tensor as a 2-D weight matrix for encoding: rank >= 2
    /// tensors keep their leading dimension as rows (a `k x n` weight
    /// stays `k x n`), vectors and scalars become one row. This is the
    /// bridge the registry's import path walks.
    pub fn layer_mats(&self) -> Result<Vec<(String, Mat)>> {
        self.names
            .iter()
            .zip(&self.tensors)
            .map(|(name, t)| {
                let data = t
                    .as_f32()
                    .context("checkpoints store f32 tensors only")?
                    .to_vec();
                let rows = if t.shape().len() >= 2 { t.shape()[0] } else { 1 };
                let cols = if rows == 0 { 0 } else { data.len() / rows };
                let mat = Mat::new(rows, cols, data)
                    .with_context(|| format!("tensor {name:?} is not rectangular"))?;
                Ok((name.clone(), mat))
            })
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let header = Json::obj(vec![
            (
                "names",
                Json::Arr(self.names.iter().map(Json::str).collect()),
            ),
            (
                "shapes",
                Json::Arr(
                    self.tensors
                        .iter()
                        .map(|t| {
                            Json::Arr(
                                t.shape().iter().map(|&d| Json::num(d as f64)).collect(),
                            )
                        })
                        .collect(),
                ),
            ),
            ("meta", Json::from_map(&self.meta)),
        ]);
        let hjson = header.render().into_bytes();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for t in &self.tensors {
            let data = t.as_f32().context("checkpoints store f32 tensors only")?;
            for &v in data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(anyhow!("{} is not a booster checkpoint", path.display()));
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let hlen = u64::from_le_bytes(lenb) as usize;
        let mut hjson = vec![0u8; hlen];
        f.read_exact(&mut hjson)?;
        let header = Json::parse(std::str::from_utf8(&hjson)?)?;
        let names: Vec<String> = header
            .req("names")?
            .as_arr()?
            .iter()
            .map(|v| Ok(v.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let shapes: Vec<Vec<usize>> = header
            .req("shapes")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize_vec())
            .collect::<Result<_>>()?;
        let mut meta = std::collections::BTreeMap::new();
        if let Json::Obj(fields) = header.req("meta")? {
            for (k, v) in fields {
                meta.insert(k.clone(), v.as_str()?.to_string());
            }
        }
        let mut tensors = Vec::with_capacity(shapes.len());
        for shape in &shapes {
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push(Tensor::from_f32(shape, data)?);
        }
        Ok(Self {
            names,
            tensors,
            meta,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let ck = Checkpoint::new(
            vec!["a".into(), "b".into()],
            vec![
                Tensor::from_f32(&[2, 3], vec![1., -2., 3.5, 0., 1e-7, -4.]).unwrap(),
                Tensor::from_f32(&[4], vec![9., 8., 7., 6.]).unwrap(),
            ],
        )
        .with_meta("variant", "cnn_bs64")
        .with_meta("val_acc", 0.93);
        let dir = std::env::temp_dir().join("boosters_test_ck");
        let path = dir.join("m.ck");
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.names, ck.names);
        assert_eq!(back.tensors[0], ck.tensors[0]);
        assert_eq!(back.tensors[1], ck.tensors[1]);
        assert_eq!(back.meta.get("variant").unwrap(), "cnn_bs64");
        assert_eq!(back.get("b").unwrap().shape(), &[4]);
        assert!(back.get("zzz").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn layer_mats_bridges_shapes_for_encoding() {
        let ck = Checkpoint::new(
            vec!["w".into(), "b".into()],
            vec![
                Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap(),
                Tensor::from_f32(&[4], vec![9., 8., 7., 6.]).unwrap(),
            ],
        );
        let mats = ck.layer_mats().unwrap();
        assert_eq!(mats[0].0, "w");
        assert_eq!((mats[0].1.rows, mats[0].1.cols), (2, 3));
        assert_eq!(mats[0].1.data, vec![1., 2., 3., 4., 5., 6.]);
        // Vectors become a single row.
        assert_eq!((mats[1].1.rows, mats[1].1.cols), (1, 4));
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("boosters_test_ck2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ck");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
