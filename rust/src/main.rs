//! `repro` — the Accuracy Boosters CLI (L3 leader entrypoint).
//!
//! Everything the paper reports is a subcommand:
//!
//! ```text
//! repro info                      # artifact registry + platform
//! repro smoke                     # one AOT train step end-to-end
//! repro train  --variant cnn_bs64 --policy booster1 --preset quick
//! repro table1 --model cnn --preset quick
//! repro table2 --model cnn
//! repro table3
//! repro fig1 | fig2 | fig4 | fig6
//! repro density
//! ```

use anyhow::{bail, Result};
use boosters::config::PrecisionPolicy;
use boosters::coordinator::TrainerData;
use boosters::experiments::{self, common::config_for, parse_policy, Preset};
use boosters::report::results_dir;
use boosters::runtime::{artifacts_dir, Engine, Index, StepScalars};
use boosters::util::Args;

const USAGE: &str = "\
repro — Accuracy Boosters: epoch-driven mixed-mantissa HBFP DNN training

USAGE: repro <subcommand> [--options]

SUBCOMMANDS
  info                         list artifacts + PJRT platform
  smoke   [--variant V]        one AOT train step end-to-end (sanity)
  train   [--variant V] [--policy P] [--preset quick|full]
          [--epochs N] [--seed S]
  table1  [--model cnn|mlp] [--preset]   standalone HBFP sweep
  table2  [--model cnn|mlp] [--preset]   Accuracy Boosters vs baselines
  table3  [--preset]                     transformer BLEU
  fig1    [--preset]                     Wasserstein distances
  fig2    [--preset]                     loss-landscape slices
  fig4    [--preset] [--seeds N]         seed error bars
  fig6                                   silicon-area ratio sweep
  density                                §4.2 headline density numbers
  ablation [--model] [--preset]          schedule-design ablations
                                         (autoboost / cyclic / inverse)
  serve-sim [--preset] [--requests N]    replay a synthetic mixed-size
            [--batch B] [--weights W]    GEMM request stream through the
            [--verify] [--async]         execution service; --async uses
            [--rps R] [--deadline-ms D]  open-loop BfpService admission
            [--weight-reuse R]           (Poisson arrivals, deadlines,
            [--json PATH] [--fabric N]   miss rate, queue depth) and adds
            [--registry DIR]             per-stage latency-breakdown rows
            [--epochs N]                 (queue wait / encode / gemm /
                                         decode at p50/p95/p99);
                                         --weight-reuse R skews weight
                                         picks Zipf-ishly toward a few
                                         hot weights (0 = uniform), so
                                         weight-stationary grouping has
                                         same-weight runs to batch; --json
                                         (or $REPRO_BENCH_JSON) writes a
                                         BENCH_serve.json artifact;
                                         --fabric N drives the stream
                                         through a router over N local
                                         runner processes (killing one
                                         mid-run to prove failover) and
                                         writes BENCH_fabric.json instead;
                                         --registry DIR pushes --epochs
                                         synthetic epochs into an
                                         encoded-weight registry, then
                                         benchmarks cold (fresh encode)
                                         vs warm (mmap-load, zero-encode)
                                         start and writes
                                         BENCH_registry.json
  registry push  --dir DIR [--name N]    content-addressed encoded-weight
            [--checkpoint PATH.ck]       registry: push encodes layers
            [--mantissa M] [--block B]   (from a checkpoint, or a synthetic
            [--weights W] [--seed S]     working set) into digest-keyed
  registry pull  --dir DIR [--name N]    blobs under a JSON manifest —
  registry ls    --dir DIR               identical blobs dedup by
  registry gc    --dir DIR               construction; pull loads + bit-
            [--keep-last N]              verifies; ls lists manifests;
                                         gc removes unreachable blobs;
                                         --keep-last N first retires all
                                         but the N newest manifests, then
                                         sweeps blobs nothing references
  fabric-runner [--listen HOST:PORT]     host the execution service on a
                [--registry DIR]         TCP socket for fabric routers
                                         (default $BOOSTERS_FABRIC_LISTEN
                                         or 127.0.0.1:0; the bound
                                         address is printed on stdout);
                                         --registry warm-starts the
                                         operand store from a local
                                         registry (zero encodes, zero
                                         wire transfers for covered
                                         weights)
  metrics [--connect HOST:PORT]          Prometheus text exposition of
                                         the exec counters — local
                                         process by default, a remote
                                         runner's with --connect

POLICIES: fp32 | hbfpN | hbfpN+layersM | booster[K] | cyclicMIN-MAX
Artifacts dir: --artifacts PATH (default ./artifacts or $REPRO_ARTIFACTS)
Env knobs: BOOSTERS_KERNEL=auto|scalar|autovec|avx2|avx512|neon (GEMM backend),
  BOOSTERS_AUTOTUNE=PATH (shape-dispatch table, see bench --autotune),
  BOOSTERS_PREENCODE_MB=N (resident pre-encoded activation-plane cap),
  BOOSTERS_ARENA_MB=N (recycled output/accumulator buffer-arena cap),
  BOOSTERS_GROUP_MIN_OPS=N (same-weight ops per batch before they run as
  one weight-stationary grouped GEMM; 0 disables grouping; default 2),
  BOOSTERS_GEMM_THREADS=N, BOOSTERS_CACHE_ENTRIES=N, BOOSTERS_CACHE_MB=N,
  BOOSTERS_FABRIC_RUNNERS=N (serve-sim --fabric fleet size),
  BOOSTERS_FABRIC_MAC_BUDGET=N (per-runner outstanding-MAC admission cap),
  BOOSTERS_FABRIC_STORE_MB=N (runner operand-store LRU cap, MiB),
  BOOSTERS_FABRIC_LISTEN=HOST:PORT (fabric-runner default bind),
  BOOSTERS_FABRIC_CONNECT=H:P,H:P (attach to an existing fleet instead
  of spawning one)
All BOOSTERS_* settings are validated at startup; every malformed value
is reported (to stderr, exit code 2) in one pass.";

fn main() -> Result<()> {
    // Validate every BOOSTERS_* knob up front and report *all* bad
    // settings at once — a typo'd cap should not surface as a silent
    // fallback to the default deep inside the execution runtime.
    let env_issues = boosters::util::validate_env();
    if !env_issues.is_empty() {
        for issue in &env_issues {
            eprintln!("error: {issue}");
        }
        eprintln!(
            "{} invalid BOOSTERS_* environment setting(s); see `repro help` for accepted values",
            env_issues.len()
        );
        std::process::exit(2);
    }
    let args = Args::from_env()?;
    let artifacts = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(artifacts_dir);
    let preset = || Preset::parse(&args.get_or("preset", "quick"));

    match args.subcommand.as_deref() {
        None | Some("help") => println!("{USAGE}"),
        Some("info") => {
            let engine = Engine::new()?;
            println!("platform: {}", engine.platform());
            let index = Index::load(&artifacts)?;
            println!("artifacts ({}):", index.variants.len());
            for v in &index.variants {
                let m = engine
                    .load_variant_by_name(&artifacts, &v.name)
                    .map(|mv| mv.manifest.total_weights())
                    .unwrap_or(0);
                println!(
                    "  {:24} model={:12} block={:4} pallas={} params={}",
                    v.name, v.model, v.block, v.pallas, m
                );
            }
        }
        Some("smoke") => {
            let variant = args.get_or("variant", "mlp_bs64");
            let engine = Engine::new()?;
            println!("platform: {}", engine.platform());
            let v = engine.load_variant_by_name(&artifacts, &variant)?;
            let cfg = config_for(&v, PrecisionPolicy::booster(1), Preset::Quick);
            let data = TrainerData::for_variant(&v, &cfg)?;
            let mut state = boosters::coordinator::init_state(&v.manifest, 42)?;
            let idx: Vec<usize> = (0..v.manifest.batch).collect();
            let (x, y) = data.batch(&idx, false);
            let sc = StepScalars::hbfp(4.0);
            let s = engine.train_step(&v, &mut state, &x, &y, sc, 0.05)?;
            println!("train_step: loss={:.4} metric={:.4}", s.loss, s.metric);
            let e = engine.eval_batch(&v, &state, &x, &y, sc)?;
            println!("eval:       loss={:.4} metric={:.4}", e.loss, e.metric);
            println!("smoke OK ({})", v.manifest.variant);
        }
        Some("train") => {
            let variant = args.get_or("variant", "cnn_bs64");
            let engine = Engine::new()?;
            let v = engine.load_variant_by_name(&artifacts, &variant)?;
            let pol = parse_policy(&args.get_or("policy", "booster1"))?;
            let mut cfg = config_for(&v, pol.clone(), preset()?);
            if let Some(e) = args.get_parse::<usize>("epochs")? {
                cfg.epochs = e;
            }
            if let Some(s) = args.get_parse::<u64>("seed")? {
                cfg.seed = s;
            }
            let data = TrainerData::for_variant(&v, &cfg)?;
            let (acc, hist, result) =
                experiments::common::run_one(&engine, &v, &data, cfg, true)?;
            println!(
                "final val metric: {acc:.4} (best {:.4})",
                hist.best_val_acc()
            );
            let stem = format!(
                "train_{}_{}",
                variant,
                pol.label().replace(['+', '(', ')'], "_")
            );
            hist.write_csv(&results_dir().join(format!("{stem}.csv")))?;
            let names: Vec<String> = v.manifest.params.iter().map(|p| p.name.clone()).collect();
            boosters::checkpoint::Checkpoint::new(names, result.params)
                .with_meta("variant", &variant)
                .with_meta("policy", pol.label())
                .with_meta("val_acc", acc)
                .save(&results_dir().join(format!("{stem}.ck")))?;
            println!("wrote results/{stem}.csv and .ck");
        }
        Some("table1") => {
            let engine = Engine::new()?;
            experiments::table1::run(&engine, &artifacts, &args.get_or("model", "cnn"), preset()?)?
                .print();
        }
        Some("table2") => {
            let engine = Engine::new()?;
            experiments::table2::run(&engine, &artifacts, &args.get_or("model", "cnn"), preset()?)?
                .table
                .print();
        }
        Some("table3") => {
            let engine = Engine::new()?;
            experiments::table3::run(&engine, &artifacts, preset()?)?.print();
        }
        Some("fig1") => {
            let engine = Engine::new()?;
            experiments::figs::fig1(&engine, &artifacts, preset()?)?.print();
        }
        Some("fig2") => {
            let engine = Engine::new()?;
            experiments::figs::fig2(&engine, &artifacts, preset()?)?.print();
        }
        Some("fig4") => {
            let engine = Engine::new()?;
            let seeds = args.get_parse_or::<usize>("seeds", 5)?;
            experiments::figs::fig4(&engine, &artifacts, preset()?, seeds)?.print();
        }
        Some("ablation") => {
            let engine = Engine::new()?;
            experiments::ablation::run(&engine, &artifacts, &args.get_or("model", "cnn"), preset()?)?
                .print();
        }
        Some("serve-sim") => {
            // Pure host-side: no engine or artifacts needed.
            let mut cfg = match preset()? {
                Preset::Quick => experiments::serve_sim::ServeSimConfig::quick(),
                Preset::Full => experiments::serve_sim::ServeSimConfig::full(),
            };
            if let Some(n) = args.get_parse::<usize>("requests")? {
                cfg.requests = n;
            }
            if let Some(b) = args.get_parse::<usize>("batch")? {
                cfg.batch = b;
            }
            if let Some(w) = args.get_parse::<usize>("weights")? {
                cfg.weights = w;
            }
            if args.has_flag("verify") {
                cfg.verify = true;
            }
            if args.has_flag("async") {
                cfg.mode = experiments::serve_sim::ServeMode::Async;
            }
            if let Some(r) = args.get_parse::<f64>("rps")? {
                cfg.offered_rps = r;
            }
            if let Some(d) = args.get_parse::<f64>("deadline-ms")? {
                cfg.deadline_ms = Some(d);
            }
            if let Some(r) = args.get_parse::<f64>("weight-reuse")? {
                anyhow::ensure!(
                    r >= 0.0 && r.is_finite(),
                    "--weight-reuse must be a finite non-negative number, got {r}"
                );
                cfg.weight_reuse = r;
            }
            cfg.json = args
                .get("json")
                .map(std::path::PathBuf::from)
                .or_else(|| std::env::var_os("REPRO_BENCH_JSON").map(std::path::PathBuf::from));
            if let Some(dir) = args.get("registry") {
                let epochs = args.get_parse_or::<usize>("epochs", 3)?;
                let report = experiments::serve_sim::run_registry(
                    &boosters::exec::global_arc(),
                    &cfg,
                    std::path::Path::new(dir),
                    epochs,
                )?;
                report.table.print();
            } else if args.has_flag("fabric") || args.get("fabric").is_some() {
                let runners = args
                    .get_parse::<usize>("fabric")?
                    .unwrap_or_else(boosters::util::fabric_runners);
                let connect = boosters::util::fabric_connect();
                let report = experiments::serve_sim::run_fabric(
                    &boosters::exec::global_arc(),
                    &cfg,
                    runners,
                    &connect,
                )?;
                report.table.print();
            } else {
                let report = experiments::serve_sim::run(&boosters::exec::global_arc(), &cfg)?;
                report.table.print();
            }
        }
        Some("fabric-runner") => {
            let listen = args
                .get("listen")
                .map(str::to_string)
                .or_else(boosters::util::fabric_listen)
                .unwrap_or_else(|| "127.0.0.1:0".to_string());
            let registry = args.get("registry").map(std::path::PathBuf::from);
            boosters::fabric::serve(&listen, registry.as_deref())?;
        }
        Some("registry") => registry_cli(&args)?,
        Some("metrics") => {
            let text = match args.get("connect") {
                Some(addr) => boosters::fabric::fetch_metrics(addr)?,
                None => boosters::metrics::render_text(
                    &boosters::metrics::exec_service_snapshot(),
                    &boosters::metrics::exec_cache_snapshot(),
                    &boosters::metrics::exec_arena_snapshot(),
                    &[],
                ),
            };
            print!("{text}");
        }
        Some("fig6") => experiments::figs::fig6()?.print(),
        Some("density") => experiments::figs::density()?.print(),
        Some(other) => bail!("unknown subcommand {other:?}\n\n{USAGE}"),
    }
    Ok(())
}

/// `repro registry {push,pull,ls,gc}` — operate a content-addressed
/// encoded-weight registry on disk. Pure host-side; no engine needed.
fn registry_cli(args: &Args) -> Result<()> {
    use boosters::bfp::{BlockFormat, Mat};
    use boosters::registry::{PushLayer, Registry};

    let dir = args.get_or("dir", "results/registry");
    let reg = Registry::open(std::path::Path::new(&dir))?;
    match args.verb.as_deref() {
        Some("push") => {
            let name = args.get_or("name", "latest");
            let m = args.get_parse_or::<u32>("mantissa", 4)?;
            let b = args.get_parse_or::<usize>("block", 64)?;
            let fmt = BlockFormat::new(m, b)?;
            let (manifest, stats) = if let Some(ck) = args.get("checkpoint") {
                let ck = boosters::checkpoint::Checkpoint::load(std::path::Path::new(ck))?;
                reg.import_checkpoint(&ck, &name, |_| fmt)?
            } else {
                // No checkpoint: push a deterministic synthetic working
                // set (the serve-sim shapes) — enough to exercise dedup
                // and warm starts without a trained model on hand.
                let weights = args.get_parse_or::<usize>("weights", 6)?;
                let seed = args.get_parse_or::<u64>("seed", 42)?;
                let shapes = [(64usize, 48usize), (128, 96), (192, 64), (256, 128)];
                let mut rng = boosters::util::Rng::new(seed);
                let mats: Vec<(String, Mat)> = (0..weights.max(1))
                    .map(|i| {
                        let (k, n) = shapes[i % shapes.len()];
                        let data = (0..k * n).map(|_| rng.normal_scaled(1.0)).collect();
                        Mat::new(k, n, data).map(|m| (format!("layer{i:02}"), m))
                    })
                    .collect::<Result<_>>()?;
                let layers: Vec<PushLayer<'_>> = mats
                    .iter()
                    .map(|(name, w)| PushLayer {
                        name,
                        weight: w,
                        fmt,
                    })
                    .collect();
                reg.push(&name, &layers, &Default::default())?
            };
            println!(
                "pushed manifest {:?}: {} layer(s), {} blob(s) written ({} B), \
                 {} deduped ({} B avoided)",
                manifest.name,
                stats.layers,
                stats.blobs_written,
                stats.bytes_written,
                stats.blobs_deduped,
                stats.bytes_deduped
            );
        }
        Some("pull") => {
            let name = args.get_or("name", "latest");
            let layers = reg.pull(&name)?;
            println!("manifest {name:?}: {} layer(s)", layers.len());
            for (entry, planes) in &layers {
                // `pull` validated header, checksum, and digest on load.
                let label = entry.layout.label();
                println!(
                    "  {:16} {} m{}b{} {} {}x{} (encoded {}x{})",
                    entry.name,
                    entry.digest.to_hex(),
                    entry.fmt.mantissa_bits,
                    entry.fmt.block_size,
                    label,
                    entry.rows,
                    entry.cols,
                    planes.rows,
                    planes.cols
                );
            }
        }
        Some("ls") => {
            let names = reg.manifest_names()?;
            let (blobs, bytes) = reg.blob_stats()?;
            println!(
                "{} manifest(s), {} blob(s), {} blob byte(s) at {}",
                names.len(),
                blobs,
                bytes,
                reg.root().display()
            );
            for name in names {
                let m = reg.manifest(&name)?;
                let total: u64 = m.layers.iter().map(|l| l.blob_bytes).sum();
                println!("  {:24} {} layer(s), {} blob B", m.name, m.layers.len(), total);
            }
        }
        Some("gc") => {
            let s = match args.get_parse::<usize>("keep-last")? {
                Some(n) => reg.gc_keep_last(n)?,
                None => reg.gc()?,
            };
            println!(
                "gc: retired {} manifest(s), kept {} blob(s), removed {} ({} B reclaimed)",
                s.manifests_removed, s.blobs_kept, s.blobs_removed, s.bytes_removed
            );
        }
        other => bail!(
            "registry needs a verb: push | pull | ls | gc (got {other:?})\n\n{USAGE}"
        ),
    }
    Ok(())
}
