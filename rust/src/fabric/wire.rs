//! Versioned wire format of the execution fabric.
//!
//! Every message is one length-prefixed **frame**:
//!
//! ```text
//! magic   "BFAB"            4 bytes
//! version u16 LE            (currently 1)
//! kind    u8                (see [`Frame`])
//! flags   u8                (reserved, must be 0)
//! len     u32 LE            payload length in bytes
//! payload len bytes
//! ```
//!
//! Everything inside a payload is **little-endian** and
//! value-defined: f32s travel as `to_bits()` words, so a result decoded
//! on any host is bit-identical to the runner's buffer — the same
//! bit-identity contract the kernels keep. Payloads are bounded by
//! [`MAX_PAYLOAD`]; a reader rejects oversized, truncated, or
//! trailing-garbage payloads with a typed error instead of reading
//! junk.
//!
//! Weight operands never travel as raw f32. They are referenced by
//! [`OperandKey`] — the shared 128-bit content [`Digest`] plus the
//! block format — and their bytes move (at most once per runner) as
//! **encoded BFP planes** in a [`PutOperandFrame`]: one mantissa plane
//! in the format's storage layout (nibble-packed 4-bit, i8, or i16)
//! plus the per-block `i32` exponent plane. That is the paper's density
//! argument applied to the network: a 4-bit weight plane crosses the
//! wire at ~4.5 bits/value instead of 32.

use crate::bfp::{BfpMatrix, BlockFormat, MantissaPlane, PlaneLayout};
use crate::exec::queue::Priority;
use crate::util::digest::Digest;
use anyhow::{anyhow, bail, Context, Result};
use std::io::{Read, Write};

/// Frame preamble: magic bytes every frame starts with.
pub const MAGIC: [u8; 4] = *b"BFAB";
/// Protocol version. Bump on any incompatible payload change; a reader
/// rejects frames from another version loudly (mixed fleets must fail
/// fast, not misdecode).
pub const VERSION: u16 = 1;
/// Upper bound on one frame's payload. Large enough for any serve-sim
/// operand, small enough that a corrupt length prefix cannot OOM the
/// peer.
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Reject codes 1–3 map [`crate::exec::AdmissionError`] via its
/// `wire_code`; these two extend the space with fabric-level outcomes.
/// The runner does not hold the referenced weight operand; the detail
/// is the digest hex. The router re-sends the planes and resubmits.
pub const REJECT_NEED_OPERAND: u8 = 4;
/// Execution failed on the runner; the detail is the error chain.
pub const REJECT_EXEC_FAILED: u8 = 5;

/// Identity of one encoded weight operand in a runner's store: content
/// digest + block format (the layout is a function of the format, and
/// fabric weights are always column/transposed-encoded).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OperandKey {
    pub digest: Digest,
    pub m_bits: u32,
    pub block: u32,
}

impl OperandKey {
    pub fn new(digest: Digest, fmt: BlockFormat) -> Self {
        Self {
            digest,
            m_bits: fmt.mantissa_bits,
            block: fmt.block_size as u32,
        }
    }
}

/// One GEMM submission: op metadata, the activation inline as raw f32
/// (fresh per request — no dedup value), the weight by reference.
#[derive(Debug, Clone, PartialEq)]
pub struct SubmitFrame {
    /// Router-assigned correlation id, echoed on the result/reject.
    pub id: u64,
    pub priority: Priority,
    /// Deadline budget remaining at transmission, milliseconds.
    pub deadline_ms: Option<u64>,
    pub fmt: BlockFormat,
    pub x_rows: u32,
    pub x_cols: u32,
    /// Row-major activation values (bit-exact via `to_bits`).
    pub x_data: Vec<f32>,
    pub w_rows: u32,
    pub w_cols: u32,
    /// Content digest of the weight operand; the runner resolves it in
    /// its operand store (or rejects with [`REJECT_NEED_OPERAND`]).
    pub w_digest: Digest,
}

/// One completed GEMM streaming back: the output plus the runner-side
/// per-stage latency breakdown.
#[derive(Debug, Clone, PartialEq)]
pub struct ResultFrame {
    pub id: u64,
    pub rows: u32,
    pub cols: u32,
    pub data: Vec<f32>,
    pub queue_ms: f64,
    pub total_ms: f64,
    pub deadline_missed: bool,
    pub encode_ms: f64,
    pub gemm_ms: f64,
    pub decode_ms: f64,
}

/// Typed failure for one submission: admission backpressure
/// (codes 1–3, see [`crate::exec::AdmissionError::from_wire`]),
/// [`REJECT_NEED_OPERAND`], or [`REJECT_EXEC_FAILED`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RejectFrame {
    pub id: u64,
    pub code: u8,
    pub detail: String,
}

/// Encoded weight planes for one operand — sent only after the runner
/// reported a miss for the key (the dedup contract).
#[derive(Debug, Clone, PartialEq)]
pub struct PutOperandFrame {
    pub key: OperandKey,
    /// Column/transposed-encoded (always true today; carried so the
    /// orientation is explicit on the wire).
    pub transposed: bool,
    pub planes: BfpMatrix,
}

/// "Do you hold this operand?" — the digest-first half of the dedup
/// negotiation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeFrame {
    pub key: OperandKey,
}

/// Answer to a [`ProbeFrame`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeReplyFrame {
    pub key: OperandKey,
    pub present: bool,
}

/// Every message the fabric speaks. See the module docs for the frame
/// envelope; kinds are frozen (append, never renumber).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    Submit(SubmitFrame),
    Result(ResultFrame),
    Reject(RejectFrame),
    PutOperand(PutOperandFrame),
    Probe(ProbeFrame),
    ProbeReply(ProbeReplyFrame),
    /// Ask the peer for a metrics snapshot.
    MetricsRequest,
    /// Prometheus-style text exposition (see [`crate::metrics::render_text`]).
    MetricsText(String),
}

impl Frame {
    fn kind(&self) -> u8 {
        match self {
            Frame::Submit(_) => 1,
            Frame::Result(_) => 2,
            Frame::Reject(_) => 3,
            Frame::PutOperand(_) => 4,
            Frame::Probe(_) => 5,
            Frame::ProbeReply(_) => 6,
            Frame::MetricsRequest => 7,
            Frame::MetricsText(_) => 8,
        }
    }
}

// ---------------------------------------------------------------------
// Payload primitives
// ---------------------------------------------------------------------

#[derive(Default)]
struct PayloadWriter {
    buf: Vec<u8>,
}

impl PayloadWriter {
    fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }
    fn digest(&mut self, d: Digest) {
        self.buf.extend_from_slice(&d.to_le_bytes());
    }
    fn bytes(&mut self, b: &[u8]) {
        self.u32(b.len() as u32);
        self.buf.extend_from_slice(b);
    }
    fn string(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }
    fn f32s(&mut self, xs: &[f32]) {
        self.u32(xs.len() as u32);
        self.buf.reserve(xs.len() * 4);
        for x in xs {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }
    fn i32s(&mut self, xs: &[i32]) {
        self.u32(xs.len() as u32);
        self.buf.reserve(xs.len() * 4);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
    fn i16s(&mut self, xs: &[i16]) {
        self.u32(xs.len() as u32);
        self.buf.reserve(xs.len() * 2);
        for x in xs {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
    }
}

struct PayloadReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> PayloadReader<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow!(
                    "truncated payload: need {n} bytes at offset {}, have {}",
                    self.pos,
                    self.buf.len() - self.pos
                )
            })?;
        let slice = &self.buf[self.pos..end];
        self.pos = end;
        Ok(slice)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn digest(&mut self) -> Result<Digest> {
        Ok(Digest::from_le_bytes(
            self.take(Digest::WIRE_BYTES)?.try_into().unwrap(),
        ))
    }
    fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.u32()? as usize;
        self.take(n)
    }
    fn string(&mut self) -> Result<String> {
        let b = self.bytes()?;
        String::from_utf8(b.to_vec()).context("payload string is not UTF-8")
    }
    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("f32 run overflows"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }
    fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow!("i32 run overflows"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
    fn i16s(&mut self) -> Result<Vec<i16>> {
        let n = self.u32()? as usize;
        let raw = self.take(n.checked_mul(2).ok_or_else(|| anyhow!("i16 run overflows"))?)?;
        Ok(raw
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// The decode contract: every payload byte must be consumed.
    fn finish(self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "payload has {} trailing bytes after a complete frame",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Field encodings
// ---------------------------------------------------------------------

fn put_key(w: &mut PayloadWriter, key: &OperandKey) {
    w.digest(key.digest);
    w.u32(key.m_bits);
    w.u32(key.block);
}

fn take_key(r: &mut PayloadReader) -> Result<OperandKey> {
    Ok(OperandKey {
        digest: r.digest()?,
        m_bits: r.u32()?,
        block: r.u32()?,
    })
}

fn priority_byte(p: Priority) -> u8 {
    match p {
        Priority::Interactive => 0,
        Priority::Bulk => 1,
    }
}

fn priority_from(b: u8) -> Result<Priority> {
    match b {
        0 => Ok(Priority::Interactive),
        1 => Ok(Priority::Bulk),
        other => bail!("unknown priority byte {other}"),
    }
}

fn layout_byte(l: PlaneLayout) -> u8 {
    match l {
        PlaneLayout::I4Packed => 1,
        PlaneLayout::I8 => 2,
        PlaneLayout::I16 => 3,
    }
}

fn put_bfp(w: &mut PayloadWriter, m: &BfpMatrix) {
    w.u32(m.fmt.mantissa_bits);
    w.u32(m.fmt.block_size as u32);
    w.u32(m.rows as u32);
    w.u32(m.cols as u32);
    w.u32(m.blocks_per_row as u32);
    w.u8(layout_byte(m.mantissas.layout()));
    match &m.mantissas {
        MantissaPlane::I4Packed(v) => w.bytes(v),
        MantissaPlane::I8(v) => {
            // i8 planes ship as their two's-complement bytes.
            w.u32(v.len() as u32);
            w.buf.extend(v.iter().map(|&b| b as u8));
        }
        MantissaPlane::I16(v) => w.i16s(v),
    }
    w.i32s(&m.exponents);
}

/// Decode and **validate** one encoded matrix: the format must be
/// constructible, the layout must be the one that format produces, and
/// every plane length must be consistent with the shape — a corrupt
/// frame is rejected here, never handed to a kernel.
fn take_bfp(r: &mut PayloadReader) -> Result<BfpMatrix> {
    let m_bits = r.u32()?;
    let block = r.u32()? as usize;
    let fmt = BlockFormat::new(m_bits, block).context("wire matrix block format")?;
    let rows = r.u32()? as usize;
    let cols = r.u32()? as usize;
    let blocks_per_row = r.u32()? as usize;
    if blocks_per_row != cols.div_ceil(block) {
        bail!(
            "wire matrix blocks_per_row {blocks_per_row} inconsistent with {cols} cols of block {block}"
        );
    }
    let layout = r.u8()?;
    let expect = fmt.plane_layout();
    if layout != layout_byte(expect) {
        bail!(
            "wire matrix layout byte {layout} does not match format layout {}",
            expect.label()
        );
    }
    let logical = rows
        .checked_mul(blocks_per_row)
        .and_then(|b| b.checked_mul(block))
        .ok_or_else(|| anyhow!("wire matrix plane size overflows"))?;
    let mantissas = match expect {
        PlaneLayout::I4Packed => {
            let v = r.bytes()?.to_vec();
            if v.len() * 2 != logical {
                bail!("i4 plane holds {} values, shape needs {logical}", v.len() * 2);
            }
            MantissaPlane::I4Packed(v)
        }
        PlaneLayout::I8 => {
            let v: Vec<i8> = r.bytes()?.iter().map(|&b| b as i8).collect();
            if v.len() != logical {
                bail!("i8 plane holds {} values, shape needs {logical}", v.len());
            }
            MantissaPlane::I8(v)
        }
        PlaneLayout::I16 => {
            let v = r.i16s()?;
            if v.len() != logical {
                bail!("i16 plane holds {} values, shape needs {logical}", v.len());
            }
            MantissaPlane::I16(v)
        }
    };
    let exponents = r.i32s()?;
    if exponents.len() != rows * blocks_per_row {
        bail!(
            "exponent plane holds {} blocks, shape needs {}",
            exponents.len(),
            rows * blocks_per_row
        );
    }
    Ok(BfpMatrix {
        fmt,
        rows,
        cols,
        blocks_per_row,
        mantissas,
        exponents,
    })
}

// ---------------------------------------------------------------------
// Frame encode / decode
// ---------------------------------------------------------------------

impl Frame {
    /// Serialize the whole frame (envelope + payload).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = PayloadWriter::default();
        match self {
            Frame::Submit(s) => {
                w.u64(s.id);
                w.u8(priority_byte(s.priority));
                w.u64(s.deadline_ms.unwrap_or(u64::MAX));
                w.u32(s.fmt.mantissa_bits);
                w.u32(s.fmt.block_size as u32);
                w.u32(s.x_rows);
                w.u32(s.x_cols);
                w.f32s(&s.x_data);
                w.u32(s.w_rows);
                w.u32(s.w_cols);
                w.digest(s.w_digest);
            }
            Frame::Result(res) => {
                w.u64(res.id);
                w.u32(res.rows);
                w.u32(res.cols);
                w.f32s(&res.data);
                w.f64(res.queue_ms);
                w.f64(res.total_ms);
                w.u8(res.deadline_missed as u8);
                w.f64(res.encode_ms);
                w.f64(res.gemm_ms);
                w.f64(res.decode_ms);
            }
            Frame::Reject(rej) => {
                w.u64(rej.id);
                w.u8(rej.code);
                w.string(&rej.detail);
            }
            Frame::PutOperand(put) => {
                put_key(&mut w, &put.key);
                w.u8(put.transposed as u8);
                put_bfp(&mut w, &put.planes);
            }
            Frame::Probe(p) => put_key(&mut w, &p.key),
            Frame::ProbeReply(p) => {
                put_key(&mut w, &p.key);
                w.u8(p.present as u8);
            }
            Frame::MetricsRequest => {}
            Frame::MetricsText(text) => w.string(text),
        }
        let payload = w.buf;
        let mut out = Vec::with_capacity(12 + payload.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(self.kind());
        out.push(0); // reserved flags
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        out
    }

    fn decode(kind: u8, payload: &[u8]) -> Result<Frame> {
        let mut r = PayloadReader::new(payload);
        let frame = match kind {
            1 => {
                let id = r.u64()?;
                let priority = priority_from(r.u8()?)?;
                let deadline_raw = r.u64()?;
                let fmt = BlockFormat::new(r.u32()?, r.u32()? as usize)
                    .context("submit frame block format")?;
                let x_rows = r.u32()?;
                let x_cols = r.u32()?;
                let x_data = r.f32s()?;
                if (x_rows as u64) * (x_cols as u64) != x_data.len() as u64 {
                    bail!(
                        "submit activation {}x{} != {} values",
                        x_rows,
                        x_cols,
                        x_data.len()
                    );
                }
                Frame::Submit(SubmitFrame {
                    id,
                    priority,
                    deadline_ms: (deadline_raw != u64::MAX).then_some(deadline_raw),
                    fmt,
                    x_rows,
                    x_cols,
                    x_data,
                    w_rows: r.u32()?,
                    w_cols: r.u32()?,
                    w_digest: r.digest()?,
                })
            }
            2 => {
                let id = r.u64()?;
                let rows = r.u32()?;
                let cols = r.u32()?;
                let data = r.f32s()?;
                if (rows as u64) * (cols as u64) != data.len() as u64 {
                    bail!("result {}x{} != {} values", rows, cols, data.len());
                }
                Frame::Result(ResultFrame {
                    id,
                    rows,
                    cols,
                    data,
                    queue_ms: r.f64()?,
                    total_ms: r.f64()?,
                    deadline_missed: r.u8()? != 0,
                    encode_ms: r.f64()?,
                    gemm_ms: r.f64()?,
                    decode_ms: r.f64()?,
                })
            }
            3 => Frame::Reject(RejectFrame {
                id: r.u64()?,
                code: r.u8()?,
                detail: r.string()?,
            }),
            4 => {
                let key = take_key(&mut r)?;
                let transposed = r.u8()? != 0;
                let planes = take_bfp(&mut r)?;
                if planes.fmt.mantissa_bits != key.m_bits
                    || planes.fmt.block_size != key.block as usize
                {
                    bail!("operand planes' format disagrees with their key");
                }
                Frame::PutOperand(PutOperandFrame {
                    key,
                    transposed,
                    planes,
                })
            }
            5 => Frame::Probe(ProbeFrame {
                key: take_key(&mut r)?,
            }),
            6 => Frame::ProbeReply(ProbeReplyFrame {
                key: take_key(&mut r)?,
                present: r.u8()? != 0,
            }),
            7 => Frame::MetricsRequest,
            8 => Frame::MetricsText(r.string()?),
            other => bail!("unknown frame kind {other}"),
        };
        r.finish()?;
        Ok(frame)
    }

    /// Write one frame to `w` (single `write_all` — frames are the
    /// atomic unit interleaving writers must respect).
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        w.write_all(&self.encode()).context("writing fabric frame")?;
        w.flush().context("flushing fabric frame")
    }

    /// Read one frame from `r`. `Ok(None)` on clean EOF **at a frame
    /// boundary** (the peer closed between frames); anything else —
    /// mid-frame EOF, bad magic, wrong version, unknown kind, oversized
    /// or malformed payload — is an error.
    pub fn read_from(r: &mut impl Read) -> Result<Option<Frame>> {
        let mut header = [0u8; 12];
        // Distinguish clean EOF (no bytes at all) from truncation.
        let mut got = 0usize;
        while got < header.len() {
            match r.read(&mut header[got..]) {
                Ok(0) if got == 0 => return Ok(None),
                Ok(0) => bail!("connection closed mid-frame ({got}/12 header bytes)"),
                Ok(n) => got += n,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e).context("reading fabric frame header"),
            }
        }
        if header[..4] != MAGIC {
            bail!("bad frame magic {:02x?}", &header[..4]);
        }
        let version = u16::from_le_bytes(header[4..6].try_into().unwrap());
        if version != VERSION {
            bail!("fabric protocol version {version} (this peer speaks {VERSION})");
        }
        let kind = header[6];
        if header[7] != 0 {
            bail!("nonzero reserved flags byte {:#x}", header[7]);
        }
        let len = u32::from_le_bytes(header[8..12].try_into().unwrap()) as usize;
        if len > MAX_PAYLOAD {
            bail!("frame payload of {len} bytes exceeds the {MAX_PAYLOAD}-byte cap");
        }
        let mut payload = vec![0u8; len];
        r.read_exact(&mut payload).context("reading fabric frame payload")?;
        Frame::decode(kind, &payload).map(Some)
    }
}

/// Resident bytes of one encoded operand's planes as the wire and the
/// dedup counters account them: mantissa plane bytes + `i32` exponent
/// plane bytes (the same arithmetic as the operand cache's byte cap).
pub fn plane_wire_bytes(m: &BfpMatrix) -> u64 {
    (m.mantissas.resident_bytes() + m.exponents.len() * std::mem::size_of::<i32>()) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::{Mat, Quantizer};
    use crate::util::digest::content_fingerprint;

    fn encode_w(data: &[f32], rows: usize, cols: usize, fmt: BlockFormat) -> BfpMatrix {
        let w = Mat::new(rows, cols, data.to_vec()).unwrap();
        BfpMatrix::encode_transposed(&w, fmt, Quantizer::nearest(fmt.mantissa_bits)).unwrap()
    }

    fn roundtrip(f: Frame) -> Frame {
        let bytes = f.encode();
        let mut cur = std::io::Cursor::new(bytes);
        let back = Frame::read_from(&mut cur).unwrap().unwrap();
        // The reader consumed the whole stream: a second read is clean EOF.
        assert!(Frame::read_from(&mut cur).unwrap().is_none());
        back
    }

    #[test]
    fn submit_result_reject_roundtrip() {
        let submit = Frame::Submit(SubmitFrame {
            id: 42,
            priority: Priority::Interactive,
            deadline_ms: Some(25),
            fmt: BlockFormat::new(4, 16).unwrap(),
            x_rows: 2,
            x_cols: 3,
            x_data: vec![1.0, -2.5, 0.0, f32::MIN_POSITIVE, 7.25, -0.125],
            w_rows: 3,
            w_cols: 4,
            w_digest: content_fingerprint(&[1.0, 2.0], 1, 2),
        });
        assert_eq!(roundtrip(submit.clone()), submit);
        // No deadline survives as None, not 0.
        let nodeadline = Frame::Submit(SubmitFrame {
            deadline_ms: None,
            priority: Priority::Bulk,
            ..match submit {
                Frame::Submit(s) => s,
                _ => unreachable!(),
            }
        });
        assert_eq!(roundtrip(nodeadline.clone()), nodeadline);
        let result = Frame::Result(ResultFrame {
            id: 42,
            rows: 2,
            cols: 2,
            data: vec![1.5, -0.25, 1e-30, 3.0],
            queue_ms: 0.25,
            total_ms: 1.75,
            deadline_missed: true,
            encode_ms: 0.1,
            gemm_ms: 0.9,
            decode_ms: 0.2,
        });
        assert_eq!(roundtrip(result.clone()), result);
        let reject = Frame::Reject(RejectFrame {
            id: 7,
            code: REJECT_NEED_OPERAND,
            detail: "deadbeef".into(),
        });
        assert_eq!(roundtrip(reject.clone()), reject);
        assert_eq!(roundtrip(Frame::MetricsRequest), Frame::MetricsRequest);
        let text = Frame::MetricsText("boosters_up 1\n".into());
        assert_eq!(roundtrip(text.clone()), text);
    }

    #[test]
    fn operand_frames_roundtrip_every_layout_on_ragged_shapes() {
        // One format per mantissa-plane layout, shapes that do not
        // divide the block size (ragged tails exercise the padding).
        let cases = [
            (4u32, 16usize, 5usize, 7usize),  // I4Packed
            (6, 16, 9, 3),                    // I8
            (12, 16, 3, 5),                   // I16
            (4, 64, 130, 2),                  // ragged across two blocks
        ];
        for (m_bits, block, k, n) in cases {
            let fmt = BlockFormat::new(m_bits, block).unwrap();
            let data: Vec<f32> = (0..k * n).map(|i| (i as f32) * 0.37 - 3.0).collect();
            let planes = encode_w(&data, k, n, fmt);
            let key = OperandKey::new(content_fingerprint(&data, k, n), fmt);
            let put = Frame::PutOperand(PutOperandFrame {
                key,
                transposed: true,
                planes: planes.clone(),
            });
            match roundtrip(put) {
                Frame::PutOperand(back) => {
                    assert_eq!(back.key, key);
                    assert!(back.transposed);
                    assert_eq!(back.planes, planes, "m={m_bits} b={block} {k}x{n}");
                }
                other => panic!("wrong frame {other:?}"),
            }
            let probe = Frame::Probe(ProbeFrame { key });
            assert_eq!(roundtrip(probe.clone()), probe);
            let reply = Frame::ProbeReply(ProbeReplyFrame { key, present: true });
            assert_eq!(roundtrip(reply.clone()), reply);
        }
    }

    #[test]
    fn corrupt_frames_are_rejected() {
        let good = Frame::Probe(ProbeFrame {
            key: OperandKey::new(
                content_fingerprint(&[1.0], 1, 1),
                BlockFormat::new(4, 16).unwrap(),
            ),
        })
        .encode();

        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Frame::read_from(&mut &bad[..]).is_err());

        // Wrong version.
        let mut bad = good.clone();
        bad[4] = 99;
        let err = Frame::read_from(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // Unknown kind.
        let mut bad = good.clone();
        bad[6] = 200;
        assert!(Frame::read_from(&mut &bad[..]).is_err());

        // Nonzero reserved flags.
        let mut bad = good.clone();
        bad[7] = 1;
        assert!(Frame::read_from(&mut &bad[..]).is_err());

        // Truncated payload: mid-frame EOF, not a clean None.
        let bad = &good[..good.len() - 3];
        assert!(Frame::read_from(&mut &bad[..]).is_err());

        // Truncated header.
        let bad = &good[..7];
        assert!(Frame::read_from(&mut &bad[..]).is_err());

        // Trailing garbage inside the declared payload length.
        let mut bad = good.clone();
        bad.push(0xAB);
        let len = u32::from_le_bytes(bad[8..12].try_into().unwrap()) + 1;
        bad[8..12].copy_from_slice(&len.to_le_bytes());
        let err = Frame::read_from(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");

        // A length prefix past the payload cap is rejected before any
        // allocation.
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&(MAX_PAYLOAD as u32 + 1).to_le_bytes());
        let err = Frame::read_from(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn corrupt_operand_planes_are_rejected() {
        let fmt = BlockFormat::new(4, 16).unwrap();
        let data: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let planes = encode_w(&data, 16, 2, fmt);
        let key = OperandKey::new(content_fingerprint(&data, 16, 2), fmt);
        let good = Frame::PutOperand(PutOperandFrame {
            key,
            transposed: true,
            planes,
        })
        .encode();
        // Flip the layout byte inside the matrix encoding: header(12) +
        // key(24) + transposed(1) + fmt(8) + rows/cols/bpr(12) = offset
        // 57 holds the layout byte.
        let mut bad = good.clone();
        assert_eq!(bad[57], 1, "layout byte moved; update the offset");
        bad[57] = 2;
        let err = Frame::read_from(&mut &bad[..]).unwrap_err();
        assert!(err.to_string().contains("layout"), "{err}");
        // A format the encoder can never produce (mantissa bits out of
        // range) is rejected by BlockFormat validation.
        let mut bad = good.clone();
        bad[37] = 99; // m_bits LSB inside the matrix's BlockFormat
        assert!(Frame::read_from(&mut &bad[..]).is_err());
    }

    #[test]
    fn admission_error_codes_compose_with_reject_frames() {
        use crate::exec::AdmissionError;
        let e = AdmissionError::QueueFull { capacity: 256 };
        let rej = RejectFrame {
            id: 1,
            code: e.wire_code(),
            detail: e.wire_detail(),
        };
        let back = AdmissionError::from_wire(rej.code, &rej.detail).unwrap();
        assert_eq!(back, e);
        // Fabric-level codes live above the admission range.
        assert!(AdmissionError::from_wire(REJECT_NEED_OPERAND, "").is_none());
        assert!(AdmissionError::from_wire(REJECT_EXEC_FAILED, "").is_none());
    }
}
