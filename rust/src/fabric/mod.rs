//! Multi-node execution fabric: remote BFP runners, digest-dedup
//! operand transfer, and a deadline-sharding router.
//!
//! The [`crate::exec`] service executes HBFP GEMMs behind a
//! submit/ticket surface on one machine. This module stretches that
//! surface across processes: a [`runner`] hosts a
//! [`crate::exec::BfpService`] behind a TCP socket, and a [`router`]
//! offers the same submit/ticket API over N such runners. Everything
//! rides the determinism contract — a GEMM is a pure function of
//! `(x, w, fmt)`, bit-identical to `hbfp_gemm_scalar` wherever it runs
//! — which is what makes transparent failover *correct* rather than
//! merely optimistic.
//!
//! # Frame layout
//!
//! One TCP connection carries a sequence of length-prefixed frames
//! (see [`wire`] for the authoritative byte-level spec):
//!
//! ```text
//! "BFAB" | version u16 | kind u8 | flags u8 | payload_len u32 | payload
//! ```
//!
//! All integers little-endian; f32 values travel as `to_bits()` words,
//! preserving bit-identity end to end. Frames are the atomic write
//! unit; readers reject truncated, oversized, or trailing-garbage
//! payloads and drop the connection rather than resynchronize.
//!
//! # Digest negotiation (operand dedup)
//!
//! Weight operands are referenced by the 128-bit content digest of
//! [`crate::util::digest`] — the *same* fingerprint the exec-layer
//! operand cache keys on, single-homed so cache and wire agree
//! byte-for-byte. The transfer protocol is digest-first:
//!
//! 1. the router checks its per-runner known-key set (no traffic);
//! 2. on a miss it sends a [`wire::ProbeFrame`] and the runner answers
//!    from its operand store;
//! 3. only a negative answer moves plane bytes — one
//!    [`wire::PutOperandFrame`] carrying the **encoded** mantissa +
//!    exponent planes (a 4-bit weight crosses the wire at ~4.5
//!    bits/value, the paper's density argument applied to the network);
//! 4. a submission that still references an unknown digest (runner
//!    restart) bounces with `REJECT_NEED_OPERAND` and the router
//!    re-negotiates — the store needs no session state to recover.
//!
//! Each distinct weight plane therefore crosses the wire **at most
//! once per runner residency** in steady state; [`FabricStats`] carries
//! both the hit counters and the bytes-sent / bytes-deduped pair that
//! prove it. The runner-side store is LRU-bounded by resident plane
//! bytes (`BOOSTERS_FABRIC_STORE_MB`, default 256 MiB); an eviction
//! simply re-triggers step 4, and the forced re-transfer is counted in
//! its own runner counter (`fabric_runner_operands_retransferred`)
//! rather than diluting the dedup numbers.
//!
//! # Registry warm start
//!
//! `repro fabric-runner --registry DIR` preloads the operand store from
//! a local [`crate::registry::Registry`] before accepting connections:
//! manifest-covered weights arrive as mmap-loaded, already-encoded
//! planes under the same content-digest key the router probes for, so
//! a fresh fleet answers step 2 positively and steps 3–4 never run —
//! zero plane bytes on the wire, zero weight encodes on the runner.
//!
//! # Failover contract
//!
//! The router holds every in-flight op's inputs until its result
//! lands. A dropped connection (EOF, send failure, probe timeout)
//! marks the runner dead, drains its in-flight map exactly once, and
//! re-places each orphan on the survivors — re-running the operand
//! negotiation there. Callers observe nothing but latency: the ticket
//! fulfills with a bit-identical result. Ops are never executed
//! speculatively on two runners, so "at most once per runner, exactly
//! once overall" holds for every op whose router survives. Only when
//! no runner remains does a ticket fail, with a typed error.
//!
//! Death is not permanent: the router's reconnect thread redials dead
//! addresses with bounded exponential backoff, and a restarted runner
//! rejoins the fleet — its known-key set reset, its store re-probed
//! digest-by-digest ([`FabricStats::reconnects`] counts the rejoins).

pub mod router;
pub mod runner;
pub mod wire;

pub use router::{fetch_metrics, FabricRouter, FabricStats, RouterConfig, RunnerView};
pub use runner::{serve, serve_on, serve_on_capped, warm_start_store, RunnerHandle, RunnerShared};
pub use wire::{Frame, OperandKey};
