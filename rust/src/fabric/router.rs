//! Client-side router of the fabric: the submit/ticket surface over N
//! runner connections.
//!
//! # Sharding policy
//!
//! Placement keys on **deadline slack × per-runner outstanding-MAC
//! budget**. Every runner connection tracks the MACs it has accepted
//! but not yet completed; a runner whose backlog would exceed the
//! configured [`RouterConfig::mac_budget`] is not a candidate.
//! Within the candidates:
//!
//! * a request carrying a deadline (its slack is finite) packs onto the
//!   runner with the **smallest outstanding backlog** — backlog is the
//!   queueing delay it will eat out of that slack;
//! * slack-free bulk traffic **round-robins**, spreading work instead
//!   of convoying behind the same emptiest node.
//!
//! When no runner is under budget, a fresh submission gets
//! [`AdmissionError::QueueFull`] — the same typed backpressure a local
//! caller sees, with `capacity` carrying the runner count. Failover
//! resubmissions bypass the budget: an accepted op is never dropped for
//! being unlucky about when its runner died.
//!
//! # Dedup negotiation
//!
//! Weight operands travel by content digest. Per runner the router
//! keeps the set of keys it believes the runner holds; on a miss it
//! probes ("do you hold `digest`?") and ships the encoded planes only
//! on a negative answer. Counters record both sides of the bargain:
//! bytes actually sent and bytes a naive router would have re-sent
//! ([`FabricStats::plane_bytes_deduped`]).
//!
//! # Failover contract
//!
//! Ops are pure functions of `(x, w, fmt)`, so the router keeps each
//! in-flight op's inputs until its result lands. When a connection
//! drops, every op in flight on it is resubmitted to the surviving
//! runners — re-negotiating operands there — and its caller's
//! [`Ticket`] fulfills from wherever the op finally ran, bit-identical
//! by the determinism contract. Only when no runner survives does a
//! ticket fail.
//!
//! # Reconnect
//!
//! A dead runner is not forgotten: a **reconnect** thread retries each
//! dead address with bounded exponential backoff (50 ms doubling to a
//! 2 s cap) until the router drops. A successful reconnect wipes the
//! connection's optimistic known-key set (the restarted process holds
//! nothing we negotiated with its predecessor), installs the fresh
//! socket, spawns a new reader, and **re-probes** every digest this
//! router ever negotiated anywhere — so a runner warm-started from a
//! registry (`--registry DIR`) is rediscovered digest-by-digest before
//! traffic lands on it, with probe-positives counted as dedup hits.
//! [`FabricStats::reconnects`] counts successful rejoins; a runner
//! that stays down just keeps its connection marked dead, exactly as
//! before.
//!
//! # Threading
//!
//! Four kinds of thread touch a connection: submitters (any caller
//! thread), one **reader** per connection, one **repair** thread per
//! router, and one **reconnect** thread per router. Only submitters
//! and the repair thread ever *place* ops — placement can block on a
//! probe round-trip, and a reader blocking on a reply only it could
//! deliver would deadlock. Readers therefore never place: they hand
//! orphaned ops (dead connection, remote reject) to the repair thread
//! through a channel and go back to reading. The reconnect thread only
//! revives connections; it never places.

use super::wire::{
    plane_wire_bytes, Frame, OperandKey, ProbeFrame, PutOperandFrame, SubmitFrame,
    REJECT_EXEC_FAILED, REJECT_NEED_OPERAND,
};
use crate::bfp::{BfpMatrix, BlockFormat, Mat};
use crate::exec::queue::TicketInner;
use crate::exec::{AdmissionError, ExecRuntime, GemmResponse, Priority, Ticket};
use crate::util::digest::content_fingerprint;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{Shutdown, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long a submitter waits for a probe answer before declaring the
/// connection dead (a runner answers probes from memory; seconds of
/// silence means the node, not the store, is the problem).
const PROBE_TIMEOUT: Duration = Duration::from_secs(10);

/// First retry delay after a runner connection dies; doubles per
/// failed attempt up to [`RECONNECT_CAP`].
const RECONNECT_BASE: Duration = Duration::from_millis(50);
/// Ceiling of the reconnect backoff — a runner that stays down costs
/// one refused `connect` every two seconds, nothing more.
const RECONNECT_CAP: Duration = Duration::from_secs(2);
/// Poll cadence of the reconnect thread's scan over dead connections
/// (also bounds how long `Drop` waits for the thread to notice).
const RECONNECT_TICK: Duration = Duration::from_millis(25);

#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Outstanding-MAC budget per runner; the admission half of the
    /// sharding policy (see module docs).
    pub mac_budget: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            mac_budget: crate::util::fabric_mac_budget(),
        }
    }
}

/// One request as the router must remember it to be able to run it
/// again somewhere else.
struct InflightOp {
    x: Arc<Mat>,
    w: Arc<Mat>,
    fmt: BlockFormat,
    deadline_at: Option<Instant>,
    priority: Priority,
    ticket: Arc<TicketInner>,
    macs: u64,
    submitted_at: Instant,
    attempts: u32,
}

/// Work for the repair thread: place (or re-place) one op. `backpressure`
/// carries the typed error to surface if placement finds no capacity —
/// `None` means the op must land somewhere or fail outright.
struct RepairJob {
    op: InflightOp,
    must_place: bool,
    backpressure: Option<AdmissionError>,
}

#[derive(Default)]
struct RouterCounters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rejected_remote: AtomicU64,
    retries: AtomicU64,
    failovers: AtomicU64,
    probes: AtomicU64,
    reconnects: AtomicU64,
    dedup_hits: AtomicU64,
    dedup_misses: AtomicU64,
    plane_bytes_sent: AtomicU64,
    plane_bytes_deduped: AtomicU64,
}

/// One runner connection and everything the router knows about it.
struct RunnerConn {
    index: usize,
    addr: String,
    writer: Mutex<TcpStream>,
    alive: AtomicBool,
    outstanding_macs: AtomicU64,
    completed: AtomicU64,
    peak_inflight: AtomicU64,
    dedup_hits: AtomicU64,
    plane_bytes_sent: AtomicU64,
    inflight: Mutex<HashMap<u64, InflightOp>>,
    /// Keys this router believes the runner holds (optimistic — a
    /// `REJECT_NEED_OPERAND` invalidates the set and re-negotiates).
    known: Mutex<HashSet<OperandKey>>,
    /// Serializes operand negotiation per runner so concurrent
    /// submitters cannot double-ship the same planes.
    negotiate: Mutex<()>,
    probe_replies: Mutex<HashMap<OperandKey, bool>>,
    probe_cv: Condvar,
}

impl RunnerConn {
    fn send(&self, frame: &Frame) -> Result<()> {
        let mut w = self.writer.lock().unwrap_or_else(|p| p.into_inner());
        frame.write_to(&mut *w)
    }
}

struct RouterShared {
    runners: Vec<Arc<RunnerConn>>,
    rt: Arc<ExecRuntime>,
    next_id: AtomicU64,
    rr: AtomicU64,
    mac_budget: u64,
    counters: RouterCounters,
    /// Every operand key this router ever negotiated with *any* runner,
    /// with its wire size — the re-probe list a reconnected runner is
    /// walked through (see the module's reconnect section).
    ever_sent: Mutex<HashMap<OperandKey, u64>>,
    /// Reader handles, appendable: reconnects spawn fresh readers after
    /// `connect` returns, so the list lives behind a lock on the shared
    /// state rather than on the router value.
    readers: Mutex<Vec<JoinHandle<()>>>,
    /// Router teardown flag — tells the reconnect thread that dead
    /// connections are now *supposed* to stay dead.
    shutting_down: AtomicBool,
}

/// Live per-runner view for the stats surface.
#[derive(Debug, Clone)]
pub struct RunnerView {
    pub addr: String,
    pub alive: bool,
    /// Ops accepted by this router and not yet completed there — the
    /// router-observed queue depth of the runner.
    pub inflight: usize,
    pub peak_inflight: u64,
    pub outstanding_macs: u64,
    pub completed: u64,
    pub dedup_hits: u64,
    pub plane_bytes_sent: u64,
}

/// Snapshot of the router's counters (see module docs for what each
/// side of the dedup pair means).
#[derive(Debug, Clone)]
pub struct FabricStats {
    pub runners: Vec<RunnerView>,
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub rejected_remote: u64,
    pub retries: u64,
    pub failovers: u64,
    pub probes: u64,
    /// Successful rejoins of previously-dead runner connections.
    pub reconnects: u64,
    pub dedup_hits: u64,
    pub dedup_misses: u64,
    pub plane_bytes_sent: u64,
    pub plane_bytes_deduped: u64,
}

impl FabricStats {
    /// Fraction of weight-operand references that moved no plane bytes.
    pub fn dedup_hit_rate(&self) -> f64 {
        let total = self.dedup_hits + self.dedup_misses;
        if total == 0 {
            0.0
        } else {
            self.dedup_hits as f64 / total as f64
        }
    }

    /// Counter pairs for the metrics exposition.
    pub fn metric_pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("fabric_router_submitted_total", self.submitted),
            ("fabric_router_completed_total", self.completed),
            ("fabric_router_failed_total", self.failed),
            ("fabric_router_rejected_remote_total", self.rejected_remote),
            ("fabric_router_retries_total", self.retries),
            ("fabric_router_failovers_total", self.failovers),
            ("fabric_router_probes_total", self.probes),
            ("fabric_router_reconnects_total", self.reconnects),
            ("fabric_router_dedup_hits_total", self.dedup_hits),
            ("fabric_router_dedup_misses_total", self.dedup_misses),
            ("fabric_router_plane_bytes_sent_total", self.plane_bytes_sent),
            (
                "fabric_router_plane_bytes_deduped_total",
                self.plane_bytes_deduped,
            ),
        ]
    }
}

/// The client-side entry point: connect once, submit many.
pub struct FabricRouter {
    shared: Arc<RouterShared>,
    repair_tx: Option<mpsc::Sender<RepairJob>>,
    repair: Option<JoinHandle<()>>,
    reconnect: Option<JoinHandle<()>>,
}

impl FabricRouter {
    /// Connect to every runner address. All connections must succeed —
    /// a fleet that starts degraded is a misconfiguration, not a
    /// failover case. Weights are encoded locally on `rt` (its operand
    /// cache makes each distinct weight a single encode per process).
    pub fn connect(addrs: &[String], cfg: RouterConfig, rt: Arc<ExecRuntime>) -> Result<Self> {
        if addrs.is_empty() {
            bail!("fabric router needs at least one runner address");
        }
        let mut runners = Vec::with_capacity(addrs.len());
        for (index, addr) in addrs.iter().enumerate() {
            let stream = TcpStream::connect(addr)
                .with_context(|| format!("connecting to fabric runner {addr}"))?;
            let _ = stream.set_nodelay(true);
            runners.push(Arc::new(RunnerConn {
                index,
                addr: addr.clone(),
                writer: Mutex::new(stream),
                alive: AtomicBool::new(true),
                outstanding_macs: AtomicU64::new(0),
                completed: AtomicU64::new(0),
                peak_inflight: AtomicU64::new(0),
                dedup_hits: AtomicU64::new(0),
                plane_bytes_sent: AtomicU64::new(0),
                inflight: Mutex::new(HashMap::new()),
                known: Mutex::new(HashSet::new()),
                negotiate: Mutex::new(()),
                probe_replies: Mutex::new(HashMap::new()),
                probe_cv: Condvar::new(),
            }));
        }
        let shared = Arc::new(RouterShared {
            runners,
            rt,
            next_id: AtomicU64::new(1),
            rr: AtomicU64::new(0),
            mac_budget: cfg.mac_budget.max(1),
            counters: RouterCounters::default(),
            ever_sent: Mutex::new(HashMap::new()),
            readers: Mutex::new(Vec::new()),
            shutting_down: AtomicBool::new(false),
        });
        let (repair_tx, repair_rx) = mpsc::channel::<RepairJob>();
        let repair = {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name("fabric-repair".into())
                .spawn(move || repair_loop(shared, repair_rx))
                .context("spawning fabric repair thread")?
        };
        for conn in &shared.runners {
            let shared2 = Arc::clone(&shared);
            let conn2 = Arc::clone(conn);
            let tx = repair_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fabric-rx-{}", conn.index))
                .spawn(move || reader_loop(shared2, conn2, tx))
                .context("spawning fabric reader thread")?;
            shared
                .readers
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .push(handle);
        }
        let reconnect = {
            let shared = Arc::clone(&shared);
            let tx = repair_tx.clone();
            std::thread::Builder::new()
                .name("fabric-reconnect".into())
                .spawn(move || reconnect_loop(shared, tx))
                .context("spawning fabric reconnect thread")?
        };
        Ok(Self {
            shared,
            repair_tx: Some(repair_tx),
            repair: Some(repair),
            reconnect: Some(reconnect),
        })
    }

    /// Submit one GEMM to the fabric. Same contract as
    /// [`crate::exec::BfpService::submit`]: non-blocking admission with
    /// typed [`AdmissionError`] backpressure, and a [`Ticket`] whose
    /// result is bit-identical to the local scalar reference.
    pub fn submit(
        &self,
        x: Arc<Mat>,
        w: Arc<Mat>,
        fmt: BlockFormat,
        deadline: Option<Duration>,
        priority: Priority,
    ) -> Result<Ticket, AdmissionError> {
        if x.cols != w.rows {
            return Err(AdmissionError::InvalidShape {
                reason: format!("inner dims {} vs {} do not contract", x.cols, w.rows),
            });
        }
        let macs = (x.rows as u64) * (x.cols as u64) * (w.cols as u64);
        let ticket = TicketInner::new();
        let now = Instant::now();
        let op = InflightOp {
            x,
            w,
            fmt,
            deadline_at: deadline.map(|d| now + d),
            priority,
            ticket: Arc::clone(&ticket),
            macs,
            submitted_at: now,
            attempts: 0,
        };
        // Fresh submissions respect the budget (backpressure); only
        // failover resubmissions may overrun it.
        if let Err((_op, adm)) = route(&self.shared, op, false) {
            return Err(adm);
        }
        self.shared.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket::from_inner(ticket))
    }

    pub fn stats(&self) -> FabricStats {
        let c = &self.shared.counters;
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        FabricStats {
            runners: self
                .shared
                .runners
                .iter()
                .map(|r| RunnerView {
                    addr: r.addr.clone(),
                    alive: r.alive.load(Ordering::SeqCst),
                    inflight: r.inflight.lock().unwrap_or_else(|p| p.into_inner()).len(),
                    peak_inflight: g(&r.peak_inflight),
                    outstanding_macs: g(&r.outstanding_macs),
                    completed: g(&r.completed),
                    dedup_hits: g(&r.dedup_hits),
                    plane_bytes_sent: g(&r.plane_bytes_sent),
                })
                .collect(),
            submitted: g(&c.submitted),
            completed: g(&c.completed),
            failed: g(&c.failed),
            rejected_remote: g(&c.rejected_remote),
            retries: g(&c.retries),
            failovers: g(&c.failovers),
            probes: g(&c.probes),
            reconnects: g(&c.reconnects),
            dedup_hits: g(&c.dedup_hits),
            dedup_misses: g(&c.dedup_misses),
            plane_bytes_sent: g(&c.plane_bytes_sent),
            plane_bytes_deduped: g(&c.plane_bytes_deduped),
        }
    }

    /// Number of runners still connected.
    pub fn alive_runners(&self) -> usize {
        self.shared
            .runners
            .iter()
            .filter(|r| r.alive.load(Ordering::SeqCst))
            .count()
    }
}

impl Drop for FabricRouter {
    fn drop(&mut self) {
        // Reconnect must see the flag before the connections die, and
        // must be joined before the readers are drained (its last act
        // may be pushing a fresh reader handle into the list).
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        for conn in &self.shared.runners {
            conn.alive.store(false, Ordering::SeqCst);
            conn.probe_cv.notify_all();
            let w = conn.writer.lock().unwrap_or_else(|p| p.into_inner());
            let _ = w.shutdown(Shutdown::Both);
        }
        if let Some(h) = self.reconnect.take() {
            let _ = h.join();
        }
        let readers: Vec<JoinHandle<()>> = {
            let mut guard = self
                .shared
                .readers
                .lock()
                .unwrap_or_else(|p| p.into_inner());
            guard.drain(..).collect()
        };
        for h in readers {
            let _ = h.join();
        }
        // Readers are gone; dropping the last sender ends the repair
        // loop once it has drained what they enqueued.
        self.repair_tx = None;
        if let Some(h) = self.repair.take() {
            let _ = h.join();
        }
    }
}

fn repair_loop(shared: Arc<RouterShared>, rx: mpsc::Receiver<RepairJob>) {
    while let Ok(job) = rx.recv() {
        if let Err((op, adm)) = route(&shared, job.op, job.must_place) {
            // No capacity anywhere: surface the typed backpressure the
            // runner originally sent (or the local QueueFull).
            let adm = job.backpressure.unwrap_or(adm);
            fail_op_with(&shared, op, anyhow!(adm));
        }
    }
}

/// Pick a runner for `macs` of work (see module docs for the policy).
fn pick_runner(
    shared: &RouterShared,
    macs: u64,
    deadline_at: Option<Instant>,
    must_place: bool,
) -> Option<Arc<RunnerConn>> {
    let alive: Vec<&Arc<RunnerConn>> = shared
        .runners
        .iter()
        .filter(|r| r.alive.load(Ordering::SeqCst))
        .collect();
    if alive.is_empty() {
        return None;
    }
    let under_budget: Vec<&Arc<RunnerConn>> = alive
        .iter()
        .copied()
        .filter(|r| {
            r.outstanding_macs
                .load(Ordering::Relaxed)
                .saturating_add(macs)
                <= shared.mac_budget
        })
        .collect();
    if under_budget.is_empty() {
        if !must_place {
            return None;
        }
        // Failover placement: least backlog wins, budget or not.
        return alive
            .into_iter()
            .min_by_key(|r| r.outstanding_macs.load(Ordering::Relaxed))
            .cloned();
    }
    let chosen = if deadline_at.is_some() {
        // Finite slack: backlog is queueing delay — pack the emptiest.
        under_budget
            .iter()
            .min_by_key(|r| r.outstanding_macs.load(Ordering::Relaxed))
            .copied()
    } else {
        // Slack-free bulk: spread round-robin across the candidates.
        let n = shared.rr.fetch_add(1, Ordering::Relaxed) as usize;
        under_budget.get(n % under_budget.len()).copied()
    };
    chosen.map(Arc::clone)
}

/// Make sure `conn` holds the encoded planes for `key` before any
/// submission references it: known-set hit, probe hit, or plane
/// transfer — in that order of preference (and cost).
fn ensure_operand(
    shared: &RouterShared,
    conn: &RunnerConn,
    key: OperandKey,
    planes: &Arc<BfpMatrix>,
) -> Result<()> {
    let bytes = plane_wire_bytes(planes);
    // Remember every key we ever negotiate (with its wire size): a
    // runner that dies and rejoins is walked through this list so its
    // surviving or registry-warmed store is rediscovered up front.
    shared
        .ever_sent
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .insert(key, bytes);
    let _serialize = conn.negotiate.lock().unwrap_or_else(|p| p.into_inner());
    if conn
        .known
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .contains(&key)
    {
        shared.counters.dedup_hits.fetch_add(1, Ordering::Relaxed);
        shared
            .counters
            .plane_bytes_deduped
            .fetch_add(bytes, Ordering::Relaxed);
        conn.dedup_hits.fetch_add(1, Ordering::Relaxed);
        return Ok(());
    }
    shared.counters.probes.fetch_add(1, Ordering::Relaxed);
    conn.send(&Frame::Probe(ProbeFrame { key }))?;
    let present = wait_probe_reply(conn, key)?;
    if present {
        shared.counters.dedup_hits.fetch_add(1, Ordering::Relaxed);
        shared
            .counters
            .plane_bytes_deduped
            .fetch_add(bytes, Ordering::Relaxed);
        conn.dedup_hits.fetch_add(1, Ordering::Relaxed);
    } else {
        conn.send(&Frame::PutOperand(PutOperandFrame {
            key,
            transposed: true,
            planes: (**planes).clone(),
        }))?;
        shared.counters.dedup_misses.fetch_add(1, Ordering::Relaxed);
        shared
            .counters
            .plane_bytes_sent
            .fetch_add(bytes, Ordering::Relaxed);
        conn.plane_bytes_sent.fetch_add(bytes, Ordering::Relaxed);
    }
    conn.known
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .insert(key);
    Ok(())
}

fn wait_probe_reply(conn: &RunnerConn, key: OperandKey) -> Result<bool> {
    let deadline = Instant::now() + PROBE_TIMEOUT;
    let mut replies = conn.probe_replies.lock().unwrap_or_else(|p| p.into_inner());
    loop {
        if let Some(present) = replies.remove(&key) {
            return Ok(present);
        }
        if !conn.alive.load(Ordering::SeqCst) {
            bail!("runner {} died during operand negotiation", conn.addr);
        }
        let now = Instant::now();
        if now >= deadline {
            bail!("probe to runner {} timed out", conn.addr);
        }
        let (guard, _) = conn
            .probe_cv
            .wait_timeout(replies, deadline - now)
            .unwrap_or_else(|p| p.into_inner());
        replies = guard;
    }
}

/// Place one op on some runner: encode its weight locally (cached),
/// negotiate the operand, record it in flight, ship the submit frame.
/// A connection failure at any step fails that runner over (draining
/// and re-placing its whole backlog — we are never on a reader thread
/// here, so placing inline is safe) and retries on the survivors.
///
/// `Err` returns the op **unplaced** with the backpressure to surface —
/// only possible when `must_place` is false; with `must_place` the op
/// is always consumed (placed, or its ticket failed).
#[allow(clippy::result_large_err)]
fn route(
    shared: &Arc<RouterShared>,
    mut op: InflightOp,
    must_place: bool,
) -> Result<(), (InflightOp, AdmissionError)> {
    op.attempts += 1;
    if op.attempts as usize > shared.runners.len().saturating_mul(2).max(2) {
        let attempts = op.attempts;
        fail_op_with(
            shared,
            op,
            anyhow!(
                "op gave up after {attempts} placement attempts across {} runners",
                shared.runners.len()
            ),
        );
        return Ok(());
    }
    let Some(conn) = pick_runner(shared, op.macs, op.deadline_at, must_place) else {
        if must_place {
            // Accepted op, no survivors: its ticket fails — there is
            // nowhere left that could compute it.
            fail_op_with(shared, op, anyhow!("no fabric runner survives"));
            return Ok(());
        }
        return Err((
            op,
            AdmissionError::QueueFull {
                capacity: shared.runners.len(),
            },
        ));
    };
    let planes = match shared.rt.encode_transposed_cached(op.w.as_ref(), op.fmt) {
        Ok(p) => p,
        Err(e) => {
            // Local encode failure is deterministic — no runner could
            // do better with the same operand.
            fail_op_with(shared, op, e.context("local weight encode"));
            return Ok(());
        }
    };
    let key = OperandKey::new(
        content_fingerprint(&op.w.data, op.w.rows, op.w.cols),
        op.fmt,
    );
    if let Err(e) = ensure_operand(shared, &conn, key, &planes) {
        eprintln!(
            "fabric: operand negotiation with {} failed ({e:#}); failing over",
            conn.addr
        );
        fail_runner_inline(shared, &conn);
        return route(shared, op, must_place);
    }
    let id = shared.next_id.fetch_add(1, Ordering::Relaxed);
    let frame = Frame::Submit(SubmitFrame {
        id,
        priority: op.priority,
        deadline_ms: op
            .deadline_at
            .map(|d| d.saturating_duration_since(Instant::now()).as_millis() as u64),
        fmt: op.fmt,
        x_rows: op.x.rows as u32,
        x_cols: op.x.cols as u32,
        x_data: op.x.data.clone(),
        w_rows: op.w.rows as u32,
        w_cols: op.w.cols as u32,
        w_digest: key.digest,
    });
    let macs = op.macs;
    {
        // Record before sending: a result can race back before the
        // submit call returns.
        let mut inflight = conn.inflight.lock().unwrap_or_else(|p| p.into_inner());
        inflight.insert(id, op);
        let depth = inflight.len() as u64;
        conn.peak_inflight.fetch_max(depth, Ordering::Relaxed);
    }
    conn.outstanding_macs.fetch_add(macs, Ordering::Relaxed);
    let send_failed = conn.send(&frame).is_err();
    if send_failed || !conn.alive.load(Ordering::SeqCst) {
        // Either the send broke the news, or the runner died while we
        // were inserting (in which case the drain may already have
        // taken our op — `take_inflight` returning None means someone
        // else is re-placing it).
        if let Some(op) = take_inflight(&conn, id) {
            eprintln!("fabric: submit to {} failed; failing over", conn.addr);
            fail_runner_inline(shared, &conn);
            return route(shared, op, must_place);
        }
        fail_runner_inline(shared, &conn);
    }
    Ok(())
}

fn fail_op_with(shared: &Arc<RouterShared>, op: InflightOp, err: anyhow::Error) {
    shared.counters.failed.fetch_add(1, Ordering::Relaxed);
    op.ticket.fulfill(Err(err));
}

/// Mark a runner dead and drain its in-flight ops. Idempotent and
/// atomic per op: the map drain hands each orphan to exactly one
/// caller.
fn mark_dead(conn: &RunnerConn) -> Vec<InflightOp> {
    if conn.alive.swap(false, Ordering::SeqCst) {
        let w = conn.writer.lock().unwrap_or_else(|p| p.into_inner());
        let _ = w.shutdown(Shutdown::Both);
    }
    // Wake any submitter parked on a probe answer that will never come.
    conn.probe_cv.notify_all();
    let orphans: Vec<InflightOp> = {
        let mut inflight = conn.inflight.lock().unwrap_or_else(|p| p.into_inner());
        inflight.drain().map(|(_, op)| op).collect()
    };
    // The backlog accounting dies with the runner.
    conn.outstanding_macs.store(0, Ordering::Relaxed);
    orphans
}

/// Fail a runner over from a placement context (submitter or repair
/// thread): its backlog is re-placed inline.
fn fail_runner_inline(shared: &Arc<RouterShared>, conn: &Arc<RunnerConn>) {
    for op in mark_dead(conn) {
        shared.counters.failovers.fetch_add(1, Ordering::Relaxed);
        let _ = route(shared, op, true);
    }
}

fn reader_loop(shared: Arc<RouterShared>, conn: Arc<RunnerConn>, repair: mpsc::Sender<RepairJob>) {
    let reader = match conn
        .writer
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .try_clone()
    {
        Ok(s) => s,
        Err(_) => {
            fail_runner_via(&shared, &conn, &repair);
            return;
        }
    };
    let mut reader = BufReader::new(reader);
    loop {
        match Frame::read_from(&mut reader) {
            Ok(Some(Frame::Result(res))) => {
                let Some(op) = take_inflight(&conn, res.id) else {
                    continue;
                };
                conn.completed.fetch_add(1, Ordering::Relaxed);
                shared.counters.completed.fetch_add(1, Ordering::Relaxed);
                let total_ms = op.submitted_at.elapsed().as_secs_f64() * 1e3;
                let missed_here = op.deadline_at.map(|d| Instant::now() > d).unwrap_or(false);
                let out = match Mat::new(res.rows as usize, res.cols as usize, res.data) {
                    Ok(m) => m,
                    Err(e) => {
                        fail_op_with(&shared, op, anyhow!("malformed result matrix: {e:#}"));
                        continue;
                    }
                };
                op.ticket.fulfill(Ok(GemmResponse {
                    out,
                    queue_ms: res.queue_ms,
                    // The client-observed latency includes the wire.
                    total_ms,
                    deadline_missed: res.deadline_missed || missed_here,
                    encode_ms: res.encode_ms,
                    gemm_ms: res.gemm_ms,
                    decode_ms: res.decode_ms,
                }));
            }
            Ok(Some(Frame::Reject(rej))) => {
                let Some(op) = take_inflight(&conn, rej.id) else {
                    continue;
                };
                shared
                    .counters
                    .rejected_remote
                    .fetch_add(1, Ordering::Relaxed);
                handle_reject(&shared, &conn, &repair, op, rej.code, &rej.detail);
            }
            Ok(Some(Frame::ProbeReply(p))) => {
                conn.probe_replies
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .insert(p.key, p.present);
                conn.probe_cv.notify_all();
            }
            // Metrics pulls go through fetch_metrics' own connection;
            // stray text on this one is harmless.
            Ok(Some(Frame::MetricsText(_))) => {}
            Ok(Some(_)) | Ok(None) | Err(_) => break,
        }
    }
    fail_runner_via(&shared, &conn, &repair);
}

/// Fail a runner over from its own reader thread: orphans go to the
/// repair thread (a reader must never block on placement — see the
/// module's threading section).
fn fail_runner_via(
    shared: &Arc<RouterShared>,
    conn: &Arc<RunnerConn>,
    repair: &mpsc::Sender<RepairJob>,
) {
    for op in mark_dead(conn) {
        shared.counters.failovers.fetch_add(1, Ordering::Relaxed);
        if let Err(mpsc::SendError(job)) = repair.send(RepairJob {
            op,
            must_place: true,
            backpressure: None,
        }) {
            // Router torn down: nothing can place this op anymore.
            fail_op_with(shared, job.op, anyhow!("fabric router shut down"));
        }
    }
}

/// Background scan over dead connections with per-connection bounded
/// exponential backoff. A connection that comes back is revived by
/// [`try_reconnect`]; one that stays down just keeps its next-attempt
/// timestamp pushed out (50 ms doubling to the 2 s cap).
fn reconnect_loop(shared: Arc<RouterShared>, repair: mpsc::Sender<RepairJob>) {
    let n = shared.runners.len();
    let mut backoff: Vec<Duration> = vec![RECONNECT_BASE; n];
    let mut next_try: Vec<Option<Instant>> = vec![None; n];
    while !shared.shutting_down.load(Ordering::SeqCst) {
        for (i, conn) in shared.runners.iter().enumerate() {
            if conn.alive.load(Ordering::SeqCst) {
                backoff[i] = RECONNECT_BASE;
                next_try[i] = None;
                continue;
            }
            let now = Instant::now();
            match next_try[i] {
                // Just observed dead: first attempt fires immediately.
                None => next_try[i] = Some(now),
                Some(t) if now >= t => {
                    if try_reconnect(&shared, conn, &repair) {
                        shared.counters.reconnects.fetch_add(1, Ordering::Relaxed);
                        backoff[i] = RECONNECT_BASE;
                        next_try[i] = None;
                    } else {
                        backoff[i] = (backoff[i] * 2).min(RECONNECT_CAP);
                        next_try[i] = Some(Instant::now() + backoff[i]);
                    }
                }
                Some(_) => {}
            }
        }
        std::thread::sleep(RECONNECT_TICK);
    }
}

/// One reconnect attempt: dial the runner's address, and on success
/// wipe the stale negotiation state, install the fresh socket, mark the
/// connection alive, spawn a new reader, and re-probe every digest this
/// router ever negotiated (probe-positives count as dedup hits — the
/// bytes a naive router would have re-shipped).
fn try_reconnect(
    shared: &Arc<RouterShared>,
    conn: &Arc<RunnerConn>,
    repair: &mpsc::Sender<RepairJob>,
) -> bool {
    let Ok(stream) = TcpStream::connect(&conn.addr) else {
        return false;
    };
    let _ = stream.set_nodelay(true);
    // The restarted process holds nothing its predecessor negotiated:
    // forget the optimistic known-set and any stale probe answers
    // before a submitter can consult them.
    conn.known.lock().unwrap_or_else(|p| p.into_inner()).clear();
    conn.probe_replies
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .clear();
    *conn.writer.lock().unwrap_or_else(|p| p.into_inner()) = stream;
    conn.alive.store(true, Ordering::SeqCst);
    let reader = {
        let shared2 = Arc::clone(shared);
        let conn2 = Arc::clone(conn);
        let tx = repair.clone();
        std::thread::Builder::new()
            .name(format!("fabric-rx-{}", conn.index))
            .spawn(move || reader_loop(shared2, conn2, tx))
    };
    match reader {
        Ok(h) => shared
            .readers
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push(h),
        Err(_) => {
            // No reader means no results: the connection is useless.
            // A submitter may have raced an op onto it in the moment it
            // was alive — hand any such orphan to the repair thread.
            fail_runner_via(shared, conn, repair);
            return false;
        }
    }
    eprintln!("fabric: reconnected to runner {}", conn.addr);
    let keys: Vec<(OperandKey, u64)> = {
        let ever = shared.ever_sent.lock().unwrap_or_else(|p| p.into_inner());
        ever.iter().map(|(k, b)| (*k, *b)).collect()
    };
    for (key, bytes) in keys {
        if reprobe(shared, conn, key, bytes).is_err() {
            // The fresh connection died mid-probe; the reader (or the
            // failed send) already marked it dead — back to backoff.
            return false;
        }
    }
    true
}

/// Ask a rejoined runner whether it still (or already — registry warm
/// start) holds `key`. A positive answer seeds the known-set and counts
/// as a dedup hit of `bytes`; a negative answer leaves the key to the
/// normal lazy negotiation on next use.
fn reprobe(
    shared: &Arc<RouterShared>,
    conn: &Arc<RunnerConn>,
    key: OperandKey,
    bytes: u64,
) -> Result<()> {
    let _serialize = conn.negotiate.lock().unwrap_or_else(|p| p.into_inner());
    shared.counters.probes.fetch_add(1, Ordering::Relaxed);
    conn.send(&Frame::Probe(ProbeFrame { key }))?;
    if wait_probe_reply(conn, key)? {
        shared.counters.dedup_hits.fetch_add(1, Ordering::Relaxed);
        shared
            .counters
            .plane_bytes_deduped
            .fetch_add(bytes, Ordering::Relaxed);
        conn.dedup_hits.fetch_add(1, Ordering::Relaxed);
        conn.known
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(key);
    }
    Ok(())
}

fn take_inflight(conn: &RunnerConn, id: u64) -> Option<InflightOp> {
    let op = conn
        .inflight
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .remove(&id)?;
    let mut macs = conn.outstanding_macs.load(Ordering::Relaxed);
    loop {
        let next = macs.saturating_sub(op.macs);
        match conn.outstanding_macs.compare_exchange_weak(
            macs,
            next,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(cur) => macs = cur,
        }
    }
    Some(op)
}

fn handle_reject(
    shared: &Arc<RouterShared>,
    conn: &Arc<RunnerConn>,
    repair: &mpsc::Sender<RepairJob>,
    op: InflightOp,
    code: u8,
    detail: &str,
) {
    let enqueue = |op: InflightOp, must_place: bool, backpressure: Option<AdmissionError>| {
        shared.counters.retries.fetch_add(1, Ordering::Relaxed);
        if let Err(mpsc::SendError(job)) = repair.send(RepairJob {
            op,
            must_place,
            backpressure,
        }) {
            fail_op_with(shared, job.op, anyhow!("fabric router shut down"));
        }
    };
    match code {
        REJECT_NEED_OPERAND => {
            // Our optimistic known-set was wrong (runner restarted or a
            // probe raced): forget it and re-place the op, which
            // re-negotiates from scratch.
            conn.known.lock().unwrap_or_else(|p| p.into_inner()).clear();
            enqueue(op, true, None);
        }
        REJECT_EXEC_FAILED => {
            // Deterministic ops fail deterministically — retrying
            // elsewhere would compute the same error, slower.
            fail_op_with(shared, op, anyhow!("runner execution failed: {detail}"));
        }
        code => match AdmissionError::from_wire(code, detail) {
            Some(AdmissionError::InvalidShape { reason }) => {
                fail_op_with(shared, op, anyhow!(AdmissionError::InvalidShape { reason }));
            }
            Some(adm) => {
                // QueueFull / ShuttingDown: transient, runner-local —
                // try the rest of the fleet; if everyone is saturated,
                // the caller sees the runner's own typed backpressure.
                enqueue(op, false, Some(adm));
            }
            None => {
                fail_op_with(
                    shared,
                    op,
                    anyhow!("runner rejected op with unknown code {code}: {detail}"),
                );
            }
        },
    }
}

/// One-shot metrics pull from a runner socket (`repro metrics
/// --connect ADDR`): its own connection, one request frame, one text
/// frame back.
pub fn fetch_metrics(addr: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)
        .with_context(|| format!("connecting to fabric runner {addr}"))?;
    Frame::MetricsRequest.write_to(&mut stream)?;
    let mut reader = BufReader::new(stream);
    match Frame::read_from(&mut reader)? {
        Some(Frame::MetricsText(text)) => Ok(text),
        Some(other) => bail!("runner answered metrics request with {other:?}"),
        None => bail!("runner closed the connection before answering"),
    }
}
