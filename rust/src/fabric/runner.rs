//! The remote execution half of the fabric: a TCP server that hosts one
//! [`BfpService`] and speaks the [`super::wire`] protocol.
//!
//! A runner is deliberately thin — it owns no policy. Per connection it
//! runs two threads:
//!
//! * a **reader** that dispatches frames: digest probes against the
//!   operand store, operand installs, and submissions (which it admits
//!   into the local service exactly as an in-process caller would, so
//!   queue bounds, EDF batching, and deadline accounting all apply
//!   unchanged);
//! * a **completion streamer** that watches the submissions' tickets
//!   and writes [`ResultFrame`]s back as they fulfill — out of
//!   submission order when the service reorders (EDF does), which is
//!   why every frame carries its correlation id.
//!
//! # The operand store
//!
//! Weight planes arriving in [`PutOperandFrame`]s land in a
//! digest-keyed store of **encoded** matrices shared by every
//! connection. The store is LRU-bounded by resident plane bytes
//! (`BOOSTERS_FABRIC_STORE_MB`, default 256 MiB): past the cap the
//! least-recently-used planes are dropped, and a later submission
//! referencing an evicted digest simply re-triggers the
//! [`REJECT_NEED_OPERAND`] re-negotiation below. Evictions and the
//! resulting **re-transfers are counted separately**
//! (`fabric_runner_operands_evicted`,
//! `fabric_runner_operands_retransferred`) so the dedup contract stays
//! exact and monotone: "each distinct weight crosses the wire at most
//! once per runner *residency*", with every extra crossing visible in
//! its own counter rather than silently eroding the dedup numbers.
//!
//! A submission referencing a digest the runner does not hold is
//! rejected with [`REJECT_NEED_OPERAND`] and the digest hex as detail —
//! the router re-sends the planes and resubmits, so a restarted runner
//! self-heals without any session state.
//!
//! # Registry warm start
//!
//! `repro fabric-runner --registry DIR` preloads the store from a
//! [`crate::registry`] before serving: every manifest-covered weight is
//! mmap-loaded as already-encoded planes and installed under the same
//! [`OperandKey`] the router derives from the shared content digest, so
//! the probe/put negotiation of a fresh fleet becomes a near-no-op —
//! probes hit, nothing crosses the wire, and the runner performs zero
//! weight encodes.
//!
//! # Execution path
//!
//! The runner never sees raw weight f32s. It encodes the activation on
//! the service pool (the same `encode_into_on` call admission-time
//! pre-encode uses), pairs it with the stored encoded weight via
//! [`OwnedGemmOp::install_encoded`], and submits. The execution stage
//! consumes the filled slot, so results are bit-identical to a local
//! caller encoding from f32 — the property the loopback integration
//! test pins against `hbfp_gemm_scalar`.

use super::wire::{
    plane_wire_bytes, Frame, OperandKey, ProbeReplyFrame, RejectFrame, ResultFrame, SubmitFrame,
    REJECT_EXEC_FAILED, REJECT_NEED_OPERAND,
};
use crate::bfp::{BfpMatrix, Mat, Quantizer};
use crate::exec::{BfpService, ExecRuntime, GemmRequest, ServiceConfig, Ticket};
use anyhow::{Context, Result};
use std::collections::{HashMap, HashSet};
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Monotonic counters of one runner process, all frame-level (the
/// service's own stats cover the execution side). Snapshot via
/// [`RunnerShared::counters_snapshot`]; rendered into the metrics
/// exposition and asserted by the integration tests.
#[derive(Default)]
pub struct RunnerCounters {
    /// Submissions received (whether admitted or rejected).
    pub ops: AtomicU64,
    /// Results streamed back.
    pub results: AtomicU64,
    /// Reject frames written (admission + need-operand + exec failures).
    pub rejects: AtomicU64,
    /// Digest probes answered.
    pub probes: AtomicU64,
    /// Probes answered "present" — a dedup hit another connection (or
    /// an earlier session on this connection) paid for.
    pub probe_hits: AtomicU64,
    /// Operand planes installed into the store.
    pub operands_stored: AtomicU64,
    /// Resident bytes of stored operand planes.
    pub operand_bytes_stored: AtomicU64,
    /// Submissions bounced for a missing operand.
    pub need_operand: AtomicU64,
    /// Planes LRU-evicted past the store's byte cap.
    pub operands_evicted: AtomicU64,
    /// Resident bytes released by those evictions.
    pub operand_bytes_evicted: AtomicU64,
    /// Installs of a digest this runner had stored before (an
    /// eviction-forced re-transfer) — kept separate so the first-copy
    /// dedup accounting stays exact and monotone.
    pub operands_retransferred: AtomicU64,
    /// Planes installed from a local registry at warm start (no wire
    /// transfer, no encode).
    pub operands_preloaded: AtomicU64,
}

impl RunnerCounters {
    fn snapshot(&self) -> Vec<(&'static str, u64)> {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        vec![
            ("fabric_runner_ops_total", g(&self.ops)),
            ("fabric_runner_results_total", g(&self.results)),
            ("fabric_runner_rejects_total", g(&self.rejects)),
            ("fabric_runner_probes_total", g(&self.probes)),
            ("fabric_runner_probe_hits_total", g(&self.probe_hits)),
            ("fabric_runner_operands_stored", g(&self.operands_stored)),
            (
                "fabric_runner_operand_bytes_stored",
                g(&self.operand_bytes_stored),
            ),
            ("fabric_runner_need_operand_total", g(&self.need_operand)),
            ("fabric_runner_operands_evicted", g(&self.operands_evicted)),
            (
                "fabric_runner_operand_bytes_evicted",
                g(&self.operand_bytes_evicted),
            ),
            (
                "fabric_runner_operands_retransferred",
                g(&self.operands_retransferred),
            ),
            (
                "fabric_runner_operands_preloaded",
                g(&self.operands_preloaded),
            ),
        ]
    }
}

struct StoreEntry {
    planes: Arc<BfpMatrix>,
    bytes: u64,
    last_used: u64,
}

/// The digest-keyed operand store: LRU-bounded by resident plane bytes
/// (see module docs). `ever` remembers every key this runner has held,
/// so an install after eviction is attributed as a re-transfer rather
/// than diluting the first-copy dedup accounting.
struct OperandStore {
    entries: HashMap<OperandKey, StoreEntry>,
    ever: HashSet<OperandKey>,
    bytes: u64,
    tick: u64,
}

impl OperandStore {
    fn new() -> Self {
        Self {
            entries: HashMap::new(),
            ever: HashSet::new(),
            bytes: 0,
            tick: 0,
        }
    }
}

/// State shared by every connection of one runner.
pub struct RunnerShared {
    service: BfpService,
    store: Mutex<OperandStore>,
    /// Resident-byte cap on the operand store (`BOOSTERS_FABRIC_STORE_MB`).
    store_budget: u64,
    counters: RunnerCounters,
    stop: AtomicBool,
    conns: Mutex<Vec<TcpStream>>,
}

impl RunnerShared {
    /// Frame-level counters as `(metric name, value)` pairs.
    pub fn counters_snapshot(&self) -> Vec<(&'static str, u64)> {
        self.counters.snapshot()
    }

    fn metrics_text(&self) -> String {
        crate::metrics::render_text(
            &self.service.stats(),
            &self.service.runtime().cache_stats(),
            &self.service.runtime().arena_stats(),
            &self.counters.snapshot(),
        )
    }

    /// Install encoded planes under `key`, evicting LRU entries past
    /// the byte cap. Duplicate installs of a resident key are
    /// idempotent (two clients can race the same probe-miss); only the
    /// first charges the store counters. `preloaded` marks a registry
    /// warm-start install (no wire transfer happened).
    fn store_install(&self, key: OperandKey, planes: Arc<BfpMatrix>, preloaded: bool) {
        let bytes = plane_wire_bytes(&planes);
        let mut store = self.store.lock().unwrap_or_else(|e| e.into_inner());
        store.tick += 1;
        let tick = store.tick;
        if store.entries.contains_key(&key) {
            return;
        }
        let seen_before = !store.ever.insert(key);
        store.entries.insert(
            key,
            StoreEntry {
                planes,
                bytes,
                last_used: tick,
            },
        );
        store.bytes += bytes;
        if preloaded {
            self.counters.operands_preloaded.fetch_add(1, Ordering::Relaxed);
        } else if seen_before {
            self.counters
                .operands_retransferred
                .fetch_add(1, Ordering::Relaxed);
        }
        self.counters.operands_stored.fetch_add(1, Ordering::Relaxed);
        self.counters
            .operand_bytes_stored
            .fetch_add(bytes, Ordering::Relaxed);
        // Evict past the cap — but never the key just installed when it
        // is the sole resident (an oversized-but-needed operand must
        // still serve; the next install will displace it).
        while store.bytes > self.store_budget && store.entries.len() > 1 {
            let victim = store
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            if let Some(e) = store.entries.remove(&victim) {
                store.bytes -= e.bytes;
                self.counters.operands_evicted.fetch_add(1, Ordering::Relaxed);
                self.counters
                    .operand_bytes_evicted
                    .fetch_add(e.bytes, Ordering::Relaxed);
            }
        }
    }

    /// Fetch `key`'s planes for a submission, refreshing the LRU stamp.
    fn store_get(&self, key: &OperandKey) -> Option<Arc<BfpMatrix>> {
        let mut store = self.store.lock().unwrap_or_else(|e| e.into_inner());
        store.tick += 1;
        let tick = store.tick;
        store.entries.get_mut(key).map(|e| {
            e.last_used = tick;
            Arc::clone(&e.planes)
        })
    }

    fn store_contains(&self, key: &OperandKey) -> bool {
        self.store
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .entries
            .contains_key(key)
    }
}

/// Handle to an in-process runner (the loopback-test and serve-sim
/// embedding). Dropping the handle does **not** stop the runner; call
/// [`RunnerHandle::kill`] — the failover tests need a runner that dies
/// abruptly, mid-conversation, which is exactly what `kill` does.
pub struct RunnerHandle {
    addr: SocketAddr,
    shared: Arc<RunnerShared>,
    accept: Option<JoinHandle<()>>,
    workers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl RunnerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shared(&self) -> &Arc<RunnerShared> {
        &self.shared
    }

    /// Stop serving **abruptly**: every live connection is shut down at
    /// the socket level (peers observe EOF mid-stream, as they would on
    /// a crashed node) and the accept loop exits. In-flight service
    /// work finishes and is discarded — results are pure, so the
    /// router's resubmission to a surviving runner is bit-identical.
    pub fn kill(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        for s in self.shared.conns.lock().unwrap_or_else(|p| p.into_inner()).iter() {
            let _ = s.shutdown(Shutdown::Both);
        }
        // Unblock the accept loop with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let workers = std::mem::take(
            &mut *self.workers.lock().unwrap_or_else(|p| p.into_inner()),
        );
        for h in workers {
            let _ = h.join();
        }
    }

    /// Block until the accept loop exits (the binary-mode tail: a
    /// standalone runner serves until the process is killed).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

/// Serve the fabric protocol on an already-bound listener, executing on
/// `rt` through a dedicated [`BfpService`]. Returns immediately; the
/// accept loop and per-connection threads run in the background. The
/// operand-store cap comes from the environment
/// (`BOOSTERS_FABRIC_STORE_MB`); tests pin it via [`serve_on_capped`].
pub fn serve_on(listener: TcpListener, rt: Arc<ExecRuntime>) -> Result<RunnerHandle> {
    serve_on_capped(listener, rt, crate::util::fabric_store_budget())
}

/// [`serve_on`] with an explicit operand-store byte cap.
pub fn serve_on_capped(
    listener: TcpListener,
    rt: Arc<ExecRuntime>,
    store_budget: u64,
) -> Result<RunnerHandle> {
    let addr = listener.local_addr().context("runner listener address")?;
    let shared = Arc::new(RunnerShared {
        service: BfpService::new(rt, ServiceConfig::default()),
        store: Mutex::new(OperandStore::new()),
        store_budget: store_budget.max(1),
        counters: RunnerCounters::default(),
        stop: AtomicBool::new(false),
        conns: Mutex::new(Vec::new()),
    });
    let workers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let accept = {
        let shared = Arc::clone(&shared);
        let workers = Arc::clone(&workers);
        std::thread::Builder::new()
            .name("fabric-accept".into())
            .spawn(move || loop {
                let conn = match listener.accept() {
                    Ok((s, _)) => s,
                    Err(_) => break,
                };
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let _ = conn.set_nodelay(true);
                if let Ok(clone) = conn.try_clone() {
                    shared
                        .conns
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .push(clone);
                }
                let shared2 = Arc::clone(&shared);
                if let Ok(h) = std::thread::Builder::new()
                    .name("fabric-conn".into())
                    .spawn(move || handle_conn(shared2, conn))
                {
                    workers.lock().unwrap_or_else(|p| p.into_inner()).push(h);
                }
            })
            .context("spawning fabric accept thread")?
    };
    Ok(RunnerHandle {
        addr,
        shared,
        accept: Some(accept),
        workers,
    })
}

/// Preload a runner's operand store from every manifest of a local
/// [`crate::registry::Registry`]: planes are mmap-loaded already
/// encoded and installed under the [`OperandKey`] the router derives
/// from the shared content digest — no wire transfer, no encode.
/// Returns the number of planes installed.
pub fn warm_start_store(shared: &RunnerShared, dir: &std::path::Path) -> Result<usize> {
    let reg = crate::registry::Registry::open(dir)?;
    let mut installed = 0usize;
    for name in reg.manifest_names()? {
        for (entry, planes) in reg.pull(&name)? {
            let key = OperandKey::new(entry.digest, entry.fmt);
            if !shared.store_contains(&key) {
                shared.store_install(key, planes, true);
                installed += 1;
            }
        }
    }
    Ok(installed)
}

/// Binary mode (`repro fabric-runner --listen ADDR [--registry DIR]`):
/// bind, optionally warm-start the operand store from a local registry,
/// announce the bound address on stdout (the line serve-sim's parent
/// process parses — keep its shape stable), and serve on the global
/// runtime until killed.
pub fn serve(listen: &str, registry: Option<&std::path::Path>) -> Result<()> {
    let listener =
        TcpListener::bind(listen).with_context(|| format!("binding fabric runner to {listen}"))?;
    let addr = listener.local_addr()?;
    let handle = serve_on(listener, crate::exec::global_arc())?;
    if let Some(dir) = registry {
        let installed = warm_start_store(handle.shared(), dir)
            .with_context(|| format!("warm-starting from registry {}", dir.display()))?;
        eprintln!("fabric-runner warm-started {installed} operand(s) from {}", dir.display());
    }
    println!("fabric-runner listening on {addr}");
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    handle.wait();
    Ok(())
}

fn write_frame(writer: &Mutex<TcpStream>, frame: &Frame) -> Result<()> {
    let mut w = writer.lock().unwrap_or_else(|p| p.into_inner());
    frame.write_to(&mut *w)
}

fn handle_conn(shared: Arc<RunnerShared>, stream: TcpStream) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let (tx, rx) = mpsc::channel::<(u64, Ticket)>();
    let streamer = {
        let shared = Arc::clone(&shared);
        let writer = Arc::clone(&writer);
        std::thread::Builder::new()
            .name("fabric-stream".into())
            .spawn(move || stream_completions(shared, writer, rx))
    };
    loop {
        match Frame::read_from(&mut reader) {
            Ok(Some(frame)) => {
                if dispatch(&shared, &writer, &tx, frame).is_err() {
                    break;
                }
            }
            Ok(None) => break,
            Err(e) => {
                // Corrupt traffic: drop the connection — the framing
                // cannot be resynchronized mid-stream.
                eprintln!("fabric-runner: closing connection: {e:#}");
                break;
            }
        }
    }
    drop(tx);
    if let Ok(h) = streamer {
        let _ = h.join();
    }
}

fn dispatch(
    shared: &Arc<RunnerShared>,
    writer: &Arc<Mutex<TcpStream>>,
    tx: &mpsc::Sender<(u64, Ticket)>,
    frame: Frame,
) -> Result<()> {
    match frame {
        Frame::Probe(p) => {
            shared.counters.probes.fetch_add(1, Ordering::Relaxed);
            let present = shared.store_contains(&p.key);
            if present {
                shared.counters.probe_hits.fetch_add(1, Ordering::Relaxed);
            }
            write_frame(
                writer,
                &Frame::ProbeReply(ProbeReplyFrame {
                    key: p.key,
                    present,
                }),
            )
        }
        Frame::PutOperand(put) => {
            shared.store_install(put.key, Arc::new(put.planes), false);
            Ok(())
        }
        Frame::Submit(s) => {
            shared.counters.ops.fetch_add(1, Ordering::Relaxed);
            match admit(shared, &s) {
                Ok(ticket) => {
                    // A closed channel means the streamer died with the
                    // connection; surface it to drop the conn.
                    tx.send((s.id, ticket))
                        .map_err(|_| anyhow::anyhow!("completion streamer gone"))
                }
                Err(reject) => {
                    shared.counters.rejects.fetch_add(1, Ordering::Relaxed);
                    write_frame(writer, &Frame::Reject(reject))
                }
            }
        }
        Frame::MetricsRequest => write_frame(writer, &Frame::MetricsText(shared.metrics_text())),
        // A runner only ever *produces* these; receiving one is a
        // protocol violation worth dropping the connection over.
        Frame::Result(_) | Frame::Reject(_) | Frame::ProbeReply(_) | Frame::MetricsText(_) => {
            anyhow::bail!("unexpected client-bound frame on a runner socket")
        }
    }
}

/// Turn one submission into an admitted service request, or the exact
/// reject frame to send instead.
fn admit(shared: &Arc<RunnerShared>, s: &SubmitFrame) -> Result<Ticket, RejectFrame> {
    let key = OperandKey::new(s.w_digest, s.fmt);
    let Some(w_planes) = shared.store_get(&key) else {
        shared.counters.need_operand.fetch_add(1, Ordering::Relaxed);
        return Err(RejectFrame {
            id: s.id,
            code: REJECT_NEED_OPERAND,
            detail: s.w_digest.to_hex(),
        });
    };
    let invalid = |reason: String| RejectFrame {
        id: s.id,
        code: crate::exec::AdmissionError::InvalidShape {
            reason: reason.clone(),
        }
        .wire_code(),
        detail: reason,
    };
    let x = Mat::new(s.x_rows as usize, s.x_cols as usize, s.x_data.clone())
        .map_err(|e| invalid(format!("{e:#}")))?;
    // The weight participates only through its encoded planes; the op
    // still needs an f32-shaped handle for shape checks and MAC
    // accounting, so give it an all-zero stand-in of the right shape.
    let w = Mat::zeros(s.w_rows as usize, s.w_cols as usize);
    let op = crate::exec::OwnedGemmOp::new(Arc::new(x), Arc::new(w), s.fmt)
        .map_err(|e| invalid(format!("{e:#}")))?;
    let mut xq = BfpMatrix::empty();
    xq.encode_into_on(
        shared.service.runtime().pool(),
        &op.x.data,
        op.x.rows,
        op.x.cols,
        s.fmt,
        Quantizer::nearest(s.fmt.mantissa_bits),
        0,
    )
    .map_err(|e| RejectFrame {
        id: s.id,
        code: REJECT_EXEC_FAILED,
        detail: format!("activation encode: {e:#}"),
    })?;
    op.install_encoded(Arc::new(xq), w_planes);
    let mut req = GemmRequest::new(op).with_priority(s.priority);
    if let Some(ms) = s.deadline_ms {
        req = req.with_deadline(Duration::from_millis(ms));
    }
    shared.service.submit(req).map_err(|e| RejectFrame {
        id: s.id,
        code: e.wire_code(),
        detail: e.wire_detail(),
    })
}

/// Watch submitted tickets and stream each outcome back the moment it
/// fulfills. The service reorders (EDF within priority), so readiness
/// is scanned across all pending tickets rather than head-only.
fn stream_completions(
    shared: Arc<RunnerShared>,
    writer: Arc<Mutex<TcpStream>>,
    rx: mpsc::Receiver<(u64, Ticket)>,
) {
    let mut pending: Vec<(u64, Ticket)> = Vec::new();
    loop {
        if pending.is_empty() {
            match rx.recv() {
                Ok(item) => pending.push(item),
                Err(_) => return,
            }
        }
        while let Ok(item) = rx.try_recv() {
            pending.push(item);
        }
        let done = if let Some(pos) = pending.iter().position(|(_, t)| t.poll()) {
            let (id, t) = pending.remove(pos);
            Some((id, t.wait()))
        } else {
            // Nothing ready: park briefly on the oldest ticket. The
            // timeout bounds how stale the try_recv drain above can get.
            pending[0]
                .1
                .wait_deadline(Duration::from_millis(2))
                .map(|outcome| (pending.remove(0).0, outcome))
        };
        let Some((id, outcome)) = done else { continue };
        let frame = match outcome {
            Ok(resp) => {
                shared.counters.results.fetch_add(1, Ordering::Relaxed);
                Frame::Result(ResultFrame {
                    id,
                    rows: resp.out.rows as u32,
                    cols: resp.out.cols as u32,
                    data: resp.out.data,
                    queue_ms: resp.queue_ms,
                    total_ms: resp.total_ms,
                    deadline_missed: resp.deadline_missed,
                    encode_ms: resp.encode_ms,
                    gemm_ms: resp.gemm_ms,
                    decode_ms: resp.decode_ms,
                })
            }
            Err(e) => {
                shared.counters.rejects.fetch_add(1, Ordering::Relaxed);
                Frame::Reject(RejectFrame {
                    id,
                    code: REJECT_EXEC_FAILED,
                    detail: format!("{e:#}"),
                })
            }
        };
        if write_frame(&writer, &frame).is_err() {
            // Connection gone: the remaining tickets' results recycle
            // through their Drop impls; nothing to stream them to.
            return;
        }
    }
}
