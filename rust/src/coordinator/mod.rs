//! L3 coordinator — the paper's system contribution.
//!
//! The Accuracy Booster is, operationally, a *scheduling* idea: run 99.7%
//! of training arithmetic at HBFP4 and switch the mantissa width to 6 for
//! (a) the first/last layers always and (b) every layer in the final
//! epoch(s). This module owns that decision loop:
//!
//! * [`PrecisionScheduler`] maps (policy, epoch) -> the runtime scalars
//!   `{bits_mid, bits_edge, rmode, seed}` the AOT-compiled step function
//!   consumes — the software analogue of bit-slicing HBFP6 ops onto an
//!   HBFP4 datapath without recompilation or retuning.
//! * [`Trainer`] drives epochs: shuffle -> train steps -> eval, with the
//!   LR schedule and metrics capture.
//! * [`init`] materializes initial parameters/optimizer state from the
//!   manifest's init specs with a seeded RNG (no python at run time).

pub mod autoboost;
pub mod init;
pub mod precision;
pub mod trainer;

pub use autoboost::AutoBoost;
pub use init::init_state;
pub use precision::PrecisionScheduler;
pub use trainer::{RunResult, Trainer, TrainerData};
