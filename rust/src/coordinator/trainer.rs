//! The training orchestrator: epoch loop over the AOT-compiled step
//! function, with the precision scheduler in the driver's seat.

use crate::analysis::quantize_params_packed_cached;
use crate::config::TrainConfig;
use crate::data::{Batcher, ImageDataset, ImageGenSpec, TextDataset, TextGenSpec};
use crate::metrics::{corpus_bleu, EpochStats, RunHistory};
use crate::runtime::{Engine, ModelVariant, StepScalars, Tensor, TrainState};
use crate::util::{Rng, Stopwatch};
use anyhow::{anyhow, Result};

use super::init::init_state;
use super::precision::PrecisionScheduler;

/// Dataset wrapper: images (mlp/cnn) or token sequences (transformer).
pub enum TrainerData {
    Images(ImageDataset),
    Text(TextDataset),
}

impl TrainerData {
    /// Build the dataset matching a variant's manifest. MLP variants view
    /// the image task as flattened patches at their input width.
    pub fn for_variant(variant: &ModelVariant, cfg: &TrainConfig) -> Result<Self> {
        let m = &variant.manifest;
        match m.model.as_str() {
            "cnn" => Ok(TrainerData::Images(ImageDataset::generate(
                ImageGenSpec {
                    image: m.input_shape[0],
                    classes: m.num_classes,
                    train_size: cfg.train_size,
                    val_size: cfg.val_size,
                    ..Default::default()
                },
                cfg.seed ^ 0xDA7A,
            ))),
            "mlp" => {
                // MLP input is a flat patch; synthesize 4x4x3 images.
                let side = ((m.input_shape[0] / 3) as f64).sqrt() as usize;
                if side * side * 3 != m.input_shape[0] {
                    return Err(anyhow!("mlp input {} not a HWC patch", m.input_shape[0]));
                }
                Ok(TrainerData::Images(ImageDataset::generate(
                    ImageGenSpec {
                        image: side,
                        classes: m.num_classes,
                        noise: 0.25,
                        train_size: cfg.train_size,
                        val_size: cfg.val_size,
                    },
                    cfg.seed ^ 0xDA7A,
                )))
            }
            "transformer" => Ok(TrainerData::Text(TextDataset::generate(
                TextGenSpec {
                    train_size: cfg.train_size,
                    val_size: cfg.val_size,
                    ..Default::default()
                },
                cfg.seed ^ 0x7E97,
            ))),
            other => Err(anyhow!("unknown model kind {other}")),
        }
    }

    pub fn train_size(&self) -> usize {
        match self {
            TrainerData::Images(d) => d.train_y.len(),
            TrainerData::Text(d) => d.train_src.len() / d.spec.src_len,
        }
    }

    pub fn val_size(&self) -> usize {
        match self {
            TrainerData::Images(d) => d.val_y.len(),
            TrainerData::Text(d) => d.val_src.len() / d.spec.src_len,
        }
    }

    pub fn batch(&self, idx: &[usize], val: bool) -> (Tensor, Tensor) {
        match self {
            TrainerData::Images(d) => d.batch(idx, val),
            TrainerData::Text(d) => d.batch(idx, val),
        }
    }
}

/// Result of one training run.
pub struct RunResult {
    pub history: RunHistory,
    pub params: Vec<Tensor>,
    pub state: TrainState,
}

impl RunResult {
    pub fn final_val_acc(&self) -> f64 {
        self.history.final_val_acc()
    }
}

/// Epoch-loop driver. Owns nothing heavier than references; the engine
/// and datasets are supplied by the caller so sweeps can share them.
pub struct Trainer<'a> {
    pub engine: &'a Engine,
    pub variant: &'a ModelVariant,
    pub data: &'a TrainerData,
    pub cfg: TrainConfig,
    /// Per-epoch callback (progress printing); epoch stats are final.
    pub on_epoch: Option<Box<dyn Fn(&EpochStats) + 'a>>,
    /// Host-side BFP weight-store emulation: when set, parameters are
    /// round-tripped through a packed HBFP carrier of this block size
    /// after every epoch, at the scheduler's current mid mantissa width
    /// — emulating weights that *live* in accelerator BFP SRAM rather
    /// than only passing through quantizers inside the graph.
    pub host_bfp_block: Option<usize>,
}

impl<'a> Trainer<'a> {
    pub fn new(
        engine: &'a Engine,
        variant: &'a ModelVariant,
        data: &'a TrainerData,
        cfg: TrainConfig,
    ) -> Self {
        Self {
            engine,
            variant,
            data,
            cfg,
            on_epoch: None,
            host_bfp_block: None,
        }
    }

    pub fn with_progress(mut self, f: impl Fn(&EpochStats) + 'a) -> Self {
        self.on_epoch = Some(Box::new(f));
        self
    }

    /// Enable host-side packed-BFP weight storage emulation (see
    /// [`Trainer::host_bfp_block`]).
    pub fn with_host_bfp_store(mut self, block: usize) -> Self {
        self.host_bfp_block = Some(block);
        self
    }

    /// Evaluate current params over `eval_batches` fixed validation
    /// batches; returns (loss, metric) averages.
    pub fn evaluate(&self, state: &TrainState, scalars: StepScalars) -> Result<(f64, f64)> {
        let batch = self.variant.manifest.batch;
        let n_batches = self
            .cfg
            .eval_batches
            .min(self.data.val_size() / batch)
            .max(1);
        let mut loss = 0.0;
        let mut acc = 0.0;
        for b in Batcher::sequential(n_batches * batch, batch) {
            let (x, y) = self.data.batch(&b, true);
            let s = self.engine.eval_batch(self.variant, state, &x, &y, scalars)?;
            loss += s.loss as f64;
            acc += s.metric as f64;
        }
        Ok((loss / n_batches as f64, acc / n_batches as f64))
    }

    /// Run the full schedule; returns history + final parameters.
    pub fn run(&self) -> Result<RunResult> {
        let m = &self.variant.manifest;
        let mut state = init_state(m, self.cfg.seed)?;
        let sched = PrecisionScheduler::new(
            self.cfg.policy.clone(),
            self.cfg.epochs,
            self.cfg.stochastic_grad,
        );
        let mut batcher = Batcher::new(self.data.train_size(), m.batch);
        let steps = self
            .cfg
            .steps_per_epoch
            .min(batcher.batches_per_epoch())
            .max(1);
        let mut rng = Rng::new(self.cfg.seed ^ 0x5FF1E);
        let mut history = RunHistory::new(format!("{}/{}", m.variant, self.cfg.policy.label()));
        let mut global_step = 0usize;
        // Shared decode buffer for the emulated BFP weight store
        // (allocated once, reused every epoch). The encodings themselves
        // go through the exec operand cache, so a parameter tensor that
        // did not change since its last round-trip is not re-encoded.
        let mut emu_buf: Vec<f32> = Vec::new();

        for epoch in 0..self.cfg.epochs {
            let sw = Stopwatch::start();
            batcher.shuffle(&mut rng);
            let mut tr_loss = 0.0;
            let mut tr_acc = 0.0;
            let mut lr_last = 0.0;
            for s in 0..steps {
                let (x, y) = self.data.batch(batcher.batch_indices(s), false);
                let scalars = sched.scalars_at(epoch, global_step);
                let lr = self
                    .cfg
                    .lr
                    .lr_at(global_step, epoch, self.cfg.epochs) as f32;
                lr_last = lr as f64;
                let stats = self
                    .engine
                    .train_step(self.variant, &mut state, &x, &y, scalars, lr)?;
                tr_loss += stats.loss as f64;
                tr_acc += stats.metric as f64;
                global_step += 1;
            }
            if let Some(block) = self.host_bfp_block {
                let (mid, _) = sched.bits_at(epoch);
                // At bypass widths (>= 23) the emulated store holds FP32
                // and the round-trip is the identity — skip the literal
                // churn. Everything below that (including 17..=22, which
                // the packed entry point delegates past the integer
                // carrier) genuinely re-grids the weights.
                if mid < 23.0 {
                    requantize_params(&mut state, mid as u32, block, &mut emu_buf)?;
                }
            }
            let eval_sc = sched.eval_scalars(epoch);
            let (val_loss, val_acc) = self.evaluate(&state, eval_sc)?;
            let (bits_mid, bits_edge) = sched.bits_at(epoch);
            let e = EpochStats {
                epoch,
                train_loss: tr_loss / steps as f64,
                train_acc: tr_acc / steps as f64,
                val_loss,
                val_acc,
                lr: lr_last,
                bits_mid,
                bits_edge,
                wall_secs: sw.secs(),
            };
            if let Some(cb) = &self.on_epoch {
                cb(&e);
            }
            history.push(e);
        }

        let params = state.params_to_tensors()?;
        Ok(RunResult {
            history,
            params,
            state,
        })
    }
}

/// Round-trip every f32 parameter through the packed HBFP carrier:
/// snapshot, snap via the shared [`quantize_params_packed_cached`]
/// helper (row-major flat blocking — the storage emulation, not the
/// graph's per-axis operand blocking), write the snapped literals back.
/// The work runs on an **encode-only session** of the global execution
/// service: it does not pass the GEMM admission loop (there is no GEMM
/// here), but it shares the service's runtime and operand cache, so
/// unchanged tensors are served from cache instead of re-encoding
/// (`metrics::exec_cache_snapshot` exposes the hit/miss counters).
fn requantize_params(
    state: &mut TrainState,
    m_bits: u32,
    block: usize,
    buf: &mut Vec<f32>,
) -> Result<()> {
    let mut params = state.params_to_tensors()?;
    let session = crate::exec::global_service().session("trainer host-BFP store");
    quantize_params_packed_cached(&mut params, m_bits, block, session.runtime(), buf)?;
    state.params = params
        .iter()
        .map(|t| t.to_literal())
        .collect::<Result<_>>()?;
    Ok(())
}

/// Greedy-decode the validation set and score corpus BLEU (Table 3).
pub fn evaluate_bleu(
    engine: &Engine,
    variant: &ModelVariant,
    state: &TrainState,
    data: &TextDataset,
    n_batches: usize,
    scalars: StepScalars,
) -> Result<f64> {
    let batch = variant.manifest.batch;
    let dec = variant
        .manifest
        .decode
        .as_ref()
        .ok_or_else(|| anyhow!("variant has no decode info"))?;
    let n_batches = n_batches.min(data.val_src.len() / data.spec.src_len / batch).max(1);
    let mut hyps = Vec::new();
    let mut refs = Vec::new();
    for b in Batcher::sequential(n_batches * batch, batch) {
        let (src, r) = data.decode_batch(&b, true);
        let out = engine.decode(variant, state, &src, scalars)?;
        let toks = out.as_i32()?;
        for row in toks.chunks(dec.out_len) {
            hyps.push(row.to_vec());
        }
        refs.extend(r);
    }
    Ok(corpus_bleu(&hyps, &refs, Some(dec.eos)).bleu)
}
