//! AutoBoost — the paper's §2 hypothesis turned into a controller.
//!
//! §2: "After reaching a certain loss value during small mantissa
//! bitwidth training, switching the tensors to a larger mantissa bitwidth
//! enables the sensitive fine-tuning performed in the final epochs."
//! The published Accuracy Booster fixes the switch at the *last epoch*;
//! this extension (paper future-work territory, exercised by the
//! `repro ablation` driver and `bench_booster`) triggers the switch
//! *adaptively* when the validation loss plateaus — no schedule
//! hyperparameter, same bit-sliced datapath story.
//!
//! Trigger: relative improvement of the windowed-mean val loss over the
//! previous window falls below `min_rel_improvement` for `patience`
//! consecutive epochs. Once boosted, never un-boosts (matching the
//! Booster's monotone precision trajectory).

use crate::bfp::BlockFormat;
use crate::runtime::StepScalars;
use anyhow::Result;

#[derive(Debug, Clone)]
pub struct AutoBoost {
    pub low_bits: u32,
    pub high_bits: u32,
    /// Epochs per comparison window.
    pub window: usize,
    /// Plateau threshold: relative improvement below this counts.
    pub min_rel_improvement: f64,
    /// Consecutive plateau epochs required to trigger.
    pub patience: usize,
    /// Stochastic gradient rounding below the bypass width.
    pub stochastic_grad: bool,
    losses: Vec<f64>,
    plateau_run: usize,
    boosted_at: Option<usize>,
}

impl AutoBoost {
    pub fn new(low_bits: u32, high_bits: u32) -> Self {
        Self {
            low_bits,
            high_bits,
            window: 3,
            min_rel_improvement: 0.02,
            patience: 2,
            stochastic_grad: true,
            losses: Vec::new(),
            plateau_run: 0,
            boosted_at: None,
        }
    }

    pub fn boosted(&self) -> bool {
        self.boosted_at.is_some()
    }

    pub fn boosted_at(&self) -> Option<usize> {
        self.boosted_at
    }

    /// Feed the epoch's validation loss; returns true if this epoch ends
    /// with the controller in the boosted state.
    pub fn observe(&mut self, epoch: usize, val_loss: f64) -> bool {
        self.losses.push(val_loss);
        if self.boosted() {
            return true;
        }
        let w = self.window;
        if self.losses.len() >= 2 * w {
            let n = self.losses.len();
            let recent: f64 = self.losses[n - w..].iter().sum::<f64>() / w as f64;
            let prior: f64 = self.losses[n - 2 * w..n - w].iter().sum::<f64>() / w as f64;
            let rel = (prior - recent) / prior.abs().max(1e-12);
            if rel < self.min_rel_improvement {
                self.plateau_run += 1;
            } else {
                self.plateau_run = 0;
            }
            if self.plateau_run >= self.patience {
                self.boosted_at = Some(epoch);
            }
        }
        self.boosted()
    }

    /// Mantissa widths for the *next* epoch's steps.
    pub fn bits(&self) -> (f32, f32) {
        let mid = if self.boosted() {
            self.high_bits
        } else {
            self.low_bits
        };
        (mid as f32, self.high_bits as f32)
    }

    /// Packed-carrier format for the controller's *current* mid
    /// precision — what [`super::Trainer`]'s host-side BFP weight-store
    /// emulation should hold this epoch. Tracks the boost: HBFP(low)
    /// planes before the switch, HBFP(high) after.
    pub fn emulation_format(&self, block: usize) -> Result<BlockFormat> {
        let (mid, _) = self.bits();
        BlockFormat::new(mid as u32, block)
    }

    pub fn scalars(&self, epoch: usize, step: usize) -> StepScalars {
        let (mid, edge) = self.bits();
        let seed = (epoch as u32)
            .wrapping_mul(0x2545F)
            .wrapping_add(step as u32)
            % 0xFF_FFFF;
        StepScalars {
            bits_mid: mid,
            bits_edge: edge,
            rmode_grad: if self.stochastic_grad && mid < 23.0 { 1.0 } else { 0.0 },
            seed: seed as f32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn does_not_boost_while_improving() {
        let mut ab = AutoBoost::new(4, 6);
        for e in 0..20 {
            // Steady 10% improvement per epoch — never plateaus.
            let boosted = ab.observe(e, 2.0 * 0.9f64.powi(e as i32));
            assert!(!boosted, "boosted at epoch {e}");
            assert_eq!(ab.bits(), (4.0, 6.0));
        }
    }

    #[test]
    fn boosts_on_plateau_and_stays_boosted() {
        let mut ab = AutoBoost::new(4, 6);
        let mut boosted_epoch = None;
        for e in 0..30 {
            // Improve for 8 epochs, then flatline.
            let loss = if e < 8 { 2.0 - 0.2 * e as f64 } else { 0.45 };
            if ab.observe(e, loss) && boosted_epoch.is_none() {
                boosted_epoch = Some(e);
            }
        }
        let be = boosted_epoch.expect("should boost on plateau");
        assert!(be >= 8, "boosted too early: {be}");
        assert!(be < 20, "boosted too late: {be}");
        assert_eq!(ab.bits(), (6.0, 6.0));
        assert_eq!(ab.boosted_at(), Some(be));
    }

    #[test]
    fn noise_resets_plateau_run() {
        let mut ab = AutoBoost::new(4, 6);
        // Alternate plateau-ish and improving windows; patience=2 should
        // not trip on a single flat epoch.
        let losses = [2.0, 1.9, 1.8, 1.79, 1.6, 1.5, 1.4, 1.3, 1.2, 1.1];
        for (e, &l) in losses.iter().enumerate() {
            ab.observe(e, l);
        }
        assert!(!ab.boosted());
    }

    #[test]
    fn emulation_format_tracks_the_boost() {
        let mut ab = AutoBoost::new(4, 6);
        let f = ab.emulation_format(64).unwrap();
        assert_eq!((f.mantissa_bits, f.block_size), (4, 64));
        for e in 0..12 {
            ab.observe(e, 1.0); // immediate plateau
        }
        assert!(ab.boosted());
        assert_eq!(ab.emulation_format(64).unwrap().mantissa_bits, 6);
    }

    #[test]
    fn scalars_reflect_state() {
        let mut ab = AutoBoost::new(4, 6);
        assert_eq!(ab.scalars(0, 0).bits_mid, 4.0);
        assert_eq!(ab.scalars(0, 0).rmode_grad, 1.0);
        for e in 0..12 {
            ab.observe(e, 1.0); // immediate plateau
        }
        assert!(ab.boosted());
        assert_eq!(ab.scalars(12, 0).bits_mid, 6.0);
    }
}
