//! Parameter / optimizer-state initialization from manifest init specs.
//!
//! The manifest carries `(init kind, scale)` per parameter (computed by
//! the python model builders: He for convs, Xavier for linears, 0/1 for
//! biases and norm weights), so runs seed their own weights in pure rust.

use crate::runtime::{Manifest, Tensor, TrainState};
use crate::util::Rng;
use anyhow::{anyhow, Result};

/// Materialize one parameter from its spec.
pub fn init_param(spec: &crate::runtime::ParamSpec, rng: &mut Rng) -> Result<Tensor> {
    let n = spec.numel();
    let data: Vec<f32> = match spec.init.as_str() {
        "zeros" => vec![0.0; n],
        "ones" => vec![1.0; n],
        "normal" => (0..n).map(|_| rng.normal_scaled(spec.scale)).collect(),
        "uniform" => (0..n)
            .map(|_| rng.uniform_in(-spec.scale, spec.scale) as f32)
            .collect(),
        other => return Err(anyhow!("unknown init kind {other}")),
    };
    Tensor::from_f32(&spec.shape, data)
}

/// Initial parameters as host tensors.
pub fn init_params(manifest: &Manifest, seed: u64) -> Result<Vec<Tensor>> {
    let mut rng = Rng::new(seed);
    manifest
        .params
        .iter()
        .map(|s| init_param(s, &mut rng))
        .collect()
}

/// Optimizer slots start at zero (momentum buffers, Adam moments, t).
pub fn init_opt(manifest: &Manifest) -> Vec<Tensor> {
    manifest
        .opt
        .slots
        .iter()
        .map(|s| Tensor::zeros(&s.shape))
        .collect()
}

/// Full training state (params + optimizer) ready for the engine.
pub fn init_state(manifest: &Manifest, seed: u64) -> Result<TrainState> {
    let params = init_params(manifest, seed)?;
    let opt = init_opt(manifest);
    TrainState::from_tensors(&params, &opt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn spec(init: &str, scale: f64) -> ParamSpec {
        ParamSpec {
            name: "t".into(),
            shape: vec![64, 32],
            init: init.into(),
            scale,
        }
    }

    #[test]
    fn zeros_ones() {
        let mut rng = Rng::new(0);
        let z = init_param(&spec("zeros", 0.0), &mut rng).unwrap();
        assert!(z.as_f32().unwrap().iter().all(|&v| v == 0.0));
        let o = init_param(&spec("ones", 0.0), &mut rng).unwrap();
        assert!(o.as_f32().unwrap().iter().all(|&v| v == 1.0));
    }

    #[test]
    fn normal_has_requested_std() {
        let mut rng = Rng::new(1);
        let t = init_param(&spec("normal", 0.05), &mut rng).unwrap();
        let d = t.as_f32().unwrap();
        let var: f64 =
            d.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>() / d.len() as f64;
        assert!((var.sqrt() - 0.05).abs() < 0.01, "{}", var.sqrt());
    }

    #[test]
    fn uniform_bounded() {
        let mut rng = Rng::new(2);
        let t = init_param(&spec("uniform", 0.3), &mut rng).unwrap();
        assert!(t.as_f32().unwrap().iter().all(|&v| v.abs() <= 0.3));
    }

    #[test]
    fn unknown_kind_rejected() {
        let mut rng = Rng::new(3);
        assert!(init_param(&spec("he_but_wrong", 1.0), &mut rng).is_err());
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let ta = init_param(&spec("normal", 1.0), &mut a).unwrap();
        let tb = init_param(&spec("normal", 1.0), &mut b).unwrap();
        assert_eq!(ta, tb);
    }
}
