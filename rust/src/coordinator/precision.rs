//! The epoch-driven mixed-mantissa scheduler (the Accuracy Booster).

use crate::config::PrecisionPolicy;
use crate::runtime::StepScalars;

/// FP32-bypass mantissa width (>= 23 per the quantizer contract).
pub const FP32_BITS: f32 = 32.0;

/// Decides the per-step precision scalars from the policy and the
/// training clock. Stateless; the trainer queries it each step.
#[derive(Debug, Clone)]
pub struct PrecisionScheduler {
    policy: PrecisionPolicy,
    total_epochs: usize,
    stochastic_grad: bool,
}

impl PrecisionScheduler {
    pub fn new(policy: PrecisionPolicy, total_epochs: usize, stochastic_grad: bool) -> Self {
        Self {
            policy,
            total_epochs,
            stochastic_grad,
        }
    }

    pub fn policy(&self) -> &PrecisionPolicy {
        &self.policy
    }

    /// Mantissa widths (mid, edge) for a given epoch.
    pub fn bits_at(&self, epoch: usize) -> (f32, f32) {
        match &self.policy {
            PrecisionPolicy::Fp32 => (FP32_BITS, FP32_BITS),
            PrecisionPolicy::Hbfp { bits } => (*bits as f32, *bits as f32),
            PrecisionPolicy::HbfpLayers { mid, edge } => (*mid as f32, *edge as f32),
            PrecisionPolicy::Booster {
                low,
                high,
                boost_epochs,
            } => {
                // Edge layers always high; middle layers switch to high
                // for the final `boost_epochs` epochs.
                let boosted = epoch + boost_epochs >= self.total_epochs;
                let mid = if boosted { *high } else { *low };
                (mid as f32, *high as f32)
            }
            PrecisionPolicy::Cyclic { min, max, edge } => {
                // Triangular cycle over epochs (CPT-style baseline).
                let span = (max - min) as f32;
                let period = 8.0f32;
                let phase = (epoch as f32 % period) / period;
                let tri = if phase < 0.5 {
                    2.0 * phase
                } else {
                    2.0 - 2.0 * phase
                };
                ((*min as f32 + span * tri).round(), *edge as f32)
            }
        }
    }

    /// Whether epoch runs in the boosted (high-precision) phase.
    pub fn is_boosted(&self, epoch: usize) -> bool {
        match &self.policy {
            PrecisionPolicy::Booster { boost_epochs, .. } => {
                epoch + boost_epochs >= self.total_epochs
            }
            _ => false,
        }
    }

    /// Full scalar set for one training step. The seed folds epoch and
    /// step so every stochastic-rounding draw in the run is unique.
    pub fn scalars_at(&self, epoch: usize, step: usize) -> StepScalars {
        let (mid, edge) = self.bits_at(epoch);
        let rmode = if self.stochastic_grad && mid < 23.0 {
            1.0
        } else {
            0.0
        };
        // 16M steps per epoch headroom inside the f32-exact u24 window.
        let seed = (epoch as u32)
            .wrapping_mul(0x2545F)
            .wrapping_add(step as u32)
            % 0xFF_FFFF;
        StepScalars {
            bits_mid: mid,
            bits_edge: edge,
            rmode_grad: rmode,
            seed: seed as f32,
        }
    }

    /// Scalars for evaluation: deterministic (nearest) rounding.
    pub fn eval_scalars(&self, epoch: usize) -> StepScalars {
        let (mid, edge) = self.bits_at(epoch);
        StepScalars {
            bits_mid: mid,
            bits_edge: edge,
            rmode_grad: 0.0,
            seed: 0.0,
        }
    }

    /// Fraction of training arithmetic executed at the low mantissa width
    /// (the paper's 99.7% claim): approximated as the epoch fraction times
    /// the non-edge compute fraction.
    pub fn low_precision_fraction(&self, edge_flop_fraction: f64) -> f64 {
        match &self.policy {
            PrecisionPolicy::Booster { boost_epochs, .. } => {
                let epoch_frac =
                    1.0 - (*boost_epochs.min(&self.total_epochs) as f64) / self.total_epochs as f64;
                epoch_frac * (1.0 - edge_flop_fraction)
            }
            PrecisionPolicy::Hbfp { .. } => 1.0,
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn booster_switches_last_epoch_only() {
        let s = PrecisionScheduler::new(PrecisionPolicy::booster(1), 20, true);
        for e in 0..19 {
            assert_eq!(s.bits_at(e), (4.0, 6.0), "epoch {e}");
            assert!(!s.is_boosted(e));
        }
        assert_eq!(s.bits_at(19), (6.0, 6.0));
        assert!(s.is_boosted(19));
    }

    #[test]
    fn booster_last_10() {
        let s = PrecisionScheduler::new(
            PrecisionPolicy::Booster {
                low: 4,
                high: 6,
                boost_epochs: 10,
            },
            160,
            true,
        );
        assert_eq!(s.bits_at(149), (4.0, 6.0));
        assert_eq!(s.bits_at(150), (6.0, 6.0));
        assert_eq!(s.bits_at(159), (6.0, 6.0));
    }

    #[test]
    fn fp32_never_quantizes_and_never_stochastic() {
        let s = PrecisionScheduler::new(PrecisionPolicy::Fp32, 10, true);
        let sc = s.scalars_at(3, 5);
        assert!(sc.bits_mid >= 23.0 && sc.bits_edge >= 23.0);
        assert_eq!(sc.rmode_grad, 0.0);
    }

    #[test]
    fn hbfp_uses_stochastic_grads_when_asked() {
        let s = PrecisionScheduler::new(PrecisionPolicy::Hbfp { bits: 4 }, 10, true);
        assert_eq!(s.scalars_at(0, 0).rmode_grad, 1.0);
        let s2 = PrecisionScheduler::new(PrecisionPolicy::Hbfp { bits: 4 }, 10, false);
        assert_eq!(s2.scalars_at(0, 0).rmode_grad, 0.0);
    }

    #[test]
    fn eval_is_deterministic() {
        let s = PrecisionScheduler::new(PrecisionPolicy::booster(1), 10, true);
        let sc = s.eval_scalars(3);
        assert_eq!(sc.rmode_grad, 0.0);
        assert_eq!(sc.seed, 0.0);
        assert_eq!((sc.bits_mid, sc.bits_edge), (4.0, 6.0));
    }

    #[test]
    fn seeds_unique_across_steps_and_epochs() {
        let s = PrecisionScheduler::new(PrecisionPolicy::Hbfp { bits: 4 }, 10, true);
        let mut seen = std::collections::HashSet::new();
        for e in 0..10 {
            for st in 0..50 {
                assert!(seen.insert(s.scalars_at(e, st).seed.to_bits()));
            }
        }
    }

    #[test]
    fn paper_low_precision_fraction() {
        // ResNet20 on CIFAR10: 160 epochs, booster(last 1), edge layers
        // ~1.08% of FLOPs -> ~98.3% of ops at HBFP4; the paper's 99.7%
        // is the average over its (larger) model zoo where edge layers
        // are 0.27-0.39%.
        let s = PrecisionScheduler::new(PrecisionPolicy::booster(1), 160, true);
        let f = s.low_precision_fraction(0.0027);
        assert!(f > 0.99, "{f}");
    }

    #[test]
    fn cyclic_stays_in_band() {
        let s = PrecisionScheduler::new(
            PrecisionPolicy::Cyclic {
                min: 3,
                max: 8,
                edge: 8,
            },
            32,
            true,
        );
        for e in 0..32 {
            let (mid, edge) = s.bits_at(e);
            assert!((3.0..=8.0).contains(&mid), "epoch {e}: {mid}");
            assert_eq!(edge, 8.0);
        }
    }
}
