//! Experiment configuration: JSON-loadable run specs, the precision
//! policy grammar, and learning-rate schedules.
//!
//! The precision policy is the paper's subject matter, so it is a
//! first-class config object here (see [`PrecisionPolicy`]): every
//! experiment row in Tables 1–3 is a `TrainConfig` with a different
//! policy, and the Accuracy Booster itself is
//! `booster(low=4, high=6, boost_epochs=1)`.

use crate::util::Json;
use anyhow::{anyhow, bail, Result};
use std::path::Path;

/// Which mantissa widths the scheduler feeds per epoch/layer-class.
#[derive(Debug, Clone, PartialEq)]
pub enum PrecisionPolicy {
    /// FP32 baseline (bits >= 23 bypass).
    Fp32,
    /// Standalone HBFP(bits) everywhere, all epochs.
    Hbfp { bits: u32 },
    /// Layer-aware only: `edge` bits for first/last layers, `mid` inside
    /// ("HBFP4+Layers" in Fig 2).
    HbfpLayers { mid: u32, edge: u32 },
    /// The paper's Accuracy Booster: `low` bits everywhere with `high`
    /// bits on edge layers, switching middle layers to `high` for the
    /// final `boost_epochs` epochs.
    Booster {
        low: u32,
        high: u32,
        boost_epochs: usize,
    },
    /// Cyclic precision (CPT-style related-work baseline): mid bits cycle
    /// between `min` and `max` per epoch.
    Cyclic { min: u32, max: u32, edge: u32 },
}

impl PrecisionPolicy {
    pub fn booster(boost_epochs: usize) -> Self {
        PrecisionPolicy::Booster {
            low: 4,
            high: 6,
            boost_epochs,
        }
    }

    /// Short label used in tables/CSV file names.
    pub fn label(&self) -> String {
        match self {
            PrecisionPolicy::Fp32 => "fp32".into(),
            PrecisionPolicy::Hbfp { bits } => format!("hbfp{bits}"),
            PrecisionPolicy::HbfpLayers { mid, edge } => format!("hbfp{mid}+layers{edge}"),
            PrecisionPolicy::Booster {
                low,
                high,
                boost_epochs,
            } => format!("booster{low}-{high}(last{boost_epochs})"),
            PrecisionPolicy::Cyclic { min, max, .. } => format!("cyclic{min}-{max}"),
        }
    }

    pub fn to_json(&self) -> Json {
        match self {
            PrecisionPolicy::Fp32 => Json::obj(vec![("kind", Json::str("fp32"))]),
            PrecisionPolicy::Hbfp { bits } => Json::obj(vec![
                ("kind", Json::str("hbfp")),
                ("bits", Json::num(*bits as f64)),
            ]),
            PrecisionPolicy::HbfpLayers { mid, edge } => Json::obj(vec![
                ("kind", Json::str("hbfp_layers")),
                ("mid", Json::num(*mid as f64)),
                ("edge", Json::num(*edge as f64)),
            ]),
            PrecisionPolicy::Booster {
                low,
                high,
                boost_epochs,
            } => Json::obj(vec![
                ("kind", Json::str("booster")),
                ("low", Json::num(*low as f64)),
                ("high", Json::num(*high as f64)),
                ("boost_epochs", Json::num(*boost_epochs as f64)),
            ]),
            PrecisionPolicy::Cyclic { min, max, edge } => Json::obj(vec![
                ("kind", Json::str("cyclic")),
                ("min", Json::num(*min as f64)),
                ("max", Json::num(*max as f64)),
                ("edge", Json::num(*edge as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let u32of = |key: &str| -> Result<u32> { Ok(v.req(key)?.as_usize()? as u32) };
        Ok(match v.req("kind")?.as_str()? {
            "fp32" => PrecisionPolicy::Fp32,
            "hbfp" => PrecisionPolicy::Hbfp { bits: u32of("bits")? },
            "hbfp_layers" => PrecisionPolicy::HbfpLayers {
                mid: u32of("mid")?,
                edge: u32of("edge")?,
            },
            "booster" => PrecisionPolicy::Booster {
                low: u32of("low")?,
                high: u32of("high")?,
                boost_epochs: v.req("boost_epochs")?.as_usize()?,
            },
            "cyclic" => PrecisionPolicy::Cyclic {
                min: u32of("min")?,
                max: u32of("max")?,
                edge: u32of("edge")?,
            },
            other => bail!("unknown policy kind {other}"),
        })
    }
}

/// Learning-rate schedule: linear warmup then step decay at fixed epoch
/// fractions (the paper's 82/122-of-160 recipe generalized). A negative
/// `decay_factor` selects inverse-sqrt (the transformer recipe).
#[derive(Debug, Clone, PartialEq)]
pub struct LrSchedule {
    pub base: f64,
    /// Warmup steps (linear from base/10).
    pub warmup_steps: usize,
    /// Epoch fractions at which lr decays by `decay_factor`.
    pub decay_at: Vec<f64>,
    pub decay_factor: f64,
}

impl Default for LrSchedule {
    fn default() -> Self {
        Self {
            base: 0.1,
            warmup_steps: 40,
            decay_at: vec![0.5, 0.75],
            decay_factor: 0.1,
        }
    }
}

impl LrSchedule {
    /// Inverse-sqrt schedule (transformer recipe, Appendix A.2).
    pub fn inverse_sqrt(base: f64, warmup_steps: usize) -> Self {
        Self {
            base,
            warmup_steps,
            decay_at: vec![],
            decay_factor: -1.0, // sentinel selecting inverse-sqrt
        }
    }

    pub fn lr_at(&self, global_step: usize, epoch: usize, total_epochs: usize) -> f64 {
        if self.decay_factor < 0.0 {
            // inverse-sqrt with linear warmup: base * min(s/w, sqrt(w/s)).
            let s = (global_step + 1) as f64;
            let w = self.warmup_steps.max(1) as f64;
            return self.base * (s / w).min((w / s).sqrt());
        }
        let mut lr = self.base;
        if global_step < self.warmup_steps {
            let frac = (global_step + 1) as f64 / self.warmup_steps as f64;
            lr *= 0.1 + 0.9 * frac;
        }
        let progress = epoch as f64 / total_epochs.max(1) as f64;
        for &at in &self.decay_at {
            if progress >= at {
                lr *= self.decay_factor;
            }
        }
        lr
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("base", Json::num(self.base)),
            ("warmup_steps", Json::num(self.warmup_steps as f64)),
            (
                "decay_at",
                Json::Arr(self.decay_at.iter().map(|&v| Json::num(v)).collect()),
            ),
            ("decay_factor", Json::num(self.decay_factor)),
        ])
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            base: v.req("base")?.as_f64()?,
            warmup_steps: v.req("warmup_steps")?.as_usize()?,
            decay_at: v
                .req("decay_at")?
                .as_arr()?
                .iter()
                .map(|x| x.as_f64())
                .collect::<Result<_>>()?,
            decay_factor: v.req("decay_factor")?.as_f64()?,
        })
    }
}

/// One training run.
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    /// Artifact variant, e.g. "cnn_bs64".
    pub variant: String,
    pub policy: PrecisionPolicy,
    pub epochs: usize,
    /// Steps per epoch (bounded by dataset/batch).
    pub steps_per_epoch: usize,
    pub seed: u64,
    pub lr: LrSchedule,
    /// Batches of validation data per eval.
    pub eval_batches: usize,
    /// Stochastic rounding for gradient quantization.
    pub stochastic_grad: bool,
    /// Dataset size knobs.
    pub train_size: usize,
    pub val_size: usize,
}

impl TrainConfig {
    pub fn quick(variant: &str, policy: PrecisionPolicy) -> Self {
        Self {
            variant: variant.into(),
            policy,
            epochs: 8,
            steps_per_epoch: 16,
            seed: 42,
            lr: LrSchedule::default(),
            eval_batches: 4,
            stochastic_grad: true,
            train_size: 4096,
            val_size: 1024,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("variant", Json::str(&self.variant)),
            ("policy", self.policy.to_json()),
            ("epochs", Json::num(self.epochs as f64)),
            ("steps_per_epoch", Json::num(self.steps_per_epoch as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("lr", self.lr.to_json()),
            ("eval_batches", Json::num(self.eval_batches as f64)),
            ("stochastic_grad", Json::Bool(self.stochastic_grad)),
            ("train_size", Json::num(self.train_size as f64)),
            ("val_size", Json::num(self.val_size as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            variant: v.req("variant")?.as_str()?.to_string(),
            policy: PrecisionPolicy::from_json(v.req("policy")?)?,
            epochs: v.req("epochs")?.as_usize()?,
            steps_per_epoch: v.req("steps_per_epoch")?.as_usize()?,
            seed: v.req("seed")?.as_i64()? as u64,
            lr: LrSchedule::from_json(v.req("lr")?)?,
            eval_batches: v.req("eval_batches")?.as_usize()?,
            stochastic_grad: v.req("stochastic_grad")?.as_bool()?,
            train_size: v.req("train_size")?.as_usize()?,
            val_size: v.req("val_size")?.as_usize()?,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e}", path.display()))?;
        Self::from_json(&Json::parse(&text)?)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().render())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_labels() {
        assert_eq!(PrecisionPolicy::Fp32.label(), "fp32");
        assert_eq!(PrecisionPolicy::Hbfp { bits: 6 }.label(), "hbfp6");
        assert_eq!(PrecisionPolicy::booster(1).label(), "booster4-6(last1)");
    }

    #[test]
    fn lr_warmup_and_decay() {
        let s = LrSchedule {
            base: 0.1,
            warmup_steps: 10,
            decay_at: vec![0.5, 0.75],
            decay_factor: 0.1,
        };
        assert!(s.lr_at(0, 0, 100) < 0.1);
        assert!((s.lr_at(50, 10, 100) - 0.1).abs() < 1e-12);
        assert!((s.lr_at(5000, 50, 100) - 0.01).abs() < 1e-12);
        assert!((s.lr_at(9000, 80, 100) - 0.001).abs() < 1e-12);
    }

    #[test]
    fn inverse_sqrt_peaks_at_warmup() {
        let s = LrSchedule::inverse_sqrt(0.0005, 100);
        let before = s.lr_at(10, 0, 10);
        let at = s.lr_at(99, 0, 10);
        let after = s.lr_at(400, 5, 10);
        assert!(before < at, "{before} {at}");
        assert!(after < at, "{after} {at}");
        assert!((after - 0.0005 * 0.5).abs() < 1e-5); // sqrt(100/400)=0.5
    }

    #[test]
    fn json_roundtrip_all_policies() {
        for p in [
            PrecisionPolicy::Fp32,
            PrecisionPolicy::Hbfp { bits: 5 },
            PrecisionPolicy::HbfpLayers { mid: 4, edge: 6 },
            PrecisionPolicy::booster(10),
            PrecisionPolicy::Cyclic {
                min: 3,
                max: 8,
                edge: 8,
            },
        ] {
            let back = PrecisionPolicy::from_json(&p.to_json()).unwrap();
            assert_eq!(back, p);
        }
    }

    #[test]
    fn config_roundtrip_via_file() {
        let c = TrainConfig::quick("cnn_bs64", PrecisionPolicy::booster(1));
        let dir = std::env::temp_dir().join("boosters_test_cfg");
        let p = dir.join("run.json");
        c.save(&p).unwrap();
        let back = TrainConfig::load(&p).unwrap();
        assert_eq!(back, c);
        std::fs::remove_dir_all(&dir).ok();
    }
}
