//! Table 1 — Top-1 validation accuracy of standalone HBFP configurations
//! across mantissa widths {8,6,5,4} and the paper's block-size axis, with
//! the analytic area-gain column.

use crate::bfp::BlockFormat;
use crate::config::PrecisionPolicy;
use crate::coordinator::TrainerData;
use crate::experiments::common::{config_for, run_one, Preset};
use crate::hw_model::area_gain_hbfp;
use crate::report::{fmt_pct, results_dir, Table};
use crate::runtime::Engine;
use anyhow::Result;
use std::path::Path;

pub const MANTISSAS: [u32; 4] = [8, 6, 5, 4];

/// Run the Table-1 sweep for one model family ("cnn" or "mlp").
///
/// Alongside the paper's area-gain column, each row reports the packed
/// software layout of the format — wire bits/value and the host mantissa
/// plane dtype — so the silicon-density story and the [`BfpMatrix`]
/// storage the runs emulate are visibly the same arithmetic.
///
/// [`BfpMatrix`]: crate::bfp::BfpMatrix
pub fn run(engine: &Engine, artifacts: &Path, model: &str, preset: Preset) -> Result<Table> {
    let mut table = Table::new(
        &format!("Table 1 — standalone HBFP, {model} (synthetic task)"),
        &[
            "format",
            "block",
            "area_gain",
            "bits_per_val",
            "plane",
            "final_val_acc",
            "best_val_acc",
        ],
    );

    // FP32 baseline: block size is irrelevant under bypass; use bs64.
    let v64 = engine.load_variant_by_name(artifacts, &format!("{model}_bs64"))?;
    let data = TrainerData::for_variant(&v64, &config_for(&v64, PrecisionPolicy::Fp32, preset))?;
    let cfg = config_for(&v64, PrecisionPolicy::Fp32, preset);
    println!("[table1] {model} fp32 baseline ...");
    let (acc, hist, _) = run_one(engine, &v64, &data, cfg, false)?;
    table.row(vec![
        "FP32".into(),
        "-".into(),
        "1.0".into(),
        "32.00".into(),
        "f32".into(),
        fmt_pct(acc),
        fmt_pct(hist.best_val_acc()),
    ]);

    for &block in preset.block_sizes() {
        let variant = if block == 64 {
            // reuse already-loaded bs64
            None
        } else {
            Some(engine.load_variant_by_name(artifacts, &format!("{model}_bs{block}"))?)
        };
        let v = variant.as_ref().unwrap_or(&v64);
        for &m in &MANTISSAS {
            // HBFP8 only at the paper's single row (b=576) unless full.
            if m == 8 && preset == Preset::Quick && block != 576 {
                continue;
            }
            let policy = PrecisionPolicy::Hbfp { bits: m };
            let cfg = config_for(v, policy, preset);
            println!("[table1] {model} hbfp{m} b={block} ...");
            let fmt = BlockFormat::new(m, block)?;
            let (acc, hist, _) = run_one(engine, v, &data, cfg, false)?;
            table.row(vec![
                format!("HBFP{m}"),
                block.to_string(),
                format!("{:.1}", area_gain_hbfp(m as u64, block as u64)),
                format!("{:.2}", fmt.bits_per_value()),
                fmt.plane_layout().label().to_string(),
                fmt_pct(acc),
                fmt_pct(hist.best_val_acc()),
            ]);
        }
    }

    table.write_csv(&results_dir().join(format!("table1_{model}.csv")))?;
    // The sweep's quantization traffic runs on the exec runtime; print
    // the operand-cache counters next to the accuracy numbers.
    println!(
        "[table1] exec operand cache: {}",
        crate::metrics::exec_cache_snapshot().summary()
    );
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mantissa_axis_matches_paper() {
        assert_eq!(MANTISSAS, [8, 6, 5, 4]);
    }

    #[test]
    fn sweep_formats_fit_the_narrow_planes() {
        // Every Table-1 cell (m <= 8) runs on a narrow mantissa plane —
        // nibble-packed for the paper's 4-bit headline formats, i8
        // otherwise — keeping the density narrative and the host
        // layout aligned.
        for &m in MANTISSAS.iter() {
            for &b in Preset::Full.block_sizes() {
                let fmt = BlockFormat::new(m, b).unwrap();
                let label = fmt.plane_layout().label();
                if m <= 4 && b % 2 == 0 {
                    assert_eq!(label, "i4x2", "m={m} b={b}");
                } else {
                    assert_eq!(label, "i8", "m={m} b={b}");
                }
                assert!(fmt.bits_per_value() < 9.0);
            }
        }
    }
}
