//! Shared experiment plumbing: presets, policy parsing, run helpers.

use crate::config::{LrSchedule, PrecisionPolicy, TrainConfig};
use crate::coordinator::{Trainer, TrainerData};
use crate::metrics::RunHistory;
use crate::runtime::{Engine, ModelVariant};
use anyhow::{anyhow, Result};

/// Experiment scale. The paper trains 160-300 epochs on CIFAR; `Quick`
/// validates the shape in ~minutes, `Full` is the EXPERIMENTS.md setting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Preset {
    Quick,
    Full,
}

impl Preset {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "quick" => Ok(Preset::Quick),
            "full" => Ok(Preset::Full),
            other => Err(anyhow!("unknown preset {other} (quick|full)")),
        }
    }

    /// (epochs, steps_per_epoch) per model family.
    pub fn schedule(&self, model: &str) -> (usize, usize) {
        match (self, model) {
            (Preset::Quick, "mlp") => (8, 16),
            (Preset::Quick, "cnn") => (8, 16),
            (Preset::Quick, "transformer") => (12, 32),
            (Preset::Full, "mlp") => (20, 30),
            (Preset::Full, "cnn") => (18, 30),
            (Preset::Full, "transformer") => (40, 64),
            _ => (8, 16),
        }
    }

    pub fn block_sizes(&self) -> &'static [usize] {
        match self {
            Preset::Quick => &[16, 64, 576],
            Preset::Full => &[16, 25, 36, 49, 64, 256, 576],
        }
    }
}

/// Parse CLI policy strings: fp32 | hbfpN | hbfpN+layersM | boosterK |
/// cyclicMIN-MAX.
pub fn parse_policy(s: &str) -> Result<PrecisionPolicy> {
    if s == "fp32" {
        return Ok(PrecisionPolicy::Fp32);
    }
    if let Some(rest) = s.strip_prefix("booster") {
        let k: usize = if rest.is_empty() { 1 } else { rest.parse()? };
        return Ok(PrecisionPolicy::booster(k));
    }
    if let Some(rest) = s.strip_prefix("cyclic") {
        let (a, b) = rest
            .split_once('-')
            .ok_or_else(|| anyhow!("cyclic needs MIN-MAX"))?;
        return Ok(PrecisionPolicy::Cyclic {
            min: a.parse()?,
            max: b.parse()?,
            edge: 8,
        });
    }
    if let Some(rest) = s.strip_prefix("hbfp") {
        if let Some((mid, edge)) = rest.split_once("+layers") {
            return Ok(PrecisionPolicy::HbfpLayers {
                mid: mid.parse()?,
                edge: edge.parse()?,
            });
        }
        return Ok(PrecisionPolicy::Hbfp { bits: rest.parse()? });
    }
    Err(anyhow!("unknown policy {s}"))
}

/// Default TrainConfig for (variant, policy, preset).
pub fn config_for(variant: &ModelVariant, policy: PrecisionPolicy, preset: Preset) -> TrainConfig {
    let m = &variant.manifest;
    let (epochs, steps) = preset.schedule(&m.model);
    let lr = if m.model == "transformer" {
        LrSchedule::inverse_sqrt(0.003, 60)
    } else {
        LrSchedule {
            base: 0.08,
            warmup_steps: 20,
            decay_at: vec![0.5, 0.75],
            decay_factor: 0.1,
        }
    };
    TrainConfig {
        variant: m.variant.clone(),
        policy,
        epochs,
        steps_per_epoch: steps,
        seed: 42,
        lr,
        eval_batches: 6,
        stochastic_grad: true,
        train_size: (steps * m.batch).max(1024),
        val_size: (6 * m.batch).max(512),
    }
}

/// Train one configuration and return (final val metric, history).
pub fn run_one(
    engine: &Engine,
    variant: &ModelVariant,
    data: &TrainerData,
    cfg: TrainConfig,
    verbose: bool,
) -> Result<(f64, RunHistory, crate::coordinator::RunResult)> {
    let label = format!("{}/{}", variant.manifest.variant, cfg.policy.label());
    let trainer = if verbose {
        let l = label.clone();
        Trainer::new(engine, variant, data, cfg).with_progress(move |e| {
            println!(
                "  [{l}] epoch {:>3}  train_loss {:.4}  val_acc {:.4}  bits {}/{}  ({:.1}s)",
                e.epoch, e.train_loss, e.val_acc, e.bits_mid, e.bits_edge, e.wall_secs
            );
        })
    } else {
        Trainer::new(engine, variant, data, cfg)
    };
    let result = trainer.run()?;
    Ok((result.final_val_acc(), result.history.clone(), result))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parsing() {
        assert_eq!(parse_policy("fp32").unwrap(), PrecisionPolicy::Fp32);
        assert_eq!(
            parse_policy("hbfp6").unwrap(),
            PrecisionPolicy::Hbfp { bits: 6 }
        );
        assert_eq!(
            parse_policy("hbfp4+layers6").unwrap(),
            PrecisionPolicy::HbfpLayers { mid: 4, edge: 6 }
        );
        assert_eq!(parse_policy("booster").unwrap(), PrecisionPolicy::booster(1));
        assert_eq!(
            parse_policy("booster10").unwrap(),
            PrecisionPolicy::booster(10)
        );
        assert!(matches!(
            parse_policy("cyclic3-8").unwrap(),
            PrecisionPolicy::Cyclic { min: 3, max: 8, .. }
        ));
        assert!(parse_policy("nonsense").is_err());
    }

    #[test]
    fn presets() {
        assert_eq!(Preset::parse("quick").unwrap(), Preset::Quick);
        assert_eq!(Preset::Quick.block_sizes().len(), 3);
        assert_eq!(Preset::Full.block_sizes().len(), 7);
        assert!(Preset::Full.schedule("cnn").0 > Preset::Quick.schedule("cnn").0);
    }
}
