//! Figure drivers: Fig 1 (Wasserstein), Fig 2/5 (loss landscapes),
//! Fig 4 (seed error bars), Fig 6 (area ratio sweep) and the §4.2
//! density headline.

use crate::analysis::{filter_normalized_direction, landscape::alpha_grid, landscape_1d, layer_sweep};
use crate::analysis::wasserstein_sweep::fig1_layers;
use crate::checkpoint::Checkpoint;
use crate::config::PrecisionPolicy;
use crate::coordinator::{PrecisionScheduler, TrainerData};
use crate::experiments::common::{config_for, run_one, Preset};
use crate::hw_model::{area_gain_hbfp, bf16_gain, booster_density, fig6_series};
use crate::metrics::r_squared;
use crate::report::{results_dir, Table};
use crate::runtime::Engine;
use crate::util::Rng;
use anyhow::Result;
use std::path::Path;

/// Fig 1 — Wasserstein distances of HBFP6/HBFP4 weight tensors vs FP32
/// for four layers of a *trained* FP32 CNN, across block sizes.
pub fn fig1(engine: &Engine, artifacts: &Path, preset: Preset) -> Result<Table> {
    let v = engine.load_variant_by_name(artifacts, "cnn_bs64")?;
    let cfg = config_for(&v, PrecisionPolicy::Fp32, preset);
    let data = TrainerData::for_variant(&v, &cfg)?;
    println!("[fig1] training FP32 reference model ...");
    let (_, _, result) = run_one(engine, &v, &data, cfg, false)?;
    let names: Vec<String> = v.manifest.params.iter().map(|p| p.name.clone()).collect();
    let ck = Checkpoint::new(names.clone(), result.params.clone());
    ck.save(&results_dir().join("fig1_fp32_cnn.ck"))?;

    let layers = fig1_layers(&names);
    let layer_refs: Vec<&str> = layers.iter().map(|s| s.as_str()).collect();
    let blocks: Vec<usize> = preset.block_sizes().to_vec();
    let points = layer_sweep(&ck, &layer_refs, &[6, 4], &blocks);

    let mut table = Table::new(
        "Fig 1 — Wasserstein distance to FP32 (trained CNN weights)",
        &["layer", "format", "block", "wasserstein"],
    );
    for p in &points {
        table.row(vec![
            p.layer.clone(),
            format!("HBFP{}", p.m_bits),
            p.block.to_string(),
            format!("{:.3e}", p.distance),
        ]);
    }
    table.write_csv(&results_dir().join("fig1_wasserstein.csv"))?;

    // Headline checks printed alongside (paper: HBFP4 ≈ 3.5x HBFP6, and
    // edge layers sit above middle layers).
    let avg = |m: u32| {
        let v: Vec<f64> = points
            .iter()
            .filter(|p| p.m_bits == m)
            .map(|p| p.distance)
            .collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    println!(
        "[fig1] mean W: HBFP4/HBFP6 ratio = {:.2} (paper ≈ 3.5)",
        avg(4) / avg(6)
    );
    Ok(table)
}

/// §3's R² claim: correlation between Wasserstein distance and the
/// accuracy gap, computed from a (distance, accuracy) series.
pub fn wasserstein_accuracy_r2(distances: &[f64], accuracies: &[f64]) -> f64 {
    r_squared(distances, accuracies)
}

/// Fig 2 — 1-D loss-landscape slices for the five configurations.
pub fn fig2(engine: &Engine, artifacts: &Path, preset: Preset) -> Result<Table> {
    let v = engine.load_variant_by_name(artifacts, "cnn_bs64")?;
    let cfg0 = config_for(&v, PrecisionPolicy::Fp32, preset);
    let data = TrainerData::for_variant(&v, &cfg0)?;
    let policies = vec![
        PrecisionPolicy::Fp32,
        PrecisionPolicy::Hbfp { bits: 6 },
        PrecisionPolicy::Hbfp { bits: 4 },
        PrecisionPolicy::HbfpLayers { mid: 4, edge: 6 },
        PrecisionPolicy::booster(1),
    ];
    // Fixed eval batches for every curve.
    let batches: Vec<_> = (0..2)
        .map(|i| {
            let idx: Vec<usize> =
                (i * v.manifest.batch..(i + 1) * v.manifest.batch).collect();
            data.batch(&idx, true)
        })
        .collect();
    let alphas = alpha_grid(0.6, 21);

    let mut table = Table::new(
        "Fig 2 — loss landscape slices (min depth + sharpness)",
        &["policy", "min_loss", "sharpness", "curve_csv"],
    );
    for policy in policies {
        let cfg = config_for(&v, policy.clone(), preset);
        println!("[fig2] training {} ...", policy.label());
        let epochs = cfg.epochs;
        let (_, _, result) = run_one(engine, &v, &data, cfg, false)?;
        let mut rng = Rng::new(1234);
        let dir = filter_normalized_direction(&result.params, &mut rng);
        let sched = PrecisionScheduler::new(policy.clone(), epochs, false);
        let scalars = sched.eval_scalars(epochs - 1);
        println!("[fig2] sweeping landscape for {} ...", policy.label());
        let curve = landscape_1d(
            engine,
            &v,
            &policy.label(),
            &result.params,
            &dir,
            &alphas,
            &batches,
            scalars,
        )?;
        // CSV per curve.
        let fname = format!(
            "fig2_landscape_{}.csv",
            policy.label().replace(['+', '(', ')'], "_")
        );
        let mut csv = Table::new(&curve.label, &["alpha", "loss"]);
        for (a, l) in curve.alphas.iter().zip(&curve.losses) {
            csv.row(vec![format!("{a:.4}"), format!("{l:.6}")]);
        }
        csv.write_csv(&results_dir().join(&fname))?;
        table.row(vec![
            policy.label(),
            format!("{:.4}", curve.min_loss()),
            format!("{:.4}", curve.sharpness()),
            fname,
        ]);
    }
    table.write_csv(&results_dir().join("fig2_summary.csv"))?;
    Ok(table)
}

/// Fig 4 — error bars: N seeds x {FP32, HBFP6, Booster}.
pub fn fig4(engine: &Engine, artifacts: &Path, preset: Preset, seeds: usize) -> Result<Table> {
    let v = engine.load_variant_by_name(artifacts, "cnn_bs64")?;
    let policies = vec![
        PrecisionPolicy::Fp32,
        PrecisionPolicy::Hbfp { bits: 6 },
        PrecisionPolicy::booster(1),
    ];
    let mut table = Table::new(
        &format!("Fig 4 — seed variability ({seeds} seeds)"),
        &["policy", "mean_val_acc", "std", "min", "max"],
    );
    for policy in policies {
        let mut accs = Vec::new();
        for s in 0..seeds {
            let mut cfg = config_for(&v, policy.clone(), preset);
            cfg.seed = 1000 + s as u64;
            let data = TrainerData::for_variant(&v, &cfg)?;
            println!("[fig4] {} seed {} ...", policy.label(), cfg.seed);
            let (acc, _, _) = run_one(engine, &v, &data, cfg, false)?;
            accs.push(acc);
        }
        let mean = crate::util::mean(&accs);
        let std = crate::util::stddev(&accs);
        table.row(vec![
            policy.label(),
            format!("{:.4}", mean),
            format!("{:.4}", std),
            format!("{:.4}", accs.iter().copied().fold(f64::INFINITY, f64::min)),
            format!("{:.4}", accs.iter().copied().fold(0.0f64, f64::max)),
        ]);
    }
    table.write_csv(&results_dir().join("fig4_seeds.csv"))?;
    Ok(table)
}

/// Fig 6 — silicon-area ratio FP32/HBFP across block sizes.
pub fn fig6() -> Result<Table> {
    let blocks: Vec<u64> = vec![4, 8, 16, 25, 36, 49, 64, 128, 256, 400, 576, 1024];
    let mut table = Table::new(
        "Fig 6 — silicon area ratio (FP32 / HBFP)",
        &["block", "HBFP8", "HBFP6", "HBFP5", "HBFP4"],
    );
    for row in fig6_series(&blocks) {
        table.row(vec![
            row.block.to_string(),
            format!("{:.2}", row.hbfp8),
            format!("{:.2}", row.hbfp6),
            format!("{:.2}", row.hbfp5),
            format!("{:.2}", row.hbfp4),
        ]);
    }
    table.write_csv(&results_dir().join("fig6_area_ratio.csv"))?;
    Ok(table)
}

/// §4.2 density headline vs the paper's numbers.
pub fn density() -> Result<Table> {
    let mut table = Table::new(
        "Arithmetic density (§4.2) — model vs paper",
        &["quantity", "model", "paper"],
    );
    table.row(vec![
        "HBFP4 vs FP32 @ b=64".into(),
        format!("{:.1}x", area_gain_hbfp(4, 64)),
        "21.3x".into(),
    ]);
    table.row(vec![
        "HBFP4 vs FP32 @ b=576".into(),
        format!("{:.1}x", area_gain_hbfp(4, 576)),
        "23.9x".into(),
    ]);
    table.row(vec![
        "HBFP6 vs FP32 @ b=64".into(),
        format!("{:.1}x", area_gain_hbfp(6, 64)),
        "13.9x".into(),
    ]);
    table.row(vec![
        "BF16 vs FP32".into(),
        format!("{:.1}x", bf16_gain(64)),
        "4.9x".into(),
    ]);
    table.row(vec![
        "HBFP4 vs BF16 @ b=64".into(),
        format!("{:.1}x", area_gain_hbfp(4, 64) / bf16_gain(64)),
        "4.4x".into(),
    ]);
    table.row(vec![
        "Booster density (99.7% @4b) @ b=64".into(),
        format!("{:.1}x", booster_density(64, 0.003)),
        "≈21.3x".into(),
    ]);
    table.write_csv(&results_dir().join("density_headline.csv"))?;
    Ok(table)
}
