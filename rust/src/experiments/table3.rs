//! Table 3 — BLEU scores for the transformer on the synthetic translation
//! task: FP32 / HBFP6 / HBFP4 / Booster (block 64, Adam, inverse-sqrt lr).

use crate::config::PrecisionPolicy;
use crate::coordinator::{trainer::evaluate_bleu, TrainerData};
use crate::experiments::common::{config_for, run_one, Preset};
use crate::report::{fmt_pct, results_dir, Table};
use crate::runtime::Engine;
use anyhow::{anyhow, Result};
use std::path::Path;

pub fn policies() -> Vec<PrecisionPolicy> {
    vec![
        PrecisionPolicy::Fp32,
        PrecisionPolicy::Hbfp { bits: 6 },
        PrecisionPolicy::Hbfp { bits: 4 },
        PrecisionPolicy::booster(1),
    ]
}

pub fn run(engine: &Engine, artifacts: &Path, preset: Preset) -> Result<Table> {
    let v = engine.load_variant_by_name(artifacts, "transformer_bs64")?;
    let cfg0 = config_for(&v, PrecisionPolicy::Fp32, preset);
    let data = TrainerData::for_variant(&v, &cfg0)?;
    let text = match &data {
        TrainerData::Text(t) => t,
        _ => return Err(anyhow!("transformer variant must use text data")),
    };
    let mut table = Table::new(
        "Table 3 — Transformer BLEU, synthetic De→En stand-in @ block 64",
        &["policy", "BLEU", "token_acc", "final_val_loss"],
    );
    for policy in policies() {
        let cfg = config_for(&v, policy.clone(), preset);
        println!("[table3] transformer {} ...", policy.label());
        let (acc, hist, result) = run_one(engine, &v, &data, cfg, false)?;
        // BLEU decodes with the *final-epoch* precision of the policy
        // (FP32 bypass for fp32; the boosted bits for Booster).
        let sched = crate::coordinator::PrecisionScheduler::new(
            policy.clone(),
            hist.epochs.len(),
            false,
        );
        let scalars = sched.eval_scalars(hist.epochs.len().saturating_sub(1));
        let bleu = evaluate_bleu(engine, &v, &result.state, text, 4, scalars)?;
        table.row(vec![
            policy.label(),
            format!("{bleu:.2}"),
            fmt_pct(acc),
            format!("{:.4}", hist.final_val_loss()),
        ]);
    }
    table.write_csv(&results_dir().join("table3_transformer.csv"))?;
    Ok(table)
}
