//! Experiment drivers — one per paper table/figure (DESIGN.md §5).
//!
//! Each driver builds the workload, sweeps the configurations the paper
//! sweeps, and renders a [`crate::report::Table`] with the same rows the
//! paper reports (plus CSV dumps under `results/`). The CLI (`repro`),
//! the examples and the benches are all thin wrappers over these.

pub mod ablation;
pub mod common;
pub mod figs;
pub mod serve_sim;
pub mod table1;
pub mod table2;
pub mod table3;

pub use common::{parse_policy, Preset};
