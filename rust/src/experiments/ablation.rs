//! Ablation driver — the design-choice studies DESIGN.md calls out:
//!
//!  * **Schedule family**: fixed Booster (paper) vs AutoBoost (adaptive,
//!    loss-plateau-triggered — §2's hypothesis operationalized) vs cyclic
//!    precision (CPT-style related-work baseline) vs plain HBFP4.
//!  * **Boost placement**: boosting the FIRST epochs instead of the last
//!    (tests the frequency-principle claim that the *end* of training is
//!    what needs mantissa).
//!  * **Edge-layer ablation**: Booster without the first/last-layer
//!    HBFP6 override.
//!
//! Uses the same AOT artifact for every arm — only runtime scalars move.

use crate::config::PrecisionPolicy;
use crate::coordinator::{init_state, AutoBoost, Trainer, TrainerData};
use crate::experiments::common::{config_for, run_one, Preset};
use crate::metrics::{EpochStats, RunHistory};
use crate::report::{fmt_pct, results_dir, Table};
use crate::runtime::Engine;
use crate::util::{Rng, Stopwatch};
use anyhow::Result;
use std::path::Path;

/// A Booster that boosts the FIRST k epochs instead of the last (the
/// wrong-way control for the frequency-principle argument).
fn inverse_booster_bits(epoch: usize, boost_epochs: usize) -> (f32, f32) {
    if epoch < boost_epochs {
        (6.0, 6.0)
    } else {
        (4.0, 6.0)
    }
}

/// Manual epoch loop for the two arms the PrecisionScheduler does not
/// cover (AutoBoost and the inverse Booster).
#[allow(clippy::too_many_arguments)]
fn run_custom(
    engine: &Engine,
    variant: &crate::runtime::ModelVariant,
    data: &TrainerData,
    cfg: &crate::config::TrainConfig,
    label: &str,
    mut bits_for_epoch: impl FnMut(usize, f64) -> (f32, f32),
) -> Result<RunHistory> {
    let m = &variant.manifest;
    let mut state = init_state(m, cfg.seed)?;
    let mut batcher = crate::data::Batcher::new(data.train_size(), m.batch);
    let steps = cfg.steps_per_epoch.min(batcher.batches_per_epoch()).max(1);
    let mut rng = Rng::new(cfg.seed ^ 0x5FF1E);
    let mut history = RunHistory::new(label.to_string());
    let mut global_step = 0usize;
    let mut last_val_loss = f64::INFINITY;

    for epoch in 0..cfg.epochs {
        let sw = Stopwatch::start();
        batcher.shuffle(&mut rng);
        let (bits_mid, bits_edge) = bits_for_epoch(epoch, last_val_loss);
        let mut tr_loss = 0.0;
        let mut tr_acc = 0.0;
        let mut lr_last = 0.0;
        for s in 0..steps {
            let (x, y) = data.batch(batcher.batch_indices(s), false);
            let lr = cfg.lr.lr_at(global_step, epoch, cfg.epochs) as f32;
            lr_last = lr as f64;
            let seed = (epoch * 100_003 + s) as u32 % 0xFF_FFFF;
            let scalars = crate::runtime::StepScalars {
                bits_mid,
                bits_edge,
                rmode_grad: if bits_mid < 23.0 { 1.0 } else { 0.0 },
                seed: seed as f32,
            };
            let st = engine.train_step(variant, &mut state, &x, &y, scalars, lr)?;
            tr_loss += st.loss as f64;
            tr_acc += st.metric as f64;
            global_step += 1;
        }
        // Eval with this epoch's precision, deterministic rounding.
        let eval_sc = crate::runtime::StepScalars {
            bits_mid,
            bits_edge,
            rmode_grad: 0.0,
            seed: 0.0,
        };
        let trainer = Trainer::new(engine, variant, data, cfg.clone());
        let (val_loss, val_acc) = trainer.evaluate(&state, eval_sc)?;
        last_val_loss = val_loss;
        history.push(EpochStats {
            epoch,
            train_loss: tr_loss / steps as f64,
            train_acc: tr_acc / steps as f64,
            val_loss,
            val_acc,
            lr: lr_last,
            bits_mid,
            bits_edge,
            wall_secs: sw.secs(),
        });
    }
    Ok(history)
}

pub fn run(engine: &Engine, artifacts: &Path, model: &str, preset: Preset) -> Result<Table> {
    let v = engine.load_variant_by_name(artifacts, &format!("{model}_bs64"))?;
    let cfg = config_for(&v, PrecisionPolicy::booster(1), preset);
    let data = TrainerData::for_variant(&v, &cfg)?;
    let boost_k = (cfg.epochs / 8).max(1);

    let mut table = Table::new(
        &format!("Ablations — schedule design choices, {model} @ block 64"),
        &["arm", "final_val_acc", "best_val_acc", "boost_epochs_used"],
    );

    // Paper arms via the standard scheduler.
    for policy in [
        PrecisionPolicy::Hbfp { bits: 4 },
        PrecisionPolicy::Booster {
            low: 4,
            high: 6,
            boost_epochs: boost_k,
        },
        PrecisionPolicy::Cyclic {
            min: 4,
            max: 6,
            edge: 6,
        },
    ] {
        let c = config_for(&v, policy.clone(), preset);
        println!("[ablation] {} ...", policy.label());
        let (acc, hist, _) = run_one(engine, &v, &data, c, false)?;
        table.row(vec![
            policy.label(),
            fmt_pct(acc),
            fmt_pct(hist.best_val_acc()),
            if matches!(policy, PrecisionPolicy::Booster { .. }) {
                boost_k.to_string()
            } else {
                "-".into()
            },
        ]);
    }

    // AutoBoost: adaptive switch on val-loss plateau.
    println!("[ablation] autoboost ...");
    let mut ab = AutoBoost::new(4, 6);
    ab.window = 2;
    ab.patience = 1;
    let hist = run_custom(engine, &v, &data, &cfg, "autoboost", |epoch, last_loss| {
        if epoch > 0 {
            ab.observe(epoch - 1, last_loss);
        }
        ab.bits()
    })?;
    hist.write_csv(&results_dir().join(format!("ablation_autoboost_{model}.csv")))?;
    table.row(vec![
        "autoboost4-6(plateau)".into(),
        fmt_pct(hist.final_val_acc()),
        fmt_pct(hist.best_val_acc()),
        ab.boosted_at()
            .map(|e| format!("from ep{e}"))
            .unwrap_or_else(|| "never".into()),
    ]);

    // Inverse Booster: boost the FIRST epochs (control).
    println!("[ablation] inverse booster ...");
    let hist = run_custom(engine, &v, &data, &cfg, "inverse", |epoch, _| {
        inverse_booster_bits(epoch, boost_k)
    })?;
    table.row(vec![
        format!("inverse-booster(first{boost_k})"),
        fmt_pct(hist.final_val_acc()),
        fmt_pct(hist.best_val_acc()),
        boost_k.to_string(),
    ]);

    // Booster with the packed-BFP host weight store: parameters live in
    // (are round-tripped through) the same BfpMatrix planes the GEMM
    // kernels consume, at the scheduler's current mid width — the
    // closest software emulation of weights resident in BFP SRAM.
    println!("[ablation] booster + host packed-BFP weight store ...");
    let c = config_for(
        &v,
        PrecisionPolicy::Booster {
            low: 4,
            high: 6,
            boost_epochs: boost_k,
        },
        preset,
    );
    let cache_before = crate::metrics::exec_cache_snapshot();
    let result = Trainer::new(engine, &v, &data, c)
        .with_host_bfp_store(64)
        .run()?;
    let cache_after = crate::metrics::exec_cache_snapshot();
    println!(
        "[ablation] host-BFP store operand cache: +{} hits / +{} misses this arm ({})",
        cache_after.hits - cache_before.hits,
        cache_after.misses - cache_before.misses,
        cache_after.summary()
    );
    table.row(vec![
        "booster+host-bfp-store(b64)".into(),
        fmt_pct(result.history.final_val_acc()),
        fmt_pct(result.history.best_val_acc()),
        boost_k.to_string(),
    ]);

    // Booster without edge-layer override (edge runs at 4 bits too).
    println!("[ablation] booster w/o edge layers ...");
    let hist = run_custom(engine, &v, &data, &cfg, "noedge", |epoch, _| {
        if epoch + boost_k >= cfg.epochs {
            (6.0, 6.0)
        } else {
            (4.0, 4.0)
        }
    })?;
    table.row(vec![
        "booster-no-edge-override".into(),
        fmt_pct(hist.final_val_acc()),
        fmt_pct(hist.best_val_acc()),
        boost_k.to_string(),
    ]);

    table.write_csv(&results_dir().join(format!("ablation_{model}.csv")))?;
    Ok(table)
}
