//! Table 2 — Accuracy Boosters (last-1 and last-10 epochs) vs FP32 and
//! the HBFP4 / HBFP4+Layers ablations (Fig 2's configurations), at the
//! paper's sweet-spot block size 64.

use crate::config::PrecisionPolicy;
use crate::coordinator::TrainerData;
use crate::experiments::common::{config_for, run_one, Preset};
use crate::metrics::RunHistory;
use crate::report::{fmt_pct, results_dir, Table};
use crate::runtime::Engine;
use anyhow::Result;
use std::path::Path;

/// The Table-2 policy ladder (incl. the Fig-2 ablation rungs).
pub fn policies(total_epochs: usize) -> Vec<PrecisionPolicy> {
    vec![
        PrecisionPolicy::Fp32,
        PrecisionPolicy::Hbfp { bits: 6 },
        PrecisionPolicy::Hbfp { bits: 4 },
        PrecisionPolicy::HbfpLayers { mid: 4, edge: 6 },
        PrecisionPolicy::booster(1),
        PrecisionPolicy::Booster {
            low: 4,
            high: 6,
            boost_epochs: (total_epochs / 8).max(2), // the "last 10 of 160" analogue
        },
    ]
}

pub struct Table2Output {
    pub table: Table,
    pub histories: Vec<RunHistory>,
}

pub fn run(engine: &Engine, artifacts: &Path, model: &str, preset: Preset) -> Result<Table2Output> {
    let v = engine.load_variant_by_name(artifacts, &format!("{model}_bs64"))?;
    let cfg0 = config_for(&v, PrecisionPolicy::Fp32, preset);
    let data = TrainerData::for_variant(&v, &cfg0)?;
    let mut table = Table::new(
        &format!("Table 2 — Accuracy Boosters, {model} @ block 64"),
        &["policy", "final_val_acc", "best_val_acc", "final_val_loss"],
    );
    let mut histories = Vec::new();
    for policy in policies(cfg0.epochs) {
        let cfg = config_for(&v, policy.clone(), preset);
        println!("[table2] {model} {} ...", policy.label());
        let (acc, hist, _) = run_one(engine, &v, &data, cfg, false)?;
        table.row(vec![
            policy.label(),
            fmt_pct(acc),
            fmt_pct(hist.best_val_acc()),
            format!("{:.4}", hist.final_val_loss()),
        ]);
        hist.write_csv(&results_dir().join(format!(
            "fig3_curve_{model}_{}.csv",
            policy.label().replace(['+', '(', ')'], "_")
        )))?;
        histories.push(hist);
    }
    table.write_csv(&results_dir().join(format!("table2_{model}.csv")))?;
    Ok(Table2Output { table, histories })
}
