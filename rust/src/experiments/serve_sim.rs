//! `repro serve-sim` — synthetic serving workload over the execution
//! service.
//!
//! Replays a deterministic stream of mixed-size GEMM requests (random
//! activation heights against a fixed working set of weight matrices in
//! several HBFP formats) in one of two modes:
//!
//! * **sync** (`ServeMode::Sync`, the PR-2 shape): requests are chunked
//!   into fixed batches and pushed through the blocking [`BatchGemm`]
//!   facade, batch by batch — closed-loop, batch-attributed latency.
//! * **async** (`ServeMode::Async`, `--async`): an **open-loop** client
//!   submits through [`BfpService::submit`] at Poisson arrival times
//!   (`offered_rps`), each request carrying a deadline; the service's
//!   admission loop forms deadline-aware batches on its own thread.
//!   Reported: achieved throughput, per-request p50/p95/p99 latency,
//!   deadline-miss rate, admission rejections (queue full = shed load),
//!   and queue-depth high-water mark.
//!
//! With `verify` on (the `quick` preset default, used by the CI smoke
//! steps), a sample of responses is checked **bit-for-bit** against the
//! scalar reference [`hbfp_gemm_scalar`], so the smoke run doubles as
//! an end-to-end integration check of queue + scheduler thread + pool +
//! cache — including the invariant that asynchronous admission reorders
//! execution but never numerics.
//!
//! With a JSON sink configured (`--json PATH` or `REPRO_BENCH_JSON`),
//! the metrics are additionally written as a `BENCH_serve.json`-style
//! artifact for the bench trajectory.
//!
//! A third drive mode, `--registry DIR` ([`run_registry`]), benchmarks
//! the content-addressed encoded-weight registry: it pushes several
//! synthetic "epochs" of the weight working set (perturbing a subset of
//! layers per epoch, so cross-epoch dedup is observable), then times a
//! **cold** start (fresh encode of every weight) against a **warm**
//! start (mmap-loading the final manifest's already-encoded planes into
//! a fresh operand cache). The warm path must perform **zero** weight
//! encodes and load planes **bit-identical** to a fresh encode — both
//! are hard assertions, and both land in `BENCH_registry.json`.

use crate::bfp::{hbfp_gemm_scalar, BfpMatrix, BlockFormat, KernelOpCounts, Mat, Quantizer};
use crate::exec::{
    AdmissionError, BatchGemm, BfpService, CacheStats, ExecRuntime, GemmRequest, OwnedGemmOp,
    Priority, ServiceConfig, ServiceStats,
};
use crate::fabric::{fetch_metrics, FabricRouter, FabricStats, RouterConfig};
use crate::registry::{PushLayer, Registry};
use crate::report::Table;
use crate::util::{Json, Rng, Stopwatch};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::io::BufRead;
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::Duration;

/// Submission discipline of the simulated client (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    Sync,
    Async,
}

impl ServeMode {
    fn label(self) -> &'static str {
        match self {
            ServeMode::Sync => "sync (blocking BatchGemm facade)",
            ServeMode::Async => "async (BfpService open loop)",
        }
    }

    fn json_tag(self) -> &'static str {
        match self {
            ServeMode::Sync => "sync",
            ServeMode::Async => "async",
        }
    }
}

/// Workload shape knobs (CLI flags override the preset values).
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Total requests in the stream.
    pub requests: usize,
    /// Requests per `BatchGemm` submission (sync mode only; the async
    /// admission loop forms its own batches).
    pub batch: usize,
    /// Distinct weight matrices in the working set.
    pub weights: usize,
    /// Cross-check a sample of responses against the scalar reference.
    pub verify: bool,
    pub seed: u64,
    pub mode: ServeMode,
    /// Async mode: Poisson arrival rate (req/s); 0 submits the whole
    /// stream as fast as admission allows.
    pub offered_rps: f64,
    /// Async mode: per-request deadline.
    pub deadline_ms: Option<f64>,
    /// Weight-pick skew (`--weight-reuse R`). `0.0` keeps the uniform
    /// pick (bit-identical request stream to every earlier artifact);
    /// `R > 0` skews picks Zipf-ishly toward low-index weights
    /// (`u^(1+R)` scaled over the working set), concentrating traffic
    /// on a few hot weights so weight-stationary grouping has material
    /// same-digest runs to work with.
    pub weight_reuse: f64,
    /// Write the metrics as a JSON artifact (`BENCH_serve.json`).
    pub json: Option<PathBuf>,
}

impl ServeSimConfig {
    pub fn quick() -> Self {
        Self {
            requests: 96,
            batch: 16,
            weights: 6,
            verify: true,
            seed: 42,
            mode: ServeMode::Sync,
            offered_rps: 2000.0,
            deadline_ms: Some(25.0),
            weight_reuse: 0.0,
            json: None,
        }
    }

    pub fn full() -> Self {
        Self {
            requests: 512,
            batch: 32,
            weights: 12,
            verify: false,
            seed: 42,
            mode: ServeMode::Sync,
            offered_rps: 4000.0,
            deadline_ms: Some(25.0),
            weight_reuse: 0.0,
            json: None,
        }
    }
}

/// Result summary (the table is the printable form).
pub struct ServeSimReport {
    pub table: Table,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub requests_per_s: f64,
    /// Requests that received a response (async admission may shed).
    pub completed: usize,
    /// Requests turned away with `AdmissionError::QueueFull`.
    pub rejected: u64,
    /// Deadline misses / completed (0.0 when no deadline was set).
    pub deadline_miss_rate: f64,
    /// Admission-queue high-water mark (async mode; 0 in sync mode).
    pub peak_queue_depth: usize,
    pub cache: CacheStats,
    json: Json,
}

impl ServeSimReport {
    /// Machine-readable form (what the `--json` sink writes).
    pub fn to_json(&self) -> &Json {
        &self.json
    }
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let pos = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[pos]
}

struct Request {
    wi: usize,
    x: Arc<Mat>,
}

/// Per-request stage-latency samples (async mode only — the sync
/// facade has no admission queue and no stage attribution). Stage
/// times are batch-attributed: every request reports the wall time of
/// the batch that carried it through each pipeline stage.
#[derive(Default)]
struct StageSamples {
    queue_ms: Vec<f64>,
    encode_ms: Vec<f64>,
    gemm_ms: Vec<f64>,
    decode_ms: Vec<f64>,
}

/// p50/p95/p99 of one stage's samples.
fn stage_pcts(samples: &[f64]) -> (f64, f64, f64) {
    let mut v = samples.to_vec();
    v.sort_by(f64::total_cmp);
    (
        percentile(&v, 0.50),
        percentile(&v, 0.95),
        percentile(&v, 0.99),
    )
}

/// Outcome of driving the request stream in either mode.
struct DriveOutcome {
    /// Per-request latency (ms) for every completed request.
    lat_ms: Vec<f64>,
    /// `results[i]` is request `i`'s response (None when shed).
    results: Vec<Option<Mat>>,
    wall_s: f64,
    rejected: u64,
    misses: u64,
    service: Option<ServiceStats>,
    /// Per-stage latency samples (async mode; `None` in sync mode).
    stages: Option<StageSamples>,
    /// Which backend **actually executed** each op, per M×N×K bucket —
    /// recorded at dispatch, not inferred from the configured choice
    /// (a forced backend can still degrade per op).
    kernel_ops: KernelOpCounts,
}

/// Deterministic weight working set + request stream shared by every
/// drive mode (sync facade, async service, fabric fleet): same seed,
/// same workload, comparable numbers.
#[allow(clippy::type_complexity)]
fn build_workload(
    cfg: &ServeSimConfig,
) -> Result<(Vec<(Arc<Mat>, BlockFormat)>, Vec<Request>, Rng)> {
    // (K, n) shapes and formats of the weight working set — mixed block
    // sizes and mantissa widths, all on the paper's parameter grid.
    let shapes = [
        (64usize, 48usize),
        (128, 96),
        (192, 64),
        (256, 128),
        (96, 192),
        (320, 64),
    ];
    let fmts = [
        BlockFormat::new(4, 64)?,
        BlockFormat::new(6, 64)?,
        BlockFormat::new(4, 16)?,
    ];
    let mut rng = Rng::new(cfg.seed);
    let mut weights: Vec<(Arc<Mat>, BlockFormat)> = Vec::with_capacity(cfg.weights);
    for i in 0..cfg.weights {
        let (k, n) = shapes[i % shapes.len()];
        let data = randn(&mut rng, k * n);
        weights.push((Arc::new(Mat::new(k, n, data)?), fmts[i % fmts.len()]));
    }
    // Request stream: random weight pick, random activation height.
    // With `weight_reuse == 0.0` the pick stays the exact historical
    // `rng.below` call (artifact streams are bit-identical to every
    // prior version); with R > 0 it skews Zipf-ishly toward low-index
    // weights, concentrating traffic on a few hot weights.
    let mut requests: Vec<Request> = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let wi = if cfg.weight_reuse > 0.0 {
            let u = rng.uniform().powf(1.0 + cfg.weight_reuse);
            ((u * weights.len() as f64) as usize).min(weights.len() - 1)
        } else {
            rng.below(weights.len())
        };
        let k = weights[wi].0.rows;
        let m = 1 + rng.below(48);
        let data = randn(&mut rng, m * k);
        requests.push(Request {
            wi,
            x: Arc::new(Mat::new(m, k, data)?),
        });
    }
    Ok((weights, requests, rng))
}

/// Bit-identity spot check against the scalar reference: first, middle,
/// and last request of the stream (shed requests are skipped; at least
/// one sample must have completed).
fn verify_sample(
    requests: &[Request],
    weights: &[(Arc<Mat>, BlockFormat)],
    results: &[Option<Mat>],
) -> Result<()> {
    let n = requests.len();
    let mut verified = 0usize;
    for &idx in &[0, n / 2, n - 1] {
        let Some(got) = &results[idx] else {
            continue; // shed by admission control; nothing to check
        };
        let r = &requests[idx];
        let want = hbfp_gemm_scalar(&r.x, &weights[r.wi].0, weights[r.wi].1)?;
        ensure!(
            got.data.len() == want.data.len(),
            "request {idx}: shape drift vs scalar reference"
        );
        for (g, w) in got.data.iter().zip(&want.data) {
            ensure!(
                g.to_bits() == w.to_bits(),
                "request {idx}: response diverged from hbfp_gemm_scalar"
            );
        }
        verified += 1;
    }
    ensure!(verified > 0, "verification sample was entirely shed");
    Ok(())
}

/// Run the simulation on `rt` (normally [`crate::exec::global_arc`]).
pub fn run(rt: &Arc<ExecRuntime>, cfg: &ServeSimConfig) -> Result<ServeSimReport> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.weights > 0, "need at least one weight matrix");
    let (weights, requests, mut rng) = build_workload(cfg)?;

    let cache_before = rt.cache_stats();
    let outcome = match cfg.mode {
        ServeMode::Sync => drive_sync(rt, cfg, &requests, &weights)?,
        ServeMode::Async => drive_async(rt, cfg, &mut rng, &requests, &weights)?,
    };

    if cfg.verify {
        verify_sample(&requests, &weights, &outcome.results)?;
    }

    let total_macs: f64 = requests
        .iter()
        .zip(&outcome.results)
        .filter(|(_, out)| out.is_some())
        .map(|(r, _)| {
            let w = &weights[r.wi].0;
            (r.x.rows * w.cols * w.rows) as f64
        })
        .sum();
    let mut sorted = outcome.lat_ms.clone();
    sorted.sort_by(f64::total_cmp);
    let (p50, p95, p99) = (
        percentile(&sorted, 0.50),
        percentile(&sorted, 0.95),
        percentile(&sorted, 0.99),
    );
    let cache_after = rt.cache_stats();
    let completed = outcome.lat_ms.len();
    let wall_s = outcome.wall_s.max(1e-9);
    let rps = completed as f64 / wall_s;
    let miss_rate = if completed == 0 {
        0.0
    } else {
        outcome.misses as f64 / completed as f64
    };
    let peak_depth = outcome.service.map(|s| s.peak_queue_depth).unwrap_or(0);

    let mut table = Table::new(
        "serve-sim — BFP GEMM serving emulation over the execution service",
        &["metric", "value"],
    );
    let mut kv = |k: &str, v: String| {
        table.row(vec![k.to_string(), v]);
    };
    kv("mode", cfg.mode.label().to_string());
    kv("requests", cfg.requests.to_string());
    match cfg.mode {
        ServeMode::Sync => kv("batch size", cfg.batch.to_string()),
        ServeMode::Async => {
            kv(
                "offered load (req/s)",
                if cfg.offered_rps > 0.0 {
                    format!("{:.0} (Poisson)", cfg.offered_rps)
                } else {
                    "unpaced".to_string()
                },
            );
            kv(
                "deadline (ms)",
                cfg.deadline_ms
                    .map(|d| format!("{d:.1}"))
                    .unwrap_or_else(|| "none".to_string()),
            );
        }
    }
    kv("weight working set", cfg.weights.to_string());
    kv("pool threads", rt.pool().threads().to_string());
    kv(
        "gemm kernel",
        crate::bfp::kernels::registry().preferred().name().to_string(),
    );
    let executed: Vec<String> = outcome
        .kernel_ops
        .entries()
        .into_iter()
        .map(|(k, b, n)| format!("{k}/{b}: {n}"))
        .collect();
    kv(
        "kernel ops (executed)",
        if executed.is_empty() {
            "none".to_string()
        } else {
            executed.join(", ")
        },
    );
    kv("completed", completed.to_string());
    kv("rejected (queue full)", outcome.rejected.to_string());
    kv("total MACs (completed)", format!("{total_macs:.3e}"));
    kv("wall time (s)", format!("{wall_s:.3}"));
    kv("achieved throughput (req/s)", format!("{rps:.1}"));
    kv(
        "throughput (MMAC/s)",
        format!("{:.1}", total_macs / wall_s / 1e6),
    );
    kv("latency p50 (ms)", format!("{p50:.3}"));
    kv("latency p95 (ms)", format!("{p95:.3}"));
    kv("latency p99 (ms)", format!("{p99:.3}"));
    kv("deadline-miss rate", format!("{:.3}", miss_rate));
    if let Some(s) = &outcome.service {
        kv("queue depth (peak)", s.peak_queue_depth.to_string());
        kv("execution batches", s.batches.to_string());
        kv(
            "effective batch MACs (last)",
            format!("{:.3e}", s.effective_batch_macs as f64),
        );
        kv(
            "pre-encoded ops (pipeline)",
            format!(
                "{} ({:.0}% hit rate)",
                s.pre_encoded,
                100.0 * s.pre_encode_hit_rate()
            ),
        );
        kv("inline-encoded ops", s.inline_encoded.to_string());
        kv(
            "encode stage (ms total)",
            format!("{:.3}", s.encode_us as f64 / 1e3),
        );
        kv(
            "decode stage ops",
            format!(
                "{} ({} overlapped a later batch)",
                s.decode_ops, s.decoded_overlapped
            ),
        );
        kv(
            "decode stage (ms total)",
            format!("{:.3}", s.decode_us as f64 / 1e3),
        );
        kv(
            "grouped ops (weight-stationary)",
            format!(
                "{} grouped / {} ungrouped in {} groups",
                s.grouped_ops, s.ungrouped_ops, s.groups_formed
            ),
        );
        kv(
            "weight-plane loads avoided (KiB)",
            (s.weight_plane_loads_avoided >> 10).to_string(),
        );
        kv(
            "arena checkouts",
            format!(
                "{} hits / {} misses ({:.0}% hit rate)",
                s.arena_hits,
                s.arena_misses,
                100.0 * s.arena_hit_rate()
            ),
        );
        kv(
            "arena recycled / resident (KiB)",
            format!(
                "{} / {}",
                s.arena_recycled_bytes >> 10,
                s.arena_resident_bytes >> 10
            ),
        );
    }
    if let Some(st) = &outcome.stages {
        // Per-percentile latency breakdown: where a request's time went
        // (queue wait vs each pipeline stage). Stage times are
        // batch-attributed, so the columns need not sum to the total
        // latency percentile — they answer "which stage dominates at
        // this percentile", not "what did request X pay".
        let rows = [
            ("queue wait", &st.queue_ms),
            ("encode stage", &st.encode_ms),
            ("gemm stage", &st.gemm_ms),
            ("decode stage", &st.decode_ms),
        ];
        for (name, samples) in rows {
            let (q50, q95, q99) = stage_pcts(samples);
            kv(
                &format!("breakdown {name} p50/p95/p99 (ms)"),
                format!("{q50:.3} / {q95:.3} / {q99:.3}"),
            );
        }
    }
    kv(
        "cache hits (this run)",
        (cache_after.hits - cache_before.hits).to_string(),
    );
    kv(
        "cache misses (this run)",
        (cache_after.misses - cache_before.misses).to_string(),
    );
    kv("cache", cache_after.summary());
    kv(
        "verified vs scalar",
        if cfg.verify { "yes (bit-exact sample)" } else { "no" }.to_string(),
    );

    let reg = crate::bfp::kernels::registry();
    let (cache_entries_cap, cache_bytes_cap) = rt.cache().caps();
    // The env-resolved budget, independent of which runtime ran the
    // sim: with `BOOSTERS_CACHE_MB`/`_ENTRIES` unset these are the
    // compiled-in defaults, so the artifact always records the caps a
    // reproducer would actually get instead of omitting them.
    let (budget_entries, budget_bytes) = crate::util::cache_budget();
    // Service-stat fields are Null in sync mode (no admission loop, no
    // pre-encode stage) — one projection helper instead of a copy of
    // the map/unwrap dance per field.
    let svc_num = |f: fn(&ServiceStats) -> f64| {
        outcome
            .service
            .as_ref()
            .map(|s| Json::Num(f(s)))
            .unwrap_or(Json::Null)
    };
    // Per-percentile stage breakdown (Null in sync mode, like the
    // service counters): one object per stage with its latency
    // percentiles over completed requests.
    let breakdown = outcome
        .stages
        .as_ref()
        .map(|st| {
            let stage = |samples: &[f64]| {
                let (p50, p95, p99) = stage_pcts(samples);
                Json::obj(vec![
                    ("p50_ms", Json::Num(p50)),
                    ("p95_ms", Json::Num(p95)),
                    ("p99_ms", Json::Num(p99)),
                ])
            };
            Json::obj(vec![
                ("queue", stage(&st.queue_ms)),
                ("encode", stage(&st.encode_ms)),
                ("gemm", stage(&st.gemm_ms)),
                ("decode", stage(&st.decode_ms)),
            ])
        })
        .unwrap_or(Json::Null);
    let json = Json::obj(vec![
        ("suite", Json::str("serve_sim")),
        ("mode", Json::str(cfg.mode.json_tag())),
        // Self-describing run environment: which kernel backend,
        // thread budget, and cache caps produced these numbers, so
        // BENCH_serve.json trajectories compare like for like.
        ("kernel", Json::str(reg.preferred().name())),
        ("kernel_choice", Json::str(reg.choice().label())),
        // Ground truth next to the configured identity above: which
        // backend each op actually dispatched to, per M×N×K bucket.
        (
            "kernel_ops",
            Json::arr(outcome.kernel_ops.entries().into_iter().map(
                |(kernel, bucket, ops)| {
                    Json::obj(vec![
                        ("kernel", Json::str(kernel)),
                        ("bucket", Json::str(bucket)),
                        ("ops", Json::num(ops as f64)),
                    ])
                },
            )),
        ),
        (
            "thread_budget",
            Json::Num(crate::util::gemm_thread_budget() as f64),
        ),
        ("cache_entries_cap", Json::Num(cache_entries_cap as f64)),
        (
            "cache_mb_cap",
            Json::Num((cache_bytes_cap >> 20) as f64),
        ),
        ("cache_budget_entries", Json::Num(budget_entries as f64)),
        (
            "cache_budget_mb",
            Json::Num((budget_bytes >> 20) as f64),
        ),
        (
            "effective_batch_macs",
            svc_num(|s| s.effective_batch_macs as f64),
        ),
        // Encode-pipeline counters (async mode only).
        ("pre_encoded_ops", svc_num(|s| s.pre_encoded as f64)),
        ("inline_encoded_ops", svc_num(|s| s.inline_encoded as f64)),
        ("pre_encode_hit_rate", svc_num(ServiceStats::pre_encode_hit_rate)),
        ("encode_stage_ms", svc_num(|s| s.encode_us as f64 / 1e3)),
        // Decode-stage and buffer-arena counters (async mode only).
        ("decode_ops", svc_num(|s| s.decode_ops as f64)),
        ("decoded_overlapped", svc_num(|s| s.decoded_overlapped as f64)),
        ("decode_stage_ms", svc_num(|s| s.decode_us as f64 / 1e3)),
        // Weight-stationary grouping counters (async mode only):
        // grouped_ops + ungrouped_ops == completed, always.
        ("grouped_ops", svc_num(|s| s.grouped_ops as f64)),
        ("ungrouped_ops", svc_num(|s| s.ungrouped_ops as f64)),
        ("groups_formed", svc_num(|s| s.groups_formed as f64)),
        (
            "weight_plane_loads_avoided_bytes",
            svc_num(|s| s.weight_plane_loads_avoided as f64),
        ),
        ("weight_reuse", Json::Num(cfg.weight_reuse)),
        ("arena_hits", svc_num(|s| s.arena_hits as f64)),
        ("arena_misses", svc_num(|s| s.arena_misses as f64)),
        (
            "arena_recycled_bytes",
            svc_num(|s| s.arena_recycled_bytes as f64),
        ),
        (
            "arena_resident_bytes",
            svc_num(|s| s.arena_resident_bytes as f64),
        ),
        ("arena_hit_rate", svc_num(ServiceStats::arena_hit_rate)),
        ("breakdown", breakdown),
        ("requests", Json::Num(cfg.requests as f64)),
        ("completed", Json::Num(completed as f64)),
        ("rejected", Json::Num(outcome.rejected as f64)),
        (
            "offered_rps",
            if cfg.mode == ServeMode::Async {
                Json::Num(cfg.offered_rps)
            } else {
                Json::Null
            },
        ),
        (
            "deadline_ms",
            match (cfg.mode, cfg.deadline_ms) {
                (ServeMode::Async, Some(d)) => Json::Num(d),
                _ => Json::Null,
            },
        ),
        ("wall_s", Json::Num(wall_s)),
        ("throughput_rps", Json::Num(rps)),
        ("throughput_mmacs", Json::Num(total_macs / wall_s / 1e6)),
        ("p50_ms", Json::Num(p50)),
        ("p95_ms", Json::Num(p95)),
        ("p99_ms", Json::Num(p99)),
        ("deadline_miss_rate", Json::Num(miss_rate)),
        ("peak_queue_depth", Json::Num(peak_depth as f64)),
        ("pool_threads", Json::Num(rt.pool().threads() as f64)),
        (
            "cache",
            Json::obj(vec![
                (
                    "hits",
                    Json::Num((cache_after.hits - cache_before.hits) as f64),
                ),
                (
                    "misses",
                    Json::Num((cache_after.misses - cache_before.misses) as f64),
                ),
                (
                    "evictions",
                    Json::Num((cache_after.evictions - cache_before.evictions) as f64),
                ),
            ]),
        ),
        ("verified", Json::Bool(cfg.verify)),
    ]);
    if let Some(path) = &cfg.json {
        let mut text = json.render();
        text.push('\n');
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
        println!("wrote serve-sim JSON artifact to {}", path.display());
    }

    Ok(ServeSimReport {
        table,
        p50_ms: p50,
        p95_ms: p95,
        p99_ms: p99,
        requests_per_s: rps,
        completed,
        rejected: outcome.rejected,
        deadline_miss_rate: miss_rate,
        peak_queue_depth: peak_depth,
        cache: cache_after,
        json,
    })
}

/// Closed-loop batch-by-batch submission through the blocking facade
/// (the PR-2 shape, kept as the baseline comparator).
fn drive_sync(
    rt: &Arc<ExecRuntime>,
    cfg: &ServeSimConfig,
    requests: &[Request],
    weights: &[(Arc<Mat>, BlockFormat)],
) -> Result<DriveOutcome> {
    let mut lat_ms: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut results: Vec<Option<Mat>> = Vec::with_capacity(cfg.requests);
    let mut kernel_ops = KernelOpCounts::default();
    let sw_all = Stopwatch::start();
    for chunk in requests.chunks(cfg.batch.max(1)) {
        let ops: Vec<OwnedGemmOp> = chunk
            .iter()
            .map(|r| {
                OwnedGemmOp::new(
                    Arc::clone(&r.x),
                    Arc::clone(&weights[r.wi].0),
                    weights[r.wi].1,
                )
            })
            .collect::<Result<_>>()?;
        let sw = Stopwatch::start();
        let (outs, report) = BatchGemm::new(rt).run_with_stats(&ops)?;
        let ms = sw.ms();
        kernel_ops.merge(&report.kernel_ops);
        for _ in chunk {
            lat_ms.push(ms);
        }
        results.extend(outs.into_iter().map(Some));
    }
    Ok(DriveOutcome {
        lat_ms,
        results,
        wall_s: sw_all.secs(),
        rejected: 0,
        misses: 0,
        service: None,
        stages: None,
        kernel_ops,
    })
}

/// Open-loop submission through a private [`BfpService`] on `rt`:
/// Poisson arrivals, non-blocking admission (queue-full responses are
/// shed and counted), per-request deadlines, ticket-measured latency.
fn drive_async(
    rt: &Arc<ExecRuntime>,
    cfg: &ServeSimConfig,
    rng: &mut Rng,
    requests: &[Request],
    weights: &[(Arc<Mat>, BlockFormat)],
) -> Result<DriveOutcome> {
    let service = BfpService::new(Arc::clone(rt), ServiceConfig::default());
    // Poisson process: exponential inter-arrival gaps at the offered
    // rate, fixed up front so submission jitter cannot skew the plan.
    let mut arrivals_s: Vec<f64> = Vec::with_capacity(requests.len());
    let mut t = 0.0f64;
    for _ in 0..requests.len() {
        if cfg.offered_rps > 0.0 {
            let u = rng.uniform().max(f64::MIN_POSITIVE);
            t += -u.ln() / cfg.offered_rps;
        }
        arrivals_s.push(t);
    }

    let deadline = cfg
        .deadline_ms
        .map(|ms| Duration::from_secs_f64((ms / 1e3).max(0.0)));
    let mut tickets: Vec<(usize, crate::exec::Ticket)> = Vec::with_capacity(requests.len());
    let mut rejected = 0u64;
    let sw_all = Stopwatch::start();
    for (i, r) in requests.iter().enumerate() {
        if cfg.offered_rps > 0.0 {
            let now = sw_all.secs();
            if arrivals_s[i] > now {
                std::thread::sleep(Duration::from_secs_f64(arrivals_s[i] - now));
            }
        }
        let op = OwnedGemmOp::new(
            Arc::clone(&r.x),
            Arc::clone(&weights[r.wi].0),
            weights[r.wi].1,
        )?;
        let mut req = GemmRequest::new(op).with_priority(Priority::Interactive);
        if let Some(d) = deadline {
            req = req.with_deadline(d);
        }
        match service.submit(req) {
            Ok(ticket) => tickets.push((i, ticket)),
            Err(AdmissionError::QueueFull { .. }) => rejected += 1,
            Err(e) => return Err(anyhow::Error::new(e).context("async submission")),
        }
    }

    let mut lat_ms: Vec<f64> = Vec::with_capacity(tickets.len());
    let mut results: Vec<Option<Mat>> = (0..requests.len()).map(|_| None).collect();
    let mut misses = 0u64;
    let mut stages = StageSamples::default();
    for (i, ticket) in tickets {
        let resp = ticket
            .wait()
            .with_context(|| format!("request {i} failed in the service"))?;
        lat_ms.push(resp.total_ms);
        stages.queue_ms.push(resp.queue_ms);
        stages.encode_ms.push(resp.encode_ms);
        stages.gemm_ms.push(resp.gemm_ms);
        stages.decode_ms.push(resp.decode_ms);
        if resp.deadline_missed {
            misses += 1;
        }
        results[i] = Some(resp.out);
    }
    let wall_s = sw_all.secs();
    let stats = service.stats();
    drop(service);
    Ok(DriveOutcome {
        lat_ms,
        results,
        wall_s,
        rejected,
        misses,
        service: Some(stats),
        stages: Some(stages),
        kernel_ops: stats.kernel_ops,
    })
}

// ---------------------------------------------------------------------------
// Fabric drive mode (`repro serve-sim --fabric N`)
// ---------------------------------------------------------------------------

/// One spawned local `repro fabric-runner` child.
struct RunnerProc {
    child: Child,
    addr: String,
}

/// Spawn `repro fabric-runner --listen 127.0.0.1:0` as a child process
/// and parse the announced ephemeral address off its first stdout line.
fn spawn_runner() -> Result<RunnerProc> {
    let exe = std::env::current_exe().context("resolving the repro binary path")?;
    let mut child = Command::new(&exe)
        .args(["fabric-runner", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit())
        .spawn()
        .with_context(|| format!("spawning fabric runner via {}", exe.display()))?;
    let stdout = child.stdout.take().context("runner stdout was not piped")?;
    let mut line = String::new();
    let read = std::io::BufReader::new(stdout).read_line(&mut line);
    let addr = line
        .trim()
        .strip_prefix("fabric-runner listening on ")
        .map(str::to_string)
        .filter(|a| !a.is_empty());
    match (read, addr) {
        (Ok(_), Some(addr)) => Ok(RunnerProc { child, addr }),
        _ => {
            let _ = child.kill();
            let _ = child.wait();
            anyhow::bail!("fabric runner did not announce a listen address (got {line:?})")
        }
    }
}

/// Raw numbers out of one fabric drive.
struct FabricOutcome {
    lat_ms: Vec<f64>,
    results: Vec<Option<Mat>>,
    wall_s: f64,
    rejected: u64,
    failed: u64,
    misses: u64,
    stats: FabricStats,
    killed: bool,
    /// Lines of Prometheus text scraped from one surviving runner's
    /// socket (0 when the scrape failed — reported, not fatal).
    metrics_lines: usize,
}

/// Result summary of a fabric run (printable table + JSON artifact).
pub struct FabricSimReport {
    pub table: Table,
    pub completed: usize,
    pub rejected: u64,
    pub failed: u64,
    pub failovers: u64,
    pub dedup_hits: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    json: Json,
}

impl FabricSimReport {
    /// Machine-readable form (what the `--json` sink writes).
    pub fn to_json(&self) -> &Json {
        &self.json
    }
}

/// `repro serve-sim --fabric N`: drive the standard request stream
/// through a [`FabricRouter`] over a fleet of runner processes.
///
/// With `connect` empty the fleet is `runners` local children spawned
/// from the current binary, and (when there are at least two) one of
/// them is **killed 60% through submission** to exercise failover under
/// load. With `connect` non-empty (the `BOOSTERS_FABRIC_CONNECT` path)
/// the run attaches to an existing external fleet instead — nothing is
/// spawned and nothing is killed.
pub fn run_fabric(
    rt: &Arc<ExecRuntime>,
    cfg: &ServeSimConfig,
    runners: usize,
    connect: &[String],
) -> Result<FabricSimReport> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.weights > 0, "need at least one weight matrix");
    ensure!(
        runners >= 1 || !connect.is_empty(),
        "need at least one fabric runner"
    );
    let (weights, requests, _rng) = build_workload(cfg)?;

    let mut procs: Vec<RunnerProc> = Vec::new();
    let addrs: Vec<String> = if connect.is_empty() {
        for _ in 0..runners {
            match spawn_runner() {
                Ok(p) => procs.push(p),
                Err(e) => {
                    for p in &mut procs {
                        let _ = p.child.kill();
                        let _ = p.child.wait();
                    }
                    return Err(e);
                }
            }
        }
        procs.iter().map(|p| p.addr.clone()).collect()
    } else {
        connect.to_vec()
    };
    let spawned = !procs.is_empty();

    // Children are reaped on every exit path; the drive itself never
    // early-returns past this point without coming back through here.
    let outcome = drive_fabric(rt, cfg, &requests, &weights, &addrs, &mut procs);
    for p in &mut procs {
        let _ = p.child.kill();
        let _ = p.child.wait();
    }
    let outcome = outcome?;

    if cfg.verify {
        verify_sample(&requests, &weights, &outcome.results)?;
    }

    let stats = &outcome.stats;
    let completed = outcome.lat_ms.len();
    let accepted = completed as u64 + outcome.failed;
    let alive_end = stats.runners.iter().filter(|r| r.alive).count();
    let mut sorted = outcome.lat_ms.clone();
    sorted.sort_by(f64::total_cmp);
    let (p50, p95, p99) = (
        percentile(&sorted, 0.50),
        percentile(&sorted, 0.95),
        percentile(&sorted, 0.99),
    );
    let wall_s = outcome.wall_s.max(1e-9);
    let rps = completed as f64 / wall_s;
    let miss_rate = if completed == 0 {
        0.0
    } else {
        outcome.misses as f64 / completed as f64
    };

    let mut table = Table::new(
        "serve-sim --fabric — BFP GEMM serving over the multi-node fabric",
        &["metric", "value"],
    );
    let mut kv = |k: &str, v: String| {
        table.row(vec![k.to_string(), v]);
    };
    kv(
        "fleet",
        if spawned {
            format!("{} spawned local runner process(es)", addrs.len())
        } else {
            format!("{} external runner(s)", addrs.len())
        },
    );
    kv("requests", cfg.requests.to_string());
    kv("weight working set", cfg.weights.to_string());
    kv("completed", completed.to_string());
    kv("rejected (backpressure)", outcome.rejected.to_string());
    kv("failed", outcome.failed.to_string());
    kv(
        "runner killed mid-run",
        if outcome.killed { "yes" } else { "no" }.to_string(),
    );
    kv(
        "runners alive at end",
        format!("{alive_end}/{}", addrs.len()),
    );
    kv("failovers (ops re-placed)", stats.failovers.to_string());
    kv("retries (incl. re-negotiation)", stats.retries.to_string());
    kv("digest probes", stats.probes.to_string());
    kv(
        "operand dedup",
        format!(
            "{} hits / {} misses ({:.0}% hit rate)",
            stats.dedup_hits,
            stats.dedup_misses,
            100.0 * stats.dedup_hit_rate()
        ),
    );
    kv(
        "plane bytes on wire / deduped",
        format!(
            "{} / {}",
            stats.plane_bytes_sent, stats.plane_bytes_deduped
        ),
    );
    for r in &stats.runners {
        kv(
            &format!("runner {}", r.addr),
            format!(
                "{} · queue {} (peak {}) · {} done · {} dedup hits · {} plane B",
                if r.alive { "alive" } else { "dead" },
                r.inflight,
                r.peak_inflight,
                r.completed,
                r.dedup_hits,
                r.plane_bytes_sent
            ),
        );
    }
    kv("wall time (s)", format!("{wall_s:.3}"));
    kv("achieved throughput (req/s)", format!("{rps:.1}"));
    kv("cross-node latency p50 (ms)", format!("{p50:.3}"));
    kv("cross-node latency p95 (ms)", format!("{p95:.3}"));
    kv("cross-node latency p99 (ms)", format!("{p99:.3}"));
    kv("deadline-miss rate", format!("{miss_rate:.3}"));
    kv(
        "runner metrics scrape",
        if outcome.metrics_lines > 0 {
            format!("{} lines of Prometheus text", outcome.metrics_lines)
        } else {
            "unavailable".to_string()
        },
    );
    kv(
        "verified vs scalar",
        if cfg.verify { "yes (bit-exact sample)" } else { "no" }.to_string(),
    );

    let json = Json::obj(vec![
        ("suite", Json::str("serve_fabric")),
        ("runners", Json::Num(addrs.len() as f64)),
        ("spawned", Json::Bool(spawned)),
        ("requests", Json::Num(cfg.requests as f64)),
        ("accepted", Json::Num(accepted as f64)),
        ("completed", Json::Num(completed as f64)),
        ("rejected", Json::Num(outcome.rejected as f64)),
        ("failed", Json::Num(outcome.failed as f64)),
        ("killed_runner", Json::Bool(outcome.killed)),
        ("alive_runners_end", Json::Num(alive_end as f64)),
        ("failovers", Json::Num(stats.failovers as f64)),
        ("retries", Json::Num(stats.retries as f64)),
        ("rejected_remote", Json::Num(stats.rejected_remote as f64)),
        ("probes", Json::Num(stats.probes as f64)),
        ("dedup_hits", Json::Num(stats.dedup_hits as f64)),
        ("dedup_misses", Json::Num(stats.dedup_misses as f64)),
        ("dedup_hit_rate", Json::Num(stats.dedup_hit_rate())),
        ("plane_bytes_sent", Json::Num(stats.plane_bytes_sent as f64)),
        (
            "plane_bytes_deduped",
            Json::Num(stats.plane_bytes_deduped as f64),
        ),
        ("wall_s", Json::Num(wall_s)),
        ("throughput_rps", Json::Num(rps)),
        ("p50_ms", Json::Num(p50)),
        ("p95_ms", Json::Num(p95)),
        ("p99_ms", Json::Num(p99)),
        ("deadline_miss_rate", Json::Num(miss_rate)),
        (
            "runner_metrics_lines",
            Json::Num(outcome.metrics_lines as f64),
        ),
        (
            "per_runner",
            Json::arr(stats.runners.iter().map(|r| {
                Json::obj(vec![
                    ("addr", Json::str(&r.addr)),
                    ("alive", Json::Bool(r.alive)),
                    ("inflight", Json::Num(r.inflight as f64)),
                    ("peak_inflight", Json::Num(r.peak_inflight as f64)),
                    ("completed", Json::Num(r.completed as f64)),
                    ("dedup_hits", Json::Num(r.dedup_hits as f64)),
                    ("plane_bytes_sent", Json::Num(r.plane_bytes_sent as f64)),
                ])
            })),
        ),
        ("verified", Json::Bool(cfg.verify)),
    ]);
    if let Some(path) = &cfg.json {
        let mut text = json.render();
        text.push('\n');
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
        println!("wrote fabric JSON artifact to {}", path.display());
    }

    Ok(FabricSimReport {
        table,
        completed,
        rejected: outcome.rejected,
        failed: outcome.failed,
        failovers: stats.failovers,
        dedup_hits: stats.dedup_hits,
        p50_ms: p50,
        p95_ms: p95,
        p99_ms: p99,
        json,
    })
}

/// Submit the stream through the router, killing one spawned runner 60%
/// of the way through when the fleet can survive it.
fn drive_fabric(
    rt: &Arc<ExecRuntime>,
    cfg: &ServeSimConfig,
    requests: &[Request],
    weights: &[(Arc<Mat>, BlockFormat)],
    addrs: &[String],
    procs: &mut [RunnerProc],
) -> Result<FabricOutcome> {
    let router = FabricRouter::connect(addrs, RouterConfig::default(), Arc::clone(rt))
        .context("connecting the fabric router")?;
    let deadline = cfg
        .deadline_ms
        .map(|ms| Duration::from_secs_f64((ms / 1e3).max(0.0)));
    // Only kill a runner we spawned, and only when survivors remain.
    let kill_at = if procs.len() >= 2 {
        (requests.len() * 3) / 5
    } else {
        usize::MAX
    };
    let mut killed = false;
    let mut tickets: Vec<(usize, crate::exec::Ticket)> = Vec::with_capacity(requests.len());
    let mut rejected = 0u64;
    let sw_all = Stopwatch::start();
    for (i, r) in requests.iter().enumerate() {
        if i == kill_at {
            // SIGKILL, not a polite shutdown: the router must notice the
            // dropped connection and re-place the accepted in-flight ops
            // on the survivors without any client-visible failure.
            let victim = procs.last_mut().expect("kill_at implies procs");
            victim.child.kill().context("killing a fabric runner")?;
            let _ = victim.child.wait();
            killed = true;
        }
        // Alternate QoS classes so both sharding paths run: deadline
        // ops route by slack × outstanding MACs, bulk ops round-robin.
        let (prio, dl) = if i % 2 == 0 {
            (Priority::Interactive, deadline)
        } else {
            (Priority::Bulk, None)
        };
        let (w, fmt) = (&weights[r.wi].0, weights[r.wi].1);
        match router.submit(Arc::clone(&r.x), Arc::clone(w), fmt, dl, prio) {
            Ok(t) => tickets.push((i, t)),
            Err(AdmissionError::QueueFull { .. }) => rejected += 1,
            Err(e) => return Err(anyhow::Error::new(e).context("fabric submission")),
        }
    }

    let mut lat_ms: Vec<f64> = Vec::with_capacity(tickets.len());
    let mut results: Vec<Option<Mat>> = (0..requests.len()).map(|_| None).collect();
    let mut misses = 0u64;
    let mut failed = 0u64;
    for (i, ticket) in tickets {
        match ticket.wait() {
            Ok(resp) => {
                lat_ms.push(resp.total_ms);
                if resp.deadline_missed {
                    misses += 1;
                }
                results[i] = Some(resp.out);
            }
            Err(e) => {
                // Accepted ops only fail when no runner survives — keep
                // the run alive so the report shows the loss.
                eprintln!("[serve-sim] fabric request {i} failed: {e:#}");
                failed += 1;
            }
        }
    }
    let wall_s = sw_all.secs();
    // Scrape one survivor's metrics socket end-to-end — the same text
    // `repro metrics --connect` prints.
    let metrics_lines = router
        .stats()
        .runners
        .iter()
        .find(|r| r.alive)
        .and_then(|r| fetch_metrics(&r.addr).ok())
        .map(|t| t.lines().count())
        .unwrap_or(0);
    let stats = router.stats();
    drop(router);
    Ok(FabricOutcome {
        lat_ms,
        results,
        wall_s,
        rejected,
        failed,
        misses,
        stats,
        killed,
        metrics_lines,
    })
}

// ---------------------------------------------------------------------------
// Registry drive mode (`repro serve-sim --registry DIR`)
// ---------------------------------------------------------------------------

/// Result summary of a registry cold-vs-warm run (printable table +
/// `BENCH_registry.json` artifact).
pub struct RegistrySimReport {
    pub table: Table,
    /// Blobs actually written across all pushed epochs.
    pub blobs_written: usize,
    /// Layer pushes satisfied by an existing blob (cross-epoch dedup).
    pub blobs_deduped: usize,
    /// `blobs_deduped / layers_pushed` — > 0 whenever epochs share
    /// unchanged layers.
    pub dedup_ratio: f64,
    /// Operand-cache encode misses during the warm start — the
    /// headline zero (asserted, then reported).
    pub weight_encodes_warm: u64,
    /// Requests completed by the warm-started runtime.
    pub completed: usize,
    json: Json,
}

impl RegistrySimReport {
    /// Machine-readable form (what the `--json` sink writes).
    pub fn to_json(&self) -> &Json {
        &self.json
    }
}

/// `repro serve-sim --registry DIR [--epochs N]`: push N synthetic
/// epochs of the standard weight working set into a registry at `dir`
/// (perturbing layer `i` in epoch `e > 0` when `i % 3 == e % 3`, so
/// most layers dedup against the previous epoch), then benchmark a
/// cold start (fresh serial encode of every final-epoch weight)
/// against a warm start (mmap-load the final manifest into a fresh
/// runtime's operand cache) and drive the standard request stream
/// through the warm runtime.
///
/// Hard assertions, not just reported numbers: the warm start performs
/// **zero** weight encodes (every weight's cache key is manifest-
/// covered) and every registry-loaded plane is **bit-identical** to a
/// fresh encode of the same f32 source.
pub fn run_registry(
    rt: &Arc<ExecRuntime>,
    cfg: &ServeSimConfig,
    dir: &Path,
    epochs: usize,
) -> Result<RegistrySimReport> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.weights > 0, "need at least one weight matrix");
    ensure!(epochs >= 1, "need at least one epoch to push");
    let (weights, requests, mut rng) = build_workload(cfg)?;
    let reg = Registry::open(dir)?;

    // Push the epoch sequence. `current` evolves like a training run:
    // each epoch re-randomizes a subset of layers and leaves the rest
    // untouched — the untouched ones must dedup by construction.
    let mut current = weights;
    let (mut layers_pushed, mut blobs_written, mut blobs_deduped) = (0usize, 0usize, 0usize);
    let (mut bytes_written, mut bytes_deduped) = (0u64, 0u64);
    let sw_push = Stopwatch::start();
    for e in 0..epochs {
        if e > 0 {
            for (i, (w, _)) in current.iter_mut().enumerate() {
                if i % 3 == e % 3 {
                    let (k, n) = (w.rows, w.cols);
                    *w = Arc::new(Mat::new(k, n, randn(&mut rng, k * n))?);
                }
            }
        }
        let names: Vec<String> = (0..current.len()).map(|i| format!("layer{i:02}")).collect();
        let layers: Vec<PushLayer<'_>> = current
            .iter()
            .zip(&names)
            .map(|((w, fmt), name)| PushLayer {
                name,
                weight: w,
                fmt: *fmt,
            })
            .collect();
        let mut meta = BTreeMap::new();
        meta.insert("epoch".to_string(), e.to_string());
        let (_, stats) = reg.push(&format!("epoch{e}"), &layers, &meta)?;
        layers_pushed += stats.layers;
        blobs_written += stats.blobs_written;
        blobs_deduped += stats.blobs_deduped;
        bytes_written += stats.bytes_written;
        bytes_deduped += stats.bytes_deduped;
    }
    let push_ms = sw_push.ms();
    let dedup_ratio = if layers_pushed == 0 {
        0.0
    } else {
        blobs_deduped as f64 / layers_pushed as f64
    };
    let (blob_count, blob_bytes) = reg.blob_stats()?;

    // Cold start: what a registry-less process pays — a fresh encode of
    // every final-epoch weight (the same serial path `push` used, so
    // the bit-identity check below compares like against like).
    let sw_cold = Stopwatch::start();
    let fresh: Vec<BfpMatrix> = current
        .iter()
        .map(|(w, fmt)| {
            BfpMatrix::encode_transposed(w, *fmt, Quantizer::nearest(fmt.mantissa_bits))
        })
        .collect::<Result<_>>()?;
    let cold_ms = sw_cold.ms();

    // Warm start: a fresh runtime (empty operand cache) fed straight
    // from the registry — never from f32, never through the encoder.
    let warm_rt = Arc::new(ExecRuntime::with_threads(rt.pool().threads()));
    let last = format!("epoch{}", epochs - 1);
    let sw_warm = Stopwatch::start();
    let warm = Registry::open(dir)?.warm_cache(&last, warm_rt.cache())?;
    let warm_ms = sw_warm.ms();

    // Touch every weight through the cached-encode front door and pin
    // the two contract halves: all hits (zero encodes), and planes
    // bit-identical to the fresh encodes (BfpMatrix derives Eq).
    for ((w, fmt), want) in current.iter().zip(&fresh) {
        let got = warm_rt.encode_transposed_cached(w, *fmt)?;
        ensure!(
            *got == *want,
            "registry-loaded planes for a {}x{} weight diverged from a fresh encode",
            w.rows,
            w.cols
        );
    }
    let warm_cache = warm_rt.cache_stats();
    let weight_encodes_warm = warm_cache.misses;
    ensure!(
        weight_encodes_warm == 0,
        "warm start performed {weight_encodes_warm} weight encode(s); \
         the manifest should cover the whole working set"
    );
    let warm_speedup = if warm_ms > 0.0 { cold_ms / warm_ms } else { 0.0 };

    // Drive the standard stream through the warm runtime — end-to-end
    // proof that registry-fed planes serve real traffic.
    let outcome = drive_sync(&warm_rt, cfg, &requests, &current)?;
    if cfg.verify {
        verify_sample(&requests, &current, &outcome.results)?;
    }
    let completed = outcome.lat_ms.len();

    let mut table = Table::new(
        "serve-sim --registry — encoded-weight registry cold vs warm start",
        &["metric", "value"],
    );
    let mut kv = |k: &str, v: String| {
        table.row(vec![k.to_string(), v]);
    };
    kv("registry", dir.display().to_string());
    kv("epochs pushed", epochs.to_string());
    kv("layers per epoch", current.len().to_string());
    kv(
        "blobs written / deduped",
        format!("{blobs_written} / {blobs_deduped} ({:.0}% dedup)", 100.0 * dedup_ratio),
    );
    kv(
        "blob bytes written / deduped",
        format!("{bytes_written} / {bytes_deduped}"),
    );
    kv("resident blobs (count / bytes)", format!("{blob_count} / {blob_bytes}"));
    kv("push wall (ms)", format!("{push_ms:.3}"));
    kv("cold start: fresh encodes (ms)", format!("{cold_ms:.3}"));
    kv(
        "warm start: registry load (ms)",
        format!("{warm_ms:.3} ({} planes, {} mmap-served)", warm.installed, warm.mapped_loads),
    );
    kv("warm speedup (cold/warm)", format!("{warm_speedup:.2}x"));
    kv("warm plane bytes installed", warm.plane_bytes.to_string());
    kv(
        "weight encodes during warm start",
        format!("{weight_encodes_warm} (asserted zero)"),
    );
    kv(
        "warm cache hits (working-set touch)",
        warm_cache.hits.to_string(),
    );
    kv("requests driven warm", format!("{completed}/{}", cfg.requests));
    kv(
        "verified vs scalar",
        if cfg.verify { "yes (bit-exact sample)" } else { "no" }.to_string(),
    );
    kv("bit-identity vs fresh encode", "yes (all layers)".to_string());

    let json = Json::obj(vec![
        ("suite", Json::str("serve_registry")),
        ("registry_dir", Json::str(dir.display().to_string())),
        ("epochs", Json::Num(epochs as f64)),
        ("layers_per_epoch", Json::Num(current.len() as f64)),
        ("layers_pushed", Json::Num(layers_pushed as f64)),
        ("blobs_written", Json::Num(blobs_written as f64)),
        ("blobs_deduped", Json::Num(blobs_deduped as f64)),
        ("dedup_ratio", Json::Num(dedup_ratio)),
        ("bytes_written", Json::Num(bytes_written as f64)),
        ("bytes_deduped", Json::Num(bytes_deduped as f64)),
        ("blob_count", Json::Num(blob_count as f64)),
        ("blob_bytes", Json::Num(blob_bytes as f64)),
        ("push_ms", Json::Num(push_ms)),
        ("cold_encode_ms", Json::Num(cold_ms)),
        ("warm_load_ms", Json::Num(warm_ms)),
        ("warm_speedup", Json::Num(warm_speedup)),
        ("warm_installed", Json::Num(warm.installed as f64)),
        ("warm_plane_bytes", Json::Num(warm.plane_bytes as f64)),
        ("mapped_loads", Json::Num(warm.mapped_loads as f64)),
        ("weight_encodes_warm", Json::Num(weight_encodes_warm as f64)),
        ("warm_cache_hits", Json::Num(warm_cache.hits as f64)),
        ("encode_ops_avoided", Json::Num(current.len() as f64)),
        ("requests", Json::Num(cfg.requests as f64)),
        ("completed", Json::Num(completed as f64)),
        ("verified", Json::Bool(true)),
    ]);
    if let Some(path) = &cfg.json {
        let mut text = json.render();
        text.push('\n');
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))?;
        println!("wrote registry JSON artifact to {}", path.display());
    }

    Ok(RegistrySimReport {
        table,
        blobs_written,
        blobs_deduped,
        dedup_ratio,
        weight_encodes_warm,
        completed,
        json,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_preset_runs_verified_and_hits_the_cache() {
        let rt = Arc::new(ExecRuntime::with_threads(2));
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 24;
        cfg.batch = 8;
        cfg.weights = 3;
        let report = run(&rt, &cfg).unwrap();
        // 24 requests over <= 3 distinct weights: one cache access per
        // request, misses only on first encounters — everything else
        // must be served from the operand cache.
        assert!(report.cache.misses <= 3, "{:?}", report.cache);
        assert!(report.cache.hits >= 21, "{:?}", report.cache);
        assert_eq!(
            report.cache.hits + report.cache.misses,
            24,
            "{:?}",
            report.cache
        );
        assert!(report.requests_per_s > 0.0);
        assert_eq!(report.completed, 24);
        assert_eq!(report.rejected, 0);
        assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
        assert_eq!(report.table.headers.len(), 2);
        assert_eq!(
            report.to_json().req("mode").unwrap().as_str().unwrap(),
            "sync"
        );
        // Sync mode has no pipeline stages: the service counters and
        // the stage breakdown project to Null, not zeros.
        assert!(matches!(
            report.to_json().req("decode_ops").unwrap(),
            Json::Null
        ));
        assert!(matches!(
            report.to_json().req("breakdown").unwrap(),
            Json::Null
        ));
        // The artifact records which kernel actually executed each op;
        // the per-bucket counts must cover the full completed stream
        // and name only registered backends.
        let entries = report.to_json().req("kernel_ops").unwrap().as_arr().unwrap().to_vec();
        let mut total = 0usize;
        for e in &entries {
            let kernel = e.req("kernel").unwrap().as_str().unwrap().to_string();
            assert!(
                crate::bfp::kernels::registry().by_name(&kernel).is_some(),
                "{kernel:?} must be a registered backend"
            );
            total += e.req("ops").unwrap().as_usize().unwrap();
        }
        assert_eq!(total, 24, "executed-kernel counts cover every op");
    }

    #[test]
    fn async_mode_verifies_and_accounts_deadlines() {
        let rt = Arc::new(ExecRuntime::with_threads(2));
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 24;
        cfg.weights = 3;
        cfg.mode = ServeMode::Async;
        cfg.offered_rps = 0.0; // unpaced: no sleeps in unit tests
        cfg.deadline_ms = Some(60_000.0); // generous: no misses expected
        let report = run(&rt, &cfg).unwrap();
        assert_eq!(report.completed + report.rejected as usize, 24);
        assert!(report.completed > 0);
        assert_eq!(report.deadline_miss_rate, 0.0, "60s deadlines cannot miss");
        assert!(report.peak_queue_depth >= 1);
        assert!(report.p50_ms <= report.p99_ms);
        let j = report.to_json();
        assert_eq!(j.req("mode").unwrap().as_str().unwrap(), "async");
        assert!(j.req("throughput_rps").unwrap().as_f64().unwrap() > 0.0);
        // Every completed op was either pre-encoded by the pipeline or
        // encoded inline at execution — the two counters partition the
        // completed stream exactly.
        let pre = j.req("pre_encoded_ops").unwrap().as_f64().unwrap();
        let inline = j.req("inline_encoded_ops").unwrap().as_f64().unwrap();
        assert_eq!(pre as usize + inline as usize, report.completed);
        assert!(j.req("encode_stage_ms").unwrap().as_f64().unwrap() >= 0.0);
        let rate = j.req("pre_encode_hit_rate").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&rate));
        // Executed-kernel accounting covers the completed stream in
        // async mode too (shed requests never execute, so they never
        // count).
        let total: usize = j
            .req("kernel_ops")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|e| e.req("ops").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(total, report.completed);
        // The decode stage fulfilled every completed request, and the
        // buffer-arena counters are live.
        let decode_ops = j.req("decode_ops").unwrap().as_usize().unwrap();
        assert_eq!(decode_ops, report.completed);
        // Grouping counters partition the completed stream exactly —
        // whatever the grouping threshold resolved to.
        let grouped = j.req("grouped_ops").unwrap().as_usize().unwrap();
        let ungrouped = j.req("ungrouped_ops").unwrap().as_usize().unwrap();
        assert_eq!(grouped + ungrouped, report.completed);
        assert!(j.req("weight_plane_loads_avoided_bytes").unwrap().as_f64().unwrap() >= 0.0);
        let overlapped = j.req("decoded_overlapped").unwrap().as_usize().unwrap();
        assert!(overlapped <= decode_ops);
        assert!(j.req("decode_stage_ms").unwrap().as_f64().unwrap() >= 0.0);
        let hits = j.req("arena_hits").unwrap().as_f64().unwrap();
        let arena_misses = j.req("arena_misses").unwrap().as_f64().unwrap();
        assert!(hits + arena_misses > 0.0, "arena saw no traffic");
        let arate = j.req("arena_hit_rate").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&arate));
        // The per-stage latency breakdown rides along with ordered
        // percentiles per stage.
        let bd = j.req("breakdown").unwrap();
        for stage in ["queue", "encode", "gemm", "decode"] {
            let s = bd.req(stage).unwrap();
            let p50 = s.req("p50_ms").unwrap().as_f64().unwrap();
            let p95 = s.req("p95_ms").unwrap().as_f64().unwrap();
            let p99 = s.req("p99_ms").unwrap().as_f64().unwrap();
            assert!(p50 >= 0.0 && p95 >= p50 && p99 >= p95, "{stage}");
        }
    }

    #[test]
    fn json_artifact_is_written_when_configured() {
        let rt = Arc::new(ExecRuntime::with_threads(1));
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 6;
        cfg.batch = 3;
        cfg.weights = 2;
        let dir = std::env::temp_dir().join("boosters_serve_sim_test");
        let path = dir.join("BENCH_serve.json");
        let _ = std::fs::remove_file(&path);
        cfg.json = Some(path.clone());
        run(&rt, &cfg).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.req("suite").unwrap().as_str().unwrap(), "serve_sim");
        assert_eq!(back.req("requests").unwrap().as_usize().unwrap(), 6);
        // The artifact is self-describing: kernel identity, thread
        // budget, and cache caps ride along with the numbers.
        let kernel = back.req("kernel").unwrap().as_str().unwrap().to_string();
        assert!(
            crate::bfp::kernels::registry().by_name(&kernel).is_some(),
            "{kernel:?} must be a registered backend"
        );
        assert!(back.req("thread_budget").unwrap().as_f64().unwrap() >= 1.0);
        assert!(back.req("cache_entries_cap").unwrap().as_f64().unwrap() >= 1.0);
        assert!(back.req("cache_mb_cap").unwrap().as_f64().unwrap() >= 1.0);
        // The env-resolved budget rides along even when the variables
        // are unset (it then records the compiled-in defaults), so the
        // artifact pins the caps a reproducer would get.
        assert!(back.req("cache_budget_entries").unwrap().as_f64().unwrap() >= 1.0);
        assert!(back.req("cache_budget_mb").unwrap().as_f64().unwrap() >= 1.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn registry_mode_dedups_and_warm_starts_with_zero_encodes() {
        let rt = Arc::new(ExecRuntime::with_threads(1));
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 12;
        cfg.weights = 4;
        let dir = std::env::temp_dir().join(format!(
            "boosters-serve-registry-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let json_path = dir.join("BENCH_registry.json");
        cfg.json = Some(json_path.clone());
        let report = run_registry(&rt, &cfg, &dir.join("reg"), 3).unwrap();
        // Epochs 1 and 2 each perturb one of the four layers, so three
        // layers dedup against the previous epoch both times.
        assert_eq!(report.blobs_written, 4 + 1 + 1);
        assert_eq!(report.blobs_deduped, 3 + 3);
        assert!(report.dedup_ratio > 0.0);
        // The headline contract: warm start encodes nothing.
        assert_eq!(report.weight_encodes_warm, 0);
        assert_eq!(report.completed, 12);
        let back = Json::parse(&std::fs::read_to_string(&json_path).unwrap()).unwrap();
        assert_eq!(
            back.req("suite").unwrap().as_str().unwrap(),
            "serve_registry"
        );
        assert_eq!(
            back.req("weight_encodes_warm").unwrap().as_usize().unwrap(),
            0
        );
        assert!(back.req("dedup_ratio").unwrap().as_f64().unwrap() > 0.0);
        assert!(back.req("verified").unwrap().as_bool().unwrap());
        assert!(back.req("warm_load_ms").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn weight_reuse_skews_picks_without_touching_the_baseline_stream() {
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 200;
        cfg.weights = 6;
        let (_, base, _) = build_workload(&cfg).unwrap();
        cfg.weight_reuse = 3.0;
        let (_, skewed, _) = build_workload(&cfg).unwrap();
        let hot = |reqs: &[Request]| reqs.iter().filter(|r| r.wi == 0).count();
        // Zipf-ish skew concentrates traffic on the low-index weights;
        // the uniform baseline spreads it ~evenly.
        assert!(
            hot(&skewed) > 2 * hot(&base),
            "skewed {} vs base {}",
            hot(&skewed),
            hot(&base)
        );
        assert!(skewed.iter().all(|r| r.wi < cfg.weights));
        // R == 0.0 must replay the exact historical pick sequence.
        cfg.weight_reuse = 0.0;
        let (_, again, _) = build_workload(&cfg).unwrap();
        assert!(base.iter().zip(&again).all(|(a, b)| a.wi == b.wi));
    }

    #[test]
    fn degenerate_configs_rejected() {
        let rt = Arc::new(ExecRuntime::with_threads(1));
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 0;
        assert!(run(&rt, &cfg).is_err());
    }
}
