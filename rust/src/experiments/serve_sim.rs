//! `repro serve-sim` — synthetic serving workload over the execution
//! runtime.
//!
//! Replays a deterministic stream of mixed-size GEMM requests (random
//! activation heights against a fixed working set of weight matrices in
//! several HBFP formats) through [`BatchGemm`], batch by batch, and
//! reports throughput, batch-attributed latency percentiles, and the
//! operand-cache counters. This is the north-star serving shape in
//! miniature: heterogeneous ops sharded across the persistent pool,
//! weights encoded once and reused across the whole stream.
//!
//! With `verify` on (the `quick` preset default, used by the CI smoke
//! step), a sample of responses is checked **bit-for-bit** against the
//! scalar reference [`hbfp_gemm_scalar`], so the smoke run doubles as
//! an end-to-end integration check of pool + cache + scheduler.

use crate::bfp::{hbfp_gemm_scalar, BlockFormat, Mat};
use crate::exec::{BatchGemm, CacheStats, ExecRuntime, GemmOp};
use crate::report::Table;
use crate::util::{Rng, Stopwatch};
use anyhow::{ensure, Result};

/// Workload shape knobs (CLI flags override the preset values).
#[derive(Debug, Clone)]
pub struct ServeSimConfig {
    /// Total requests in the stream.
    pub requests: usize,
    /// Requests per `BatchGemm` submission.
    pub batch: usize,
    /// Distinct weight matrices in the working set.
    pub weights: usize,
    /// Cross-check a sample of responses against the scalar reference.
    pub verify: bool,
    pub seed: u64,
}

impl ServeSimConfig {
    pub fn quick() -> Self {
        Self {
            requests: 96,
            batch: 16,
            weights: 6,
            verify: true,
            seed: 42,
        }
    }

    pub fn full() -> Self {
        Self {
            requests: 512,
            batch: 32,
            weights: 12,
            verify: false,
            seed: 42,
        }
    }
}

/// Result summary (the table is the printable form).
pub struct ServeSimReport {
    pub table: Table,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub requests_per_s: f64,
    pub cache: CacheStats,
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal_scaled(1.0)).collect()
}

fn percentile(sorted_ms: &[f64], q: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let pos = ((sorted_ms.len() - 1) as f64 * q).round() as usize;
    sorted_ms[pos]
}

/// Run the simulation on `rt` (normally [`crate::exec::global`]).
pub fn run(rt: &ExecRuntime, cfg: &ServeSimConfig) -> Result<ServeSimReport> {
    ensure!(cfg.requests > 0, "need at least one request");
    ensure!(cfg.weights > 0, "need at least one weight matrix");
    // (K, n) shapes and formats of the weight working set — mixed block
    // sizes and mantissa widths, all on the paper's parameter grid.
    let shapes = [(64usize, 48usize), (128, 96), (192, 64), (256, 128), (96, 192), (320, 64)];
    let fmts = [
        BlockFormat::new(4, 64)?,
        BlockFormat::new(6, 64)?,
        BlockFormat::new(4, 16)?,
    ];
    let mut rng = Rng::new(cfg.seed);
    let mut weights: Vec<(Mat, BlockFormat)> = Vec::with_capacity(cfg.weights);
    for i in 0..cfg.weights {
        let (k, n) = shapes[i % shapes.len()];
        let data = randn(&mut rng, k * n);
        weights.push((Mat::new(k, n, data)?, fmts[i % fmts.len()]));
    }
    // Request stream: random weight pick, random activation height.
    struct Request {
        wi: usize,
        x: Mat,
    }
    let mut requests: Vec<Request> = Vec::with_capacity(cfg.requests);
    for _ in 0..cfg.requests {
        let wi = rng.below(weights.len());
        let k = weights[wi].0.rows;
        let m = 1 + rng.below(48);
        let data = randn(&mut rng, m * k);
        requests.push(Request {
            wi,
            x: Mat::new(m, k, data)?,
        });
    }

    let cache_before = rt.cache_stats();
    let mut lat_ms: Vec<f64> = Vec::with_capacity(cfg.requests);
    let mut results: Vec<Mat> = Vec::with_capacity(cfg.requests);
    let sw_all = Stopwatch::start();
    for chunk in requests.chunks(cfg.batch.max(1)) {
        let ops: Vec<GemmOp> = chunk
            .iter()
            .map(|r| GemmOp {
                x: &r.x,
                w: &weights[r.wi].0,
                fmt: weights[r.wi].1,
            })
            .collect();
        let sw = Stopwatch::start();
        let outs = BatchGemm::new(rt).run(&ops)?;
        let ms = sw.ms();
        for _ in chunk {
            lat_ms.push(ms);
        }
        results.extend(outs);
    }
    let total_s = sw_all.secs();

    if cfg.verify {
        for &idx in &[0, cfg.requests / 2, cfg.requests - 1] {
            let r = &requests[idx];
            let want = hbfp_gemm_scalar(&r.x, &weights[r.wi].0, weights[r.wi].1)?;
            ensure!(
                results[idx].data.len() == want.data.len(),
                "request {idx}: shape drift vs scalar reference"
            );
            for (g, w) in results[idx].data.iter().zip(&want.data) {
                ensure!(
                    g.to_bits() == w.to_bits(),
                    "request {idx}: response diverged from hbfp_gemm_scalar"
                );
            }
        }
    }

    let total_macs: f64 = requests
        .iter()
        .map(|r| {
            let w = &weights[r.wi].0;
            (r.x.rows * w.cols * w.rows) as f64
        })
        .sum();
    let mut sorted = lat_ms.clone();
    sorted.sort_by(f64::total_cmp);
    let (p50, p95, p99) = (
        percentile(&sorted, 0.50),
        percentile(&sorted, 0.95),
        percentile(&sorted, 0.99),
    );
    let cache_after = rt.cache_stats();
    let rps = cfg.requests as f64 / total_s.max(1e-9);

    let mut table = Table::new(
        "serve-sim — batched/sharded BFP GEMM serving emulation",
        &["metric", "value"],
    );
    let mut kv = |k: &str, v: String| {
        table.row(vec![k.to_string(), v]);
    };
    kv("requests", cfg.requests.to_string());
    kv("batch size", cfg.batch.to_string());
    kv("weight working set", cfg.weights.to_string());
    kv("pool threads", rt.pool().threads().to_string());
    kv("total MACs", format!("{total_macs:.3e}"));
    kv("wall time (s)", format!("{total_s:.3}"));
    kv("throughput (req/s)", format!("{rps:.1}"));
    kv(
        "throughput (MMAC/s)",
        format!("{:.1}", total_macs / total_s.max(1e-9) / 1e6),
    );
    kv("latency p50 (ms)", format!("{p50:.3}"));
    kv("latency p95 (ms)", format!("{p95:.3}"));
    kv("latency p99 (ms)", format!("{p99:.3}"));
    kv(
        "cache hits (this run)",
        (cache_after.hits - cache_before.hits).to_string(),
    );
    kv(
        "cache misses (this run)",
        (cache_after.misses - cache_before.misses).to_string(),
    );
    kv("cache", cache_after.summary());
    kv(
        "verified vs scalar",
        if cfg.verify { "yes (bit-exact sample)" } else { "no" }.to_string(),
    );

    Ok(ServeSimReport {
        table,
        p50_ms: p50,
        p95_ms: p95,
        p99_ms: p99,
        requests_per_s: rps,
        cache: cache_after,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_preset_runs_verified_and_hits_the_cache() {
        let rt = ExecRuntime::with_threads(2);
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 24;
        cfg.batch = 8;
        cfg.weights = 3;
        let report = run(&rt, &cfg).unwrap();
        // 24 requests over <= 3 distinct weights: one cache access per
        // request, misses only on first encounters — everything else
        // must be served from the operand cache.
        assert!(report.cache.misses <= 3, "{:?}", report.cache);
        assert!(report.cache.hits >= 21, "{:?}", report.cache);
        assert_eq!(report.cache.hits + report.cache.misses, 24, "{:?}", report.cache);
        assert!(report.requests_per_s > 0.0);
        assert!(report.p50_ms <= report.p95_ms && report.p95_ms <= report.p99_ms);
        assert_eq!(report.table.headers.len(), 2);
    }

    #[test]
    fn degenerate_configs_rejected() {
        let rt = ExecRuntime::with_threads(1);
        let mut cfg = ServeSimConfig::quick();
        cfg.requests = 0;
        assert!(run(&rt, &cfg).is_err());
    }
}
