//! `BfpService` — the asynchronous front door of the BFP execution
//! runtime.
//!
//! PR 2's [`super::BatchGemm`] made heterogeneous GEMM batches cheap,
//! but its blocking `run(&[ops])` call couples batch formation to the
//! caller: requests arriving while a batch is in flight wait at the
//! API boundary, and every caller must assemble its own batches. The
//! service moves batch formation off the caller's critical path — the
//! same shape as the paper's own host/accelerator split, where the FP
//! exponent management runs asynchronously off the fixed-point MAC
//! datapath.
//!
//! # Architecture
//!
//! ```text
//! submit(GemmRequest) ─▶ SubmitQueue (bounded, QoS-aware) ─▶ scheduler thread
//!        │                     │                                │ EDF + MAC-budget batch
//!        │                     ▼ claim                          │ run_split_with_stats
//!        │              encode thread ── pre-encodes ──▶ op's encoded slot
//!        │              (pool + operand cache)                  │ staged i32 MACs
//!        │                                                      ▼
//!        │                                               decode thread
//!        │                                     (scale-shift decode, worker pool,
//!        │                                      staging buffers → BufferArena)
//!      Ticket ◀──────────────── fulfill ◀──────────────────────┘
//! ```
//!
//! * [`BfpService::submit`] is **non-blocking**: it validates the op,
//!   stamps the QoS envelope ([`Priority`], optional deadline), and
//!   either admits the request or returns a typed [`AdmissionError`]
//!   (`QueueFull` is the backpressure signal — no hidden waiting).
//! * A dedicated **encode thread** (the pipeline's pre-encode stage)
//!   claims admitted requests and encodes their operands ahead of
//!   execution — activations on the shared pool, weights through the
//!   operand cache — into each op's shared encoded slot, **while the
//!   previous batch's GEMM is still executing**. The execution stage
//!   consumes filled slots and encodes the rest inline; either way the
//!   bits are identical (encoding is deterministic), so the pipeline
//!   is pure overlap. [`ServiceStats`] reports the pre-encode hit rate
//!   and cumulative encode-stage latency.
//! * A dedicated **scheduler thread** drains the queue, forming
//!   earliest-deadline-first batches within a MAC budget
//!   ([`ServiceConfig`]), and drives the split execution path
//!   ([`BatchGemm::run_split_with_stats`]) on the shared worker pool:
//!   the batch stops after the integer-MAC stage, its raw `i32` MACs
//!   staged in arena-recycled planes.
//! * A dedicated **decode thread** (the pipeline's third stage) turns
//!   staged MACs into f32 outputs — band-sharded on the same pool,
//!   replaying the exact accumulation the fused kernels run, so the
//!   hand-off is bit-identical — and **fulfills every ticket**. Because
//!   fulfillment left the scheduler thread, the scheduler is free to
//!   form and execute batch `n + 1` while batch `n` is still decoding;
//!   [`ServiceStats::decoded_overlapped`] counts ops whose decode
//!   actually overlapped a later batch's execution. Output buffers and
//!   MAC/shift staging planes come from the runtime's
//!   [`super::arena::BufferArena`] and recycle across batches (returned
//!   on ticket take or drop).
//! * Callers hold a [`Ticket`] (`poll` / `wait` / `wait_deadline`) and
//!   receive a [`GemmResponse`] carrying the result plus observed
//!   queue/total latency, per-stage (encode/GEMM/decode) batch wall
//!   times, and the deadline-miss flag.
//!
//! # Determinism
//!
//! Admission order, priorities, deadlines, pauses, and batch cuts
//! decide *when* an op executes, never *what* it computes: every batch
//! runs the bit-deterministic execution stage, so results are
//! bit-identical to [`crate::bfp::hbfp_gemm_scalar`] across thread
//! counts, arrival orders, and batch boundaries
//! (`tests/property_service.rs`).
//!
//! # Sessions
//!
//! Synchronous consumers ([`crate::bfp::hbfp_gemm`],
//! [`crate::bfp::dequant_gemm`], the Trainer's host-BFP weight store)
//! go through a [`ServiceSession`] — a labeled handle that submits with
//! blocking admission (those APIs were blocking contracts already) and
//! exposes the runtime's operand cache for encode-only paths.

use super::arena::BufferArena;
use super::pool::{lock_or_poisoned, wait_or_poisoned};
use super::queue::{
    AdmissionError, GemmRequest, GemmResponse, Pending, Priority, SubmitQueue, Ticket,
};
use super::scheduler::{decode_staged, BatchGemm, EncodeReport, OwnedGemmOp, StagedOut};
use super::ExecRuntime;
use crate::bfp::{kernels, BfpMatrix, BlockFormat, KernelOpCounts, Mat};
use crate::util::KernelChoice;
use anyhow::{Context, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::Instant;

/// Admission-loop knobs. The defaults suit the serve-sim workload
/// shapes; embedders with very large or very small ops should scale
/// `max_batch_macs` with them.
#[derive(Debug, Clone, Copy)]
pub struct ServiceConfig {
    /// Bounded-queue capacity; beyond it `submit` returns
    /// [`AdmissionError::QueueFull`].
    pub queue_capacity: usize,
    /// Max requests fused into one execution batch.
    pub max_batch_ops: usize,
    /// **Base** cumulative MAC volume per batch (a single larger op
    /// still runs alone — the budget cuts batches, it never starves
    /// ops). With `adaptive_batch` on, the scheduler scales this with
    /// observed queue depth and deadline pressure per batch — see
    /// [`adaptive_batch_macs`]; the effective value is surfaced in
    /// [`ServiceStats::effective_batch_macs`].
    pub max_batch_macs: usize,
    /// Scale the MAC budget with observed load (default on). Off =
    /// the static PR-3 behavior.
    pub adaptive_batch: bool,
    /// GEMM kernel backend for this service's batches: `Auto` (the
    /// default) keeps the registry's per-operand-pair dispatch; a
    /// named choice forces that backend where it supports the operand
    /// layouts. Either way results are bit-identical — this is a
    /// performance and test knob, never a numerics one.
    pub kernel: KernelChoice,
    /// Byte budget for pre-encoded activation planes resident in the
    /// queue (claimed by the pre-encode stage, not yet popped into a
    /// batch). Over budget the encoder **stalls** until pops release
    /// bytes — it never drops work; unclaimed requests simply encode
    /// inline at execution. Defaults to the `BOOSTERS_PREENCODE_MB`
    /// environment knob (256 MiB when unset).
    pub pre_encode_cap_bytes: u64,
    /// Minimum number of same-weight split-path ops in a batch before
    /// they execute as one weight-stationary group (shared weight
    /// planes stream through memory once per band tile per group).
    /// `0` disables grouping — and the queue's group-aware batch fill
    /// with it. Bit-identical either way. Defaults to the
    /// `BOOSTERS_GROUP_MIN_OPS` environment knob (2 when unset).
    pub group_min_ops: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self {
            queue_capacity: 256,
            max_batch_ops: 64,
            max_batch_macs: 1 << 26,
            adaptive_batch: true,
            kernel: KernelChoice::Auto,
            pre_encode_cap_bytes: crate::util::preencode_budget(),
            group_min_ops: crate::util::group_min_ops(),
        }
    }
}

/// Effective per-batch MAC budget under observed load: monotonically
/// non-decreasing in queue depth — a deeper backlog merges into
/// larger (more throughput-efficient) batches, up to 4x the configured
/// base at a full queue — and cut to a quarter of the base while the
/// **EDF head** of the queue is already past its deadline, so that
/// request starts executing in an interactive-sized batch instead of
/// riding a bulk one (the caller keys `deadline_due` on the head of
/// the batch being formed, never on requests the cut cannot help).
pub fn adaptive_batch_macs(
    base: usize,
    queue_depth: usize,
    queue_capacity: usize,
    deadline_due: bool,
) -> usize {
    let base = base.max(1);
    if deadline_due {
        return (base / 4).max(1);
    }
    let cap = queue_capacity.max(1);
    let fill = queue_depth.min(cap);
    base.saturating_add(base.saturating_mul(3).saturating_mul(fill) / cap)
}

#[derive(Default)]
struct ServiceCounters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    rejected: AtomicU64,
    deadline_missed: AtomicU64,
    batches: AtomicU64,
    /// MAC budget the adaptive scheduler used for the most recent
    /// batch (the base budget until the first batch forms).
    effective_batch_macs: AtomicU64,
    /// Ops that reached execution with their operand slot already
    /// filled by the pre-encode stage.
    pre_encoded: AtomicU64,
    /// Ops the execution stage had to encode inline.
    inline_encoded: AtomicU64,
    /// Cumulative encode-stage wall time, nanoseconds: the pre-encode
    /// thread's encoding work plus the execution stage's inline encode
    /// phase.
    encode_ns: AtomicU64,
    /// Ops whose outputs the decode stage published (everything that
    /// went through the split path — fused-in-split ops included, since
    /// their tickets are still fulfilled by the decode thread).
    decode_ops: AtomicU64,
    /// Decode-stage ops whose decode demonstrably overlapped a later
    /// batch's execution (the scheduler had already started another
    /// batch by the time the decode finished) — the pipeline's
    /// overlapped-decode evidence.
    decoded_overlapped: AtomicU64,
    /// Cumulative decode-stage wall time, nanoseconds.
    decode_ns: AtomicU64,
    /// Batches the scheduler thread has **started** executing —
    /// compared against a hand-off snapshot by the decode thread to
    /// detect overlap. Distinct from `batches` only in role; kept
    /// separate so the overlap probe never races stats readers'
    /// expectations about `batches`.
    exec_batches_started: AtomicU64,
    /// Split-path ops executed inside a weight-stationary group (their
    /// shared weight planes streamed through memory once per band tile
    /// instead of once per op).
    grouped_ops: AtomicU64,
    /// Ops executed outside any group — solo weights, sub-threshold
    /// buckets, fused-path ops, and solo retries after a batch error.
    /// `grouped_ops + ungrouped_ops == completed` always holds.
    ungrouped_ops: AtomicU64,
    /// Weight-stationary groups formed (each covers ≥ 2 ops).
    groups_formed: AtomicU64,
    /// Encoded weight-plane bytes grouping did **not** re-stream:
    /// plane footprint × (group size − 1), summed over groups.
    weight_plane_loads_avoided: AtomicU64,
    /// Which backend the execution stage actually dispatched per op,
    /// by M×N×K bucket (ground truth next to the configured
    /// `KernelChoice`). A mutex, not atomics: updated once per batch,
    /// read once per stats snapshot.
    kernel_ops: Mutex<KernelOpCounts>,
}

impl ServiceCounters {
    fn record_encode(&self, report: &EncodeReport) {
        self.pre_encoded
            .fetch_add(report.pre_encoded as u64, Ordering::Relaxed);
        self.inline_encoded
            .fetch_add(report.inline_encoded as u64, Ordering::Relaxed);
        self.encode_ns.fetch_add(report.encode_ns, Ordering::Relaxed);
        // Every executed op is either grouped or not; solo retries
        // report grouped_ops == 0 and land entirely in `ungrouped`, so
        // `grouped + ungrouped == completed` stays an invariant.
        let total = report.pre_encoded + report.inline_encoded;
        self.grouped_ops
            .fetch_add(report.grouped_ops as u64, Ordering::Relaxed);
        self.ungrouped_ops
            .fetch_add((total - report.grouped_ops) as u64, Ordering::Relaxed);
        self.groups_formed
            .fetch_add(report.groups_formed as u64, Ordering::Relaxed);
        self.weight_plane_loads_avoided
            .fetch_add(report.weight_plane_loads_avoided, Ordering::Relaxed);
        lock_or_poisoned(&self.kernel_ops, "service kernel-op counts")
            .merge(&report.kernel_ops);
    }
}

/// Counter snapshot of one service (see
/// [`crate::metrics::exec_service_snapshot`] for the global one).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests admitted into the queue.
    pub submitted: u64,
    /// Requests fulfilled with a result.
    pub completed: u64,
    /// Requests fulfilled with an execution error.
    pub failed: u64,
    /// Requests turned away at admission (`QueueFull`).
    pub rejected: u64,
    /// Fulfilled requests that finished after their deadline.
    pub deadline_missed: u64,
    /// Execution batches formed by the admission loop.
    pub batches: u64,
    /// Requests pending right now.
    pub queue_depth: usize,
    /// High-water mark of the pending queue.
    pub peak_queue_depth: usize,
    /// MAC budget the (adaptive) scheduler applied to the most recent
    /// batch — equals `ServiceConfig::max_batch_macs` when adaptation
    /// is off or the queue is idle.
    pub effective_batch_macs: u64,
    /// Executed ops whose operands the pipeline pre-encoded ahead of
    /// their batch (admission-time encode overlapped a running GEMM).
    pub pre_encoded: u64,
    /// Executed ops the execution stage encoded inline (the pipeline
    /// lost the race or the op arrived straight at execution).
    pub inline_encoded: u64,
    /// Cumulative encode-stage wall time in microseconds (pre-encode
    /// thread + inline encode inside the execution stage).
    pub encode_us: u64,
    /// Kernel backend identity this service executes with (the forced
    /// [`ServiceConfig::kernel`] choice, or the registry's preferred
    /// backend under `Auto`; per-op dispatch may still fall back for
    /// layout pairs the backend cannot run).
    pub kernel: &'static str,
    /// Which backend **actually executed** each op, per M×N×K bucket —
    /// the ground truth behind `kernel` (forced choices degrade per op,
    /// and `Auto` dispatches per layout pair and shape bucket).
    pub kernel_ops: KernelOpCounts,
    /// Pre-encoded activation bytes currently charged against the
    /// `BOOSTERS_PREENCODE_MB` budget (claimed by the pre-encode stage
    /// and still waiting in the queue).
    pub pre_encode_resident_bytes: u64,
    /// Ops fulfilled by the decode stage (the split pipeline's third
    /// stage).
    pub decode_ops: u64,
    /// Decode-stage ops whose decode overlapped a later batch's
    /// execution — nonzero means the three-stage pipeline actually
    /// pipelined.
    pub decoded_overlapped: u64,
    /// Cumulative decode-stage wall time in microseconds.
    pub decode_us: u64,
    /// Split-path ops executed inside a weight-stationary group: the
    /// scheduler stacked same-digest ops into one tall-M grouped GEMM,
    /// streaming the shared weight planes through memory once per band
    /// tile instead of once per op.
    pub grouped_ops: u64,
    /// Ops executed outside any group (solo weights, sub-threshold
    /// buckets, fused-path ops, solo retries). The partition is exact:
    /// `grouped_ops + ungrouped_ops == completed`.
    pub ungrouped_ops: u64,
    /// Weight-stationary groups formed (each covers ≥ 2 ops; divide
    /// `grouped_ops` by this for the mean group size).
    pub groups_formed: u64,
    /// Encoded weight-plane bytes grouping avoided re-streaming:
    /// plane footprint × (group size − 1), summed over groups.
    pub weight_plane_loads_avoided: u64,
    /// Buffer-arena checkouts served from the free list.
    pub arena_hits: u64,
    /// Buffer-arena checkouts that had to allocate.
    pub arena_misses: u64,
    /// Cumulative bytes served from recycled arena buffers.
    pub arena_recycled_bytes: u64,
    /// Arena bytes resident right now (free lists + checked out).
    pub arena_resident_bytes: u64,
}

impl Default for ServiceStats {
    fn default() -> Self {
        Self {
            submitted: 0,
            completed: 0,
            failed: 0,
            rejected: 0,
            deadline_missed: 0,
            batches: 0,
            queue_depth: 0,
            peak_queue_depth: 0,
            effective_batch_macs: 0,
            pre_encoded: 0,
            inline_encoded: 0,
            encode_us: 0,
            kernel: "",
            kernel_ops: KernelOpCounts::default(),
            pre_encode_resident_bytes: 0,
            decode_ops: 0,
            decoded_overlapped: 0,
            decode_us: 0,
            grouped_ops: 0,
            ungrouped_ops: 0,
            groups_formed: 0,
            weight_plane_loads_avoided: 0,
            arena_hits: 0,
            arena_misses: 0,
            arena_recycled_bytes: 0,
            arena_resident_bytes: 0,
        }
    }
}

impl ServiceStats {
    /// Deadline-miss rate over fulfilled requests (0.0 when none had
    /// finished yet).
    pub fn miss_rate(&self) -> f64 {
        let done = self.completed + self.failed;
        if done == 0 {
            0.0
        } else {
            self.deadline_missed as f64 / done as f64
        }
    }

    /// Share of executed ops whose operands were pre-encoded by the
    /// pipeline (0.0 before anything executed).
    pub fn pre_encode_hit_rate(&self) -> f64 {
        let total = self.pre_encoded + self.inline_encoded;
        if total == 0 {
            0.0
        } else {
            self.pre_encoded as f64 / total as f64
        }
    }

    /// Share of buffer-arena checkouts served from the free list (0.0
    /// before the arena sees traffic).
    pub fn arena_hit_rate(&self) -> f64 {
        let total = self.arena_hits + self.arena_misses;
        if total == 0 {
            0.0
        } else {
            self.arena_hits as f64 / total as f64
        }
    }

    /// Share of decode-stage ops whose decode overlapped a later
    /// batch's execution (0.0 before anything decoded).
    pub fn decode_overlap_rate(&self) -> f64 {
        if self.decode_ops == 0 {
            0.0
        } else {
            self.decoded_overlapped as f64 / self.decode_ops as f64
        }
    }
}

/// Hand-off channel between the scheduler (MAC) stage and the decode
/// stage: executed batches waiting for their f32 decode, FIFO (batches
/// were already formed EDF-first; reordering decodes would only add
/// latency jitter). Closed by the scheduler thread when it exits, after
/// which `pop` drains the backlog and then returns `None` — the drain
/// path every admitted ticket's fulfillment rides on during drop.
struct DecodeQueue {
    state: Mutex<DecodeQueueState>,
    cv: Condvar,
}

struct DecodeQueueState {
    batches: VecDeque<DecodeBatch>,
    closed: bool,
}

/// One executed batch in flight between the MAC and decode stages.
struct DecodeBatch {
    /// Submission-ordered pairs of the request and its staged output.
    items: Vec<(Pending, StagedOut)>,
    /// When the batch started executing (queue_ms anchor).
    started: Instant,
    /// The batch's encode-stage wall time, milliseconds.
    encode_ms: f64,
    /// The batch's MAC/GEMM-stage wall time, milliseconds.
    gemm_ms: f64,
    /// `exec_batches_started` snapshot at hand-off: if the counter has
    /// moved by the time this batch finishes decoding, the decode
    /// overlapped a later batch's execution.
    handoff_batches: u64,
}

impl DecodeQueue {
    fn new() -> Self {
        Self {
            state: Mutex::new(DecodeQueueState {
                batches: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn push(&self, batch: DecodeBatch) {
        let mut st = lock_or_poisoned(&self.state, "decode queue");
        st.batches.push_back(batch);
        self.cv.notify_one();
    }

    /// Idempotent: wakes the decode thread to drain and exit.
    fn close(&self) {
        let mut st = lock_or_poisoned(&self.state, "decode queue");
        st.closed = true;
        self.cv.notify_all();
    }

    fn pop(&self) -> Option<DecodeBatch> {
        let mut st = lock_or_poisoned(&self.state, "decode queue");
        loop {
            if let Some(b) = st.batches.pop_front() {
                return Some(b);
            }
            if st.closed {
                return None;
            }
            st = wait_or_poisoned(&self.cv, st, "decode queue");
        }
    }
}

/// The asynchronous BFP execution service (see module docs).
pub struct BfpService {
    rt: Arc<ExecRuntime>,
    queue: Arc<SubmitQueue>,
    decode_q: Arc<DecodeQueue>,
    counters: Arc<ServiceCounters>,
    cfg: ServiceConfig,
    scheduler: Option<JoinHandle<()>>,
    decoder: Option<JoinHandle<()>>,
    encoder: Option<JoinHandle<()>>,
}

impl BfpService {
    /// Spawn a service (its scheduler, decode-stage, and pre-encode
    /// stage threads) over `rt`. The runtime is shared: the service's
    /// batches, direct `BatchGemm` users, and encode-only consumers all
    /// see one pool, one operand cache, and one buffer arena.
    pub fn new(rt: Arc<ExecRuntime>, cfg: ServiceConfig) -> Self {
        let queue = Arc::new(SubmitQueue::new(cfg.queue_capacity, cfg.group_min_ops));
        let decode_q = Arc::new(DecodeQueue::new());
        let counters = Arc::new(ServiceCounters::default());
        counters
            .effective_batch_macs
            .store(cfg.max_batch_macs.max(1) as u64, Ordering::Relaxed);
        let scheduler = {
            let rt = Arc::clone(&rt);
            let queue = Arc::clone(&queue);
            let decode_q = Arc::clone(&decode_q);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("bfp-service-sched".into())
                .spawn(move || scheduler_loop(&rt, &queue, &decode_q, &counters, cfg))
                .expect("spawn service scheduler thread")
        };
        let decoder = {
            let rt = Arc::clone(&rt);
            let decode_q = Arc::clone(&decode_q);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("bfp-service-decode".into())
                .spawn(move || decoder_loop(&rt, &decode_q, &counters))
                .expect("spawn service decode-stage thread")
        };
        let encoder = {
            let rt = Arc::clone(&rt);
            let queue = Arc::clone(&queue);
            let counters = Arc::clone(&counters);
            std::thread::Builder::new()
                .name("bfp-service-encode".into())
                .spawn(move || encoder_loop(&rt, &queue, &counters, cfg.pre_encode_cap_bytes))
                .expect("spawn service encode-stage thread")
        };
        Self {
            rt,
            queue,
            decode_q,
            counters,
            cfg,
            scheduler: Some(scheduler),
            decoder: Some(decoder),
            encoder: Some(encoder),
        }
    }

    /// A service with default config over a private runtime — test and
    /// embedder convenience.
    pub fn with_threads(threads: usize) -> Self {
        Self::new(
            Arc::new(ExecRuntime::with_threads(threads)),
            ServiceConfig::default(),
        )
    }

    /// The shared runtime (pool + operand cache) this service executes
    /// on.
    pub fn runtime(&self) -> &ExecRuntime {
        &self.rt
    }

    /// **Non-blocking** admission: validate, stamp QoS, enqueue. A full
    /// queue or shutdown returns the typed [`AdmissionError`]
    /// immediately — the caller, not the service, decides how to shed
    /// load.
    pub fn submit(&self, req: GemmRequest) -> Result<Ticket, AdmissionError> {
        self.validate(&req)?;
        match self.queue.push(req) {
            Ok(inner) => {
                self.counters.submitted.fetch_add(1, Ordering::Relaxed);
                Ok(Ticket::from_inner(inner))
            }
            Err(e) => {
                if matches!(e, AdmissionError::QueueFull { .. }) {
                    self.counters.rejected.fetch_add(1, Ordering::Relaxed);
                }
                Err(e)
            }
        }
    }

    /// Blocking admission for synchronous facades: waits for queue
    /// space instead of returning `QueueFull` (errors only on shutdown
    /// or invalid shape).
    pub fn submit_blocking(&self, req: GemmRequest) -> Result<Ticket, AdmissionError> {
        self.validate(&req)?;
        let inner = self.queue.push_blocking(req)?;
        self.counters.submitted.fetch_add(1, Ordering::Relaxed);
        Ok(Ticket::from_inner(inner))
    }

    fn validate(&self, req: &GemmRequest) -> Result<(), AdmissionError> {
        let (x, w) = (&req.op.x, &req.op.w);
        if x.cols != w.rows {
            return Err(AdmissionError::InvalidShape {
                reason: format!("inner dims {} vs {} do not contract", x.cols, w.rows),
            });
        }
        Ok(())
    }

    /// Labeled synchronous handle for consumers migrating from the
    /// blocking PR-2 API (see module docs).
    pub fn session(&self, label: &'static str) -> ServiceSession<'_> {
        ServiceSession { svc: self, label }
    }

    /// Counter snapshot (cumulative for this service's lifetime).
    pub fn stats(&self) -> ServiceStats {
        let arena = self.rt.arena().stats();
        ServiceStats {
            submitted: self.counters.submitted.load(Ordering::Relaxed),
            completed: self.counters.completed.load(Ordering::Relaxed),
            failed: self.counters.failed.load(Ordering::Relaxed),
            rejected: self.counters.rejected.load(Ordering::Relaxed),
            deadline_missed: self.counters.deadline_missed.load(Ordering::Relaxed),
            batches: self.counters.batches.load(Ordering::Relaxed),
            queue_depth: self.queue.depth(),
            peak_queue_depth: self.queue.peak_depth(),
            effective_batch_macs: self.counters.effective_batch_macs.load(Ordering::Relaxed),
            pre_encoded: self.counters.pre_encoded.load(Ordering::Relaxed),
            inline_encoded: self.counters.inline_encoded.load(Ordering::Relaxed),
            encode_us: self.counters.encode_ns.load(Ordering::Relaxed) / 1_000,
            kernel: kernels::registry().resolve(self.cfg.kernel).name(),
            kernel_ops: *lock_or_poisoned(&self.counters.kernel_ops, "service kernel-op counts"),
            pre_encode_resident_bytes: self.queue.pre_encode_bytes(),
            decode_ops: self.counters.decode_ops.load(Ordering::Relaxed),
            decoded_overlapped: self.counters.decoded_overlapped.load(Ordering::Relaxed),
            decode_us: self.counters.decode_ns.load(Ordering::Relaxed) / 1_000,
            grouped_ops: self.counters.grouped_ops.load(Ordering::Relaxed),
            ungrouped_ops: self.counters.ungrouped_ops.load(Ordering::Relaxed),
            groups_formed: self.counters.groups_formed.load(Ordering::Relaxed),
            weight_plane_loads_avoided: self
                .counters
                .weight_plane_loads_avoided
                .load(Ordering::Relaxed),
            arena_hits: arena.hits,
            arena_misses: arena.misses,
            arena_recycled_bytes: arena.recycled_bytes,
            arena_resident_bytes: arena.resident_bytes,
        }
    }

    /// Queue capacity this service admits up to.
    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Stop the admission loop from forming batches. Admission itself
    /// stays open, so the bounded queue fills — the deterministic way
    /// to probe backpressure (tests) or to quiesce execution before a
    /// reconfiguration.
    pub fn pause(&self) {
        self.queue.set_paused(true);
    }

    /// Resume batch formation after [`BfpService::pause`].
    pub fn resume(&self) {
        self.queue.set_paused(false);
    }
}

impl Drop for BfpService {
    /// Graceful three-stage drain: admission closes; the scheduler
    /// executes everything already admitted (a pause is overridden) and
    /// hands the staged batches to the decode queue before closing it;
    /// the decode thread drains that backlog, fulfilling every ticket —
    /// no ticket is ever abandoned. Join order matters: scheduler first
    /// (it is the decode queue's producer and closer), then decoder,
    /// then the encode thread. The encode thread exits on shutdown
    /// without draining: anything it had not pre-encoded was encoded
    /// inline by the scheduler's drain.
    fn drop(&mut self) {
        self.queue.shutdown();
        if let Some(h) = self.scheduler.take() {
            let _ = h.join();
        }
        // Normally a no-op (the scheduler closed it on exit); insurance
        // against a panicked scheduler leaving the decoder blocked.
        self.decode_q.close();
        if let Some(h) = self.decoder.take() {
            let _ = h.join();
        }
        if let Some(h) = self.encoder.take() {
            let _ = h.join();
        }
    }
}

/// A batch executor honoring the service's kernel choice (`Auto`
/// keeps the registry's per-operand-pair dispatch).
fn batch_stage<'rt>(rt: &'rt ExecRuntime, cfg: &ServiceConfig) -> BatchGemm<'rt> {
    let gemm = BatchGemm::new(rt).group_min_ops(cfg.group_min_ops);
    match cfg.kernel {
        KernelChoice::Auto => gemm,
        choice => gemm.with_kernel(kernels::registry().resolve(choice)),
    }
}

/// Requests the pre-encode stage claims per wakeup — enough to stay
/// ahead of one execution batch without hoarding the queue under a
/// burst.
const ENCODE_CLAIM_MAX: usize = 64;

/// The pipeline's pre-encode stage: claim admitted requests and fill
/// their ops' shared encoded slots (activations on the pool, weights
/// through the operand cache) while the scheduler thread is busy
/// executing the previous batch. Claims whose request has already been
/// popped into a batch are skipped — encoding them would only
/// duplicate the execution stage's inline encode and steal pool time
/// from the running GEMM. Encode failures are swallowed on purpose —
/// the execution stage re-encodes inline and routes the error to the
/// right ticket. Claims arrive in EDF order and are bounded by
/// `cap_bytes` of resident pre-encoded activation bytes (the
/// `BOOSTERS_PREENCODE_MB` budget): over budget this loop stalls
/// inside `claim_encode_work` until pops release bytes.
fn encoder_loop(
    rt: &ExecRuntime,
    queue: &SubmitQueue,
    counters: &ServiceCounters,
    cap_bytes: u64,
) {
    while let Some(claims) = queue.claim_encode_work(ENCODE_CLAIM_MAX, cap_bytes) {
        for claim in &claims {
            // Skip claims that can do no useful work, and keep their
            // bookkeeping out of encode_ns — the reported encode-stage
            // latency is time spent encoding, not iterating claims.
            if !claim.still_queued() || claim.op.is_pre_encoded() {
                continue;
            }
            let started = Instant::now();
            let _ = claim.op.pre_encode(rt);
            counters
                .encode_ns
                .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }
    }
}

fn scheduler_loop(
    rt: &ExecRuntime,
    queue: &SubmitQueue,
    decode_q: &DecodeQueue,
    counters: &ServiceCounters,
    cfg: ServiceConfig,
) {
    // The adaptive MAC budget is computed by `pop_batch` itself, under
    // the lock that forms the batch — from the depth and deadline
    // pressure of exactly the requests being cut (see
    // [`adaptive_batch_macs`]). Adaptation is a throughput/latency
    // heuristic, never a correctness input.
    while let Some((batch, effective_macs)) =
        queue.pop_batch(cfg.max_batch_macs, cfg.max_batch_ops, cfg.adaptive_batch)
    {
        counters
            .effective_batch_macs
            .store(effective_macs as u64, Ordering::Relaxed);
        counters.batches.fetch_add(1, Ordering::Relaxed);
        counters.exec_batches_started.fetch_add(1, Ordering::Relaxed);
        let started = Instant::now();
        let ops: Vec<OwnedGemmOp> = batch.iter().map(|p| p.op.clone()).collect();
        match batch_stage(rt, &cfg).run_split_with_stats(&ops) {
            Ok(staged) => {
                counters.record_encode(&staged.report);
                let encode_ms = staged.report.encode_ns as f64 / 1e6;
                let exec_ms = started.elapsed().as_secs_f64() * 1e3;
                decode_q.push(DecodeBatch {
                    items: batch.into_iter().zip(staged.staged).collect(),
                    started,
                    encode_ms,
                    gemm_ms: (exec_ms - encode_ms).max(0.0),
                    handoff_batches: counters.exec_batches_started.load(Ordering::Relaxed),
                });
            }
            Err(_) => {
                // A batch-level failure must not poison neighbors that
                // would succeed alone: retry each op by itself —
                // synchronously, on the fused path — and give every
                // ticket its own verdict right here (nothing was
                // staged, so there is nothing for the decode stage).
                for p in batch {
                    let one = batch_stage(rt, &cfg)
                        .run_with_stats(std::slice::from_ref(&p.op))
                        .map(|(mut outs, report)| {
                            counters.record_encode(&report);
                            outs.remove(0)
                        });
                    fulfill(p, one, started, counters, StageTimes::default(), None);
                }
            }
        }
    }
    // Producer done: let the decode thread drain its backlog and exit.
    decode_q.close();
}

/// The pipeline's third stage: decode staged MAC planes into f32
/// outputs and publish every ticket. Runs until the scheduler closes
/// the hand-off queue and the backlog drains.
fn decoder_loop(rt: &ExecRuntime, decode_q: &DecodeQueue, counters: &ServiceCounters) {
    while let Some(db) = decode_q.pop() {
        let decode_started = Instant::now();
        let done: Vec<(Pending, Mat)> = db
            .items
            .into_iter()
            .map(|(p, staged)| {
                let out = decode_staged(rt, staged);
                (p, out)
            })
            .collect();
        let decode_ms = decode_started.elapsed().as_secs_f64() * 1e3;
        let n_ops = done.len() as u64;
        counters.decode_ops.fetch_add(n_ops, Ordering::Relaxed);
        counters
            .decode_ns
            .fetch_add(decode_started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        // If the scheduler started another batch since this one was
        // handed off, this decode ran concurrently with that execution
        // — the overlap the three-stage split exists to create.
        if counters.exec_batches_started.load(Ordering::Relaxed) > db.handoff_batches {
            counters.decoded_overlapped.fetch_add(n_ops, Ordering::Relaxed);
        }
        let times = StageTimes {
            encode_ms: db.encode_ms,
            gemm_ms: db.gemm_ms,
            decode_ms,
        };
        let arena = rt.arena();
        for (p, out) in done {
            let bytes = (out.data.capacity() * std::mem::size_of::<f32>()) as u64;
            fulfill(
                p,
                Ok(out),
                db.started,
                counters,
                times,
                Some((Arc::clone(arena), bytes)),
            );
        }
    }
}

/// Per-request stage-time attribution carried into the
/// [`GemmResponse`]: the executing batch's encode/GEMM/decode wall
/// times (every request in a batch reports its batch's stage times).
#[derive(Debug, Clone, Copy, Default)]
struct StageTimes {
    encode_ms: f64,
    gemm_ms: f64,
    decode_ms: f64,
}

fn fulfill(
    p: Pending,
    result: Result<Mat>,
    started: Instant,
    counters: &ServiceCounters,
    times: StageTimes,
    arena: Option<(Arc<BufferArena>, u64)>,
) {
    let now = Instant::now();
    let missed = p.deadline_at.map(|d| now > d).unwrap_or(false);
    if missed {
        counters.deadline_missed.fetch_add(1, Ordering::Relaxed);
    }
    match &result {
        Ok(_) => counters.completed.fetch_add(1, Ordering::Relaxed),
        Err(_) => counters.failed.fetch_add(1, Ordering::Relaxed),
    };
    let queue_ms = started.saturating_duration_since(p.submitted_at).as_secs_f64() * 1e3;
    let total_ms = now.saturating_duration_since(p.submitted_at).as_secs_f64() * 1e3;
    let arena = if result.is_ok() { arena } else { None };
    p.ticket.fulfill_recycling(
        result.map(|out| GemmResponse {
            out,
            queue_ms,
            total_ms,
            deadline_missed: missed,
            encode_ms: times.encode_ms,
            gemm_ms: times.gemm_ms,
            decode_ms: times.decode_ms,
        }),
        arena,
    );
}

/// A labeled synchronous handle onto a [`BfpService`] — the migration
/// path for PR-2's blocking consumers. GEMMs go through the full
/// admission loop (blocking admission: these were blocking APIs);
/// encode-only paths reach the shared operand cache directly.
pub struct ServiceSession<'s> {
    svc: &'s BfpService,
    label: &'static str,
}

impl ServiceSession<'_> {
    pub fn label(&self) -> &'static str {
        self.label
    }

    /// The shared runtime, for encode-only consumers
    /// (`quantize_params_packed_cached`, analysis sweeps).
    pub fn runtime(&self) -> &ExecRuntime {
        self.svc.runtime()
    }

    /// Submit one GEMM through the service and wait for it: the
    /// synchronous `hbfp_gemm` contract over the asynchronous path.
    /// Operands are copied into owned form; hold `Arc<Mat>`s and use
    /// [`BfpService::submit`] directly to avoid the copies.
    pub fn gemm(&self, x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Mat> {
        let op = OwnedGemmOp::from_mats(x, w, fmt)?;
        let ticket = self
            .svc
            .submit_blocking(GemmRequest::new(op).with_priority(Priority::Bulk))
            .with_context(|| format!("session {:?}: admission failed", self.label))?;
        ticket
            .wait()
            .map(|resp| resp.out)
            .with_context(|| format!("session {:?}: execution failed", self.label))
    }

    /// Column-encode `w` through the shared operand cache (weight-side
    /// layout, nearest rounding — the cacheable transform).
    pub fn encode_transposed_cached(&self, w: &Mat, fmt: BlockFormat) -> Result<Arc<BfpMatrix>> {
        self.runtime().encode_transposed_cached(w, fmt)
    }
}

static SERVICE: OnceLock<BfpService> = OnceLock::new();

/// The process-wide service over the global [`ExecRuntime`] (created on
/// first use; its scheduler thread lives for the rest of the process).
pub fn global() -> &'static BfpService {
    SERVICE.get_or_init(|| BfpService::new(super::global_arc(), ServiceConfig::default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::hbfp_gemm_scalar;
    use crate::util::Rng;
    use std::time::Duration;

    fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Arc<Mat> {
        Arc::new(
            Mat::new(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn submit_wait_roundtrip_matches_scalar() {
        let svc = BfpService::with_threads(2);
        let mut rng = Rng::new(0x5E21);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let x = randmat(&mut rng, 5, 40);
        let w = randmat(&mut rng, 40, 7);
        let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        let ticket = svc
            .submit(GemmRequest::new(op).with_deadline(Duration::from_secs(60)))
            .unwrap();
        let resp = ticket.wait().unwrap();
        let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
        assert_eq!((resp.out.rows, resp.out.cols), (want.rows, want.cols));
        for (g, s) in resp.out.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), s.to_bits());
        }
        assert!(!resp.deadline_missed);
        assert!(resp.total_ms >= resp.queue_ms);
        let stats = svc.stats();
        assert_eq!((stats.submitted, stats.completed), (1, 1));
        assert_eq!(stats.deadline_missed, 0);
    }

    #[test]
    fn invalid_shape_rejected_at_admission() {
        let svc = BfpService::with_threads(1);
        let mut rng = Rng::new(0xBAD);
        let fmt = BlockFormat::new(4, 16).unwrap();
        // Bypass OwnedGemmOp::new's validation via the struct literal.
        let op = OwnedGemmOp {
            x: randmat(&mut rng, 2, 8),
            w: randmat(&mut rng, 9, 3),
            fmt,
            encoded: Default::default(),
            digest: Default::default(),
        };
        match svc.submit(GemmRequest::new(op)) {
            Err(AdmissionError::InvalidShape { reason }) => {
                assert!(reason.contains("8"), "{reason}");
            }
            other => panic!("expected InvalidShape, got {other:?}"),
        }
        assert_eq!(svc.stats().submitted, 0);
    }

    #[test]
    fn session_gemm_is_bit_identical_to_scalar() {
        let svc = BfpService::with_threads(2);
        let sess = svc.session("unit test");
        assert_eq!(sess.label(), "unit test");
        let mut rng = Rng::new(0x5E55);
        let fmt = BlockFormat::new(6, 64).unwrap();
        let x = randmat(&mut rng, 4, 130);
        let w = randmat(&mut rng, 130, 9);
        let got = sess.gemm(&x, &w, fmt).unwrap();
        let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
        for (g, s) in got.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), s.to_bits());
        }
    }

    #[test]
    fn drop_drains_admitted_work() {
        let svc = BfpService::with_threads(2);
        svc.pause();
        let mut rng = Rng::new(0xD2A1);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let tickets: Vec<Ticket> = (0..4)
            .map(|_| {
                let op = OwnedGemmOp::new(
                    randmat(&mut rng, 3, 32),
                    randmat(&mut rng, 32, 4),
                    fmt,
                )
                .unwrap();
                svc.submit(GemmRequest::new(op)).unwrap()
            })
            .collect();
        // Still paused — nothing fulfilled yet; drop must drain anyway.
        drop(svc);
        for t in &tickets {
            assert!(t.poll(), "drop must fulfill every admitted ticket");
            assert!(t.wait().is_ok());
        }
    }

    #[test]
    fn adaptive_budget_is_monotone_in_depth_and_cut_under_deadline_pressure() {
        let base = 1 << 20;
        let cap = 64usize;
        // Monotone non-decreasing in queue depth...
        let mut last = 0usize;
        for depth in 0..=cap {
            let eff = adaptive_batch_macs(base, depth, cap, false);
            assert!(eff >= last, "depth {depth}: {eff} < {last}");
            last = eff;
        }
        // ...anchored at the base when idle, 4x at a full queue, and
        // saturating (depth beyond capacity changes nothing).
        assert_eq!(adaptive_batch_macs(base, 0, cap, false), base);
        assert_eq!(adaptive_batch_macs(base, cap, cap, false), 4 * base);
        assert_eq!(
            adaptive_batch_macs(base, 10 * cap, cap, false),
            adaptive_batch_macs(base, cap, cap, false)
        );
        // Deadline pressure cuts to a quarter of the base, regardless
        // of depth — latency beats batching efficiency when a deadline
        // is already burning.
        for depth in [0usize, 1, cap] {
            assert_eq!(adaptive_batch_macs(base, depth, cap, true), base / 4);
        }
        // Degenerate inputs stay usable (the progress guarantee).
        assert_eq!(adaptive_batch_macs(0, 5, 0, false), 4);
        assert_eq!(adaptive_batch_macs(1, 0, 8, true), 1);
    }

    #[test]
    fn effective_budget_and_kernel_are_surfaced_in_stats() {
        let base = 1 << 22;
        let svc = BfpService::new(
            Arc::new(ExecRuntime::with_threads(2)),
            ServiceConfig {
                max_batch_macs: base,
                ..ServiceConfig::default()
            },
        );
        // Before any batch forms, the snapshot reports the base budget
        // and the registry-resolved kernel identity.
        let s0 = svc.stats();
        assert_eq!(s0.effective_batch_macs, base as u64);
        assert!(
            crate::bfp::registry().by_name(s0.kernel).is_some(),
            "stats kernel {:?} must be a registered backend",
            s0.kernel
        );
        // Run one request; the adaptive budget stays within its
        // [base/4, 4*base] envelope and the result is still exact.
        let mut rng = Rng::new(0xADA9);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let x = randmat(&mut rng, 3, 32);
        let w = randmat(&mut rng, 32, 5);
        let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        let resp = svc.submit(GemmRequest::new(op)).unwrap().wait().unwrap();
        let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
        for (g, s) in resp.out.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), s.to_bits());
        }
        let s1 = svc.stats();
        assert!(s1.batches >= 1);
        assert!(
            (base as u64 / 4..=4 * base as u64).contains(&s1.effective_batch_macs),
            "{}",
            s1.effective_batch_macs
        );
    }

    #[test]
    fn forced_kernel_choices_stay_bit_identical() {
        let mut rng = Rng::new(0x5CA1);
        let fmt4 = BlockFormat::new(4, 16).unwrap(); // nibble-packed planes
        let fmt6 = BlockFormat::new(6, 16).unwrap(); // i8 planes
        let ops: Vec<OwnedGemmOp> = [fmt4, fmt6]
            .iter()
            .flat_map(|&fmt| {
                let mut v = Vec::new();
                for _ in 0..3 {
                    v.push(
                        OwnedGemmOp::new(randmat(&mut rng, 4, 48), randmat(&mut rng, 48, 6), fmt)
                            .unwrap(),
                    );
                }
                v
            })
            .collect();
        for choice in [
            crate::util::KernelChoice::Scalar,
            crate::util::KernelChoice::Autovec,
            crate::util::KernelChoice::Avx2,
            crate::util::KernelChoice::Avx512,
            crate::util::KernelChoice::Neon,
        ] {
            let svc = BfpService::new(
                Arc::new(ExecRuntime::with_threads(2)),
                ServiceConfig {
                    kernel: choice,
                    ..ServiceConfig::default()
                },
            );
            assert!(!svc.stats().kernel.is_empty());
            for (i, op) in ops.iter().enumerate() {
                let resp = svc
                    .submit_blocking(GemmRequest::new(op.clone()))
                    .unwrap()
                    .wait()
                    .unwrap();
                let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
                for (g, s) in resp.out.data.iter().zip(&want.data) {
                    assert_eq!(
                        g.to_bits(),
                        s.to_bits(),
                        "kernel {:?} op {i}",
                        choice
                    );
                }
            }
        }
    }

    #[test]
    fn kernel_op_counts_and_preencode_residency_are_surfaced() {
        // A 1-byte pre-encode budget exercises the stalling path (the
        // progress guarantee claims at most one op at a time); results
        // and counts must come out exactly as with an ample budget.
        let svc = BfpService::new(
            Arc::new(ExecRuntime::with_threads(2)),
            ServiceConfig {
                pre_encode_cap_bytes: 1,
                ..ServiceConfig::default()
            },
        );
        let mut rng = Rng::new(0xC0DE);
        let fmt = BlockFormat::new(4, 16).unwrap();
        for _ in 0..3 {
            let x = randmat(&mut rng, 3, 32);
            let w = randmat(&mut rng, 32, 5);
            let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
            let resp = svc
                .submit_blocking(GemmRequest::new(op))
                .unwrap()
                .wait()
                .unwrap();
            let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
            for (g, s) in resp.out.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits());
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 3);
        // Every executed op is attributed to a registered backend.
        assert_eq!(stats.kernel_ops.total(), 3, "{:?}", stats.kernel_ops);
        for (name, _, _) in stats.kernel_ops.entries() {
            assert!(
                crate::bfp::registry().by_name(name).is_some(),
                "executed-kernel name {name:?} must be registered"
            );
        }
        // A drained queue holds no resident pre-encode bytes.
        assert_eq!(stats.pre_encode_resident_bytes, 0, "{stats:?}");
    }

    #[test]
    fn pre_encode_pipeline_fills_slots_while_paused_and_is_counted() {
        // Pause stops batch formation but NOT the pre-encode stage:
        // the encode thread keeps claiming and filling slots, which is
        // the deterministic way to observe the pipeline. After resume,
        // every op must execute from its pre-encoded slot.
        let svc = BfpService::with_threads(2);
        svc.pause();
        let mut rng = Rng::new(0x93E2);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let ops: Vec<OwnedGemmOp> = (0..6)
            .map(|_| {
                OwnedGemmOp::new(
                    randmat(&mut rng, 32, 96),
                    randmat(&mut rng, 96, 16),
                    fmt,
                )
                .unwrap()
            })
            .collect();
        let tickets: Vec<Ticket> = ops
            .iter()
            .map(|op| svc.submit(GemmRequest::new(op.clone())).unwrap())
            .collect();
        // The submitted clones share each op's encoded slot, so the
        // pipeline's progress is observable right here.
        let deadline = Instant::now() + Duration::from_secs(30);
        while !ops.iter().all(OwnedGemmOp::is_pre_encoded) {
            assert!(
                Instant::now() < deadline,
                "pre-encode stage never filled all slots"
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        svc.resume();
        for (t, op) in tickets.iter().zip(&ops) {
            let resp = t.wait().unwrap();
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            for (g, s) in resp.out.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits());
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.pre_encoded, 6, "{stats:?}");
        assert_eq!(stats.inline_encoded, 0, "{stats:?}");
        assert_eq!(stats.pre_encode_hit_rate(), 1.0);
        assert!(stats.encode_us > 0, "{stats:?}");
    }

    #[test]
    fn grouped_counters_partition_completed_ops() {
        // Pause so all ops land in one batch, four of them sharing one
        // weight: the scheduler must form a weight-stationary group and
        // the counters must partition exactly.
        let svc = BfpService::new(
            Arc::new(ExecRuntime::with_threads(2)),
            ServiceConfig {
                group_min_ops: 2,
                ..Default::default()
            },
        );
        svc.pause();
        let mut rng = Rng::new(0x6209);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let shared = randmat(&mut rng, 64, 5);
        let solo_w = randmat(&mut rng, 64, 5);
        let mut ops: Vec<OwnedGemmOp> = (0..4)
            .map(|i| {
                OwnedGemmOp::new(randmat(&mut rng, 3 + i, 64), Arc::clone(&shared), fmt).unwrap()
            })
            .collect();
        ops.push(OwnedGemmOp::new(randmat(&mut rng, 4, 64), solo_w, fmt).unwrap());
        let tickets: Vec<Ticket> = ops
            .iter()
            .map(|op| svc.submit(GemmRequest::new(op.clone())).unwrap())
            .collect();
        svc.resume();
        for (t, op) in tickets.iter().zip(&ops) {
            let resp = t.wait().unwrap();
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            for (g, s) in resp.out.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits());
            }
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 5);
        assert_eq!(
            stats.grouped_ops + stats.ungrouped_ops,
            stats.completed,
            "{stats:?}"
        );
        assert_eq!(stats.grouped_ops, 4, "{stats:?}");
        assert_eq!(stats.groups_formed, 1, "{stats:?}");
        assert!(stats.weight_plane_loads_avoided > 0, "{stats:?}");

        // With grouping disabled, the same traffic is all ungrouped.
        let off = BfpService::new(
            Arc::new(ExecRuntime::with_threads(2)),
            ServiceConfig {
                group_min_ops: 0,
                ..Default::default()
            },
        );
        off.pause();
        let tickets: Vec<Ticket> = ops
            .iter()
            .map(|op| off.submit(GemmRequest::new(op.clone())).unwrap())
            .collect();
        off.resume();
        for t in &tickets {
            t.wait().unwrap();
        }
        let stats = off.stats();
        assert_eq!(stats.grouped_ops, 0, "{stats:?}");
        assert_eq!(stats.groups_formed, 0, "{stats:?}");
        assert_eq!(stats.ungrouped_ops, 5, "{stats:?}");
        assert_eq!(stats.weight_plane_loads_avoided, 0, "{stats:?}");
    }

    #[test]
    fn decode_stage_counters_and_stage_times_are_surfaced() {
        let svc = BfpService::with_threads(2);
        let mut rng = Rng::new(0xDEC0);
        let fmt = BlockFormat::new(4, 16).unwrap();
        for _ in 0..5 {
            let x = randmat(&mut rng, 6, 64);
            let w = randmat(&mut rng, 64, 7);
            let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
            let resp = svc
                .submit_blocking(GemmRequest::new(op))
                .unwrap()
                .wait()
                .unwrap();
            let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
            for (g, s) in resp.out.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits());
            }
            assert!(resp.encode_ms >= 0.0 && resp.gemm_ms >= 0.0 && resp.decode_ms >= 0.0);
        }
        let stats = svc.stats();
        assert_eq!(stats.completed, 5);
        // Every op went through the decode stage (4-bit planes take the
        // MAC/decode split).
        assert_eq!(stats.decode_ops, 5, "{stats:?}");
        assert!(stats.decoded_overlapped <= stats.decode_ops);
        // Sequential same-shape ops recycle the previous op's staging
        // planes: from the second op on, checkouts hit the free list.
        assert!(stats.arena_hits > 0, "{stats:?}");
        assert!(stats.arena_recycled_bytes > 0, "{stats:?}");
        let rate = stats.arena_hit_rate();
        assert!((0.0..=1.0).contains(&rate), "{rate}");
        assert!((0.0..=1.0).contains(&stats.decode_overlap_rate()));
    }

    #[test]
    fn tiny_arena_cap_degrades_without_corruption() {
        // A 1-byte arena cap forces the stall/evict/degrade path on
        // every checkout; results must stay bit-identical and every
        // ticket fulfilled. (Kept to few/small ops — each over-cap
        // checkout stalls briefly before degrading.)
        let svc = BfpService::new(
            Arc::new(ExecRuntime::new_with_caps(2, 16, 1 << 20, 1)),
            ServiceConfig::default(),
        );
        let mut rng = Rng::new(0xCA9);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let x = randmat(&mut rng, 3, 32);
        let w = randmat(&mut rng, 32, 4);
        let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        let resp = svc.submit(GemmRequest::new(op)).unwrap().wait().unwrap();
        let want = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
        for (g, s) in resp.out.data.iter().zip(&want.data) {
            assert_eq!(g.to_bits(), s.to_bits());
        }
        let stats = svc.stats();
        assert_eq!((stats.completed, stats.failed), (1, 0));
        assert_eq!(stats.decode_ops, 1, "{stats:?}");
    }

    #[test]
    fn global_service_is_singleton() {
        let a = global() as *const BfpService;
        let b = global() as *const BfpService;
        assert_eq!(a, b);
        assert!(global().queue_capacity() >= 1);
    }
}
