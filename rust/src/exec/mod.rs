//! The BFP **execution service**: an asynchronous submit/ticket front
//! door ([`service::BfpService`]) over a persistent worker pool, an
//! encoded-operand cache, and the batched/sharded GEMM execution stage
//! — the host-side throughput layer the paper's density argument needs
//! to pay off at system level.
//!
//! PR 1 made the fixed-point datapath bandwidth-bound per call; PR 2
//! made it saturable across calls with [`BatchGemm`]; PR 3 moved batch
//! formation off the caller's critical path; PR 5 split the service
//! into pipelined encode/execute stages; PR 7 completed the
//! **three-stage pipeline** — encode, integer-MAC GEMM, and f32
//! decode/writeback each run on their own stage, with a size-classed
//! [`arena::BufferArena`] recycling the buffers that flow between
//! them. The **front door of this module is [`service::BfpService`]**:
//!
//! * [`BfpService::submit`](service::BfpService::submit) is
//!   non-blocking — it admits an owned [`OwnedGemmOp`] wrapped in a
//!   [`GemmRequest`] (optional deadline + [`Priority`] class) and hands
//!   back a [`Ticket`]; a full bounded queue returns the typed
//!   [`AdmissionError::QueueFull`] instead of blocking (backpressure is
//!   the caller's signal, not a hidden wait);
//! * **stage 1 — pre-encode**: a dedicated thread claims admitted
//!   requests and encodes their operands ahead of execution —
//!   activations on the shared pool, weights through the operand cache
//!   — into each op's shared encoded slot, while the previous batch's
//!   GEMM is still running. Encoding is deterministic, so the pipeline
//!   is pure overlap: pre-encoded and inline-encoded ops are
//!   bit-identical (property-pinned), and [`ServiceStats`] reports the
//!   pre-encode hit rate and cumulative encode-stage latency;
//! * **stage 2 — MAC/GEMM**: a dedicated scheduler thread forms
//!   earliest-deadline-first, MAC-budgeted batches and drives the
//!   split execution path
//!   ([`BatchGemm::run_split_with_stats`](scheduler::BatchGemm::run_split_with_stats)):
//!   narrow-mantissa ops stop after storing raw `i32` block MACs into
//!   arena-backed planes; wide ops run the fused kernel. Since PR 10
//!   the split path is **weight-stationary**: split ops sharing one
//!   encoded weight — keyed by `(content digest, mantissa bits, block
//!   size)` — execute as a single grouped GEMM whose logical tall-M
//!   operand stacks the member activations, so the shared weight
//!   planes stream through memory once per band tile per *group*
//!   instead of once per *op*. Each member's MAC plane is written in
//!   place (the stack is virtual; scatter is free), the queue's
//!   `pop_batch` pulls same-digest ops into a batch's MAC-budget
//!   headroom without ever jumping a waiting higher-priority class,
//!   and `BOOSTERS_GROUP_MIN_OPS` (default 2; `0` disables) gates the
//!   whole path. Stored split-path MACs are exact independent `i32`
//!   integers, so grouped and per-op traversal are bit-identical by
//!   construction (pinned by `tests/property_group.rs`). The blocking
//!   [`BatchGemm::run`] stays a thin synchronous facade for
//!   tests/benches — it never touches the arena or the decode stage;
//! * **stage 3 — decode/writeback**: a dedicated decode thread turns
//!   staged MACs into f32 outputs (band-sharded on the same pool,
//!   bit-identical by construction — it replays the exact per-element
//!   `f64` scale-shift sum the fused kernels run), publishes each
//!   [`Ticket`]'s result, and returns staging buffers to the arena.
//!   Because fulfillment happens here, the scheduler is already
//!   forming and executing batch `n + 1` while batch `n` decodes —
//!   [`ServiceStats::decoded_overlapped`] counts exactly those ops;
//! * the [`arena::BufferArena`] (byte-capped by `BOOSTERS_ARENA_MB`,
//!   default 512 MiB) recycles output `Mat`s, `i32` MAC/shift planes,
//!   and encode scratch across batches: checked out per batch,
//!   returned on ticket take or drop. Over-cap checkouts briefly stall
//!   for returns, then evict free buffers and proceed — the cap
//!   degrades to backpressure, never to corruption. Hit/miss/recycled
//!   counters surface in [`ServiceStats`] and
//!   [`crate::metrics::exec_service_snapshot`];
//! * synchronous consumers (`hbfp_gemm`, `dequant_gemm`, the Trainer's
//!   host-BFP weight store) go through labeled
//!   [`ServiceSession`](service::ServiceSession)s.
//!
//! Pause/drain semantics cover all three stages: `set_paused` gates
//! batch formation while admission and pre-encode keep running, and
//! service drop drains admitted work through MAC **and** decode before
//! joining any stage thread — every admitted ticket is always
//! fulfilled.
//!
//! # Pool lifecycle
//!
//! The process-wide [`ExecRuntime`] (reached via [`global`] /
//! [`global_arc`], and serving the process-wide
//! [`service::global`] service) is created lazily on first use and
//! lives for the remainder of the process. Its [`WorkerPool`] is sized
//! **once** at creation from [`crate::util::gemm_thread_budget`]
//! (`BOOSTERS_GEMM_THREADS` override, else `available_parallelism`,
//! capped at 16); later changes to the environment variable do not
//! resize a pool that already exists. A budget of 1 spawns no OS
//! threads: all work runs inline on the caller, which is the strict-
//! serial reference mode. Tests and embedders can build private
//! runtimes with [`ExecRuntime::with_threads`] (dropping one joins its
//! workers) and private services with
//! [`service::BfpService::with_threads`] (dropping one drains admitted
//! work, then joins its scheduler).
//!
//! # Cache keying
//!
//! The [`OperandCache`] is content-addressed: `(128-bit fingerprint of
//! the raw f32 bits + shape, mantissa_bits, block_size, plane layout,
//! transposed)` — see [`cache::CacheKey`]. The
//! [`crate::bfp::PlaneLayout`] component means an entry encoded under
//! one mantissa storage layout (nibble-packed 4-bit pairs vs i8 vs
//! i16) is never served to a consumer keyed for another. Only
//! deterministic nearest-even encodings are cacheable (stochastic
//! rounding depends on seed/site state); the `encode_*_cached` entry
//! points enforce this by construction. The cache is LRU-bounded by
//! entry count and by approximate resident bytes (nibble-packed planes
//! charge half a byte per mantissa); the caps come from
//! [`crate::util::cache_budget`] (`BOOSTERS_CACHE_ENTRIES` /
//! `BOOSTERS_CACHE_MB`, defaults 96 entries / 128 MiB), and its
//! hit/miss/eviction counters are surfaced through
//! [`crate::metrics::exec_cache_snapshot`].
//!
//! # Kernel backends
//!
//! The GEMM inner loops executed by the pool come from the
//! [`crate::bfp::kernels`] registry (scalar / autovec / AVX2, selected
//! per operand-layout pair, `BOOSTERS_KERNEL` override). [`BatchGemm`]
//! resolves the kernel per op; [`service::BfpService`] reports the
//! registry's preferred backend in
//! [`crate::metrics::exec_service_snapshot`] so serving artifacts are
//! attributable to the kernel that produced them. Kernel choice can
//! never change results — every backend is bit-identical to the
//! scalar reference, which the property suites pin per backend.
//!
//! # Determinism guarantees
//!
//! The runtime and the service schedule *where and when* work runs,
//! never *what* is computed:
//!
//! * every output element is produced by exactly one band job, which
//!   accumulates its blocks in ascending contraction order;
//! * encoding is per-block independent, so parallel encode equals
//!   serial encode bit-for-bit (including the stochastic stream, which
//!   is indexed by absolute block position);
//! * cached operands are byte-identical to freshly encoded ones
//!   (deterministic nearest rounding, content-addressed identity);
//! * pre-encoded operands (the pipeline's admission-time encode) are
//!   byte-identical to inline-encoded ones — the encode race between
//!   the pre-encode stage and the execution stage can only change
//!   **who** encodes, never what;
//! * admission order, priority classes, deadlines, and batch-budget
//!   cuts reorder **execution**, never accumulation.
//!
//! Consequently service responses, [`BatchGemm`], and `gemm_packed`
//! results are **bit-identical** across thread counts, shard sizes,
//! batch orderings, arrival orders, and cache hits/misses — and
//! bit-identical to the scalar reference
//! [`crate::bfp::hbfp_gemm_scalar`]. `tests/property_exec.rs` and
//! `tests/property_service.rs` pin all of these.
//!
//! # Cross-node execution
//!
//! [`crate::fabric`] stretches this module's submit/ticket surface
//! across processes: `repro fabric-runner` hosts a [`BfpService`]
//! behind a TCP socket (speaking the versioned frame protocol of
//! [`crate::fabric::wire`]), and [`crate::fabric::FabricRouter`]
//! re-offers `submit → Ticket` over N runners, sharding by deadline
//! slack × per-runner outstanding-MAC budget. The pieces the fabric
//! reuses from here are load-bearing contracts, not conveniences:
//!
//! * [`AdmissionError`] is the backpressure type **on the wire** — a
//!   runner's queue-full/shutting-down/invalid-shape rejection arrives
//!   at the remote caller as the same typed error a local `submit`
//!   returns (`queue::AdmissionError::wire_code`/`from_wire`);
//! * the operand cache's 128-bit content fingerprint
//!   ([`crate::util::digest`], the first component of
//!   [`cache::CacheKey`]) doubles as the transfer-dedup identity:
//!   weights cross the wire as **encoded** planes at most once per
//!   distinct digest per runner;
//! * the determinism guarantees above are what make router failover
//!   correct — a re-placed op re-executes on a different runner and
//!   fulfills its ticket with a bit-identical result.

pub mod arena;
pub mod cache;
pub mod pool;
pub mod queue;
pub mod scheduler;
pub mod service;

pub use arena::{ArenaStats, BufferArena};
pub use cache::{CacheKey, CacheStats, OperandCache};
pub use pool::{Job, WorkerPool};
pub use queue::{AdmissionError, GemmRequest, GemmResponse, Priority, Ticket};
pub use scheduler::{BatchGemm, EncodeReport, OwnedGemmOp};
pub use service::{adaptive_batch_macs, BfpService, ServiceConfig, ServiceSession, ServiceStats};

use crate::bfp::{BfpMatrix, BlockFormat, Mat, Quantizer};
use anyhow::Result;
use std::sync::{Arc, OnceLock};

/// One worker pool + one operand cache + one buffer arena: the unit
/// every execution-path consumer shares. See the module docs for
/// lifecycle and guarantees.
pub struct ExecRuntime {
    pool: WorkerPool,
    cache: OperandCache,
    arena: Arc<BufferArena>,
}

impl ExecRuntime {
    pub fn new(threads: usize, cache_entries: usize, cache_bytes: usize) -> Self {
        Self::new_with_caps(
            threads,
            cache_entries,
            cache_bytes,
            crate::util::DEFAULT_ARENA_BYTES,
        )
    }

    /// [`ExecRuntime::new`] with an explicit arena residency cap in
    /// bytes — tests use tiny caps to exercise the arena's
    /// stall/evict/degrade path.
    pub fn new_with_caps(
        threads: usize,
        cache_entries: usize,
        cache_bytes: usize,
        arena_bytes: u64,
    ) -> Self {
        Self {
            pool: WorkerPool::with_threads(threads),
            cache: OperandCache::new(cache_entries, cache_bytes),
            arena: Arc::new(BufferArena::new(arena_bytes)),
        }
    }

    /// A runtime with explicit parallelism and default cache bounds.
    pub fn with_threads(threads: usize) -> Self {
        let (entries, bytes) = crate::util::default_cache_budget();
        Self::new(threads, entries, bytes)
    }

    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    pub fn cache(&self) -> &OperandCache {
        &self.cache
    }

    /// The size-classed recycling arena behind the pipeline's output
    /// and staging buffers (`BOOSTERS_ARENA_MB` for the global
    /// runtime's cap).
    pub fn arena(&self) -> &Arc<BufferArena> {
        &self.arena
    }

    pub fn arena_stats(&self) -> ArenaStats {
        self.arena.stats()
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// A batch scheduler bound to this runtime — the synchronous
    /// execution-stage facade ([`service::BfpService`] is the async
    /// front door).
    pub fn batch(&self) -> BatchGemm<'_> {
        BatchGemm::new(self)
    }

    /// Row-encode `data` (`rows x cols`, blocked along columns) through
    /// the operand cache, encoding on **this runtime's** pool on a miss.
    /// Nearest rounding only — see module docs.
    pub fn encode_cached(
        &self,
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
    ) -> Result<Arc<BfpMatrix>> {
        let key = CacheKey::for_matrix(data, rows, cols, fmt, false);
        self.cache.get_or_encode(key, || {
            let mut m = BfpMatrix::empty();
            m.encode_into_on(
                &self.pool,
                data,
                rows,
                cols,
                fmt,
                Quantizer::nearest(fmt.mantissa_bits),
                0,
            )?;
            Ok(m)
        })
    }

    /// Column-encode the weight matrix `w` (`k x n`, blocked along K)
    /// through the operand cache, encoding on **this runtime's** pool on
    /// a miss. Nearest rounding only.
    pub fn encode_transposed_cached(&self, w: &Mat, fmt: BlockFormat) -> Result<Arc<BfpMatrix>> {
        let key = CacheKey::for_matrix(&w.data, w.rows, w.cols, fmt, true);
        self.cache.get_or_encode(key, || {
            let mut m = BfpMatrix::empty();
            m.encode_transposed_on(&self.pool, w, fmt, Quantizer::nearest(fmt.mantissa_bits))?;
            Ok(m)
        })
    }
}

static GLOBAL: OnceLock<Arc<ExecRuntime>> = OnceLock::new();

fn global_cell() -> &'static Arc<ExecRuntime> {
    GLOBAL.get_or_init(|| {
        let (entries, bytes) = crate::util::cache_budget();
        Arc::new(ExecRuntime::new_with_caps(
            crate::util::gemm_thread_budget().min(16),
            entries,
            bytes,
            crate::util::arena_budget(),
        ))
    })
}

/// The process-wide runtime. Created on first use; the pool is sized by
/// [`crate::util::gemm_thread_budget`] (capped at 16 workers).
pub fn global() -> &'static ExecRuntime {
    global_cell().as_ref()
}

/// Owning handle to the process-wide runtime — what
/// [`service::BfpService`] and other thread-crossing embedders hold.
pub fn global_arc() -> Arc<ExecRuntime> {
    Arc::clone(global_cell())
}

/// The process-wide service over the global runtime (see
/// [`service::global`]).
pub fn global_service() -> &'static BfpService {
    service::global()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn global_runtime_is_singleton_and_sized() {
        let a = global() as *const ExecRuntime;
        let b = global() as *const ExecRuntime;
        assert_eq!(a, b);
        assert!(global().pool().threads() >= 1);
        assert!(Arc::ptr_eq(&global_arc(), &global_arc()));
    }

    #[test]
    fn cached_encode_is_bit_identical_to_direct_encode() {
        let rt = ExecRuntime::with_threads(2);
        let mut rng = Rng::new(31);
        let data: Vec<f32> = (0..500).map(|_| rng.normal_scaled(1.0)).collect();
        let fmt = BlockFormat::new(4, 64).unwrap();
        let cached = rt.encode_cached(&data, 1, data.len(), fmt).unwrap();
        let direct = BfpMatrix::encode(&data, 1, data.len(), fmt, Quantizer::nearest(4)).unwrap();
        assert_eq!(cached.exponents, direct.exponents);
        // m=4 with an even block: nibble-packed planes, byte-compared.
        assert_eq!(
            cached.mantissas.try_i4().unwrap(),
            direct.mantissas.try_i4().unwrap()
        );
        // Second call is a hit returning the same planes.
        let again = rt.encode_cached(&data, 1, data.len(), fmt).unwrap();
        assert!(Arc::ptr_eq(&cached, &again));
        let s = rt.cache_stats();
        assert_eq!((s.hits, s.misses), (1, 1));
    }

    #[test]
    fn transposed_and_row_encodings_do_not_alias() {
        let rt = ExecRuntime::with_threads(1);
        let mut rng = Rng::new(32);
        let w = Mat::new(16, 4, (0..64).map(|_| rng.normal_scaled(1.0)).collect()).unwrap();
        let fmt = BlockFormat::new(6, 16).unwrap();
        let t = rt.encode_transposed_cached(&w, fmt).unwrap();
        let r = rt.encode_cached(&w.data, 16, 4, fmt).unwrap();
        // Same bytes, different layout flag: two distinct entries.
        assert_eq!(rt.cache_stats().entries, 2);
        assert_eq!((t.rows, t.cols), (4, 16));
        assert_eq!((r.rows, r.cols), (16, 4));
    }
}
