//! Encoded-operand cache: content-addressed reuse of packed BFP planes.
//!
//! Serving and emulation workloads multiply **the same weight planes**
//! against a stream of fresh activations, and the Trainer's host-BFP
//! weight store re-grids parameter tensors every epoch even when a
//! tensor did not change. Encoding is the expensive part of those paths
//! (quantize + plane packing); the cache makes it pay-once. Since PR 5
//! the service's **pre-encode stage** is a first-class writer too: it
//! pulls weight operands through this cache at admission time, so by
//! the time a batch executes, repeated weights are already resident —
//! hit/miss accounting is identical whichever stage did the pull.
//!
//! # Keying
//!
//! Entries are keyed by [`CacheKey`]: a 128-bit content fingerprint of
//! the raw f32 bits plus the logical shape, the `(mantissa_bits,
//! block_size)` format, the **mantissa-plane storage layout**
//! ([`PlaneLayout`] — nibble-packed vs byte vs i16 planes are distinct
//! encodings of the same values, and a consumer must never be served
//! one when it asked for another), and the orientation flag
//! (row-encoded vs column/transposed-encoded). Two FNV-1a streams over
//! independent bases make accidental collisions across a process
//! lifetime negligible; shape is mixed in so a reshape of the same
//! bytes cannot alias. The fingerprint itself is single-homed in
//! [`crate::util::digest`] — the fabric wire protocol ships the same
//! [`Digest`] for cross-node transfer dedup, so cache and wire agree
//! byte-for-byte by construction (stability test pins known values).
//!
//! **Only deterministic nearest-even encodings are cacheable.**
//! Stochastic rounding depends on `(seed, site)` and must never be
//! served from cache; the runtime's `encode_*_cached` entry points
//! therefore always encode with [`Quantizer::nearest`].
//!
//! # Bounds and counters
//!
//! The cache is LRU-evicted under two simultaneous caps (entry count
//! and approximate plane bytes). Hit/miss/eviction counters are atomic
//! and cheap; [`OperandCache::stats`] snapshots them for the metrics
//! surface ([`crate::metrics::exec_cache_snapshot`]) and the serve-sim
//! report. Concurrent [`OperandCache::get_or_encode`] misses on the
//! same key coalesce onto one in-flight encode (one miss, the rest
//! hits), so the pre-encode and execution stages racing on a cold
//! weight never pay for — or count — the same encode twice.

use crate::bfp::{BfpMatrix, BlockFormat, PlaneLayout};
use crate::util::digest::{content_fingerprint, Digest};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Identity of one encoded operand (see module docs for the contract).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// 128-bit content fingerprint over raw f32 bits + shape — the
    /// same [`Digest`] the fabric wire protocol ships for transfer
    /// dedup (single-homed in [`crate::util::digest`] so the two can
    /// never disagree).
    pub content: Digest,
    pub m_bits: u32,
    pub block: usize,
    /// Mantissa-plane storage layout the entry was encoded under. Today
    /// this is a function of `(m_bits, block)`, but it is part of the
    /// key on purpose: if the layout rule ever changes (or becomes
    /// configurable), stale entries in a different layout must read as
    /// misses, not be served to a kernel expecting other storage.
    pub layout: PlaneLayout,
    /// True for weight-side (column/transposed) encodings.
    pub transposed: bool,
}

impl CacheKey {
    pub fn for_matrix(
        data: &[f32],
        rows: usize,
        cols: usize,
        fmt: BlockFormat,
        transposed: bool,
    ) -> Self {
        Self {
            content: content_fingerprint(data, rows, cols),
            m_bits: fmt.mantissa_bits,
            block: fmt.block_size,
            layout: fmt.plane_layout(),
            transposed,
        }
    }
}

/// Approximate resident bytes of one encoded matrix (mantissa plane +
/// exponent plane), used for the byte cap. Nibble-packed planes charge
/// half a byte per mantissa — the cache holds twice as many 4-bit
/// weights under the same `BOOSTERS_CACHE_MB` budget.
fn plane_bytes(m: &BfpMatrix) -> usize {
    m.mantissas.resident_bytes() + m.exponents.len() * std::mem::size_of::<i32>()
}

struct Entry {
    value: Arc<BfpMatrix>,
    bytes: usize,
    last_used: u64,
}

/// One coalesced encode in flight. The owner publishes its outcome
/// here so waiters are **handed the encoded planes directly** — not
/// re-looked-up in the map, because `insert` can legitimately decline
/// to retain a value (larger than the byte cap, or instantly evicted)
/// and waiters must still be served without re-encoding.
struct Flight {
    /// `Some(planes)` on success; `None` when the owning encode failed
    /// or panicked (waiters then race to become the next owner).
    outcome: OnceLock<Option<Arc<BfpMatrix>>>,
}

struct CacheState {
    entries: HashMap<CacheKey, Entry>,
    /// Keys whose encode is currently running outside the lock.
    /// [`OperandCache::get_or_encode`] coalesces concurrent misses on
    /// the same key: one caller encodes (one miss), the rest wait on
    /// `flight_cv` and consume the [`Flight`] handoff (hits) — so the
    /// pipelined pre-encode stage and the execution stage can never
    /// both pay for (or both count a miss for) the same weight.
    in_flight: HashMap<CacheKey, Arc<Flight>>,
    tick: u64,
    bytes: usize,
}

/// Counter snapshot (also re-exported through [`crate::metrics`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
    pub bytes: usize,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} hits / {} misses ({:.1}% hit rate), {} entries, {:.1} KiB resident, {} evictions",
            self.hits,
            self.misses,
            100.0 * self.hit_rate(),
            self.entries,
            self.bytes as f64 / 1024.0,
            self.evictions
        )
    }
}

/// Bounded, thread-safe, content-addressed store of encoded operands.
pub struct OperandCache {
    state: Mutex<CacheState>,
    /// Wakes callers waiting for another thread's in-flight encode of
    /// the same key (see `CacheState::in_flight`).
    flight_cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    preloads: AtomicU64,
    max_entries: usize,
    max_bytes: usize,
}

/// Clears a key's in-flight reservation and wakes coalesced waiters
/// when the owning encode finishes — on success, error, or panic (the
/// drop runs on unwind too, so a panicking encode can never strand its
/// waiters; publishing `None` makes them race to take over).
struct FlightGuard<'a> {
    cache: &'a OperandCache,
    key: CacheKey,
    flight: Arc<Flight>,
}

impl CacheState {
    /// Deregister `flight` from the in-flight map — but only if it is
    /// still the registered flight for `key` (a failed flight's waiters
    /// may already have installed a successor). The single home of the
    /// flight-lifecycle invariant, shared by the owner's guard and the
    /// waiter takeover path.
    fn deregister_flight(&mut self, key: &CacheKey, flight: &Arc<Flight>) {
        if let Some(cur) = self.in_flight.get(key) {
            if Arc::ptr_eq(cur, flight) {
                self.in_flight.remove(key);
            }
        }
    }
}

impl Drop for FlightGuard<'_> {
    fn drop(&mut self) {
        // No-op when the owner already published success; on error or
        // panic this marks the flight failed so waiters take over.
        let _ = self.flight.outcome.set(None);
        if let Ok(mut st) = self.cache.state.lock() {
            st.deregister_flight(&self.key, &self.flight);
        }
        self.cache.flight_cv.notify_all();
    }
}

impl OperandCache {
    pub fn new(max_entries: usize, max_bytes: usize) -> Self {
        Self {
            state: Mutex::new(CacheState {
                entries: HashMap::new(),
                in_flight: HashMap::new(),
                tick: 0,
                bytes: 0,
            }),
            flight_cv: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            preloads: AtomicU64::new(0),
            max_entries: max_entries.max(1),
            max_bytes: max_bytes.max(1),
        }
    }

    /// Look up an encoding, refreshing its LRU stamp. Counts a hit or a
    /// miss.
    pub fn lookup(&self, key: &CacheKey) -> Option<Arc<BfpMatrix>> {
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        match st.entries.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(Arc::clone(&e.value))
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert an encoding, evicting least-recently-used entries until
    /// both caps hold. Values larger than the whole byte budget are not
    /// cached at all.
    pub fn insert(&self, key: CacheKey, value: Arc<BfpMatrix>) {
        let bytes = plane_bytes(&value);
        if bytes > self.max_bytes {
            return;
        }
        let mut st = self.state.lock().unwrap();
        st.tick += 1;
        let tick = st.tick;
        if let Some(old) = st.entries.insert(
            key,
            Entry {
                value,
                bytes,
                last_used: tick,
            },
        ) {
            st.bytes -= old.bytes;
        }
        st.bytes += bytes;
        while st.entries.len() > self.max_entries || st.bytes > self.max_bytes {
            let victim = st
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k);
            let Some(victim) = victim else { break };
            if victim == key && st.entries.len() == 1 {
                break;
            }
            if let Some(e) = st.entries.remove(&victim) {
                st.bytes -= e.bytes;
            }
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// The cache's main entry point: return the cached encoding for
    /// `key`, or run `encode` (outside the lock), cache the result, and
    /// return it. Errors from `encode` propagate and cache nothing.
    ///
    /// Concurrent misses on the same key **coalesce**: exactly one
    /// caller runs `encode` (counting the one miss) while the others
    /// wait and are handed the encoded planes directly (counting hits)
    /// — so two pipeline stages racing on a cold weight can never both
    /// pay the encode or double-count the miss, even when the value is
    /// too large for the cache to retain. If the owning encode fails,
    /// one waiter takes over as the new encoder (with its own miss).
    pub fn get_or_encode(
        &self,
        key: CacheKey,
        encode: impl FnOnce() -> Result<BfpMatrix>,
    ) -> Result<Arc<BfpMatrix>> {
        let flight = loop {
            let mut st = self.state.lock().unwrap();
            st.tick += 1;
            let tick = st.tick;
            if let Some(e) = st.entries.get_mut(&key) {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(Arc::clone(&e.value));
            }
            match st.in_flight.get(&key) {
                None => {
                    // This caller owns the encode for `key`.
                    let flight = Arc::new(Flight {
                        outcome: OnceLock::new(),
                    });
                    st.in_flight.insert(key, Arc::clone(&flight));
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    break flight;
                }
                Some(f) => {
                    // Another thread is encoding this key right now:
                    // wait for its outcome.
                    let f = Arc::clone(f);
                    while f.outcome.get().is_none() {
                        st = self.flight_cv.wait(st).unwrap();
                    }
                    match f.outcome.get().expect("flight outcome published") {
                        Some(v) => {
                            self.hits.fetch_add(1, Ordering::Relaxed);
                            return Ok(Arc::clone(v));
                        }
                        None => {
                            // The owner failed. Deregister the dead
                            // flight and retry as a candidate owner.
                            st.deregister_flight(&key, &f);
                        }
                    }
                }
            }
        };
        let guard = FlightGuard {
            cache: self,
            key,
            flight: Arc::clone(&flight),
        };
        let value = Arc::new(encode()?);
        self.insert(key, Arc::clone(&value));
        // Hand waiters the planes directly (the insert above may have
        // declined to retain them), then deregister via the guard.
        let _ = flight.outcome.set(Some(Arc::clone(&value)));
        drop(guard);
        Ok(value)
    }

    /// Publish an **already-encoded** value (a registry warm start)
    /// without charging the hit/miss accounting — preloads are not
    /// workload traffic, and the warm-start speedup claim rests on the
    /// subsequent lookups being real hits. Returns whether the value
    /// fit under the byte cap (an over-budget plane is not retained,
    /// exactly as [`Self::insert`] declines it). Only deterministic
    /// nearest-even encodings may be preloaded — the same cacheability
    /// contract as every other writer (see module docs).
    pub fn preload(&self, key: CacheKey, value: Arc<BfpMatrix>) -> bool {
        let fits = plane_bytes(&value) <= self.max_bytes;
        self.preloads.fetch_add(1, Ordering::Relaxed);
        self.insert(key, value);
        fits
    }

    /// Total values published through [`Self::preload`].
    pub fn preloads(&self) -> u64 {
        self.preloads.load(Ordering::Relaxed)
    }

    pub fn stats(&self) -> CacheStats {
        let st = self.state.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: st.entries.len(),
            bytes: st.bytes,
        }
    }

    /// Configured caps `(max_entries, max_bytes)` — surfaced so bench
    /// and serving artifacts can describe the cache they ran under.
    pub fn caps(&self) -> (usize, usize) {
        (self.max_entries, self.max_bytes)
    }

    /// Drop every entry (counters are preserved).
    pub fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        st.entries.clear();
        st.bytes = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::Quantizer;

    fn fmt(m: u32, b: usize) -> BlockFormat {
        BlockFormat::new(m, b).unwrap()
    }

    fn encode(data: &[f32], f: BlockFormat) -> BfpMatrix {
        BfpMatrix::encode(data, 1, data.len(), f, Quantizer::nearest(f.mantissa_bits)).unwrap()
    }

    #[test]
    fn fingerprint_separates_content_shape_and_format() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 2.0, 3.0, 5.0];
        assert_eq!(content_fingerprint(&a, 2, 2), content_fingerprint(&a, 2, 2));
        assert_ne!(content_fingerprint(&a, 2, 2), content_fingerprint(&b, 2, 2));
        assert_ne!(content_fingerprint(&a, 2, 2), content_fingerprint(&a, 1, 4));
        let k1 = CacheKey::for_matrix(&a, 2, 2, fmt(4, 16), false);
        let k2 = CacheKey::for_matrix(&a, 2, 2, fmt(6, 16), false);
        let k3 = CacheKey::for_matrix(&a, 2, 2, fmt(4, 16), true);
        assert_ne!(k1, k2);
        assert_ne!(k1, k3);
        // The storage layout is part of the operand identity.
        assert_eq!(k1.layout, PlaneLayout::I4Packed);
        assert_eq!(k2.layout, PlaneLayout::I8);
    }

    #[test]
    fn layout_mismatch_reads_as_a_miss() {
        // An entry inserted under one PlaneLayout must never be served
        // to a lookup expecting another, even if every other key field
        // matches (the guard for future layout-rule changes).
        let cache = OperandCache::new(8, 1 << 20);
        let d: Vec<f32> = (0..32).map(|i| i as f32 * 0.5).collect();
        let f = fmt(4, 16);
        let key = CacheKey::for_matrix(&d, 1, 32, f, false);
        cache.insert(key, Arc::new(encode(&d, f)));
        assert!(cache.lookup(&key).is_some());
        let stale = CacheKey {
            layout: PlaneLayout::I8,
            ..key
        };
        assert!(cache.lookup(&stale).is_none(), "layout must partition entries");
    }

    #[test]
    fn nibble_packed_entries_charge_half_the_plane_bytes() {
        let d: Vec<f32> = (0..256).map(|i| i as f32 * 0.25 - 32.0).collect();
        let packed = encode(&d, fmt(4, 16));
        let bytes8 = encode(&d, fmt(5, 16));
        // Same element count; the m=4 plane resides in half the bytes
        // (plus the identical exponent plane).
        let exp_bytes = packed.exponents.len() * std::mem::size_of::<i32>();
        assert_eq!(plane_bytes(&packed) - exp_bytes, 128);
        assert_eq!(plane_bytes(&bytes8) - exp_bytes, 256);
    }

    #[test]
    fn hit_miss_counting_and_reuse() {
        let cache = OperandCache::new(8, 1 << 20);
        let data: Vec<f32> = (0..64).map(|i| i as f32 * 0.25 - 4.0).collect();
        let key = CacheKey::for_matrix(&data, 1, 64, fmt(4, 16), false);
        let first = cache
            .get_or_encode(key, || Ok(encode(&data, fmt(4, 16))))
            .unwrap();
        let second = cache
            .get_or_encode(key, || panic!("must be served from cache"))
            .unwrap();
        assert!(Arc::ptr_eq(&first, &second));
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert!(s.bytes > 0);
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn entry_cap_evicts_lru() {
        let cache = OperandCache::new(2, 1 << 20);
        let f = fmt(4, 16);
        let mk = |seed: f32| -> (CacheKey, Vec<f32>) {
            let d: Vec<f32> = (0..32).map(|i| i as f32 + seed).collect();
            (CacheKey::for_matrix(&d, 1, 32, f, false), d)
        };
        let (k1, d1) = mk(0.5);
        let (k2, d2) = mk(1.5);
        let (k3, d3) = mk(2.5);
        cache.get_or_encode(k1, || Ok(encode(&d1, f))).unwrap();
        cache.get_or_encode(k2, || Ok(encode(&d2, f))).unwrap();
        // Touch k1 so k2 is the LRU victim when k3 arrives.
        assert!(cache.lookup(&k1).is_some());
        cache.get_or_encode(k3, || Ok(encode(&d3, f))).unwrap();
        let s = cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert!(cache.lookup(&k1).is_some());
        assert!(cache.lookup(&k2).is_none(), "k2 was the LRU victim");
    }

    #[test]
    fn byte_cap_and_oversized_values() {
        let f = fmt(4, 16);
        let d: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let enc = encode(&d, f);
        let bytes = plane_bytes(&enc);
        // A cache smaller than one entry refuses to store it.
        let tiny = OperandCache::new(8, bytes - 1);
        let key = CacheKey::for_matrix(&d, 1, 256, f, false);
        tiny.insert(key, Arc::new(enc.clone()));
        assert_eq!(tiny.stats().entries, 0);
        // A cache holding exactly one entry evicts on the second insert.
        let one = OperandCache::new(8, bytes + bytes / 2);
        one.insert(key, Arc::new(enc.clone()));
        let d2: Vec<f32> = (0..256).map(|i| i as f32 + 0.5).collect();
        let key2 = CacheKey::for_matrix(&d2, 1, 256, f, false);
        one.insert(key2, Arc::new(encode(&d2, f)));
        let s = one.stats();
        assert_eq!(s.entries, 1);
        assert_eq!(s.evictions, 1);
        assert!(one.lookup(&key2).is_some());
    }

    #[test]
    fn concurrent_get_or_encode_coalesces_in_flight_misses() {
        use std::sync::atomic::AtomicUsize;
        let cache = OperandCache::new(8, 1 << 20);
        let d: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let f = fmt(4, 16);
        let key = CacheKey::for_matrix(&d, 1, 64, f, false);
        let encodes = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    let got = cache
                        .get_or_encode(key, || {
                            encodes.fetch_add(1, Ordering::SeqCst);
                            // Hold the in-flight window open so racing
                            // callers actually overlap it.
                            std::thread::sleep(std::time::Duration::from_millis(20));
                            Ok(encode(&d, f))
                        })
                        .unwrap();
                    assert_eq!(got.mantissas.len(), 64);
                });
            }
        });
        // Whoever won the race encoded; everyone else was served the
        // same entry — one miss, one encode, three hits, regardless of
        // interleaving.
        assert_eq!(encodes.load(Ordering::SeqCst), 1, "in-flight misses must coalesce");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (3, 1, 1), "{s:?}");
    }

    #[test]
    fn oversized_values_are_handed_off_without_convoy_or_reencode() {
        use std::sync::atomic::AtomicUsize;
        // A value larger than the byte cap is never retained by the
        // map, so waiters must be served through the flight handoff —
        // not by re-looking-up the map and re-encoding serially.
        let d: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let f = fmt(4, 16);
        let too_small = plane_bytes(&encode(&d, f)) - 1;
        let cache = OperandCache::new(8, too_small);
        let key = CacheKey::for_matrix(&d, 1, 256, f, false);
        let encodes = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..3 {
                s.spawn(|| {
                    let v = cache
                        .get_or_encode(key, || {
                            encodes.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_millis(15));
                            Ok(encode(&d, f))
                        })
                        .unwrap();
                    assert_eq!(v.mantissas.len(), 256);
                });
            }
        });
        let s = cache.stats();
        assert_eq!(s.entries, 0, "over-cap value must not be retained");
        // Every caller was served, every actual encode cost exactly one
        // miss, and overlapping callers shared the handoff as hits —
        // true under any interleaving.
        assert_eq!(s.misses as usize, encodes.load(Ordering::SeqCst), "{s:?}");
        assert_eq!(s.hits + s.misses, 3, "{s:?}");
    }

    #[test]
    fn failed_in_flight_encode_hands_over_to_a_waiter() {
        use std::sync::atomic::AtomicUsize;
        let cache = OperandCache::new(8, 1 << 20);
        let d: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let f = fmt(4, 16);
        let key = CacheKey::for_matrix(&d, 1, 32, f, false);
        let attempts = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..2 {
                s.spawn(|| {
                    // First attempt fails; the waiter must be handed the
                    // encoder role (its own miss) instead of hanging.
                    let r = cache.get_or_encode(key, || {
                        if attempts.fetch_add(1, Ordering::SeqCst) == 0 {
                            std::thread::sleep(std::time::Duration::from_millis(10));
                            anyhow::bail!("transient encode failure")
                        }
                        Ok(encode(&d, f))
                    });
                    // One thread sees the error, the other (or the same
                    // thread on a non-overlapping schedule) succeeds.
                    if let Ok(v) = r {
                        assert_eq!(v.mantissas.len(), 32);
                    }
                });
            }
        });
        // No waiter hung, and the cache ended consistent: at most one
        // entry, failures cached nothing.
        assert!(cache.stats().entries <= 1);
    }

    #[test]
    fn encode_errors_propagate_and_cache_nothing() {
        let cache = OperandCache::new(4, 1 << 20);
        let d = [1.0f32; 8];
        let key = CacheKey::for_matrix(&d, 1, 8, fmt(4, 8), false);
        let r = cache.get_or_encode(key, || anyhow::bail!("encode failed"));
        assert!(r.is_err());
        assert_eq!(cache.stats().entries, 0);
        // The failed attempt counted as a miss, not a hit.
        assert_eq!(cache.stats().misses, 1);
    }

    #[test]
    fn preload_publishes_without_charging_traffic_counters() {
        let cache = OperandCache::new(8, 1 << 20);
        let d: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let f = fmt(4, 16);
        let key = CacheKey::for_matrix(&d, 1, 64, f, false);
        assert!(cache.preload(key, Arc::new(encode(&d, f))));
        assert_eq!(cache.preloads(), 1);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 1));
        // A warmed key is a pure hit: the encode closure must not run.
        let got = cache
            .get_or_encode(key, || panic!("warm start must not encode"))
            .unwrap();
        assert_eq!(got.mantissas.len(), 64);
        assert_eq!(cache.stats().hits, 1);
        // An over-budget preload reports not-retained and stores nothing.
        let tiny = OperandCache::new(8, 4);
        assert!(!tiny.preload(key, Arc::new(encode(&d, f))));
        assert_eq!(tiny.stats().entries, 0);
        assert_eq!(tiny.preloads(), 1);
    }

    #[test]
    fn clear_preserves_counters() {
        let cache = OperandCache::new(4, 1 << 20);
        let d = [2.0f32; 16];
        let f = fmt(4, 16);
        let key = CacheKey::for_matrix(&d, 1, 16, f, false);
        cache.get_or_encode(key, || Ok(encode(&d, f))).unwrap();
        cache.clear();
        let s = cache.stats();
        assert_eq!(s.entries, 0);
        assert_eq!(s.bytes, 0);
        assert_eq!(s.misses, 1);
    }
}
