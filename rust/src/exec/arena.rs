//! Size-classed buffer recycling arena — the allocation-reuse half of
//! the three-stage pipeline.
//!
//! Every batch the service executes needs transient storage with a
//! short, predictable lifetime: the output `Mat` data of each op, the
//! i32 MAC accumulator planes of the split decode path, and the
//! per-operand scale-shift scratch derived from the exponent planes.
//! Allocating these fresh per batch puts the allocator on the hot path
//! at exactly the batch cadence; the arena instead keeps returned
//! buffers on power-of-two size-classed free lists and hands them back
//! on the next checkout of a compatible size.
//!
//! # Contract
//!
//! * **Purity** — a checked-out buffer is always zeroed (`clear` +
//!   `resize(len, 0)`) before it is returned, so a recycled buffer can
//!   never leak a prior batch's contents, whatever the previous user
//!   wrote. Property tests pin this.
//! * **Byte-capped residency** — `BOOSTERS_ARENA_MB` (default
//!   [`crate::util::DEFAULT_ARENA_BYTES`]) caps the sum of free-list
//!   and checked-out bytes. A checkout that would exceed the cap first
//!   **stalls** (bounded waits for in-flight buffers to return), then
//!   evicts free buffers, and finally allocates anyway — the cap
//!   degrades to back-pressure plus eviction, never to corruption or
//!   deadlock. A returned buffer that would push residency over the
//!   cap is simply dropped.
//! * **Checkout/return** — the execution stages check buffers out per
//!   batch; output buffers ride inside `Mat`s to the caller's
//!   [`crate::exec::Ticket`], which returns them on result take
//!   (accounting release — ownership leaves the arena) or recycles
//!   them on drop-without-take. MAC and scratch planes return at the
//!   end of the decode stage.
//!
//! Counters (hits, misses, recycled bytes, resident bytes) surface in
//! [`crate::exec::ServiceStats`] and `exec_service_snapshot()`.

use super::pool::{lock_or_poisoned, wait_timeout_or_poisoned};
use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// One bounded wait while a checkout stalls on the residency cap.
const STALL_WAIT: Duration = Duration::from_millis(20);

/// Maximum stall rounds before a checkout proceeds regardless — keeps
/// the cap a throttle, not a deadlock (the waited-for buffers may be
/// held by the very pipeline stage that is asking).
const STALL_ROUNDS: usize = 5;

/// Point-in-time arena counters (monotonic except `resident_bytes`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Checkouts served from a free list.
    pub hits: u64,
    /// Checkouts that had to allocate fresh storage.
    pub misses: u64,
    /// Total bytes of reused (not freshly allocated) checkouts.
    pub recycled_bytes: u64,
    /// Free-list plus checked-out bytes right now.
    pub resident_bytes: u64,
    /// The configured residency cap.
    pub cap_bytes: u64,
}

impl ArenaStats {
    /// Fraction of checkouts served from the free lists (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Free lists keyed by the largest power of two <= the buffer's actual
/// capacity, so every buffer filed under class `C` can serve any
/// request of class <= `C` without reallocating.
struct ArenaState {
    f32_free: BTreeMap<usize, Vec<Vec<f32>>>,
    i32_free: BTreeMap<usize, Vec<Vec<i32>>>,
    free_bytes: u64,
    outstanding_bytes: u64,
    hits: u64,
    misses: u64,
    recycled_bytes: u64,
}

/// The size-classed recycling arena (see module docs).
pub struct BufferArena {
    state: Mutex<ArenaState>,
    /// Signalled on every return/release so stalled checkouts re-check.
    space_cv: Condvar,
    cap_bytes: u64,
}

/// Request class: smallest power of two >= `len` (min 1) — the
/// capacity a fresh allocation asks for.
fn size_class(len: usize) -> usize {
    len.max(1).next_power_of_two()
}

/// Filing class: largest power of two <= `cap`. Filed under the floor
/// (not `next_power_of_two`) because the allocator may hand back more
/// capacity than requested; flooring keeps the invariant that every
/// buffer in class `C` has capacity >= `C`.
fn floor_class(cap: usize) -> usize {
    debug_assert!(cap > 0);
    1usize << (usize::BITS - 1 - cap.leading_zeros())
}

/// Pop a buffer from the smallest class >= `class` (best fit).
fn pop_at_least<T>(map: &mut BTreeMap<usize, Vec<Vec<T>>>, class: usize) -> Option<Vec<T>> {
    let key = map.range(class..).next().map(|(k, _)| *k)?;
    let bucket = map.get_mut(&key).expect("class bucket exists");
    let buf = bucket.pop();
    if bucket.is_empty() {
        map.remove(&key);
    }
    buf
}

/// Drop one free buffer, largest class first (either element type).
/// Returns the bytes reclaimed, or `None` when the free lists are
/// empty.
fn evict_one(st: &mut ArenaState) -> Option<u64> {
    let f_max = st.f32_free.keys().next_back().copied().unwrap_or(0);
    let i_max = st.i32_free.keys().next_back().copied().unwrap_or(0);
    if f_max == 0 && i_max == 0 {
        return None;
    }
    let bytes = if f_max >= i_max {
        let buf = pop_at_least(&mut st.f32_free, f_max)?;
        (buf.capacity() * std::mem::size_of::<f32>()) as u64
    } else {
        let buf = pop_at_least(&mut st.i32_free, i_max)?;
        (buf.capacity() * std::mem::size_of::<i32>()) as u64
    };
    st.free_bytes = st.free_bytes.saturating_sub(bytes);
    Some(bytes)
}

impl BufferArena {
    /// An arena whose free + checked-out bytes are capped at
    /// `cap_bytes` (the `BOOSTERS_ARENA_MB` budget for the runtime's
    /// instance; tests pass explicit caps).
    pub fn new(cap_bytes: u64) -> Self {
        Self {
            state: Mutex::new(ArenaState {
                f32_free: BTreeMap::new(),
                i32_free: BTreeMap::new(),
                free_bytes: 0,
                outstanding_bytes: 0,
                hits: 0,
                misses: 0,
                recycled_bytes: 0,
            }),
            space_cv: Condvar::new(),
            cap_bytes,
        }
    }

    /// The configured residency cap in bytes.
    pub fn cap_bytes(&self) -> u64 {
        self.cap_bytes
    }

    /// Point-in-time counters.
    pub fn stats(&self) -> ArenaStats {
        let st = lock_or_poisoned(&self.state, "buffer arena");
        ArenaStats {
            hits: st.hits,
            misses: st.misses,
            recycled_bytes: st.recycled_bytes,
            resident_bytes: st.free_bytes + st.outstanding_bytes,
            cap_bytes: self.cap_bytes,
        }
    }

    /// Account a checked-out buffer as having left the arena for good
    /// (the caller took ownership of the result, e.g. on ticket take).
    pub fn release(&self, bytes: u64) {
        let mut st = lock_or_poisoned(&self.state, "buffer arena");
        st.outstanding_bytes = st.outstanding_bytes.saturating_sub(bytes);
        drop(st);
        self.space_cv.notify_all();
    }
}

/// The typed checkout/return pair — one instantiation per element
/// type, sharing the class/accounting logic above. Both paths zero the
/// buffer on checkout (the purity contract) and account residency by
/// the buffer's **actual** capacity, so take/put bookkeeping always
/// cancels exactly.
macro_rules! arena_typed {
    ($take:ident, $put:ident, $field:ident, $ty:ty, $zero:expr) => {
        impl BufferArena {
            /// Check out a zeroed buffer of `len` elements.
            pub fn $take(&self, len: usize) -> Vec<$ty> {
                let class = size_class(len);
                let need = (class * std::mem::size_of::<$ty>()) as u64;
                let mut st = lock_or_poisoned(&self.state, "buffer arena");
                let mut stalls = 0;
                loop {
                    if let Some(mut buf) = pop_at_least(&mut st.$field, class) {
                        let bytes = (buf.capacity() * std::mem::size_of::<$ty>()) as u64;
                        st.free_bytes = st.free_bytes.saturating_sub(bytes);
                        st.outstanding_bytes += bytes;
                        st.hits += 1;
                        st.recycled_bytes += bytes;
                        drop(st);
                        buf.clear();
                        buf.resize(len, $zero);
                        return buf;
                    }
                    let over = st.free_bytes + st.outstanding_bytes + need > self.cap_bytes;
                    if over && st.outstanding_bytes > 0 && stalls < STALL_ROUNDS {
                        // Residency cap back-pressure: wait (bounded)
                        // for in-flight buffers to come back, then
                        // retry the free lists.
                        stalls += 1;
                        st = wait_timeout_or_poisoned(
                            &self.space_cv,
                            st,
                            STALL_WAIT,
                            "buffer arena",
                        );
                        continue;
                    }
                    if over {
                        while st.free_bytes + st.outstanding_bytes + need > self.cap_bytes
                            && evict_one(&mut st).is_some()
                        {}
                    }
                    st.misses += 1;
                    let mut buf: Vec<$ty> = Vec::with_capacity(class);
                    buf.resize(len, $zero);
                    st.outstanding_bytes +=
                        (buf.capacity() * std::mem::size_of::<$ty>()) as u64;
                    return buf;
                }
            }

            /// Return a checked-out buffer for reuse. Dropped instead
            /// of filed when keeping it would exceed the residency cap.
            pub fn $put(&self, buf: Vec<$ty>) {
                let cap = buf.capacity();
                if cap == 0 {
                    return;
                }
                let bytes = (cap * std::mem::size_of::<$ty>()) as u64;
                let mut st = lock_or_poisoned(&self.state, "buffer arena");
                st.outstanding_bytes = st.outstanding_bytes.saturating_sub(bytes);
                if st.free_bytes + st.outstanding_bytes + bytes <= self.cap_bytes {
                    st.free_bytes += bytes;
                    st.$field.entry(floor_class(cap)).or_default().push(buf);
                }
                drop(st);
                self.space_cv.notify_all();
            }
        }
    };
}

arena_typed!(take_f32, put_f32, f32_free, f32, 0.0f32);
arena_typed!(take_i32, put_i32, i32_free, i32, 0i32);

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recycled_buffers_are_zeroed_and_reuse_storage() {
        let arena = BufferArena::new(1 << 20);
        let mut buf = arena.take_f32(100);
        assert_eq!(buf.len(), 100);
        assert!(buf.iter().all(|&v| v == 0.0));
        // Poison it the way a prior batch would.
        for v in buf.iter_mut() {
            *v = f32::NAN;
        }
        let cap = buf.capacity();
        arena.put_f32(buf);
        // Smaller request of the same class reuses the storage, zeroed.
        let again = arena.take_f32(64);
        assert_eq!(again.capacity(), cap, "free-list storage was reused");
        assert_eq!(again.len(), 64);
        assert!(again.iter().all(|&v| v == 0.0), "recycled buffer leaked contents");
        let s = arena.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert!(s.recycled_bytes >= (64 * 4) as u64);
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn i32_planes_recycle_independently_of_f32() {
        let arena = BufferArena::new(1 << 20);
        let mut m = arena.take_i32(257);
        m.iter_mut().for_each(|v| *v = -7);
        arena.put_i32(m);
        // An f32 request never steals i32 storage.
        let f = arena.take_f32(257);
        assert!(f.iter().all(|&v| v == 0.0));
        assert_eq!(arena.stats().misses, 2);
        let m2 = arena.take_i32(300);
        // 300 classes to 512, same as 257: hit, zeroed.
        assert!(m2.iter().all(|&v| v == 0));
        assert_eq!(arena.stats().hits, 1);
    }

    #[test]
    fn residency_cap_drops_returns_and_never_blocks_progress() {
        // Cap of one byte: nothing may be retained, everything still
        // works (bounded stall, then allocate).
        let arena = BufferArena::new(1);
        let a = arena.take_f32(16);
        assert_eq!(a.len(), 16);
        // Second checkout while the first is outstanding: over cap with
        // outstanding > 0 — stalls (bounded), then proceeds correctly.
        let b = arena.take_f32(16);
        assert_eq!(b.len(), 16);
        assert!(b.iter().all(|&v| v == 0.0));
        arena.put_f32(a);
        arena.put_f32(b);
        let s = arena.stats();
        // Nothing retained: both returns were dropped.
        assert_eq!(s.resident_bytes, 0);
        assert_eq!(s.hits, 0);
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn release_accounts_buffers_that_leave_the_arena() {
        let arena = BufferArena::new(1 << 20);
        let buf = arena.take_f32(128);
        let bytes = (buf.capacity() * 4) as u64;
        assert_eq!(arena.stats().resident_bytes, bytes);
        // The caller keeps the buffer (ticket take): accounting-only
        // release returns residency to zero.
        arena.release(bytes);
        assert_eq!(arena.stats().resident_bytes, 0);
        drop(buf);
    }

    #[test]
    fn over_cap_checkout_evicts_free_buffers_first() {
        // Cap fits exactly one 1024-element f32 buffer.
        let arena = BufferArena::new(4096);
        let a = arena.take_f32(1024);
        arena.put_f32(a);
        assert_eq!(arena.stats().resident_bytes, 4096);
        // An i32 request of the same byte size cannot reuse the f32
        // buffer; it evicts it to stay under the cap.
        let b = arena.take_i32(1024);
        assert_eq!(b.len(), 1024);
        let s = arena.stats();
        assert_eq!(s.resident_bytes, 4096, "evicted the free f32 buffer, kept the i32");
        assert_eq!(s.misses, 2);
    }
}
