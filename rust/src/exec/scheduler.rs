//! `BatchGemm` — the batched/sharded GEMM **execution stage** of the
//! execution runtime.
//!
//! Since PR 3 this is the internal stage the async
//! [`super::service::BfpService`] drives: the service's admission loop
//! forms deadline-aware, MAC-budgeted batches of [`OwnedGemmOp`]s and
//! hands each batch to [`BatchGemm::run`]. The `run` entry point is
//! kept public as a **thin synchronous facade** (tests, benches, and
//! embedders that want batch-at-a-time semantics); new consumers should
//! migrate to [`super::service::BfpService::submit`], which adds
//! backpressure, deadlines, and cross-batch pipelining on top of the
//! same execution stage.
//!
//! A serving workload is a stream of heterogeneous `(A, B, format)`
//! multiplies. Running them one `gemm_packed` call at a time leaves the
//! pool idle at every op boundary and re-encodes weight operands that
//! repeat across requests. `BatchGemm` instead:
//!
//! 1. **consumes pre-encoded operands** where the service's
//!    admission-time pipeline already filled an op's shared slot
//!    ([`OwnedGemmOp`]'s encoded-operand slot — encode of the next
//!    batch overlaps the GEMM of the current one), and otherwise
//!    **encodes** activation operands in parallel on the pool and
//!    pulls weight operands through the runtime's encoded-operand cache
//!    ([`super::cache`]) so repeated weights are packed once;
//! 2. **shards** every op into band-level work items (contiguous
//!    activation-row ranges, sized by each op's share of the batch MAC
//!    volume) and runs the whole batch's bands on the pool as one
//!    scope — small ops no longer serialize behind large ones;
//! 3. returns results **in submission order**.
//!
//! # Determinism
//!
//! Band partitioning never changes numerics: each output element is
//! accumulated by exactly one band job in ascending block order, so any
//! shard size, any pool width, and any batch ordering produce results
//! bit-identical to per-op [`crate::bfp::hbfp_gemm_scalar`] — the
//! invariant `tests/property_exec.rs` and `tests/property_service.rs`
//! pin. The service may *reorder execution* across batches; it can
//! never reorder accumulation within an op.
//!
//! The same invariant carries through the **MAC/decode split**
//! ([`BatchGemm::run_split_with_stats`] + [`decode_staged`]): the MAC
//! stage stores each block dot product as the exact `i32` the fused
//! kernels feed their accumulator, and the decode stage replays the
//! identical per-element `f64` scale-shift sum in the identical
//! ascending-`k` order. No output element ever shares an accumulator,
//! so the two stages can be band-sharded independently (the decode of
//! batch `n` overlaps the GEMM of batch `n + 1` in the service) without
//! perturbing a single bit.

use super::pool::Job;
use super::ExecRuntime;
use crate::bfp::gemm::{band_shifts, band_shifts_into, BandTask, PARALLEL_MIN_MACS};
use crate::bfp::kernels::{self, GemmKernel, GemmShape, KernelOpCounts, MacBandTask};
use crate::bfp::{BfpMatrix, BlockFormat, Mat, PlaneLayout, Quantizer};
use crate::util::{content_fingerprint, Digest};
use anyhow::{bail, Context, Result};
use std::sync::{Arc, OnceLock};
use std::time::Instant;

/// Below this batch MAC volume a decode runs serially on the calling
/// (decode-stage) thread — sharding tiny decodes costs more in job
/// setup than it saves.
const DECODE_PARALLEL_MIN: usize = 1 << 20;

/// Pre-encoded operand planes of one op: the activation encoded
/// row-wise and the weight encoded column-wise (through the operand
/// cache). Filled at most once — by the service's admission-time
/// encode stage when it wins the race, otherwise never (the execution
/// stage encodes inline without publishing, so the sync facade's
/// cache-counter semantics stay exactly as before).
pub(crate) struct PreEncoded {
    pub(crate) x: Arc<BfpMatrix>,
    pub(crate) w: Arc<BfpMatrix>,
}

/// One GEMM: `x (m x K)` times `w (K x n)` with both operands quantized
/// to `fmt` (nearest rounding — the deterministic forward-pass
/// transform, required for operand caching).
///
/// Operands are **owned** (`Arc<Mat>`), so an op can cross threads,
/// outlive its submitting frame, and share a weight matrix across many
/// requests without copying — the contract the async
/// [`super::service::BfpService`] needs. (The pre-service `GemmOp<'a>`
/// borrowed its operands and could not leave the caller's stack; those
/// `&'a` borrows are gone.)
///
/// Every clone of an op shares one **encoded-operand slot**: the
/// service's pipeline pre-encodes into it at admission time, and the
/// execution stage consumes it instead of re-encoding — the encode →
/// execute handoff that lets encode of batch `n + 1` overlap the GEMM
/// of batch `n`.
#[derive(Clone)]
pub struct OwnedGemmOp {
    pub x: Arc<Mat>,
    pub w: Arc<Mat>,
    pub fmt: BlockFormat,
    /// Shared across clones; see the type docs.
    pub(crate) encoded: Arc<OnceLock<PreEncoded>>,
    /// Lazily computed weight fingerprint, shared across clones — the
    /// grouping identity the weight-stationary batch path and the
    /// queue's group-aware `pop_batch` both key on.
    pub(crate) digest: Arc<OnceLock<Digest>>,
}

/// Weight-identity key for weight-stationary grouped execution and the
/// queue's group-aware batch selection: the 128-bit content fingerprint
/// (it covers data *and* shape, so equal digests imply equal `K` and
/// `N`) plus the block format, which fixes the encoded plane layout and
/// block partitioning. Two ops with equal keys are guaranteed to share
/// bit-identical encoded weight planes.
pub(crate) type GroupKey = (Digest, u32, usize);

impl OwnedGemmOp {
    /// Build an op, validating the contraction dims up front (the
    /// service rejects malformed ops at admission, not mid-batch).
    pub fn new(x: Arc<Mat>, w: Arc<Mat>, fmt: BlockFormat) -> Result<Self> {
        if x.cols != w.rows {
            bail!("inner dims {} vs {} do not contract", x.cols, w.rows);
        }
        Ok(Self {
            x,
            w,
            fmt,
            encoded: Arc::new(OnceLock::new()),
            digest: Arc::new(OnceLock::new()),
        })
    }

    /// Content fingerprint of the weight operand — the same digest the
    /// operand cache and the fabric compute for this matrix. Computed
    /// at most once and shared across clones.
    pub(crate) fn weight_digest(&self) -> Digest {
        *self
            .digest
            .get_or_init(|| content_fingerprint(&self.w.data, self.w.rows, self.w.cols))
    }

    /// Grouping key for weight-stationary execution; see [`GroupKey`].
    pub(crate) fn group_key(&self) -> GroupKey {
        (
            self.weight_digest(),
            self.fmt.mantissa_bits,
            self.fmt.block_size,
        )
    }

    /// Convenience for callers that hold plain `&Mat`s: copies both
    /// operands into fresh `Arc`s. Callers with long-lived weights
    /// should hold `Arc<Mat>` themselves and use [`OwnedGemmOp::new`].
    pub fn from_mats(x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Self> {
        Self::new(Arc::new(x.clone()), Arc::new(w.clone()), fmt)
    }

    /// MAC volume of this op (saturating) — the unit of the service's
    /// per-batch admission budget.
    pub fn macs(&self) -> usize {
        self.x
            .rows
            .saturating_mul(self.w.cols)
            .saturating_mul(self.x.cols)
    }

    /// Whether this op's encoded-operand slot has been filled by the
    /// pre-encode stage. Observability for tests and metrics; the
    /// execution stage reads the slot itself.
    pub fn is_pre_encoded(&self) -> bool {
        self.encoded.get().is_some()
    }

    /// Deterministic estimate of this op's pre-encoded **activation**
    /// plane bytes — what an encode claim charges against the service's
    /// `BOOSTERS_PREENCODE_MB` budget. Counts the mantissa plane (rows
    /// padded to whole blocks, stored per the format's plane layout)
    /// plus the per-block `i32` exponent plane. Weight planes are
    /// excluded on purpose: they live in the operand cache under its
    /// own `BOOSTERS_CACHE_MB` budget, shared across requests.
    pub fn pre_encode_estimate_bytes(&self) -> u64 {
        let rows = self.x.rows as u64;
        let blocks_per_row = (self.x.cols as u64).div_ceil(self.fmt.block_size.max(1) as u64);
        let blocks = rows.saturating_mul(blocks_per_row);
        let values = blocks.saturating_mul(self.fmt.block_size as u64);
        let mantissa_bytes = match self.fmt.plane_layout() {
            PlaneLayout::I4Packed => values / 2,
            PlaneLayout::I8 => values,
            PlaneLayout::I16 => values.saturating_mul(2),
        };
        mantissa_bytes.saturating_add(blocks.saturating_mul(4))
    }

    /// Encode both operands into the shared slot: the activation on
    /// `rt`'s pool, the weight through `rt`'s operand cache (nearest
    /// rounding — the deterministic cacheable transform). No-op when
    /// the slot is already filled. Pre-encode failures leave the slot
    /// empty on purpose: the execution stage re-encodes inline and
    /// routes the error to the op's ticket, so a malformed op fails
    /// where its caller is listening.
    pub(crate) fn pre_encode(&self, rt: &ExecRuntime) -> Result<()> {
        if self.encoded.get().is_some() {
            return Ok(());
        }
        let q = Quantizer::nearest(self.fmt.mantissa_bits);
        let mut xq = BfpMatrix::empty();
        xq.encode_into_on(rt.pool(), &self.x.data, self.x.rows, self.x.cols, self.fmt, q, 0)?;
        let wq = rt.encode_transposed_cached(self.w.as_ref(), self.fmt)?;
        // An op submitted more than once shares one slot across its
        // clones, so a concurrent pre-encode may have won the race;
        // either value is bit-identical (deterministic encode), so the
        // loser's work is just dropped.
        let _ = self.encoded.set(PreEncoded {
            x: Arc::new(xq),
            w: wq,
        });
        Ok(())
    }

    /// Fill the shared slot with **externally produced** encoded planes
    /// — how a fabric runner installs operands that arrived over the
    /// wire (or from its digest-addressed operand store) so the
    /// execution stage consumes them without ever touching the op's raw
    /// f32 data. Same race semantics as [`OwnedGemmOp::pre_encode`]:
    /// first writer wins, losers' planes are dropped (deterministic
    /// encode makes every candidate bit-identical).
    pub(crate) fn install_encoded(&self, x: Arc<BfpMatrix>, w: Arc<BfpMatrix>) {
        let _ = self.encoded.set(PreEncoded { x, w });
    }
}

/// Encode-stage accounting of one [`BatchGemm::run_with_stats`] call —
/// what the service aggregates into [`super::ServiceStats`] (pre-encode
/// hit rate, encode-stage latency).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EncodeReport {
    /// Ops whose operand slot was already filled when the batch reached
    /// the execution stage (the admission-time pipeline won the race).
    pub pre_encoded: usize,
    /// Ops encoded inline by the execution stage.
    pub inline_encoded: usize,
    /// Wall time of the execution stage's encode phase, nanoseconds
    /// (near zero for a fully pre-encoded batch — that is the point).
    pub encode_ns: u64,
    /// Which backend the execution stage actually dispatched, per op
    /// and M×N×K bucket — the ground truth behind the configured
    /// `KernelChoice` (a forced backend can still degrade per op).
    pub kernel_ops: KernelOpCounts,
    /// Ops executed through a weight-stationary group (split path only;
    /// the remaining `pre_encoded + inline_encoded - grouped_ops` ran
    /// per-op).
    pub grouped_ops: usize,
    /// Weight-stationary groups formed for this batch (each has at
    /// least two member ops).
    pub groups_formed: usize,
    /// Encoded weight plane bytes (mantissas + exponents) the grouped
    /// path did *not* re-stream: for a group of `g` ops the weight is
    /// loaded once per band tile instead of `g` times, saving
    /// `(g - 1) x plane_bytes`.
    pub weight_plane_loads_avoided: u64,
}

/// Per-op execution plan alongside the staged buffer of the split
/// path. Fused ops keep their shift planes here (dropped after the
/// GEMM stage); split ops carry theirs inside `StagedOut::Macs`
/// because the decode stage needs them later.
struct Plan {
    kernel: &'static dyn GemmKernel,
    band: usize,
    fused_shifts: Option<(Vec<i32>, Vec<i32>)>,
}

/// Resident bytes of one encoded operand's planes (mantissas +
/// per-block `i32` exponents) — what a weight-stationary group avoids
/// re-streaming for every member after the first.
fn encoded_plane_bytes(m: &BfpMatrix) -> u64 {
    m.mantissas.resident_bytes() as u64 + (m.exponents.len() as u64) * 4
}

/// Batched GEMM executor over an [`ExecRuntime`] (see module docs).
pub struct BatchGemm<'rt> {
    rt: &'rt ExecRuntime,
    band_rows: Option<usize>,
    cache_weights: bool,
    kernel: Option<&'static dyn GemmKernel>,
    group_min_ops: usize,
}

impl<'rt> BatchGemm<'rt> {
    pub fn new(rt: &'rt ExecRuntime) -> Self {
        Self {
            rt,
            band_rows: None,
            cache_weights: true,
            kernel: None,
            group_min_ops: crate::util::group_min_ops(),
        }
    }

    /// Force a fixed shard height (activation rows per band) instead of
    /// the MAC-proportional default. Any value yields bit-identical
    /// results; this exists for tests and tuning.
    pub fn band_rows(mut self, rows: usize) -> Self {
        self.band_rows = Some(rows.max(1));
        self
    }

    /// Disable the weight-operand cache for this batch (weights are
    /// then encoded fresh, still in parallel).
    pub fn cache_weights(mut self, on: bool) -> Self {
        self.cache_weights = on;
        self
    }

    /// Force a specific kernel backend instead of the registry's
    /// per-operand-pair dispatch. Ops whose plane-layout pair the
    /// forced backend cannot run degrade down the registry's fallback
    /// chain (ending at the scalar kernel) — bit-identical either way.
    /// This is how the property suites pin every registered backend.
    pub fn with_kernel(mut self, kernel: &'static dyn GemmKernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Minimum number of same-weight split-path ops before the batch
    /// executes them as one weight-stationary group (`0` disables
    /// grouping). Defaults to the `BOOSTERS_GROUP_MIN_OPS` env knob.
    /// Grouping never changes numerics — it only changes how many
    /// times the shared weight planes stream through memory.
    pub fn group_min_ops(mut self, min_ops: usize) -> Self {
        self.group_min_ops = min_ops;
        self
    }

    /// Execute the batch; `out[i]` corresponds to `ops[i]`.
    ///
    /// This is the **synchronous facade** over the execution stage: the
    /// caller blocks for the whole batch. It is what the
    /// [`super::service::BfpService`] scheduler thread calls internally;
    /// request-level consumers should migrate to `BfpService::submit`,
    /// which pipelines batches and adds deadlines and backpressure.
    pub fn run(&self, ops: &[OwnedGemmOp]) -> Result<Vec<Mat>> {
        self.run_with_stats(ops).map(|(outs, _)| outs)
    }

    /// Shared **encode stage** of both the fused and the split
    /// execution paths: returns the encoded operand pair per op plus an
    /// [`EncodeReport`] with `encode_ns` stamped and `kernel_ops` still
    /// empty (the execute stage records dispatch as it selects).
    fn encode_batch(
        &self,
        ops: &[OwnedGemmOp],
    ) -> Result<(Vec<Arc<BfpMatrix>>, Vec<Arc<BfpMatrix>>, EncodeReport)> {
        for (i, op) in ops.iter().enumerate() {
            if op.x.cols != op.w.rows {
                bail!(
                    "op {i}: inner dims {} vs {} do not contract",
                    op.x.cols,
                    op.w.rows
                );
            }
        }

        // ---- encode stage -------------------------------------------
        // Ops whose shared slot the admission-time pipeline already
        // filled are consumed as-is; the rest encode inline exactly as
        // before (activations in parallel on the pool, weights through
        // the operand cache). Inline encodes are NOT published back to
        // the slot: the sync facade must stay a pure function of its
        // inputs (the cache-purity property tests count on it). A
        // cache-bypassing executor (`cache_weights(false)`) ignores the
        // slots entirely — pre-encoded weights came through the cache,
        // which that configuration promises not to consume.
        let encode_started = Instant::now();
        let pre: Vec<Option<(Arc<BfpMatrix>, Arc<BfpMatrix>)>> = ops
            .iter()
            .map(|op| {
                if !self.cache_weights {
                    return None;
                }
                op.encoded
                    .get()
                    .map(|e| (Arc::clone(&e.x), Arc::clone(&e.w)))
            })
            .collect();
        let pre_encoded = pre.iter().filter(|p| p.is_some()).count();
        let inline_encoded = ops.len() - pre_encoded;
        let mut xs: Vec<Option<BfpMatrix>> = pre
            .iter()
            .map(|p| if p.is_some() { None } else { Some(BfpMatrix::empty()) })
            .collect();
        let mut xerrs: Vec<Option<anyhow::Error>> = (0..ops.len()).map(|_| None).collect();
        {
            let jobs: Vec<Job> = xs
                .iter_mut()
                .zip(xerrs.iter_mut())
                .zip(ops)
                .filter_map(|((slot, err), op)| {
                    let slot = slot.as_mut()?;
                    let q = Quantizer::nearest(op.fmt.mantissa_bits);
                    Some(Box::new(move || {
                        if let Err(e) =
                            slot.encode_into_serial(&op.x.data, op.x.rows, op.x.cols, op.fmt, q, 0)
                        {
                            *err = Some(e);
                        }
                    }) as Job)
                })
                .collect();
            self.rt.pool().scope_run(jobs);
        }
        for (i, e) in xerrs.iter_mut().enumerate() {
            if let Some(e) = e.take() {
                return Err(e.context(format!("encoding activations of op {i}")));
            }
        }
        let mut xenc: Vec<Arc<BfpMatrix>> = Vec::with_capacity(ops.len());
        let mut wenc: Vec<Arc<BfpMatrix>> = Vec::with_capacity(ops.len());
        for (i, ((op, slot), inline_x)) in ops.iter().zip(pre).zip(xs).enumerate() {
            if let Some((xq, wq)) = slot {
                xenc.push(xq);
                wenc.push(wq);
                continue;
            }
            // A pre-encode may have landed after the batch-start
            // snapshot; harvest it rather than re-encoding the weight
            // (the inline activation work is already spent; bits are
            // identical either way). The counters keep describing the
            // snapshot — this is purely work avoidance. Only on the
            // cached path: a cache-bypassing facade must not consume
            // cache-produced planes.
            if self.cache_weights {
                if let Some(e) = op.encoded.get() {
                    xenc.push(Arc::clone(&e.x));
                    wenc.push(Arc::clone(&e.w));
                    continue;
                }
            }
            xenc.push(Arc::new(inline_x.expect("inline ops got an encode slot")));
            let enc = if self.cache_weights {
                self.rt.encode_transposed_cached(op.w.as_ref(), op.fmt)
            } else {
                let mut fresh = BfpMatrix::empty();
                fresh
                    .encode_transposed_on(
                        self.rt.pool(),
                        op.w.as_ref(),
                        op.fmt,
                        Quantizer::nearest(op.fmt.mantissa_bits),
                    )
                    .map(|_| Arc::new(fresh))
            };
            wenc.push(enc.with_context(|| format!("encoding weights of op {i}"))?);
        }
        let report = EncodeReport {
            pre_encoded,
            inline_encoded,
            encode_ns: encode_started.elapsed().as_nanos() as u64,
            ..EncodeReport::default()
        };
        Ok((xenc, wenc, report))
    }

    /// [`BatchGemm::run`] plus the batch's [`EncodeReport`] — how the
    /// service attributes encode-stage latency and pre-encode hits.
    pub fn run_with_stats(&self, ops: &[OwnedGemmOp]) -> Result<(Vec<Mat>, EncodeReport)> {
        let (xenc, wenc, mut report) = self.encode_batch(ops)?;

        // ---- shard + execute stage ----------------------------------
        let shifts: Vec<(Vec<i32>, Vec<i32>)> = xenc
            .iter()
            .zip(&wenc)
            .map(|(x, w)| (band_shifts(x), band_shifts(w)))
            .collect();
        let mut outs: Vec<Mat> = ops
            .iter()
            .map(|op| Mat::zeros(op.x.rows, op.w.cols))
            .collect();
        let threads = self.rt.pool().threads();
        let total_macs: usize = ops
            .iter()
            .map(OwnedGemmOp::macs)
            .fold(0usize, usize::saturating_add);
        let mut jobs: Vec<Job> = Vec::new();
        for (((out, xp), wp), (xsh, wsh)) in outs.iter_mut().zip(&xenc).zip(&wenc).zip(&shifts) {
            let (m, n) = (xp.rows, wp.rows);
            if m == 0 || n == 0 {
                continue;
            }
            // Kernel dispatch is per op: a heterogeneous batch can mix
            // nibble-packed, i8, and i16 operands, each running the
            // best backend for its layout pair.
            let (xl, wl) = (xp.mantissas.layout(), wp.mantissas.layout());
            let block = xp.fmt.block_size;
            let shape = GemmShape::new(m, n, xp.cols);
            let kernel = match self.kernel {
                Some(k) => kernels::registry().select_from(k, xl, wl, block),
                None => kernels::active_kernel(xl, wl, block, shape),
            };
            // Record the backend that actually dispatches, not the
            // configured choice — a forced backend can degrade per op.
            report.kernel_ops.record(kernel.name(), shape.mnk_bucket());
            let macs = m.saturating_mul(n).saturating_mul(xp.cols);
            let band = self.band_for(m, macs, total_macs, threads);
            let xref: &BfpMatrix = xp;
            let wref: &BfpMatrix = wp;
            for (t, chunk) in out.data.chunks_mut(band * n).enumerate() {
                let r0 = t * band;
                let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
                jobs.push(Box::new(move || {
                    kernel.run_band(BandTask {
                        x: xref,
                        w: wref,
                        xsh,
                        wsh,
                        r0,
                        rows: chunk.len() / n,
                        out: chunk,
                    });
                }) as Job);
            }
        }
        self.rt.pool().scope_run(jobs);
        Ok((outs, report))
    }

    /// The **split** execution path behind the service's three-stage
    /// pipeline: encode + integer MAC stage only. Ops whose operand
    /// layouts support `i32` MAC storage
    /// ([`kernels::mac_split_supported`]) stop after storing raw block
    /// MACs into an arena-backed plane; the f32 scale-shift decode is
    /// deferred to [`decode_staged`], which a separate pipeline stage
    /// runs while the scheduler forms and executes the next batch.
    /// Unsupported (wide-mantissa) ops run the fused kernel here and
    /// pass through decode — the split is a scheduling change only,
    /// never a numerics change.
    ///
    /// Every arena checkout happens after the last fallible step, so an
    /// `Err` return can never strand outstanding arena bytes.
    pub(crate) fn run_split_with_stats(&self, ops: &[OwnedGemmOp]) -> Result<StagedBatch> {
        let (xenc, wenc, mut report) = self.encode_batch(ops)?;

        let arena = self.rt.arena();
        let threads = self.rt.pool().threads();
        let total_macs: usize = ops
            .iter()
            .map(OwnedGemmOp::macs)
            .fold(0usize, usize::saturating_add);

        // Weight-stationary grouping: split-path ops sharing a weight
        // group key execute as one tall-M grouped GEMM so the shared
        // weight planes stream through memory once per band tile per
        // group instead of once per op. `group_min_ops == 0` disables
        // grouping; a group needs at least two members to save anything
        // either way.
        let min_group = match self.group_min_ops {
            0 => usize::MAX,
            n => n.max(2),
        };
        let grouping = min_group != usize::MAX;

        let mut staged: Vec<StagedOut> = Vec::with_capacity(ops.len());
        let mut plans: Vec<Option<Plan>> = Vec::with_capacity(ops.len());
        let mut split_keys: Vec<Option<GroupKey>> = Vec::with_capacity(ops.len());
        for ((op, xp), wp) in ops.iter().zip(&xenc).zip(&wenc) {
            let (m, n) = (xp.rows, wp.rows);
            if m == 0 || n == 0 {
                staged.push(StagedOut::Fused(Mat::zeros(op.x.rows, op.w.cols)));
                plans.push(None);
                split_keys.push(None);
                continue;
            }
            let (xl, wl) = (xp.mantissas.layout(), wp.mantissas.layout());
            let block = xp.fmt.block_size;
            let kb = xp.blocks_per_row;
            if kernels::mac_split_supported(xl, wl, block) && kb > 0 {
                let mut xsh = arena.take_i32(m * kb);
                band_shifts_into(xp, &mut xsh);
                let mut wsh = arena.take_i32(n * kb);
                band_shifts_into(wp, &mut wsh);
                staged.push(StagedOut::Macs {
                    macs: arena.take_i32(m * n * kb),
                    xsh,
                    wsh,
                    m,
                    n,
                    kb,
                });
                if grouping {
                    // Kernel dispatch and banding are deferred for
                    // split ops while grouping is on: both depend on
                    // whether this op lands in a group (the grouped
                    // path dispatches on the stacked M).
                    plans.push(None);
                    split_keys.push(Some(op.group_key()));
                } else {
                    plans.push(Some(self.per_op_plan(xp, wp, &mut report, total_macs, threads)));
                    split_keys.push(None);
                }
            } else {
                let shape = GemmShape::new(m, n, xp.cols);
                let kernel = match self.kernel {
                    Some(k) => kernels::registry().select_from(k, xl, wl, block),
                    None => kernels::active_kernel(xl, wl, block, shape),
                };
                report.kernel_ops.record(kernel.name(), shape.mnk_bucket());
                let macs = m.saturating_mul(n).saturating_mul(xp.cols);
                staged.push(StagedOut::Fused(Mat {
                    rows: m,
                    cols: n,
                    data: arena.take_f32(m * n),
                }));
                plans.push(Some(Plan {
                    kernel,
                    band: self.band_for(m, macs, total_macs, threads),
                    fused_shifts: Some((band_shifts(xp), band_shifts(wp))),
                }));
                split_keys.push(None);
            }
        }

        // ---- group formation ----------------------------------------
        // Bucket split-path ops by weight identity in submission order.
        // Sub-threshold buckets fall back to the per-op plan;
        // qualifying buckets become weight-stationary groups whose
        // kernel and band height come from the stacked (tall-M) shape,
        // so autotune buckets on the M the hardware actually streams.
        struct GroupExec {
            members: Vec<usize>,
            kernel: &'static dyn GemmKernel,
            band: usize,
        }
        let mut groups: Vec<GroupExec> = Vec::new();
        if grouping {
            let mut buckets: Vec<(GroupKey, Vec<usize>)> = Vec::new();
            for (i, key) in split_keys.iter().enumerate() {
                let Some(key) = key else { continue };
                match buckets.iter_mut().find(|(k, _)| k == key) {
                    Some((_, members)) => members.push(i),
                    None => buckets.push((*key, vec![i])),
                }
            }
            for (_, members) in buckets {
                if members.len() < min_group {
                    for &i in &members {
                        plans[i] = Some(self.per_op_plan(
                            &xenc[i],
                            &wenc[i],
                            &mut report,
                            total_macs,
                            threads,
                        ));
                    }
                    continue;
                }
                let (xp0, wp0) = (&xenc[members[0]], &wenc[members[0]]);
                let (n, k) = (wp0.rows, xp0.cols);
                let (xl, wl) = (xp0.mantissas.layout(), wp0.mantissas.layout());
                let block = xp0.fmt.block_size;
                let total_m: usize = members.iter().map(|&i| xenc[i].rows).sum();
                let gshape = GemmShape::new(total_m, n, k);
                let kernel = match self.kernel {
                    Some(kk) => kernels::registry().select_from(kk, xl, wl, block),
                    None => kernels::active_kernel(xl, wl, block, gshape),
                };
                for _ in &members {
                    report.kernel_ops.record(kernel.name(), gshape.mnk_bucket());
                }
                let gmacs = total_m.saturating_mul(n).saturating_mul(k);
                report.grouped_ops += members.len();
                report.groups_formed += 1;
                report.weight_plane_loads_avoided +=
                    encoded_plane_bytes(wp0).saturating_mul(members.len() as u64 - 1);
                groups.push(GroupExec {
                    band: self.band_for(total_m, gmacs, total_macs, threads),
                    members,
                    kernel,
                });
            }
        }

        let mut jobs: Vec<Job> = Vec::new();
        // Grouped members' staged slots are taken here; the per-op loop
        // below only sees what grouping left behind.
        let mut slots: Vec<Option<&mut StagedOut>> = staged.iter_mut().map(Some).collect();
        for g in &groups {
            let wref: &BfpMatrix = wenc[g.members[0]].as_ref();
            let kernel = g.kernel;
            let band = g.band;
            let total_m: usize = g.members.iter().map(|&i| xenc[i].rows).sum();
            // One segment list per band tile of the stacked row space:
            // each member contributes the consecutive slice of its MAC
            // plane that falls inside the tile, carved with
            // `split_at_mut` so every band job owns disjoint storage —
            // the per-op "scatter" is free because members' MACs are
            // written in place, in their own planes.
            let mut per_band: Vec<Vec<kernels::GroupedMacSegment<'_>>> =
                (0..total_m.div_ceil(band)).map(|_| Vec::new()).collect();
            let mut off = 0usize;
            for &i in &g.members {
                let st = slots[i].take().expect("grouped member owns its staged slot");
                let StagedOut::Macs { macs, m, n, kb, .. } = st else {
                    continue; // unreachable: groups form over split ops only
                };
                let (m, n, kb) = (*m, *n, *kb);
                let xref: &BfpMatrix = xenc[i].as_ref();
                let mut rest: &mut [i32] = &mut macs[..m * n * kb];
                let mut row = 0usize;
                while row < m {
                    let tile = (off + row) / band;
                    let rows = ((tile + 1) * band - (off + row)).min(m - row);
                    let (seg, tail) = rest.split_at_mut(rows * n * kb);
                    per_band[tile].push(kernels::GroupedMacSegment {
                        x: xref,
                        r0: row,
                        rows,
                        macs: seg,
                    });
                    rest = tail;
                    row += rows;
                }
                off += m;
            }
            for mut segs in per_band {
                if segs.is_empty() {
                    continue;
                }
                jobs.push(Box::new(move || {
                    kernel.run_band_macs_grouped(wref, &mut segs);
                }) as Job);
            }
        }
        for (i, plan) in plans.iter().enumerate() {
            let Some(plan) = plan else { continue };
            let Some(st) = slots[i].take() else { continue };
            let kernel = plan.kernel;
            let band = plan.band;
            let xref: &BfpMatrix = xenc[i].as_ref();
            let wref: &BfpMatrix = wenc[i].as_ref();
            match st {
                StagedOut::Macs { macs, n, kb, .. } => {
                    let (n, kb) = (*n, *kb);
                    for (t, chunk) in macs.chunks_mut(band * n * kb).enumerate() {
                        let r0 = t * band;
                        jobs.push(Box::new(move || {
                            kernel.run_band_macs(MacBandTask {
                                x: xref,
                                w: wref,
                                r0,
                                rows: chunk.len() / (n * kb),
                                macs: chunk,
                            });
                        }) as Job);
                    }
                }
                StagedOut::Fused(out) => {
                    let (xsh, wsh) = plan.fused_shifts.as_ref().expect("fused ops carry shifts");
                    let n = wref.rows;
                    for (t, chunk) in out.data.chunks_mut(band * n).enumerate() {
                        let r0 = t * band;
                        let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
                        jobs.push(Box::new(move || {
                            kernel.run_band(BandTask {
                                x: xref,
                                w: wref,
                                xsh,
                                wsh,
                                r0,
                                rows: chunk.len() / n,
                                out: chunk,
                            });
                        }) as Job);
                    }
                }
            }
        }
        drop(slots);
        self.rt.pool().scope_run(jobs);
        Ok(StagedBatch { staged, report })
    }

    /// Per-op split plan — kernel dispatch on the op's own shape plus
    /// its MAC-proportional band height. Shared by the grouping-off
    /// path and by sub-threshold grouping buckets.
    fn per_op_plan(
        &self,
        xp: &BfpMatrix,
        wp: &BfpMatrix,
        report: &mut EncodeReport,
        total_macs: usize,
        threads: usize,
    ) -> Plan {
        let (m, n) = (xp.rows, wp.rows);
        let (xl, wl) = (xp.mantissas.layout(), wp.mantissas.layout());
        let block = xp.fmt.block_size;
        let shape = GemmShape::new(m, n, xp.cols);
        let kernel = match self.kernel {
            Some(k) => kernels::registry().select_from(k, xl, wl, block),
            None => kernels::active_kernel(xl, wl, block, shape),
        };
        report.kernel_ops.record(kernel.name(), shape.mnk_bucket());
        let macs = m.saturating_mul(n).saturating_mul(xp.cols);
        Plan {
            kernel,
            band: self.band_for(m, macs, total_macs, threads),
            fused_shifts: None,
        }
    }

    /// Shard height for one op: the explicit override, or a height that
    /// gives the op a number of bands proportional to its share of the
    /// batch MAC volume (targeting ~3 bands per pool thread overall).
    /// Small batches stay whole-op serial.
    fn band_for(&self, m: usize, macs: usize, total_macs: usize, threads: usize) -> usize {
        if let Some(rows) = self.band_rows {
            return rows;
        }
        if threads <= 1 || total_macs < PARALLEL_MIN_MACS {
            return m.max(1);
        }
        let share = (macs as f64 / total_macs as f64 * (3 * threads) as f64).round() as usize;
        let bands = share.clamp(1, m.max(1));
        m.div_ceil(bands).max(1)
    }
}

/// One op's output as it leaves the MAC stage of
/// [`BatchGemm::run_split_with_stats`], waiting for the decode stage.
pub(crate) enum StagedOut {
    /// Already a finished f32 output (wide-mantissa ops the split does
    /// not cover, and degenerate empty shapes). Arena-backed except for
    /// the empty case.
    Fused(Mat),
    /// Raw `i32` block MACs plus the shift planes needed to decode
    /// them. All three buffers are arena checkouts; `decode_staged`
    /// returns them. Layout: `macs[(i * n + j) * kb + k]` for output
    /// row `i`, column `j`, block `k`.
    Macs {
        macs: Vec<i32>,
        xsh: Vec<i32>,
        wsh: Vec<i32>,
        m: usize,
        n: usize,
        kb: usize,
    },
}

/// Everything [`BatchGemm::run_split_with_stats`] hands the decode
/// stage: one [`StagedOut`] per op, submission-ordered, plus the
/// batch's encode report.
pub(crate) struct StagedBatch {
    pub(crate) staged: Vec<StagedOut>,
    pub(crate) report: EncodeReport,
}

/// The **decode stage** of the split path: turn one [`StagedOut`] into
/// its final f32 output. `Fused` passes through; `Macs` replays the
/// exact per-element scale-shift accumulation the fused kernels run
/// (same `f64` accumulator, same ascending-`k` order — bit-identical by
/// construction), band-sharded on the pool when the volume warrants it.
/// The MAC and shift planes return to the arena here; the f32 output is
/// an arena checkout the caller attaches to the ticket.
pub(crate) fn decode_staged(rt: &ExecRuntime, staged: StagedOut) -> Mat {
    match staged {
        StagedOut::Fused(out) => out,
        StagedOut::Macs { macs, xsh, wsh, m, n, kb } => {
            let arena = rt.arena();
            let mut data = arena.take_f32(m * n);
            let threads = rt.pool().threads();
            let work = m.saturating_mul(n).saturating_mul(kb);
            if threads <= 1 || work < DECODE_PARALLEL_MIN {
                kernels::decode_mac_band(&macs[..m * n * kb], &xsh, &wsh, 0, m, n, kb, &mut data);
            } else {
                // Same banding idea as the GEMM stage: ~3 bands per
                // pool thread, each decoding a contiguous row range.
                let band = m.div_ceil(3 * threads).max(1);
                let jobs: Vec<Job> = data
                    .chunks_mut(band * n)
                    .enumerate()
                    .map(|(t, chunk)| {
                        let r0 = t * band;
                        let rows = chunk.len() / n;
                        let macs = &macs[r0 * n * kb..(r0 + rows) * n * kb];
                        let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
                        Box::new(move || {
                            kernels::decode_mac_band(macs, xsh, wsh, r0, rows, n, kb, chunk);
                        }) as Job
                    })
                    .collect();
                rt.pool().scope_run(jobs);
            }
            arena.put_i32(macs);
            arena.put_i32(xsh);
            arena.put_i32(wsh);
            Mat {
                rows: m,
                cols: n,
                data,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::hbfp_gemm_scalar;
    use crate::util::Rng;

    fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Arc<Mat> {
        Arc::new(
            Mat::new(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn empty_batch_is_empty() {
        let rt = ExecRuntime::with_threads(2);
        assert!(BatchGemm::new(&rt).run(&[]).unwrap().is_empty());
    }

    #[test]
    fn owned_op_validates_and_reports_macs() {
        let mut rng = Rng::new(6);
        let x = randmat(&mut rng, 2, 8);
        let w = randmat(&mut rng, 8, 3);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        assert_eq!(op.macs(), 2 * 3 * 8);
        let bad = randmat(&mut rng, 9, 3);
        assert!(OwnedGemmOp::new(x, bad, fmt).is_err());
    }

    #[test]
    fn shape_errors_name_the_offending_op() {
        let rt = ExecRuntime::with_threads(1);
        let mut rng = Rng::new(7);
        let a = randmat(&mut rng, 2, 8);
        let w_ok = randmat(&mut rng, 8, 3);
        let w_bad = randmat(&mut rng, 9, 3);
        let fmt = BlockFormat::new(4, 16).unwrap();
        // Struct-literal construction bypasses `new`'s validation; `run`
        // still catches it and names the op.
        let err = BatchGemm::new(&rt)
            .run(&[
                OwnedGemmOp {
                    x: Arc::clone(&a),
                    w: w_ok,
                    fmt,
                    encoded: Default::default(),
                    digest: Default::default(),
                },
                OwnedGemmOp {
                    x: a,
                    w: w_bad,
                    fmt,
                    encoded: Default::default(),
                    digest: Default::default(),
                },
            ])
            .unwrap_err();
        assert!(err.to_string().contains("op 1"), "{err}");
    }

    #[test]
    fn heterogeneous_batch_matches_scalar_in_submission_order() {
        let rt = ExecRuntime::with_threads(3);
        let mut rng = Rng::new(0xBA7);
        // Mixed shapes, formats, and plane dtypes (m=12 -> i16).
        let cases = [
            (4u32, 16usize, 5usize, 40, 7),
            (6, 64, 9, 130, 4),
            (12, 16, 3, 33, 6),
        ];
        let ops: Vec<OwnedGemmOp> = cases
            .iter()
            .map(|&(m, b, r, k, c)| {
                let fmt = BlockFormat::new(m, b).unwrap();
                OwnedGemmOp::new(randmat(&mut rng, r, k), randmat(&mut rng, k, c), fmt).unwrap()
            })
            .collect();
        let outs = BatchGemm::new(&rt).run(&ops).unwrap();
        assert_eq!(outs.len(), ops.len());
        for (i, (op, got)) in ops.iter().zip(&outs).enumerate() {
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            assert_eq!((got.rows, got.cols), (want.rows, want.cols), "op {i}");
            for (g, s) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits(), "op {i}");
            }
        }
    }

    #[test]
    fn band_override_and_cache_toggle_keep_bits() {
        let rt = ExecRuntime::with_threads(4);
        let mut rng = Rng::new(0x5EED);
        let fmt = BlockFormat::new(4, 64).unwrap();
        let x = randmat(&mut rng, 23, 100);
        let w = randmat(&mut rng, 100, 11);
        let op = OwnedGemmOp::new(x, w, fmt).unwrap();
        let base = BatchGemm::new(&rt).run(std::slice::from_ref(&op)).unwrap();
        for band in [1usize, 4, 1000] {
            for cached in [true, false] {
                let got = BatchGemm::new(&rt)
                    .band_rows(band)
                    .cache_weights(cached)
                    .run(std::slice::from_ref(&op))
                    .unwrap();
                for (g, b) in got[0].data.iter().zip(&base[0].data) {
                    assert_eq!(g.to_bits(), b.to_bits(), "band {band} cached {cached}");
                }
            }
        }
    }

    #[test]
    fn pre_encoded_ops_skip_inline_encode_and_keep_bits() {
        // Fill the shared slot the way the service's pipeline does,
        // then run the batch: the report must attribute the op to the
        // pre-encode path and the result must stay bit-identical to a
        // fresh (inline-encoded) op and to the scalar reference.
        let rt = ExecRuntime::with_threads(2);
        let mut rng = Rng::new(0x93E);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let x = randmat(&mut rng, 7, 96);
        let w = randmat(&mut rng, 96, 9);
        let pre_op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        assert!(!pre_op.is_pre_encoded());
        pre_op.pre_encode(&rt).unwrap();
        assert!(pre_op.is_pre_encoded());
        // Idempotent: a second call leaves the filled slot alone.
        pre_op.pre_encode(&rt).unwrap();
        let (pre_out, pre_report) = BatchGemm::new(&rt)
            .run_with_stats(std::slice::from_ref(&pre_op))
            .unwrap();
        assert_eq!((pre_report.pre_encoded, pre_report.inline_encoded), (1, 0));
        let inline_op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        let (inline_out, inline_report) = BatchGemm::new(&rt)
            .run_with_stats(std::slice::from_ref(&inline_op))
            .unwrap();
        assert_eq!(
            (inline_report.pre_encoded, inline_report.inline_encoded),
            (0, 1)
        );
        // The sync facade never publishes inline encodes to the slot.
        assert!(!inline_op.is_pre_encoded());
        let want = crate::bfp::hbfp_gemm_scalar(&x, &w, fmt).unwrap();
        for ((p, i), s) in pre_out[0]
            .data
            .iter()
            .zip(&inline_out[0].data)
            .zip(&want.data)
        {
            assert_eq!(p.to_bits(), i.to_bits());
            assert_eq!(p.to_bits(), s.to_bits());
        }
    }

    #[test]
    fn pre_encode_estimate_counts_activation_planes() {
        let fmt = BlockFormat::new(4, 16).unwrap();
        let op = OwnedGemmOp::new(Arc::new(Mat::zeros(3, 20)), Arc::new(Mat::zeros(20, 5)), fmt)
            .unwrap();
        // 3 rows x ceil(20/16) = 2 blocks each: 6 blocks of 16
        // nibble-packed values (8 bytes) + 6 i32 exponents.
        assert_eq!(op.pre_encode_estimate_bytes(), 6 * 8 + 6 * 4);
        // Wider mantissas charge their wider planes: i8 and i16.
        let fmt8 = BlockFormat::new(6, 16).unwrap();
        let op8 = OwnedGemmOp::new(Arc::new(Mat::zeros(3, 20)), Arc::new(Mat::zeros(20, 5)), fmt8)
            .unwrap();
        assert_eq!(op8.pre_encode_estimate_bytes(), 6 * 16 + 6 * 4);
        let fmt16 = BlockFormat::new(12, 16).unwrap();
        let op16 =
            OwnedGemmOp::new(Arc::new(Mat::zeros(3, 20)), Arc::new(Mat::zeros(20, 5)), fmt16)
                .unwrap();
        assert_eq!(op16.pre_encode_estimate_bytes(), 6 * 32 + 6 * 4);
    }

    #[test]
    fn split_path_matches_fused_and_recycles_staging() {
        let rt = ExecRuntime::with_threads(3);
        let mut rng = Rng::new(0x5137);
        // Narrow formats take the MAC/decode split; the 12-bit op's
        // i16 planes keep the fused kernel inside the split path.
        let cases = [
            (4u32, 16usize, 6usize, 70, 5),
            (6, 64, 9, 130, 4),
            (12, 16, 3, 33, 6),
        ];
        let ops: Vec<OwnedGemmOp> = cases
            .iter()
            .map(|&(mb, b, r, k, c)| {
                let fmt = BlockFormat::new(mb, b).unwrap();
                OwnedGemmOp::new(randmat(&mut rng, r, k), randmat(&mut rng, k, c), fmt).unwrap()
            })
            .collect();
        let bg = BatchGemm::new(&rt);
        let batch = bg.run_split_with_stats(&ops).unwrap();
        assert!(matches!(batch.staged[0], StagedOut::Macs { .. }));
        assert!(matches!(batch.staged[1], StagedOut::Macs { .. }));
        assert!(matches!(batch.staged[2], StagedOut::Fused(_)));
        assert_eq!(batch.report.kernel_ops.total(), ops.len() as u64);
        let mut outs: Vec<Mat> = Vec::new();
        for s in batch.staged {
            outs.push(decode_staged(&rt, s));
        }
        for (i, (op, got)) in ops.iter().zip(&outs).enumerate() {
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            assert_eq!((got.rows, got.cols), (want.rows, want.cols), "op {i}");
            for (g, s) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits(), "op {i}");
            }
        }
        // Return the outputs the way a ticket drop would, then rerun:
        // the second split run must recycle the staging planes.
        for o in outs {
            rt.arena().put_f32(o.data);
        }
        let before = rt.arena().stats();
        assert!(before.resident_bytes > 0, "{before:?}");
        let batch = bg.run_split_with_stats(&ops).unwrap();
        let after = rt.arena().stats();
        assert!(after.hits > before.hits, "{after:?}");
        for s in batch.staged {
            rt.arena().put_f32(decode_staged(&rt, s).data);
        }
    }

    #[test]
    fn grouped_split_matches_per_op_and_counts() {
        let rt = ExecRuntime::with_threads(3);
        let mut rng = Rng::new(0x6A0);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let w = randmat(&mut rng, 48, 7);
        let other = randmat(&mut rng, 48, 7);
        // Three ops share `w` (one group), one op is a singleton.
        let ops: Vec<OwnedGemmOp> = vec![
            OwnedGemmOp::new(randmat(&mut rng, 5, 48), Arc::clone(&w), fmt).unwrap(),
            OwnedGemmOp::new(randmat(&mut rng, 3, 48), other, fmt).unwrap(),
            OwnedGemmOp::new(randmat(&mut rng, 9, 48), Arc::clone(&w), fmt).unwrap(),
            OwnedGemmOp::new(randmat(&mut rng, 2, 48), w, fmt).unwrap(),
        ];
        let grouped = BatchGemm::new(&rt)
            .group_min_ops(2)
            .run_split_with_stats(&ops)
            .unwrap();
        assert_eq!(grouped.report.grouped_ops, 3, "{:?}", grouped.report);
        assert_eq!(grouped.report.groups_formed, 1, "{:?}", grouped.report);
        assert!(grouped.report.weight_plane_loads_avoided > 0);
        assert_eq!(grouped.report.kernel_ops.total(), ops.len() as u64);
        let off = BatchGemm::new(&rt)
            .group_min_ops(0)
            .run_split_with_stats(&ops)
            .unwrap();
        assert_eq!(off.report.grouped_ops, 0);
        assert_eq!(off.report.groups_formed, 0);
        assert_eq!(off.report.weight_plane_loads_avoided, 0);
        // Tiny forced bands make every group span several band tiles;
        // segments then cross member boundaries mid-tile.
        let banded = BatchGemm::new(&rt)
            .group_min_ops(2)
            .band_rows(2)
            .run_split_with_stats(&ops)
            .unwrap();
        let decode =
            |b: StagedBatch| -> Vec<Mat> { b.staged.into_iter().map(|s| decode_staged(&rt, s)).collect() };
        let (got, base, got_banded) = (decode(grouped), decode(off), decode(banded));
        for (i, op) in ops.iter().enumerate() {
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            for (((g, b), t), s) in got[i]
                .data
                .iter()
                .zip(&base[i].data)
                .zip(&got_banded[i].data)
                .zip(&want.data)
            {
                assert_eq!(g.to_bits(), b.to_bits(), "op {i}");
                assert_eq!(g.to_bits(), t.to_bits(), "op {i}");
                assert_eq!(g.to_bits(), s.to_bits(), "op {i}");
            }
        }
    }

    #[test]
    fn repeated_weights_hit_the_cache() {
        let rt = ExecRuntime::with_threads(2);
        let mut rng = Rng::new(0xCAC4E);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let w = randmat(&mut rng, 32, 8);
        let x1 = randmat(&mut rng, 4, 32);
        let x2 = randmat(&mut rng, 6, 32);
        let ops = [
            OwnedGemmOp::new(x1, Arc::clone(&w), fmt).unwrap(),
            OwnedGemmOp::new(x2, w, fmt).unwrap(),
        ];
        BatchGemm::new(&rt).run(&ops).unwrap();
        BatchGemm::new(&rt).run(&ops).unwrap();
        let s = rt.cache_stats();
        assert!(s.hits >= 3, "same weights must be encoded once: {s:?}");
        assert_eq!(s.misses, 1, "{s:?}");
    }
}
