//! `BatchGemm` — the batched/sharded GEMM **execution stage** of the
//! execution runtime.
//!
//! Since PR 3 this is the internal stage the async
//! [`super::service::BfpService`] drives: the service's admission loop
//! forms deadline-aware, MAC-budgeted batches of [`OwnedGemmOp`]s and
//! hands each batch to [`BatchGemm::run`]. The `run` entry point is
//! kept public as a **thin synchronous facade** (tests, benches, and
//! embedders that want batch-at-a-time semantics); new consumers should
//! migrate to [`super::service::BfpService::submit`], which adds
//! backpressure, deadlines, and cross-batch pipelining on top of the
//! same execution stage.
//!
//! A serving workload is a stream of heterogeneous `(A, B, format)`
//! multiplies. Running them one `gemm_packed` call at a time leaves the
//! pool idle at every op boundary and re-encodes weight operands that
//! repeat across requests. `BatchGemm` instead:
//!
//! 1. **encodes** all activation operands in parallel on the pool and
//!    pulls weight operands through the runtime's encoded-operand cache
//!    ([`super::cache`]) so repeated weights are packed once;
//! 2. **shards** every op into band-level work items (contiguous
//!    activation-row ranges, sized by each op's share of the batch MAC
//!    volume) and runs the whole batch's bands on the pool as one
//!    scope — small ops no longer serialize behind large ones;
//! 3. returns results **in submission order**.
//!
//! # Determinism
//!
//! Band partitioning never changes numerics: each output element is
//! accumulated by exactly one band job in ascending block order, so any
//! shard size, any pool width, and any batch ordering produce results
//! bit-identical to per-op [`crate::bfp::hbfp_gemm_scalar`] — the
//! invariant `tests/property_exec.rs` and `tests/property_service.rs`
//! pin. The service may *reorder execution* across batches; it can
//! never reorder accumulation within an op.

use super::pool::Job;
use super::ExecRuntime;
use crate::bfp::gemm::{band_shifts, BandTask, PARALLEL_MIN_MACS};
use crate::bfp::kernels::{self, GemmKernel};
use crate::bfp::{BfpMatrix, BlockFormat, Mat, Quantizer};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// One GEMM: `x (m x K)` times `w (K x n)` with both operands quantized
/// to `fmt` (nearest rounding — the deterministic forward-pass
/// transform, required for operand caching).
///
/// Operands are **owned** (`Arc<Mat>`), so an op can cross threads,
/// outlive its submitting frame, and share a weight matrix across many
/// requests without copying — the contract the async
/// [`super::service::BfpService`] needs. (The pre-service `GemmOp<'a>`
/// borrowed its operands and could not leave the caller's stack; those
/// `&'a` borrows are gone.)
#[derive(Clone)]
pub struct OwnedGemmOp {
    pub x: Arc<Mat>,
    pub w: Arc<Mat>,
    pub fmt: BlockFormat,
}

impl OwnedGemmOp {
    /// Build an op, validating the contraction dims up front (the
    /// service rejects malformed ops at admission, not mid-batch).
    pub fn new(x: Arc<Mat>, w: Arc<Mat>, fmt: BlockFormat) -> Result<Self> {
        if x.cols != w.rows {
            bail!("inner dims {} vs {} do not contract", x.cols, w.rows);
        }
        Ok(Self { x, w, fmt })
    }

    /// Convenience for callers that hold plain `&Mat`s: copies both
    /// operands into fresh `Arc`s. Callers with long-lived weights
    /// should hold `Arc<Mat>` themselves and use [`OwnedGemmOp::new`].
    pub fn from_mats(x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Self> {
        Self::new(Arc::new(x.clone()), Arc::new(w.clone()), fmt)
    }

    /// MAC volume of this op (saturating) — the unit of the service's
    /// per-batch admission budget.
    pub fn macs(&self) -> usize {
        self.x
            .rows
            .saturating_mul(self.w.cols)
            .saturating_mul(self.x.cols)
    }
}

/// Batched GEMM executor over an [`ExecRuntime`] (see module docs).
pub struct BatchGemm<'rt> {
    rt: &'rt ExecRuntime,
    band_rows: Option<usize>,
    cache_weights: bool,
    kernel: Option<&'static dyn GemmKernel>,
}

impl<'rt> BatchGemm<'rt> {
    pub fn new(rt: &'rt ExecRuntime) -> Self {
        Self {
            rt,
            band_rows: None,
            cache_weights: true,
            kernel: None,
        }
    }

    /// Force a fixed shard height (activation rows per band) instead of
    /// the MAC-proportional default. Any value yields bit-identical
    /// results; this exists for tests and tuning.
    pub fn band_rows(mut self, rows: usize) -> Self {
        self.band_rows = Some(rows.max(1));
        self
    }

    /// Disable the weight-operand cache for this batch (weights are
    /// then encoded fresh, still in parallel).
    pub fn cache_weights(mut self, on: bool) -> Self {
        self.cache_weights = on;
        self
    }

    /// Force a specific kernel backend instead of the registry's
    /// per-operand-pair dispatch. Ops whose plane-layout pair the
    /// forced backend cannot run degrade down the registry's fallback
    /// chain (ending at the scalar kernel) — bit-identical either way.
    /// This is how the property suites pin every registered backend.
    pub fn with_kernel(mut self, kernel: &'static dyn GemmKernel) -> Self {
        self.kernel = Some(kernel);
        self
    }

    /// Execute the batch; `out[i]` corresponds to `ops[i]`.
    ///
    /// This is the **synchronous facade** over the execution stage: the
    /// caller blocks for the whole batch. It is what the
    /// [`super::service::BfpService`] scheduler thread calls internally;
    /// request-level consumers should migrate to `BfpService::submit`,
    /// which pipelines batches and adds deadlines and backpressure.
    pub fn run(&self, ops: &[OwnedGemmOp]) -> Result<Vec<Mat>> {
        for (i, op) in ops.iter().enumerate() {
            if op.x.cols != op.w.rows {
                bail!(
                    "op {i}: inner dims {} vs {} do not contract",
                    op.x.cols,
                    op.w.rows
                );
            }
        }

        // ---- encode stage: activations in parallel, weights cached ----
        let mut xs: Vec<BfpMatrix> = (0..ops.len()).map(|_| BfpMatrix::empty()).collect();
        let mut xerrs: Vec<Option<anyhow::Error>> = (0..ops.len()).map(|_| None).collect();
        {
            let jobs: Vec<Job> = xs
                .iter_mut()
                .zip(xerrs.iter_mut())
                .zip(ops)
                .map(|((slot, err), op)| {
                    let q = Quantizer::nearest(op.fmt.mantissa_bits);
                    Box::new(move || {
                        if let Err(e) =
                            slot.encode_into_serial(&op.x.data, op.x.rows, op.x.cols, op.fmt, q, 0)
                        {
                            *err = Some(e);
                        }
                    }) as Job
                })
                .collect();
            self.rt.pool().scope_run(jobs);
        }
        for (i, e) in xerrs.iter_mut().enumerate() {
            if let Some(e) = e.take() {
                return Err(e.context(format!("encoding activations of op {i}")));
            }
        }
        let mut ws: Vec<Arc<BfpMatrix>> = Vec::with_capacity(ops.len());
        for (i, op) in ops.iter().enumerate() {
            let enc = if self.cache_weights {
                self.rt.encode_transposed_cached(op.w.as_ref(), op.fmt)
            } else {
                let mut fresh = BfpMatrix::empty();
                fresh
                    .encode_transposed_on(
                        self.rt.pool(),
                        op.w.as_ref(),
                        op.fmt,
                        Quantizer::nearest(op.fmt.mantissa_bits),
                    )
                    .map(|_| Arc::new(fresh))
            };
            ws.push(enc.with_context(|| format!("encoding weights of op {i}"))?);
        }

        // ---- shard + execute stage ----
        let shifts: Vec<(Vec<i32>, Vec<i32>)> = xs
            .iter()
            .zip(&ws)
            .map(|(x, w)| (band_shifts(x), band_shifts(w)))
            .collect();
        let mut outs: Vec<Mat> = ops
            .iter()
            .map(|op| Mat::zeros(op.x.rows, op.w.cols))
            .collect();
        let threads = self.rt.pool().threads();
        let total_macs: usize = ops
            .iter()
            .map(OwnedGemmOp::macs)
            .fold(0usize, usize::saturating_add);
        let mut jobs: Vec<Job> = Vec::new();
        for (((out, xp), wp), (xsh, wsh)) in outs.iter_mut().zip(&xs).zip(&ws).zip(&shifts) {
            let (m, n) = (xp.rows, wp.rows);
            if m == 0 || n == 0 {
                continue;
            }
            // Kernel dispatch is per op: a heterogeneous batch can mix
            // nibble-packed, i8, and i16 operands, each running the
            // best backend for its layout pair.
            let (xl, wl) = (xp.mantissas.layout(), wp.mantissas.layout());
            let block = xp.fmt.block_size;
            let kernel = match self.kernel {
                Some(k) => kernels::registry().select_from(k, xl, wl, block),
                None => kernels::active_kernel(xl, wl, block),
            };
            let macs = m.saturating_mul(n).saturating_mul(xp.cols);
            let band = self.band_for(m, macs, total_macs, threads);
            let wref: &BfpMatrix = wp;
            for (t, chunk) in out.data.chunks_mut(band * n).enumerate() {
                let r0 = t * band;
                let (xsh, wsh) = (xsh.as_slice(), wsh.as_slice());
                jobs.push(Box::new(move || {
                    kernel.run_band(BandTask {
                        x: xp,
                        w: wref,
                        xsh,
                        wsh,
                        r0,
                        rows: chunk.len() / n,
                        out: chunk,
                    });
                }) as Job);
            }
        }
        self.rt.pool().scope_run(jobs);
        Ok(outs)
    }

    /// Shard height for one op: the explicit override, or a height that
    /// gives the op a number of bands proportional to its share of the
    /// batch MAC volume (targeting ~3 bands per pool thread overall).
    /// Small batches stay whole-op serial.
    fn band_for(&self, m: usize, macs: usize, total_macs: usize, threads: usize) -> usize {
        if let Some(rows) = self.band_rows {
            return rows;
        }
        if threads <= 1 || total_macs < PARALLEL_MIN_MACS {
            return m.max(1);
        }
        let share = (macs as f64 / total_macs as f64 * (3 * threads) as f64).round() as usize;
        let bands = share.clamp(1, m.max(1));
        m.div_ceil(bands).max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::hbfp_gemm_scalar;
    use crate::util::Rng;

    fn randmat(rng: &mut Rng, rows: usize, cols: usize) -> Arc<Mat> {
        Arc::new(
            Mat::new(
                rows,
                cols,
                (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn empty_batch_is_empty() {
        let rt = ExecRuntime::with_threads(2);
        assert!(BatchGemm::new(&rt).run(&[]).unwrap().is_empty());
    }

    #[test]
    fn owned_op_validates_and_reports_macs() {
        let mut rng = Rng::new(6);
        let x = randmat(&mut rng, 2, 8);
        let w = randmat(&mut rng, 8, 3);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let op = OwnedGemmOp::new(Arc::clone(&x), Arc::clone(&w), fmt).unwrap();
        assert_eq!(op.macs(), 2 * 3 * 8);
        let bad = randmat(&mut rng, 9, 3);
        assert!(OwnedGemmOp::new(x, bad, fmt).is_err());
    }

    #[test]
    fn shape_errors_name_the_offending_op() {
        let rt = ExecRuntime::with_threads(1);
        let mut rng = Rng::new(7);
        let a = randmat(&mut rng, 2, 8);
        let w_ok = randmat(&mut rng, 8, 3);
        let w_bad = randmat(&mut rng, 9, 3);
        let fmt = BlockFormat::new(4, 16).unwrap();
        // Struct-literal construction bypasses `new`'s validation; `run`
        // still catches it and names the op.
        let err = BatchGemm::new(&rt)
            .run(&[
                OwnedGemmOp {
                    x: Arc::clone(&a),
                    w: w_ok,
                    fmt,
                },
                OwnedGemmOp { x: a, w: w_bad, fmt },
            ])
            .unwrap_err();
        assert!(err.to_string().contains("op 1"), "{err}");
    }

    #[test]
    fn heterogeneous_batch_matches_scalar_in_submission_order() {
        let rt = ExecRuntime::with_threads(3);
        let mut rng = Rng::new(0xBA7);
        // Mixed shapes, formats, and plane dtypes (m=12 -> i16).
        let cases = [
            (4u32, 16usize, 5usize, 40, 7),
            (6, 64, 9, 130, 4),
            (12, 16, 3, 33, 6),
        ];
        let ops: Vec<OwnedGemmOp> = cases
            .iter()
            .map(|&(m, b, r, k, c)| {
                let fmt = BlockFormat::new(m, b).unwrap();
                OwnedGemmOp::new(randmat(&mut rng, r, k), randmat(&mut rng, k, c), fmt).unwrap()
            })
            .collect();
        let outs = BatchGemm::new(&rt).run(&ops).unwrap();
        assert_eq!(outs.len(), ops.len());
        for (i, (op, got)) in ops.iter().zip(&outs).enumerate() {
            let want = hbfp_gemm_scalar(&op.x, &op.w, op.fmt).unwrap();
            assert_eq!((got.rows, got.cols), (want.rows, want.cols), "op {i}");
            for (g, s) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), s.to_bits(), "op {i}");
            }
        }
    }

    #[test]
    fn band_override_and_cache_toggle_keep_bits() {
        let rt = ExecRuntime::with_threads(4);
        let mut rng = Rng::new(0x5EED);
        let fmt = BlockFormat::new(4, 64).unwrap();
        let x = randmat(&mut rng, 23, 100);
        let w = randmat(&mut rng, 100, 11);
        let op = OwnedGemmOp::new(x, w, fmt).unwrap();
        let base = BatchGemm::new(&rt).run(std::slice::from_ref(&op)).unwrap();
        for band in [1usize, 4, 1000] {
            for cached in [true, false] {
                let got = BatchGemm::new(&rt)
                    .band_rows(band)
                    .cache_weights(cached)
                    .run(std::slice::from_ref(&op))
                    .unwrap();
                for (g, b) in got[0].data.iter().zip(&base[0].data) {
                    assert_eq!(g.to_bits(), b.to_bits(), "band {band} cached {cached}");
                }
            }
        }
    }

    #[test]
    fn repeated_weights_hit_the_cache() {
        let rt = ExecRuntime::with_threads(2);
        let mut rng = Rng::new(0xCAC4E);
        let fmt = BlockFormat::new(4, 16).unwrap();
        let w = randmat(&mut rng, 32, 8);
        let x1 = randmat(&mut rng, 4, 32);
        let x2 = randmat(&mut rng, 6, 32);
        let ops = [
            OwnedGemmOp::new(x1, Arc::clone(&w), fmt).unwrap(),
            OwnedGemmOp::new(x2, w, fmt).unwrap(),
        ];
        BatchGemm::new(&rt).run(&ops).unwrap();
        BatchGemm::new(&rt).run(&ops).unwrap();
        let s = rt.cache_stats();
        assert!(s.hits >= 3, "same weights must be encoded once: {s:?}");
        assert_eq!(s.misses, 1, "{s:?}");
    }
}
