//! Admission queue of the BFP execution service: bounded submission,
//! per-request QoS, and the deadline-aware batch-selection policy.
//!
//! [`super::service::BfpService`] splits into two halves. This module is
//! the **admission half**: a bounded MPSC queue of pending
//! [`GemmRequest`]s plus the [`Ticket`] handles their callers hold. The
//! service's scheduler thread drains it with [`SubmitQueue::pop_batch`],
//! which forms one execution batch per call: requests sorted
//! **earliest-deadline-first within priority class** (no-deadline
//! requests sort after every deadline in their class, FIFO among
//! themselves), cut off at a MAC budget so one giant batch cannot
//! monopolize the pool while a deadline burns. The service's
//! pre-encode stage drains the same queue through
//! [`SubmitQueue::claim_encode_work`] — claims come out in the same
//! EDF order, bounded by the `BOOSTERS_PREENCODE_MB` byte budget
//! (charged per claim, released per pop; the encoder stalls, never
//! drops, when over budget).
//!
//! # Backpressure contract
//!
//! `push` never blocks: a full queue returns
//! [`AdmissionError::QueueFull`] to the submitter immediately, which is
//! the service's backpressure signal (`submit` is non-blocking by API
//! contract). `push_blocking` exists for the synchronous facades, which
//! are allowed to wait for space — they were blocking APIs to begin
//! with.
//!
//! # Ordering vs numerics
//!
//! Admission order, batch formation, and priority classes decide *when*
//! a request executes, never *what* it computes: every batch runs
//! through the bit-deterministic [`super::scheduler::BatchGemm`] stage,
//! so any admission order yields results bit-identical to the scalar
//! reference (`tests/property_service.rs` pins this).

use super::pool::{lock_or_poisoned, wait_or_poisoned, wait_timeout_or_poisoned};
use super::scheduler::{GroupKey, OwnedGemmOp};
use crate::bfp::Mat;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Priority class of a request. Within a batch-selection pass, every
/// `Interactive` request outranks every `Bulk` one; deadlines order
/// requests inside a class. Sustained `Interactive` load can therefore
/// starve `Bulk` — that is the intended semantics of a priority class,
/// not an accident of the implementation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-sensitive serving traffic.
    Interactive,
    /// Throughput traffic (sweeps, training-side requantization).
    Bulk,
}

/// One unit of admission: an owned op plus its QoS envelope.
pub struct GemmRequest {
    pub op: OwnedGemmOp,
    /// Deadline **relative to submission**; the service records the
    /// absolute deadline at admission. A missed deadline is *observed*
    /// (per-response flag + service counter), never enforced by
    /// cancellation — results stay bit-identical either way.
    pub deadline: Option<Duration>,
    pub priority: Priority,
}

impl GemmRequest {
    pub fn new(op: OwnedGemmOp) -> Self {
        Self {
            op,
            deadline: None,
            priority: Priority::Bulk,
        }
    }

    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }
}

/// Typed admission failure. `submit` hands these back instead of
/// blocking or panicking; callers decide whether to shed, retry, or
/// fall back to the blocking facade.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionError {
    /// The bounded queue is at capacity — backpressure, try later.
    QueueFull { capacity: usize },
    /// The service is shutting down and admits nothing new.
    ShuttingDown,
    /// The op can never execute (shape mismatch); submitting again will
    /// not help.
    InvalidShape { reason: String },
}

impl AdmissionError {
    /// Stable wire code of this variant — the fabric protocol ships
    /// typed backpressure as `(code, detail)` so a remote submitter
    /// gets the same enum a local one does. Codes are frozen (the wire
    /// is a cross-process contract); new variants append, never renumber.
    pub fn wire_code(&self) -> u8 {
        match self {
            AdmissionError::QueueFull { .. } => 1,
            AdmissionError::ShuttingDown => 2,
            AdmissionError::InvalidShape { .. } => 3,
        }
    }

    /// Variant-specific detail string paired with [`wire_code`] on the
    /// wire (`capacity` rendered as decimal; the `InvalidShape` reason
    /// verbatim).
    ///
    /// [`wire_code`]: AdmissionError::wire_code
    pub fn wire_detail(&self) -> String {
        match self {
            AdmissionError::QueueFull { capacity } => capacity.to_string(),
            AdmissionError::ShuttingDown => String::new(),
            AdmissionError::InvalidShape { reason } => reason.clone(),
        }
    }

    /// Inverse of [`wire_code`] / [`wire_detail`]: `None` for an
    /// unknown code (a newer peer's variant — the caller surfaces it as
    /// an opaque remote error rather than guessing).
    ///
    /// [`wire_code`]: AdmissionError::wire_code
    /// [`wire_detail`]: AdmissionError::wire_detail
    pub fn from_wire(code: u8, detail: &str) -> Option<Self> {
        match code {
            1 => Some(AdmissionError::QueueFull {
                capacity: detail.trim().parse().unwrap_or(0),
            }),
            2 => Some(AdmissionError::ShuttingDown),
            3 => Some(AdmissionError::InvalidShape {
                reason: detail.to_string(),
            }),
            _ => None,
        }
    }
}

impl std::fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdmissionError::QueueFull { capacity } => {
                write!(f, "admission queue full ({capacity} pending requests)")
            }
            AdmissionError::ShuttingDown => write!(f, "service is shutting down"),
            AdmissionError::InvalidShape { reason } => {
                write!(f, "request rejected at admission: {reason}")
            }
        }
    }
}

impl std::error::Error for AdmissionError {}

/// Completed-request payload handed back through a [`Ticket`].
#[derive(Debug)]
pub struct GemmResponse {
    pub out: Mat,
    /// Admission → start of the executing batch.
    pub queue_ms: f64,
    /// Admission → fulfillment (what a client observes).
    pub total_ms: f64,
    /// The request finished after its absolute deadline.
    pub deadline_missed: bool,
    /// Wall time the executing batch spent in its encode stage
    /// (batch-attributed: every request in a batch reports the batch's
    /// stage times, which is what the latency breakdown aggregates).
    pub encode_ms: f64,
    /// Wall time of the batch's integer-GEMM (MAC) stage.
    pub gemm_ms: f64,
    /// Wall time of the batch's decode/writeback stage.
    pub decode_ms: f64,
}

#[derive(Debug)]
struct TicketState {
    outcome: Option<Result<GemmResponse>>,
    taken: bool,
    /// Set by the decode stage when the response's output buffer is
    /// arena-backed: the arena plus the buffer's charged bytes. Cleared
    /// on take (accounting release — the caller owns the buffer now)
    /// or consumed on drop-without-take (the buffer itself recycles).
    arena: Option<(Arc<super::arena::BufferArena>, u64)>,
}

/// Shared completion slot between a [`Ticket`] and the decode stage
/// (or, on batch-error retries, the scheduler thread).
#[derive(Debug)]
pub(crate) struct TicketInner {
    state: Mutex<TicketState>,
    cv: Condvar,
}

impl TicketInner {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            state: Mutex::new(TicketState {
                outcome: None,
                taken: false,
                arena: None,
            }),
            cv: Condvar::new(),
        })
    }

    /// Publish the outcome and wake every waiter. Called exactly once
    /// per request by the pipeline stage that finished it.
    pub(crate) fn fulfill(&self, outcome: Result<GemmResponse>) {
        self.fulfill_recycling(outcome, None);
    }

    /// [`TicketInner::fulfill`] for arena-backed outputs: `arena`
    /// carries the arena handle and the output buffer's charged bytes,
    /// so the take/drop paths can release or recycle it.
    pub(crate) fn fulfill_recycling(
        &self,
        outcome: Result<GemmResponse>,
        arena: Option<(Arc<super::arena::BufferArena>, u64)>,
    ) {
        let mut st = lock_or_poisoned(&self.state, "service ticket");
        debug_assert!(st.outcome.is_none() && !st.taken, "ticket fulfilled twice");
        st.outcome = Some(outcome);
        st.arena = arena;
        self.cv.notify_all();
    }
}

impl Drop for TicketInner {
    fn drop(&mut self) {
        // Last handle gone: a fulfilled-but-never-taken arena-backed
        // output recycles instead of hitting the allocator — this is
        // the "returned on drop" half of the ticket/arena contract.
        let Ok(st) = self.state.get_mut() else {
            return;
        };
        if st.taken {
            return;
        }
        if let Some((arena, bytes)) = st.arena.take() {
            match st.outcome.take() {
                Some(Ok(resp)) => arena.put_f32(resp.out.data),
                // An arena charge without a live output (cannot happen
                // today — errors fulfill without an arena) still must
                // not leak residency accounting.
                _ => arena.release(bytes),
            }
        }
    }
}

/// The caller's handle to one in-flight request. The result is a
/// take-once value: the first successful `wait`/`wait_deadline` moves
/// the [`GemmResponse`] out; later calls report it as already taken.
#[derive(Debug)]
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    pub(crate) fn from_inner(inner: Arc<TicketInner>) -> Self {
        Self { inner }
    }

    /// Non-blocking readiness probe: `true` once the request has been
    /// fulfilled (even if the result was already taken).
    pub fn poll(&self) -> bool {
        let st = lock_or_poisoned(&self.inner.state, "service ticket");
        st.outcome.is_some() || st.taken
    }

    /// Block until the request completes and take its result.
    pub fn wait(&self) -> Result<GemmResponse> {
        let mut st = lock_or_poisoned(&self.inner.state, "service ticket");
        loop {
            if let Some(outcome) = st.outcome.take() {
                st.taken = true;
                // The caller owns an arena-backed output from here on:
                // drop its residency charge (accounting only — the
                // buffer itself left the arena for good).
                let arena = st.arena.take();
                drop(st);
                if let Some((arena, bytes)) = arena {
                    arena.release(bytes);
                }
                return outcome;
            }
            if st.taken {
                return Err(anyhow!("ticket result already taken"));
            }
            st = wait_or_poisoned(&self.inner.cv, st, "service ticket");
        }
    }

    /// [`Ticket::wait`] bounded by `timeout`: `None` if the request is
    /// still in flight when the timeout expires (the ticket stays valid
    /// — poll or wait again later).
    pub fn wait_deadline(&self, timeout: Duration) -> Option<Result<GemmResponse>> {
        let until = Instant::now() + timeout;
        let mut st = lock_or_poisoned(&self.inner.state, "service ticket");
        loop {
            if let Some(outcome) = st.outcome.take() {
                st.taken = true;
                let arena = st.arena.take();
                drop(st);
                if let Some((arena, bytes)) = arena {
                    arena.release(bytes);
                }
                return Some(outcome);
            }
            if st.taken {
                return Some(Err(anyhow!("ticket result already taken")));
            }
            let now = Instant::now();
            if now >= until {
                return None;
            }
            st = wait_timeout_or_poisoned(&self.inner.cv, st, until - now, "service ticket");
        }
    }
}

/// One admitted request as the scheduler thread sees it.
pub(crate) struct Pending {
    pub(crate) op: OwnedGemmOp,
    pub(crate) ticket: Arc<TicketInner>,
    pub(crate) submitted_at: Instant,
    pub(crate) deadline_at: Option<Instant>,
    pub(crate) priority: Priority,
    pub(crate) macs: usize,
    /// The pre-encode stage has claimed this request (it clones the op
    /// and encodes outside the lock). Claiming is advisory — a claimed
    /// request can still be popped into a batch at any time; the
    /// op's shared encoded slot arbitrates the race.
    encode_claimed: bool,
    /// True while the request sits in the queue; cleared by `pop_batch`
    /// when it joins an execution batch. Shared with outstanding
    /// [`EncodeClaim`]s so the pre-encode stage can skip requests whose
    /// batch is already executing instead of duplicating the execution
    /// stage's inline encode.
    queued: Arc<AtomicBool>,
    /// Bytes charged against the pre-encode memory budget when the
    /// encoder claimed this request (0 when never claimed). Released
    /// when the request pops into a batch.
    pre_encode_charged: u64,
    /// Weight-identity key for group-aware batch selection, computed at
    /// admission (outside the lock) when grouping is enabled. `None`
    /// when `group_min_ops == 0` — the pop path then never inspects
    /// weight identity at all.
    group_key: Option<GroupKey>,
    seq: u64,
}

/// One request handed to the pre-encode stage: the op clone to encode
/// plus the liveness flag that tells the encoder whether the request is
/// still waiting in the queue (encoding a popped request could only
/// duplicate work the execution stage is doing right now).
pub(crate) struct EncodeClaim {
    pub(crate) op: OwnedGemmOp,
    queued: Arc<AtomicBool>,
}

impl EncodeClaim {
    /// Whether the claimed request is still in the queue (its batch has
    /// not started executing).
    pub(crate) fn still_queued(&self) -> bool {
        self.queued.load(Ordering::Acquire)
    }
}

impl Pending {
    /// Earliest-deadline-first key: priority class, then deadline
    /// (absent deadlines sort last within the class, FIFO by admission
    /// time), then admission sequence as the total-order tiebreak.
    fn edf_key(&self) -> (Priority, u8, Instant, u64) {
        match self.deadline_at {
            Some(d) => (self.priority, 0, d, self.seq),
            None => (self.priority, 1, self.submitted_at, self.seq),
        }
    }
}

struct QueueState {
    pending: Vec<Pending>,
    seq: u64,
    /// Sum of `pre_encode_charged` over queued requests: the resident
    /// set of the pre-encode memory budget. Charged at claim time from
    /// the deterministic plane-size estimate, released when the request
    /// pops into a batch (whether or not the encode finished).
    pre_encode_bytes: u64,
    shutdown: bool,
    /// Guarded by the state mutex (not an atomic): the scheduler checks
    /// it under the same lock it waits on, so a `resume` can never slip
    /// between the check and the wait (no lost wakeup).
    paused: bool,
    peak_depth: usize,
}

/// Bounded submission queue + EDF batch selection (see module docs).
pub(crate) struct SubmitQueue {
    state: Mutex<QueueState>,
    /// Signals the scheduler thread: work arrived / shutdown / resume.
    work_cv: Condvar,
    /// Signals blocked submitters: space freed.
    space_cv: Condvar,
    capacity: usize,
    /// Same-weight grouping threshold of the execution stage (0 =
    /// grouping disabled). The queue only uses it as an on/off switch:
    /// when on, admission fingerprints each op's weight and `pop_batch`
    /// prefers same-weight ops when filling out a budget-cut batch.
    group_min_ops: usize,
}

impl SubmitQueue {
    pub(crate) fn new(capacity: usize, group_min_ops: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                pending: Vec::new(),
                seq: 0,
                pre_encode_bytes: 0,
                shutdown: false,
                paused: false,
                peak_depth: 0,
            }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            capacity: capacity.max(1),
            group_min_ops,
        }
    }

    pub(crate) fn capacity(&self) -> usize {
        self.capacity
    }

    pub(crate) fn depth(&self) -> usize {
        lock_or_poisoned(&self.state, "service queue").pending.len()
    }

    pub(crate) fn peak_depth(&self) -> usize {
        lock_or_poisoned(&self.state, "service queue").peak_depth
    }

    /// Bytes of pre-encoded activation planes charged against the
    /// `BOOSTERS_PREENCODE_MB` budget for requests still in the queue
    /// (the stats surface's `pre_encode_resident_bytes`).
    pub(crate) fn pre_encode_bytes(&self) -> u64 {
        lock_or_poisoned(&self.state, "service queue").pre_encode_bytes
    }

    /// Stop the scheduler from forming batches (admission continues) —
    /// the drain-control / backpressure-test hook.
    pub(crate) fn set_paused(&self, paused: bool) {
        let mut st = lock_or_poisoned(&self.state, "service queue");
        st.paused = paused;
        drop(st);
        if !paused {
            self.work_cv.notify_all();
        }
    }

    fn admit_locked(
        &self,
        st: &mut QueueState,
        op: OwnedGemmOp,
        deadline: Option<Duration>,
        priority: Priority,
        group_key: Option<GroupKey>,
    ) -> Arc<TicketInner> {
        let ticket = TicketInner::new();
        let now = Instant::now();
        st.seq += 1;
        let macs = op.macs();
        st.pending.push(Pending {
            op,
            ticket: Arc::clone(&ticket),
            submitted_at: now,
            deadline_at: deadline.map(|d| now + d),
            priority,
            macs,
            encode_claimed: false,
            queued: Arc::new(AtomicBool::new(true)),
            pre_encode_charged: 0,
            group_key,
            seq: st.seq,
        });
        st.peak_depth = st.peak_depth.max(st.pending.len());
        // Two consumers wait on work_cv (the batch scheduler and the
        // pre-encode stage); wake both so neither can be starved by a
        // wakeup landing on the other.
        self.work_cv.notify_all();
        ticket
    }

    /// Block until admitted requests the pre-encode stage has not yet
    /// claimed exist, mark up to `max` of them claimed **in EDF order**
    /// (the same comparator [`SubmitQueue::pop_batch`] uses, so the
    /// encoder warms exactly the requests the scheduler will pop
    /// first), and return clones of their ops (cheap: `Arc` operands
    /// sharing the encoded slot).
    ///
    /// Claims are bounded by `budget_bytes` of estimated pre-encoded
    /// activation bytes: each claim charges its op's deterministic
    /// plane-size estimate against the queue's resident total, and the
    /// charge is released when the request pops into a batch. Over
    /// budget the encoder **stalls** (waits for pops to release bytes)
    /// — it never drops work; an unclaimed request is simply encoded
    /// inline by the execution stage. One oversized op still claims
    /// when nothing is resident (the progress guarantee mirroring the
    /// MAC budget), so a budget below any single op degrades to
    /// one-at-a-time pre-encoding instead of deadlock.
    ///
    /// Runs through pauses — pre-encoding while batch formation is
    /// paused is exactly the pipelining this stage exists for. Returns
    /// `None` on shutdown: whatever is still unclaimed will be encoded
    /// inline by the drain.
    pub(crate) fn claim_encode_work(
        &self,
        max: usize,
        budget_bytes: u64,
    ) -> Option<Vec<EncodeClaim>> {
        let mut st = lock_or_poisoned(&self.state, "service queue");
        loop {
            if st.shutdown {
                return None;
            }
            let mut order: Vec<usize> = (0..st.pending.len())
                .filter(|&i| !st.pending[i].encode_claimed)
                .collect();
            order.sort_by_key(|&i| st.pending[i].edf_key());
            let mut claims = Vec::new();
            for &i in &order {
                if claims.len() >= max.max(1) {
                    break;
                }
                let est = st.pending[i].op.pre_encode_estimate_bytes();
                let over = st.pre_encode_bytes.saturating_add(est) > budget_bytes;
                if over && !(st.pre_encode_bytes == 0 && claims.is_empty()) {
                    break;
                }
                let p = &mut st.pending[i];
                p.encode_claimed = true;
                p.pre_encode_charged = est;
                claims.push(EncodeClaim {
                    op: p.op.clone(),
                    queued: Arc::clone(&p.queued),
                });
                st.pre_encode_bytes = st.pre_encode_bytes.saturating_add(est);
            }
            if !claims.is_empty() {
                return Some(claims);
            }
            st = wait_or_poisoned(&self.work_cv, st, "service queue");
        }
    }

    /// Weight fingerprint for group-aware batch selection, computed
    /// **before** taking the state lock (hashing a large weight under
    /// the lock would serialize every submitter). The digest is cached
    /// in the op's shared slot, so resubmitted clones pay it once.
    fn group_key_for(&self, req: &GemmRequest) -> Option<GroupKey> {
        (self.group_min_ops > 0).then(|| req.op.group_key())
    }

    /// Non-blocking admission (the `submit` contract).
    pub(crate) fn push(&self, req: GemmRequest) -> Result<Arc<TicketInner>, AdmissionError> {
        let group_key = self.group_key_for(&req);
        let mut st = lock_or_poisoned(&self.state, "service queue");
        if st.shutdown {
            return Err(AdmissionError::ShuttingDown);
        }
        if st.pending.len() >= self.capacity {
            return Err(AdmissionError::QueueFull {
                capacity: self.capacity,
            });
        }
        Ok(self.admit_locked(&mut st, req.op, req.deadline, req.priority, group_key))
    }

    /// Blocking admission for the synchronous facades: waits for space
    /// instead of returning `QueueFull`.
    pub(crate) fn push_blocking(
        &self,
        req: GemmRequest,
    ) -> Result<Arc<TicketInner>, AdmissionError> {
        let group_key = self.group_key_for(&req);
        let mut st = lock_or_poisoned(&self.state, "service queue");
        loop {
            if st.shutdown {
                return Err(AdmissionError::ShuttingDown);
            }
            if st.pending.len() < self.capacity {
                return Ok(self.admit_locked(&mut st, req.op, req.deadline, req.priority, group_key));
            }
            st = wait_or_poisoned(&self.space_cv, st, "service queue");
        }
    }

    /// Block until work is available (or shutdown) and carve one
    /// execution batch: EDF order, cut at a cumulative MAC budget
    /// (always at least one request) and `max_ops` requests. With
    /// `adaptive` on, the budget is computed **after** waking, under
    /// the same lock that forms the batch — from the depth and
    /// deadline pressure of exactly the requests being cut — so a
    /// burst arriving while the scheduler was parked on an empty queue
    /// is batched under its own load, never a stale idle-time sample.
    /// Returns the batch plus the effective budget applied (for the
    /// stats surface); `None` only when the queue is shut down **and**
    /// fully drained, so no admitted ticket is ever abandoned.
    pub(crate) fn pop_batch(
        &self,
        base_macs: usize,
        max_ops: usize,
        adaptive: bool,
    ) -> Option<(Vec<Pending>, usize)> {
        let mut st = lock_or_poisoned(&self.state, "service queue");
        loop {
            let runnable = !st.pending.is_empty() && (!st.paused || st.shutdown);
            if runnable {
                break;
            }
            if st.shutdown && st.pending.is_empty() {
                return None;
            }
            st = wait_or_poisoned(&self.work_cv, st, "service queue");
        }
        let mut order: Vec<usize> = (0..st.pending.len()).collect();
        order.sort_by_key(|&i| st.pending[i].edf_key());
        let max_macs = if adaptive {
            // Deadline pressure keys on the **EDF head** — the request
            // guaranteed to lead the batch being formed — so a cut
            // batch always contains the due request it exists to help.
            // A due request buried behind a higher priority class must
            // not quarter service-wide throughput: no cut can ever
            // bring it forward past EDF order.
            let head_due = st.pending[order[0]]
                .deadline_at
                .map(|d| d <= Instant::now())
                .unwrap_or(false);
            super::service::adaptive_batch_macs(
                base_macs,
                st.pending.len(),
                self.capacity,
                head_due,
            )
        } else {
            base_macs
        };
        let mut rank = vec![usize::MAX; st.pending.len()];
        let mut budget = 0usize;
        let mut taken = 0usize;
        for &i in &order {
            if taken >= max_ops.max(1) {
                break;
            }
            let macs = st.pending[i].macs;
            if taken > 0 && budget.saturating_add(macs) > max_macs {
                break;
            }
            budget = budget.saturating_add(macs);
            rank[i] = taken;
            taken += 1;
        }
        // ---- group-aware fill ---------------------------------------
        // A budget-cut batch leaves MAC headroom behind ops too big to
        // fit. Spend it on ops that share a weight with something
        // already taken: they ride the weight-stationary grouped path
        // for free, and every same-weight op pulled forward is one
        // fewer re-stream of the same encoded planes in a later batch.
        // EDF is bent, never broken: only ops of the **highest priority
        // class still waiting** are eligible (a Bulk op can never jump
        // a waiting Interactive one), and the MAC budget still binds.
        if self.group_min_ops > 0 && taken < max_ops.max(1) {
            let mut keys: Vec<GroupKey> = Vec::new();
            for (i, r) in rank.iter().enumerate() {
                if *r == usize::MAX {
                    continue;
                }
                if let Some(k) = st.pending[i].group_key {
                    if !keys.contains(&k) {
                        keys.push(k);
                    }
                }
            }
            let limit = order
                .iter()
                .filter(|&&i| rank[i] == usize::MAX)
                .map(|&i| st.pending[i].priority)
                .min();
            if let (false, Some(limit)) = (keys.is_empty(), limit) {
                for &i in &order {
                    if taken >= max_ops.max(1) {
                        break;
                    }
                    if rank[i] != usize::MAX {
                        continue;
                    }
                    let p = &st.pending[i];
                    if p.priority != limit {
                        continue;
                    }
                    let Some(k) = p.group_key else { continue };
                    if !keys.contains(&k) {
                        continue;
                    }
                    if budget.saturating_add(p.macs) > max_macs {
                        continue;
                    }
                    budget = budget.saturating_add(p.macs);
                    rank[i] = taken;
                    taken += 1;
                }
            }
        }
        let mut batch: Vec<Option<Pending>> = (0..taken).map(|_| None).collect();
        let mut rest = Vec::with_capacity(st.pending.len() - taken);
        let mut released = 0u64;
        for (i, p) in std::mem::take(&mut st.pending).into_iter().enumerate() {
            match rank[i] {
                usize::MAX => rest.push(p),
                r => {
                    // Invalidate outstanding encode claims: this
                    // request's batch is about to execute, so a late
                    // pre-encode could only duplicate the execution
                    // stage's inline encode.
                    p.queued.store(false, Ordering::Release);
                    released = released.saturating_add(p.pre_encode_charged);
                    batch[r] = Some(p);
                }
            }
        }
        st.pending = rest;
        st.pre_encode_bytes = st.pre_encode_bytes.saturating_sub(released);
        drop(st);
        self.space_cv.notify_all();
        if released > 0 {
            // A budget-stalled pre-encode stage waits on work_cv; the
            // bytes this pop released are its wakeup.
            self.work_cv.notify_all();
        }
        Some((
            batch.into_iter().map(|p| p.expect("rank fully assigned")).collect(),
            max_macs,
        ))
    }

    /// Begin shutdown: new admissions fail, the scheduler drains what
    /// is already admitted (ignoring pause) and then stops.
    pub(crate) fn shutdown(&self) {
        let mut st = lock_or_poisoned(&self.state, "service queue");
        st.shutdown = true;
        drop(st);
        self.work_cv.notify_all();
        self.space_cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::BlockFormat;
    use std::sync::Arc;

    fn op(m: usize, k: usize, n: usize) -> OwnedGemmOp {
        let x = Arc::new(Mat::zeros(m, k));
        let w = Arc::new(Mat::zeros(k, n));
        OwnedGemmOp::new(x, w, BlockFormat::new(4, 16).unwrap()).unwrap()
    }

    fn req(m: usize) -> GemmRequest {
        GemmRequest::new(op(m, 16, 2))
    }

    #[test]
    fn bounded_push_reports_queue_full() {
        let q = SubmitQueue::new(2, 0);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        match q.push(req(3)) {
            Err(AdmissionError::QueueFull { capacity: 2 }) => {}
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert_eq!(q.depth(), 2);
        assert_eq!(q.peak_depth(), 2);
    }

    #[test]
    fn pop_batch_is_edf_within_priority_under_mac_budget() {
        let q = SubmitQueue::new(16, 0);
        // Bulk with the earliest deadline, then interactive requests
        // with deadlines out of submission order, then one with none.
        q.push(req(1).with_priority(Priority::Bulk).with_deadline(Duration::from_millis(1)))
            .unwrap();
        q.push(
            req(2)
                .with_priority(Priority::Interactive)
                .with_deadline(Duration::from_millis(500)),
        )
        .unwrap();
        q.push(
            req(3)
                .with_priority(Priority::Interactive)
                .with_deadline(Duration::from_millis(100)),
        )
        .unwrap();
        q.push(req(4).with_priority(Priority::Interactive)).unwrap();
        let (batch, eff) = q.pop_batch(usize::MAX, 16, false).unwrap();
        assert_eq!(eff, usize::MAX, "non-adaptive pop applies the base budget");
        let rows: Vec<usize> = batch.iter().map(|p| p.op.x.rows).collect();
        // Interactive first (EDF: 3 before 2, no-deadline 4 last), the
        // bulk request last despite holding the earliest deadline.
        assert_eq!(rows, vec![3, 2, 4, 1]);
        assert_eq!(q.depth(), 0);
    }

    #[test]
    fn mac_budget_cuts_batches_but_never_starves() {
        let q = SubmitQueue::new(16, 0);
        for m in [8usize, 8, 8] {
            q.push(req(m)).unwrap();
        }
        // Each op is 8 * 2 * 16 = 256 MACs; a 300-MAC budget takes one.
        let (b1, eff1) = q.pop_batch(300, 16, false).unwrap();
        assert_eq!(eff1, 300);
        assert_eq!(b1.len(), 1);
        // A budget smaller than any single op still takes one (progress
        // guarantee), never zero.
        let (b2, _) = q.pop_batch(1, 16, false).unwrap();
        assert_eq!(b2.len(), 1);
        let (b3, _) = q.pop_batch(usize::MAX, 16, false).unwrap();
        assert_eq!(b3.len(), 1);
        assert_eq!(q.depth(), 0);
    }

    /// Request over a weight whose content is `fill` everywhere —
    /// distinct fills give distinct group keys, equal fills share one.
    fn wreq(m: usize, fill: f32) -> GemmRequest {
        let x = Arc::new(Mat::zeros(m, 16));
        let w = Arc::new(Mat::new(16, 2, vec![fill; 32]).unwrap());
        GemmRequest::new(OwnedGemmOp::new(x, w, BlockFormat::new(4, 16).unwrap()).unwrap())
    }

    #[test]
    fn group_aware_pop_pulls_same_weight_ops_into_budget_headroom() {
        // A (32 MACs, weight W1), B (256 MACs, W2), C (32 MACs, W1).
        // A 100-MAC budget cuts after A; the group-aware fill pulls C
        // (same weight, fits the headroom) past B, which waits.
        let q = SubmitQueue::new(16, 2);
        q.push(wreq(1, 1.0)).unwrap();
        q.push(wreq(8, 2.0)).unwrap();
        q.push(wreq(1, 1.0)).unwrap();
        let (batch, _) = q.pop_batch(100, 16, false).unwrap();
        assert_eq!(batch.len(), 2, "same-weight op fills the headroom");
        assert!(batch.iter().all(|p| p.op.x.rows == 1));
        assert_eq!(q.depth(), 1, "the big foreign-weight op waits");
        // The fill still honors the MAC budget: nothing else fits.
        let (rest, _) = q.pop_batch(usize::MAX, 16, false).unwrap();
        assert_eq!(rest[0].op.x.rows, 8);

        // Grouping disabled: the identical scenario takes only A.
        let q0 = SubmitQueue::new(16, 0);
        q0.push(wreq(1, 1.0)).unwrap();
        q0.push(wreq(8, 2.0)).unwrap();
        q0.push(wreq(1, 1.0)).unwrap();
        let (batch0, _) = q0.pop_batch(100, 16, false).unwrap();
        assert_eq!(batch0.len(), 1);
        assert_eq!(q0.depth(), 2);
    }

    #[test]
    fn group_aware_pop_never_jumps_a_higher_priority_class() {
        // Taken: one Interactive op over W1. Waiting: an Interactive op
        // over W2 (too big for the budget) and a Bulk op over W1 that
        // would fit. The Bulk op must NOT be pulled past the waiting
        // Interactive class, same weight or not.
        let q = SubmitQueue::new(16, 2);
        q.push(wreq(8, 1.0).with_priority(Priority::Interactive))
            .unwrap();
        q.push(wreq(8, 2.0).with_priority(Priority::Interactive))
            .unwrap();
        q.push(wreq(1, 1.0).with_priority(Priority::Bulk)).unwrap();
        let (batch, _) = q.pop_batch(280, 16, false).unwrap();
        assert_eq!(batch.len(), 1, "no pull past a waiting higher class");
        assert_eq!(batch[0].op.x.rows, 8);
        assert_eq!(q.depth(), 2);
        // Within one class the pull is allowed: drain the second
        // Interactive op, then Bulk comes out alone.
        let (b2, _) = q.pop_batch(usize::MAX, 16, false).unwrap();
        assert_eq!(b2.len(), 2);
    }

    #[test]
    fn adaptive_pop_cuts_only_when_the_edf_head_is_due() {
        let q = SubmitQueue::new(8, 0);
        let base = 1 << 20;
        // No deadlines pending: the budget scales with depth, no cut.
        q.push(req(1)).unwrap();
        let (_, eff) = q.pop_batch(base, 16, true).unwrap();
        assert!(eff >= base, "{eff}");
        // An already-expired deadline at the EDF head cuts to base/4,
        // and the cut batch leads with exactly that request.
        q.push(req(2).with_deadline(Duration::ZERO)).unwrap();
        q.push(req(3)).unwrap();
        let (batch, eff) = q.pop_batch(base, 16, true).unwrap();
        assert_eq!(eff, base / 4);
        assert_eq!(batch[0].op.x.rows, 2, "due request leads the cut batch");
    }

    #[test]
    fn claim_encode_work_marks_each_request_once() {
        let q = SubmitQueue::new(8, 0);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        q.push(req(3)).unwrap();
        let first = q.claim_encode_work(2, u64::MAX).unwrap();
        assert_eq!(first.len(), 2, "claim honors its batch cap");
        assert!(first.iter().all(EncodeClaim::still_queued));
        let second = q.claim_encode_work(8, u64::MAX).unwrap();
        assert_eq!(second.len(), 1, "already-claimed requests stay claimed");
        // Everything is claimed: the next call would block, and
        // shutdown must unblock it with None instead.
        q.shutdown();
        assert!(q.claim_encode_work(8, u64::MAX).is_none());
        // Claiming is advisory — claimed requests still pop into
        // batches for execution...
        assert_eq!(q.pop_batch(usize::MAX, 16, false).unwrap().0.len(), 3);
        // ...and popping invalidates every outstanding claim, so the
        // encode stage never duplicates an executing batch's work.
        assert!(first.iter().all(|c| !c.still_queued()));
        assert!(second.iter().all(|c| !c.still_queued()));
    }

    #[test]
    fn claim_encode_work_hands_out_edf_order() {
        let q = SubmitQueue::new(8, 0);
        // Admission order 1, 2, 3 — EDF order 3, 2, 1 (interactive
        // deadlines before the bulk request).
        q.push(req(1).with_priority(Priority::Bulk)).unwrap();
        q.push(
            req(2)
                .with_priority(Priority::Interactive)
                .with_deadline(Duration::from_millis(500)),
        )
        .unwrap();
        q.push(
            req(3)
                .with_priority(Priority::Interactive)
                .with_deadline(Duration::from_millis(100)),
        )
        .unwrap();
        let claims = q.claim_encode_work(8, u64::MAX).unwrap();
        let rows: Vec<usize> = claims.iter().map(|c| c.op.x.rows).collect();
        // Same comparator as pop_batch: the encoder warms exactly the
        // requests the scheduler will pop first, not admission order.
        assert_eq!(rows, vec![3, 2, 1]);
        // A capped claim also takes the EDF head of what remains.
        let q2 = SubmitQueue::new(8, 0);
        q2.push(req(4).with_priority(Priority::Bulk)).unwrap();
        q2.push(req(5).with_deadline(Duration::from_millis(1)).with_priority(Priority::Bulk))
            .unwrap();
        let head = q2.claim_encode_work(1, u64::MAX).unwrap();
        assert_eq!(head[0].op.x.rows, 5, "capped claim takes the EDF head");
    }

    #[test]
    fn pre_encode_budget_stalls_claims_and_pops_release_bytes() {
        let q = SubmitQueue::new(8, 0);
        q.push(req(1)).unwrap();
        q.push(req(2)).unwrap();
        let est = op(1, 16, 2).pre_encode_estimate_bytes();
        assert!(est > 0, "estimate must charge something");
        // A budget of exactly one op's bytes claims one of the two
        // requests (the second would overflow the budget).
        let c1 = q.claim_encode_work(8, est).unwrap();
        assert_eq!(c1.len(), 1, "budget cuts the claim batch");
        assert_eq!(q.pre_encode_bytes(), est);
        // Popping the charged request releases its bytes — stalls end
        // via pops, never via drops.
        let (b, _) = q.pop_batch(usize::MAX, 1, false).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(q.pre_encode_bytes(), 0);
        // req(2) alone over-runs the budget (twice the rows), but with
        // nothing resident the progress guarantee still claims it.
        let c2 = q.claim_encode_work(8, est).unwrap();
        assert_eq!(c2.len(), 1, "one oversized op claims when idle");
        assert_eq!(q.pre_encode_bytes(), 2 * est);
        let _ = q.pop_batch(usize::MAX, 16, false).unwrap();
        assert_eq!(q.pre_encode_bytes(), 0, "drain releases every charge");
    }

    #[test]
    fn admission_error_wire_mapping_roundtrips() {
        let variants = [
            AdmissionError::QueueFull { capacity: 128 },
            AdmissionError::ShuttingDown,
            AdmissionError::InvalidShape {
                reason: "inner dims 8 vs 9 do not contract".into(),
            },
        ];
        for e in variants {
            let back = AdmissionError::from_wire(e.wire_code(), &e.wire_detail()).unwrap();
            assert_eq!(back, e);
        }
        // Codes are frozen: renumbering would desynchronize mixed-version
        // fleets silently, so pin them.
        assert_eq!(AdmissionError::QueueFull { capacity: 0 }.wire_code(), 1);
        assert_eq!(AdmissionError::ShuttingDown.wire_code(), 2);
        assert_eq!(
            AdmissionError::InvalidShape { reason: String::new() }.wire_code(),
            3
        );
        // Unknown codes surface as None, never a guessed variant.
        assert!(AdmissionError::from_wire(99, "x").is_none());
    }

    #[test]
    fn shutdown_drains_then_stops() {
        let q = SubmitQueue::new(4, 0);
        q.push(req(1)).unwrap();
        q.shutdown();
        assert!(matches!(q.push(req(2)), Err(AdmissionError::ShuttingDown)));
        // Already-admitted work still comes out...
        assert_eq!(q.pop_batch(usize::MAX, 16, false).unwrap().0.len(), 1);
        // ...then the queue reports done instead of blocking.
        assert!(q.pop_batch(usize::MAX, 16, false).is_none());
    }

    #[test]
    fn ticket_take_once_semantics() {
        let inner = TicketInner::new();
        let t = Ticket::from_inner(Arc::clone(&inner));
        assert!(!t.poll());
        assert!(t.wait_deadline(Duration::from_millis(1)).is_none());
        inner.fulfill(Ok(GemmResponse {
            out: Mat::zeros(1, 1),
            queue_ms: 0.1,
            total_ms: 0.2,
            deadline_missed: false,
            encode_ms: 0.0,
            gemm_ms: 0.0,
            decode_ms: 0.0,
        }));
        assert!(t.poll());
        let resp = t.wait().unwrap();
        assert_eq!((resp.out.rows, resp.out.cols), (1, 1));
        assert!(!resp.deadline_missed);
        // Second take reports the result as gone (still "ready").
        assert!(t.poll());
        assert!(t.wait().is_err());
        assert!(t.wait_deadline(Duration::from_millis(1)).unwrap().is_err());
    }

    fn arena_backed_response(arena: &Arc<super::super::arena::BufferArena>) -> (GemmResponse, u64) {
        let mut out = Mat::zeros(4, 4);
        out.data = arena.take_f32(16);
        let bytes = (out.data.capacity() * std::mem::size_of::<f32>()) as u64;
        (
            GemmResponse {
                out,
                queue_ms: 0.0,
                total_ms: 0.0,
                deadline_missed: false,
                encode_ms: 0.0,
                gemm_ms: 0.0,
                decode_ms: 0.0,
            },
            bytes,
        )
    }

    #[test]
    fn taken_tickets_release_arena_accounting() {
        let arena = Arc::new(super::super::arena::BufferArena::new(1 << 20));
        let inner = TicketInner::new();
        let t = Ticket::from_inner(Arc::clone(&inner));
        let (resp, bytes) = arena_backed_response(&arena);
        assert_eq!(arena.stats().resident_bytes, bytes);
        inner.fulfill_recycling(Ok(resp), Some((Arc::clone(&arena), bytes)));
        let resp = t.wait().unwrap();
        // The buffer now belongs to the caller: residency is released
        // without the storage ever returning to the free list.
        assert_eq!(arena.stats().resident_bytes, 0);
        drop(resp);
        drop(t);
        drop(inner);
        assert_eq!(arena.stats().hits, 0);
        assert_eq!(arena.stats().resident_bytes, 0);
    }

    #[test]
    fn dropped_unconsumed_tickets_recycle_arena_outputs() {
        let arena = Arc::new(super::super::arena::BufferArena::new(1 << 20));
        let inner = TicketInner::new();
        let t = Ticket::from_inner(Arc::clone(&inner));
        let (resp, bytes) = arena_backed_response(&arena);
        inner.fulfill_recycling(Ok(resp), Some((Arc::clone(&arena), bytes)));
        // Abandon the result without taking it: the output buffer must
        // return to the arena free list, not leak to the allocator.
        drop(t);
        drop(inner);
        let st = arena.stats();
        assert_eq!(st.resident_bytes, bytes);
        assert_eq!(st.hits, 0);
        // Recycled checkout is a hit and comes back zeroed.
        let again = arena.take_f32(16);
        assert!(again.iter().all(|&v| v == 0.0));
        assert_eq!(arena.stats().hits, 1);
    }
}
