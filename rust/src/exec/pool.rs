//! Persistent worker pool — the thread substrate of the execution
//! runtime.
//!
//! PR 1's GEMM spawned a fresh `std::thread::scope` per call, paying
//! thread creation and teardown on every multiply. The pool here is
//! spawned **once** (per [`WorkerPool`]; the process-wide instance lives
//! in [`crate::exec::global`]) and serves band-level work items from a
//! shared FIFO queue for the rest of the process lifetime.
//!
//! # Scoped execution over a persistent pool
//!
//! [`WorkerPool::scope_run`] accepts jobs that **borrow** from the
//! caller's stack (operand planes, output bands) even though the worker
//! threads are long-lived. The lifetime is erased with one audited
//! `transmute` and re-established by construction: `scope_run` does not
//! return until every submitted job has retired, so no borrow can
//! outlive the frame that owns it — the same contract
//! `std::thread::scope` enforces, amortized over persistent threads.
//!
//! While waiting, the submitting thread **helps drain the queue**
//! instead of sleeping. This keeps the pool deadlock-free under nested
//! or concurrent `scope_run` calls (some thread always makes progress)
//! and puts the caller's core to work instead of parking it.
//!
//! # Determinism
//!
//! The pool schedules *which thread* runs a job, never *what* the job
//! computes: callers partition work into disjoint output regions and
//! each region is produced by exactly one job. Results are therefore
//! bit-identical regardless of worker count, queue order, or whether
//! the caller ran inline — the property the GEMM stack's tests pin.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

/// Lock a runtime mutex, turning a poisoned lock (a worker panicked
/// while holding it) into a panic that **names the owning subsystem**
/// instead of the opaque `PoisonError` backtrace a bare
/// `lock().unwrap()` produces. The original panic has already been
/// reported on its own thread; this message ties the cascade back to
/// it.
pub(crate) fn lock_or_poisoned<'a, T>(m: &'a Mutex<T>, subsystem: &str) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|_| {
        panic!("exec {subsystem}: mutex poisoned by a panicked worker (see panic above)")
    })
}

/// [`Condvar::wait`] with the same named-subsystem poison diagnostics as
/// [`lock_or_poisoned`].
pub(crate) fn wait_or_poisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    subsystem: &str,
) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|_| {
        panic!("exec {subsystem}: mutex poisoned by a panicked worker (see panic above)")
    })
}

/// [`Condvar::wait_timeout`] variant of [`wait_or_poisoned`]. Returns
/// the reacquired guard; callers re-check their predicate and their own
/// deadline, so the `WaitTimeoutResult` is not propagated.
pub(crate) fn wait_timeout_or_poisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    timeout: Duration,
    subsystem: &str,
) -> MutexGuard<'a, T> {
    cv.wait_timeout(guard, timeout)
        .unwrap_or_else(|_| {
            panic!(
                "exec {subsystem}: mutex poisoned by a panicked worker (see the original panic above)"
            )
        })
        .0
}

/// A unit of work. Jobs may borrow from the submitting frame ('env);
/// [`WorkerPool::scope_run`] guarantees they retire before it returns.
pub type Job<'env> = Box<dyn FnOnce() + Send + 'env>;

struct Shared {
    queue: Mutex<VecDeque<Job<'static>>>,
    work_cv: Condvar,
    shutdown: AtomicBool,
}

/// Per-`scope_run` completion state: outstanding job count + a flag
/// recording whether any job panicked (re-raised at the caller).
struct ScopeState {
    remaining: Mutex<usize>,
    done: Condvar,
    panicked: AtomicBool,
}

/// A fixed-size persistent worker pool (see module docs).
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers. `threads <= 1` spawns no OS
    /// threads at all: every `scope_run` executes inline on the caller,
    /// which is the strict-serial mode `BOOSTERS_GEMM_THREADS=1` asks
    /// for.
    pub fn with_threads(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            work_cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let mut handles = Vec::new();
        if threads > 1 {
            for i in 0..threads {
                let sh = Arc::clone(&shared);
                let h = std::thread::Builder::new()
                    .name(format!("bfp-exec-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn exec worker");
                handles.push(h);
            }
        }
        Self {
            shared,
            handles,
            threads,
        }
    }

    /// Configured parallelism (1 means strictly inline/serial).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `jobs` to completion, blocking the caller until every job has
    /// retired. Jobs may borrow from the caller's frame; disjointness of
    /// any mutable borrows is the caller's responsibility (hand each job
    /// its own `chunks_mut` region). If any job panics, the panic is
    /// re-raised here after the whole scope has drained, and the pool
    /// remains usable.
    pub fn scope_run<'env>(&self, jobs: Vec<Job<'env>>) {
        if jobs.is_empty() {
            return;
        }
        if self.handles.is_empty() || jobs.len() == 1 {
            for job in jobs {
                job();
            }
            return;
        }
        let state = Arc::new(ScopeState {
            remaining: Mutex::new(jobs.len()),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        {
            let mut q = lock_or_poisoned(&self.shared.queue, "pool job queue");
            for job in jobs {
                // SAFETY: `scope_run` blocks below until `remaining`
                // reaches zero, so every borrow captured by `job` is
                // live for the whole execution window; the 'static view
                // never escapes it (jobs are consumed exactly once).
                let job: Job<'static> =
                    unsafe { std::mem::transmute::<Job<'env>, Job<'static>>(job) };
                let st = Arc::clone(&state);
                q.push_back(Box::new(move || {
                    if catch_unwind(AssertUnwindSafe(job)).is_err() {
                        st.panicked.store(true, Ordering::Release);
                    }
                    let mut left = lock_or_poisoned(&st.remaining, "pool scope counter");
                    *left -= 1;
                    if *left == 0 {
                        st.done.notify_all();
                    }
                }));
            }
            self.shared.work_cv.notify_all();
        }
        // Help drain the queue while this scope is outstanding (the jobs
        // popped here may belong to other concurrent scopes — running
        // them is what keeps nested waits deadlock-free). Stop helping
        // the moment our own jobs have all retired, so a small scope is
        // never held hostage by a large concurrent one.
        loop {
            if *lock_or_poisoned(&state.remaining, "pool scope counter") == 0 {
                break;
            }
            let job = lock_or_poisoned(&self.shared.queue, "pool job queue").pop_front();
            match job {
                Some(job) => job(),
                None => break,
            }
        }
        let mut left = lock_or_poisoned(&state.remaining, "pool scope counter");
        while *left > 0 {
            left = wait_or_poisoned(&state.done, left, "pool scope counter");
        }
        drop(left);
        if state.panicked.load(Ordering::Acquire) {
            panic!("exec worker pool: a parallel job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Release);
        self.shared.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = lock_or_poisoned(&shared.queue, "pool job queue");
            loop {
                if let Some(job) = q.pop_front() {
                    break Some(job);
                }
                if shared.shutdown.load(Ordering::Acquire) {
                    break None;
                }
                q = wait_or_poisoned(&shared.work_cv, q, "pool job queue");
            }
        };
        match job {
            Some(job) => job(),
            None => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn scope_run_fills_disjoint_regions() {
        let pool = WorkerPool::with_threads(4);
        let mut out = vec![0usize; 64];
        let jobs: Vec<Job> = out
            .chunks_mut(5)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = i * 100 + j + 1;
                    }
                }) as Job
            })
            .collect();
        pool.scope_run(jobs);
        for (idx, &v) in out.iter().enumerate() {
            assert_eq!(v, (idx / 5) * 100 + idx % 5 + 1, "element {idx}");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = WorkerPool::with_threads(1);
        assert_eq!(pool.threads(), 1);
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Job> = (0..7)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Job
            })
            .collect();
        pool.scope_run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn panicking_job_propagates_and_pool_survives() {
        let pool = WorkerPool::with_threads(2);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.scope_run(vec![
                Box::new(|| panic!("boom")) as Job,
                Box::new(|| {}) as Job,
            ]);
        }));
        assert!(caught.is_err(), "scope_run must re-raise job panics");
        // The pool keeps serving scopes after a panic.
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Job> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Job
            })
            .collect();
        pool.scope_run(jobs);
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn poisoned_lock_panic_names_the_subsystem() {
        let m = Mutex::new(0usize);
        // Poison it.
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _guard = m.lock().unwrap();
            panic!("worker died");
        }));
        assert!(m.is_poisoned());
        let err = catch_unwind(AssertUnwindSafe(|| {
            let _ = lock_or_poisoned(&m, "pool test fixture");
        }))
        .expect_err("poisoned lock must still panic");
        let msg = err
            .downcast_ref::<String>()
            .map(String::as_str)
            .or_else(|| err.downcast_ref::<&str>().copied())
            .unwrap_or("");
        assert!(
            msg.contains("pool test fixture"),
            "diagnosable message must name the subsystem: {msg:?}"
        );
    }

    #[test]
    fn many_scopes_reuse_the_same_workers() {
        let pool = WorkerPool::with_threads(3);
        let counter = AtomicUsize::new(0);
        for _ in 0..20 {
            let jobs: Vec<Job> = (0..6)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Job
                })
                .collect();
            pool.scope_run(jobs);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 120);
    }
}
