//! Energy model companion to the area model.
//!
//! §2 discusses HBFP's *power* footprint alongside silicon area; the
//! paper's headline is density, but the Broader-Impact section argues the
//! energy case. We extend the Appendix-F counting style to switching
//! energy: each gate's dynamic energy is proportional to its area times
//! an activity factor, so a unit's relative energy per operation follows
//! its gate count weighted per component class (multipliers toggle ~every
//! cycle; converters only on operand load; the accumulator always).
//!
//! Outputs feed the `repro density` narrative and `bench_area_model`;
//! absolute joules are out of scope (no technology node), ratios are the
//! claim — mirroring how the paper treats its own model.

use super::dot_unit::{bf16_dot_unit, fp32_dot_unit, hbfp_dot_unit, DotUnitArea};

/// Activity factors per component class (fraction of cycles toggling).
#[derive(Debug, Clone, Copy)]
pub struct Activity {
    pub multipliers: f64,
    pub adder_tree: f64,
    pub accumulator: f64,
    pub activation: f64,
    pub exponent_logic: f64,
    pub converters: f64,
}

impl Default for Activity {
    fn default() -> Self {
        Self {
            multipliers: 1.0,
            adder_tree: 1.0,
            accumulator: 1.0,
            // Activation fires once per dot product, not per MAC.
            activation: 0.1,
            exponent_logic: 0.2,
            // Converters toggle on operand load; weights are reused.
            converters: 0.5,
        }
    }
}

/// Relative dynamic energy per dot-product operation (arbitrary units:
/// gate-count x activity).
pub fn unit_energy(u: &DotUnitArea, act: Activity) -> f64 {
    u.multipliers as f64 * act.multipliers
        + u.adder_tree as f64 * act.adder_tree
        + u.accumulator as f64 * act.accumulator
        + u.activation as f64 * act.activation
        + u.exponent_logic as f64 * act.exponent_logic
        + u.converters as f64 * act.converters
}

/// Energy-efficiency gain of HBFP(m) at block b over FP32 (ops/J ratio).
pub fn energy_gain_hbfp(m: u64, b: u64) -> f64 {
    let act = Activity::default();
    unit_energy(&fp32_dot_unit(b), act) / unit_energy(&hbfp_dot_unit(m, b), act)
}

pub fn energy_gain_bf16(n: u64) -> f64 {
    let act = Activity::default();
    unit_energy(&fp32_dot_unit(n), act) / unit_energy(&bf16_dot_unit(n), act)
}

/// Whole-training-run energy ratio for a mixed schedule: the Booster runs
/// `frac_low` of ops at HBFP(low) and the rest at HBFP(high).
pub fn schedule_energy_gain(low: u64, high: u64, b: u64, frac_low: f64) -> f64 {
    let per_low = 1.0 / energy_gain_hbfp(low, b);
    let per_high = 1.0 / energy_gain_hbfp(high, b);
    1.0 / (frac_low * per_low + (1.0 - frac_low) * per_high)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn energy_tracks_area_direction() {
        // Energy gains order the formats the same way area gains do.
        assert!(energy_gain_hbfp(4, 64) > energy_gain_hbfp(6, 64));
        assert!(energy_gain_hbfp(6, 64) > energy_gain_hbfp(8, 64));
        assert!(energy_gain_hbfp(4, 64) > energy_gain_bf16(64));
    }

    #[test]
    fn energy_gain_exceeds_area_gain_when_converters_idle() {
        // Converters toggle less than MACs, so the energy ratio is at
        // least as favourable as the area ratio for HBFP.
        let area = super::super::density::area_gain_hbfp(4, 64);
        let energy = energy_gain_hbfp(4, 64);
        assert!(energy > 0.9 * area, "energy {energy} vs area {area}");
    }

    #[test]
    fn booster_schedule_energy_is_nearly_hbfp4() {
        let full4 = energy_gain_hbfp(4, 64);
        let mix = schedule_energy_gain(4, 6, 64, 0.997);
        assert!(mix / full4 > 0.98, "{mix} vs {full4}");
        // And pure-high is strictly worse than the mix.
        assert!(mix > energy_gain_hbfp(6, 64));
    }

    #[test]
    fn custom_activity_profile() {
        let idle_conv = Activity {
            converters: 0.0,
            ..Default::default()
        };
        let busy_conv = Activity {
            converters: 1.0,
            ..Default::default()
        };
        let u = hbfp_dot_unit(4, 64);
        assert!(unit_energy(&u, idle_conv) < unit_energy(&u, busy_conv));
    }
}
