//! Whole dot-product-plus-activation units — §4's fixed operation.
//!
//! FP32 unit of size N:   N FP32 multipliers + (N-1) FP32 adders (tree)
//!                        + 1 FP32 accumulator adder + 1 FP activation.
//! HBFP unit of size N:   N m-bit fixed multipliers + (N-1) fixed adders
//!                        (tree, widths growing from 2m) + 1 signed
//!                        10-bit exponent adder + 1 FP32 accumulator
//!                        + 1 FP activation + FP32<->BFP converters.

use super::converter::{bfp_to_fp32_converter, dot_unit_converters};
use super::fp::{fp_activation_unit, fp_adder, fp_multiplier, FpFormat, FP32};
use super::units::*;

/// Area breakdown of one dot-product unit (gate counts).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DotUnitArea {
    pub multipliers: u64,
    pub adder_tree: u64,
    pub accumulator: u64,
    pub activation: u64,
    pub exponent_logic: u64,
    pub converters: u64,
}

impl DotUnitArea {
    pub fn total(&self) -> u64 {
        self.multipliers
            + self.adder_tree
            + self.accumulator
            + self.activation
            + self.exponent_logic
            + self.converters
    }
}

/// Floating-point dot-product unit of size `n` for format `f`.
pub fn fp_dot_unit(n: u64, f: FpFormat) -> DotUnitArea {
    DotUnitArea {
        multipliers: n * fp_multiplier(f),
        // (n-1) FP adders arranged as a tree; FP adder width is fixed.
        adder_tree: (n.saturating_sub(1)) * fp_adder(f),
        accumulator: fp_adder(FP32), // FP32 accumulation in all designs
        activation: fp_activation_unit(FP32),
        exponent_logic: 0,
        converters: 0,
    }
}

pub fn fp32_dot_unit(n: u64) -> DotUnitArea {
    fp_dot_unit(n, FP32)
}

pub fn bf16_dot_unit(n: u64) -> DotUnitArea {
    fp_dot_unit(n, super::fp::BF16)
}

/// HBFP dot-product unit: `n`-wide, `m`-bit mantissas (block size == n:
/// one shared exponent per operand vector, as in the paper's §4 model).
pub fn hbfp_dot_unit(m: u64, n: u64) -> DotUnitArea {
    // log2-width growth in the integer accumulation tree.
    let acc_bits = 2 * m + 64 - n.leading_zeros() as u64;
    DotUnitArea {
        multipliers: n * signed_multiplier(m),
        adder_tree: adder_tree(n, 2 * m),
        accumulator: fp_adder(FP32),
        activation: fp_activation_unit(FP32),
        // One signed exponent adder per block pair (10-bit).
        exponent_logic: ripple_adder(10),
        converters: dot_unit_converters(n, m) + bfp_to_fp32_converter(acc_bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_unit_dominated_by_macs() {
        let u = fp32_dot_unit(64);
        assert!(u.multipliers + u.adder_tree > 9 * (u.accumulator + u.activation));
    }

    #[test]
    fn hbfp_unit_converter_amortizes() {
        // Converter fraction shrinks only mildly with n (it's per-element),
        // but fixed overheads (accumulator/activation) amortize strongly.
        let small = hbfp_dot_unit(4, 16);
        let big = hbfp_dot_unit(4, 576);
        let fixed_frac_small =
            (small.accumulator + small.activation) as f64 / small.total() as f64;
        let fixed_frac_big = (big.accumulator + big.activation) as f64 / big.total() as f64;
        assert!(fixed_frac_big < fixed_frac_small / 10.0);
    }

    #[test]
    fn mantissa_scaling() {
        // HBFP8 -> HBFP4 should shrink the multiplier area superlinearly.
        let h8 = hbfp_dot_unit(8, 64);
        let h4 = hbfp_dot_unit(4, 64);
        assert!(h8.multipliers as f64 / h4.multipliers as f64 > 3.0);
        assert!(h8.total() > h4.total());
    }

    #[test]
    fn exponent_bits_are_amortized() {
        // §2 footnote: even at b=4, 5-bit vs 10-bit shared exponent moves
        // total area by ~<10% (we model the 10-bit path only; here we just
        // check exponent logic is a tiny fraction at any block size).
        for n in [4u64, 16, 64] {
            let u = hbfp_dot_unit(4, n);
            assert!((u.exponent_logic as f64) < 0.03 * u.total() as f64);
        }
    }
}
