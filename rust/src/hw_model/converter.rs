//! FP32 <-> BFP converter blocks (Appendix F, last paragraph).
//!
//! Converting a block of N FP32 values to BFP needs:
//!   * N-1 exponent comparators (max-exponent tree),
//!   * N exponent subtractors (distance to the shared exponent),
//!   * N shifters (mantissa alignment), and
//!   * XORshift circuits generating random bits for stochastic rounding.
//!
//! Datapath widths follow what the conversion actually touches: exponent
//! compare is over the 8-bit FP32 exponent field; the per-element
//! exponent delta saturates at m+1 (any larger shift underflows to 0), so
//! the delta subtractor and the alignment shifter run at narrow widths
//! (m+2-bit mantissa datapath, shift range m+1). A full 24-bit barrel
//! shifter per element would dominate the whole HBFP4 MAC and contradicts
//! the paper's 21.3x headline — see EXPERIMENTS.md §HW-model for the
//! calibration discussion.
//!
//! In the weight-stationary dot-product array the *weight* operand is
//! converted once per tile and reused across the systolic pass, so only
//! one streaming converter bank (activations) plus the shared
//! max-exponent tree sits on the per-unit area path; the amortized weight
//! converter is priced at the tile-load rate (1/ROWS of a bank).

use super::gates::MUX2;
use super::units::*;

const FP32_EXP: u64 = 8;

/// Rows a weight tile is reused across in the systolic array (the
/// amortization factor for the weight-side converter bank).
pub const WEIGHT_REUSE_ROWS: u64 = 64;

/// Exponent-compare cost in the max tree: lean 8-bit comparator + steer
/// mux on the 8-bit exponent word.
fn exp_compare() -> u64 {
    comparator_lean(FP32_EXP) + FP32_EXP * MUX2
}

/// Per-element conversion datapath for an m-bit mantissa target:
/// saturating exponent-delta subtract + narrow alignment shift + round.
fn per_element(m: u64) -> u64 {
    let delta_bits = 64 - (m + 1).leading_zeros() as u64 + 1; // log2(m+1)+1
    subtractor(delta_bits.max(4))          // saturating exponent delta
        + barrel_shifter(m + 2, m + 1)     // align at the m+2-bit datapath
        + ripple_adder(m)                  // round increment
        + m * MUX2                         // stochastic-bit injection mux
}

/// Converter bank turning one block of `n` FP32 values into BFP with
/// `m`-bit mantissas (shared 10-bit exponent): streamed activations.
pub fn fp32_to_bfp_converter_bank(n: u64, m: u64) -> u64 {
    let max_exp = (n - 1) * exp_compare();
    max_exp + n * per_element(m) + xorshift32()
}

/// Both operand banks of a dot unit: one streamed (activations) + one
/// amortized across WEIGHT_REUSE_ROWS systolic rows (weights).
pub fn dot_unit_converters(n: u64, m: u64) -> u64 {
    let bank = fp32_to_bfp_converter_bank(n, m);
    bank + bank.div_ceil(WEIGHT_REUSE_ROWS)
}

/// BFP dot-product result -> FP32 normalization (one per unit output).
pub fn bfp_to_fp32_converter(acc_bits: u64) -> u64 {
    leading_zero_counter(acc_bits) + barrel_shifter(acc_bits, acc_bits) + ripple_adder(10)
}

/// Word-level output mux (used when bit-slicing HBFP6 onto HBFP4 lanes —
/// §4.2's mixed-mantissa execution); priced for completeness.
pub fn bitslice_steering(m: u64, lanes: u64) -> u64 {
    lanes * m * MUX2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converter_scales_linearly_in_block() {
        let c16 = fp32_to_bfp_converter_bank(16, 4);
        let c64 = fp32_to_bfp_converter_bank(64, 4);
        let c256 = fp32_to_bfp_converter_bank(256, 4);
        let r1 = (c64 - c16) as f64 / (64.0 - 16.0);
        let r2 = (c256 - c64) as f64 / (256.0 - 64.0);
        assert!((r1 - r2).abs() / r1 < 0.05, "{r1} vs {r2}");
    }

    #[test]
    fn converter_much_cheaper_than_fp32_mac() {
        // The whole point of BFP: conversion logic per element must be far
        // below an FP32 multiply-add.
        use super::super::fp::{fp_adder, fp_multiplier, FP32};
        let conv = per_element(4);
        let mac = fp_multiplier(FP32) + fp_adder(FP32);
        assert!(conv * 20 < mac, "conv {conv} vs mac {mac}");
    }

    #[test]
    fn weight_bank_amortized() {
        let both = dot_unit_converters(64, 4);
        let one = fp32_to_bfp_converter_bank(64, 4);
        assert!(both < one + one / 32);
        assert!(both > one);
    }

    #[test]
    fn converter_mantissa_dependence_is_mild() {
        let a = fp32_to_bfp_converter_bank(64, 4);
        let b = fp32_to_bfp_converter_bank(64, 8);
        assert!((b as f64 - a as f64) / (a as f64) < 0.8);
    }
}
