//! Arithmetic-density gains: Fig 6 series, Table-1 area-gain column,
//! and the §4.2 headline ratios (HBFP4 vs FP32, vs BFloat16).
//!
//! With the §4 operation fixed (dot product of size N + activation),
//! density gain == area ratio. Our gate model is the paper's counting
//! scheme rebuilt from Appendix F; EXPERIMENTS.md compares the resulting
//! ratios against the paper's own table values row by row.

use super::dot_unit::{bf16_dot_unit, fp32_dot_unit, hbfp_dot_unit};

/// Density/area gain of HBFP(m) with block size b over FP32 (same N = b).
pub fn area_gain_hbfp(m: u64, b: u64) -> f64 {
    fp32_dot_unit(b).total() as f64 / hbfp_dot_unit(m, b).total() as f64
}

/// Gain of BFloat16 over FP32 (block-size independent; both pure-FP).
pub fn bf16_gain(n: u64) -> f64 {
    fp32_dot_unit(n).total() as f64 / bf16_dot_unit(n).total() as f64
}

/// Gain of HBFP(m1) over HBFP(m2) at block size b.
pub fn area_gain_vs(m1: u64, m2: u64, b: u64) -> f64 {
    hbfp_dot_unit(m2, b).total() as f64 / hbfp_dot_unit(m1, b).total() as f64
}

/// Accuracy Boosters run 99.7% of ops at HBFP4 with HBFP6 bit-sliced onto
/// the same 4-bit lanes at unchanged throughput (§4.2) — so the deployed
/// density is HBFP4's, derated by the small HBFP6 fraction executed at
/// half rate (two 4-bit slices per 6-bit op, conservatively).
pub fn booster_density(b: u64, hbfp6_frac: f64) -> f64 {
    let d4 = area_gain_hbfp(4, b);
    // HBFP6 ops occupy 2 lane-cycles; throughput-weighted density:
    let slowdown = 1.0 / (1.0 - hbfp6_frac + 2.0 * hbfp6_frac);
    d4 * slowdown
}

/// One row of the Fig 6 sweep.
#[derive(Debug, Clone, Copy)]
pub struct Fig6Row {
    pub block: u64,
    pub hbfp8: f64,
    pub hbfp6: f64,
    pub hbfp5: f64,
    pub hbfp4: f64,
}

/// Silicon-area ratio FP32/HBFP across a block-size sweep (Fig 6).
pub fn fig6_series(blocks: &[u64]) -> Vec<Fig6Row> {
    blocks
        .iter()
        .map(|&b| Fig6Row {
            block: b,
            hbfp8: area_gain_hbfp(8, b),
            hbfp6: area_gain_hbfp(6, b),
            hbfp5: area_gain_hbfp(5, b),
            hbfp4: area_gain_hbfp(4, b),
        })
        .collect()
}

/// The paper's block-size axis.
pub const PAPER_BLOCKS: [u64; 7] = [16, 25, 36, 49, 64, 256, 576];

/// Paper Table-1 area-gain column for cross-checking (format, block, gain).
pub const PAPER_TABLE1_GAINS: [(u64, u64, f64); 22] = [
    (8, 576, 10.0),
    (6, 16, 11.2),
    (6, 25, 12.3),
    (6, 36, 13.1),
    (6, 49, 13.6),
    (6, 64, 13.9),
    (6, 256, 14.8),
    (6, 576, 15.0),
    (5, 16, 13.4),
    (5, 25, 15.0),
    (5, 36, 16.2),
    (5, 49, 16.9),
    (5, 64, 17.5),
    (5, 256, 18.9),
    (5, 576, 19.2),
    (4, 16, 15.5),
    (4, 25, 17.8),
    (4, 36, 19.3),
    (4, 49, 20.4),
    (4, 64, 21.3),
    (4, 256, 23.4),
    (4, 576, 23.9),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotone_in_block_size() {
        // Fig 6: gains rise with block size and saturate.
        for m in [4u64, 5, 6, 8] {
            let mut prev = 0.0;
            for b in PAPER_BLOCKS {
                let g = area_gain_hbfp(m, b);
                assert!(g > prev, "m={m} b={b}: {g} <= {prev}");
                prev = g;
            }
            // Saturation: the 256 -> 576 step is small.
            let d = area_gain_hbfp(m, 576) / area_gain_hbfp(m, 256);
            assert!(d < 1.10, "m={m}: {d}");
        }
    }

    #[test]
    fn monotone_in_mantissa() {
        for b in PAPER_BLOCKS {
            assert!(area_gain_hbfp(4, b) > area_gain_hbfp(5, b));
            assert!(area_gain_hbfp(5, b) > area_gain_hbfp(6, b));
            assert!(area_gain_hbfp(6, b) > area_gain_hbfp(8, b));
        }
    }

    #[test]
    fn headline_ratios_in_band() {
        // §4.2: HBFP4 up to 21.3x vs FP32 at b=64 (23.9x at 576),
        // BF16 4.9x, HBFP4-vs-BF16 4.4x. Our rebuilt gate model must land
        // in the same regime (±35% band; exact constants are the authors').
        let g64 = area_gain_hbfp(4, 64);
        assert!(g64 > 13.8 && g64 < 28.8, "hbfp4@64 {g64}");
        let bf = bf16_gain(64);
        assert!(bf > 3.2 && bf < 6.7, "bf16 {bf}");
        let vs_bf = g64 / bf;
        assert!(vs_bf > 2.8 && vs_bf < 6.0, "hbfp4 vs bf16 {vs_bf}");
    }

    #[test]
    fn paper_table_shape_tracks_model() {
        // Relative *shape*: each paper gain normalized by the paper's
        // HBFP6@64 value should match our model's same normalization
        // within 30% — the sweep's structure is reproduced even if the
        // absolute calibration differs.
        let ours_ref = area_gain_hbfp(6, 64);
        let paper_ref = 13.9;
        for &(m, b, paper) in PAPER_TABLE1_GAINS.iter() {
            let ours = area_gain_hbfp(m, b) / ours_ref;
            let want = paper / paper_ref;
            let rel = (ours - want).abs() / want;
            assert!(rel < 0.30, "m={m} b={b}: ours {ours:.2} vs paper {want:.2}");
        }
    }

    #[test]
    fn hbfp4_vs_hbfp8_matches_infeasible_example() {
        // §3: "HBFP4 with a block size of 576 ... a 2.4x improvement in
        // area/power relative to HBFP8" — check the same ballpark.
        let r = area_gain_vs(4, 8, 576);
        assert!(r > 1.6 && r < 3.2, "{r}");
    }

    #[test]
    fn booster_density_near_hbfp4() {
        let d = booster_density(64, 0.003);
        let d4 = area_gain_hbfp(4, 64);
        assert!((d / d4 - 1.0).abs() < 0.01, "{d} vs {d4}");
    }

    #[test]
    fn block64_reaches_90pct_of_max() {
        // §4.2: "a block size of 64 is within 90% of the maximum area
        // gain"; our model should agree for HBFP4.
        let g64 = area_gain_hbfp(4, 64);
        let gmax = area_gain_hbfp(4, 576);
        assert!(g64 / gmax > 0.85, "{}", g64 / gmax);
    }
}
