//! Analytic gate-level silicon-area model (paper Appendix F).
//!
//! Area = number of basic gates (AND/OR/NOT = 1 each), composed
//! hierarchically: XOR = 5, half-adder = 6, full-adder = 13, and upward
//! through ripple adders, array multipliers, barrel shifters, FP units,
//! FP32<->BFP converter banks and whole dot-product-plus-activation units.
//!
//! The headline quantity is **arithmetic density** ((ops/s)/area). With the
//! operation fixed to "dot product of size N followed by an activation"
//! (§4), density gain over FP32 equals the *area ratio* of the two units —
//! regenerating Fig 6 and the area-gain columns of Table 1, plus the
//! 21.3x-vs-FP32 / 4.4x-vs-BFloat16 claims of §4.2.

pub mod converter;
pub mod density;
pub mod energy;
pub mod dot_unit;
pub mod fp;
pub mod gates;
pub mod units;

pub use converter::{bfp_to_fp32_converter, fp32_to_bfp_converter_bank};
pub use density::{area_gain_hbfp, area_gain_vs, bf16_gain, booster_density, fig6_series, Fig6Row};
pub use energy::{energy_gain_bf16, energy_gain_hbfp, schedule_energy_gain, unit_energy, Activity};
pub use dot_unit::{bf16_dot_unit, fp32_dot_unit, hbfp_dot_unit, DotUnitArea};
pub use fp::{fp_adder, fp_multiplier, FpFormat, BF16, FP32};
