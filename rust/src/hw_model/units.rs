//! Composite integer/combinational units built from the Appendix-F gates.

use super::gates::*;

/// n-bit ripple-carry adder: (n-1) full adders + 1 half adder.
pub fn ripple_adder(n: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    (n - 1) * FULL_ADDER + HALF_ADDER
}

/// n-bit subtractor: invert one operand (n NOT) + adder with carry-in
/// (n full adders).
pub fn subtractor(n: u64) -> u64 {
    n * NOT + n * FULL_ADDER
}

/// n-bit magnitude comparator, modeled as a subtractor (borrow chain).
pub fn comparator(n: u64) -> u64 {
    subtractor(n)
}

/// Optimized magnitude comparator (gate-minimized greater-than cell per
/// bit + OR chain, ~6 gates/bit): what a synthesized max-exponent tree
/// actually uses — the compare result is a single bit, not a difference.
pub fn comparator_lean(n: u64) -> u64 {
    6 * n
}

/// Unsigned n x m array multiplier: n*m partial-product AND gates plus
/// (n-1) rows of m full adders.
pub fn array_multiplier(n: u64, m: u64) -> u64 {
    if n == 0 || m == 0 {
        return 0;
    }
    n * m * AND + (n - 1) * m * FULL_ADDER
}

/// Signed (two's-complement, Baugh-Wooley) n x n multiplier: the array
/// plus one extra adder row for the sign-correction terms.
pub fn signed_multiplier(n: u64) -> u64 {
    array_multiplier(n, n) + ripple_adder(n)
}

/// Logarithmic barrel shifter for an n-bit word over up to `max_shift`
/// positions: ceil(log2(max_shift+1)) stages of n 2:1 muxes.
pub fn barrel_shifter(n: u64, max_shift: u64) -> u64 {
    let stages = 64 - max_shift.leading_zeros() as u64; // ceil(log2(s+1))
    stages * n * MUX2
}

/// Leading-zero counter over n bits (normalization): ~n muxes + n OR.
pub fn leading_zero_counter(n: u64) -> u64 {
    n * MUX2 + n * OR
}

/// Comparator *tree* finding the max of `n` values of `bits` bits:
/// (n-1) comparators + (n-1) word-muxes to steer the winner.
pub fn max_tree(n: u64, bits: u64) -> u64 {
    if n <= 1 {
        return 0;
    }
    (n - 1) * (comparator(bits) + bits * MUX2)
}

/// Adder tree summing n terms whose width grows by one bit per level:
/// level l (0-based, ceil(log2 n) levels) has n/2^(l+1) adders of
/// (w + l) bits.
pub fn adder_tree(n: u64, w: u64) -> u64 {
    let mut area = 0;
    let mut terms = n;
    let mut level = 0u64;
    while terms > 1 {
        let pairs = terms / 2;
        area += pairs * ripple_adder(w + level);
        terms = terms - pairs; // odd term forwarded
        level += 1;
    }
    area
}

/// 32-bit XORshift PRNG for stochastic rounding: 3 shift-XOR stages
/// (32 XOR each) + a 32-bit state register.
pub fn xorshift32() -> u64 {
    3 * 32 * XOR + 32 * DFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adder_scaling() {
        assert_eq!(ripple_adder(1), HALF_ADDER);
        assert_eq!(ripple_adder(8), 7 * 13 + 6);
        assert!(ripple_adder(32) > ripple_adder(8));
    }

    #[test]
    fn multiplier_quadratic() {
        // Halving the width should shrink the multiplier superlinearly —
        // the "arithmetic logic improves quadratically" claim of §1.
        let m24 = array_multiplier(24, 24);
        let m12 = array_multiplier(12, 12);
        let m6 = array_multiplier(6, 6);
        assert!(m24 as f64 / m12 as f64 > 3.5);
        assert!(m12 as f64 / m6 as f64 > 3.5);
    }

    #[test]
    fn mantissa_width_dominates_fixed_mac() {
        // 4-bit vs 8-bit fixed multiplier: ~4x smaller (quadratic).
        let r = signed_multiplier(8) as f64 / signed_multiplier(4) as f64;
        assert!(r > 3.0 && r < 5.0, "{r}");
    }

    #[test]
    fn barrel_shifter_log_stages() {
        assert_eq!(barrel_shifter(24, 1), 24 * MUX2);
        assert_eq!(barrel_shifter(24, 3), 2 * 24 * MUX2);
        assert_eq!(barrel_shifter(24, 24), 5 * 24 * MUX2);
    }

    #[test]
    fn adder_tree_counts() {
        // 4 terms of width 8: 2 adders @8 + 1 adder @9.
        assert_eq!(adder_tree(4, 8), 2 * ripple_adder(8) + ripple_adder(9));
        // Odd n forwards a term.
        assert!(adder_tree(5, 8) > adder_tree(4, 8));
    }

    #[test]
    fn max_tree_zero_for_single() {
        assert_eq!(max_tree(1, 8), 0);
        assert!(max_tree(64, 8) > max_tree(16, 8));
    }
}
