//! Basic gate costs (paper Appendix F counting rules).
//!
//! "The analytic model approximates the area of a circuit as the total
//! number of basic gates (AND, OR, NOT) present in the circuit. An XOR
//! gate is made up of 2 NOT, 2 AND and 1 OR, so its area is 5. A
//! half-adder (XOR + AND) has area 6; a full adder (2 HA + OR) 13."

/// Area of one basic gate.
pub const GATE: u64 = 1;
pub const NOT: u64 = GATE;
pub const AND: u64 = GATE;
pub const OR: u64 = GATE;

/// XOR = 2 NOT + 2 AND + 1 OR.
pub const XOR: u64 = 2 * NOT + 2 * AND + OR; // 5

/// Half adder = XOR + AND.
pub const HALF_ADDER: u64 = XOR + AND; // 6

/// Full adder = 2 half adders + OR.
pub const FULL_ADDER: u64 = 2 * HALF_ADDER + OR; // 13

/// 2:1 multiplexer: out = (a AND !s) OR (b AND s).
pub const MUX2: u64 = 2 * AND + OR + NOT; // 4

/// D flip-flop approximated as 6 NAND-equivalents (registers appear in
/// accumulators and the XORshift state).
pub const DFF: u64 = 6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appendix_f_examples() {
        assert_eq!(XOR, 5);
        assert_eq!(HALF_ADDER, 6);
        assert_eq!(FULL_ADDER, 13);
        assert_eq!(MUX2, 4);
    }
}
