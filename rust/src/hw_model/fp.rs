//! Floating-point unit area models (FP32 and BFloat16).
//!
//! An FP adder aligns significands (exponent subtract + barrel shift),
//! adds, renormalizes (LZC + shift) and rounds; an FP multiplier multiplies
//! significands (array), adds exponents and renormalizes/rounds. Widths
//! include the hidden bit.

use super::units::*;

/// An IEEE-like floating-point format (exponent / stored-mantissa bits).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FpFormat {
    pub exp_bits: u64,
    /// Stored mantissa bits (excluding the hidden bit).
    pub man_bits: u64,
}

impl FpFormat {
    /// Significand width including the hidden bit.
    pub fn sig(&self) -> u64 {
        self.man_bits + 1
    }
}

pub const FP32: FpFormat = FpFormat {
    exp_bits: 8,
    man_bits: 23,
};

/// BFloat16: FP32's exponent, half the total bits.
pub const BF16: FpFormat = FpFormat {
    exp_bits: 8,
    man_bits: 7,
};

/// Floating-point adder area.
pub fn fp_adder(f: FpFormat) -> u64 {
    let s = f.sig();
    let exp_diff = subtractor(f.exp_bits);
    let align = barrel_shifter(s, s); // shift smaller operand by up to s
    let mant_add = ripple_adder(s + 1); // +1 carry headroom
    let norm = leading_zero_counter(s + 1) + barrel_shifter(s + 1, s);
    let exp_adjust = ripple_adder(f.exp_bits);
    let round = ripple_adder(s); // increment-on-round
    exp_diff + align + mant_add + norm + exp_adjust + round
}

/// Floating-point multiplier area.
pub fn fp_multiplier(f: FpFormat) -> u64 {
    let s = f.sig();
    let mant_mul = array_multiplier(s, s);
    let exp_add = ripple_adder(f.exp_bits) + subtractor(f.exp_bits); // +bias removal
    let norm = barrel_shifter(2 * s, 1) + ripple_adder(f.exp_bits);
    let round = ripple_adder(s);
    mant_mul + exp_add + norm + round
}

/// The floating-point activation unit of §4's fixed operation
/// ("dot product followed by activation"): modeled as a comparator +
/// output mux over the FP32 word (a ReLU-class unit).
pub fn fp_activation_unit(f: FpFormat) -> u64 {
    let w = 1 + f.exp_bits + f.man_bits;
    comparator(w) + w * super::gates::MUX2
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_multiplier_dominates_adder() {
        // The 24x24 significand array dwarfs the adder datapath.
        assert!(fp_multiplier(FP32) > 3 * fp_adder(FP32));
    }

    #[test]
    fn bf16_much_smaller_than_fp32() {
        let r = fp_multiplier(FP32) as f64 / fp_multiplier(BF16) as f64;
        assert!(r > 5.0, "bf16 multiplier ratio {r}"); // ~(24/8)^2
    }

    #[test]
    fn formats() {
        assert_eq!(FP32.sig(), 24);
        assert_eq!(BF16.sig(), 8);
    }
}
