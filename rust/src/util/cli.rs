//! Tiny CLI argument parser (no external deps): subcommand + optional
//! verb (second positional, e.g. `repro registry push`) + `--key value`
//! / `--flag` options.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    /// Second positional — the sub-verb of compound subcommands
    /// (`repro registry push --dir D`). `None` for plain subcommands.
    pub verb: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    bail!("bare '--' not supported");
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.opts.insert(name.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok);
            } else if out.verb.is_none() {
                out.verb = Some(tok);
            } else {
                bail!("unexpected positional argument {tok:?}");
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_parse_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(key)?.unwrap_or(default))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("table1 --model cnn --preset full --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("table1"));
        assert_eq!(a.get("model"), Some("cnn"));
        assert_eq!(a.get_or("preset", "quick"), "full");
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn equals_form_and_parsing() {
        let a = parse("train --epochs=12 --seed 7");
        assert_eq!(a.get_parse::<usize>("epochs").unwrap(), Some(12));
        assert_eq!(a.get_parse_or::<u64>("seed", 0).unwrap(), 7);
        assert_eq!(a.get_parse_or::<u64>("missing", 3).unwrap(), 3);
        assert!(a.get_parse::<usize>("seed").is_ok());
    }

    #[test]
    fn verb_is_the_second_positional() {
        let a = parse("registry push --dir /tmp/reg --name epoch3");
        assert_eq!(a.subcommand.as_deref(), Some("registry"));
        assert_eq!(a.verb.as_deref(), Some("push"));
        assert_eq!(a.get("dir"), Some("/tmp/reg"));
        assert_eq!(a.get("name"), Some("epoch3"));
        assert!(parse("train --epochs 3").verb.is_none());
    }

    #[test]
    fn errors() {
        assert!(Args::parse(["x".into(), "y".into(), "z".into()]).is_err());
        assert!(parse("train").get_parse::<usize>("epochs").unwrap().is_none());
        let bad = Args::parse(["t".into(), "--epochs".into(), "abc".into()]).unwrap();
        assert!(bad.get_parse::<usize>("epochs").is_err());
    }
}
