//! Single home of the 128-bit content digest shared by the operand
//! cache and the fabric wire protocol.
//!
//! The digest started life inside `exec/cache.rs` as the content half
//! of [`crate::exec::CacheKey`]. The multi-node fabric turns it into a
//! **cross-process contract**: a router ships `(digest, shape, format)`
//! first and plane bytes only when the remote runner reports a miss, so
//! the router-side hash and the runner-side hash must agree
//! byte-for-byte forever. Hoisting the function here (and pinning known
//! values in `stability_pins_known_digests`) makes any drift a test
//! failure instead of a silent cross-node cache-poisoning bug.
//!
//! # Construction
//!
//! Two independent FNV-1a streams over the little-endian f32 bit
//! patterns, with the logical shape folded into the bases — so a
//! reshape of the same bytes cannot alias, and 128 bits of independent
//! state make accidental collisions across a process (or fleet)
//! lifetime negligible. The hash is deterministic across runs,
//! platforms, and endiannesses (`f32::to_bits` is value-, not
//! memory-order-, defined).

/// 128-bit content digest: `(h1, h2)` of the two FNV-1a streams.
///
/// The wire encoding is fixed: `h1` then `h2`, each little-endian —
/// see [`Digest::to_le_bytes`] / [`Digest::from_le_bytes`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Digest(pub u64, pub u64);

impl Digest {
    /// Serialized size on the wire (two little-endian u64s).
    pub const WIRE_BYTES: usize = 16;

    /// Fixed wire encoding: `h1` little-endian, then `h2`.
    pub fn to_le_bytes(self) -> [u8; Self::WIRE_BYTES] {
        let mut out = [0u8; Self::WIRE_BYTES];
        out[..8].copy_from_slice(&self.0.to_le_bytes());
        out[8..].copy_from_slice(&self.1.to_le_bytes());
        out
    }

    /// Inverse of [`Digest::to_le_bytes`].
    pub fn from_le_bytes(b: [u8; Self::WIRE_BYTES]) -> Self {
        let mut h1 = [0u8; 8];
        let mut h2 = [0u8; 8];
        h1.copy_from_slice(&b[..8]);
        h2.copy_from_slice(&b[8..]);
        Self(u64::from_le_bytes(h1), u64::from_le_bytes(h2))
    }

    /// 32-hex-char rendering (`h1` then `h2`), for logs and metrics.
    pub fn to_hex(self) -> String {
        format!("{:016x}{:016x}", self.0, self.1)
    }
}

impl std::fmt::Display for Digest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:016x}{:016x}", self.0, self.1)
    }
}

/// Two independent FNV-1a streams over the f32 bit patterns, with the
/// shape folded into the bases. Deterministic across runs and
/// platforms. **Frozen**: the operand cache keys by it in-process and
/// the fabric negotiates transfer dedup with it across processes, so
/// any change to this function invalidates every remote operand store
/// — `stability_pins_known_digests` below pins the exact values.
pub fn content_fingerprint(data: &[f32], rows: usize, cols: usize) -> Digest {
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h1: u64 = 0xcbf2_9ce4_8422_2325 ^ (rows as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
    let mut h2: u64 = 0x6c62_272e_07bb_0142 ^ (cols as u64).wrapping_mul(0xc2b2_ae3d_27d4_eb4f);
    for &x in data {
        let b = x.to_bits() as u64;
        h1 = (h1 ^ b).wrapping_mul(PRIME);
        h2 = (h2 ^ b.rotate_left(17)).wrapping_mul(PRIME);
    }
    Digest(h1, h2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stability_pins_known_digests() {
        // Cross-process contract: these exact values are what every
        // router and runner in a fleet computes for these inputs. If
        // this test fails, the hash changed — which silently partitions
        // mixed-version fleets and must be a deliberate, versioned
        // wire-format bump, never an incidental edit.
        assert_eq!(
            content_fingerprint(&[1.0, 2.0, 3.0, 4.0], 2, 2),
            Digest(0xfaaf_f61d_c4cc_177f, 0x22e7_c675_41bd_d39c)
        );
        // Empty input: the bases themselves (shape multiplier is 0).
        assert_eq!(
            content_fingerprint(&[], 0, 0),
            Digest(0xcbf2_9ce4_8422_2325, 0x6c62_272e_07bb_0142)
        );
        // A single zero still advances both streams.
        assert_eq!(
            content_fingerprint(&[0.0], 1, 1),
            Digest(0x27a3_eeb2_3259_be90, 0x7c42_f880_1e2a_b417)
        );
        // Sign and fraction bits feed through f32::to_bits.
        assert_eq!(
            content_fingerprint(&[-1.5, 0.25], 1, 2),
            Digest(0xb54b_18fd_813e_ceb0, 0xbc1b_410d_f024_a63c)
        );
    }

    #[test]
    fn digest_separates_content_and_shape() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [1.0f32, 2.0, 3.0, 5.0];
        assert_eq!(content_fingerprint(&a, 2, 2), content_fingerprint(&a, 2, 2));
        assert_ne!(content_fingerprint(&a, 2, 2), content_fingerprint(&b, 2, 2));
        // Shape is part of the identity: a reshape must not alias.
        assert_ne!(content_fingerprint(&a, 2, 2), content_fingerprint(&a, 1, 4));
        assert_ne!(content_fingerprint(&a, 2, 2), content_fingerprint(&a, 4, 1));
    }

    #[test]
    fn wire_bytes_roundtrip_and_hex() {
        let d = content_fingerprint(&[1.0, 2.0, 3.0, 4.0], 2, 2);
        assert_eq!(Digest::from_le_bytes(d.to_le_bytes()), d);
        let bytes = d.to_le_bytes();
        assert_eq!(bytes.len(), Digest::WIRE_BYTES);
        // Little-endian, h1 first: the first byte is h1's low byte.
        assert_eq!(bytes[0], (d.0 & 0xff) as u8);
        assert_eq!(bytes[8], (d.1 & 0xff) as u8);
        assert_eq!(d.to_hex(), "faaff61dc4cc177f22e7c67541bdd39c");
        assert_eq!(format!("{d}"), d.to_hex());
    }
}
