//! Minimal JSON substrate (parser + serializer).
//!
//! The image has no serde available offline, and this system needs JSON in
//! three places — artifact manifests, golden numeric vectors, checkpoint
//! headers — so we implement the subset of JSON they use (which is all of
//! JSON minus exotic number forms) from scratch. Object key order is
//! preserved; numbers are f64 (exact for every f32 and for u32 counters).

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---- constructors -----------------------------------------------------

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    pub fn str(v: impl Into<String>) -> Json {
        Json::Str(v.into())
    }

    pub fn from_map(map: &BTreeMap<String, String>) -> Json {
        Json::Obj(
            map.iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        )
    }

    // ---- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_i64(&self) -> Result<i64> {
        let n = self.as_f64()?;
        if n.fract() != 0.0 {
            bail!("expected integer, got {n}");
        }
        Ok(n as i64)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// usize vector helper (shapes).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?
            .iter()
            .map(|v| Ok(v.as_f64()? as f32))
            .collect()
    }

    // ---- parsing ----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- serialization ------------------------------------------------------

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if *n == 0.0 && n.is_sign_negative() {
                    out.push_str("-0.0");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    // 17 significant digits round-trips every f64.
                    let _ = write!(out, "{n:.17e}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos, other as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected ',' or ']' at byte {}, got {:?}", self.pos, other as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint {code}"))?,
                            );
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                b if b < 0x80 => out.push(b as char),
                _ => {
                    // Multi-byte UTF-8: re-decode from the byte stream.
                    let start = self.pos - 1;
                    let width = utf8_width(b);
                    let end = start + width;
                    let s = std::str::from_utf8(
                        self.bytes
                            .get(start..end)
                            .ok_or_else(|| anyhow!("truncated utf8"))?,
                    )?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if start == self.pos {
            bail!("expected value at byte {start}");
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

fn utf8_width(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.req("c").unwrap().as_str().unwrap(), "x");
        let arr = v.req("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert!(!arr[2].req("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_preserves_f32_exactness() {
        // Every f32 must survive render -> parse exactly.
        let vals: Vec<f32> = vec![0.1, -2.5e-7, 3.4e38, 1.0, -0.0, 2.0f32.powi(-130)];
        let j = Json::Arr(vals.iter().map(|&v| Json::Num(v as f64)).collect());
        let back = Json::parse(&j.render()).unwrap();
        let got = back.as_f32_vec().unwrap();
        for (a, b) in vals.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn roundtrip_u32() {
        let j = Json::arr([Json::num(0u32 as f64), Json::num(u32::MAX as f64)]);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.as_arr().unwrap()[1].as_i64().unwrap(), u32::MAX as i64);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "quote\" slash\\ nl\n tab\t unicode\u{00e9}\u{4e2d}";
        let j = Json::str(s);
        let back = Json::parse(&j.render()).unwrap();
        assert_eq!(back.as_str().unwrap(), s);
    }

    #[test]
    fn unicode_escape_parse() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap().as_str().unwrap(),
            "\u{00e9}"
        );
    }

    #[test]
    fn object_helpers() {
        let j = Json::obj(vec![("n", Json::num(5.0)), ("s", Json::str("x"))]);
        assert_eq!(j.req("n").unwrap().as_usize().unwrap(), 5);
        assert!(j.req("zzz").is_err());
        assert!(j.req("s").unwrap().as_usize().is_err());
    }

    #[test]
    fn parses_real_manifest_like_doc() {
        let doc = r#"{
          "variant": "mlp_bs64", "block": 64, "pallas": false,
          "params": [{"name": "w", "shape": [48, 96], "scale": 0.12}],
          "decode": null
        }"#;
        let v = Json::parse(doc).unwrap();
        assert!(v.req("decode").unwrap().is_null());
        assert_eq!(
            v.req("params").unwrap().as_arr().unwrap()[0]
                .req("shape")
                .unwrap()
                .as_usize_vec()
                .unwrap(),
            vec![48, 96]
        );
    }
}
