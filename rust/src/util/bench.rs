//! Micro-benchmark harness (criterion is unavailable offline, so we ship
//! our own): warmup, timed iterations, mean / p50 / p95 / throughput
//! reporting, plus a simple suite runner used by `cargo bench`
//! (`harness = false` benches call [`BenchSuite::run`] from `main`).
//!
//! Results can additionally be captured as JSON for check-in or CI
//! artifacts: pass `--json PATH` to the bench binary (`cargo bench
//! --bench bench_quantize -- --json BENCH_gemm.json`) or set
//! `REPRO_BENCH_JSON=PATH`; [`BenchSuite::finish`] then writes the
//! machine-readable suite next to the human-readable stdout report.

use crate::util::{Json, Stopwatch};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n / (self.mean_ns / 1e9))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.as_str())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("p50_ns", Json::Num(self.p50_ns)),
            ("p95_ns", Json::Num(self.p95_ns)),
            (
                "items",
                self.items.map(Json::Num).unwrap_or(Json::Null),
            ),
            (
                "items_per_s",
                self.throughput().map(Json::Num).unwrap_or(Json::Null),
            ),
        ])
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:44} {:>10.3} ms/iter  (p50 {:>8.3}, p95 {:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.iters
        );
        if let Some(t) = self.throughput() {
            s.push_str(&format!("  [{t:.3e} items/s]"));
        }
        s
    }
}

/// Time `f` adaptively: warm up, then run until `budget_ms` or `max_iters`.
pub fn bench_fn(name: &str, budget_ms: f64, items: Option<f64>, mut f: impl FnMut()) -> BenchResult {
    // Warmup: one call, plus more if it's fast.
    let sw = Stopwatch::start();
    f();
    let first_ms = sw.ms();
    let warmups = if first_ms < 1.0 { 5 } else { 1 };
    for _ in 1..warmups {
        f();
    }
    let target_iters = ((budget_ms / first_ms.max(1e-3)).ceil() as usize).clamp(3, 1000);
    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs() * 1e9);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: pick(0.5),
        p95_ns: pick(0.95),
        items,
    }
}

/// A named collection of benches with uniform reporting.
pub struct BenchSuite {
    pub title: String,
    pub budget_ms: f64,
    pub results: Vec<BenchResult>,
    /// Execution-environment descriptors embedded in the JSON artifact
    /// (kernel backend, thread budget, cache caps, ...) so uploaded
    /// `BENCH_*.json` trajectories are comparable across runs and
    /// runners. Seeded by [`BenchSuite::new`]; extend with
    /// [`BenchSuite::meta`].
    pub meta: Vec<(String, Json)>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // `REPRO_BENCH_BUDGET_MS` trims bench time in CI.
        let budget_ms = std::env::var("REPRO_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300.0);
        println!("### bench suite: {title}");
        let reg = crate::bfp::kernels::registry();
        let (cache_entries, cache_bytes) = crate::util::cache_budget();
        let meta = vec![
            ("kernel".to_string(), Json::str(reg.preferred().name())),
            (
                "kernel_choice".to_string(),
                Json::str(reg.choice().label()),
            ),
            (
                "thread_budget".to_string(),
                Json::Num(crate::util::gemm_thread_budget() as f64),
            ),
            (
                "cache_entries_cap".to_string(),
                Json::Num(cache_entries as f64),
            ),
            (
                "cache_mb_cap".to_string(),
                Json::Num((cache_bytes >> 20) as f64),
            ),
            // How many shape-dispatch entries the registry loaded from
            // the autotune artifact (0 = static dispatch only) — so a
            // BENCH trajectory records whether numbers ran tuned.
            (
                "autotune_entries".to_string(),
                Json::Num(reg.autotune().map(|t| t.len()).unwrap_or(0) as f64),
            ),
        ];
        Self {
            title: title.to_string(),
            budget_ms,
            results: Vec::new(),
            meta,
        }
    }

    /// Attach (or override) one metadata field on the JSON artifact.
    pub fn meta(&mut self, key: &str, value: Json) {
        if let Some(slot) = self.meta.iter_mut().find(|(k, _)| k == key) {
            slot.1 = value;
        } else {
            self.meta.push((key.to_string(), value));
        }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) {
        self.bench_items(name, None, f);
    }

    pub fn bench_items(&mut self, name: &str, items: Option<f64>, f: impl FnMut()) {
        let r = bench_fn(name, self.budget_ms, items, f);
        println!("{}", r.report());
        self.results.push(r);
    }

    /// Machine-readable form of the whole suite (self-describing: the
    /// `meta` object names the kernel backend, thread budget, and
    /// cache caps the numbers were measured under).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::str(self.title.as_str())),
            ("budget_ms", Json::Num(self.budget_ms)),
            (
                "meta",
                Json::Obj(self.meta.clone()),
            ),
            (
                "results",
                Json::arr(self.results.iter().map(BenchResult::to_json)),
            ),
        ])
    }

    /// Write the suite as JSON (parent directories created).
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut text = self.to_json().render();
        text.push('\n');
        std::fs::write(path, text)
    }

    /// Final summary line (keeps `cargo bench` output grep-friendly).
    /// Honors `--json PATH` / `REPRO_BENCH_JSON` (see module docs).
    pub fn finish(self) {
        if let Some(path) = json_sink() {
            match self.write_json(&path) {
                Ok(()) => println!("### wrote {}", path.display()),
                Err(e) => eprintln!("### bench json write failed ({}): {e}", path.display()),
            }
        }
        println!(
            "### {}: {} benches done",
            self.title,
            self.results.len()
        );
    }
}

/// `--json PATH` (or `--json=PATH`) from the bench binary's argv, else
/// `REPRO_BENCH_JSON`. Scanned manually: cargo prepends its own flags
/// to harness-false bench binaries.
fn json_sink() -> Option<PathBuf> {
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            return args.next().map(PathBuf::from);
        }
        if let Some(rest) = a.strip_prefix("--json=") {
            return Some(PathBuf::from(rest));
        }
    }
    std::env::var_os("REPRO_BENCH_JSON").map(PathBuf::from)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_fn("spin", 5.0, Some(1000.0), || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.iters >= 3);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report().contains("spin"));
    }

    #[test]
    fn suite_meta_is_self_describing() {
        let suite = BenchSuite::new("meta test");
        let j = suite.to_json();
        let meta = j.req("meta").unwrap();
        let kernel = meta.req("kernel").unwrap().as_str().unwrap().to_string();
        assert!(
            crate::bfp::registry().by_name(&kernel).is_some(),
            "meta kernel {kernel:?} must be a registered backend"
        );
        assert!(meta.req("thread_budget").unwrap().as_f64().unwrap() >= 1.0);
        assert!(meta.req("cache_entries_cap").unwrap().as_f64().unwrap() >= 1.0);
        assert!(meta.req("cache_mb_cap").unwrap().as_f64().unwrap() >= 1.0);
        assert!(meta.req("autotune_entries").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn json_roundtrips_the_suite() {
        let r = BenchResult {
            name: "gemm".into(),
            iters: 12,
            mean_ns: 1.5e6,
            p50_ns: 1.4e6,
            p95_ns: 2.0e6,
            items: Some(1024.0),
        };
        let mut suite = BenchSuite {
            title: "t".into(),
            budget_ms: 20.0,
            results: vec![r],
            meta: vec![("kernel".to_string(), Json::str("scalar-tiled"))],
        };
        suite.meta("thread_budget", Json::Num(4.0));
        suite.meta("kernel", Json::str("autovec")); // override, not append
        let back = Json::parse(&suite.to_json().render()).unwrap();
        assert_eq!(back.req("suite").unwrap().as_str().unwrap(), "t");
        let meta = back.req("meta").unwrap();
        assert_eq!(meta.req("kernel").unwrap().as_str().unwrap(), "autovec");
        assert_eq!(meta.req("thread_budget").unwrap().as_usize().unwrap(), 4);
        let results = back.req("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].req("name").unwrap().as_str().unwrap(), "gemm");
        assert_eq!(results[0].req("iters").unwrap().as_usize().unwrap(), 12);
        assert!(results[0].req("items_per_s").unwrap().as_f64().unwrap() > 0.0);
        // No-items results serialize throughput as null.
        let r2 = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_ns: 1.0,
            p50_ns: 1.0,
            p95_ns: 1.0,
            items: None,
        };
        assert!(r2.to_json().req("items_per_s").unwrap().is_null());
    }
}
