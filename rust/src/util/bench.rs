//! Micro-benchmark harness (criterion is unavailable offline, so we ship
//! our own): warmup, timed iterations, mean / p50 / p95 / throughput
//! reporting, plus a simple suite runner used by `cargo bench`
//! (`harness = false` benches call [`BenchSuite::run`] from `main`).

use crate::util::Stopwatch;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    /// Optional items-per-iteration for throughput reporting.
    pub items: Option<f64>,
}

impl BenchResult {
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }

    pub fn throughput(&self) -> Option<f64> {
        self.items.map(|n| n / (self.mean_ns / 1e9))
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "{:44} {:>10.3} ms/iter  (p50 {:>8.3}, p95 {:>8.3}, n={})",
            self.name,
            self.mean_ns / 1e6,
            self.p50_ns / 1e6,
            self.p95_ns / 1e6,
            self.iters
        );
        if let Some(t) = self.throughput() {
            s.push_str(&format!("  [{t:.3e} items/s]"));
        }
        s
    }
}

/// Time `f` adaptively: warm up, then run until `budget_ms` or `max_iters`.
pub fn bench_fn(name: &str, budget_ms: f64, items: Option<f64>, mut f: impl FnMut()) -> BenchResult {
    // Warmup: one call, plus more if it's fast.
    let sw = Stopwatch::start();
    f();
    let first_ms = sw.ms();
    let warmups = if first_ms < 1.0 { 5 } else { 1 };
    for _ in 1..warmups {
        f();
    }
    let target_iters = ((budget_ms / first_ms.max(1e-3)).ceil() as usize).clamp(3, 1000);
    let mut samples = Vec::with_capacity(target_iters);
    for _ in 0..target_iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.secs() * 1e9);
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean_ns: mean,
        p50_ns: pick(0.5),
        p95_ns: pick(0.95),
        items,
    }
}

/// A named collection of benches with uniform reporting.
pub struct BenchSuite {
    pub title: String,
    pub budget_ms: f64,
    pub results: Vec<BenchResult>,
}

impl BenchSuite {
    pub fn new(title: &str) -> Self {
        // `REPRO_BENCH_BUDGET_MS` trims bench time in CI.
        let budget_ms = std::env::var("REPRO_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(300.0);
        println!("### bench suite: {title}");
        Self {
            title: title.to_string(),
            budget_ms,
            results: Vec::new(),
        }
    }

    pub fn bench(&mut self, name: &str, f: impl FnMut()) {
        self.bench_items(name, None, f);
    }

    pub fn bench_items(&mut self, name: &str, items: Option<f64>, f: impl FnMut()) {
        let r = bench_fn(name, self.budget_ms, items, f);
        println!("{}", r.report());
        self.results.push(r);
    }

    /// Final summary line (keeps `cargo bench` output grep-friendly).
    pub fn finish(self) {
        println!(
            "### {}: {} benches done",
            self.title,
            self.results.len()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench_fn("spin", 5.0, Some(1000.0), || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            std::hint::black_box(acc);
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.p95_ns >= r.p50_ns);
        assert!(r.iters >= 3);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report().contains("spin"));
    }
}
