//! Small shared utilities: deterministic RNG, timers, math helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;

/// Wall-clock stopwatch for coarse phase timing in examples/CLI output.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
