//! Small shared utilities: deterministic RNG, timers, math helpers.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;

/// Wall-clock stopwatch for coarse phase timing in examples/CLI output.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Worker-thread budget for the fixed-point execution runtime: the
/// single home of the `BOOSTERS_GEMM_THREADS` override (any positive
/// integer) with `available_parallelism` as the fallback. Used to size
/// the persistent [`crate::exec`] worker pool and by the GEMM
/// dispatcher's serial-vs-parallel heuristic; hoisted here so the two
/// can never disagree.
pub fn gemm_thread_budget() -> usize {
    std::env::var("BOOSTERS_GEMM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_is_positive() {
        // Whatever the environment says, the budget is a usable count.
        assert!(gemm_thread_budget() >= 1);
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
