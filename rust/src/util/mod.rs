//! Small shared utilities: deterministic RNG, timers, math helpers.

pub mod bench;
pub mod cli;
pub mod digest;
pub mod json;
pub mod rng;

pub use cli::Args;
pub use digest::{content_fingerprint, Digest};
pub use json::Json;
pub use rng::Rng;

/// Wall-clock stopwatch for coarse phase timing in examples/CLI output.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Self(std::time::Instant::now())
    }
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Worker-thread budget for the fixed-point execution runtime: the
/// single home of the `BOOSTERS_GEMM_THREADS` override (any positive
/// integer) with `available_parallelism` as the fallback. Used to size
/// the persistent [`crate::exec`] worker pool and by the GEMM
/// dispatcher's serial-vs-parallel heuristic; hoisted here so the two
/// can never disagree.
pub fn gemm_thread_budget() -> usize {
    std::env::var("BOOSTERS_GEMM_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// GEMM micro-kernel selection — the parsed form of the
/// `BOOSTERS_KERNEL` override. `Auto` lets the kernel registry
/// ([`crate::bfp::kernels`]) pick the best runtime-detected backend;
/// the named variants force one (AVX2 falls back loudly when the host
/// cannot run it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KernelChoice {
    #[default]
    Auto,
    Scalar,
    Autovec,
    Avx2,
    Avx512,
    Neon,
}

impl KernelChoice {
    pub fn label(self) -> &'static str {
        match self {
            KernelChoice::Auto => "auto",
            KernelChoice::Scalar => "scalar",
            KernelChoice::Autovec => "autovec",
            KernelChoice::Avx2 => "avx2",
            KernelChoice::Avx512 => "avx512",
            KernelChoice::Neon => "neon",
        }
    }
}

/// Pure parsing core of [`kernel_override`]: case-insensitive match on
/// `{auto, scalar, autovec, avx2, avx512, neon}`. Returns the parsed
/// choice plus the rejected raw value (if any) so the env-reading
/// wrapper can warn — unknown values must fall back to `Auto`, never
/// panic.
pub fn parse_kernel_choice(raw: Option<&str>) -> (KernelChoice, Option<String>) {
    let Some(raw) = raw else {
        return (KernelChoice::Auto, None);
    };
    match raw.trim().to_ascii_lowercase().as_str() {
        "" | "auto" => (KernelChoice::Auto, None),
        "scalar" => (KernelChoice::Scalar, None),
        "autovec" => (KernelChoice::Autovec, None),
        "avx2" => (KernelChoice::Avx2, None),
        "avx512" => (KernelChoice::Avx512, None),
        "neon" => (KernelChoice::Neon, None),
        _ => (KernelChoice::Auto, Some(raw.to_string())),
    }
}

/// GEMM kernel override: the single home of the `BOOSTERS_KERNEL`
/// environment variable (`auto` / `scalar` / `autovec` / `avx2` /
/// `avx512` / `neon`), hoisted here next to [`gemm_thread_budget`] /
/// [`cache_budget`] so every dispatch site resolves it identically.
/// Unknown values warn (once) and fall back to `auto`.
pub fn kernel_override() -> KernelChoice {
    let (choice, rejected) = parse_kernel_choice(std::env::var("BOOSTERS_KERNEL").ok().as_deref());
    if let Some(raw) = rejected {
        static WARNED: std::sync::Once = std::sync::Once::new();
        WARNED.call_once(|| {
            eprintln!(
                "[boosters] BOOSTERS_KERNEL={raw:?} is not one of \
                 auto/scalar/autovec/avx2/avx512/neon; falling back to auto"
            );
        });
    }
    choice
}

/// Autotune-table path override: the single home of the
/// `BOOSTERS_AUTOTUNE` environment variable. `Some(path)` when set and
/// non-empty; the kernel registry then treats a missing or corrupt file
/// at that path as a (warn-once) fall back to static dispatch. When
/// unset, the registry probes the default artifact locations instead
/// (`artifacts/autotune.json` relative to the package root, or
/// `rust/artifacts/autotune.json` relative to the repo root).
pub fn autotune_path() -> Option<std::path::PathBuf> {
    std::env::var("BOOSTERS_AUTOTUNE")
        .ok()
        .map(|v| v.trim().to_string())
        .filter(|v| !v.is_empty())
        .map(std::path::PathBuf::from)
}

/// Default cap on resident pre-encoded activation planes queued ahead
/// of execution (bytes): 256 MiB.
pub const DEFAULT_PREENCODE_BYTES: u64 = 256 << 20;

/// Pre-encode memory budget (bytes) for the async exec service: the
/// single home of the `BOOSTERS_PREENCODE_MB` override (any positive
/// integer, in MiB). The background encoder stalls — never drops work —
/// while the resident bytes of pre-encoded-but-still-queued activation
/// planes sit at or above this cap.
pub fn preencode_budget() -> u64 {
    parse_preencode_budget(std::env::var("BOOSTERS_PREENCODE_MB").ok().as_deref())
}

/// Pure parsing core of [`preencode_budget`]: malformed, zero, or
/// missing values fall back to [`DEFAULT_PREENCODE_BYTES`].
pub fn parse_preencode_budget(mb: Option<&str>) -> u64 {
    mb.and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .map(|mb| mb << 20)
        .unwrap_or(DEFAULT_PREENCODE_BYTES)
}

/// Default operand-cache caps: entry count and approximate resident
/// plane bytes.
pub const DEFAULT_CACHE_ENTRIES: usize = 96;
pub const DEFAULT_CACHE_BYTES: usize = 128 << 20;

/// Operand-cache budget `(max_entries, max_bytes)` for the execution
/// runtime: the single home of the `BOOSTERS_CACHE_ENTRIES` /
/// `BOOSTERS_CACHE_MB` overrides (any positive integer; `_MB` is in
/// MiB), hoisted here next to [`gemm_thread_budget`] so every runtime
/// constructor resolves the environment the same way.
pub fn cache_budget() -> (usize, usize) {
    parse_cache_budget(
        std::env::var("BOOSTERS_CACHE_ENTRIES").ok().as_deref(),
        std::env::var("BOOSTERS_CACHE_MB").ok().as_deref(),
    )
}

/// The compiled-in defaults, for constructors that must not consult the
/// environment (private test runtimes stay reproducible regardless of
/// the ambient shell).
pub fn default_cache_budget() -> (usize, usize) {
    (DEFAULT_CACHE_ENTRIES, DEFAULT_CACHE_BYTES)
}

/// Pure parsing core of [`cache_budget`]: malformed, zero, or missing
/// values fall back to the defaults (unit-tested without touching the
/// process environment, which would race parallel tests).
pub fn parse_cache_budget(entries: Option<&str>, mb: Option<&str>) -> (usize, usize) {
    fn positive(v: Option<&str>) -> Option<usize> {
        v.and_then(|s| s.trim().parse::<usize>().ok()).filter(|&n| n >= 1)
    }
    (
        positive(entries).unwrap_or(DEFAULT_CACHE_ENTRIES),
        positive(mb).map(|mb| mb << 20).unwrap_or(DEFAULT_CACHE_BYTES),
    )
}

/// Default cap on buffer-arena residency (free-list plus checked-out
/// bytes): 512 MiB.
pub const DEFAULT_ARENA_BYTES: u64 = 512 << 20;

/// Buffer-arena residency budget (bytes) for the execution runtime:
/// the single home of the `BOOSTERS_ARENA_MB` override (any positive
/// integer, in MiB). Checkouts that would exceed the cap stall
/// (bounded) and evict free buffers before allocating; returns beyond
/// the cap are dropped instead of retained.
pub fn arena_budget() -> u64 {
    parse_arena_budget(std::env::var("BOOSTERS_ARENA_MB").ok().as_deref())
}

/// Pure parsing core of [`arena_budget`]: malformed, zero, or missing
/// values fall back to [`DEFAULT_ARENA_BYTES`].
pub fn parse_arena_budget(mb: Option<&str>) -> u64 {
    mb.and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .map(|mb| mb << 20)
        .unwrap_or(DEFAULT_ARENA_BYTES)
}

/// Default local runner-process count for `repro serve-sim --fabric`.
pub const DEFAULT_FABRIC_RUNNERS: usize = 2;

/// Default per-runner outstanding-MAC budget for the fabric router's
/// sharding policy: 2^28 MACs (~a few serve-sim batches) in flight per
/// runner before admission pushes back.
pub const DEFAULT_FABRIC_MAC_BUDGET: u64 = 1 << 28;

/// Fabric fleet size: the single home of the `BOOSTERS_FABRIC_RUNNERS`
/// override (any positive integer) — how many local runner processes
/// `repro serve-sim --fabric` spawns when the `--fabric N` flag does
/// not say otherwise.
pub fn fabric_runners() -> usize {
    parse_fabric_runners(std::env::var("BOOSTERS_FABRIC_RUNNERS").ok().as_deref())
}

/// Pure parsing core of [`fabric_runners`]: malformed, zero, or missing
/// values fall back to [`DEFAULT_FABRIC_RUNNERS`].
pub fn parse_fabric_runners(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_FABRIC_RUNNERS)
}

/// Per-runner outstanding-MAC budget for the fabric router: the single
/// home of the `BOOSTERS_FABRIC_MAC_BUDGET` override (any positive
/// integer, raw MACs).
pub fn fabric_mac_budget() -> u64 {
    parse_fabric_mac_budget(std::env::var("BOOSTERS_FABRIC_MAC_BUDGET").ok().as_deref())
}

/// Pure parsing core of [`fabric_mac_budget`]: malformed, zero, or
/// missing values fall back to [`DEFAULT_FABRIC_MAC_BUDGET`].
pub fn parse_fabric_mac_budget(raw: Option<&str>) -> u64 {
    raw.and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_FABRIC_MAC_BUDGET)
}

/// Default cap on the fabric runner's resident digest-operand store:
/// 256 MiB of encoded planes.
pub const DEFAULT_FABRIC_STORE_BYTES: u64 = 256 << 20;

/// Fabric runner operand-store budget (bytes): the single home of the
/// `BOOSTERS_FABRIC_STORE_MB` override (any positive integer, in MiB).
/// The runner LRU-evicts stored weight planes past this cap; an evicted
/// digest simply re-triggers the router's `NEED_OPERAND` re-negotiation
/// on next use (re-transfers are counted separately so the dedup
/// counters stay monotone and exact).
pub fn fabric_store_budget() -> u64 {
    parse_fabric_store_budget(std::env::var("BOOSTERS_FABRIC_STORE_MB").ok().as_deref())
}

/// Pure parsing core of [`fabric_store_budget`]: malformed, zero, or
/// missing values fall back to [`DEFAULT_FABRIC_STORE_BYTES`].
pub fn parse_fabric_store_budget(mb: Option<&str>) -> u64 {
    mb.and_then(|s| s.trim().parse::<u64>().ok())
        .filter(|&n| n >= 1)
        .map(|mb| mb << 20)
        .unwrap_or(DEFAULT_FABRIC_STORE_BYTES)
}

/// Default same-weight grouping threshold for the batch execution
/// stage: groups of at least this many ops execute weight-stationary.
pub const DEFAULT_GROUP_MIN_OPS: usize = 2;

/// Same-weight grouping threshold for the batch execution stage: the
/// single home of the `BOOSTERS_GROUP_MIN_OPS` override. Ops of one
/// batch sharing a weight `(digest, format, layout)` key execute as a
/// single weight-stationary grouped GEMM when the group has at least
/// this many members; `0` disables grouping entirely (the pre-group
/// per-op behavior). Grouping is a memory-bandwidth optimization,
/// never a numerics one — results stay bit-identical either way.
pub fn group_min_ops() -> usize {
    parse_group_min_ops(std::env::var("BOOSTERS_GROUP_MIN_OPS").ok().as_deref())
}

/// Pure parsing core of [`group_min_ops`]: missing or malformed values
/// fall back to [`DEFAULT_GROUP_MIN_OPS`]; an explicit `0` is valid
/// and disables grouping (unlike the budget knobs, where 0 would mean
/// all-stall and is rejected).
pub fn parse_group_min_ops(raw: Option<&str>) -> usize {
    raw.and_then(|s| s.trim().parse::<usize>().ok())
        .unwrap_or(DEFAULT_GROUP_MIN_OPS)
}

/// Listen address for `repro fabric-runner` when `--listen` is not
/// given: the single home of the `BOOSTERS_FABRIC_LISTEN` override.
/// `Some(addr)` when set and non-empty.
pub fn fabric_listen() -> Option<String> {
    std::env::var("BOOSTERS_FABRIC_LISTEN")
        .ok()
        .map(|v| v.trim().to_string())
        .filter(|v| !v.is_empty())
}

/// Runner addresses for a fabric client when none are given on the
/// command line: the single home of the `BOOSTERS_FABRIC_CONNECT`
/// override (comma-separated `host:port` list).
pub fn fabric_connect() -> Vec<String> {
    parse_fabric_connect(std::env::var("BOOSTERS_FABRIC_CONNECT").ok().as_deref())
}

/// Pure parsing core of [`fabric_connect`]: split on commas, trim,
/// drop empties. (Whether each entry is a *valid* address is
/// [`validate_env_vars`]'s concern; connection errors stay typed at
/// connect time either way.)
pub fn parse_fabric_connect(raw: Option<&str>) -> Vec<String> {
    raw.map(|s| {
        s.split(',')
            .map(str::trim)
            .filter(|e| !e.is_empty())
            .map(str::to_string)
            .collect()
    })
    .unwrap_or_default()
}

/// Shape check for one `host:port` endpoint: a literal socket address,
/// or any non-empty host followed by a valid port. No DNS resolution —
/// startup validation must not block on the network.
fn endpoint_shape_ok(addr: &str) -> bool {
    if addr.parse::<std::net::SocketAddr>().is_ok() {
        return true;
    }
    match addr.rsplit_once(':') {
        Some((host, port)) => !host.is_empty() && port.parse::<u16>().is_ok(),
        None => false,
    }
}

/// One misconfigured `BOOSTERS_*` environment variable, as found by
/// [`validate_env_vars`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EnvIssue {
    /// The variable name (e.g. `BOOSTERS_GEMM_THREADS`).
    pub var: &'static str,
    /// The raw value that failed validation.
    pub value: String,
    /// What is wrong with it and what would be accepted.
    pub problem: String,
}

impl std::fmt::Display for EnvIssue {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}={:?}: {}", self.var, self.value, self.problem)
    }
}

/// Startup validation pass over every `BOOSTERS_*` knob. Unlike the
/// per-variable accessors above — which warn once and fall back so a
/// long-running process never dies mid-stream on a bad setting — this
/// pass collects **every** bad setting at once, so an operator fixes
/// one failed launch instead of discovering misconfigurations one
/// warn-and-fallback at a time. The accessors stay authoritative for
/// fallback semantics; this is a front door, not a second parser home
/// (it delegates to the same pure cores).
///
/// The injected `get` closure stands in for `std::env::var` so the
/// pass is unit-testable without touching the process environment.
pub fn validate_env_vars(get: impl Fn(&str) -> Option<String>) -> Vec<EnvIssue> {
    let mut issues = Vec::new();
    let mut positive_int = |var: &'static str, what: &str| {
        if let Some(v) = get(var) {
            if v.trim().parse::<u64>().ok().filter(|&n| n >= 1).is_none() {
                issues.push(EnvIssue {
                    var,
                    value: v,
                    problem: format!("expected a positive integer ({what})"),
                });
            }
        }
    };
    positive_int("BOOSTERS_GEMM_THREADS", "worker thread count");
    positive_int("BOOSTERS_CACHE_ENTRIES", "operand-cache entry cap");
    positive_int("BOOSTERS_CACHE_MB", "operand-cache byte cap, MiB");
    positive_int("BOOSTERS_PREENCODE_MB", "pre-encode residency cap, MiB");
    positive_int("BOOSTERS_ARENA_MB", "buffer-arena residency cap, MiB");
    positive_int("BOOSTERS_FABRIC_RUNNERS", "fabric runner-process count");
    positive_int("BOOSTERS_FABRIC_MAC_BUDGET", "per-runner outstanding-MAC budget");
    positive_int("BOOSTERS_FABRIC_STORE_MB", "runner operand-store cap, MiB");
    if let Some(v) = get("BOOSTERS_FABRIC_LISTEN") {
        let trimmed = v.trim();
        if !trimmed.is_empty() && !endpoint_shape_ok(trimmed) {
            issues.push(EnvIssue {
                var: "BOOSTERS_FABRIC_LISTEN",
                value: v,
                problem: "expected a host:port listen address".to_string(),
            });
        }
    }
    if let Some(v) = get("BOOSTERS_FABRIC_CONNECT") {
        let entries = parse_fabric_connect(Some(&v));
        if entries.is_empty() {
            if !v.trim().is_empty() {
                issues.push(EnvIssue {
                    var: "BOOSTERS_FABRIC_CONNECT",
                    value: v.clone(),
                    problem: "expected a comma-separated host:port list".to_string(),
                });
            }
        } else if let Some(bad) = entries.iter().find(|e| !endpoint_shape_ok(e)) {
            issues.push(EnvIssue {
                var: "BOOSTERS_FABRIC_CONNECT",
                value: v.clone(),
                problem: format!("entry {bad:?} is not a host:port address"),
            });
        }
    }
    if let Some(v) = get("BOOSTERS_GROUP_MIN_OPS") {
        // 0 is valid here (it disables grouping), so this knob cannot
        // ride the positive_int helper.
        if v.trim().parse::<u64>().is_err() {
            issues.push(EnvIssue {
                var: "BOOSTERS_GROUP_MIN_OPS",
                value: v,
                problem: "expected a non-negative integer (same-weight grouping \
                          threshold; 0 disables)"
                    .to_string(),
            });
        }
    }
    if let Some(v) = get("BOOSTERS_KERNEL") {
        let (_, rejected) = parse_kernel_choice(Some(&v));
        if rejected.is_some() {
            issues.push(EnvIssue {
                var: "BOOSTERS_KERNEL",
                value: v,
                problem: "expected one of auto/scalar/autovec/avx2/avx512/neon".to_string(),
            });
        }
    }
    if let Some(v) = get("BOOSTERS_AUTOTUNE") {
        let trimmed = v.trim();
        // Empty means "unset" to the accessor; only a named path that
        // does not resolve to a readable file is a misconfiguration.
        // (Whether the table parses is the kernel registry's concern —
        // host-independent validation stops at the filesystem.)
        if !trimmed.is_empty() && !std::path::Path::new(trimmed).is_file() {
            issues.push(EnvIssue {
                var: "BOOSTERS_AUTOTUNE",
                value: v,
                problem: "path does not exist or is not a file".to_string(),
            });
        }
    }
    issues
}

/// [`validate_env_vars`] over the real process environment — called
/// once at CLI startup, which reports every issue and exits instead of
/// limping along on fallbacks the operator did not ask for.
pub fn validate_env() -> Vec<EnvIssue> {
    validate_env_vars(|var| std::env::var(var).ok())
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0.0 for n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_budget_is_positive() {
        // Whatever the environment says, the budget is a usable count.
        assert!(gemm_thread_budget() >= 1);
    }

    #[test]
    fn cache_budget_parsing_and_fallback() {
        // Unset -> defaults.
        assert_eq!(
            parse_cache_budget(None, None),
            (DEFAULT_CACHE_ENTRIES, DEFAULT_CACHE_BYTES)
        );
        // Valid overrides (MB converts to bytes; whitespace tolerated).
        assert_eq!(parse_cache_budget(Some("12"), Some(" 64 ")), (12, 64 << 20));
        // Zero and garbage fall back per-variable, independently.
        assert_eq!(
            parse_cache_budget(Some("0"), Some("sixty-four")),
            (DEFAULT_CACHE_ENTRIES, DEFAULT_CACHE_BYTES)
        );
        assert_eq!(
            parse_cache_budget(Some("-3"), Some("8")),
            (DEFAULT_CACHE_ENTRIES, 8 << 20)
        );
        assert_eq!(parse_cache_budget(Some("1"), None), (1, DEFAULT_CACHE_BYTES));
        // The env-reading wrapper always yields usable caps.
        let (entries, bytes) = cache_budget();
        assert!(entries >= 1 && bytes >= 1);
        assert_eq!(default_cache_budget(), (DEFAULT_CACHE_ENTRIES, DEFAULT_CACHE_BYTES));
    }

    #[test]
    fn kernel_choice_parsing_and_fallback() {
        // Unset / empty / auto -> Auto, nothing rejected.
        assert_eq!(parse_kernel_choice(None), (KernelChoice::Auto, None));
        assert_eq!(parse_kernel_choice(Some("")), (KernelChoice::Auto, None));
        assert_eq!(parse_kernel_choice(Some("auto")), (KernelChoice::Auto, None));
        // The named backends, case-insensitive, whitespace tolerated.
        assert_eq!(parse_kernel_choice(Some("scalar")), (KernelChoice::Scalar, None));
        assert_eq!(parse_kernel_choice(Some(" AutoVec ")), (KernelChoice::Autovec, None));
        assert_eq!(parse_kernel_choice(Some("AVX2")), (KernelChoice::Avx2, None));
        assert_eq!(parse_kernel_choice(Some("avx512")), (KernelChoice::Avx512, None));
        assert_eq!(parse_kernel_choice(Some(" NEON ")), (KernelChoice::Neon, None));
        // Unknown values fall back to Auto and surface the raw string
        // for the warn path — no panic.
        let (choice, rejected) = parse_kernel_choice(Some("sse9"));
        assert_eq!(choice, KernelChoice::Auto);
        assert_eq!(rejected.as_deref(), Some("sse9"));
        // The env-reading wrapper always yields a usable choice.
        let _ = kernel_override();
        assert_eq!(KernelChoice::default(), KernelChoice::Auto);
        assert_eq!(KernelChoice::Avx2.label(), "avx2");
        assert_eq!(KernelChoice::Avx512.label(), "avx512");
        assert_eq!(KernelChoice::Neon.label(), "neon");
    }

    #[test]
    fn preencode_budget_parsing_and_fallback() {
        // Unset -> default cap.
        assert_eq!(parse_preencode_budget(None), DEFAULT_PREENCODE_BYTES);
        // Valid override (MiB converts to bytes; whitespace tolerated).
        assert_eq!(parse_preencode_budget(Some(" 8 ")), 8 << 20);
        // Zero and garbage fall back — the cap is never 0 (which would
        // permanently stall the encoder).
        assert_eq!(parse_preencode_budget(Some("0")), DEFAULT_PREENCODE_BYTES);
        assert_eq!(parse_preencode_budget(Some("lots")), DEFAULT_PREENCODE_BYTES);
        // The env-reading wrapper always yields a usable cap.
        assert!(preencode_budget() >= 1);
    }

    #[test]
    fn arena_budget_parsing_and_fallback() {
        // Unset -> default cap.
        assert_eq!(parse_arena_budget(None), DEFAULT_ARENA_BYTES);
        // Valid override (MiB converts to bytes; whitespace tolerated).
        assert_eq!(parse_arena_budget(Some(" 16 ")), 16 << 20);
        // Zero and garbage fall back — a 0 cap would be all-stall.
        assert_eq!(parse_arena_budget(Some("0")), DEFAULT_ARENA_BYTES);
        assert_eq!(parse_arena_budget(Some("big")), DEFAULT_ARENA_BYTES);
        // The env-reading wrapper always yields a usable cap.
        assert!(arena_budget() >= 1);
    }

    #[test]
    fn group_min_ops_parsing_and_fallback() {
        // Unset or garbage -> the default threshold.
        assert_eq!(parse_group_min_ops(None), DEFAULT_GROUP_MIN_OPS);
        assert_eq!(parse_group_min_ops(Some("many")), DEFAULT_GROUP_MIN_OPS);
        assert_eq!(parse_group_min_ops(Some("-2")), DEFAULT_GROUP_MIN_OPS);
        // An explicit 0 is valid: it disables grouping.
        assert_eq!(parse_group_min_ops(Some("0")), 0);
        assert_eq!(parse_group_min_ops(Some(" 0 ")), 0);
        // Any non-negative integer is accepted verbatim.
        assert_eq!(parse_group_min_ops(Some(" 4 ")), 4);
        // The env-reading wrapper runs without panicking.
        let _ = group_min_ops();
    }

    #[test]
    fn fabric_knob_parsing_and_fallback() {
        // Unset -> defaults; zero and garbage fall back, never 0.
        assert_eq!(parse_fabric_runners(None), DEFAULT_FABRIC_RUNNERS);
        assert_eq!(parse_fabric_runners(Some(" 4 ")), 4);
        assert_eq!(parse_fabric_runners(Some("0")), DEFAULT_FABRIC_RUNNERS);
        assert_eq!(parse_fabric_runners(Some("fleet")), DEFAULT_FABRIC_RUNNERS);
        assert_eq!(parse_fabric_mac_budget(None), DEFAULT_FABRIC_MAC_BUDGET);
        assert_eq!(parse_fabric_mac_budget(Some(" 1024 ")), 1024);
        assert_eq!(parse_fabric_mac_budget(Some("0")), DEFAULT_FABRIC_MAC_BUDGET);
        assert_eq!(parse_fabric_mac_budget(Some("lots")), DEFAULT_FABRIC_MAC_BUDGET);
        // Operand-store cap: MiB converts to bytes, zero/garbage fall
        // back — a 0 cap would evict every stored plane immediately.
        assert_eq!(parse_fabric_store_budget(None), DEFAULT_FABRIC_STORE_BYTES);
        assert_eq!(parse_fabric_store_budget(Some(" 32 ")), 32 << 20);
        assert_eq!(parse_fabric_store_budget(Some("0")), DEFAULT_FABRIC_STORE_BYTES);
        assert_eq!(parse_fabric_store_budget(Some("huge")), DEFAULT_FABRIC_STORE_BYTES);
        assert!(fabric_store_budget() >= 1);
        // Connect lists split on commas, trim, and drop empties.
        assert!(parse_fabric_connect(None).is_empty());
        assert_eq!(
            parse_fabric_connect(Some(" 127.0.0.1:7001 , 127.0.0.1:7002 ,")),
            vec!["127.0.0.1:7001".to_string(), "127.0.0.1:7002".to_string()]
        );
        assert!(parse_fabric_connect(Some("  ,, ")).is_empty());
        // Endpoint shape: literal socket addrs and host:port both pass;
        // missing or non-numeric ports fail. No DNS at validation time.
        assert!(endpoint_shape_ok("127.0.0.1:7000"));
        assert!(endpoint_shape_ok("[::1]:7000"));
        assert!(endpoint_shape_ok("localhost:7000"));
        assert!(!endpoint_shape_ok("nowhere"));
        assert!(!endpoint_shape_ok(":7000"));
        assert!(!endpoint_shape_ok("host:port"));
        // The env-reading wrappers always yield usable values.
        assert!(fabric_runners() >= 1);
        assert!(fabric_mac_budget() >= 1);
        let _ = fabric_listen();
        let _ = fabric_connect();
    }

    #[test]
    fn env_validation_reports_every_bad_setting_at_once() {
        use std::collections::HashMap;
        // A clean environment (or one with only valid settings) passes.
        assert!(validate_env_vars(|_| None).is_empty());
        let ok: HashMap<&str, &str> = [
            ("BOOSTERS_GEMM_THREADS", "4"),
            ("BOOSTERS_CACHE_ENTRIES", "32"),
            ("BOOSTERS_CACHE_MB", " 64 "),
            ("BOOSTERS_PREENCODE_MB", "128"),
            ("BOOSTERS_ARENA_MB", "256"),
            ("BOOSTERS_KERNEL", " AutoVec "),
            ("BOOSTERS_FABRIC_RUNNERS", "3"),
            ("BOOSTERS_FABRIC_MAC_BUDGET", "1048576"),
            ("BOOSTERS_FABRIC_STORE_MB", "64"),
            ("BOOSTERS_FABRIC_LISTEN", "127.0.0.1:7000"),
            ("BOOSTERS_FABRIC_CONNECT", "127.0.0.1:7001, localhost:7002"),
            ("BOOSTERS_GROUP_MIN_OPS", "0"),
        ]
        .into_iter()
        .collect();
        assert!(validate_env_vars(|v| ok.get(v).map(|s| s.to_string())).is_empty());
        // Every bad setting is reported in one pass, not one at a time.
        let bad: HashMap<&str, &str> = [
            ("BOOSTERS_GEMM_THREADS", "0"),
            ("BOOSTERS_CACHE_ENTRIES", "many"),
            ("BOOSTERS_CACHE_MB", "-1"),
            ("BOOSTERS_PREENCODE_MB", ""),
            ("BOOSTERS_ARENA_MB", "0x10"),
            ("BOOSTERS_KERNEL", "sse9"),
            ("BOOSTERS_AUTOTUNE", "/no/such/table.json"),
            ("BOOSTERS_FABRIC_RUNNERS", "zero"),
            ("BOOSTERS_FABRIC_MAC_BUDGET", "0"),
            ("BOOSTERS_FABRIC_STORE_MB", "-5"),
            ("BOOSTERS_FABRIC_LISTEN", "nowhere"),
            ("BOOSTERS_FABRIC_CONNECT", "127.0.0.1:7001,bogus"),
            ("BOOSTERS_GROUP_MIN_OPS", "many"),
        ]
        .into_iter()
        .collect();
        let issues = validate_env_vars(|v| bad.get(v).map(|s| s.to_string()));
        assert_eq!(issues.len(), 13, "{issues:?}");
        for issue in &issues {
            // Display output names the variable and the rejected value
            // so the operator can fix all of them from one failure.
            let line = issue.to_string();
            assert!(line.starts_with(issue.var), "{line}");
            assert!(!issue.problem.is_empty());
        }
        // KERNEL's unknown-name detection goes through the same parser
        // as the warn-once accessor — the two can never disagree.
        let kernel_issue = issues.iter().find(|i| i.var == "BOOSTERS_KERNEL").unwrap();
        assert!(kernel_issue.problem.contains("avx512"));
        // An empty BOOSTERS_AUTOTUNE means "unset" — not an issue.
        let empty: HashMap<&str, &str> = [("BOOSTERS_AUTOTUNE", "  ")].into_iter().collect();
        assert!(validate_env_vars(|v| empty.get(v).map(|s| s.to_string())).is_empty());
        // The process-environment wrapper runs without panicking.
        let _ = validate_env();
    }

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(stddev(&[1.0]), 0.0);
    }
}
