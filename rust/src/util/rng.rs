//! Deterministic RNG (splitmix64 seeding + xoshiro256**) used everywhere
//! randomness is needed outside the compiled graph: parameter init,
//! dataset generation, shuffling, landscape directions. No external crate
//! so runs are reproducible byte-for-byte across builds.

/// xoshiro256** seeded via splitmix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
            spare_normal: None,
        }
    }

    /// Derive an independent stream (e.g. per epoch / per worker).
    pub fn fork(&self, salt: u64) -> Self {
        Self::new(self.s[0] ^ salt.wrapping_mul(0x9E3779B97F4A7C15) ^ self.s[3])
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    pub fn normal_scaled(&mut self, std: f64) -> f32 {
        (self.normal() * std) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn fork_diverges() {
        let base = Rng::new(1);
        let mut a = base.fork(1);
        let mut b = base.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 40_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_permutes() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }
}
