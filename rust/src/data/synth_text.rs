//! Synthetic translation task — the IWSLT'14 stand-in (Table 3).
//!
//! Deterministic transduction grammar: a *keyed dual-dialect* of the
//! reversed source,
//!
//! `tgt[i] = perm_d[src[L-1-i]]`, `d = src[0] mod 2`
//!
//! i.e. the source is reversed and mapped through one of two token
//! permutations ("dialects"), selected by the parity class of the key
//! token src[0]. Learning it requires content transformation (two
//! permutations), positional reasoning (reversal) and *binding* (every
//! output must consult the key token) — the competence profile attention
//! is built for, without modular arithmetic (which small models famously
//! grok only after very long training). BLEU is the metric, with enough
//! headroom below saturation for mantissa-width effects to register
//! (DESIGN.md §3). Sequences are framed
//! as `[BOS] src [SEP] tgt [EOS]` for the decoder-only model; labels are
//! next-token ids over the target span and -1 elsewhere.

use crate::runtime::Tensor;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct TextGenSpec {
    /// Payload vocabulary (ids 0..payload_vocab); specials live above.
    pub payload_vocab: i32,
    pub vocab: i32,
    pub src_len: usize,
    pub tgt_len: usize,
    pub train_size: usize,
    pub val_size: usize,
}

impl Default for TextGenSpec {
    fn default() -> Self {
        Self {
            payload_vocab: 26,
            vocab: 32,
            src_len: 8,
            tgt_len: 8,
            train_size: 4096,
            val_size: 512,
        }
    }
}

impl TextGenSpec {
    pub fn bos(&self) -> i32 {
        self.vocab - 6
    }
    pub fn sep(&self) -> i32 {
        self.vocab - 5
    }
    pub fn eos(&self) -> i32 {
        self.vocab - 4
    }
    pub fn seq_len(&self) -> usize {
        self.src_len + self.tgt_len + 3
    }
}

pub struct TextDataset {
    pub spec: TextGenSpec,
    /// The two "dialect" permutations over payload tokens.
    pub perm: Vec<i32>,
    pub perm2: Vec<i32>,
    pub train_src: Vec<i32>, // [n, src_len]
    pub val_src: Vec<i32>,
}

impl TextDataset {
    pub fn generate(spec: TextGenSpec, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut perm: Vec<i32> = (0..spec.payload_vocab).collect();
        rng.shuffle(&mut perm);
        let mut perm2: Vec<i32> = (0..spec.payload_vocab).collect();
        rng.shuffle(&mut perm2);
        let gen = |rng: &mut Rng, n: usize| -> Vec<i32> {
            (0..n * spec.src_len)
                .map(|_| rng.below(spec.payload_vocab as usize) as i32)
                .collect()
        };
        let train_src = gen(&mut rng.fork(1), spec.train_size);
        let val_src = gen(&mut rng.fork(2), spec.val_size);
        Self {
            spec,
            perm,
            perm2,
            train_src,
            val_src,
        }
    }

    /// Ground-truth target for one source sentence (see module docs):
    /// tgt[i] = perm_d[src[L-1-i]] with dialect d = src[0] mod 2.
    pub fn translate(&self, src: &[i32]) -> Vec<i32> {
        let l = src.len();
        let dialect = if src[0] % 2 == 0 { &self.perm } else { &self.perm2 };
        (0..l).map(|i| dialect[src[l - 1 - i] as usize]).collect()
    }

    fn src_at(&self, i: usize, val: bool) -> &[i32] {
        let xs = if val { &self.val_src } else { &self.train_src };
        &xs[i * self.spec.src_len..(i + 1) * self.spec.src_len]
    }

    /// Build a training batch: (tokens [B, L], labels [B, L]).
    pub fn batch(&self, idx: &[usize], val: bool) -> (Tensor, Tensor) {
        let s = &self.spec;
        let l = s.seq_len();
        let mut x = Vec::with_capacity(idx.len() * l);
        let mut y = Vec::with_capacity(idx.len() * l);
        for &i in idx {
            let src = self.src_at(i, val);
            let tgt = self.translate(src);
            // tokens: BOS src SEP tgt EOS
            x.push(s.bos());
            x.extend_from_slice(src);
            x.push(s.sep());
            x.extend_from_slice(&tgt);
            x.push(s.eos());
            // labels: next-token over the target span (+EOS), -1 elsewhere.
            let start = 1 + s.src_len; // index of SEP
            for t in 0..l {
                if t >= start && t < start + s.tgt_len + 1 {
                    y.push(x[x.len() - l + t + 1]);
                } else {
                    y.push(-1);
                }
            }
        }
        (
            Tensor::from_i32(&[idx.len(), l], x).expect("x shape"),
            Tensor::from_i32(&[idx.len(), l], y).expect("y shape"),
        )
    }

    /// Source-only batch for decoding + its reference translations.
    pub fn decode_batch(&self, idx: &[usize], val: bool) -> (Tensor, Vec<Vec<i32>>) {
        let s = &self.spec;
        let mut x = Vec::with_capacity(idx.len() * s.src_len);
        let mut refs = Vec::with_capacity(idx.len());
        for &i in idx {
            let src = self.src_at(i, val);
            x.extend_from_slice(src);
            let mut r = self.translate(src);
            r.push(s.eos());
            refs.push(r);
        }
        (
            Tensor::from_i32(&[idx.len(), s.src_len], x).expect("src shape"),
            refs,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn permutation_is_a_bijection() {
        let d = TextDataset::generate(TextGenSpec::default(), 5);
        for perm in [&d.perm, &d.perm2] {
            let mut seen = vec![false; 26];
            for &p in perm {
                assert!(!seen[p as usize]);
                seen[p as usize] = true;
            }
            assert!(seen.iter().all(|&s| s));
        }
        assert_ne!(d.perm, d.perm2);
    }

    #[test]
    fn translate_is_the_keyed_reversed_dialect() {
        let d = TextDataset::generate(TextGenSpec::default(), 5);
        let even = vec![0, 1, 2, 3, 4, 5, 6, 7]; // key 0 -> perm
        let odd = vec![1, 1, 2, 3, 4, 5, 6, 7]; // key 1 -> perm2
        let te = d.translate(&even);
        let to = d.translate(&odd);
        for i in 0..8 {
            assert_eq!(te[i], d.perm[even[7 - i] as usize], "even i={i}");
            assert_eq!(to[i], d.perm2[odd[7 - i] as usize], "odd i={i}");
        }
        // Deterministic; dialect switch changes the output.
        assert_eq!(te, d.translate(&even));
        assert_ne!(te[1..], to[1..]);
    }

    #[test]
    fn batch_layout() {
        let spec = TextGenSpec::default();
        let l = spec.seq_len();
        let d = TextDataset::generate(spec, 9);
        let (x, y) = d.batch(&[0, 1], false);
        assert_eq!(x.shape(), &[2, l]);
        assert_eq!(y.shape(), &[2, l]);
        let xs = x.as_i32().unwrap();
        let ys = y.as_i32().unwrap();
        // BOS at 0, SEP at 1+src_len, EOS at end.
        assert_eq!(xs[0], d.spec.bos());
        assert_eq!(xs[1 + d.spec.src_len], d.spec.sep());
        assert_eq!(xs[l - 1], d.spec.eos());
        // Labels: y[t] == x[t+1] over the target span, -1 elsewhere.
        let start = 1 + d.spec.src_len;
        for t in 0..l {
            if t >= start && t <= start + d.spec.tgt_len {
                assert_eq!(ys[t], xs[t + 1], "t={t}");
            } else {
                assert_eq!(ys[t], -1, "t={t}");
            }
        }
    }

    #[test]
    fn decode_batch_refs_end_with_eos() {
        let d = TextDataset::generate(TextGenSpec::default(), 9);
        let (src, refs) = d.decode_batch(&[3, 4], true);
        assert_eq!(src.shape(), &[2, 8]);
        for r in refs {
            assert_eq!(r.len(), 9);
            assert_eq!(*r.last().unwrap(), d.spec.eos());
        }
    }

    #[test]
    fn deterministic() {
        let a = TextDataset::generate(TextGenSpec::default(), 1);
        let b = TextDataset::generate(TextGenSpec::default(), 1);
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.train_src, b.train_src);
    }
}
