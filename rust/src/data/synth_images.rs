//! Synthetic image classification task — the CIFAR stand-in.
//!
//! Each class `c` is an oriented sinusoidal grating: orientation and
//! spatial frequency are class-determined, phase and a mild amplitude
//! jitter are per-sample, plus additive Gaussian pixel noise. The three
//! channels carry phase-shifted copies (so cross-channel structure
//! matters, like natural images). FP32 models reach >90% validation
//! accuracy in a few epochs; narrow-mantissa distortion degrades it in
//! the same ordered way the paper reports on CIFAR.

use crate::runtime::Tensor;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ImageGenSpec {
    pub image: usize,
    pub classes: usize,
    pub noise: f32,
    pub train_size: usize,
    pub val_size: usize,
}

impl Default for ImageGenSpec {
    fn default() -> Self {
        Self {
            image: 16,
            classes: 10,
            noise: 1.6,
            train_size: 4096,
            val_size: 1024,
        }
    }
}

/// A fully materialized dataset (images are small; 4k train images at
/// 16x16x3 are ~12 MB).
pub struct ImageDataset {
    pub spec: ImageGenSpec,
    pub train_x: Vec<f32>,
    pub train_y: Vec<i32>,
    pub val_x: Vec<f32>,
    pub val_y: Vec<i32>,
}

impl ImageDataset {
    pub fn generate(spec: ImageGenSpec, seed: u64) -> Self {
        let rng = Rng::new(seed);
        let (train_x, train_y) = gen_split(&spec, &mut rng.fork(1), spec.train_size);
        let (val_x, val_y) = gen_split(&spec, &mut rng.fork(2), spec.val_size);
        Self {
            spec,
            train_x,
            train_y,
            val_x,
            val_y,
        }
    }

    pub fn example_size(&self) -> usize {
        self.spec.image * self.spec.image * 3
    }

    /// Batch `idx` examples into tensors ([B, H, W, 3], [B]).
    pub fn batch(&self, idx: &[usize], val: bool) -> (Tensor, Tensor) {
        let (xs, ys) = if val {
            (&self.val_x, &self.val_y)
        } else {
            (&self.train_x, &self.train_y)
        };
        let es = self.example_size();
        let mut x = Vec::with_capacity(idx.len() * es);
        let mut y = Vec::with_capacity(idx.len());
        for &i in idx {
            x.extend_from_slice(&xs[i * es..(i + 1) * es]);
            y.push(ys[i]);
        }
        let h = self.spec.image;
        (
            Tensor::from_f32(&[idx.len(), h, h, 3], x).expect("batch shape"),
            Tensor::from_i32(&[idx.len()], y).expect("label shape"),
        )
    }
}

fn gen_split(spec: &ImageGenSpec, rng: &mut Rng, n: usize) -> (Vec<f32>, Vec<i32>) {
    let es = spec.image * spec.image * 3;
    let mut xs = Vec::with_capacity(n * es);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(spec.classes);
        ys.push(c as i32);
        gen_image(spec, c, rng, &mut xs);
    }
    (xs, ys)
}

/// Append one HWC image for class `c`.
///
/// Difficulty is tuned so FP32 lands around the low-90s validation
/// accuracy (like ResNet20/CIFAR10) instead of saturating: adjacent
/// classes are only `pi/classes` apart in orientation, each sample adds a
/// random *distractor* grating, and pixel noise is strong.
fn gen_image(spec: &ImageGenSpec, c: usize, rng: &mut Rng, out: &mut Vec<f32>) {
    let s = spec.image as f32;
    // Class-determined structure: orientation spans pi; frequency
    // alternates to keep classes from being orientation-only colinear.
    let theta = c as f32 * std::f32::consts::PI / spec.classes as f32;
    let freq = if c % 2 == 0 { 2.25 } else { 3.0 };
    let (ct, st) = (theta.cos(), theta.sin());
    let phase = rng.uniform_in(0.0, std::f64::consts::TAU) as f32;
    let amp = 1.0 + rng.uniform_in(-0.3, 0.3) as f32;
    // Per-sample distractor grating at a random orientation/frequency.
    let dtheta = rng.uniform_in(0.0, std::f64::consts::PI) as f32;
    let (dct, dst) = (dtheta.cos(), dtheta.sin());
    let dfreq = rng.uniform_in(1.5, 3.5) as f32;
    let dphase = rng.uniform_in(0.0, std::f64::consts::TAU) as f32;
    for yy in 0..spec.image {
        for xx in 0..spec.image {
            let (px, py) = (xx as f32 / s - 0.5, yy as f32 / s - 0.5);
            let u = px * ct + py * st;
            let du = px * dct + py * dst;
            let base = std::f32::consts::TAU * freq * u + phase;
            let dis = std::f32::consts::TAU * dfreq * du + dphase;
            for ch in 0..3 {
                let shift = ch as f32 * std::f32::consts::FRAC_PI_3;
                let v = amp * (base + shift).sin()
                    + 0.9 * (dis + shift).sin()
                    + spec.noise * rng.normal() as f32;
                out.push(v);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = ImageDataset::generate(ImageGenSpec::default(), 7);
        let b = ImageDataset::generate(ImageGenSpec::default(), 7);
        assert_eq!(a.train_x, b.train_x);
        assert_eq!(a.val_y, b.val_y);
        let c = ImageDataset::generate(ImageGenSpec::default(), 8);
        assert_ne!(a.train_x, c.train_x);
    }

    #[test]
    fn shapes_and_labels() {
        let spec = ImageGenSpec {
            train_size: 64,
            val_size: 32,
            ..Default::default()
        };
        let d = ImageDataset::generate(spec, 1);
        assert_eq!(d.train_x.len(), 64 * 16 * 16 * 3);
        assert_eq!(d.val_y.len(), 32);
        assert!(d.train_y.iter().all(|&y| (0..10).contains(&y)));
        let (x, y) = d.batch(&[0, 5, 9], false);
        assert_eq!(x.shape(), &[3, 16, 16, 3]);
        assert_eq!(y.shape(), &[3]);
    }

    #[test]
    fn classes_are_separable_by_construction() {
        // Mean absolute pixel difference between two same-class images
        // should be below that of two different-class images (structure
        // dominates noise).
        let spec = ImageGenSpec {
            train_size: 400,
            val_size: 0,
            noise: 0.2,
            ..Default::default()
        };
        let d = ImageDataset::generate(spec, 3);
        let es = d.example_size();
        let img = |i: usize| &d.train_x[i * es..(i + 1) * es];
        // Gather per-class mean images; distinct classes must differ.
        let mut sums = vec![vec![0.0f64; es]; 10];
        let mut counts = vec![0usize; 10];
        for i in 0..400 {
            let c = d.train_y[i] as usize;
            counts[c] += 1;
            for (s, &v) in sums[c].iter_mut().zip(img(i)) {
                *s += v as f64;
            }
        }
        let mean_dist = |a: &[f64], ca: usize, b: &[f64], cb: usize| {
            a.iter()
                .zip(b)
                .map(|(&x, &y)| (x / ca as f64 - y / cb as f64).abs())
                .sum::<f64>()
                / es as f64
        };
        // Phase jitter averages gratings toward zero, but frequency
        // differences survive averaging of |mean|: compare class 0 vs 9.
        let d09 = mean_dist(&sums[0], counts[0], &sums[9], counts[9]);
        assert!(d09.is_finite());
    }
}
