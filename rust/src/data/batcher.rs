//! Epoch batching: shuffled fixed-size index batches (drop-last, since the
//! AOT artifacts bake the batch dimension).

use crate::util::Rng;

pub struct Batcher {
    n: usize,
    batch: usize,
    order: Vec<usize>,
}

impl Batcher {
    pub fn new(n: usize, batch: usize) -> Self {
        Self {
            n,
            batch,
            order: (0..n).collect(),
        }
    }

    /// Reshuffle for a new epoch with a per-epoch RNG stream.
    pub fn shuffle(&mut self, rng: &mut Rng) {
        rng.shuffle(&mut self.order);
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.n / self.batch
    }

    /// Index slice for batch `i` of the current epoch order.
    pub fn batch_indices(&self, i: usize) -> &[usize] {
        let start = i * self.batch;
        &self.order[start..start + self.batch]
    }

    /// Sequential (unshuffled) batches over the first `n` items — used for
    /// validation so every eval sees the same examples.
    pub fn sequential(n: usize, batch: usize) -> Vec<Vec<usize>> {
        (0..n / batch)
            .map(|i| (i * batch..(i + 1) * batch).collect())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_cover_without_replacement() {
        let mut b = Batcher::new(100, 16);
        let mut rng = Rng::new(4);
        b.shuffle(&mut rng);
        assert_eq!(b.batches_per_epoch(), 6);
        let mut seen = std::collections::HashSet::new();
        for i in 0..b.batches_per_epoch() {
            for &ix in b.batch_indices(i) {
                assert!(seen.insert(ix), "duplicate {ix}");
                assert!(ix < 100);
            }
        }
        assert_eq!(seen.len(), 96); // drop-last
    }

    #[test]
    fn sequential_is_ordered() {
        let bs = Batcher::sequential(64, 32);
        assert_eq!(bs.len(), 2);
        assert_eq!(bs[0], (0..32).collect::<Vec<_>>());
        assert_eq!(bs[1], (32..64).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_changes_order_but_not_set() {
        let mut b = Batcher::new(50, 10);
        let before = b.order.clone();
        let mut rng = Rng::new(1);
        b.shuffle(&mut rng);
        assert_ne!(b.order, before);
        let mut s = b.order.clone();
        s.sort();
        assert_eq!(s, before);
    }
}
