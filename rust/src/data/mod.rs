//! Synthetic dataset substrates (DESIGN.md §3 substitutions).
//!
//! The paper trains on CIFAR10/100 and IWSLT'14 De-En — multi-GPU-day
//! workloads. We substitute deterministic synthetic tasks that exercise
//! the *same code paths* (conv/residual/softmax pipelines; attention
//! seq2seq + BLEU) at laptop scale while keeping format-induced accuracy
//! degradation measurable and ordered:
//!
//! * [`synth_images`] — class-conditional oriented-grating images
//!   (the CIFAR stand-in),
//! * [`synth_text`] — a deterministic token-mapping + reversal
//!   transduction grammar (the IWSLT stand-in).
//!
//! Everything is generated from a [`crate::util::Rng`] seed: no files, no
//! downloads, bit-reproducible runs.

pub mod batcher;
pub mod synth_images;
pub mod synth_text;

pub use batcher::Batcher;
pub use synth_images::{ImageDataset, ImageGenSpec};
pub use synth_text::{TextDataset, TextGenSpec};
