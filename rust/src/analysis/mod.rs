//! Offline analyses over trained weights and eval executables:
//! filter-normalized loss landscapes (Fig 2/5) and Wasserstein sweeps
//! (Fig 1).

pub mod directions;
pub mod landscape;
pub mod spectral;
pub mod wasserstein_sweep;

pub use directions::{filter_normalized_direction, perturb};
pub use spectral::{conv_bank_high_freq, dft_magnitudes, high_freq_energy_fraction};
pub use landscape::{
    landscape_1d, landscape_1d_hbfp, landscape_2d, quantize_params_packed,
    quantize_params_packed_cached, LandscapeCurve,
};
pub use wasserstein_sweep::{layer_sweep, WassersteinPoint};
