//! Fig 1: Wasserstein distance between FP32 weight tensors and their
//! HBFP quantizations, per layer, across mantissa widths and block sizes.

use crate::checkpoint::Checkpoint;
use crate::metrics::QuantSweep;

/// One measurement point of the Fig-1 sweep.
#[derive(Debug, Clone)]
pub struct WassersteinPoint {
    pub layer: String,
    pub m_bits: u32,
    pub block: usize,
    pub distance: f64,
}

/// Sweep selected layers of a checkpoint over (m, b) combinations.
/// Every point re-quantizes the same weights, so the whole sweep shares
/// one packed carrier and one decode buffer ([`QuantSweep`]) instead of
/// allocating per measurement.
pub fn layer_sweep(
    ck: &Checkpoint,
    layers: &[&str],
    m_bits: &[u32],
    blocks: &[usize],
) -> Vec<WassersteinPoint> {
    let mut out = Vec::new();
    let mut sweep = QuantSweep::new();
    for &layer in layers {
        let Some(t) = ck.get(layer) else { continue };
        let data = t.as_f32().expect("weights are f32");
        sweep.set_reference(data); // sorted once per layer
        for &m in m_bits {
            for &b in blocks {
                out.push(WassersteinPoint {
                    layer: layer.to_string(),
                    m_bits: m,
                    block: b,
                    distance: sweep.distance_to_reference(data, m, b),
                });
            }
        }
    }
    out
}

/// The four Fig-1 layers for the CNN: first conv, two middle convs, fc.
pub fn fig1_layers(param_names: &[String]) -> Vec<String> {
    let mut picks = Vec::new();
    if let Some(first) = param_names.iter().find(|n| n.starts_with("conv1")) {
        picks.push(first.clone());
    }
    // Two representative middle convs: first conv of each stage block 1.
    for cand in ["stage0.block1.conv1.weight", "stage1.block1.conv1.weight"] {
        if param_names.iter().any(|n| n == cand) {
            picks.push(cand.to_string());
        }
    }
    if let Some(last) = param_names.iter().find(|n| n.starts_with("fc.weight")) {
        picks.push(last.clone());
    }
    picks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Tensor;
    use crate::util::Rng;

    fn ck() -> Checkpoint {
        let mut rng = Rng::new(11);
        let mut t = |n: usize| {
            Tensor::from_f32(&[n], (0..n).map(|_| rng.normal_scaled(0.1)).collect()).unwrap()
        };
        Checkpoint::new(
            vec!["conv1.weight".into(), "fc.weight".into()],
            vec![t(432), t(320)],
        )
    }

    #[test]
    fn sweep_shape_and_ordering() {
        let ck = ck();
        let pts = layer_sweep(&ck, &["conv1.weight", "fc.weight"], &[4, 6], &[16, 64, 576]);
        assert_eq!(pts.len(), 2 * 2 * 3);
        // HBFP4 distances dominate HBFP6 at every (layer, block).
        for p4 in pts.iter().filter(|p| p.m_bits == 4) {
            let p6 = pts
                .iter()
                .find(|p| p.m_bits == 6 && p.layer == p4.layer && p.block == p4.block)
                .unwrap();
            assert!(p4.distance > p6.distance, "{p4:?} vs {p6:?}");
        }
    }

    #[test]
    fn missing_layers_skipped() {
        let ck = ck();
        let pts = layer_sweep(&ck, &["nope.weight"], &[4], &[16]);
        assert!(pts.is_empty());
    }

    #[test]
    fn fig1_layer_selection() {
        let names: Vec<String> = [
            "conv1.weight",
            "stage0.block1.conv1.weight",
            "stage1.block1.conv1.weight",
            "fc.weight",
            "fc.bias",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let picks = fig1_layers(&names);
        assert_eq!(picks.len(), 4);
        assert_eq!(picks[0], "conv1.weight");
        assert_eq!(picks[3], "fc.weight");
    }
}
