//! Spectral diagnostics for the frequency-principle argument (§2).
//!
//! The paper motivates the Booster with Rahaman et al. / Xu et al.:
//! networks learn low-frequency structure first and high-frequency detail
//! in the final epochs — which is why the *last* epoch needs more
//! mantissa. This module gives the reproduction a measurable version of
//! that claim: a radix-free DFT and (a) per-curve high-frequency energy
//! of training curves, (b) the radial spectrum of conv filters from
//! checkpoints, so `repro fig2`-style analyses can verify that boosted
//! epochs indeed move high-frequency filter content more than early ones.

/// Naive DFT magnitude spectrum of a real signal (O(n^2), n is small:
/// epochs or filter taps). Returns |X_k| for k = 0..n/2.
pub fn dft_magnitudes(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    if n == 0 {
        return vec![];
    }
    let mut out = Vec::with_capacity(n / 2 + 1);
    for k in 0..=n / 2 {
        let (mut re, mut im) = (0.0f64, 0.0f64);
        for (t, &v) in x.iter().enumerate() {
            let ang = -2.0 * std::f64::consts::PI * (k * t) as f64 / n as f64;
            re += v * ang.cos();
            im += v * ang.sin();
        }
        out.push((re * re + im * im).sqrt());
    }
    out
}

/// Fraction of spectral energy above `cut` (as a fraction of Nyquist),
/// ignoring the DC bin.
pub fn high_freq_energy_fraction(x: &[f64], cut: f64) -> f64 {
    let mags = dft_magnitudes(x);
    if mags.len() <= 1 {
        return 0.0;
    }
    let cut_bin = (cut * (mags.len() - 1) as f64).round() as usize;
    let total: f64 = mags[1..].iter().map(|m| m * m).sum();
    // Guard numerically-silent signals: DFT of a constant leaves ~1e-14
    // residue in the AC bins; treat AC energy below 1e-18 of the DC
    // energy (or absolute epsilon) as zero.
    if total <= 1e-18 * (mags[0] * mags[0]).max(1.0) {
        return 0.0;
    }
    let hi: f64 = mags[cut_bin.max(1)..].iter().map(|m| m * m).sum();
    hi / total
}

/// Radially-averaged 2-D spectrum of a k x k filter (k is 1 or 3 here):
/// returns energies at integer radii 0..=k/2+1 from the 2-D DFT.
pub fn filter_radial_spectrum(filter: &[f32], k: usize) -> Vec<f64> {
    assert_eq!(filter.len(), k * k);
    let n = k;
    let mut radial = vec![0.0f64; n / 2 + 2];
    let mut counts = vec![0usize; n / 2 + 2];
    for kx in 0..n {
        for ky in 0..n {
            let (mut re, mut im) = (0.0f64, 0.0f64);
            for x in 0..n {
                for y in 0..n {
                    let ang = -2.0
                        * std::f64::consts::PI
                        * ((kx * x + ky * y) as f64 / n as f64);
                    let v = filter[y * n + x] as f64;
                    re += v * ang.cos();
                    im += v * ang.sin();
                }
            }
            // Fold frequencies to [0, n/2].
            let fx = kx.min(n - kx);
            let fy = ky.min(n - ky);
            let r = ((fx * fx + fy * fy) as f64).sqrt().round() as usize;
            let r = r.min(radial.len() - 1);
            radial[r] += re * re + im * im;
            counts[r] += 1;
        }
    }
    for (v, &c) in radial.iter_mut().zip(&counts) {
        if c > 0 {
            *v /= c as f64;
        }
    }
    radial
}

/// Mean high-frequency fraction over a bank of k x k x cin x cout conv
/// filters stored HWIO (the layout of this repo's checkpoints).
pub fn conv_bank_high_freq(weights: &[f32], k: usize, cin: usize, cout: usize) -> f64 {
    assert_eq!(weights.len(), k * k * cin * cout);
    let mut acc = 0.0;
    let mut n = 0usize;
    let mut filt = vec![0.0f32; k * k];
    for ci in 0..cin {
        for co in 0..cout {
            for y in 0..k {
                for x in 0..k {
                    // HWIO: ((y * k + x) * cin + ci) * cout + co
                    filt[y * k + x] = weights[((y * k + x) * cin + ci) * cout + co];
                }
            }
            let spec = filter_radial_spectrum(&filt, k);
            let total: f64 = spec.iter().sum();
            if total > 0.0 {
                let hi: f64 = spec[spec.len() - 2..].iter().sum();
                acc += hi / total;
                n += 1;
            }
        }
    }
    if n == 0 {
        0.0
    } else {
        acc / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dft_of_constant_is_dc_only() {
        let mags = dft_magnitudes(&[3.0; 16]);
        assert!(mags[0] > 1.0);
        assert!(mags[1..].iter().all(|&m| m < 1e-9));
        assert_eq!(high_freq_energy_fraction(&[3.0; 16], 0.5), 0.0);
    }

    #[test]
    fn dft_locates_a_pure_tone() {
        let n = 32;
        let x: Vec<f64> = (0..n)
            .map(|t| (2.0 * std::f64::consts::PI * 4.0 * t as f64 / n as f64).sin())
            .collect();
        let mags = dft_magnitudes(&x);
        let peak = mags
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(peak, 4);
    }

    #[test]
    fn high_freq_fraction_orders_signals() {
        let n = 64;
        let slow: Vec<f64> = (0..n)
            .map(|t| (2.0 * std::f64::consts::PI * 1.0 * t as f64 / n as f64).sin())
            .collect();
        let fast: Vec<f64> = (0..n)
            .map(|t| (2.0 * std::f64::consts::PI * 14.0 * t as f64 / n as f64).sin())
            .collect();
        assert!(
            high_freq_energy_fraction(&fast, 0.4) > high_freq_energy_fraction(&slow, 0.4)
        );
    }

    #[test]
    fn radial_spectrum_of_checkerboard_is_high_freq() {
        // 3x3 checkerboard: energy concentrated at max radius.
        let filt: Vec<f32> = (0..9)
            .map(|i| if (i / 3 + i % 3) % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        let spec = filter_radial_spectrum(&filt, 3);
        let total: f64 = spec.iter().sum();
        assert!(spec.last().unwrap() + spec[spec.len() - 2] > 0.5 * total, "{spec:?}");
        // Flat filter: all DC.
        let flat = vec![1.0f32; 9];
        let fspec = filter_radial_spectrum(&flat, 3);
        assert!(fspec[0] > 0.99 * fspec.iter().sum::<f64>());
    }

    #[test]
    fn conv_bank_shapes() {
        let w = vec![0.5f32; 3 * 3 * 2 * 4];
        let f = conv_bank_high_freq(&w, 3, 2, 4);
        assert!(f >= 0.0 && f < 0.05); // constant filters: ~no HF energy
    }
}
