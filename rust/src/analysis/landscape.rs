//! Loss-landscape slices (Fig 2) and grids (Fig 5): evaluate the AOT eval
//! executable at θ + α·d1 (+ β·d2) over a sweep of α (and β), with the
//! quantization scalars of the configuration under study — so each curve
//! shows the loss surface *as seen through that numeric format*.

use crate::bfp::{quantize_flat, quantize_packed_into, BfpMatrix, BlockFormat, Quantizer};
use crate::exec::ExecRuntime;
use crate::runtime::{Engine, ModelVariant, StepScalars, Tensor, TrainState};
use anyhow::Result;

use super::directions::perturb;

/// A 1-D landscape slice.
#[derive(Debug, Clone)]
pub struct LandscapeCurve {
    pub label: String,
    pub alphas: Vec<f32>,
    pub losses: Vec<f64>,
}

impl LandscapeCurve {
    pub fn min_loss(&self) -> f64 {
        self.losses.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Curvature proxy at the center: mean second difference over the
    /// inner third of the sweep (sharpness comparisons in §3).
    pub fn sharpness(&self) -> f64 {
        let n = self.losses.len();
        if n < 5 {
            return 0.0;
        }
        let (lo, hi) = (n / 3, 2 * n / 3);
        let mut acc = 0.0;
        let mut cnt = 0;
        for i in lo.max(1)..hi.min(n - 1) {
            acc += self.losses[i - 1] - 2.0 * self.losses[i] + self.losses[i + 1];
            cnt += 1;
        }
        if cnt == 0 {
            0.0
        } else {
            acc / cnt as f64
        }
    }
}

/// Average eval loss of `params` over the provided batches.
fn loss_at(
    engine: &Engine,
    variant: &ModelVariant,
    params: &[Tensor],
    batches: &[(Tensor, Tensor)],
    scalars: StepScalars,
) -> Result<f64> {
    let state = TrainState::from_tensors(params, &[])?;
    let mut acc = 0.0;
    for (x, y) in batches {
        acc += engine.eval_batch(variant, &state, x, y, scalars)?.loss as f64;
    }
    Ok(acc / batches.len() as f64)
}

/// 1-D slice: losses at θ + α·d over `alphas`.
#[allow(clippy::too_many_arguments)]
pub fn landscape_1d(
    engine: &Engine,
    variant: &ModelVariant,
    label: &str,
    params: &[Tensor],
    direction: &[Tensor],
    alphas: &[f32],
    batches: &[(Tensor, Tensor)],
    scalars: StepScalars,
) -> Result<LandscapeCurve> {
    let mut losses = Vec::with_capacity(alphas.len());
    for &a in alphas {
        let p = perturb(params, direction, a, None);
        losses.push(loss_at(engine, variant, &p, batches, scalars)?);
    }
    Ok(LandscapeCurve {
        label: label.into(),
        alphas: alphas.to_vec(),
        losses,
    })
}

/// Snap every f32 parameter tensor to the HBFP(m, b) grid host-side,
/// in place, through one shared packed carrier (i32-label tensors pass
/// through; `m_bits` in 17..=22 delegates past the integer carrier and
/// `m_bits >= 23` is the FP32 bypass — both still well-defined).
/// This is the emulation view of "weights stored in BFP SRAM": the same
/// packed planes the GEMM kernels consume, applied outside the graph.
/// Shared by [`landscape_1d_hbfp`] and the Trainer's host-BFP-store
/// emulation.
pub fn quantize_params_packed(
    params: &mut [Tensor],
    m_bits: u32,
    block: usize,
    scratch: &mut BfpMatrix,
    qbuf: &mut Vec<f32>,
) -> Result<()> {
    let q = Quantizer::nearest(m_bits);
    for t in params.iter_mut() {
        if let Ok(d) = t.as_f32_mut() {
            quantize_packed_into(d, block, q, 0, scratch, qbuf)?;
            d.copy_from_slice(qbuf);
        }
    }
    Ok(())
}

/// [`quantize_params_packed`] routed through an [`ExecRuntime`]'s
/// encoded-operand cache: each tensor's encoding is keyed by its
/// content, so a tensor whose values did not change since the last
/// round-trip (a frozen layer, a plateaued parameter, a repeated
/// evaluation point) is served from cache instead of re-encoded.
/// Bit-identical to the uncached helper — cached planes come from the
/// same deterministic nearest-rounding encode.
///
/// This is what the Trainer's host-BFP weight store calls every epoch;
/// hit/miss counts are visible via [`crate::metrics::exec_cache_snapshot`].
pub fn quantize_params_packed_cached(
    params: &mut [Tensor],
    m_bits: u32,
    block: usize,
    rt: &ExecRuntime,
    qbuf: &mut Vec<f32>,
) -> Result<()> {
    let q = Quantizer::nearest(m_bits);
    if q.is_bypass() {
        return Ok(());
    }
    for t in params.iter_mut() {
        if let Ok(d) = t.as_f32_mut() {
            if !(2..=16).contains(&m_bits) {
                // Mantissas beyond the integer carrier (17..=22):
                // delegate exactly like `quantize_packed_into`.
                let flat = quantize_flat(d, block, q, 0);
                d.copy_from_slice(&flat);
                continue;
            }
            let fmt = BlockFormat::new(m_bits, block)?;
            let enc = rt.encode_cached(d, 1, d.len(), fmt)?;
            enc.decode_into(qbuf);
            d.copy_from_slice(qbuf);
        }
    }
    Ok(())
}

/// 1-D slice of the loss surface *as stored in packed HBFP(m, b)*:
/// perturbed parameters are snapped to the BFP grid host-side before
/// evaluation (with FP32 scalars, so the only quantization is the one
/// we applied). Complements [`landscape_1d`], whose quantization lives
/// inside the compiled graph.
#[allow(clippy::too_many_arguments)]
pub fn landscape_1d_hbfp(
    engine: &Engine,
    variant: &ModelVariant,
    label: &str,
    params: &[Tensor],
    direction: &[Tensor],
    alphas: &[f32],
    batches: &[(Tensor, Tensor)],
    fmt: BlockFormat,
) -> Result<LandscapeCurve> {
    let mut scratch = BfpMatrix::empty();
    let mut qbuf = Vec::new();
    let mut losses = Vec::with_capacity(alphas.len());
    for &a in alphas {
        let mut p = perturb(params, direction, a, None);
        quantize_params_packed(
            &mut p,
            fmt.mantissa_bits,
            fmt.block_size,
            &mut scratch,
            &mut qbuf,
        )?;
        losses.push(loss_at(engine, variant, &p, batches, StepScalars::fp32())?);
    }
    Ok(LandscapeCurve {
        label: label.into(),
        alphas: alphas.to_vec(),
        losses,
    })
}

/// 2-D grid: row-major losses at θ + α·d1 + β·d2 (Fig 5's 3-D surface).
#[allow(clippy::too_many_arguments)]
pub fn landscape_2d(
    engine: &Engine,
    variant: &ModelVariant,
    params: &[Tensor],
    d1: &[Tensor],
    d2: &[Tensor],
    alphas: &[f32],
    betas: &[f32],
    batches: &[(Tensor, Tensor)],
    scalars: StepScalars,
) -> Result<Vec<Vec<f64>>> {
    let mut grid = Vec::with_capacity(alphas.len());
    for &a in alphas {
        let mut row = Vec::with_capacity(betas.len());
        for &b in betas {
            let p = perturb(params, d1, a, Some((d2, b)));
            row.push(loss_at(engine, variant, &p, batches, scalars)?);
        }
        grid.push(row);
    }
    Ok(grid)
}

/// Standard symmetric sweep grid.
pub fn alpha_grid(half_range: f32, points: usize) -> Vec<f32> {
    let n = points.max(3) | 1; // force odd so α=0 is sampled
    (0..n)
        .map(|i| (i as f32 / (n - 1) as f32 * 2.0 - 1.0) * half_range)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alpha_grid_symmetric_with_center() {
        let g = alpha_grid(1.0, 11);
        assert_eq!(g.len(), 11);
        assert!((g[5]).abs() < 1e-7);
        assert_eq!(g[0], -1.0);
        assert_eq!(*g.last().unwrap(), 1.0);
        // Even requests are bumped to odd.
        assert_eq!(alpha_grid(1.0, 10).len(), 11);
    }

    #[test]
    fn params_snap_to_the_packed_grid() {
        use crate::bfp::quantize_tensor;
        use crate::util::Rng;
        let mut rng = Rng::new(21);
        let w: Vec<f32> = (0..200).map(|_| rng.normal_scaled(0.5)).collect();
        let labels = Tensor::from_i32(&[3], vec![1, 2, 3]).unwrap();
        let mut params = vec![
            Tensor::from_f32(&[10, 20], w.clone()).unwrap(),
            labels.clone(),
        ];
        let mut scratch = BfpMatrix::empty();
        let mut qbuf = Vec::new();
        quantize_params_packed(&mut params, 4, 64, &mut scratch, &mut qbuf).unwrap();
        let want = quantize_tensor(&w, 64, 4);
        let got = params[0].as_f32().unwrap().to_vec();
        for (g, w) in got.iter().zip(&want) {
            assert!((g == w) || (*g == 0.0 && *w == 0.0), "{g} vs {w}");
        }
        // Idempotent: already-snapped params survive a second pass.
        quantize_params_packed(&mut params, 4, 64, &mut scratch, &mut qbuf).unwrap();
        assert_eq!(params[0].as_f32().unwrap(), &got[..]);
        // Labels pass through untouched.
        assert_eq!(params[1], labels);
        // The FP32 bypass leaves values untouched (emulated store is FP32).
        let mut raw = vec![Tensor::from_f32(&[200], w.clone()).unwrap()];
        quantize_params_packed(&mut raw, 32, 64, &mut scratch, &mut qbuf).unwrap();
        assert_eq!(raw[0].as_f32().unwrap(), &w[..]);
    }

    #[test]
    fn cached_param_quantize_matches_uncached_and_hits() {
        use crate::util::Rng;
        let mut rng = Rng::new(77);
        let w: Vec<f32> = (0..300).map(|_| rng.normal_scaled(0.5)).collect();
        let rt = ExecRuntime::with_threads(1);
        let mut qbuf = Vec::new();
        for m in [4u32, 18, 32] {
            let mut cached = vec![Tensor::from_f32(&[300], w.clone()).unwrap()];
            quantize_params_packed_cached(&mut cached, m, 64, &rt, &mut qbuf).unwrap();
            let mut plain = vec![Tensor::from_f32(&[300], w.clone()).unwrap()];
            let mut scratch = BfpMatrix::empty();
            let mut buf = Vec::new();
            quantize_params_packed(&mut plain, m, 64, &mut scratch, &mut buf).unwrap();
            let (a, b) = (cached[0].as_f32().unwrap(), plain[0].as_f32().unwrap());
            for (i, (x, y)) in a.iter().zip(b).enumerate() {
                assert!(
                    (*x == 0.0 && *y == 0.0) || x.to_bits() == y.to_bits(),
                    "m={m} elem {i}: {x} vs {y}"
                );
            }
        }
        // Unchanged content re-quantized at the same format hits the cache.
        let before = rt.cache_stats().hits;
        let mut again = vec![Tensor::from_f32(&[300], w.clone()).unwrap()];
        quantize_params_packed_cached(&mut again, 4, 64, &rt, &mut qbuf).unwrap();
        assert!(rt.cache_stats().hits > before);
    }

    #[test]
    fn curve_summaries() {
        let c = LandscapeCurve {
            label: "t".into(),
            alphas: alpha_grid(1.0, 9),
            losses: vec![4.0, 2.5, 1.2, 0.5, 0.2, 0.5, 1.2, 2.5, 4.0],
        };
        assert_eq!(c.min_loss(), 0.2);
        assert!(c.sharpness() > 0.0); // convex center
        let flat = LandscapeCurve {
            label: "f".into(),
            alphas: c.alphas.clone(),
            losses: vec![1.0; 9],
        };
        assert_eq!(flat.sharpness(), 0.0);
        assert!(c.sharpness() > flat.sharpness());
    }
}
