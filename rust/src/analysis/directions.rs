//! Filter-normalized random directions (Li et al. 2018, used by §3).
//!
//! For each parameter tensor, draw a Gaussian direction and rescale each
//! *filter* (output-feature slice) to the norm of the corresponding
//! weight filter: `d_f <- d_f / ||d_f|| * ||w_f||`. This removes the
//! scale-invariance artifacts that make raw-direction landscapes
//! misleading — the property the paper relies on to compare sharpness
//! across numeric formats.

use crate::runtime::Tensor;
use crate::util::Rng;

/// Number of filters = size of the trailing axis (convs are HWIO with
/// Cout last; linears are [in, out] with out last); vectors (biases,
/// norm weights) are treated as a single filter and conventionally left
/// out of the perturbation (their direction is zeroed), matching the
/// original loss-landscape code's handling of 1-D parameters.
pub fn filter_normalized_direction(params: &[Tensor], rng: &mut Rng) -> Vec<Tensor> {
    params
        .iter()
        .map(|p| {
            let w = p.as_f32().expect("params are f32");
            let shape = p.shape().to_vec();
            if shape.len() < 2 {
                return Tensor::zeros(&shape);
            }
            let cout = *shape.last().unwrap();
            let mut d: Vec<f32> = (0..w.len()).map(|_| rng.normal_scaled(1.0)).collect();
            // Filters are strided over the trailing axis.
            for f in 0..cout {
                let mut dn = 0.0f64;
                let mut wn = 0.0f64;
                let mut i = f;
                while i < w.len() {
                    dn += (d[i] as f64) * (d[i] as f64);
                    wn += (w[i] as f64) * (w[i] as f64);
                    i += cout;
                }
                let scale = if dn > 0.0 {
                    (wn.sqrt() / dn.sqrt()) as f32
                } else {
                    0.0
                };
                let mut i = f;
                while i < w.len() {
                    d[i] *= scale;
                    i += cout;
                }
            }
            Tensor::from_f32(&shape, d).unwrap()
        })
        .collect()
}

/// θ' = θ + α·d1 (+ β·d2). Directions must be parallel to `params`.
pub fn perturb(
    params: &[Tensor],
    d1: &[Tensor],
    alpha: f32,
    d2: Option<(&[Tensor], f32)>,
) -> Vec<Tensor> {
    params
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let w = p.as_f32().unwrap();
            let a = d1[i].as_f32().unwrap();
            let mut out: Vec<f32> = w.iter().zip(a).map(|(&x, &da)| x + alpha * da).collect();
            if let Some((d2s, beta)) = d2 {
                let b = d2s[i].as_f32().unwrap();
                for (o, &db) in out.iter_mut().zip(b) {
                    *o += beta * db;
                }
            }
            Tensor::from_f32(p.shape(), out).unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn param(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        let n: usize = shape.iter().product();
        Tensor::from_f32(shape, (0..n).map(|_| rng.normal_scaled(0.5)).collect()).unwrap()
    }

    #[test]
    fn filter_norms_match_weights() {
        let p = param(&[3, 3, 8, 16], 1);
        let mut rng = Rng::new(2);
        let d = filter_normalized_direction(std::slice::from_ref(&p), &mut rng);
        let w = p.as_f32().unwrap();
        let dv = d[0].as_f32().unwrap();
        let cout = 16;
        for f in 0..cout {
            let norm = |v: &[f32]| -> f64 {
                let mut s = 0.0;
                let mut i = f;
                while i < v.len() {
                    s += (v[i] as f64) * (v[i] as f64);
                    i += cout;
                }
                s.sqrt()
            };
            let (nw, nd) = (norm(w), norm(dv));
            assert!((nw - nd).abs() < 1e-4 * nw.max(1.0), "filter {f}: {nw} vs {nd}");
        }
    }

    #[test]
    fn vectors_get_zero_direction() {
        let p = param(&[32], 3);
        let mut rng = Rng::new(4);
        let d = filter_normalized_direction(std::slice::from_ref(&p), &mut rng);
        assert!(d[0].as_f32().unwrap().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn perturb_axes() {
        let p = param(&[4, 4], 5);
        let mut rng = Rng::new(6);
        let d1 = filter_normalized_direction(std::slice::from_ref(&p), &mut rng);
        let d2 = filter_normalized_direction(std::slice::from_ref(&p), &mut rng);
        let zero = perturb(std::slice::from_ref(&p), &d1, 0.0, Some((&d2, 0.0)));
        assert_eq!(zero[0], p);
        let moved = perturb(std::slice::from_ref(&p), &d1, 0.5, None);
        assert_ne!(moved[0], p);
        // Linearity: θ + 2αd == perturb twice by α.
        let twice = perturb(&moved, &d1, 0.5, None);
        let direct = perturb(std::slice::from_ref(&p), &d1, 1.0, None);
        let a = twice[0].as_f32().unwrap();
        let b = direct[0].as_f32().unwrap();
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
