//! Run history: per-epoch statistics, CSV/JSONL persistence, and the
//! best/final summaries the tables report.

use crate::util::Json;
use std::io::Write;
use std::path::Path;

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EpochStats {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_acc: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    pub lr: f64,
    pub bits_mid: f32,
    pub bits_edge: f32,
    pub wall_secs: f64,
}

impl EpochStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("epoch", Json::num(self.epoch as f64)),
            ("train_loss", Json::num(self.train_loss)),
            ("train_acc", Json::num(self.train_acc)),
            ("val_loss", Json::num(self.val_loss)),
            ("val_acc", Json::num(self.val_acc)),
            ("lr", Json::num(self.lr)),
            ("bits_mid", Json::num(self.bits_mid as f64)),
            ("bits_edge", Json::num(self.bits_edge as f64)),
            ("wall_secs", Json::num(self.wall_secs)),
        ])
    }
}

#[derive(Debug, Clone, Default)]
pub struct RunHistory {
    pub label: String,
    pub epochs: Vec<EpochStats>,
}

impl RunHistory {
    pub fn new(label: impl Into<String>) -> Self {
        Self {
            label: label.into(),
            epochs: Vec::new(),
        }
    }

    pub fn push(&mut self, e: EpochStats) {
        self.epochs.push(e);
    }

    pub fn final_val_acc(&self) -> f64 {
        self.epochs.last().map(|e| e.val_acc).unwrap_or(0.0)
    }

    pub fn best_val_acc(&self) -> f64 {
        self.epochs.iter().map(|e| e.val_acc).fold(0.0, f64::max)
    }

    pub fn final_val_loss(&self) -> f64 {
        self.epochs.last().map(|e| e.val_loss).unwrap_or(f64::NAN)
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.wall_secs).sum()
    }

    /// Write the Fig-3-style training curve as CSV.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "epoch,train_loss,train_acc,val_loss,val_acc,lr,bits_mid,bits_edge,wall_secs"
        )?;
        for e in &self.epochs {
            writeln!(
                f,
                "{},{:.6},{:.6},{:.6},{:.6},{:.6},{},{},{:.3}",
                e.epoch,
                e.train_loss,
                e.train_acc,
                e.val_loss,
                e.val_acc,
                e.lr,
                e.bits_mid,
                e.bits_edge,
                e.wall_secs
            )?;
        }
        Ok(())
    }

    pub fn write_jsonl(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        for e in &self.epochs {
            writeln!(f, "{}", e.to_json().render())?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(epoch: usize, val_acc: f64) -> EpochStats {
        EpochStats {
            epoch,
            train_loss: 1.0,
            train_acc: 0.5,
            val_loss: 1.2,
            val_acc,
            lr: 0.1,
            bits_mid: 4.0,
            bits_edge: 6.0,
            wall_secs: 2.0,
        }
    }

    #[test]
    fn summaries() {
        let mut h = RunHistory::new("test");
        h.push(stats(0, 0.5));
        h.push(stats(1, 0.9));
        h.push(stats(2, 0.8));
        assert_eq!(h.final_val_acc(), 0.8);
        assert_eq!(h.best_val_acc(), 0.9);
        assert_eq!(h.total_wall_secs(), 6.0);
    }

    #[test]
    fn csv_and_jsonl_write() {
        let mut h = RunHistory::new("csv");
        h.push(stats(0, 0.4));
        let dir = std::env::temp_dir().join("boosters_test_tracker");
        let path = dir.join("run.csv");
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.starts_with("epoch,"));
        let jl = dir.join("run.jsonl");
        h.write_jsonl(&jl).unwrap();
        let line = std::fs::read_to_string(&jl).unwrap();
        let v = Json::parse(line.trim()).unwrap();
        assert_eq!(v.req("val_acc").unwrap().as_f64().unwrap(), 0.4);
        std::fs::remove_dir_all(&dir).ok();
    }
}
