//! Prometheus-style text exposition of the execution counters.
//!
//! One function, one format: [`render_text`] turns the three exec-layer
//! snapshots ([`ServiceStats`], [`CacheStats`], [`ArenaStats`]) plus any
//! caller-supplied counter pairs (the fabric's runner/router counters)
//! into the Prometheus text format, `# TYPE` line per metric, every
//! value an integer. Served by fabric runners on their socket
//! (`MetricsRequest` → `MetricsText`), dumped locally by
//! `repro metrics`, and scrapable as-is if a user points an agent at
//! either.
//!
//! The exact output shape is a **pinned contract**
//! (`format_is_pinned` below): dashboards and the CI assertions parse
//! it line-by-line, so changing a name or the ordering is a breaking
//! change to make deliberately, with the test, not by accident.

use crate::exec::{ArenaStats, CacheStats, ServiceStats};
use std::fmt::Write as _;

/// Every metric name carries this prefix; the paper-repro repo is the
/// "boosters" namespace everywhere else (env knobs, artifacts).
const PREFIX: &str = "boosters_";

fn push(out: &mut String, name: &str, kind: &str, value: u64) {
    let _ = writeln!(out, "# TYPE {PREFIX}{name} {kind}");
    let _ = writeln!(out, "{PREFIX}{name} {value}");
}

/// Render the standard exec-layer counters plus `extra` pairs (already
/// fully named, e.g. `fabric_runner_ops_total`) as Prometheus text.
/// Counters are cumulative for the process; gauges are instantaneous.
pub fn render_text(
    service: &ServiceStats,
    cache: &CacheStats,
    arena: &ArenaStats,
    extra: &[(&str, u64)],
) -> String {
    let mut out = String::new();
    // Kernel identity travels as a label on a constant gauge — the
    // Prometheus idiom for build/config info.
    let _ = writeln!(out, "# TYPE {PREFIX}exec_kernel_info gauge");
    let _ = writeln!(out, "{PREFIX}exec_kernel_info{{kernel=\"{}\"}} 1", service.kernel);
    push(&mut out, "exec_submitted_total", "counter", service.submitted);
    push(&mut out, "exec_completed_total", "counter", service.completed);
    push(&mut out, "exec_failed_total", "counter", service.failed);
    push(&mut out, "exec_rejected_total", "counter", service.rejected);
    push(
        &mut out,
        "exec_deadline_missed_total",
        "counter",
        service.deadline_missed,
    );
    push(&mut out, "exec_batches_total", "counter", service.batches);
    push(&mut out, "exec_queue_depth", "gauge", service.queue_depth as u64);
    push(
        &mut out,
        "exec_queue_depth_peak",
        "gauge",
        service.peak_queue_depth as u64,
    );
    push(
        &mut out,
        "exec_effective_batch_macs",
        "gauge",
        service.effective_batch_macs,
    );
    push(&mut out, "exec_pre_encoded_total", "counter", service.pre_encoded);
    push(
        &mut out,
        "exec_inline_encoded_total",
        "counter",
        service.inline_encoded,
    );
    push(&mut out, "exec_encode_us_total", "counter", service.encode_us);
    push(
        &mut out,
        "exec_pre_encode_resident_bytes",
        "gauge",
        service.pre_encode_resident_bytes,
    );
    push(&mut out, "exec_decode_ops_total", "counter", service.decode_ops);
    push(
        &mut out,
        "exec_decode_overlapped_total",
        "counter",
        service.decoded_overlapped,
    );
    push(&mut out, "exec_decode_us_total", "counter", service.decode_us);
    push(&mut out, "exec_grouped_ops_total", "counter", service.grouped_ops);
    push(
        &mut out,
        "exec_ungrouped_ops_total",
        "counter",
        service.ungrouped_ops,
    );
    push(
        &mut out,
        "exec_groups_formed_total",
        "counter",
        service.groups_formed,
    );
    push(
        &mut out,
        "exec_weight_plane_loads_avoided_bytes",
        "counter",
        service.weight_plane_loads_avoided,
    );
    push(&mut out, "cache_hits_total", "counter", cache.hits);
    push(&mut out, "cache_misses_total", "counter", cache.misses);
    push(&mut out, "cache_evictions_total", "counter", cache.evictions);
    push(&mut out, "cache_entries", "gauge", cache.entries as u64);
    push(&mut out, "cache_bytes", "gauge", cache.bytes as u64);
    push(&mut out, "arena_hits_total", "counter", arena.hits);
    push(&mut out, "arena_misses_total", "counter", arena.misses);
    push(
        &mut out,
        "arena_recycled_bytes_total",
        "counter",
        arena.recycled_bytes,
    );
    push(&mut out, "arena_resident_bytes", "gauge", arena.resident_bytes);
    push(&mut out, "arena_cap_bytes", "gauge", arena.cap_bytes);
    for (name, value) in extra {
        // Caller-supplied counters are monotonic by convention (every
        // fabric counter is); anything instantaneous belongs in the
        // fixed section above where its type is explicit.
        push(&mut out, name, "counter", *value);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::kernels::KernelOpCounts;

    fn fixed_service() -> ServiceStats {
        ServiceStats {
            submitted: 10,
            completed: 8,
            failed: 1,
            rejected: 1,
            deadline_missed: 2,
            batches: 4,
            queue_depth: 3,
            peak_queue_depth: 5,
            effective_batch_macs: 1 << 20,
            pre_encoded: 6,
            inline_encoded: 2,
            encode_us: 1234,
            kernel: "scalar",
            kernel_ops: KernelOpCounts::default(),
            pre_encode_resident_bytes: 4096,
            decode_ops: 8,
            decoded_overlapped: 5,
            decode_us: 321,
            grouped_ops: 6,
            ungrouped_ops: 2,
            groups_formed: 2,
            weight_plane_loads_avoided: 8192,
            arena_hits: 7,
            arena_misses: 1,
            arena_recycled_bytes: 2048,
            arena_resident_bytes: 1024,
        }
    }

    #[test]
    fn format_is_pinned() {
        let cache = CacheStats {
            hits: 9,
            misses: 3,
            evictions: 1,
            entries: 2,
            bytes: 512,
        };
        let arena = ArenaStats {
            hits: 7,
            misses: 1,
            recycled_bytes: 2048,
            resident_bytes: 1024,
            cap_bytes: 1 << 20,
        };
        let text = render_text(
            &fixed_service(),
            &cache,
            &arena,
            &[("fabric_runner_ops_total", 42)],
        );
        let expected = "\
# TYPE boosters_exec_kernel_info gauge
boosters_exec_kernel_info{kernel=\"scalar\"} 1
# TYPE boosters_exec_submitted_total counter
boosters_exec_submitted_total 10
# TYPE boosters_exec_completed_total counter
boosters_exec_completed_total 8
# TYPE boosters_exec_failed_total counter
boosters_exec_failed_total 1
# TYPE boosters_exec_rejected_total counter
boosters_exec_rejected_total 1
# TYPE boosters_exec_deadline_missed_total counter
boosters_exec_deadline_missed_total 2
# TYPE boosters_exec_batches_total counter
boosters_exec_batches_total 4
# TYPE boosters_exec_queue_depth gauge
boosters_exec_queue_depth 3
# TYPE boosters_exec_queue_depth_peak gauge
boosters_exec_queue_depth_peak 5
# TYPE boosters_exec_effective_batch_macs gauge
boosters_exec_effective_batch_macs 1048576
# TYPE boosters_exec_pre_encoded_total counter
boosters_exec_pre_encoded_total 6
# TYPE boosters_exec_inline_encoded_total counter
boosters_exec_inline_encoded_total 2
# TYPE boosters_exec_encode_us_total counter
boosters_exec_encode_us_total 1234
# TYPE boosters_exec_pre_encode_resident_bytes gauge
boosters_exec_pre_encode_resident_bytes 4096
# TYPE boosters_exec_decode_ops_total counter
boosters_exec_decode_ops_total 8
# TYPE boosters_exec_decode_overlapped_total counter
boosters_exec_decode_overlapped_total 5
# TYPE boosters_exec_decode_us_total counter
boosters_exec_decode_us_total 321
# TYPE boosters_exec_grouped_ops_total counter
boosters_exec_grouped_ops_total 6
# TYPE boosters_exec_ungrouped_ops_total counter
boosters_exec_ungrouped_ops_total 2
# TYPE boosters_exec_groups_formed_total counter
boosters_exec_groups_formed_total 2
# TYPE boosters_exec_weight_plane_loads_avoided_bytes counter
boosters_exec_weight_plane_loads_avoided_bytes 8192
# TYPE boosters_cache_hits_total counter
boosters_cache_hits_total 9
# TYPE boosters_cache_misses_total counter
boosters_cache_misses_total 3
# TYPE boosters_cache_evictions_total counter
boosters_cache_evictions_total 1
# TYPE boosters_cache_entries gauge
boosters_cache_entries 2
# TYPE boosters_cache_bytes gauge
boosters_cache_bytes 512
# TYPE boosters_arena_hits_total counter
boosters_arena_hits_total 7
# TYPE boosters_arena_misses_total counter
boosters_arena_misses_total 1
# TYPE boosters_arena_recycled_bytes_total counter
boosters_arena_recycled_bytes_total 2048
# TYPE boosters_arena_resident_bytes gauge
boosters_arena_resident_bytes 1024
# TYPE boosters_arena_cap_bytes gauge
boosters_arena_cap_bytes 1048576
# TYPE boosters_fabric_runner_ops_total counter
boosters_fabric_runner_ops_total 42
";
        assert_eq!(text, expected);
    }

    #[test]
    fn extra_counters_append_in_caller_order() {
        let cache = CacheStats {
            hits: 0,
            misses: 0,
            evictions: 0,
            entries: 0,
            bytes: 0,
        };
        let arena = ArenaStats {
            hits: 0,
            misses: 0,
            recycled_bytes: 0,
            resident_bytes: 0,
            cap_bytes: 0,
        };
        let text = render_text(
            &fixed_service(),
            &cache,
            &arena,
            &[("b_second", 2), ("a_first", 1)],
        );
        let b = text.find("boosters_b_second 2").expect("b_second rendered");
        let a = text.find("boosters_a_first 1").expect("a_first rendered");
        assert!(b < a, "extras must keep caller order, not sort");
    }
}
