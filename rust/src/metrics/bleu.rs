//! Corpus BLEU-4: modified n-gram precision with clipping, geometric mean,
//! brevity penalty — the standard Papineni et al. definition used to score
//! the Table-3 translation runs. Token sequences are i32 ids; generation
//! stops at the first EOS.

use std::collections::HashMap;

/// n-gram multiset of a token sequence.
pub fn sentence_ngrams(tokens: &[i32], n: usize) -> HashMap<&[i32], usize> {
    let mut map: HashMap<&[i32], usize> = HashMap::new();
    if tokens.len() >= n {
        for w in tokens.windows(n) {
            *map.entry(w).or_insert(0) += 1;
        }
    }
    map
}

#[derive(Debug, Clone, Copy)]
pub struct BleuScore {
    pub bleu: f64,
    pub precisions: [f64; 4],
    pub brevity_penalty: f64,
    pub hyp_len: usize,
    pub ref_len: usize,
}

/// Corpus-level BLEU-4 with smoothing epsilon for empty n-gram buckets
/// (method-1 style: counts of 0 contribute exp-average over available
/// orders only when sequences are shorter than 4).
pub fn corpus_bleu(hyps: &[Vec<i32>], refs: &[Vec<i32>], eos: Option<i32>) -> BleuScore {
    assert_eq!(hyps.len(), refs.len(), "hyp/ref count mismatch");
    let trim = |s: &[i32]| -> Vec<i32> {
        match eos {
            Some(e) => s.iter().take_while(|&&t| t != e).copied().collect(),
            None => s.to_vec(),
        }
    };
    let mut match_counts = [0usize; 4];
    let mut total_counts = [0usize; 4];
    let mut hyp_len = 0usize;
    let mut ref_len = 0usize;
    for (h, r) in hyps.iter().zip(refs) {
        let h = trim(h);
        let r = trim(r);
        hyp_len += h.len();
        ref_len += r.len();
        for n in 1..=4 {
            let hg = sentence_ngrams(&h, n);
            let rg = sentence_ngrams(&r, n);
            for (gram, &c) in &hg {
                let rc = rg.get(gram).copied().unwrap_or(0);
                match_counts[n - 1] += c.min(rc);
            }
            total_counts[n - 1] += h.len().saturating_sub(n - 1);
        }
    }
    let mut precisions = [0.0f64; 4];
    let mut log_sum = 0.0;
    let mut orders = 0;
    for n in 0..4 {
        if total_counts[n] == 0 {
            precisions[n] = 0.0;
            continue;
        }
        precisions[n] = match_counts[n] as f64 / total_counts[n] as f64;
        orders += 1;
        // epsilon-smooth zero precisions so one empty bucket doesn't zero
        // the whole corpus score.
        log_sum += precisions[n].max(1e-9).ln();
    }
    let geo = if orders > 0 {
        (log_sum / orders as f64).exp()
    } else {
        0.0
    };
    let bp = if hyp_len >= ref_len || hyp_len == 0 {
        1.0
    } else {
        (1.0 - ref_len as f64 / hyp_len as f64).exp()
    };
    BleuScore {
        bleu: 100.0 * bp * geo,
        precisions,
        brevity_penalty: bp,
        hyp_len,
        ref_len,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_match_is_100() {
        let refs = vec![vec![1, 2, 3, 4, 5], vec![7, 8, 9, 10]];
        let s = corpus_bleu(&refs, &refs, None);
        assert!((s.bleu - 100.0).abs() < 1e-9, "{}", s.bleu);
        assert_eq!(s.brevity_penalty, 1.0);
    }

    #[test]
    fn disjoint_is_zero_ish() {
        let hyp = vec![vec![1, 1, 1, 1, 1]];
        let refs = vec![vec![2, 3, 4, 5, 6]];
        let s = corpus_bleu(&hyp, &refs, None);
        assert!(s.bleu < 1e-3, "{}", s.bleu);
    }

    #[test]
    fn brevity_penalty_kicks_in() {
        let hyp = vec![vec![1, 2]];
        let refs = vec![vec![1, 2, 3, 4, 5, 6]];
        let s = corpus_bleu(&hyp, &refs, None);
        assert!(s.brevity_penalty < 1.0);
        let long_hyp = vec![vec![1, 2, 3, 4, 5, 6]];
        let s2 = corpus_bleu(&long_hyp, &refs, None);
        assert_eq!(s2.brevity_penalty, 1.0);
    }

    #[test]
    fn clipping_limits_repeats() {
        // "the the the the" against a ref with a single "the".
        let hyp = vec![vec![9, 9, 9, 9]];
        let refs = vec![vec![9, 1, 2, 3]];
        let s = corpus_bleu(&hyp, &refs, None);
        assert!((s.precisions[0] - 0.25).abs() < 1e-12, "{:?}", s.precisions);
    }

    #[test]
    fn eos_trimming() {
        let hyp = vec![vec![1, 2, 3, 99, 7, 7, 7]];
        let refs = vec![vec![1, 2, 3, 99]];
        let s = corpus_bleu(&hyp, &refs, Some(99));
        assert!((s.bleu - 100.0).abs() < 1e-9, "{}", s.bleu);
    }

    #[test]
    fn partial_overlap_between_zero_and_hundred() {
        // One wrong token in eight — some 4-grams still match, so the
        // score sits strictly between 0 and 100.
        let hyp = vec![vec![1, 2, 3, 4, 5, 9, 7, 8]];
        let refs = vec![vec![1, 2, 3, 4, 5, 6, 7, 8]];
        let s = corpus_bleu(&hyp, &refs, None);
        assert!(s.bleu > 5.0 && s.bleu < 95.0, "{}", s.bleu);
        assert!(s.precisions[3] > 0.0);
    }
}
