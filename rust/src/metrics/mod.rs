//! Metrics: BLEU-4 (Table 3), Wasserstein-1 distance (Fig 1), accuracy /
//! loss tracking (Fig 3/4), and the R² association check from §3.

pub mod bleu;
pub mod stats;
pub mod tracker;
pub mod wasserstein;

pub use bleu::{corpus_bleu, sentence_ngrams, BleuScore};
pub use stats::{pearson_r, r_squared};
pub use tracker::{EpochStats, RunHistory};
pub use wasserstein::{wasserstein1, wasserstein1_quantized, QuantSweep};
