//! Metrics: BLEU-4 (Table 3), Wasserstein-1 distance (Fig 1), accuracy /
//! loss tracking (Fig 3/4), the R² association check from §3, and the
//! execution-runtime counters (operand-cache hits/misses).

pub mod bleu;
pub mod stats;
pub mod tracker;
pub mod wasserstein;

pub use bleu::{corpus_bleu, sentence_ngrams, BleuScore};
pub use stats::{pearson_r, r_squared};
pub use tracker::{EpochStats, RunHistory};
pub use wasserstein::{wasserstein1, wasserstein1_quantized, QuantSweep};

// The operand-cache counter snapshot is a metrics surface: experiment
// drivers and serve-sim print it next to their accuracy/latency numbers.
pub use crate::exec::CacheStats;

/// Snapshot of the **global** execution runtime's encoded-operand cache
/// counters (hits, misses, evictions, residency). Counters are
/// cumulative for the process; sample before/after a phase to attribute
/// traffic to it.
pub fn exec_cache_snapshot() -> CacheStats {
    crate::exec::global().cache_stats()
}
