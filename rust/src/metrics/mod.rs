//! Metrics: BLEU-4 (Table 3), Wasserstein-1 distance (Fig 1), accuracy /
//! loss tracking (Fig 3/4), the R² association check from §3, and the
//! execution-service counters (operand-cache hits/misses, admission
//! queue depth, deadline misses).

pub mod bleu;
pub mod export;
pub mod stats;
pub mod tracker;
pub mod wasserstein;

pub use bleu::{corpus_bleu, sentence_ngrams, BleuScore};
pub use export::render_text;
pub use stats::{pearson_r, r_squared};
pub use tracker::{EpochStats, RunHistory};
pub use wasserstein::{wasserstein1, wasserstein1_quantized, QuantSweep};

// The execution-service counter snapshots are a metrics surface:
// experiment drivers and serve-sim print them next to their
// accuracy/latency numbers.
pub use crate::exec::{ArenaStats, CacheStats, ServiceStats};

/// Snapshot of the **global** execution runtime's buffer-arena counters
/// (checkout hits/misses, cumulative recycled bytes, resident bytes vs
/// the `BOOSTERS_ARENA_MB` cap). Cumulative for the process; sample
/// before/after a phase to attribute traffic to it. The same numbers
/// ride along in [`exec_service_snapshot`] for the service's runtime.
pub fn exec_arena_snapshot() -> ArenaStats {
    crate::exec::global().arena_stats()
}

/// Snapshot of the **global** execution runtime's encoded-operand cache
/// counters (hits, misses, evictions, residency). Counters are
/// cumulative for the process; sample before/after a phase to attribute
/// traffic to it.
pub fn exec_cache_snapshot() -> CacheStats {
    crate::exec::global().cache_stats()
}

/// Snapshot of the **global** [`crate::exec::BfpService`] admission
/// counters (submitted/completed/rejected, deadline misses, queue
/// depth + high-water mark), the effective adaptive batch-MAC budget
/// of the most recent batch, the GEMM kernel backend identity the
/// service executes with (plus per-backend/per-bucket counts of which
/// kernel **actually** ran each op), and the encode-pipeline counters
/// (ops pre-encoded at admission time vs encoded inline at execution,
/// resident pre-encoded bytes under the `BOOSTERS_PREENCODE_MB`
/// budget, plus cumulative encode-stage latency — see
/// [`crate::exec::ServiceStats::pre_encode_hit_rate`]), the
/// decode-stage counters (ops decoded, ops whose decode overlapped a
/// later batch's execution, cumulative decode latency), and the
/// buffer-arena counters (hits/misses, recycled and resident bytes).
/// Cumulative for the process; sample before/after a phase to
/// attribute traffic to it. First use instantiates the service.
pub fn exec_service_snapshot() -> ServiceStats {
    crate::exec::global_service().stats()
}
