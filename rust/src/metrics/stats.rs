//! Small statistics helpers: Pearson r and R² (the §3 claim that
//! Wasserstein distance and model accuracy have R² ≈ 0.99).

/// Pearson correlation coefficient.
pub fn pearson_r(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.len() < 2 {
        return 0.0;
    }
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&a, &b) in x.iter().zip(y) {
        sxy += (a - mx) * (b - my);
        sxx += (a - mx) * (a - mx);
        syy += (b - my) * (b - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Coefficient of determination of the linear fit y ~ x.
pub fn r_squared(x: &[f64], y: &[f64]) -> f64 {
    let r = pearson_r(x, y);
    r * r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_linear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [2.0, 4.0, 6.0, 8.0];
        assert!((pearson_r(&x, &y) - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0, -8.0];
        assert!((pearson_r(&x, &neg) + 1.0).abs() < 1e-12);
        assert!((r_squared(&x, &neg) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_series_is_zero() {
        assert_eq!(pearson_r(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
    }

    #[test]
    fn noisy_linear_high_r2() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v + ((v * 7.3).sin())).collect();
        assert!(r_squared(&x, &y) > 0.99);
    }
}
