//! Wasserstein-1 distance between empirical 1-D distributions (§3, Fig 1).
//!
//! For two samples of equal size n, W1 reduces to the mean absolute
//! difference of the sorted samples; for unequal sizes we integrate the
//! quantile-function difference over a common grid. The Fig-1 use case —
//! a tensor vs its quantized self — is always the equal-size fast path.

use crate::bfp::{quantize_flat, Quantizer};

/// W1 between two equal-length samples: mean |sort(a) - sort(b)|.
pub fn wasserstein1(a: &[f32], b: &[f32]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty(), "empty sample");
    if a.len() == b.len() {
        let mut sa: Vec<f32> = a.to_vec();
        let mut sb: Vec<f32> = b.to_vec();
        sa.sort_by(f32::total_cmp);
        sb.sort_by(f32::total_cmp);
        sa.iter()
            .zip(&sb)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .sum::<f64>()
            / a.len() as f64
    } else {
        // Quantile integration on the union grid.
        let mut sa: Vec<f32> = a.to_vec();
        let mut sb: Vec<f32> = b.to_vec();
        sa.sort_by(f32::total_cmp);
        sb.sort_by(f32::total_cmp);
        let grid = 4096;
        (0..grid)
            .map(|i| {
                let q = (i as f64 + 0.5) / grid as f64;
                (quantile(&sa, q) - quantile(&sb, q)).abs()
            })
            .sum::<f64>()
            / grid as f64
    }
}

fn quantile(sorted: &[f32], q: f64) -> f64 {
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

/// The Fig-1 measurement: W1 between a tensor and its HBFP(m, b)
/// quantization (nearest rounding, the forward-pass transform).
pub fn wasserstein1_quantized(t: &[f32], m_bits: u32, block: usize) -> f64 {
    let q = quantize_flat(t, block, Quantizer::nearest(m_bits), 0);
    wasserstein1(t, &q)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn identical_distributions_are_zero() {
        let x = randn(500, 1);
        assert_eq!(wasserstein1(&x, &x), 0.0);
    }

    #[test]
    fn shift_equals_offset() {
        // W1 between X and X + c is exactly |c|.
        let x = randn(1000, 2);
        let y: Vec<f32> = x.iter().map(|v| v + 0.75).collect();
        assert!((wasserstein1(&x, &y) - 0.75).abs() < 1e-5);
    }

    #[test]
    fn symmetric() {
        let x = randn(300, 3);
        let y = randn(300, 4);
        assert!((wasserstein1(&x, &y) - wasserstein1(&y, &x)).abs() < 1e-12);
    }

    #[test]
    fn unequal_sizes_consistent() {
        let x = randn(512, 5);
        let y: Vec<f32> = x.iter().map(|v| v + 0.5).collect();
        let w = wasserstein1(&x, &y[..256]);
        assert!((w - 0.5).abs() < 0.1, "{w}");
    }

    #[test]
    fn hbfp4_more_distorted_than_hbfp6() {
        // The Fig-1 headline: W(HBFP4) ≈ 3-4x W(HBFP6), growing with b.
        let x = randn(4096, 6);
        let w6 = wasserstein1_quantized(&x, 6, 64);
        let w4 = wasserstein1_quantized(&x, 4, 64);
        assert!(w4 > 2.0 * w6, "w4={w4} w6={w6}");
        let w4_small = wasserstein1_quantized(&x, 4, 16);
        let w4_big = wasserstein1_quantized(&x, 4, 576);
        assert!(w4_big > w4_small, "w4@576={w4_big} w4@16={w4_small}");
        // HBFP6 is ~flat across block sizes.
        let w6_big = wasserstein1_quantized(&x, 6, 576);
        assert!(w6_big < 2.0 * w6 + 1e-9, "w6@576={w6_big} w6@64={w6}");
    }
}
