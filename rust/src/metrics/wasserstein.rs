//! Wasserstein-1 distance between empirical 1-D distributions (§3, Fig 1).
//!
//! For two samples of equal size n, W1 reduces to the mean absolute
//! difference of the sorted samples; for unequal sizes we integrate the
//! quantile-function difference over a common grid. The Fig-1 use case —
//! a tensor vs its quantized self — is always the equal-size fast path.

use crate::bfp::{quantize_packed_into, BfpMatrix, Quantizer};

/// W1 between two equal-length samples: mean |sort(a) - sort(b)|.
pub fn wasserstein1(a: &[f32], b: &[f32]) -> f64 {
    assert!(!a.is_empty() && !b.is_empty(), "empty sample");
    if a.len() == b.len() {
        let mut sa: Vec<f32> = a.to_vec();
        let mut sb: Vec<f32> = b.to_vec();
        sa.sort_by(f32::total_cmp);
        sb.sort_by(f32::total_cmp);
        sa.iter()
            .zip(&sb)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .sum::<f64>()
            / a.len() as f64
    } else {
        // Quantile integration on the union grid.
        let mut sa: Vec<f32> = a.to_vec();
        let mut sb: Vec<f32> = b.to_vec();
        sa.sort_by(f32::total_cmp);
        sb.sort_by(f32::total_cmp);
        let grid = 4096;
        (0..grid)
            .map(|i| {
                let q = (i as f64 + 0.5) / grid as f64;
                (quantile(&sa, q) - quantile(&sb, q)).abs()
            })
            .sum::<f64>()
            / grid as f64
    }
}

fn quantile(sorted: &[f32], q: f64) -> f64 {
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] as f64 * (1.0 - frac) + sorted[hi] as f64 * frac
}

/// Reusable buffers for quantization-distance sweeps: one packed BFP
/// carrier, one decode buffer, and a cached **sorted** copy of the
/// reference tensor. A Fig-1 sweep quantizes the same layer at many
/// `(m, b)` points; with the reference sorted once per layer
/// ([`QuantSweep::set_reference`]) each point costs one packed
/// round-trip plus one sort of the quantized sample — not two sorts
/// and four allocations. The round-trip itself runs on the
/// [`crate::exec`] worker pool for large layers (parallel block
/// encode, bit-identical to serial), so sweep wall-time scales with
/// the machine.
#[derive(Debug, Default)]
pub struct QuantSweep {
    packed: BfpMatrix,
    qbuf: Vec<f32>,
    sorted_ref: Vec<f32>,
    sorted_q: Vec<f32>,
}

impl QuantSweep {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sort and cache the reference sample for subsequent
    /// [`QuantSweep::distance_to_reference`] calls.
    pub fn set_reference(&mut self, t: &[f32]) {
        assert!(!t.is_empty(), "empty sample");
        self.sorted_ref.clear();
        self.sorted_ref.extend_from_slice(t);
        self.sorted_ref.sort_by(f32::total_cmp);
    }

    /// W1 between the cached reference and `t`'s HBFP(m, b)
    /// quantization (nearest rounding, the forward-pass transform),
    /// through the packed carrier. `t` must be the tensor last passed
    /// to [`QuantSweep::set_reference`]; same arithmetic (and bits) as
    /// [`wasserstein1`]'s equal-size path.
    pub fn distance_to_reference(&mut self, t: &[f32], m_bits: u32, block: usize) -> f64 {
        debug_assert_eq!(t.len(), self.sorted_ref.len(), "reference not set for this tensor");
        quantize_packed_into(
            t,
            block,
            Quantizer::nearest(m_bits),
            0,
            &mut self.packed,
            &mut self.qbuf,
        )
        .expect("nearest quantization of an f32 tensor cannot fail");
        self.sorted_q.clear();
        self.sorted_q.extend_from_slice(&self.qbuf);
        self.sorted_q.sort_by(f32::total_cmp);
        self.sorted_ref
            .iter()
            .zip(&self.sorted_q)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .sum::<f64>()
            / self.sorted_ref.len() as f64
    }

    /// One-shot W1 between `t` and its HBFP(m, b) quantization
    /// (sets the reference itself).
    pub fn distance(&mut self, t: &[f32], m_bits: u32, block: usize) -> f64 {
        self.set_reference(t);
        self.distance_to_reference(t, m_bits, block)
    }
}

/// The Fig-1 measurement: W1 between a tensor and its HBFP(m, b)
/// quantization. One-shot convenience over [`QuantSweep`].
pub fn wasserstein1_quantized(t: &[f32], m_bits: u32, block: usize) -> f64 {
    QuantSweep::new().distance(t, m_bits, block)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn identical_distributions_are_zero() {
        let x = randn(500, 1);
        assert_eq!(wasserstein1(&x, &x), 0.0);
    }

    #[test]
    fn shift_equals_offset() {
        // W1 between X and X + c is exactly |c|.
        let x = randn(1000, 2);
        let y: Vec<f32> = x.iter().map(|v| v + 0.75).collect();
        assert!((wasserstein1(&x, &y) - 0.75).abs() < 1e-5);
    }

    #[test]
    fn symmetric() {
        let x = randn(300, 3);
        let y = randn(300, 4);
        assert!((wasserstein1(&x, &y) - wasserstein1(&y, &x)).abs() < 1e-12);
    }

    #[test]
    fn unequal_sizes_consistent() {
        let x = randn(512, 5);
        let y: Vec<f32> = x.iter().map(|v| v + 0.5).collect();
        let w = wasserstein1(&x, &y[..256]);
        assert!((w - 0.5).abs() < 0.1, "{w}");
    }

    #[test]
    fn sweep_buffers_reproduce_one_shot_distances() {
        let x = randn(2048, 9);
        let mut sweep = QuantSweep::new();
        sweep.set_reference(&x);
        for m in [4u32, 6, 12] {
            for b in [16usize, 64, 576] {
                // Cached-reference path == one-shot path == the plain
                // quantize-then-wasserstein1 composition, to the bit.
                let cached = sweep.distance_to_reference(&x, m, b);
                let want = wasserstein1_quantized(&x, m, b);
                assert_eq!(cached.to_bits(), want.to_bits(), "m={m} b={b}");
                let q = crate::bfp::quantize_packed(&x, b, Quantizer::nearest(m), 0);
                let composed = wasserstein1(&x, &q);
                assert_eq!(cached.to_bits(), composed.to_bits(), "m={m} b={b}");
            }
        }
    }

    #[test]
    fn hbfp4_more_distorted_than_hbfp6() {
        // The Fig-1 headline: W(HBFP4) ≈ 3-4x W(HBFP6), growing with b.
        let x = randn(4096, 6);
        let w6 = wasserstein1_quantized(&x, 6, 64);
        let w4 = wasserstein1_quantized(&x, 4, 64);
        assert!(w4 > 2.0 * w6, "w4={w4} w6={w6}");
        let w4_small = wasserstein1_quantized(&x, 4, 16);
        let w4_big = wasserstein1_quantized(&x, 4, 576);
        assert!(w4_big > w4_small, "w4@576={w4_big} w4@16={w4_small}");
        // HBFP6 is ~flat across block sizes.
        let w6_big = wasserstein1_quantized(&x, 6, 576);
        assert!(w6_big < 2.0 * w6 + 1e-9, "w6@576={w6_big} w6@64={w6}");
    }
}
