//! # accuracy-boosters
//!
//! Rust + JAX/Pallas reproduction of *"Accuracy Boosters: Epoch-Driven
//! Mixed-Mantissa Block Floating Point for DNN Training"* (Harma et al.).
//!
//! The crate is the **L3 coordinator** of a three-layer stack
//! (see DESIGN.md):
//!
//! * [`runtime`] — loads AOT-compiled XLA artifacts (HLO text produced by
//!   `python/compile/aot.py`) and executes them on a PJRT CPU client.
//!   Python never runs on the training path.
//! * [`coordinator`] — the paper's contribution as a system: the training
//!   orchestrator whose [`coordinator::PrecisionScheduler`] flips mantissa
//!   widths per epoch and per layer-class (the Accuracy Booster schedule)
//!   by feeding runtime scalars into the compiled step function.
//! * [`bfp`] — a from-scratch software Block-Floating-Point substrate,
//!   bit-exact against the python oracle (golden-vector tested), used for
//!   host-side analysis (Fig 1) and as the quantizer reference.
//!
//!   Its production datapath is the **packed tensor engine**
//!   ([`bfp::BfpMatrix`]): tensors live as two contiguous
//!   structure-of-arrays planes — a mantissa plane whose storage is
//!   chosen by [`bfp::BlockFormat::plane_layout`] (nibble-packed 4-bit
//!   pairs for the paper's m <= 4 formats, `i8`/`i16` otherwise; rows
//!   padded to whole blocks, stride `blocks_per_row * block_size`) and
//!   one `i32` shared exponent per block. Values decode as
//!   `q * 2^scale_shift(e, m)` with `scale_shift(e, m) = e - m + 2`
//!   ([`bfp::scale_shift`]). Operands are encoded once and multiplied
//!   by a cache-tiled, register-blocked fixed-point GEMM
//!   ([`bfp::gemm`]) whose micro-kernel comes from the
//!   [`bfp::kernels`] registry — portable scalar, unrolled autovec,
//!   and runtime-detected AVX2 backends, selected per operand layout
//!   pair (override: `BOOSTERS_KERNEL`) — parallelized over whole
//!   output-row bands. Every backend and any band partitioning is
//!   bit-identical to the serial and scalar reference paths
//!   (property-tested per backend), so every analysis, sweep, and
//!   host-emulation consumer sees one set of numerics at
//!   bandwidth-bound speed.
//! * [`exec`] — the **execution service** those kernels run on. Its
//!   front door is [`exec::BfpService`]: non-blocking
//!   `submit(GemmRequest) -> Ticket` over owned ops
//!   ([`exec::OwnedGemmOp`]), per-request QoS (deadline + priority
//!   class), a bounded admission queue whose overflow is the typed
//!   [`exec::AdmissionError`] backpressure signal, and a dedicated
//!   scheduler thread forming earliest-deadline-first, MAC-budgeted
//!   batches. Underneath sit the persistent worker pool (spawned once,
//!   sized by `BOOSTERS_GEMM_THREADS` / `available_parallelism`), the
//!   content-addressed encoded-operand cache (caps via
//!   `BOOSTERS_CACHE_ENTRIES` / `BOOSTERS_CACHE_MB`, counters in
//!   [`metrics`]), and the [`exec::BatchGemm`] execution stage (its
//!   blocking `run` kept as a thin synchronous facade). Admission
//!   order reorders execution, never accumulation: responses stay
//!   bit-identical to the scalar reference across thread counts,
//!   deadlines, and arrival orders. `repro serve-sim` replays a
//!   synthetic mixed-size request stream through it, open-loop
//!   (Poisson arrivals, deadline-miss accounting) in `--async` mode.
//! * [`hw_model`] — the paper's gate-level analytic silicon-area model
//!   (Appendix F): FP32 / BFloat16 / HBFP dot-product units, converters,
//!   stochastic-rounding XORshift circuits; regenerates Fig 6 and the
//!   area-gain columns of Table 1 exactly.
//! * [`fabric`] — the **multi-node execution fabric** over [`exec`]:
//!   `repro fabric-runner` hosts a [`exec::BfpService`] behind a TCP
//!   socket speaking a versioned length-prefixed frame protocol
//!   ([`fabric::wire`]), and [`fabric::FabricRouter`] re-offers the
//!   submit/ticket surface over N runners — sharding by deadline slack
//!   × per-runner outstanding-MAC budget, shipping weight operands as
//!   encoded BFP planes deduplicated by the shared 128-bit content
//!   digest ([`util::digest`], at most one transfer per distinct
//!   weight per runner), and failing in-flight ops over to surviving
//!   runners bit-identically (ops are pure). `repro serve-sim
//!   --fabric N` drives a local fleet and emits `BENCH_fabric.json`.
//! * [`registry`] — the **content-addressed encoded-weight registry**:
//!   checkpoints as digest-addressed blobs of already-encoded
//!   [`bfp::BfpMatrix`] planes under a versioned JSON manifest, keyed
//!   by the same [`util::digest`] fingerprint the operand cache and
//!   the fabric speak. `repro registry push` dedups blobs by
//!   construction (the mixed-mantissa schedule leaves most layers'
//!   planes unchanged between epochs); warm starts mmap plane bytes
//!   straight into the operand cache / fabric operand store with zero
//!   encode operations and zero f32 touches. `repro serve-sim
//!   --registry DIR` benchmarks cold vs warm start
//!   (`BENCH_registry.json`).
//! * [`data`] — synthetic dataset substrates standing in for CIFAR and
//!   IWSLT (DESIGN.md §3 documents the substitutions).
//! * [`metrics`] — accuracy/loss tracking, BLEU-4, Wasserstein-1, R².
//! * [`analysis`] — loss-landscape (filter-normalized directions) and
//!   Wasserstein sweeps over checkpoints (Fig 1, 2, 5).
//! * [`checkpoint`], [`config`], [`report`] — persistence, experiment
//!   configuration, and paper-layout table/figure rendering.

pub mod analysis;
pub mod bfp;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod experiments;
pub mod fabric;
pub mod hw_model;
pub mod metrics;
pub mod registry;
pub mod report;
pub mod runtime;
pub mod util;

pub use anyhow::Result;
