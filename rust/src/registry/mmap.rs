//! Read-only file mapping for the registry's zero-copy blob loads —
//! **libc-free**: on Linux x86_64/aarch64 the `mmap`/`munmap` syscalls
//! are issued directly via inline assembly (the image links no libc
//! crate), so blob payloads are served straight out of the page cache
//! with no userspace read copy. Everywhere else — and for files whose
//! reported length is zero, which is how `/proc`-style virtual files
//! present themselves and why they cannot be mapped — the shim falls
//! back to one pre-sized buffered read into an owned buffer. Either
//! way the caller sees a `&[u8]` over the whole file.

use std::fs::File;
use std::io::{self, Read};
use std::ops::Deref;
use std::path::Path;

#[cfg(all(
    target_os = "linux",
    any(target_arch = "x86_64", target_arch = "aarch64")
))]
mod sys {
    //! The two raw syscalls the shim needs. Register conventions:
    //! x86_64 passes the number in `rax` and args in
    //! `rdi/rsi/rdx/r10/r8/r9` (the kernel clobbers `rcx`/`r11`);
    //! aarch64 passes the number in `x8` and args in `x0..x5`. Both
    //! return in the first register, with errors as `-errno` in
    //! `[-4095, -1]`.

    const PROT_READ: usize = 1;
    const MAP_PRIVATE: usize = 2;

    fn ok(ret: isize) -> Option<*const u8> {
        if (-4095..0).contains(&ret) {
            None
        } else {
            Some(ret as *const u8)
        }
    }

    #[cfg(target_arch = "x86_64")]
    pub unsafe fn mmap_readonly(fd: i32, len: usize) -> Option<*const u8> {
        let ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") 9isize => ret, // __NR_mmap
            in("rdi") 0usize,
            in("rsi") len,
            in("rdx") PROT_READ,
            in("r10") MAP_PRIVATE,
            in("r8") fd as isize,
            in("r9") 0usize,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
        ok(ret)
    }

    #[cfg(target_arch = "x86_64")]
    pub unsafe fn munmap(ptr: *const u8, len: usize) {
        let _ret: isize;
        core::arch::asm!(
            "syscall",
            inlateout("rax") 11isize => _ret, // __NR_munmap
            in("rdi") ptr,
            in("rsi") len,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack)
        );
    }

    #[cfg(target_arch = "aarch64")]
    pub unsafe fn mmap_readonly(fd: i32, len: usize) -> Option<*const u8> {
        let ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") 222usize, // __NR_mmap
            inlateout("x0") 0usize => ret,
            in("x1") len,
            in("x2") PROT_READ,
            in("x3") MAP_PRIVATE,
            in("x4") fd as isize,
            in("x5") 0usize,
            options(nostack)
        );
        ok(ret)
    }

    #[cfg(target_arch = "aarch64")]
    pub unsafe fn munmap(ptr: *const u8, len: usize) {
        let _ret: isize;
        core::arch::asm!(
            "svc 0",
            in("x8") 215usize, // __NR_munmap
            inlateout("x0") ptr => _ret,
            in("x1") len,
            options(nostack)
        );
    }
}

enum Backing {
    /// A live read-only mapping; unmapped on drop.
    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    Mapped { ptr: *const u8, len: usize },
    /// The buffered-read fallback (non-Linux targets, zero-length
    /// virtual files, or a refused mapping).
    Owned(Vec<u8>),
}

/// The whole contents of one file, mapped when the platform allows it
/// and owned otherwise. Dereferences to `&[u8]` either way.
pub struct MappedFile {
    backing: Backing,
}

// SAFETY: the mapping is private and read-only; the raw pointer is
// owned by this struct for its whole lifetime and only ever read
// through the `Deref` slice, so moving or sharing the handle across
// threads cannot race anything.
unsafe impl Send for MappedFile {}
unsafe impl Sync for MappedFile {}

impl MappedFile {
    /// True when the bytes are served by a live mapping (no userspace
    /// read copy was made) — surfaced so load stats can attribute the
    /// zero-copy path.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            Backing::Mapped { .. } => true,
            Backing::Owned(_) => false,
        }
    }
}

impl Deref for MappedFile {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.backing {
            #[cfg(all(
                target_os = "linux",
                any(target_arch = "x86_64", target_arch = "aarch64")
            ))]
            // SAFETY: `ptr` is a live PROT_READ mapping of exactly
            // `len` bytes, valid until `munmap` in Drop.
            Backing::Mapped { ptr, len } => unsafe { std::slice::from_raw_parts(*ptr, *len) },
            Backing::Owned(v) => v,
        }
    }
}

impl Drop for MappedFile {
    fn drop(&mut self) {
        #[cfg(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        if let Backing::Mapped { ptr, len } = self.backing {
            // SAFETY: exactly the region mmap_readonly returned.
            unsafe { sys::munmap(ptr, len) };
        }
    }
}

/// Map `path` read-only, falling back to a single pre-sized read when
/// mapping is unavailable or refused (see module docs).
pub fn map_readonly(path: &Path) -> io::Result<MappedFile> {
    let mut file = File::open(path)?;
    let meta_len = file.metadata()?.len();
    #[cfg(all(
        target_os = "linux",
        any(target_arch = "x86_64", target_arch = "aarch64")
    ))]
    {
        use std::os::unix::io::AsRawFd;
        if meta_len > 0 && meta_len <= usize::MAX as u64 {
            let len = meta_len as usize;
            // SAFETY: fd is open for reading; a failed map returns
            // None and drops through to the read fallback.
            if let Some(ptr) = unsafe { sys::mmap_readonly(file.as_raw_fd(), len) } {
                return Ok(MappedFile {
                    backing: Backing::Mapped { ptr, len },
                });
            }
        }
    }
    let mut buf = Vec::with_capacity(meta_len.min(isize::MAX as u64) as usize);
    file.read_to_end(&mut buf)?;
    Ok(MappedFile {
        backing: Backing::Owned(buf),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_path(tag: &str) -> std::path::PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        std::env::temp_dir().join(format!(
            "boosters-mmap-{}-{}-{tag}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    #[test]
    fn mapped_bytes_match_a_plain_read() {
        let path = temp_path("roundtrip");
        let payload: Vec<u8> = (0..100_000u32).map(|i| (i * 31 + 7) as u8).collect();
        std::fs::write(&path, &payload).unwrap();
        let mapped = map_readonly(&path).unwrap();
        assert_eq!(&*mapped, &payload[..]);
        // On Linux CI this exercises the real syscall mapping; the
        // fallback path still satisfies the byte-equality contract.
        #[cfg(all(
            target_os = "linux",
            any(target_arch = "x86_64", target_arch = "aarch64")
        ))]
        assert!(mapped.is_mapped(), "nonempty regular file should map");
        drop(mapped);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_takes_the_read_fallback() {
        let path = temp_path("empty");
        std::fs::write(&path, b"").unwrap();
        let mapped = map_readonly(&path).unwrap();
        assert!(!mapped.is_mapped());
        assert!(mapped.is_empty());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_a_plain_io_error() {
        let err = map_readonly(&temp_path("missing")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::NotFound);
    }
}
