//! Content-addressed registry for **encoded** weights: a digest-blob
//! store that makes checkpoints shareable by identity and makes warm
//! starts skip the encoder entirely.
//!
//! The paper's mixed-mantissa schedule (4-bit body, 6-bit first/last
//! layers and last epoch) leaves most layers' encoded planes unchanged
//! between consecutive checkpoints at a given width. The registry
//! exploits that: every blob is keyed by the 128-bit
//! [`crate::util::digest::Digest`] of the **original f32 tensor** —
//! the same fingerprint the [`crate::exec::OperandCache`] and the
//! fabric operand store use — so `push` stores only blobs whose
//! digest+format is unseen, and a warm start republishes stored planes
//! under the exact [`CacheKey`]/`OperandKey` the hot path will ask for.
//!
//! # On-disk layout
//!
//! ```text
//! <root>/
//!   blobs/<digest-hex>-m<mbits>b<block>.bfpb   one encoded BfpMatrix
//!   manifests/<name>.json                      one named checkpoint
//! ```
//!
//! The digest identifies *content*; the `-m<mbits>b<block>` suffix
//! distinguishes encodings of the same tensor under different
//! [`BlockFormat`]s (the mixed-mantissa schedule stores a layer at
//! 4-bit and 6-bit side by side).
//!
//! # Blob format (`.bfpb`, version 1)
//!
//! A fixed 72-byte self-describing header, then the raw planes:
//!
//! ```text
//! offset  size  field
//!      0     4  magic "BFPR"
//!      4     2  version (u16 LE) = 1
//!      6     1  plane-layout byte: 1 = i4x2, 2 = i8, 3 = i16
//!                 (same mapping the fabric wire protocol uses)
//!      7     1  flags: bit 0 = transposed encode
//!      8     4  mantissa bits (u32 LE)
//!     12     4  block size (u32 LE)
//!     16     8  encoded rows (u64 LE)
//!     24     8  encoded cols (u64 LE)
//!     32     8  mantissa-plane bytes (u64 LE)
//!     40     8  shared-exponent count (u64 LE)
//!     48     8  FNV-1a 64 over the payload (u64 LE)
//!     56    16  f32-content digest (Digest::to_le_bytes)
//!     72     -  payload: mantissa plane bytes, then exponents (i32 LE)
//! ```
//!
//! The payload is the [`BfpMatrix`] storage verbatim — loading slices
//! the plane bytes straight out of a read-only file mapping (see
//! [`mmap`]) with no decode, re-quantization, or f32 round-trip, which
//! is what makes the bit-identity contract structural: a loaded plane
//! is byte-identical to a fresh [`BfpMatrix::encode_transposed`] of
//! the same f32 tensor under the same format, and tests assert it via
//! `PartialEq` on the whole matrix.
//!
//! # Manifest format (`boosters-registry-v1`)
//!
//! ```json
//! {"schema": "boosters-registry-v1", "name": "epoch3",
//!  "layers": [{"name": "fc1", "digest": "<32 hex>", "m_bits": 4,
//!              "block": 64, "layout": "i4x2", "rows": 128, "cols": 96,
//!              "transposed": true, "blob_bytes": 6192}],
//!  "meta": {"note": "..."}}
//! ```
//!
//! `rows`/`cols` are the **f32 source** shape (what the scheduler sees);
//! the blob header carries the encoded shape, and the loader
//! cross-checks the two (a transposed encode of a `k x n` weight is an
//! `n x k` matrix of planes).
//!
//! Failure handling is typed ([`RegistryError`]): corrupt blobs and
//! truncated manifests are rejected with the offending path and a
//! detail string, never a panic or a silently wrong matrix. Writes go
//! through a temp file + rename so a crashed push can never leave a
//! half-written blob under a live digest; `gc` drops unreachable blobs
//! and stale temp files but never a manifest-reachable blob.

pub mod mmap;

use crate::bfp::{BfpMatrix, BlockFormat, Mat, MantissaPlane, PlaneLayout, Quantizer};
use crate::checkpoint::Checkpoint;
use crate::exec::{CacheKey, OperandCache};
use crate::util::digest::{content_fingerprint, Digest};
use crate::util::Json;
use std::collections::{BTreeMap, HashSet};
use std::fmt;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const BLOB_MAGIC: &[u8; 4] = b"BFPR";
const BLOB_VERSION: u16 = 1;
const HEADER_LEN: usize = 72;
const FLAG_TRANSPOSED: u8 = 1;
const MANIFEST_SCHEMA: &str = "boosters-registry-v1";

/// Registry failures, typed so callers (and tests) can tell a corrupt
/// artifact from a missing one from plain I/O.
#[derive(Debug)]
pub enum RegistryError {
    /// Filesystem-level failure on `path`.
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    /// A blob exists but fails structural validation (bad magic,
    /// checksum mismatch, shape/plane-length inconsistency, ...).
    CorruptBlob { path: PathBuf, detail: String },
    /// A manifest is unreadable, truncated, or schema-invalid.
    BadManifest { path: PathBuf, detail: String },
    /// A manifest references a blob the store does not hold.
    MissingBlob {
        digest: Digest,
        m_bits: u32,
        block: usize,
    },
    /// Encoding a pushed layer failed (bad shape / format).
    Encode { layer: String, detail: String },
}

impl fmt::Display for RegistryError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Io { path, source } => write!(f, "registry io on {}: {source}", path.display()),
            Self::CorruptBlob { path, detail } => {
                write!(f, "corrupt blob {}: {detail}", path.display())
            }
            Self::BadManifest { path, detail } => {
                write!(f, "bad manifest {}: {detail}", path.display())
            }
            Self::MissingBlob {
                digest,
                m_bits,
                block,
            } => write!(
                f,
                "missing blob {} (m={m_bits} b={block})",
                digest.to_hex()
            ),
            Self::Encode { layer, detail } => write!(f, "encoding layer {layer:?}: {detail}"),
        }
    }
}

impl std::error::Error for RegistryError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Self::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

pub type Result<T> = std::result::Result<T, RegistryError>;

fn io_err(path: &Path, source: std::io::Error) -> RegistryError {
    RegistryError::Io {
        path: path.to_path_buf(),
        source,
    }
}

/// Plane-layout wire byte — the same mapping the fabric's
/// `wire::layout_byte` uses (kept in lockstep by
/// `tests/property_registry.rs`); a blob written here is probed and
/// transferred by the fabric under the same identity.
fn layout_byte(layout: PlaneLayout) -> u8 {
    match layout {
        PlaneLayout::I4Packed => 1,
        PlaneLayout::I8 => 2,
        PlaneLayout::I16 => 3,
    }
}

fn layout_from_byte(b: u8) -> Option<PlaneLayout> {
    match b {
        1 => Some(PlaneLayout::I4Packed),
        2 => Some(PlaneLayout::I8),
        3 => Some(PlaneLayout::I16),
        _ => None,
    }
}

fn layout_from_label(label: &str) -> Option<PlaneLayout> {
    [PlaneLayout::I4Packed, PlaneLayout::I8, PlaneLayout::I16]
        .into_iter()
        .find(|l| l.label() == label)
}

fn digest_from_hex(hex: &str) -> Option<Digest> {
    if hex.len() != 32 {
        return None;
    }
    let hi = u64::from_str_radix(&hex[..16], 16).ok()?;
    let lo = u64::from_str_radix(&hex[16..], 16).ok()?;
    Some(Digest(hi, lo))
}

/// FNV-1a 64 payload checksum (same constants as the content
/// fingerprint's mixing prime; independent of it in coverage — this
/// one is over the *encoded* bytes and catches at-rest corruption).
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Manifest names become file names; keep them to one path component.
fn valid_name(name: &str) -> bool {
    !name.is_empty()
        && !name.starts_with('.')
        && !name.contains(['/', '\\'])
        && !name.contains("..")
}

/// One layer of a pushed checkpoint: name, f32 weight, target format.
pub struct PushLayer<'a> {
    pub name: &'a str,
    pub weight: &'a Mat,
    pub fmt: BlockFormat,
}

/// One manifest row: everything needed to address the blob and to
/// rebuild the exact cache/operand key the hot path will look up.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerEntry {
    pub name: String,
    /// Fingerprint of the f32 source tensor — the blob key, and the
    /// `content` field of the operand-cache key.
    pub digest: Digest,
    pub fmt: BlockFormat,
    pub layout: PlaneLayout,
    /// f32 source shape (`k x n` as the scheduler sees the weight).
    pub rows: usize,
    pub cols: usize,
    pub transposed: bool,
    pub blob_bytes: u64,
}

impl LayerEntry {
    /// The exact [`OperandCache`] key `encode_transposed_cached` would
    /// compute for this weight — warm starts install under it.
    pub fn cache_key(&self) -> CacheKey {
        CacheKey {
            content: self.digest,
            m_bits: self.fmt.mantissa_bits,
            block: self.fmt.block_size,
            layout: self.layout,
            transposed: self.transposed,
        }
    }
}

/// A named checkpoint: ordered layers plus free-form metadata.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub layers: Vec<LayerEntry>,
    pub meta: BTreeMap<String, String>,
}

/// Outcome of a [`Registry::push`]: dedup is observable, not inferred.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PushStats {
    pub layers: usize,
    pub blobs_written: usize,
    pub blobs_deduped: usize,
    pub bytes_written: u64,
    pub bytes_deduped: u64,
}

impl PushStats {
    /// Fraction of pushed layers satisfied by an existing blob.
    pub fn dedup_ratio(&self) -> f64 {
        if self.layers == 0 {
            0.0
        } else {
            self.blobs_deduped as f64 / self.layers as f64
        }
    }
}

/// Outcome of a [`Registry::gc`] sweep.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct GcStats {
    pub blobs_kept: usize,
    pub blobs_removed: usize,
    pub bytes_removed: u64,
    /// Manifests retired by a [`Registry::gc_keep_last`] retention pass
    /// (always 0 for plain [`Registry::gc`]).
    pub manifests_removed: usize,
}

/// Outcome of a [`Registry::warm_cache`] preload.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WarmStats {
    /// Planes published into the operand cache.
    pub installed: usize,
    /// Resident plane + exponent bytes installed.
    pub plane_bytes: u64,
    /// Loads served by a live file mapping (vs the read fallback).
    pub mapped_loads: usize,
}

/// A digest-addressed store of encoded weights under named manifests.
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    /// Open (creating directories as needed) a registry rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> Result<Self> {
        let root = root.into();
        for sub in ["blobs", "manifests"] {
            let dir = root.join(sub);
            std::fs::create_dir_all(&dir).map_err(|e| io_err(&dir, e))?;
        }
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn blobs_dir(&self) -> PathBuf {
        self.root.join("blobs")
    }

    fn manifests_dir(&self) -> PathBuf {
        self.root.join("manifests")
    }

    fn blob_file_name(digest: Digest, fmt: BlockFormat) -> String {
        format!(
            "{}-m{}b{}.bfpb",
            digest.to_hex(),
            fmt.mantissa_bits,
            fmt.block_size
        )
    }

    pub fn blob_path(&self, digest: Digest, fmt: BlockFormat) -> PathBuf {
        self.blobs_dir().join(Self::blob_file_name(digest, fmt))
    }

    pub fn has_blob(&self, digest: Digest, fmt: BlockFormat) -> bool {
        self.blob_path(digest, fmt).is_file()
    }

    fn manifest_path(&self, name: &str) -> PathBuf {
        self.manifests_dir().join(format!("{name}.json"))
    }

    /// Push one named checkpoint: encode-and-store every layer whose
    /// (digest, format) blob is unseen, reuse the rest byte-for-byte,
    /// then write the manifest. Dedup is by construction — a blob's
    /// path is a pure function of content digest and format.
    pub fn push(
        &self,
        name: &str,
        layers: &[PushLayer<'_>],
        meta: &BTreeMap<String, String>,
    ) -> Result<(Manifest, PushStats)> {
        if !valid_name(name) {
            return Err(RegistryError::BadManifest {
                path: self.manifests_dir().join(name),
                detail: "manifest name must be a single non-hidden path component".into(),
            });
        }
        let mut entries = Vec::with_capacity(layers.len());
        let mut stats = PushStats {
            layers: layers.len(),
            ..Default::default()
        };
        for layer in layers {
            let w = layer.weight;
            let digest = content_fingerprint(&w.data, w.rows, w.cols);
            let path = self.blob_path(digest, layer.fmt);
            let blob_bytes = if path.is_file() {
                stats.blobs_deduped += 1;
                let len = std::fs::metadata(&path).map_err(|e| io_err(&path, e))?.len();
                stats.bytes_deduped += len;
                len
            } else {
                let encoded = BfpMatrix::encode_transposed(
                    w,
                    layer.fmt,
                    Quantizer::nearest(layer.fmt.mantissa_bits),
                )
                .map_err(|e| RegistryError::Encode {
                    layer: layer.name.to_string(),
                    detail: e.to_string(),
                })?;
                let bytes = encode_blob(&encoded, digest);
                write_atomic(&path, &bytes)?;
                stats.blobs_written += 1;
                stats.bytes_written += bytes.len() as u64;
                bytes.len() as u64
            };
            entries.push(LayerEntry {
                name: layer.name.to_string(),
                digest,
                fmt: layer.fmt,
                layout: layer.fmt.plane_layout(),
                rows: w.rows,
                cols: w.cols,
                transposed: true,
                blob_bytes,
            });
        }
        let manifest = Manifest {
            name: name.to_string(),
            layers: entries,
            meta: meta.clone(),
        };
        write_atomic(
            &self.manifest_path(name),
            render_manifest(&manifest).as_bytes(),
        )?;
        Ok((manifest, stats))
    }

    /// Import a legacy f32 [`Checkpoint`] container: every tensor
    /// becomes a layer encoded under `fmt_for(name)`. This subsumes the
    /// f32 container as the registry's ingest path — the registry is
    /// the at-rest format, the checkpoint the interchange one.
    pub fn import_checkpoint(
        &self,
        ck: &Checkpoint,
        name: &str,
        fmt_for: impl Fn(&str) -> BlockFormat,
    ) -> Result<(Manifest, PushStats)> {
        let mats = ck.layer_mats().map_err(|e| RegistryError::Encode {
            layer: name.to_string(),
            detail: e.to_string(),
        })?;
        let layers: Vec<PushLayer<'_>> = mats
            .iter()
            .map(|(lname, mat)| PushLayer {
                name: lname,
                weight: mat,
                fmt: fmt_for(lname),
            })
            .collect();
        self.push(name, &layers, &ck.meta)
    }

    /// All manifest names, sorted.
    pub fn manifest_names(&self) -> Result<Vec<String>> {
        let dir = self.manifests_dir();
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&dir).map_err(|e| io_err(&dir, e))? {
            let entry = entry.map_err(|e| io_err(&dir, e))?;
            let fname = entry.file_name();
            if let Some(name) = fname.to_str().and_then(|f| f.strip_suffix(".json")) {
                names.push(name.to_string());
            }
        }
        names.sort();
        Ok(names)
    }

    /// Load and validate one manifest.
    pub fn manifest(&self, name: &str) -> Result<Manifest> {
        let path = self.manifest_path(name);
        let text = std::fs::read_to_string(&path).map_err(|e| io_err(&path, e))?;
        parse_manifest(&path, name, &text)
    }

    /// Load one blob into an owned [`BfpMatrix`], validating the full
    /// structural contract against the manifest entry.
    pub fn load_blob(&self, entry: &LayerEntry) -> Result<Arc<BfpMatrix>> {
        self.load_blob_inner(entry).map(|(m, _)| m)
    }

    fn load_blob_inner(&self, entry: &LayerEntry) -> Result<(Arc<BfpMatrix>, bool)> {
        let path = self.blob_path(entry.digest, entry.fmt);
        if !path.is_file() {
            return Err(RegistryError::MissingBlob {
                digest: entry.digest,
                m_bits: entry.fmt.mantissa_bits,
                block: entry.fmt.block_size,
            });
        }
        let mapped = mmap::map_readonly(&path).map_err(|e| io_err(&path, e))?;
        let was_mapped = mapped.is_mapped();
        let matrix = decode_blob(&path, &mapped, entry)?;
        Ok((Arc::new(matrix), was_mapped))
    }

    /// Load every layer of `name` (manifest order).
    pub fn pull(&self, name: &str) -> Result<Vec<(LayerEntry, Arc<BfpMatrix>)>> {
        let manifest = self.manifest(name)?;
        manifest
            .layers
            .into_iter()
            .map(|entry| self.load_blob(&entry).map(|m| (entry, m)))
            .collect()
    }

    /// Warm-start path: publish every layer of `name` into `cache`
    /// under its hot-path key. After this, `encode_transposed_cached`
    /// for a manifest-covered weight is a pure lookup — zero encode
    /// operations, zero f32 touches.
    pub fn warm_cache(&self, name: &str, cache: &OperandCache) -> Result<WarmStats> {
        let manifest = self.manifest(name)?;
        let mut stats = WarmStats::default();
        for entry in &manifest.layers {
            let (matrix, was_mapped) = self.load_blob_inner(entry)?;
            stats.plane_bytes +=
                (matrix.mantissas.resident_bytes() + matrix.exponents.len() * 4) as u64;
            if was_mapped {
                stats.mapped_loads += 1;
            }
            cache.preload(entry.cache_key(), matrix);
            stats.installed += 1;
        }
        Ok(stats)
    }

    /// Remove blobs no manifest references, plus stale temp files.
    /// Reachability is recomputed from every manifest at sweep time, so
    /// a reachable blob can never be dropped (pinned by tests).
    pub fn gc(&self) -> Result<GcStats> {
        let mut reachable = HashSet::new();
        for name in self.manifest_names()? {
            for entry in self.manifest(&name)?.layers {
                reachable.insert(Self::blob_file_name(entry.digest, entry.fmt));
            }
        }
        let dir = self.blobs_dir();
        let mut stats = GcStats::default();
        for dirent in std::fs::read_dir(&dir).map_err(|e| io_err(&dir, e))? {
            let dirent = dirent.map_err(|e| io_err(&dir, e))?;
            let fname = dirent.file_name().to_string_lossy().into_owned();
            if reachable.contains(&fname) {
                stats.blobs_kept += 1;
                continue;
            }
            let path = dirent.path();
            let len = std::fs::metadata(&path).map(|m| m.len()).unwrap_or(0);
            std::fs::remove_file(&path).map_err(|e| io_err(&path, e))?;
            stats.blobs_removed += 1;
            stats.bytes_removed += len;
        }
        Ok(stats)
    }

    /// Retention gc (`registry gc --keep-last N`): retire every manifest
    /// but the `keep` newest (by file modification time, name-sorted to
    /// break ties deterministically), then run the plain reachability
    /// sweep. Blobs the surviving manifests share with retired ones are
    /// untouched — reachability is recomputed after retirement, so a
    /// blob is removed only when **no** surviving manifest references
    /// it. `keep == 0` retires every manifest and empties the store.
    pub fn gc_keep_last(&self, keep: usize) -> Result<GcStats> {
        let mut dated: Vec<(std::time::SystemTime, String)> = Vec::new();
        for name in self.manifest_names()? {
            let path = self.manifest_path(&name);
            let mtime = std::fs::metadata(&path)
                .and_then(|m| m.modified())
                .map_err(|e| io_err(&path, e))?;
            dated.push((mtime, name));
        }
        // Newest first; equal mtimes (coarse filesystem clocks) fall
        // back to reverse name order so push order still wins when
        // names sort chronologically (epoch00, epoch01, ...).
        dated.sort_by(|a, b| b.cmp(a));
        let mut manifests_removed = 0usize;
        for (_, name) in dated.iter().skip(keep) {
            let path = self.manifest_path(name);
            std::fs::remove_file(&path).map_err(|e| io_err(&path, e))?;
            manifests_removed += 1;
        }
        let mut stats = self.gc()?;
        stats.manifests_removed = manifests_removed;
        Ok(stats)
    }

    /// Store-wide blob census for `registry ls`: (count, total bytes).
    pub fn blob_stats(&self) -> Result<(usize, u64)> {
        let dir = self.blobs_dir();
        let mut count = 0usize;
        let mut bytes = 0u64;
        for dirent in std::fs::read_dir(&dir).map_err(|e| io_err(&dir, e))? {
            let dirent = dirent.map_err(|e| io_err(&dir, e))?;
            if dirent.file_name().to_string_lossy().ends_with(".bfpb") {
                count += 1;
                bytes += dirent.metadata().map(|m| m.len()).unwrap_or(0);
            }
        }
        Ok((count, bytes))
    }
}

/// Serialize one encoded matrix into the versioned blob byte stream.
fn encode_blob(m: &BfpMatrix, digest: Digest) -> Vec<u8> {
    let plane_bytes: Vec<u8> = match &m.mantissas {
        MantissaPlane::I4Packed(v) => v.clone(),
        MantissaPlane::I8(v) => v.iter().map(|&b| b as u8).collect(),
        MantissaPlane::I16(v) => v.iter().flat_map(|&x| x.to_le_bytes()).collect(),
    };
    let mut payload = plane_bytes;
    payload.reserve(m.exponents.len() * 4);
    for &e in &m.exponents {
        payload.extend_from_slice(&e.to_le_bytes());
    }
    let plane_len = payload.len() - m.exponents.len() * 4;
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(BLOB_MAGIC);
    out.extend_from_slice(&BLOB_VERSION.to_le_bytes());
    out.push(layout_byte(m.mantissas.layout()));
    out.push(FLAG_TRANSPOSED);
    out.extend_from_slice(&m.fmt.mantissa_bits.to_le_bytes());
    out.extend_from_slice(&(m.fmt.block_size as u32).to_le_bytes());
    out.extend_from_slice(&(m.rows as u64).to_le_bytes());
    out.extend_from_slice(&(m.cols as u64).to_le_bytes());
    out.extend_from_slice(&(plane_len as u64).to_le_bytes());
    out.extend_from_slice(&(m.exponents.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv64(&payload).to_le_bytes());
    out.extend_from_slice(&digest.to_le_bytes());
    debug_assert_eq!(out.len(), HEADER_LEN);
    out.extend_from_slice(&payload);
    out
}

fn corrupt(path: &Path, detail: impl Into<String>) -> RegistryError {
    RegistryError::CorruptBlob {
        path: path.to_path_buf(),
        detail: detail.into(),
    }
}

/// Parse + validate one blob against its manifest entry. Mirrors the
/// fabric wire decoder's checklist: every length is derived twice
/// (header vs format arithmetic) and must agree before any plane byte
/// is trusted.
fn decode_blob(path: &Path, bytes: &[u8], entry: &LayerEntry) -> Result<BfpMatrix> {
    if bytes.len() < HEADER_LEN {
        return Err(corrupt(path, format!("{} bytes < header", bytes.len())));
    }
    if &bytes[0..4] != BLOB_MAGIC {
        return Err(corrupt(path, "bad magic"));
    }
    let version = u16::from_le_bytes([bytes[4], bytes[5]]);
    if version != BLOB_VERSION {
        return Err(corrupt(path, format!("unknown blob version {version}")));
    }
    let layout = layout_from_byte(bytes[6])
        .ok_or_else(|| corrupt(path, format!("unknown layout byte {}", bytes[6])))?;
    let transposed = bytes[7] & FLAG_TRANSPOSED != 0;
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap());
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap());
    let m_bits = u32_at(8);
    let block = u32_at(12) as usize;
    let rows = u64_at(16) as usize;
    let cols = u64_at(24) as usize;
    let plane_len = u64_at(32) as usize;
    let exp_count = u64_at(40) as usize;
    let payload_fnv = u64_at(48);
    let digest = Digest::from_le_bytes(bytes[56..72].try_into().unwrap());

    let fmt = BlockFormat::new(m_bits, block)
        .map_err(|e| corrupt(path, format!("bad block format: {e}")))?;
    if fmt != entry.fmt {
        return Err(corrupt(
            path,
            format!(
                "format m{}b{} != manifest m{}b{}",
                m_bits, block, entry.fmt.mantissa_bits, entry.fmt.block_size
            ),
        ));
    }
    if layout != fmt.plane_layout() {
        return Err(corrupt(path, "layout byte disagrees with format"));
    }
    if digest != entry.digest {
        return Err(corrupt(
            path,
            format!(
                "content digest {} != manifest {}",
                digest.to_hex(),
                entry.digest.to_hex()
            ),
        ));
    }
    if transposed != entry.transposed {
        return Err(corrupt(path, "transposed flag disagrees with manifest"));
    }
    // A transposed encode of the k x n source is an n x k plane matrix.
    if transposed && (rows != entry.cols || cols != entry.rows) {
        return Err(corrupt(
            path,
            format!(
                "encoded shape {rows}x{cols} does not transpose manifest {}x{}",
                entry.rows, entry.cols
            ),
        ));
    }
    let blocks_per_row = cols.div_ceil(block);
    let logical = rows
        .checked_mul(blocks_per_row)
        .and_then(|v| v.checked_mul(block))
        .ok_or_else(|| corrupt(path, "plane size overflows"))?;
    let want_plane = match layout {
        PlaneLayout::I4Packed => logical / 2,
        PlaneLayout::I8 => logical,
        PlaneLayout::I16 => logical * 2,
    };
    if plane_len != want_plane {
        return Err(corrupt(
            path,
            format!("plane length {plane_len} != expected {want_plane}"),
        ));
    }
    if exp_count != rows * blocks_per_row {
        return Err(corrupt(
            path,
            format!("exponent count {exp_count} != {}", rows * blocks_per_row),
        ));
    }
    let want_total = HEADER_LEN + plane_len + exp_count * 4;
    if bytes.len() != want_total {
        return Err(corrupt(
            path,
            format!("file is {} bytes, expected {want_total}", bytes.len()),
        ));
    }
    let payload = &bytes[HEADER_LEN..];
    if fnv64(payload) != payload_fnv {
        return Err(corrupt(path, "payload checksum mismatch"));
    }
    let plane = &payload[..plane_len];
    let mantissas = match layout {
        PlaneLayout::I4Packed => MantissaPlane::I4Packed(plane.to_vec()),
        PlaneLayout::I8 => MantissaPlane::I8(plane.iter().map(|&b| b as i8).collect()),
        PlaneLayout::I16 => MantissaPlane::I16(
            plane
                .chunks_exact(2)
                .map(|c| i16::from_le_bytes([c[0], c[1]]))
                .collect(),
        ),
    };
    let exponents: Vec<i32> = payload[plane_len..]
        .chunks_exact(4)
        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Ok(BfpMatrix {
        fmt,
        rows,
        cols,
        blocks_per_row,
        mantissas,
        exponents,
    })
}

fn render_manifest(m: &Manifest) -> String {
    let layers = m
        .layers
        .iter()
        .map(|e| {
            Json::obj(vec![
                ("name", Json::str(&e.name)),
                ("digest", Json::str(e.digest.to_hex())),
                ("m_bits", Json::num(e.fmt.mantissa_bits as f64)),
                ("block", Json::num(e.fmt.block_size as f64)),
                ("layout", Json::str(e.layout.label())),
                ("rows", Json::num(e.rows as f64)),
                ("cols", Json::num(e.cols as f64)),
                ("transposed", Json::Bool(e.transposed)),
                ("blob_bytes", Json::num(e.blob_bytes as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str(MANIFEST_SCHEMA)),
        ("name", Json::str(&m.name)),
        ("layers", Json::Arr(layers)),
        ("meta", Json::from_map(&m.meta)),
    ])
    .render()
}

fn parse_manifest(path: &Path, name: &str, text: &str) -> Result<Manifest> {
    let bad = |detail: String| RegistryError::BadManifest {
        path: path.to_path_buf(),
        detail,
    };
    let doc = Json::parse(text).map_err(|e| bad(e.to_string()))?;
    let schema = doc
        .req("schema")
        .and_then(|s| Ok(s.as_str()?.to_string()))
        .map_err(|e| bad(e.to_string()))?;
    if schema != MANIFEST_SCHEMA {
        return Err(bad(format!("unknown schema {schema:?}")));
    }
    let doc_name = doc
        .req("name")
        .and_then(|s| Ok(s.as_str()?.to_string()))
        .map_err(|e| bad(e.to_string()))?;
    if doc_name != name {
        return Err(bad(format!("manifest names itself {doc_name:?}")));
    }
    let mut layers = Vec::new();
    for (i, layer) in doc
        .req("layers")
        .and_then(|l| l.as_arr())
        .map_err(|e| bad(e.to_string()))?
        .iter()
        .enumerate()
    {
        let field = |key: &str| {
            layer
                .req(key)
                .map_err(|e| bad(format!("layer {i}: {e}")))
        };
        let digest_hex = field("digest")?
            .as_str()
            .map_err(|e| bad(format!("layer {i}: {e}")))?;
        let digest = digest_from_hex(digest_hex)
            .ok_or_else(|| bad(format!("layer {i}: digest {digest_hex:?} is not 32 hex chars")))?;
        let m_bits = field("m_bits")?
            .as_usize()
            .map_err(|e| bad(format!("layer {i}: {e}")))? as u32;
        let block = field("block")?
            .as_usize()
            .map_err(|e| bad(format!("layer {i}: {e}")))?;
        let fmt =
            BlockFormat::new(m_bits, block).map_err(|e| bad(format!("layer {i}: {e}")))?;
        let label = field("layout")?
            .as_str()
            .map_err(|e| bad(format!("layer {i}: {e}")))?
            .to_string();
        let layout = layout_from_label(&label)
            .ok_or_else(|| bad(format!("layer {i}: unknown layout {label:?}")))?;
        if layout != fmt.plane_layout() {
            return Err(bad(format!(
                "layer {i}: layout {label:?} disagrees with format m{m_bits}b{block}"
            )));
        }
        layers.push(LayerEntry {
            name: field("name")?
                .as_str()
                .map_err(|e| bad(format!("layer {i}: {e}")))?
                .to_string(),
            digest,
            fmt,
            layout,
            rows: field("rows")?
                .as_usize()
                .map_err(|e| bad(format!("layer {i}: {e}")))?,
            cols: field("cols")?
                .as_usize()
                .map_err(|e| bad(format!("layer {i}: {e}")))?,
            transposed: field("transposed")?
                .as_bool()
                .map_err(|e| bad(format!("layer {i}: {e}")))?,
            blob_bytes: field("blob_bytes")?
                .as_f64()
                .map_err(|e| bad(format!("layer {i}: {e}")))? as u64,
        });
    }
    let mut meta = BTreeMap::new();
    if let Ok(Json::Obj(fields)) = doc.req("meta") {
        for (k, v) in fields {
            meta.insert(
                k.clone(),
                v.as_str().map_err(|e| bad(e.to_string()))?.to_string(),
            );
        }
    }
    Ok(Manifest {
        name: name.to_string(),
        layers,
        meta,
    })
}

/// Write via temp file + rename so readers never observe a partial
/// file and a crashed writer never parks garbage under a live name.
fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, bytes).map_err(|e| io_err(&tmp, e))?;
    std::fs::rename(&tmp, path).map_err(|e| io_err(path, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn temp_root(tag: &str) -> PathBuf {
        static SEQ: AtomicUsize = AtomicUsize::new(0);
        std::env::temp_dir().join(format!(
            "boosters-registry-{}-{}-{tag}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ))
    }

    fn mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::new(rows, cols, (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect()).unwrap()
    }

    fn fmt(m: u32, b: usize) -> BlockFormat {
        BlockFormat::new(m, b).unwrap()
    }

    #[test]
    fn push_pull_roundtrip_is_bit_identical() {
        let root = temp_root("roundtrip");
        let reg = Registry::open(&root).unwrap();
        let weights = [mat(64, 48, 1), mat(33, 17, 2), mat(16, 64, 3)];
        let fmts = [fmt(4, 64), fmt(6, 16), fmt(12, 16)];
        let names = ["layer0", "layer1", "layer2"];
        let layers: Vec<PushLayer<'_>> = weights
            .iter()
            .zip(&fmts)
            .zip(names)
            .map(|((w, &f), name)| PushLayer {
                name,
                weight: w,
                fmt: f,
            })
            .collect();
        let (manifest, stats) = reg.push("epoch0", &layers, &BTreeMap::new()).unwrap();
        assert_eq!(stats.blobs_written, 3);
        assert_eq!(stats.blobs_deduped, 0);
        assert_eq!(manifest.layers.len(), 3);

        let pulled = reg.pull("epoch0").unwrap();
        for ((entry, loaded), (w, &f)) in pulled.iter().zip(weights.iter().zip(&fmts)) {
            let fresh =
                BfpMatrix::encode_transposed(w, f, Quantizer::nearest(f.mantissa_bits)).unwrap();
            assert_eq!(**loaded, fresh, "{}", entry.name);
            assert_eq!(entry.digest, content_fingerprint(&w.data, w.rows, w.cols));
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn push_dedups_by_digest_and_format() {
        let root = temp_root("dedup");
        let reg = Registry::open(&root).unwrap();
        let w = mat(32, 32, 7);
        let f4 = fmt(4, 16);
        let push = |name: &str, f: BlockFormat| {
            reg.push(
                name,
                &[PushLayer {
                    name: "w",
                    weight: &w,
                    fmt: f,
                }],
                &BTreeMap::new(),
            )
            .unwrap()
            .1
        };
        assert_eq!(push("a", f4).blobs_written, 1);
        // Same content + format under a new manifest: pure dedup.
        let again = push("b", f4);
        assert_eq!(again.blobs_written, 0);
        assert_eq!(again.blobs_deduped, 1);
        assert!(again.bytes_deduped > 0);
        assert!((again.dedup_ratio() - 1.0).abs() < 1e-12);
        // Same content, different mantissa width: a distinct blob.
        assert_eq!(push("c", fmt(6, 16)).blobs_written, 1);
        assert_eq!(reg.blob_stats().unwrap().0, 2);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_keeps_reachable_blobs_only() {
        let root = temp_root("gc");
        let reg = Registry::open(&root).unwrap();
        let keep = mat(16, 16, 10);
        let drop_ = mat(16, 16, 11);
        let f = fmt(4, 16);
        reg.push(
            "keep",
            &[PushLayer {
                name: "w",
                weight: &keep,
                fmt: f,
            }],
            &BTreeMap::new(),
        )
        .unwrap();
        reg.push(
            "drop",
            &[PushLayer {
                name: "w",
                weight: &drop_,
                fmt: f,
            }],
            &BTreeMap::new(),
        )
        .unwrap();
        std::fs::remove_file(root.join("manifests/drop.json")).unwrap();
        let stats = reg.gc().unwrap();
        assert_eq!(stats.blobs_kept, 1);
        assert_eq!(stats.blobs_removed, 1);
        assert!(stats.bytes_removed > 0);
        assert!(reg.has_blob(content_fingerprint(&keep.data, 16, 16), f));
        assert!(!reg.has_blob(content_fingerprint(&drop_.data, 16, 16), f));
        // The surviving manifest still pulls clean.
        assert_eq!(reg.pull("keep").unwrap().len(), 1);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_keep_last_retires_old_manifests_but_never_shared_blobs() {
        let root = temp_root("gc_keep_last");
        let reg = Registry::open(&root).unwrap();
        let f = fmt(4, 16);
        let (m1, m2, m3) = (mat(16, 16, 1), mat(16, 16, 2), mat(16, 16, 3));
        let push = |name: &str, mats: &[&Mat]| {
            let layers: Vec<PushLayer<'_>> = mats
                .iter()
                .enumerate()
                .map(|(i, w)| PushLayer {
                    name: if i == 0 { "a" } else { "b" },
                    weight: w,
                    fmt: f,
                })
                .collect();
            reg.push(name, &layers, &BTreeMap::new()).unwrap();
        };
        push("epoch00", &[&m1, &m2]);
        push("epoch01", &[&m2, &m3]); // m2 dedups against epoch00
        assert_eq!(reg.blob_stats().unwrap().0, 3);

        // keep >= manifest count: retention is a no-op.
        let s = reg.gc_keep_last(2).unwrap();
        assert_eq!(s.manifests_removed, 0);
        assert_eq!(s.blobs_removed, 0);
        assert_eq!(s.blobs_kept, 3);

        // keep 1: the older epoch00 is retired; its private blob (m1)
        // goes, but m2 — shared with the surviving epoch01 — must stay.
        let s = reg.gc_keep_last(1).unwrap();
        assert_eq!(s.manifests_removed, 1);
        assert_eq!(s.blobs_removed, 1);
        assert_eq!(s.blobs_kept, 2);
        assert!(s.bytes_removed > 0);
        assert_eq!(reg.manifest_names().unwrap(), vec!["epoch01".to_string()]);
        assert!(!reg.has_blob(content_fingerprint(&m1.data, 16, 16), f));
        assert!(reg.has_blob(content_fingerprint(&m2.data, 16, 16), f));
        assert_eq!(reg.pull("epoch01").unwrap().len(), 2);

        // keep 0: everything is retired and the store empties.
        let s = reg.gc_keep_last(0).unwrap();
        assert_eq!(s.manifests_removed, 1);
        assert_eq!(s.blobs_removed, 2);
        assert!(reg.manifest_names().unwrap().is_empty());
        assert_eq!(reg.blob_stats().unwrap().0, 0);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn corrupt_blob_is_rejected_with_a_typed_error() {
        let root = temp_root("corrupt");
        let reg = Registry::open(&root).unwrap();
        let w = mat(16, 16, 20);
        let f = fmt(4, 16);
        let (manifest, _) = reg
            .push(
                "m",
                &[PushLayer {
                    name: "w",
                    weight: &w,
                    fmt: f,
                }],
                &BTreeMap::new(),
            )
            .unwrap();
        let entry = &manifest.layers[0];
        let path = reg.blob_path(entry.digest, entry.fmt);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = HEADER_LEN + (bytes.len() - HEADER_LEN) / 2;
        bytes[mid] ^= 0x5a;
        std::fs::write(&path, &bytes).unwrap();
        match reg.load_blob(entry) {
            Err(RegistryError::CorruptBlob { detail, .. }) => {
                assert!(detail.contains("checksum"), "{detail}");
            }
            other => panic!("expected CorruptBlob, got {other:?}"),
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn truncated_and_garbage_manifests_are_typed_errors() {
        let root = temp_root("manifest");
        let reg = Registry::open(&root).unwrap();
        let path = root.join("manifests/broken.json");
        std::fs::write(&path, b"{\"schema\": \"boosters-registry-v1\"").unwrap();
        assert!(matches!(
            reg.manifest("broken"),
            Err(RegistryError::BadManifest { .. })
        ));
        std::fs::write(&path, b"{\"schema\": \"other-v9\"}").unwrap();
        assert!(matches!(
            reg.manifest("broken"),
            Err(RegistryError::BadManifest { .. })
        ));
        assert!(matches!(
            reg.manifest("absent"),
            Err(RegistryError::Io { .. })
        ));
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn manifest_render_parse_roundtrip() {
        let root = temp_root("render");
        let reg = Registry::open(&root).unwrap();
        let w = mat(24, 40, 30);
        let mut meta = BTreeMap::new();
        meta.insert("epoch".to_string(), "3".to_string());
        let (pushed, _) = reg
            .push(
                "ck",
                &[PushLayer {
                    name: "fc1",
                    weight: &w,
                    fmt: fmt(4, 64),
                }],
                &meta,
            )
            .unwrap();
        let loaded = reg.manifest("ck").unwrap();
        assert_eq!(loaded.layers, pushed.layers);
        assert_eq!(loaded.meta.get("epoch").unwrap(), "3");
        let key = loaded.layers[0].cache_key();
        assert_eq!(key.content, pushed.layers[0].digest);
        assert!(key.transposed);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bad_manifest_names_are_rejected() {
        let root = temp_root("names");
        let reg = Registry::open(&root).unwrap();
        for name in ["", "a/b", "..", ".hidden", "a\\b"] {
            assert!(
                matches!(
                    reg.push(name, &[], &BTreeMap::new()),
                    Err(RegistryError::BadManifest { .. })
                ),
                "{name:?} should be rejected"
            );
        }
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn error_display_names_the_failure() {
        let e = RegistryError::MissingBlob {
            digest: Digest(1, 2),
            m_bits: 4,
            block: 64,
        };
        let s = e.to_string();
        assert!(s.contains("m=4") && s.contains("b=64"), "{s}");
    }
}
