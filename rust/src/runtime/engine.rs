//! PJRT execution engine: loads HLO-text artifacts, compiles them once on
//! the CPU client, and drives train/eval/decode steps.
//!
//! Interchange is HLO *text* (aot.py writes it; `HloModuleProto::
//! from_text_file` reparses and reassigns instruction ids — the serialized
//! proto path is incompatible with xla_extension 0.5.1, see DESIGN.md).
//!
//! The client is `Rc`-based (not `Send`), so one `Engine` lives on one
//! thread; the coordinator owns it for the whole run.

use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

use super::manifest::Manifest;
use super::tensor::Tensor;

/// Scalar knobs fed to the compiled step functions each call. This is the
/// surface the PrecisionScheduler drives: changing mantissa widths here is
/// the runtime analogue of bit-slicing an HBFP4 datapath to serve HBFP6.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepScalars {
    pub bits_mid: f32,
    pub bits_edge: f32,
    /// 0 = round-to-nearest-even gradients, 1 = stochastic rounding.
    pub rmode_grad: f32,
    /// Stochastic-rounding stream seed (integer-valued).
    pub seed: f32,
}

impl StepScalars {
    pub fn fp32() -> Self {
        // bits >= 23 is the FP32-bypass convention (ref.py).
        Self {
            bits_mid: 32.0,
            bits_edge: 32.0,
            rmode_grad: 0.0,
            seed: 0.0,
        }
    }

    pub fn hbfp(bits: f32) -> Self {
        Self {
            bits_mid: bits,
            bits_edge: bits,
            rmode_grad: 1.0,
            seed: 0.0,
        }
    }

    pub fn with_seed(mut self, seed: u32) -> Self {
        self.seed = seed as f32;
        self
    }
}

/// Outcome of one training step.
#[derive(Debug, Clone, Copy)]
pub struct StepStats {
    pub loss: f32,
    pub metric: f32,
}

/// Mutable training state held as host literals between steps (the PJRT
/// CPU client copies on execute; keeping literals avoids an extra
/// Vec<f32> materialization per step on the hot path).
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub opt: Vec<xla::Literal>,
}

impl TrainState {
    /// Snapshot parameters to host tensors (for checkpoints / analysis).
    pub fn params_to_tensors(&self) -> Result<Vec<Tensor>> {
        self.params.iter().map(Tensor::from_literal).collect()
    }

    pub fn from_tensors(params: &[Tensor], opt: &[Tensor]) -> Result<Self> {
        Ok(Self {
            params: params.iter().map(|t| t.to_literal()).collect::<Result<_>>()?,
            opt: opt.iter().map(|t| t.to_literal()).collect::<Result<_>>()?,
        })
    }
}

/// A fully loaded model variant: manifest + compiled executables.
pub struct ModelVariant {
    pub manifest: Manifest,
    pub dir: PathBuf,
    train_step: xla::PjRtLoadedExecutable,
    eval: xla::PjRtLoadedExecutable,
    decode: Option<xla::PjRtLoadedExecutable>,
}

pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn new() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
    }

    /// Load one variant directory (e.g. `artifacts/cnn_bs64`).
    pub fn load_variant(&self, dir: &Path) -> Result<ModelVariant> {
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let get = |key: &str| -> Result<PathBuf> {
            manifest
                .artifact(key)
                .map(|f| dir.join(f))
                .ok_or_else(|| anyhow!("manifest missing artifact {key}"))
        };
        let train_step = self.compile_file(&get("train_step")?)?;
        let eval = self.compile_file(&get("eval")?)?;
        let decode = if manifest.artifact("decode").is_some() {
            Some(self.compile_file(&get("decode")?)?)
        } else {
            None
        };
        Ok(ModelVariant {
            manifest,
            dir: dir.to_path_buf(),
            train_step,
            eval,
            decode,
        })
    }

    pub fn load_variant_by_name(&self, artifacts: &Path, name: &str) -> Result<ModelVariant> {
        self.load_variant(&artifacts.join(name))
            .with_context(|| format!("loading variant {name}"))
    }

    /// Run one fused train step: fwd + bwd + optimizer update in a single
    /// PJRT execute. Updates `state` in place and returns loss/metric.
    pub fn train_step(
        &self,
        variant: &ModelVariant,
        state: &mut TrainState,
        x: &Tensor,
        y: &Tensor,
        scalars: StepScalars,
        lr: f32,
    ) -> Result<StepStats> {
        let m = &variant.manifest;
        debug_assert_eq!(state.params.len(), m.n_params());
        debug_assert_eq!(state.opt.len(), m.n_opt());

        let mut args: Vec<xla::Literal> = Vec::with_capacity(
            state.params.len() + state.opt.len() + 2 + m.scalars_train.len(),
        );
        // Calling convention: *params, *opt, x, y, scalars...
        args.extend(state.params.drain(..));
        args.extend(state.opt.drain(..));
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        for s in [
            scalars.bits_mid,
            scalars.bits_edge,
            scalars.rmode_grad,
            scalars.seed,
            lr,
        ] {
            args.push(xla::Literal::scalar(s));
        }

        let result = variant
            .train_step
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("train_step execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let mut outs = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e}"))?;
        let expected = m.n_params() + m.n_opt() + 2;
        if outs.len() != expected {
            return Err(anyhow!("expected {expected} outputs, got {}", outs.len()));
        }
        let metric = Tensor::from_literal(&outs.pop().unwrap())?.item()?;
        let loss = Tensor::from_literal(&outs.pop().unwrap())?.item()?;
        state.opt = outs.split_off(m.n_params());
        state.params = outs;
        Ok(StepStats { loss, metric })
    }

    /// Evaluate one batch: returns (loss, metric) without touching state.
    pub fn eval_batch(
        &self,
        variant: &ModelVariant,
        state: &TrainState,
        x: &Tensor,
        y: &Tensor,
        scalars: StepScalars,
    ) -> Result<StepStats> {
        let mut args: Vec<xla::Literal> = Vec::with_capacity(state.params.len() + 6);
        for p in &state.params {
            args.push(p.clone());
        }
        args.push(x.to_literal()?);
        args.push(y.to_literal()?);
        for s in [
            scalars.bits_mid,
            scalars.bits_edge,
            scalars.rmode_grad,
            scalars.seed,
        ] {
            args.push(xla::Literal::scalar(s));
        }
        let result = variant
            .eval
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("eval execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let (loss, metric) = tuple.to_tuple2().map_err(|e| anyhow!("untuple: {e}"))?;
        Ok(StepStats {
            loss: Tensor::from_literal(&loss)?.item()?,
            metric: Tensor::from_literal(&metric)?.item()?,
        })
    }

    /// Greedy decode (transformer variants only): src tokens -> generated
    /// target tokens of shape [batch, out_len].
    pub fn decode(
        &self,
        variant: &ModelVariant,
        state: &TrainState,
        src: &Tensor,
        scalars: StepScalars,
    ) -> Result<Tensor> {
        let exe = variant
            .decode
            .as_ref()
            .ok_or_else(|| anyhow!("variant {} has no decode artifact", variant.manifest.variant))?;
        let mut args: Vec<xla::Literal> = Vec::with_capacity(state.params.len() + 5);
        for p in &state.params {
            args.push(p.clone());
        }
        args.push(src.to_literal()?);
        for s in [
            scalars.bits_mid,
            scalars.bits_edge,
            scalars.rmode_grad,
            scalars.seed,
        ] {
            args.push(xla::Literal::scalar(s));
        }
        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("decode execute: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let toks = tuple.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        Tensor::from_literal(&toks)
    }
}
