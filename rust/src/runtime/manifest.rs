//! Artifact manifests: the contract between `python/compile/aot.py` and
//! the rust runtime (parameter order, shapes, init specs, scalar order).
//! Parsed with the in-tree JSON substrate (`util::json`).

use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// "zeros" | "ones" | "normal" | "uniform"
    pub init: String,
    /// std for normal, bound for uniform.
    pub scale: f64,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
            init: v.req("init")?.as_str()?.to_string(),
            scale: v.req("scale")?.as_f64()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct OptSlot {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct OptSpec {
    /// "sgdm" | "adam"
    pub kind: String,
    pub momentum: f64,
    pub weight_decay: f64,
    pub adam_betas: (f64, f64),
    pub slots: Vec<OptSlot>,
}

impl OptSpec {
    fn from_json(v: &Json) -> Result<Self> {
        let betas = v.req("adam_betas")?.as_arr()?;
        Ok(Self {
            kind: v.req("kind")?.as_str()?.to_string(),
            momentum: v.req("momentum")?.as_f64()?,
            weight_decay: v.req("weight_decay")?.as_f64()?,
            adam_betas: (betas[0].as_f64()?, betas[1].as_f64()?),
            slots: v
                .req("slots")?
                .as_arr()?
                .iter()
                .map(|s| {
                    Ok(OptSlot {
                        name: s.req("name")?.as_str()?.to_string(),
                        shape: s.req("shape")?.as_usize_vec()?,
                    })
                })
                .collect::<Result<_>>()?,
        })
    }
}

#[derive(Debug, Clone)]
pub struct DecodeInfo {
    pub src_len: usize,
    pub tgt_len: usize,
    pub out_len: usize,
    pub bos: i32,
    pub sep: i32,
    pub eos: i32,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub variant: String,
    pub model: String,
    pub block: usize,
    pub pallas: bool,
    pub batch: usize,
    pub input_shape: Vec<usize>,
    /// "f32" | "i32"
    pub input_dtype: String,
    pub label_shape: Vec<usize>,
    pub num_classes: usize,
    pub params: Vec<ParamSpec>,
    pub opt: OptSpec,
    pub scalars_train: Vec<String>,
    pub scalars_eval: Vec<String>,
    pub artifacts: Vec<(String, String)>,
    pub decode: Option<DecodeInfo>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let v = Json::parse(text).context("parsing manifest json")?;
        let strings = |key: &str| -> Result<Vec<String>> {
            v.req(key)?
                .as_arr()?
                .iter()
                .map(|s| Ok(s.as_str()?.to_string()))
                .collect()
        };
        let decode = match v.req("decode")? {
            Json::Null => None,
            d => Some(DecodeInfo {
                src_len: d.req("src_len")?.as_usize()?,
                tgt_len: d.req("tgt_len")?.as_usize()?,
                out_len: d.req("out_len")?.as_usize()?,
                bos: d.req("bos")?.as_i64()? as i32,
                sep: d.req("sep")?.as_i64()? as i32,
                eos: d.req("eos")?.as_i64()? as i32,
            }),
        };
        Ok(Self {
            variant: v.req("variant")?.as_str()?.to_string(),
            model: v.req("model")?.as_str()?.to_string(),
            block: v.req("block")?.as_usize()?,
            pallas: v.req("pallas")?.as_bool()?,
            batch: v.req("batch")?.as_usize()?,
            input_shape: v.req("input_shape")?.as_usize_vec()?,
            input_dtype: v.req("input_dtype")?.as_str()?.to_string(),
            label_shape: v.req("label_shape")?.as_usize_vec()?,
            num_classes: v.req("num_classes")?.as_usize()?,
            params: v
                .req("params")?
                .as_arr()?
                .iter()
                .map(ParamSpec::from_json)
                .collect::<Result<_>>()?,
            opt: OptSpec::from_json(v.req("opt")?)?,
            scalars_train: strings("scalars_train")?,
            scalars_eval: strings("scalars_eval")?,
            artifacts: match v.req("artifacts")? {
                Json::Obj(fields) => fields
                    .iter()
                    .map(|(k, val)| Ok((k.clone(), val.as_str()?.to_string())))
                    .collect::<Result<_>>()?,
                other => return Err(anyhow!("artifacts must be an object, got {other:?}")),
            },
            decode,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn artifact(&self, key: &str) -> Option<&str> {
        self.artifacts
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    pub fn n_opt(&self) -> usize {
        self.opt.slots.len()
    }

    /// Total trainable parameter count.
    pub fn total_weights(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Full input batch shape ([batch, ...input_shape]).
    pub fn batch_input_shape(&self) -> Vec<usize> {
        let mut s = vec![self.batch];
        s.extend_from_slice(&self.input_shape);
        s
    }

    pub fn batch_label_shape(&self) -> Vec<usize> {
        let mut s = vec![self.batch];
        s.extend_from_slice(&self.label_shape);
        s
    }

    pub fn param_index(&self, name: &str) -> Result<usize> {
        self.params
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| anyhow!("no param named {name}"))
    }
}

/// The artifact registry written by aot.py.
#[derive(Debug, Clone)]
pub struct IndexEntry {
    pub name: String,
    pub model: String,
    pub block: usize,
    pub pallas: bool,
}

#[derive(Debug, Clone)]
pub struct Index {
    pub variants: Vec<IndexEntry>,
}

impl Index {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(artifacts_dir.join("index.json"))
            .context("reading artifacts/index.json — run `make artifacts` first")?;
        let v = Json::parse(&text).context("parsing index.json")?;
        Ok(Self {
            variants: v
                .req("variants")?
                .as_arr()?
                .iter()
                .map(|e| {
                    Ok(IndexEntry {
                        name: e.req("name")?.as_str()?.to_string(),
                        model: e.req("model")?.as_str()?.to_string(),
                        block: e.req("block")?.as_usize()?,
                        pallas: e.req("pallas")?.as_bool()?,
                    })
                })
                .collect::<Result<_>>()?,
        })
    }

    /// Variants for a model family, sorted by block size.
    pub fn for_model(&self, model: &str) -> Vec<&IndexEntry> {
        let mut v: Vec<_> = self
            .variants
            .iter()
            .filter(|e| e.model == model && !e.pallas)
            .collect();
        v.sort_by_key(|e| e.block);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> &'static str {
        r#"{
          "variant": "mlp_bs64", "model": "mlp", "block": 64, "pallas": false,
          "batch": 128, "input_shape": [48], "input_dtype": "f32",
          "label_shape": [], "num_classes": 10,
          "params": [
            {"name": "fc0.weight", "shape": [48, 96], "init": "uniform", "scale": 0.2},
            {"name": "fc0.bias", "shape": [96], "init": "zeros", "scale": 0.0}
          ],
          "opt": {"kind": "sgdm", "momentum": 0.9, "weight_decay": 1e-4,
                  "adam_betas": [0.9, 0.98],
                  "slots": [{"name": "momentum.fc0.weight", "shape": [48, 96]},
                            {"name": "momentum.fc0.bias", "shape": [96]}]},
          "scalars_train": ["bits_mid", "bits_edge", "rmode_grad", "seed", "lr"],
          "scalars_eval": ["bits_mid", "bits_edge", "rmode_grad", "seed"],
          "artifacts": {"train_step": "train_step.hlo.txt", "eval": "eval.hlo.txt"},
          "decode": null
        }"#
    }

    #[test]
    fn parse_manifest() {
        let m = Manifest::parse(sample_manifest()).unwrap();
        assert_eq!(m.variant, "mlp_bs64");
        assert_eq!(m.n_params(), 2);
        assert_eq!(m.total_weights(), 48 * 96 + 96);
        assert_eq!(m.batch_input_shape(), vec![128, 48]);
        assert_eq!(m.batch_label_shape(), vec![128]);
        assert_eq!(m.param_index("fc0.bias").unwrap(), 1);
        assert!(m.param_index("nope").is_err());
        assert_eq!(m.opt.kind, "sgdm");
        assert_eq!(m.artifact("eval"), Some("eval.hlo.txt"));
        assert!(m.decode.is_none());
        assert_eq!(m.scalars_train.len(), 5);
    }

    #[test]
    fn parse_decode_info() {
        let doc = sample_manifest().replace(
            "\"decode\": null",
            r#""decode": {"src_len": 8, "tgt_len": 8, "out_len": 9,
                          "bos": 26, "sep": 27, "eos": 28}"#,
        );
        let m = Manifest::parse(&doc).unwrap();
        let d = m.decode.unwrap();
        assert_eq!(d.out_len, 9);
        assert_eq!(d.eos, 28);
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"variant": "x"}"#).is_err());
    }
}
