//! L3 runtime: the PJRT bridge to the AOT-compiled XLA artifacts.
//!
//! `python/compile/aot.py` lowers each model variant to HLO text once at
//! build time; everything here runs pure rust + the XLA CPU plugin —
//! python is never on the training path.

mod engine;
mod manifest;
mod tensor;

pub use engine::{Engine, ModelVariant, StepScalars, StepStats, TrainState};
pub use manifest::{Index, IndexEntry, Manifest, OptSlot, OptSpec, ParamSpec};
pub use tensor::Tensor;

use std::path::PathBuf;

/// Default artifacts directory: `$REPRO_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("REPRO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}
