//! Host tensors: the CPU-side value type flowing between the coordinator
//! and the PJRT runtime. Only f32 and i32 exist in this system (HBFP's
//! high-precision side is FP32; labels/tokens are i32).

use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor::F32 {
            shape: shape.to_vec(),
            data: vec![1.0; n],
        }
    }

    pub fn from_f32(shape: &[usize], data: Vec<f32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} != len {}", shape, data.len()));
        }
        Ok(Tensor::F32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn from_i32(shape: &[usize], data: Vec<i32>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(anyhow!("shape {:?} != len {}", shape, data.len()));
        }
        Ok(Tensor::I32 {
            shape: shape.to_vec(),
            data,
        })
    }

    pub fn scalar(v: f32) -> Self {
        Tensor::F32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32 { shape, .. } | Tensor::I32 { shape, .. } => shape,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            Tensor::I32 { .. } => Err(anyhow!("tensor is i32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Ok(data),
            Tensor::F32 { .. } => Err(anyhow!("tensor is f32, expected i32")),
        }
    }

    /// Scalar f32 extraction (shape []).
    pub fn item(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            return Err(anyhow!("item() on tensor with {} elements", d.len()));
        }
        Ok(d[0])
    }

    /// Convert to an xla host literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
            Tensor::I32 { data, .. } => xla::Literal::vec1(data).reshape(&dims)?,
        };
        Ok(lit)
    }

    /// Convert back from an xla literal (f32 or i32 arrays only).
    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.primitive_type() {
            xla::PrimitiveType::F32 => Tensor::from_f32(&dims, lit.to_vec::<f32>()?),
            xla::PrimitiveType::S32 => Tensor::from_i32(&dims, lit.to_vec::<i32>()?),
            other => Err(anyhow!("unsupported literal type {other:?}")),
        }
    }

    /// L2 norm (f32 tensors).
    pub fn l2_norm(&self) -> Result<f64> {
        Ok(self
            .as_f32()?
            .iter()
            .map(|&v| (v as f64) * (v as f64))
            .sum::<f64>()
            .sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_accessors() {
        let t = Tensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.numel(), 6);
        assert_eq!(t.shape(), &[2, 3]);
        assert!(t.as_i32().is_err());
        assert!(Tensor::from_f32(&[2, 2], vec![1.0]).is_err());
        let s = Tensor::scalar(3.5);
        assert_eq!(s.item().unwrap(), 3.5);
        assert!(t.item().is_err());
    }

    #[test]
    fn zeros_ones() {
        assert_eq!(Tensor::zeros(&[4]).as_f32().unwrap(), &[0.0; 4]);
        assert_eq!(Tensor::ones(&[3]).as_f32().unwrap(), &[1.0; 3]);
    }

    #[test]
    fn l2() {
        let t = Tensor::from_f32(&[2], vec![3.0, 4.0]).unwrap();
        assert!((t.l2_norm().unwrap() - 5.0).abs() < 1e-12);
    }
}
