//! Fixed-point BFP dot products — the arithmetic the paper's accelerator
//! performs: integer mantissa MACs inside a block, one signed exponent add
//! per block pair, FP32 accumulation across blocks.
//!
//! This demonstrates (and tests) the core HBFP claim: once operands are in
//! BFP, the dot product needs **no floating point** until the final
//! accumulation, which is why the silicon cost in `hw_model` is dominated
//! by small fixed-point multipliers.
//!
//! [`bfp_dot_fixed_point`] runs on the packed planes
//! ([`super::gemm::packed_dot`]); [`bfp_dot_blocks`] is the per-block
//! scalar reference it stays bit-identical to.

use super::block::{BfpBlock, BfpTensor, BlockFormat};
use super::gemm::packed_dot;
use super::packed::BfpMatrix;
use super::quantize::Quantizer;
use anyhow::{anyhow, Result};

/// Dot product of two encoded blocks using pure integer arithmetic:
///   sum_i(qx_i * qy_i) * 2^(ex - mx + 2) * 2^(ey - my + 2)
/// The integer sum is exact (i64); a single scale-by-power-of-two follows.
pub fn bfp_dot_blocks(x: &BfpBlock, y: &BfpBlock) -> Result<f64> {
    if x.format.block_size != y.format.block_size {
        return Err(anyhow!(
            "block size mismatch {} vs {}",
            x.format.block_size,
            y.format.block_size
        ));
    }
    let mut acc: i64 = 0;
    for (&a, &b) in x.mantissas.iter().zip(&y.mantissas) {
        acc += a as i64 * b as i64;
    }
    let shift = x.scale_shift() + y.scale_shift();
    Ok(acc as f64 * (2.0f64).powi(shift))
}

/// Fixed-point dot product of two equal-length vectors, blocked with
/// `fmt`: encode both sides into packed planes (large vectors encode in
/// parallel on the [`crate::exec`] pool, bit-identically to serial),
/// run integer MACs per block pair, accumulate serially in block order.
/// Operands are deliberately **not** routed through the exec operand
/// cache: dot operands are overwhelmingly one-shot, and inserting them
/// would evict the serving path's reusable weight encodings. Bit-identical
/// to summing [`bfp_dot_blocks`] over a [`BfpTensor`] pair in block order.
pub fn bfp_dot_fixed_point(x: &[f32], y: &[f32], fmt: BlockFormat) -> Result<f64> {
    if x.len() != y.len() {
        return Err(anyhow!("length mismatch {} vs {}", x.len(), y.len()));
    }
    let q = Quantizer::nearest(fmt.mantissa_bits);
    let xp = BfpMatrix::encode(x, 1, x.len(), fmt, q)?;
    let yp = BfpMatrix::encode(y, 1, y.len(), fmt, q)?;
    packed_dot(&xp, &yp)
}

/// Float-side reference: dot of the dequantized tensors in f64.
pub fn dequant_dot(x: &[f32], y: &[f32], fmt: BlockFormat) -> Result<f64> {
    let tx = BfpTensor::encode(x, fmt)?.decode();
    let ty = BfpTensor::encode(y, fmt)?.decode();
    Ok(tx.iter().zip(&ty).map(|(&a, &b)| a as f64 * b as f64).sum())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn fixed_point_equals_dequant_dot() {
        // The integer datapath must agree with the float view of the same
        // quantized values to f64 rounding (products of m-bit mantissas
        // scaled by powers of two are exact in f64).
        for (m, b, n) in [(4u32, 16usize, 128usize), (6, 64, 333), (8, 49, 98)] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let x = randn(n, 1);
            let y = randn(n, 2);
            let fixed = bfp_dot_fixed_point(&x, &y, fmt).unwrap();
            let float = dequant_dot(&x, &y, fmt).unwrap();
            assert!(
                (fixed - float).abs() <= 1e-9 * float.abs().max(1.0),
                "m={m} b={b}: {fixed} vs {float}"
            );
        }
    }

    #[test]
    fn packed_dot_bit_identical_to_scalar_blocks() {
        for (m, b, n) in [(3u32, 8usize, 77usize), (4, 64, 500), (8, 16, 130), (12, 25, 60)] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let x = randn(n, 3);
            let y = randn(n, 4);
            let got = bfp_dot_fixed_point(&x, &y, fmt).unwrap();
            let tx = BfpTensor::encode(&x, fmt).unwrap();
            let ty = BfpTensor::encode(&y, fmt).unwrap();
            let mut want = 0.0f64;
            for (bx, by) in tx.blocks.iter().zip(&ty.blocks) {
                want += bfp_dot_blocks(bx, by).unwrap();
            }
            assert_eq!(got.to_bits(), want.to_bits(), "m={m} b={b} n={n}");
        }
    }

    #[test]
    fn approaches_exact_dot_with_more_bits() {
        let x = randn(512, 3);
        let y = randn(512, 4);
        let exact: f64 = x.iter().zip(&y).map(|(&a, &b)| a as f64 * b as f64).sum();
        let err_at = |m: u32| {
            let fmt = BlockFormat::new(m, 64).unwrap();
            (bfp_dot_fixed_point(&x, &y, fmt).unwrap() - exact).abs()
        };
        // Error shrinks strongly over a wide mantissa span (individual
        // adjacent steps can be noisy; the trend must not be).
        assert!(err_at(12) < err_at(3) / 10.0, "{} vs {}", err_at(12), err_at(3));
        // 512 accumulated rounding errors at m=12 stay well under 1% of
        // the |dot| magnitude (~22 for these inputs).
        assert!(err_at(12) < 0.2, "12-bit error too large: {}", err_at(12));
    }

    #[test]
    fn mismatched_inputs_rejected() {
        let fmt = BlockFormat::new(4, 16).unwrap();
        assert!(bfp_dot_fixed_point(&[1.0; 8], &[1.0; 9], fmt).is_err());
    }

    #[test]
    fn mixed_mantissa_blocks_compose() {
        // HBFP6 x HBFP4 block dot (the bit-sliced mixed-precision case of
        // §4.2) is well-defined: exponents add, mantissa widths differ.
        let f6 = BlockFormat::new(6, 32).unwrap();
        let f4 = BlockFormat::new(4, 32).unwrap();
        let x = randn(32, 5);
        let y = randn(32, 6);
        let bx = BfpBlock::encode(&x, f6).unwrap();
        let by = BfpBlock::encode(&y, f4).unwrap();
        let got = bfp_dot_blocks(&bx, &by).unwrap();
        let want: f64 = bx
            .decode()
            .iter()
            .zip(&by.decode())
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!((got - want).abs() < 1e-9 * want.abs().max(1.0));
    }
}
