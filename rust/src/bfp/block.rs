//! Packed BFP block storage: `m`-bit two's-complement mantissas + one
//! 10-bit shared exponent per block. This is the wire/storage format an
//! HBFP accelerator would hold in SRAM; [`BfpTensor`] round-trips exactly
//! with [`super::quantize`] and substantiates the memory-footprint claims
//! (bits/value) quoted in the README.

use super::packed::PlaneLayout;
use super::quantize::{floor_log2, Quantizer};
use super::rounding::round_value;
use super::{EXPONENT_MAX, EXPONENT_MIN};
use anyhow::{anyhow, Result};

/// Power-of-two shift of one encoded block's dequantization scale:
/// a mantissa `q` decodes to `q * 2^scale_shift(e, m)` (Eq. 1 interval
/// `2^(e - m + 2)`). The single home of the `+2`; every datapath —
/// scalar blocks, packed planes, the GEMM kernels — derives its scale
/// from here.
#[inline]
pub fn scale_shift(exponent: i32, mantissa_bits: u32) -> i32 {
    exponent - mantissa_bits as i32 + 2
}

/// A BFP format descriptor: mantissa width and block size.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockFormat {
    pub mantissa_bits: u32,
    pub block_size: usize,
}

impl BlockFormat {
    pub fn new(mantissa_bits: u32, block_size: usize) -> Result<Self> {
        if !(2..=16).contains(&mantissa_bits) {
            return Err(anyhow!("mantissa bits {mantissa_bits} out of [2,16]"));
        }
        if block_size == 0 {
            return Err(anyhow!("block size must be positive"));
        }
        Ok(Self {
            mantissa_bits,
            block_size,
        })
    }

    /// Storage bits for one block: b mantissas + the shared exponent.
    pub fn bits_per_block(&self) -> usize {
        self.block_size * self.mantissa_bits as usize + super::EXPONENT_BITS as usize
    }

    pub fn bits_per_value(&self) -> f64 {
        self.bits_per_block() as f64 / self.block_size as f64
    }

    /// Compression ratio vs FP32 storage.
    pub fn compression_vs_fp32(&self) -> f64 {
        32.0 / self.bits_per_value()
    }

    /// Host mantissa-plane storage layout for this format — what
    /// [`super::packed::BfpMatrix`] stores and what the GEMM kernel
    /// registry dispatches on. Mantissas of at most 4 bits pack two
    /// per byte (`I4Packed`) when the block size is even (odd blocks
    /// would start mid-byte; they stay on the byte plane), wider
    /// mantissas take one `i8` (`m <= 8`) or `i16` (`m <= 16`).
    pub fn plane_layout(&self) -> PlaneLayout {
        if self.mantissa_bits <= 4 && self.block_size % 2 == 0 {
            PlaneLayout::I4Packed
        } else if self.mantissa_bits <= 8 {
            PlaneLayout::I8
        } else {
            PlaneLayout::I16
        }
    }

    /// Wire-density storage bits for a `len`-element tensor blocked in
    /// this format (zero-padded tail included). The software layout and
    /// the `hw_model` density arithmetic agree through this number:
    /// `storage_bits(len) / len -> bits_per_value()` as `len` grows.
    pub fn storage_bits(&self, len: usize) -> usize {
        len.div_ceil(self.block_size) * self.bits_per_block()
    }
}

/// One encoded block: integer mantissas + shared exponent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BfpBlock {
    pub exponent: i32,
    /// Two's-complement mantissas in [-2^(m-1), 2^(m-1) - 1].
    pub mantissas: Vec<i32>,
    pub format: BlockFormat,
}

impl BfpBlock {
    /// Encode a block of f32s (round-to-nearest-even).
    pub fn encode(v: &[f32], fmt: BlockFormat) -> Result<Self> {
        Self::encode_with(v, fmt, Quantizer::nearest(fmt.mantissa_bits), 0)
    }

    pub fn encode_with(v: &[f32], fmt: BlockFormat, q: Quantizer, base_idx: u32) -> Result<Self> {
        if v.len() != fmt.block_size {
            return Err(anyhow!("block len {} != format b {}", v.len(), fmt.block_size));
        }
        let maxabs = v.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
        if maxabs < f32::MIN_POSITIVE {
            return Ok(Self {
                exponent: 0,
                mantissas: vec![0; fmt.block_size],
                format: fmt,
            });
        }
        let e = floor_log2(maxabs);
        if !(EXPONENT_MIN..=EXPONENT_MAX).contains(&e) {
            return Err(anyhow!("exponent {e} exceeds the 10-bit shared-exponent range"));
        }
        let m = fmt.mantissa_bits as i32;
        let s = (2.0f64).powi(scale_shift(e, fmt.mantissa_bits)) as f32;
        let half = (1i64 << (m - 1)) as f32;
        let mantissas = v
            .iter()
            .enumerate()
            .map(|(i, &x)| {
                let r = round_value(x / s, q.mode, base_idx.wrapping_add(i as u32), q.seed);
                r.clamp(-half, half - 1.0) as i32
            })
            .collect();
        Ok(Self {
            exponent: e,
            mantissas,
            format: fmt,
        })
    }

    /// Power-of-two shift of this block's dequantization scale (see
    /// [`scale_shift`]).
    #[inline]
    pub fn scale_shift(&self) -> i32 {
        scale_shift(self.exponent, self.format.mantissa_bits)
    }

    /// Decode back to f32: mant * 2^(e - m + 2).
    pub fn decode(&self) -> Vec<f32> {
        let s = (2.0f64).powi(self.scale_shift()) as f32;
        self.mantissas.iter().map(|&q| q as f32 * s).collect()
    }

    /// Pack to a bit stream: 10-bit exponent then b m-bit mantissas.
    pub fn pack(&self) -> Vec<u8> {
        let mut bits = BitWriter::new();
        bits.write((self.exponent - EXPONENT_MIN) as u32, super::EXPONENT_BITS);
        let m = self.format.mantissa_bits;
        let mask = (1u32 << m) - 1;
        for &q in &self.mantissas {
            bits.write((q as u32) & mask, m);
        }
        bits.finish()
    }

    /// Unpack from [`Self::pack`] output.
    pub fn unpack(bytes: &[u8], fmt: BlockFormat) -> Result<Self> {
        let mut r = BitReader::new(bytes);
        let e = r.read(super::EXPONENT_BITS)? as i32 + EXPONENT_MIN;
        let m = fmt.mantissa_bits;
        let sign_bit = 1u32 << (m - 1);
        let mut mantissas = Vec::with_capacity(fmt.block_size);
        for _ in 0..fmt.block_size {
            let raw = r.read(m)?;
            // Sign-extend the m-bit two's-complement value.
            let v = if raw & sign_bit != 0 {
                (raw | !((1u32 << m) - 1)) as i32
            } else {
                raw as i32
            };
            mantissas.push(v);
        }
        Ok(Self {
            exponent: e,
            mantissas,
            format: fmt,
        })
    }
}

/// A whole tensor stored as packed BFP blocks (row-major, zero-padded
/// tail) — what an accelerator's operand SRAM would hold.
#[derive(Debug, Clone)]
pub struct BfpTensor {
    pub format: BlockFormat,
    pub len: usize,
    pub blocks: Vec<BfpBlock>,
}

impl BfpTensor {
    pub fn encode(t: &[f32], fmt: BlockFormat) -> Result<Self> {
        let b = fmt.block_size;
        let mut blocks = Vec::with_capacity(t.len().div_ceil(b));
        let mut buf = vec![0.0f32; b];
        for chunk in t.chunks(b) {
            if chunk.len() == b {
                blocks.push(BfpBlock::encode(chunk, fmt)?);
            } else {
                buf.fill(0.0);
                buf[..chunk.len()].copy_from_slice(chunk);
                blocks.push(BfpBlock::encode(&buf, fmt)?);
            }
        }
        Ok(Self {
            format: fmt,
            len: t.len(),
            blocks,
        })
    }

    pub fn decode(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len);
        for blk in &self.blocks {
            out.extend_from_slice(&blk.decode());
        }
        out.truncate(self.len);
        out
    }

    /// Total storage bits (the memory-saving claim of §4.2).
    pub fn storage_bits(&self) -> usize {
        self.blocks.len() * self.format.bits_per_block()
    }
}

// --- minimal bit I/O -------------------------------------------------------

struct BitWriter {
    bytes: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        Self {
            bytes: Vec::new(),
            acc: 0,
            nbits: 0,
        }
    }

    fn write(&mut self, v: u32, bits: u32) {
        debug_assert!(bits <= 32);
        self.acc |= (v as u64 & ((1u64 << bits) - 1)) << self.nbits;
        self.nbits += bits;
        while self.nbits >= 8 {
            self.bytes.push((self.acc & 0xFF) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.bytes.push((self.acc & 0xFF) as u8);
        }
        self.bytes
    }
}

struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        Self {
            bytes,
            pos: 0,
            acc: 0,
            nbits: 0,
        }
    }

    fn read(&mut self, bits: u32) -> Result<u32> {
        while self.nbits < bits {
            let b = *self
                .bytes
                .get(self.pos)
                .ok_or_else(|| anyhow!("bit stream exhausted"))?;
            self.acc |= (b as u64) << self.nbits;
            self.nbits += 8;
            self.pos += 1;
        }
        let v = (self.acc & ((1u64 << bits) - 1)) as u32;
        self.acc >>= bits;
        self.nbits -= bits;
        Ok(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfp::quantize::quantize_flat;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(1.0)).collect()
    }

    #[test]
    fn format_validation() {
        assert!(BlockFormat::new(1, 16).is_err());
        assert!(BlockFormat::new(4, 0).is_err());
        let f = BlockFormat::new(4, 64).unwrap();
        assert_eq!(f.bits_per_block(), 64 * 4 + 10);
        assert!((f.compression_vs_fp32() - 32.0 / 4.15625).abs() < 1e-12);
    }

    #[test]
    fn encode_decode_equals_quantizer() {
        // Decoding an encoded tensor must equal the float quantizer output
        // exactly: packed BFP is a lossless carrier of quantized values.
        let x = randn(333, 1);
        for (m, b) in [(4u32, 16usize), (6, 64), (8, 49)] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let t = BfpTensor::encode(&x, fmt).unwrap();
            let want = quantize_flat(&x, b, Quantizer::nearest(m), 0);
            assert_eq!(t.decode(), want, "m={m} b={b}");
        }
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let x = randn(64, 2);
        let fmt = BlockFormat::new(5, 64).unwrap();
        let blk = BfpBlock::encode(&x, fmt).unwrap();
        let packed = blk.pack();
        assert_eq!(packed.len(), fmt.bits_per_block().div_ceil(8));
        let back = BfpBlock::unpack(&packed, fmt).unwrap();
        assert_eq!(back, blk);
    }

    #[test]
    fn pack_unpack_negative_mantissas() {
        let fmt = BlockFormat::new(4, 8).unwrap();
        let blk = BfpBlock {
            exponent: -3,
            mantissas: vec![-8, -1, 0, 1, 7, -5, 3, -2],
            format: fmt,
        };
        let back = BfpBlock::unpack(&blk.pack(), fmt).unwrap();
        assert_eq!(back, blk);
    }

    #[test]
    fn storage_accounting() {
        let x = randn(100, 3);
        let fmt = BlockFormat::new(4, 64).unwrap();
        let t = BfpTensor::encode(&x, fmt).unwrap();
        assert_eq!(t.blocks.len(), 2); // 100 -> 2 blocks of 64
        assert_eq!(t.storage_bits(), 2 * (64 * 4 + 10));
        // ~7.4x smaller than FP32 for this tensor.
        let ratio = (100.0 * 32.0) / t.storage_bits() as f64;
        assert!(ratio > 5.9, "{ratio}");
    }

    #[test]
    fn zero_tensor() {
        let fmt = BlockFormat::new(4, 16).unwrap();
        let t = BfpTensor::encode(&[0.0; 20], fmt).unwrap();
        assert_eq!(t.decode(), vec![0.0; 20]);
    }

    #[test]
    fn storage_bits_agrees_with_density_model() {
        // The software layout and the hw_model/§2 density arithmetic
        // must quote the same bits/value as block counts grow.
        for (m, b) in [(4u32, 64usize), (6, 16), (8, 576)] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let n = 64 * b; // whole blocks: exact agreement
            assert_eq!(
                fmt.storage_bits(n) as f64 / n as f64,
                crate::bfp::bits_per_value(m, b),
                "m={m} b={b}"
            );
            let ones = vec![1.0f32; n];
            let t = BfpTensor::encode(&ones, fmt).unwrap();
            assert_eq!(t.storage_bits(), fmt.storage_bits(n));
        }
    }

    #[test]
    fn scale_shift_is_the_decode_scale() {
        let fmt = BlockFormat::new(4, 8).unwrap();
        let blk = BfpBlock::encode(&[1.5f32; 8], fmt).unwrap();
        assert_eq!(blk.scale_shift(), blk.exponent - 4 + 2);
        assert_eq!(scale_shift(0, 4), -2);
        let s = (2.0f64).powi(blk.scale_shift()) as f32;
        assert_eq!(blk.decode()[0], blk.mantissas[0] as f32 * s);
    }
}
