//! Software BFP GEMM — a full fixed-point matrix multiply over encoded
//! operands, the datapath an HBFP accelerator executes and the substrate
//! behind the emulation-vs-hardware cross-checks: `hbfp_gemm` must agree
//! with quantize-then-float-GEMM to f64 rounding, for any (m, b).
//!
//! Layout contract matches the compiled graph (hbfp.py): `x` is blocked
//! row-major (contraction dim K innermost), `w` is blocked along K too
//! (transposed before flattening), both padded with zeros to a block
//! multiple.
//!
//! [`hbfp_gemm`] encodes each operand **once** into a packed
//! [`BfpMatrix`] (structure-of-arrays mantissa/exponent planes) and runs
//! the tiled parallel kernel in [`super::gemm`]. The original per-block
//! triple loop survives as [`hbfp_gemm_scalar`], the bit-identical
//! reference that property tests pin the packed path against.

use super::block::{BfpBlock, BlockFormat};
use super::packed::BfpMatrix;
use super::quantize::Quantizer;
use anyhow::{bail, Result};

/// A [rows, cols] f32 matrix, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if rows * cols != data.len() {
            bail!("shape {rows}x{cols} != {} elems", data.len());
        }
        Ok(Self { rows, cols, data })
    }

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = vec![0.0f32; self.data.len()];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        Mat {
            rows: self.cols,
            cols: self.rows,
            data: out,
        }
    }

    /// Plain f64-accumulated float GEMM (reference).
    pub fn matmul(&self, rhs: &Mat) -> Result<Mat> {
        if self.cols != rhs.rows {
            bail!("inner dims {} vs {}", self.cols, rhs.rows);
        }
        let mut out = Mat::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for j in 0..rhs.cols {
                let mut acc = 0.0f64;
                for k in 0..self.cols {
                    acc += self.at(i, k) as f64 * rhs.at(k, j) as f64;
                }
                out.data[i * rhs.cols + j] = acc as f32;
            }
        }
        Ok(out)
    }
}

/// One operand row encoded as BFP blocks along K (zero-padded tail).
/// `buf` is caller-provided block-size scratch, hoisted so per-row calls
/// allocate only the block Vec itself.
fn encode_row(
    row: &[f32],
    fmt: BlockFormat,
    q: Quantizer,
    base: u32,
    buf: &mut [f32],
) -> Result<Vec<BfpBlock>> {
    let b = fmt.block_size;
    debug_assert_eq!(buf.len(), b);
    let mut blocks = Vec::with_capacity(row.len().div_ceil(b));
    for (bi, chunk) in row.chunks(b).enumerate() {
        let idx = base.wrapping_add((bi * b) as u32);
        if chunk.len() == b {
            blocks.push(BfpBlock::encode_with(chunk, fmt, q, idx)?);
        } else {
            buf.fill(0.0);
            buf[..chunk.len()].copy_from_slice(chunk);
            blocks.push(BfpBlock::encode_with(buf, fmt, q, idx)?);
        }
    }
    Ok(blocks)
}

/// Fixed-point HBFP GEMM: y = Q(x) @ Q(w) with integer MACs per block
/// pair, one exponent add per block pair, FP32 result store.
///
/// Production path (PR 3, pipelined in PR 5): the call is a **session
/// onto the global [`crate::exec::BfpService`]** — the op is submitted
/// through the service's admission loop (blocking admission: this is a
/// synchronous contract), its operands may be **pre-encoded by the
/// service's encode stage while an earlier batch's GEMM still runs**
/// (activations on the pool, the weight operand through the
/// encoded-operand cache, so repeated multiplies against the same
/// weights — the serving/emulation pattern — encode them exactly
/// once), and it executes in the batched stage. Admission order,
/// batch fusion, and the pre-encode race never touch numerics: the
/// result stays bit-identical to [`hbfp_gemm_scalar`]
/// (property-tested).
pub fn hbfp_gemm(x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Mat> {
    if x.cols != w.rows {
        bail!("inner dims {} vs {}", x.cols, w.rows);
    }
    crate::exec::global_service()
        .session("bfp::hbfp_gemm")
        .gemm(x, w, fmt)
}

/// The original per-block scalar GEMM, kept as the reference
/// implementation the packed kernel is cross-checked against. Same
/// numerics, allocation-bound performance.
pub fn hbfp_gemm_scalar(x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Mat> {
    if x.cols != w.rows {
        bail!("inner dims {} vs {}", x.cols, w.rows);
    }
    let q = Quantizer::nearest(fmt.mantissa_bits);
    let mut buf = vec![0.0f32; fmt.block_size];
    // Encode x rows (K innermost) and w columns (transpose first).
    let xrows: Vec<Vec<BfpBlock>> = (0..x.rows)
        .map(|i| encode_row(&x.data[i * x.cols..(i + 1) * x.cols], fmt, q, 0, &mut buf))
        .collect::<Result<_>>()?;
    let wt = w.transpose();
    let wcols: Vec<Vec<BfpBlock>> = (0..wt.rows)
        .map(|j| encode_row(&wt.data[j * wt.cols..(j + 1) * wt.cols], fmt, q, 0, &mut buf))
        .collect::<Result<_>>()?;

    let mut out = Mat::zeros(x.rows, w.cols);
    for (i, xr) in xrows.iter().enumerate() {
        for (j, wc) in wcols.iter().enumerate() {
            let mut acc = 0.0f64;
            for (bx, bw) in xr.iter().zip(wc) {
                // Integer MAC inside the block pair.
                let mut iacc: i64 = 0;
                for (&a, &b) in bx.mantissas.iter().zip(&bw.mantissas) {
                    iacc += a as i64 * b as i64;
                }
                let shift = bx.scale_shift() + bw.scale_shift();
                acc += iacc as f64 * (2.0f64).powi(shift);
            }
            out.data[i * w.cols + j] = acc as f32;
        }
    }
    Ok(out)
}

/// Quantize-then-float reference for [`hbfp_gemm`] (what the compiled
/// emulation graph computes, modulo its f32 accumulation order).
///
/// Consumes the packed encoding directly: `x` decodes in place from its
/// planes, `w` is encoded column-wise and decoded straight back into the
/// `k x n` orientation — no transpose round-trips, no full-matrix
/// clones.
pub fn dequant_gemm(x: &Mat, w: &Mat, fmt: BlockFormat) -> Result<Mat> {
    if x.cols != w.rows {
        bail!("inner dims {} vs {}", x.cols, w.rows);
    }
    let q = Quantizer::nearest(fmt.mantissa_bits);
    let xq = BfpMatrix::encode(&x.data, x.rows, x.cols, fmt, q)?.to_mat();
    // Encode-only session onto the global service: shares the operand
    // cache with `hbfp_gemm`, so comparing the two on the same (w, fmt)
    // encodes the weights once, not twice.
    let wq = crate::exec::global_service()
        .session("bfp::dequant_gemm")
        .encode_transposed_cached(w, fmt)?
        .decode_transposed();
    xq.matmul(&wq)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randmat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::new(
            rows,
            cols,
            (0..rows * cols).map(|_| rng.normal_scaled(1.0)).collect(),
        )
        .unwrap()
    }

    #[test]
    fn fixed_point_gemm_matches_dequant_gemm() {
        for (m, b, (r, k, c)) in [
            (4u32, 16usize, (5usize, 40usize, 7usize)),
            (6, 64, (8, 100, 8)),
            (8, 25, (3, 25, 3)),
        ] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let x = randmat(r, k, 1);
            let w = randmat(k, c, 2);
            let fixed = hbfp_gemm(&x, &w, fmt).unwrap();
            let float = dequant_gemm(&x, &w, fmt).unwrap();
            for (a, bb) in fixed.data.iter().zip(&float.data) {
                assert!(
                    (a - bb).abs() <= 1e-4 * bb.abs().max(1.0),
                    "m={m} b={b}: {a} vs {bb}"
                );
            }
        }
    }

    #[test]
    fn packed_gemm_bit_identical_to_scalar_reference() {
        for (m, b, (r, k, c)) in [
            (4u32, 16usize, (5usize, 40usize, 7usize)),
            (6, 64, (4, 130, 9)),
            (8, 25, (3, 26, 3)),
        ] {
            let fmt = BlockFormat::new(m, b).unwrap();
            let x = randmat(r, k, 11);
            let w = randmat(k, c, 12);
            let packed = hbfp_gemm(&x, &w, fmt).unwrap();
            let scalar = hbfp_gemm_scalar(&x, &w, fmt).unwrap();
            for (i, (a, bb)) in packed.data.iter().zip(&scalar.data).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    bb.to_bits(),
                    "m={m} b={b} elem {i}: {a} vs {bb}"
                );
            }
        }
    }

    #[test]
    fn high_mantissa_approaches_exact() {
        let fmt = BlockFormat::new(12, 16).unwrap();
        let x = randmat(6, 48, 3);
        let w = randmat(48, 5, 4);
        let exact = x.matmul(&w).unwrap();
        let got = hbfp_gemm(&x, &w, fmt).unwrap();
        for (a, b) in got.data.iter().zip(&exact.data) {
            assert!((a - b).abs() < 5e-2, "{a} vs {b}");
        }
    }

    #[test]
    fn shape_errors() {
        let x = randmat(2, 3, 5);
        let w = randmat(4, 2, 6);
        let fmt = BlockFormat::new(4, 16).unwrap();
        assert!(hbfp_gemm(&x, &w, fmt).is_err());
        assert!(hbfp_gemm_scalar(&x, &w, fmt).is_err());
        assert!(dequant_gemm(&x, &w, fmt).is_err());
        assert!(Mat::new(2, 2, vec![0.0; 3]).is_err());
    }

    #[test]
    fn transpose_involution() {
        let x = randmat(3, 7, 8);
        assert_eq!(x.transpose().transpose(), x);
    }

    #[test]
    fn padding_tail_blocks() {
        // K = 10 with b = 16: single padded block per row; GEMM must not
        // pick up padding contributions.
        let fmt = BlockFormat::new(6, 16).unwrap();
        let x = randmat(2, 10, 9);
        let w = randmat(10, 2, 10);
        let got = hbfp_gemm(&x, &w, fmt).unwrap();
        let want = dequant_gemm(&x, &w, fmt).unwrap();
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
}
