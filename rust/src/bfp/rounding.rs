//! Rounding modes. The stochastic path reproduces the counter-based
//! XORshift32 stream of `ref.py` exactly (same u32 algebra) — the same
//! circuit the hardware model prices in `hw_model::converter`.

/// How mantissas are rounded during FP32 -> BFP conversion.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoundMode {
    /// Round half to even (IEEE default); used for forward-pass operands.
    NearestEven,
    /// Unbiased stochastic rounding; the paper's gradient-path choice.
    Stochastic,
}

impl RoundMode {
    /// Runtime-scalar encoding shared with the compiled graph (0/1).
    pub fn as_scalar(self) -> f32 {
        match self {
            RoundMode::NearestEven => 0.0,
            RoundMode::Stochastic => 1.0,
        }
    }
}

/// Counter-based XORshift32 hash; identical to `ref.xorshift_hash`.
#[inline]
pub fn xorshift_hash(idx: u32, seed: u32) -> u32 {
    let mut h = idx
        .wrapping_mul(2654435761)
        .wrapping_add(seed.wrapping_mul(0x9E37_79B9));
    h ^= h << 13;
    h ^= h >> 17;
    h ^= h << 5;
    h
}

/// u in [0, 1) with 24 random bits; identical to `ref.uniform_u01`.
#[inline]
pub fn uniform_u01(idx: u32, seed: u32) -> f32 {
    (xorshift_hash(idx, seed) >> 8) as f32 * (2.0f32).powi(-24)
}

/// Apply the selected rounding to a pre-scaled mantissa value.
#[inline]
pub fn round_value(x: f32, mode: RoundMode, idx: u32, seed: u32) -> f32 {
    match mode {
        RoundMode::NearestEven => x.round_ties_even(),
        RoundMode::Stochastic => (x + uniform_u01(idx, seed)).floor(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_even_ties() {
        assert_eq!(round_value(0.5, RoundMode::NearestEven, 0, 0), 0.0);
        assert_eq!(round_value(1.5, RoundMode::NearestEven, 0, 0), 2.0);
        assert_eq!(round_value(-0.5, RoundMode::NearestEven, 0, 0), 0.0);
        assert_eq!(round_value(2.5, RoundMode::NearestEven, 0, 0), 2.0);
    }

    #[test]
    fn stochastic_bounds() {
        // floor(x + u) is always floor(x) or ceil(x).
        for idx in 0..200u32 {
            let x = 3.3f32;
            let r = round_value(x, RoundMode::Stochastic, idx, 7);
            assert!(r == 3.0 || r == 4.0, "{r}");
        }
    }

    #[test]
    fn stochastic_unbiased() {
        let x = 0.25f32;
        let n = 20_000;
        let sum: f64 = (0..n)
            .map(|i| round_value(x, RoundMode::Stochastic, i, 42) as f64)
            .sum();
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "{mean}");
    }

    #[test]
    fn hash_is_deterministic_and_seed_sensitive() {
        assert_eq!(xorshift_hash(5, 7), xorshift_hash(5, 7));
        assert_ne!(xorshift_hash(5, 7), xorshift_hash(5, 8));
        assert_ne!(xorshift_hash(5, 7), xorshift_hash(6, 7));
    }

    #[test]
    fn u01_in_range() {
        for i in 0..1000 {
            let u = uniform_u01(i, 9);
            assert!((0.0..1.0).contains(&u));
        }
    }
}
