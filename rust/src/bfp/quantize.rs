//! FP32 -> BFP quantization, bit-exact with `python/compile/kernels/ref.py`.
//!
//! Every operation mirrors the jnp reference in f32 arithmetic:
//! exponent extraction reads the IEEE-754 exponent field, the interval is
//! `2^(e - m + 2)` (Eq. 1, [`super::block::scale_shift`]), clipping is to
//! `[-2^(m-1), 2^(m-1) - 1]`, and `m >= 23` is the FP32 bypass. The
//! golden-vector integration test pins this contract across the language
//! boundary.
//!
//! Two equivalent entry points exist: the float-in/float-out
//! [`quantize_flat`] / [`quantize_blocks_into`] here, and the packed
//! [`super::packed::quantize_packed`] path that round-trips through the
//! integer mantissa planes and reuses its buffers across sweep points —
//! identical numerics (property-tested), different storage.

use super::block::scale_shift;
use super::rounding::{round_value, RoundMode};

/// floor(log2(|x|)) via the IEEE exponent field; -127 for zero/denormal.
#[inline]
pub fn floor_log2(x: f32) -> i32 {
    ((x.to_bits() >> 23) & 0xFF) as i32 - 127
}

/// 2^k as f32, exact for the full k range incl. subnormal results
/// (matches jnp.exp2 on integer-valued floats).
#[inline]
pub(crate) fn exp2i(k: i32) -> f32 {
    // f64 powi is exact for k >= -1074; the f32 cast rounds to the nearest
    // representable (subnormal) value exactly like jnp.exp2's f32 output.
    (2.0f64).powi(k) as f32
}

/// One quantization configuration (mantissa width + rounding + stream).
#[derive(Debug, Clone, Copy)]
pub struct Quantizer {
    pub m_bits: u32,
    pub mode: RoundMode,
    pub seed: u32,
}

impl Quantizer {
    pub fn nearest(m_bits: u32) -> Self {
        Self {
            m_bits,
            mode: RoundMode::NearestEven,
            seed: 0,
        }
    }

    pub fn stochastic(m_bits: u32, seed: u32) -> Self {
        Self {
            m_bits,
            mode: RoundMode::Stochastic,
            seed,
        }
    }

    /// FP32 bypass convention (ref.py): m >= 23 is the identity.
    pub fn is_bypass(&self) -> bool {
        self.m_bits >= 23
    }
}

/// Quantize one block of values sharing a single exponent.
///
/// `base_idx` is the global element index of `v[0]` in the enclosing
/// tensor (drives the per-element stochastic rounding stream).
/// Returns the shared exponent actually used (for packing / stats).
pub fn quantize_block_into(v: &[f32], out: &mut [f32], q: Quantizer, base_idx: u32) -> i32 {
    debug_assert_eq!(v.len(), out.len());
    if q.is_bypass() {
        out.copy_from_slice(v);
        return 0;
    }
    let mut maxabs = 0.0f32;
    for &x in v {
        let a = x.abs();
        if a > maxabs {
            maxabs = a;
        }
    }
    // Zero / denormal-max blocks dequantize to exactly 0.
    if maxabs < exp2i(-126) {
        out.fill(0.0);
        return 0;
    }
    let e = floor_log2(maxabs);
    let m = q.m_bits as i32;
    let s = exp2i(scale_shift(e, q.m_bits)); // Eq. 1 interval
    let half = exp2i(m - 1); // 2^(m-1)
    let lo = -half;
    let hi = half - 1.0;
    // Hot-path: dividing by an exact power of two equals multiplying by
    // its (exactly representable) reciprocal — bit-identical per IEEE-754,
    // ~1.9x faster (EXPERIMENTS.md §Perf). Fall back to division when the
    // reciprocal exponent leaves the normal range.
    let sinv_e = -scale_shift(e, q.m_bits);
    let sinv = if (-126..=127).contains(&sinv_e) {
        Some(exp2i(sinv_e))
    } else {
        None
    };
    match (q.mode, sinv) {
        (RoundMode::NearestEven, Some(si)) => {
            for (&x, o) in v.iter().zip(out.iter_mut()) {
                *o = (x * si).round_ties_even().clamp(lo, hi) * s;
            }
        }
        (RoundMode::Stochastic, Some(si)) => {
            for (i, (&x, o)) in v.iter().zip(out.iter_mut()).enumerate() {
                let idx = base_idx.wrapping_add(i as u32);
                let u = super::rounding::uniform_u01(idx, q.seed);
                *o = (x * si + u).floor().clamp(lo, hi) * s;
            }
        }
        (_, None) => {
            for (i, (&x, o)) in v.iter().zip(out.iter_mut()).enumerate() {
                let idx = base_idx.wrapping_add(i as u32);
                let r = round_value(x / s, q.mode, idx, q.seed);
                *o = r.clamp(lo, hi) * s;
            }
        }
    }
    e
}

/// Quantize a (nblocks, b) row-major buffer in place-ish (into `out`).
pub fn quantize_blocks_into(v: &[f32], block: usize, out: &mut [f32], q: Quantizer, base: u32) {
    debug_assert_eq!(v.len() % block, 0);
    for (bi, (src, dst)) in v.chunks(block).zip(out.chunks_mut(block)).enumerate() {
        quantize_block_into(src, dst, q, base.wrapping_add((bi * block) as u32));
    }
}

/// Quantize an arbitrary-length tensor in row-major blocks of `block`
/// with zero padding at the tail — identical semantics (and stochastic
/// stream) to `ref.quantize_flat`.
pub fn quantize_flat(t: &[f32], block: usize, q: Quantizer, site: u32) -> Vec<f32> {
    // Salt < 2^24 per site: survives the f32 round-trip on the jax side.
    let base = site.wrapping_mul(40503);
    let n = t.len();
    let mut out = vec![0.0f32; n];
    let full = n / block * block;
    quantize_blocks_into(&t[..full], block, &mut out[..full], q, base);
    if full < n {
        // Tail block: pad with zeros (padding never changes max|v| upward
        // ... it can only lower it to 0 for an all-pad block).
        let mut vbuf = vec![0.0f32; block];
        vbuf[..n - full].copy_from_slice(&t[full..]);
        let mut obuf = vec![0.0f32; block];
        quantize_block_into(&vbuf, &mut obuf, q, base.wrapping_add(full as u32));
        out[full..].copy_from_slice(&obuf[..n - full]);
    }
    out
}

/// Convenience: quantize a tensor (as stored, row-major) and return the
/// result — the exact transform the compiled graph applies to a forward
/// operand with the contraction axis innermost.
pub fn quantize_tensor(t: &[f32], block: usize, m_bits: u32) -> Vec<f32> {
    quantize_flat(t, block, Quantizer::nearest(m_bits), 0)
}

/// Sum of squared quantization error (distortion diagnostic).
pub fn sq_error(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal_scaled(scale as f64)).collect()
    }

    #[test]
    fn floor_log2_matches_ieee() {
        assert_eq!(floor_log2(1.0), 0);
        assert_eq!(floor_log2(1.9), 0);
        assert_eq!(floor_log2(2.0), 1);
        assert_eq!(floor_log2(0.5), -1);
        assert_eq!(floor_log2(3.7e4), 15);
        assert_eq!(floor_log2(0.0), -127);
    }

    #[test]
    fn exp2i_exact() {
        assert_eq!(exp2i(0), 1.0);
        assert_eq!(exp2i(10), 1024.0);
        assert_eq!(exp2i(-126), f32::MIN_POSITIVE);
        assert!(exp2i(-130) > 0.0 && exp2i(-130) < f32::MIN_POSITIVE); // subnormal
    }

    #[test]
    fn bypass_is_identity() {
        let x = randn(100, 1, 1.0);
        assert_eq!(quantize_flat(&x, 16, Quantizer::nearest(23), 0), x);
        assert_eq!(quantize_flat(&x, 16, Quantizer::nearest(32), 0), x);
    }

    #[test]
    fn zero_block() {
        let x = vec![0.0f32; 32];
        assert_eq!(quantize_flat(&x, 16, Quantizer::nearest(4), 0), x);
    }

    #[test]
    fn error_bound_nearest() {
        let x = randn(256, 2, 1.0);
        for m in [3u32, 4, 6, 8] {
            let out = quantize_flat(&x, 64, Quantizer::nearest(m), 0);
            for (blk, (xs, os)) in x.chunks(64).zip(out.chunks(64)).enumerate() {
                let maxabs = xs.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let interval = exp2i(floor_log2(maxabs) - m as i32 + 2);
                for (x, o) in xs.iter().zip(os) {
                    assert!(
                        (x - o).abs() <= interval,
                        "m={m} blk={blk} x={x} o={o} interval={interval}"
                    );
                }
            }
        }
    }

    #[test]
    fn idempotent() {
        let x = randn(300, 3, 2.0);
        for m in [4u32, 6] {
            let once = quantize_flat(&x, 49, Quantizer::nearest(m), 0);
            let twice = quantize_flat(&once, 49, Quantizer::nearest(m), 0);
            assert_eq!(once, twice);
        }
    }

    #[test]
    fn shared_exponent_kills_small_values() {
        let mut x = vec![1e-3f32; 16];
        x[0] = 1024.0;
        let out = quantize_flat(&x, 16, Quantizer::nearest(4), 0);
        assert_eq!(out[0], 1024.0);
        assert!(out[1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn error_monotone_in_mantissa() {
        let x = randn(4096, 4, 1.0);
        let mut prev = f64::INFINITY;
        for m in [2u32, 3, 4, 5, 6, 8, 10] {
            let e = sq_error(&x, &quantize_flat(&x, 64, Quantizer::nearest(m), 0));
            assert!(e <= prev + 1e-9, "m={m}: {e} > {prev}");
            prev = e;
        }
    }

    #[test]
    fn stochastic_seed_sensitivity_and_determinism() {
        let x = randn(128, 5, 1.0);
        let a = quantize_flat(&x, 64, Quantizer::stochastic(4, 1), 0);
        let b = quantize_flat(&x, 64, Quantizer::stochastic(4, 2), 0);
        let a2 = quantize_flat(&x, 64, Quantizer::stochastic(4, 1), 0);
        assert_eq!(a, a2);
        assert_ne!(a, b);
    }

    #[test]
    fn tail_padding_matches_explicit_pad() {
        let x = randn(70, 6, 1.0);
        let q = Quantizer::nearest(4);
        let out = quantize_flat(&x, 64, q, 0);
        let mut padded = x.clone();
        padded.resize(128, 0.0);
        let full = quantize_flat(&padded, 64, q, 0);
        assert_eq!(out, &full[..70]);
    }

    #[test]
    fn powers_of_two_survive() {
        for e in [-10i32, -1, 0, 1, 7] {
            let x = vec![exp2i(e); 16];
            let out = quantize_flat(&x, 16, Quantizer::nearest(6), 0);
            assert_eq!(out, x);
        }
    }
}
